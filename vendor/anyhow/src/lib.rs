//! Offline in-tree stand-in for the `anyhow` crate.
//!
//! The build environment has no registry access, so this vendored crate
//! carries the subset of anyhow's API the workspace actually uses:
//!
//! - [`Error`]: an opaque error holding a message-context chain,
//! - [`Result`] with the defaulted error parameter,
//! - [`anyhow!`] / [`bail!`] / [`ensure!`] macros,
//! - the [`Context`] extension trait (`.context` / `.with_context`) for
//!   `Result` and `Option`,
//! - the blanket `From<E: std::error::Error>` conversion that makes `?`
//!   work on `io::Error`, `ParseIntError`, etc.
//!
//! Display conventions mirror anyhow: `{}` shows the outermost message,
//! `{:#}` the whole chain joined with `": "`.

use std::error::Error as StdError;
use std::fmt;

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An error: a chain of messages, outermost context first.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a single displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Wrap with an outer context message.
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages in the chain, outermost first.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(|s| s.as_str())
    }

    /// The outermost message.
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

// `Error` deliberately does not implement `std::error::Error`, which is
// what makes this blanket conversion coherent (same trick as real anyhow).
impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context` / `.with_context` to `Result` and
/// `Option`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: StdError + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(context))
    }
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with an error built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        bail!("inner {}", 42)
    }

    #[test]
    fn display_outermost_alternate_chain() {
        let e = fails().unwrap_err().context("outer");
        assert_eq!(format!("{e}"), "outer");
        assert_eq!(format!("{e:#}"), "outer: inner 42");
    }

    #[test]
    fn question_mark_on_std_errors() {
        fn go() -> Result<u32> {
            let n: u32 = "12".parse()?;
            Ok(n)
        }
        assert_eq!(go().unwrap(), 12);
        fn bad() -> Result<u32> {
            let n: u32 = "nope".parse()?;
            Ok(n)
        }
        assert!(bad().is_err());
    }

    #[test]
    fn with_context_on_io() {
        let r = std::fs::read_to_string("/definitely/not/here")
            .with_context(|| "reading config".to_string());
        let e = r.unwrap_err();
        assert!(format!("{e:#}").starts_with("reading config: "));
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert!(v.context("missing").is_err());
        assert_eq!(Some(7u32).context("missing").unwrap(), 7);
    }

    #[test]
    fn ensure_macro() {
        fn check(x: u32) -> Result<()> {
            ensure!(x < 10, "x too big: {x}");
            Ok(())
        }
        assert!(check(5).is_ok());
        assert!(check(50).is_err());
    }
}
