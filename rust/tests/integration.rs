//! Cross-language integration tests: the AOT artifacts (python/JAX/Pallas
//! → HLO text) must reproduce the rust bit-accurate application semantics
//! exactly, and the coordinator must serve them end-to-end.
//!
//! These tests need `make artifacts` to have run; they skip (with a
//! message) when the artifact directory is missing so `cargo test` works
//! on a fresh checkout.

use ppc::apps::frnn::{io as frnn_io, net};
use ppc::apps::image::Image;
use ppc::apps::{blend, gdf};
use ppc::coordinator::{Coordinator, CoordinatorConfig, Job, Quality};
use ppc::ppc::preprocess::{Chain, Preproc};
use ppc::runtime::Runtime;
use ppc::util::prng::Rng;
use std::path::{Path, PathBuf};

fn artifacts_dir() -> Option<PathBuf> {
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

fn random_image(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(256) as i32).collect()
}

#[test]
fn gdf_artifact_matches_bit_accurate_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_app(&dir, "gdf").unwrap();
    let meta = rt.meta("gdf/conv").unwrap().clone();
    let (h, w) = (meta.inputs[0].dims[0], meta.inputs[0].dims[1]);
    let mut rng = Rng::new(0x61);
    let flat = random_image(&mut rng, h * w);
    let img = Image {
        width: w,
        height: h,
        pixels: flat.iter().map(|&v| v as u8).collect(),
    };
    for (config, chain) in [
        ("conv", Chain::id()),
        ("ds16", Chain::of(Preproc::Ds(16))),
        ("ds32", Chain::of(Preproc::Ds(32))),
    ] {
        let out = rt.exec_i32(&format!("gdf/{config}"), &[&flat]).unwrap();
        let expect = gdf::gdf_filter(&img, &chain);
        let got: Vec<u8> = out[0].iter().map(|&v| v as u8).collect();
        assert_eq!(got, expect.pixels, "gdf/{config} mismatch");
    }
}

#[test]
fn blend_artifact_matches_bit_accurate_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_app(&dir, "blend").unwrap();
    let meta = rt.meta("blend/conv").unwrap().clone();
    let (h, w) = (meta.inputs[0].dims[0], meta.inputs[0].dims[1]);
    let mut rng = Rng::new(0x62);
    let f1 = random_image(&mut rng, h * w);
    let f2 = random_image(&mut rng, h * w);
    let mk = |f: &[i32]| Image {
        width: w,
        height: h,
        pixels: f.iter().map(|&v| v as u8).collect(),
    };
    let (i1, i2) = (mk(&f1), mk(&f2));
    let alpha = 64i32;
    for (config, chain) in [
        ("conv", Chain::id()),
        ("ds16", Chain::of(Preproc::Ds(16))),
        ("ds32", Chain::of(Preproc::Ds(32))),
    ] {
        let out = rt
            .exec_i32(&format!("blend/{config}"), &[&f1, &f2, &[alpha]])
            .unwrap();
        let expect = blend::blend_images(
            &i1,
            &i2,
            blend::Alpha(alpha as u8),
            &chain,
            &chain,
        );
        let got: Vec<u8> = out[0].iter().map(|&v| v as u8).collect();
        assert_eq!(got, expect.pixels, "blend/{config} mismatch");
    }
}

#[test]
fn frnn_artifact_matches_bit_accurate_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let weights_path = dir.join("frnn_weights.json");
    if !weights_path.exists() {
        eprintln!("skipping: frnn weights not trained");
        return;
    }
    let rt = Runtime::load_app(&dir, "frnn").unwrap();
    let meta = rt.meta("frnn/conv").unwrap().clone();
    let (batch, row) = (meta.inputs[0].dims[0], meta.inputs[0].dims[1]);
    assert_eq!(row, 960);
    let mut rng = Rng::new(0x63);
    let pixels: Vec<i32> = (0..batch * row).map(|_| rng.below(160) as i32).collect();

    let configs: Vec<(&str, Chain, Chain)> = vec![
        ("conv", Chain::id(), Chain::id()),
        (
            "th48ds16",
            Chain::of(Preproc::Th { x: 48, y: 48 }).then(Preproc::Ds(16)),
            Chain::of(Preproc::Ds(16)),
        ),
        (
            "ds32",
            Chain::of(Preproc::Ds(32)),
            Chain::of(Preproc::Ds(32)),
        ),
    ];
    for (config, ci, cw) in configs {
        // each serving config bakes its own fine-tuned weights
        let wp = if config == "conv" {
            weights_path.clone()
        } else {
            dir.join(format!("frnn_weights_{config}.json"))
        };
        let float_net = frnn_io::load_weights(&wp).unwrap();
        let q = net::quantize(&float_net);
        let out = rt.exec_i32(&format!("frnn/{config}"), &[&pixels]).unwrap();
        assert_eq!(out[0].len(), batch * 7);
        for b in 0..batch {
            let face = ppc::apps::frnn::dataset::Face {
                pixels: pixels[b * row..(b + 1) * row].iter().map(|&v| v as u8).collect(),
                id: 0,
                pose: 0,
                sunglasses: false,
            };
            let (_, outs) = net::forward_fx(&q, &face, &ci, &cw);
            let got: Vec<u8> = out[0][b * 7..(b + 1) * 7].iter().map(|&v| v as u8).collect();
            assert_eq!(got, outs.to_vec(), "frnn/{config} row {b} mismatch");
        }
    }
}

#[test]
fn coordinator_serves_all_apps_from_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::with_artifacts(&dir, CoordinatorConfig::default()).unwrap();
    let mut rng = Rng::new(0x64);
    let img_len = {
        let rt_meta = Runtime::load_app(&dir, "gdf").unwrap();
        let m = rt_meta.meta("gdf/conv").unwrap().clone();
        m.inputs[0].dims[0] * m.inputs[0].dims[1]
    };
    // mixed workload across qualities
    let mut tickets = Vec::new();
    for i in 0..9 {
        let q = [Quality::Precise, Quality::Balanced, Quality::Economy][i % 3];
        let job = match i % 3 {
            0 => Job::Denoise { image: random_image(&mut rng, img_len) },
            1 => Job::Blend {
                p1: random_image(&mut rng, img_len),
                p2: random_image(&mut rng, img_len),
                alpha: 32,
            },
            _ => Job::Classify {
                pixels: (0..960).map(|_| rng.below(160) as i32).collect(),
            },
        };
        tickets.push((i, coord.submit_blocking(job, q).unwrap()));
    }
    for (i, t) in tickets {
        let r = t.wait().unwrap_or_else(|e| panic!("request {i}: {e:#}"));
        assert!(!r.outputs[0].is_empty());
    }
    assert_eq!(coord.metrics().completed(), 9);
    assert_eq!(coord.metrics().errors(), 0);
}

#[test]
fn runtime_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_app(&dir, "gdf").unwrap();
    assert!(rt.exec_i32("gdf/conv", &[&[1, 2, 3]]).is_err());
    assert!(rt.exec_i32("gdf/nope", &[&[]]).is_err());
}

#[test]
fn pgm_figures_roundtrip() {
    // figure writers produce readable PGMs (no artifacts needed)
    let dir = std::env::temp_dir().join("ppc_fig_test");
    let rows = ppc::tables::figures::fig6(&dir).unwrap();
    assert_eq!(rows.len(), 3);
    let img = Image::read_pgm(&dir.join("fig6_out_ds16.pgm")).unwrap();
    assert_eq!(img.width, 256);
    let _ = Path::new("x");
}
