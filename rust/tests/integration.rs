//! Integration tests.
//!
//! Native-backend tests (always on): the coordinator — router, bounded
//! queue, dynamic batcher, engine thread — serves the *synthesized PPC
//! netlists* through `NativeExecutor`, bit-exact with the fixed-point
//! application simulations, with graceful errors on unknown keys. Plus
//! property tests holding the bit-parallel interpreted netlist oracle
//! against the scalar walk, and the 256-lane compiled-tape serving
//! path against both per-request `exec` and the fixed-point
//! application oracles, for every registered catalog key.
//!
//! PJRT tests (feature `pjrt` + `make artifacts`): the AOT artifacts
//! (python/JAX/Pallas → HLO text) must reproduce the rust bit-accurate
//! application semantics exactly; they skip with a message when the
//! artifact directory is missing so `cargo test` works on a fresh
//! checkout.

use ppc::apps::frnn::{io as frnn_io, net};
use ppc::apps::image::Image;
use ppc::apps::{blend, gdf};
use ppc::catalog::{ModelKey, PpcConfig, Tensor};
use ppc::coordinator::{Coordinator, CoordinatorConfig, Job, Quality};
use ppc::ppc::preprocess::{Chain, Preproc};
use ppc::runtime::Runtime;
use ppc::util::prng::Rng;
use std::path::{Path, PathBuf};
use std::time::Duration;

fn mk(s: &str) -> ModelKey {
    ModelKey::parse(s).unwrap()
}

fn artifacts_dir() -> Option<PathBuf> {
    if !cfg!(feature = "pjrt") {
        eprintln!("skipping: built without the `pjrt` feature");
        return None;
    }
    let dir = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if dir.join("manifest.json").exists() {
        Some(dir)
    } else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        None
    }
}

// ---------------------------------------------------------------------
// Native backend: batcher → engine → NativeExecutor, no XLA/Python
// ---------------------------------------------------------------------

/// The coordinator serves the synthesized PPC adder datapath (GDF)
/// end-to-end: submissions route to the typed `gdf/ds32` key, execute
/// on the gate netlists, and come back bit-exact with `gdf_filter` —
/// exactness on the care set. Unknown keys (unregistered
/// configs/apps) fail gracefully with the available catalog in the
/// message and leave the coordinator serving.
#[test]
fn native_coordinator_serves_ppc_adders_end_to_end() {
    use ppc::runtime::NativeExecutor;
    let exec = NativeExecutor::new().register(mk("gdf/ds32")).unwrap();
    let cfg = CoordinatorConfig {
        queue_capacity: 16,
        batch_size: 4,
        classify_row: 960,
        batch_max_wait: Duration::from_millis(2),
        shards: 1,
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::with_native(cfg, exec).unwrap();

    let mut rng = Rng::new(0x17);
    let img = Image {
        width: 20,
        height: 20,
        pixels: (0..400).map(|_| rng.below(256) as u8).collect(),
    };
    let t = coord
        .submit(Job::Denoise { image: img.to_tensor() }, Quality::Economy)
        .unwrap();
    let r = t.wait().unwrap();
    assert_eq!(r.route, mk("gdf/ds32"));
    let want = gdf::gdf_filter(&img, &PpcConfig::Ds32.chain());
    assert_eq!(
        r.outputs[0],
        want.to_tensor(),
        "netlist serving path diverged from the fixed-point sim"
    );

    // gdf/ds16 is not registered → structured error listing the
    // catalog, coordinator stays up
    let t = coord
        .submit(Job::Denoise { image: img.to_tensor() }, Quality::Balanced)
        .unwrap();
    let err = t.wait().unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown model gdf/ds16"), "{msg}");
    assert!(msg.contains("available models: [gdf/ds32]"), "{msg}");
    // unregistered app through the *batcher* path (classify flushes on
    // deadline, the engine reports the unknown key per pending request)
    let t = coord
        .submit(Job::Classify { pixels: vec![0; 960] }, Quality::Economy)
        .unwrap();
    let err = t.wait_timeout(Duration::from_secs(5)).unwrap_err();
    assert!(format!("{err:#}").contains("unknown model frnn/ds32"), "{err:#}");
    assert!(coord.metrics().errors() >= 2);

    // still serving after the failures
    let t = coord.submit(Job::Denoise { image: img.to_tensor() }, Quality::Economy).unwrap();
    assert!(t.wait().is_ok());
}

/// Non-square images flow end-to-end through the coordinator on the
/// shape-carrying `Tensor` (the square-only limitation is gone); flat
/// non-square requests still fail with a structured hint.
#[test]
fn native_coordinator_serves_non_square_images() {
    use ppc::runtime::NativeExecutor;
    let exec = NativeExecutor::new().register(mk("gdf/ds32")).unwrap();
    let coord = Coordinator::with_native(CoordinatorConfig::default(), exec).unwrap();
    let mut rng = Rng::new(0x2D);
    let img = Image {
        width: 31,
        height: 9,
        pixels: (0..31 * 9).map(|_| rng.below(256) as u8).collect(),
    };
    let t = coord
        .submit(Job::Denoise { image: img.to_tensor() }, Quality::Economy)
        .unwrap();
    let r = t.wait().unwrap();
    assert_eq!(r.outputs[0].shape, vec![9, 31], "response keeps the [h, w] shape");
    assert_eq!(r.outputs[0], gdf::gdf_filter(&img, &PpcConfig::Ds32.chain()).to_tensor());

    // the legacy flat convention still cannot express 31×9 — the error
    // says how to fix it
    let flat: Vec<i32> = img.pixels.iter().map(|&p| p as i32).collect();
    let t = coord
        .submit(Job::Denoise { image: Tensor::vector(flat) }, Quality::Economy)
        .unwrap();
    let err = t.wait().unwrap_err();
    assert!(format!("{err:#}").contains("not square"), "{err:#}");
}

/// Classify requests batch up (batcher → engine → NativeExecutor) and
/// scatter back per-row results that match the bit-accurate
/// `forward_fx` — the full three-layer stack on the FRNN with zero
/// artifacts.
#[test]
fn native_coordinator_batches_classify_requests() {
    use ppc::apps::frnn::dataset;
    use ppc::runtime::NativeExecutor;
    let ds = dataset::generate(2, 0xE2E);
    let r = net::train(&ds, &net::TrainConfig { max_epochs: 6, ..Default::default() });
    let q = net::quantize(&r.net);
    let exec = NativeExecutor::new()
        .register_frnn(PpcConfig::Ds32, q.clone())
        .unwrap();
    let cfg = CoordinatorConfig {
        queue_capacity: 16,
        batch_size: 3,
        classify_row: 960,
        batch_max_wait: Duration::from_millis(2),
        shards: 1,
        ..CoordinatorConfig::default()
    };
    let coord = Coordinator::with_native(cfg, exec).unwrap();

    let faces: Vec<_> = ds.test.iter().take(3).cloned().collect();
    let tickets: Vec<_> = faces
        .iter()
        .map(|f| {
            let pixels: Vec<i32> = f.pixels.iter().map(|&p| p as i32).collect();
            coord.submit(Job::Classify { pixels }, Quality::Economy).unwrap()
        })
        .collect();
    let ci = Chain::of(Preproc::Ds(32));
    let cw = Chain::of(Preproc::Ds(32));
    for (f, t) in faces.iter().zip(tickets) {
        let r = t.wait_timeout(Duration::from_secs(60)).unwrap();
        assert_eq!(r.route, mk("frnn/ds32"));
        let (_, want) = net::forward_fx(&q, f, &ci, &cw);
        let got: Vec<u8> = r.outputs[0].data.iter().map(|&v| v as u8).collect();
        assert_eq!(got, want.to_vec(), "served FRNN row diverged from forward_fx");
    }
    assert!(coord.metrics().mean_batch_size() >= 1.0);
    assert_eq!(coord.metrics().errors(), 0);
}

/// Property test: the bit-parallel interpreted netlist oracle
/// (`eval64`) agrees with the scalar walk on random pattern batches (a
/// synthesized 4-bit adder segment — NAND/AOI/XOR-heavy mapped logic).
#[test]
fn bit_parallel_eval_matches_scalar_on_random_patterns() {
    use ppc::logic::map::Objective;
    use ppc::logic::synth::{self, BlockSpec};
    use ppc::util::propcheck::forall;
    let spec = BlockSpec::from_fn(
        9,
        5,
        "prop_add4c",
        |m| (m & 15) + ((m >> 4) & 15) + (m >> 8),
        |_| true,
    );
    let (_, nl) = synth::synthesize(&spec, Objective::Area);
    forall(
        0xB17,
        64,
        |r| -> Vec<u64> { (0..64).map(|_| r.below(512)).collect() },
        |ms| {
            let batch = nl.eval64_minterms(ms);
            ms.iter().zip(&batch).all(|(&m, &got)| got == nl.eval(m))
        },
    );
}

fn random_image(rng: &mut Rng, n: usize) -> Vec<i32> {
    (0..n).map(|_| rng.below(256) as i32).collect()
}

/// One random request for `key`'s application: small random-shape
/// images for GDF/blend (with a random alpha), one random 960-pixel
/// face row for the FRNN.
fn random_request(rng: &mut Rng, key: ModelKey) -> Vec<Tensor> {
    use ppc::catalog::App;
    match key.app {
        App::Gdf => {
            let (h, w) = (2 + rng.below(5) as usize, 2 + rng.below(6) as usize);
            vec![Tensor::matrix(h, w, random_image(rng, h * w)).unwrap()]
        }
        App::Blend => {
            let (h, w) = (2 + rng.below(4) as usize, 2 + rng.below(5) as usize);
            vec![
                Tensor::matrix(h, w, random_image(rng, h * w)).unwrap(),
                Tensor::matrix(h, w, random_image(rng, h * w)).unwrap(),
                Tensor::scalar(rng.below(128) as i32),
            ]
        }
        App::Frnn => vec![Tensor { shape: vec![1, 960], data: random_image(rng, 960) }],
    }
}

/// Property: `exec_batch` is bit-exact with N independent `exec` calls
/// for random batch sizes in 1..=200, asserted for **every registered
/// ModelKey** (the default native serving catalog — both GDF configs,
/// both blend configs, both deployed FRNN configs).
#[test]
fn exec_batch_bit_exact_with_scalar_exec_for_every_registered_model() {
    use ppc::apps::frnn::dataset;
    use ppc::catalog::App;
    use ppc::coordinator::Executor;
    use ppc::runtime::NativeExecutor;
    let ds = dataset::generate(2, 0xBA7C);
    let r = net::train(&ds, &net::TrainConfig { max_epochs: 6, ..Default::default() });
    let q = net::quantize(&r.net);
    let exec = NativeExecutor::new()
        .register(mk("gdf/ds16"))
        .unwrap()
        .register(mk("gdf/ds32"))
        .unwrap()
        .register(mk("blend/ds16"))
        .unwrap()
        .register(mk("blend/ds32"))
        .unwrap()
        .register_frnn(PpcConfig::Th48Ds16, q.clone())
        .unwrap()
        .register_frnn(PpcConfig::Ds32, q)
        .unwrap();
    assert_eq!(exec.keys().len(), 6);
    let mut rng = Rng::new(0x64EC);
    for key in exec.keys() {
        // one tiny, one sub-word, one past-the-256-lane-word-boundary
        // batch for the image apps (the FRNN's forwards dominate
        // runtime, so its batches stay small)
        let (mid, large) = if key.app == App::Frnn {
            (2 + rng.below(20) as usize, 65 + rng.below(8) as usize)
        } else {
            (2 + rng.below(62) as usize, 257 + rng.below(16) as usize)
        };
        for n in [1usize, mid, large] {
            let batch: Vec<Vec<Tensor>> =
                (0..n).map(|_| random_request(&mut rng, key)).collect();
            let got = exec.exec_batch(key, &batch).unwrap();
            assert_eq!(got.len(), n, "{key}: batch of {n}");
            for (i, inputs) in batch.iter().enumerate() {
                let want = exec.exec(key, inputs).unwrap();
                assert_eq!(got[i], want, "{key}: request {i} of a {n}-batch diverged");
            }
        }
    }
}

/// Property: chunk-parallel `exec_batch` is bit-exact across thread
/// counts — the same batch executed with the batch-thread override at
/// 1 and at 4 must produce identical bytes for **every registered
/// ModelKey**. LANES-aligned chunking keeps the per-pass lane grouping
/// (and therefore the don't-care resolutions) identical at any thread
/// count; this is the observable proof.
#[test]
fn exec_batch_bit_exact_at_one_and_four_threads_for_every_registered_model() {
    use ppc::apps::frnn::dataset;
    use ppc::catalog::App;
    use ppc::coordinator::Executor;
    use ppc::runtime::NativeExecutor;
    use ppc::util::pool;
    let ds = dataset::generate(2, 0x7D41);
    let r = net::train(&ds, &net::TrainConfig { max_epochs: 6, ..Default::default() });
    let q = net::quantize(&r.net);
    let exec = NativeExecutor::new()
        .register(mk("gdf/ds16"))
        .unwrap()
        .register(mk("gdf/ds32"))
        .unwrap()
        .register(mk("blend/ds16"))
        .unwrap()
        .register(mk("blend/ds32"))
        .unwrap()
        .register_frnn(PpcConfig::Th48Ds16, q.clone())
        .unwrap()
        .register_frnn(PpcConfig::Ds32, q)
        .unwrap();
    let mut rng = Rng::new(0x7442);
    // the override is process-global: serialize with the other tests
    // that assert under a specific thread count
    let _guard = pool::batch_threads_test_lock();
    for key in exec.keys() {
        // image-app batches reach past one 256-lane word so every
        // worker sees whole lane blocks; FRNN forwards are pricier, so
        // its batch stays small (layer 1 still splits across faces)
        let n = if key.app == App::Frnn { 6 } else { 300 };
        let batch: Vec<Vec<Tensor>> =
            (0..n).map(|_| random_request(&mut rng, key)).collect();
        pool::set_batch_threads(1);
        let serial = exec.exec_batch(key, &batch).unwrap();
        pool::set_batch_threads(4);
        let parallel = exec.exec_batch(key, &batch).unwrap();
        assert_eq!(serial, parallel, "{key}: thread count changed the bits");
    }
    pool::set_batch_threads(0);
}

/// Compiled-tape serving vs the fixed-point application oracles, for
/// **every registered catalog key**: the 256-lane compiled netlist
/// path behind `exec_batch` must reproduce `gdf_filter`,
/// `blend_images`, and `forward_fx` bit-for-bit — on image-app batches
/// sized past the full 256-lane word, so the widened `[u64; 4]` tape
/// pass (not just the narrow 64-lane fallback) is what's checked.
#[test]
fn compiled_tape_serving_matches_the_fixed_point_oracles_for_every_key() {
    use ppc::apps::frnn::dataset;
    use ppc::catalog::App;
    use ppc::coordinator::Executor;
    use ppc::runtime::NativeExecutor;
    let ds = dataset::generate(2, 0xC0DE);
    let r = net::train(&ds, &net::TrainConfig { max_epochs: 6, ..Default::default() });
    let q = net::quantize(&r.net);
    let exec = NativeExecutor::new()
        .register(mk("gdf/ds16"))
        .unwrap()
        .register(mk("gdf/ds32"))
        .unwrap()
        .register(mk("blend/ds16"))
        .unwrap()
        .register(mk("blend/ds32"))
        .unwrap()
        .register_frnn(PpcConfig::Th48Ds16, q.clone())
        .unwrap()
        .register_frnn(PpcConfig::Ds32, q.clone())
        .unwrap();
    let to_img = |t: &Tensor| Image {
        width: t.shape[1],
        height: t.shape[0],
        pixels: t.data.iter().map(|&v| v as u8).collect(),
    };
    let mut rng = Rng::new(0x257);
    for key in exec.keys() {
        // straddle the 256-lane word for the image apps; the FRNN's
        // forwards dominate runtime, so its batch stays small
        let n = if key.app == App::Frnn { 9 } else { 257 };
        let batch: Vec<Vec<Tensor>> = (0..n).map(|_| random_request(&mut rng, key)).collect();
        let got = exec.exec_batch(key, &batch).unwrap();
        assert_eq!(got.len(), n);
        let chain = key.config.chain();
        for (i, inputs) in batch.iter().enumerate() {
            match key.app {
                App::Gdf => {
                    let want = gdf::gdf_filter(&to_img(&inputs[0]), &chain).to_tensor();
                    assert_eq!(got[i][0], want, "{key}: request {i} diverged from gdf_filter");
                }
                App::Blend => {
                    let want = blend::blend_images(
                        &to_img(&inputs[0]),
                        &to_img(&inputs[1]),
                        blend::Alpha(inputs[2].data[0] as u8),
                        &chain,
                        &chain,
                    )
                    .to_tensor();
                    assert_eq!(got[i][0], want, "{key}: request {i} diverged from blend_images");
                }
                App::Frnn => {
                    let face = dataset::Face {
                        pixels: inputs[0].data.iter().map(|&v| v as u8).collect(),
                        id: 0,
                        pose: 0,
                        sunglasses: false,
                    };
                    let (_, want) =
                        net::forward_fx(&q, &face, &chain, &key.config.weight_chain());
                    let bytes: Vec<u8> = got[i][0].data.iter().map(|&v| v as u8).collect();
                    assert_eq!(
                        bytes,
                        want.to_vec(),
                        "{key}: request {i} diverged from forward_fx"
                    );
                }
            }
        }
    }
}

/// Two engine shards built from the same persistent netlist cache
/// serve concurrent lane-batched GDF traffic bit-exactly; the second
/// shard's registry loads entirely warm.
#[test]
fn sharded_native_coordinator_serves_from_shared_cache() {
    use ppc::runtime::NativeExecutor;
    let dir = std::env::temp_dir()
        .join(format!("ppc_shard_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let cfg = CoordinatorConfig {
        queue_capacity: 256,
        batch_size: 8,
        classify_row: 960,
        batch_max_wait: Duration::from_millis(2),
        shards: 2,
        ..CoordinatorConfig::default()
    };
    let cache = dir.clone();
    let coord = Coordinator::with_native_sharded(cfg, move |_shard| {
        NativeExecutor::new().with_cache(&cache)?.register(mk("gdf/ds32"))
    })
    .unwrap();

    let mut rng = Rng::new(0x5A);
    let imgs: Vec<Image> = (0..24)
        .map(|i| Image {
            width: 6 + i % 5,
            height: 4 + i % 3,
            pixels: (0..(6 + i % 5) * (4 + i % 3))
                .map(|_| rng.below(256) as u8)
                .collect(),
        })
        .collect();
    let batch = coord
        .submit_all(
            imgs.iter()
                .map(|im| (Job::Denoise { image: im.to_tensor() }, Quality::Economy)),
        )
        .unwrap();
    let responses = batch.wait().unwrap();
    for (img, r) in imgs.iter().zip(&responses) {
        assert_eq!(r.route, mk("gdf/ds32"));
        assert_eq!(
            r.outputs[0],
            gdf::gdf_filter(img, &PpcConfig::Ds32.chain()).to_tensor(),
            "sharded lane-batched serving diverged from the fixed-point sim"
        );
    }
    assert_eq!(coord.metrics().errors(), 0);
    assert!(
        coord.metrics().mean_batch_size() > 1.0,
        "whole-batch routing should produce multi-request batches"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance for sticky placement: a 6-model catalog over 4 shards
/// with one replica each. Every shard eagerly builds at most
/// ceil(6/4) = 2 datapaths (asserted via per-shard residency), yet all
/// six models answer bit-exactly through the coordinator — the catalog
/// no longer multiplies by the shard count.
#[test]
fn placed_shards_build_subsets_and_serve_the_whole_catalog() {
    use ppc::apps::frnn::dataset;
    use ppc::coordinator::Placement;
    use ppc::runtime::NativeExecutor;
    let dir = std::env::temp_dir().join(format!("ppc_placed_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);

    let keys = [
        mk("gdf/ds16"),
        mk("gdf/ds32"),
        mk("blend/ds16"),
        mk("blend/ds32"),
        mk("frnn/th48ds16"),
        mk("frnn/ds32"),
    ];
    let ds = dataset::generate(2, 0x9F1A);
    let r = net::train(&ds, &net::TrainConfig { max_epochs: 6, ..Default::default() });
    let q = net::quantize(&r.net);

    let placement = Placement::spread(&keys, 4, 1);
    let cfg = CoordinatorConfig {
        queue_capacity: 64,
        batch_size: 8,
        classify_row: 960,
        batch_max_wait: Duration::from_millis(2),
        shards: 4,
        ..CoordinatorConfig::default()
    };
    let cache = dir.clone();
    let quant = q.clone();
    let coord = Coordinator::with_native_placed(cfg, placement, move |_shard, assigned| {
        let mut exec = NativeExecutor::new().with_cache(&cache)?;
        for key in [
            mk("gdf/ds16"),
            mk("gdf/ds32"),
            mk("blend/ds16"),
            mk("blend/ds32"),
        ] {
            exec = exec.declare(key)?;
        }
        exec = exec
            .declare_frnn(PpcConfig::Th48Ds16, quant.clone())?
            .declare_frnn(PpcConfig::Ds32, quant.clone())?;
        exec.with_keys(assigned)
    })
    .unwrap();

    // every shard built at most 2 datapaths; the whole catalog is
    // resident exactly once across the pool
    let resident = coord.resident_keys().unwrap();
    assert_eq!(resident.len(), 4);
    for (shard, models) in resident.iter().enumerate() {
        assert!(
            models.len() <= 2,
            "shard {shard} built {} datapaths (subset sharding must cap it at 2)",
            models.len()
        );
    }
    assert_eq!(resident.iter().map(|m| m.len()).sum::<usize>(), 6);
    let mut all: Vec<_> = resident.into_iter().flatten().collect();
    all.sort();
    let mut want = keys.to_vec();
    want.sort();
    assert_eq!(all, want, "each model resident on exactly its sticky shard");
    // the servable union is still the whole catalog
    let mut served = coord.registered_keys().unwrap();
    served.sort();
    assert_eq!(served, want);

    // …and every model answers bit-exactly through the coordinator
    let mut rng = Rng::new(0x51C);
    let img = Image {
        width: 11,
        height: 7,
        pixels: (0..77).map(|_| rng.below(256) as u8).collect(),
    };
    let img2 = Image {
        width: 11,
        height: 7,
        pixels: (0..77).map(|_| rng.below(256) as u8).collect(),
    };
    let face = ds.test[0].clone();
    for quality in [Quality::Balanced, Quality::Economy] {
        let (ci, cw) = match quality {
            Quality::Balanced => (
                Chain::of(Preproc::Th { x: 48, y: 48 }).then(Preproc::Ds(16)),
                Chain::of(Preproc::Ds(16)),
            ),
            _ => (Chain::of(Preproc::Ds(32)), Chain::of(Preproc::Ds(32))),
        };
        let pixel_chain = match quality {
            Quality::Balanced => Chain::of(Preproc::Ds(16)),
            _ => Chain::of(Preproc::Ds(32)),
        };

        let t = coord
            .submit_blocking(Job::Denoise { image: img.to_tensor() }, quality)
            .unwrap();
        assert_eq!(
            t.wait().unwrap().outputs[0],
            gdf::gdf_filter(&img, &pixel_chain).to_tensor(),
            "gdf {quality:?} diverged"
        );

        let t = coord
            .submit_blocking(
                Job::Blend { p1: img.to_tensor(), p2: img2.to_tensor(), alpha: 48 },
                quality,
            )
            .unwrap();
        assert_eq!(
            t.wait().unwrap().outputs[0],
            blend::blend_images(&img, &img2, blend::Alpha(48), &pixel_chain, &pixel_chain)
                .to_tensor(),
            "blend {quality:?} diverged"
        );

        let pixels: Vec<i32> = face.pixels.iter().map(|&p| p as i32).collect();
        let t = coord.submit_blocking(Job::Classify { pixels }, quality).unwrap();
        let got: Vec<u8> = t
            .wait_timeout(Duration::from_secs(60))
            .unwrap()
            .outputs[0]
            .data
            .iter()
            .map(|&v| v as u8)
            .collect();
        let (_, want) = net::forward_fx(&q, &face, &ci, &cw);
        assert_eq!(got, want.to_vec(), "frnn {quality:?} diverged");
    }
    assert_eq!(coord.metrics().errors(), 0);
    assert_eq!(coord.metrics().spills(), 0, "an idle pool never spills");
    let _ = std::fs::remove_dir_all(&dir);
}

/// A replica shard whose executor factory fails does not take its
/// models down: the placed pool marks it dead, routes the key's
/// batches to a live shard, and that shard lazily registers the
/// datapath from the shared netlist cache — requests still answer
/// bit-exactly.
#[test]
fn shard_build_failure_fails_over_via_lazy_registration() {
    use ppc::coordinator::Placement;
    use ppc::runtime::NativeExecutor;
    let dir = std::env::temp_dir().join(format!("ppc_failover_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // warm the cache so the lazy failover build is a BLIF load
    NativeExecutor::new()
        .with_cache(&dir)
        .unwrap()
        .register(mk("gdf/ds16"))
        .unwrap()
        .register(mk("gdf/ds32"))
        .unwrap();

    let keys = [mk("gdf/ds16"), mk("gdf/ds32")];
    let placement = Placement::spread(&keys, 2, 1)
        .assign(mk("gdf/ds16"), &[0])
        .unwrap()
        .assign(mk("gdf/ds32"), &[1])
        .unwrap();
    let cfg = CoordinatorConfig {
        queue_capacity: 32,
        batch_size: 4,
        classify_row: 960,
        batch_max_wait: Duration::from_millis(2),
        shards: 2,
        ..CoordinatorConfig::default()
    };
    let cache = dir.clone();
    let coord = Coordinator::with_native_placed(cfg, placement, move |shard, assigned| {
        if shard == 1 {
            anyhow::bail!("simulated shard build failure");
        }
        NativeExecutor::new()
            .with_cache(&cache)?
            .declare(mk("gdf/ds16"))?
            .declare(mk("gdf/ds32"))?
            .with_keys(assigned)
    })
    .unwrap();

    // shard 1 (the gdf/ds32 owner) is dead; shard 0 starts with only
    // its own subset resident
    let resident = coord.resident_keys().unwrap();
    assert_eq!(resident[0], vec![mk("gdf/ds16")]);
    assert!(resident[1].is_empty(), "dead shard holds nothing");

    // a request for the dead shard's model still answers, bit-exactly,
    // via lazy registration on the live shard
    let mut rng = Rng::new(0xFA11);
    let img = Image {
        width: 9,
        height: 6,
        pixels: (0..54).map(|_| rng.below(256) as u8).collect(),
    };
    let t = coord
        .submit_blocking(Job::Denoise { image: img.to_tensor() }, Quality::Economy)
        .unwrap();
    assert_eq!(
        t.wait().unwrap().outputs[0],
        gdf::gdf_filter(&img, &PpcConfig::Ds32.chain()).to_tensor(),
        "failover serving diverged"
    );
    let resident = coord.resident_keys().unwrap();
    assert!(
        resident[0].contains(&mk("gdf/ds32")),
        "the live shard lazily registered the dead shard's model"
    );
    assert!(coord.metrics().spills() >= 1, "failover counts as off-replica traffic");
    assert_eq!(coord.metrics().errors(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn gdf_artifact_matches_bit_accurate_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_app(&dir, "gdf").unwrap();
    let meta = rt.meta("gdf/conv").unwrap().clone();
    let (h, w) = (meta.inputs[0].dims[0], meta.inputs[0].dims[1]);
    let mut rng = Rng::new(0x61);
    let flat = random_image(&mut rng, h * w);
    let img = Image {
        width: w,
        height: h,
        pixels: flat.iter().map(|&v| v as u8).collect(),
    };
    for (config, chain) in [
        ("conv", Chain::id()),
        ("ds16", Chain::of(Preproc::Ds(16))),
        ("ds32", Chain::of(Preproc::Ds(32))),
    ] {
        let out = rt.exec_i32(&format!("gdf/{config}"), &[&flat]).unwrap();
        let expect = gdf::gdf_filter(&img, &chain);
        let got: Vec<u8> = out[0].iter().map(|&v| v as u8).collect();
        assert_eq!(got, expect.pixels, "gdf/{config} mismatch");
    }
}

#[test]
fn blend_artifact_matches_bit_accurate_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_app(&dir, "blend").unwrap();
    let meta = rt.meta("blend/conv").unwrap().clone();
    let (h, w) = (meta.inputs[0].dims[0], meta.inputs[0].dims[1]);
    let mut rng = Rng::new(0x62);
    let f1 = random_image(&mut rng, h * w);
    let f2 = random_image(&mut rng, h * w);
    let mk = |f: &[i32]| Image {
        width: w,
        height: h,
        pixels: f.iter().map(|&v| v as u8).collect(),
    };
    let (i1, i2) = (mk(&f1), mk(&f2));
    let alpha = 64i32;
    for (config, chain) in [
        ("conv", Chain::id()),
        ("ds16", Chain::of(Preproc::Ds(16))),
        ("ds32", Chain::of(Preproc::Ds(32))),
    ] {
        let out = rt
            .exec_i32(&format!("blend/{config}"), &[&f1, &f2, &[alpha]])
            .unwrap();
        let expect = blend::blend_images(
            &i1,
            &i2,
            blend::Alpha(alpha as u8),
            &chain,
            &chain,
        );
        let got: Vec<u8> = out[0].iter().map(|&v| v as u8).collect();
        assert_eq!(got, expect.pixels, "blend/{config} mismatch");
    }
}

#[test]
fn frnn_artifact_matches_bit_accurate_rust() {
    let Some(dir) = artifacts_dir() else { return };
    let weights_path = dir.join("frnn_weights.json");
    if !weights_path.exists() {
        eprintln!("skipping: frnn weights not trained");
        return;
    }
    let rt = Runtime::load_app(&dir, "frnn").unwrap();
    let meta = rt.meta("frnn/conv").unwrap().clone();
    let (batch, row) = (meta.inputs[0].dims[0], meta.inputs[0].dims[1]);
    assert_eq!(row, 960);
    let mut rng = Rng::new(0x63);
    let pixels: Vec<i32> = (0..batch * row).map(|_| rng.below(160) as i32).collect();

    let configs: Vec<(&str, Chain, Chain)> = vec![
        ("conv", Chain::id(), Chain::id()),
        (
            "th48ds16",
            Chain::of(Preproc::Th { x: 48, y: 48 }).then(Preproc::Ds(16)),
            Chain::of(Preproc::Ds(16)),
        ),
        (
            "ds32",
            Chain::of(Preproc::Ds(32)),
            Chain::of(Preproc::Ds(32)),
        ),
    ];
    for (config, ci, cw) in configs {
        // each serving config bakes its own fine-tuned weights
        let wp = if config == "conv" {
            weights_path.clone()
        } else {
            dir.join(format!("frnn_weights_{config}.json"))
        };
        let float_net = frnn_io::load_weights(&wp).unwrap();
        let q = net::quantize(&float_net);
        let out = rt.exec_i32(&format!("frnn/{config}"), &[&pixels]).unwrap();
        assert_eq!(out[0].len(), batch * 7);
        for b in 0..batch {
            let face = ppc::apps::frnn::dataset::Face {
                pixels: pixels[b * row..(b + 1) * row].iter().map(|&v| v as u8).collect(),
                id: 0,
                pose: 0,
                sunglasses: false,
            };
            let (_, outs) = net::forward_fx(&q, &face, &ci, &cw);
            let got: Vec<u8> = out[0][b * 7..(b + 1) * 7].iter().map(|&v| v as u8).collect();
            assert_eq!(got, outs.to_vec(), "frnn/{config} row {b} mismatch");
        }
    }
}

#[test]
fn coordinator_serves_all_apps_from_artifacts() {
    let Some(dir) = artifacts_dir() else { return };
    let coord = Coordinator::with_artifacts(&dir, CoordinatorConfig::default()).unwrap();
    let mut rng = Rng::new(0x64);
    let img_len = {
        let rt_meta = Runtime::load_app(&dir, "gdf").unwrap();
        let m = rt_meta.meta("gdf/conv").unwrap().clone();
        m.inputs[0].dims[0] * m.inputs[0].dims[1]
    };
    // mixed workload across qualities
    let mut tickets = Vec::new();
    for i in 0..9 {
        let q = [Quality::Precise, Quality::Balanced, Quality::Economy][i % 3];
        let job = match i % 3 {
            0 => Job::Denoise { image: Tensor::vector(random_image(&mut rng, img_len)) },
            1 => Job::Blend {
                p1: Tensor::vector(random_image(&mut rng, img_len)),
                p2: Tensor::vector(random_image(&mut rng, img_len)),
                alpha: 32,
            },
            _ => Job::Classify {
                pixels: (0..960).map(|_| rng.below(160) as i32).collect(),
            },
        };
        tickets.push((i, coord.submit_blocking(job, q).unwrap()));
    }
    for (i, t) in tickets {
        let r = t.wait().unwrap_or_else(|e| panic!("request {i}: {e:#}"));
        assert!(!r.outputs[0].data.is_empty());
    }
    assert_eq!(coord.metrics().completed(), 9);
    assert_eq!(coord.metrics().errors(), 0);
}

#[test]
fn runtime_rejects_bad_shapes() {
    let Some(dir) = artifacts_dir() else { return };
    let rt = Runtime::load_app(&dir, "gdf").unwrap();
    assert!(rt.exec_i32("gdf/conv", &[&[1, 2, 3]]).is_err());
    assert!(rt.exec_i32("gdf/nope", &[&[]]).is_err());
}

// ---------------------------------------------------------------------
// Admission control: stress + overload-degrade property tests.
// Gated behind `--ignored` and run as a separate release-mode CI step
// (`cargo test --release -- --ignored stress`).
// ---------------------------------------------------------------------

/// Tentpole acceptance: many threads hammering *every* submit path
/// (`submit`, `submit_blocking`, `submit_deadline`, `submit_all`)
/// against a tiny `queue_capacity` and a slow shard. The observed
/// in-flight high-water mark must never exceed the cap — the old
/// `submit_blocking` bypass is gone — and every request must resolve
/// (answered, shed, or expired; none lost, none hung).
#[test]
#[ignore = "stress: run in release via `cargo test --release -- --ignored stress`"]
fn stress_every_submit_path_respects_the_inflight_cap() {
    use ppc::coordinator::{ExpiredAt, MockExecutor, OverloadPolicy, Rejection, SubmitError};
    use std::sync::atomic::{AtomicU64, Ordering};
    use std::sync::Arc;
    use std::time::Instant;
    const CAP: usize = 4;
    const THREADS: usize = 8;
    const WAVES: usize = 30;
    let cfg = CoordinatorConfig {
        queue_capacity: CAP,
        batch_size: 4,
        classify_row: 8,
        batch_max_wait: Duration::from_millis(1),
        shards: 1,
        overload: OverloadPolicy::Wait,
        fair_share: 1.0,
        autopilot: None,
    };
    let coord = Arc::new(
        Coordinator::start(cfg, |_shard| {
            let mut m = MockExecutor::full_catalog();
            // slow shard: without the gate, blocking submitters would
            // grow the shard queue far past the cap
            m.delay = Duration::from_millis(2);
            Ok(m)
        })
        .unwrap(),
    );
    let attempts = Arc::new(AtomicU64::new(0));
    let answered = Arc::new(AtomicU64::new(0));
    let shed = Arc::new(AtomicU64::new(0));
    let expired = Arc::new(AtomicU64::new(0));
    let mut handles = Vec::new();
    for t in 0..THREADS {
        let c = coord.clone();
        let attempts = attempts.clone();
        let answered = answered.clone();
        let shed = shed.clone();
        let expired = expired.clone();
        handles.push(std::thread::spawn(move || {
            let img = |v: i32| Job::Denoise { image: Tensor::vector(vec![v * 2]) };
            let settle = |r: anyhow::Result<ppc::coordinator::Response>| match r {
                Ok(_) => answered.fetch_add(1, Ordering::Relaxed),
                Err(e) => match e.downcast_ref::<Rejection>() {
                    Some(Rejection::DeadlineExpired) => expired.fetch_add(1, Ordering::Relaxed),
                    Some(Rejection::Shed) => shed.fetch_add(1, Ordering::Relaxed),
                    Some(Rejection::UnknownModel) | None => {
                        panic!("request lost to an unexpected error: {e:#}")
                    }
                },
            };
            for w in 0..WAVES {
                let v = (t * WAVES + w) as i32;
                match w % 3 {
                    0 => {
                        // a whole batch of blocking submits
                        attempts.fetch_add(3, Ordering::Relaxed);
                        let batch = c
                            .submit_all((0..3).map(|k| (img(v + k), Quality::Economy)))
                            .expect("wait policy never sheds blocking submits");
                        for r in batch.wait_each() {
                            settle(r);
                        }
                    }
                    1 => {
                        // one blocking submit + one non-blocking shove
                        attempts.fetch_add(2, Ordering::Relaxed);
                        let ticket = c
                            .submit_blocking(img(v), Quality::Economy)
                            .expect("wait policy never sheds blocking submits");
                        match c.submit(img(v), Quality::Balanced) {
                            Ok(extra) => settle(extra.wait()),
                            Err(SubmitError::Busy) => {
                                shed.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(e) => panic!("unexpected submit error {e:?}"),
                        }
                        settle(ticket.wait());
                    }
                    _ => {
                        // a deadline submit: must answer or expire, never hang
                        attempts.fetch_add(1, Ordering::Relaxed);
                        match c.submit_deadline(
                            img(v),
                            Quality::Economy,
                            Instant::now() + Duration::from_millis(30),
                        ) {
                            Ok(ticket) => settle(ticket.wait()),
                            Err(SubmitError::Expired) => {
                                expired.fetch_add(1, Ordering::Relaxed);
                            }
                            Err(e) => panic!("unexpected submit error {e:?}"),
                        }
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let attempts = attempts.load(Ordering::Relaxed);
    let answered = answered.load(Ordering::Relaxed);
    let shed = shed.load(Ordering::Relaxed);
    let expired = expired.load(Ordering::Relaxed);
    assert_eq!(
        answered + shed + expired,
        attempts,
        "every request must resolve: {answered} answered + {shed} shed + {expired} expired \
         != {attempts} attempts"
    );
    let m = coord.metrics();
    assert!(
        m.peak_in_flight() <= CAP as u64,
        "in-flight high-water mark {} exceeded queue_capacity {CAP}",
        m.peak_in_flight()
    );
    assert!(m.peak_in_flight() >= 2, "the stress load never actually concurrent?");
    // pipeline accounting reconciles: every submitted request resolved
    assert_eq!(answered, m.completed());
    assert_eq!(
        m.submitted(),
        m.completed()
            + m.errors()
            + m.expired_at(ExpiredAt::Queue)
            + m.expired_at(ExpiredAt::Shard)
    );
    assert_eq!(m.errors(), 0);
    // all permits returned once the dust settles
    for _ in 0..500 {
        if coord.admission().in_flight() == 0 {
            break;
        }
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(coord.admission().in_flight(), 0, "admission permits leaked");
}

/// Degrade-policy property: under a saturating balanced-tier workload,
/// every response served from the degraded tier is bit-exact with a
/// *direct* `Executor::exec` at that degraded quality's key — the
/// overload path bends quality, never correctness.
#[test]
#[ignore = "stress: run in release via `cargo test --release -- --ignored stress`"]
fn stress_degrade_overload_serves_bit_exact_lower_tiers() {
    use ppc::coordinator::{Executor, OverloadPolicy, Rejection, SubmitError};
    use ppc::runtime::NativeExecutor;
    use std::sync::{mpsc, Arc};
    let dir = std::env::temp_dir().join(format!("ppc_degrade_cache_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    // the reference executor doubles as the cache warmer, so the
    // coordinator shard below builds warm
    let reference = NativeExecutor::new()
        .with_cache(&dir)
        .unwrap()
        .register(mk("gdf/ds16"))
        .unwrap()
        .register(mk("gdf/ds32"))
        .unwrap();
    let cfg = CoordinatorConfig {
        queue_capacity: 2,
        batch_size: 4,
        classify_row: 960,
        batch_max_wait: Duration::from_millis(1),
        shards: 1,
        overload: OverloadPolicy::Degrade,
        fair_share: 0.5, // one key holds at most 1 of the 2 permits
        autopilot: None,
    };
    let cache = dir.clone();
    let coord = Arc::new(
        Coordinator::with_native_sharded(cfg, move |_shard| {
            NativeExecutor::new()
                .with_cache(&cache)?
                .register(mk("gdf/ds16"))?
                .register(mk("gdf/ds32"))
        })
        .unwrap(),
    );
    let (sink, results) = mpsc::channel();
    let mut handles = Vec::new();
    for t in 0..8u64 {
        let c = coord.clone();
        let sink = sink.clone();
        handles.push(std::thread::spawn(move || {
            let mut rng = Rng::new(0xD16 + t);
            for _ in 0..32 {
                let (h, w) = (4 + rng.below(8) as usize, 4 + rng.below(8) as usize);
                let img = Image {
                    width: w,
                    height: h,
                    pixels: (0..h * w).map(|_| rng.below(256) as u8).collect(),
                };
                // every request asks for Balanced; overload degrades
                match c.submit_blocking(Job::Denoise { image: img.to_tensor() }, Quality::Balanced)
                {
                    Ok(ticket) => match ticket.wait() {
                        Ok(r) => sink.send((img.clone(), r)).unwrap(),
                        Err(e) => match e.downcast_ref::<Rejection>() {
                            Some(_) => {}
                            None => panic!("unexpected serve error: {e:#}"),
                        },
                    },
                    Err(SubmitError::Shed) => {}
                    Err(e) => panic!("unexpected submit error {e:?}"),
                }
            }
        }));
    }
    drop(sink);
    for h in handles {
        h.join().unwrap();
    }
    let mut served = 0usize;
    let mut degraded_seen = 0usize;
    while let Ok((img, r)) = results.recv() {
        served += 1;
        assert!(
            r.route == mk("gdf/ds16") || r.route == mk("gdf/ds32"),
            "unexpected route {}",
            r.route
        );
        assert_eq!(r.degraded, r.route == mk("gdf/ds32"), "degraded flag names the route");
        if r.degraded {
            degraded_seen += 1;
        }
        // the property: whatever tier answered, the response is
        // bit-exact with a direct exec at that tier's key
        let want = reference.exec(r.route, &[img.to_tensor()]).unwrap();
        assert_eq!(r.outputs, want, "served {} response diverged from direct exec", r.route);
    }
    assert!(served > 0, "saturated pool served nothing");
    assert!(
        degraded_seen >= 1,
        "a saturating balanced workload over cap 2 / share 1 never degraded \
         ({served} served, {} metric degrades)",
        coord.metrics().degrades()
    );
    assert_eq!(coord.metrics().errors(), 0);
    let _ = std::fs::remove_dir_all(&dir);
}

/// Controller dynamics end to end: a steady saturating load makes the
/// autopilot walk the serving tier down until it settles on one tier
/// (no flapping while the pressure holds), and removing the load
/// brings it back to `Precise` within a bounded number of ticks.
#[test]
fn autopilot_settles_under_saturation_and_recovers_on_idle() {
    use ppc::catalog::App;
    use ppc::coordinator::{
        Autopilot, AutopilotConfig, Executor, MockExecutor, OverloadPolicy, SubmitError,
    };
    use std::collections::BTreeMap;
    use std::sync::Arc;
    use std::time::Instant;

    let keys = vec![mk("gdf/conv"), mk("gdf/ds16"), mk("gdf/ds32")];
    let probe = MockExecutor::full_catalog();
    let mut profiles = BTreeMap::new();
    for k in &keys {
        profiles.insert(*k, probe.quality(*k).unwrap());
    }
    let ap = Arc::new(Autopilot::new(
        AutopilotConfig {
            tick: Duration::from_millis(5),
            refractory: Duration::from_millis(30),
            ..AutopilotConfig::default()
        },
        keys,
        profiles,
        4,
    ));
    let cfg = CoordinatorConfig {
        queue_capacity: 4,
        batch_size: 4,
        classify_row: 960,
        batch_max_wait: Duration::from_millis(1),
        shards: 1,
        overload: OverloadPolicy::Reject,
        fair_share: 1.0,
        autopilot: Some(ap.clone()),
    };
    let coord = Coordinator::start(cfg, |_shard| {
        let mut m = MockExecutor::full_catalog();
        m.delay = Duration::from_millis(5);
        Ok(m)
    })
    .unwrap();

    // saturate: submit far faster than the shard drains; the gate
    // pins in-flight at the cap, so the tick sees pressure 1.0
    let t_load = Instant::now();
    let mut rng = Rng::new(0xA9);
    let mut tickets = Vec::new();
    let mut settled: Option<(Quality, u64)> = None;
    while t_load.elapsed() < Duration::from_millis(400) {
        let px: Vec<i32> = (0..16).map(|_| rng.below(256) as i32).collect();
        let image = Tensor::matrix(4, 4, px).unwrap();
        match coord.submit(Job::Denoise { image }, Quality::Precise) {
            Ok(t) => tickets.push(t),
            Err(SubmitError::Busy) | Err(SubmitError::Shed) => {}
            Err(e) => panic!("unexpected submit outcome {e:?}"),
        }
        if t_load.elapsed() > Duration::from_millis(250) && settled.is_none() {
            settled = Some((ap.current(App::Gdf), ap.transitions()));
        }
        std::thread::sleep(Duration::from_micros(200));
    }
    let (tier_under_load, moves_by_250ms) = settled.unwrap();
    assert_eq!(
        tier_under_load,
        Quality::Economy,
        "steady saturation settles on the lowest registered tier"
    );
    assert_eq!(ap.current(App::Gdf), Quality::Economy, "still settled at the window's end");
    assert_eq!(ap.transitions(), moves_by_250ms, "no flapping under steady pressure");

    // every answer names the tier that actually served it, with its
    // measured quality riding along
    let mut below_precise = 0usize;
    for t in tickets {
        let r = t.wait().unwrap();
        assert_eq!(r.tier, r.route.tier(), "response tier names the serving key");
        assert!(r.quality.is_some(), "a measured tier reports quality");
        if r.tier != Quality::Precise {
            below_precise += 1;
        }
    }
    assert!(below_precise > 0, "saturated traffic was steered below Precise");

    // load removed: recovery to Precise within a bounded tick budget
    let tick = ap.config().tick;
    let deadline = Instant::now() + tick * 400;
    while ap.current(App::Gdf) != Quality::Precise && Instant::now() < deadline {
        std::thread::sleep(tick);
    }
    assert_eq!(
        ap.current(App::Gdf),
        Quality::Precise,
        "the controller recovers to Precise within 400 ticks of load removal"
    );
}

#[test]
fn pgm_figures_roundtrip() {
    // figure writers produce readable PGMs (no artifacts needed)
    let dir = std::env::temp_dir().join("ppc_fig_test");
    let rows = ppc::tables::figures::fig6(&dir).unwrap();
    assert_eq!(rows.len(), 3);
    let img = Image::read_pgm(&dir.join("fig6_out_ds16.pgm")).unwrap();
    assert_eq!(img.width, 256);
    let _ = Path::new("x");
}
