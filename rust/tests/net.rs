//! Network front-door integration tests, all over real loopback
//! sockets: concurrent wire clients must be bit-exact with in-process
//! `Coordinator::submit` for every registered catalog key; overload,
//! deadline and unknown-model outcomes must come back as *typed*
//! frames (never hangs or bare disconnects); and protocol violations
//! (malformed / oversized / truncated frames) must be survivable
//! exactly where the framing layer promises.

use ppc::catalog::{App, ModelKey, Quality, Tensor};
use ppc::coordinator::{
    Coordinator, CoordinatorConfig, Job, MockExecutor, OverloadPolicy, Rejection,
};
use ppc::net::proto::{self, ClientFrame, FrameReader, Request, ServerFrame, MAX_FRAME};
use ppc::net::server::{NetServer, NetServerConfig};
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

/// FRNN row length all these tests use (small keeps frames cheap).
const ROW: usize = 8;

fn base_config() -> CoordinatorConfig {
    CoordinatorConfig {
        queue_capacity: 256,
        batch_size: 4,
        classify_row: ROW,
        batch_max_wait: Duration::from_millis(1),
        shards: 2,
        ..CoordinatorConfig::default()
    }
}

/// Spawn a mock-backed coordinator + TCP server. `keys: None` serves
/// the full catalog; `delay` slows every batch to force overlap.
fn spawn_mock(
    cfg: CoordinatorConfig,
    keys: Option<Vec<ModelKey>>,
    delay: Duration,
    net: NetServerConfig,
) -> (Arc<Coordinator>, NetServer) {
    let coord = Arc::new(
        Coordinator::start(cfg, move |_shard| {
            let mut e = match &keys {
                Some(k) => MockExecutor::new(k),
                None => MockExecutor::full_catalog(),
            };
            e.delay = delay;
            Ok(e)
        })
        .unwrap(),
    );
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let server = NetServer::spawn(listener, coord.clone(), net).unwrap();
    (coord, server)
}

/// Deterministic payload for `(app, seed)` — both the wire client and
/// the in-process reference build the exact same job from these.
fn job_for(app: App, seed: i32) -> Job {
    let base: Vec<i32> = (0..4).map(|i| (seed + i).rem_euclid(256)).collect();
    match app {
        App::Gdf => Job::Denoise { image: Tensor::matrix(2, 2, base).unwrap() },
        App::Blend => Job::Blend {
            p1: Tensor::matrix(2, 2, base.clone()).unwrap(),
            p2: Tensor::matrix(2, 2, base.iter().map(|v| (v + 7) % 256).collect()).unwrap(),
            alpha: 64,
        },
        App::Frnn => {
            Job::Classify { pixels: (0..ROW as i32).map(|i| (seed + i).rem_euclid(160)).collect() }
        }
    }
}

/// Every (app, quality) combo with a stable pipelined id.
fn combos() -> Vec<(u64, App, Quality)> {
    let mut v = Vec::new();
    for (ai, app) in App::ALL.into_iter().enumerate() {
        for (qi, quality) in Quality::ALL.into_iter().enumerate() {
            v.push(((ai * Quality::ALL.len() + qi) as u64, app, quality));
        }
    }
    v
}

/// Read one server frame, bounded so a wedged server fails the test
/// instead of hanging it (needs a read timeout on the stream).
fn read_frame_within(reader: &mut FrameReader<TcpStream>, within: Duration) -> ServerFrame {
    let t0 = Instant::now();
    loop {
        match reader.poll_frame() {
            Ok(Some(j)) => return ServerFrame::from_json(&j).unwrap(),
            Ok(None) => assert!(t0.elapsed() < within, "no frame within {within:?}"),
            Err(e) => panic!("read failed: {e}"),
        }
    }
}

/// The loopback ground truth: N concurrent TCP clients, each
/// pipelining one request per (app, quality) combo, must get back
/// exactly what the same jobs produce through in-process
/// `Coordinator::submit` — same route, same `degraded` flag, same
/// output tensors, for every registered key.
#[test]
fn concurrent_wire_clients_match_in_process_submit_for_every_key() {
    const CLIENTS: usize = 4;
    let (coord, server) =
        spawn_mock(base_config(), None, Duration::ZERO, NetServerConfig::default());
    let addr = server.local_addr();

    let handles: Vec<_> = (0..CLIENTS)
        .map(|client| {
            thread::spawn(move || {
                let mut w = TcpStream::connect(addr).unwrap();
                let _ = w.set_nodelay(true);
                let r = w.try_clone().unwrap();
                let _ = r.set_read_timeout(Some(Duration::from_millis(100)));
                let combos = combos();
                // pipelined: every request goes out before any reply is read
                for &(id, app, quality) in &combos {
                    let req = Request {
                        id,
                        job: job_for(app, (client * 100) as i32 + id as i32),
                        quality,
                        deadline_ms: None,
                    };
                    proto::write_frame(&mut w, &ClientFrame::Request(req).to_json()).unwrap();
                }
                let mut reader = FrameReader::new(r, MAX_FRAME);
                let mut got = Vec::new();
                for _ in 0..combos.len() {
                    match read_frame_within(&mut reader, Duration::from_secs(20)) {
                        ServerFrame::Response { id, route, tier, quality, degraded, outputs } => {
                            assert_eq!(tier, route.tier(), "wire tier names the serving key");
                            assert!(quality.is_some(), "a measured tier reports quality");
                            got.push((id, route, degraded, outputs))
                        }
                        other => panic!("wanted a response, got {other:?}"),
                    }
                }
                (client, got)
            })
        })
        .collect();
    let answers: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // replies arrive in submit order — the pipelining contract
    for (_, got) in &answers {
        let ids: Vec<u64> = got.iter().map(|(id, ..)| *id).collect();
        let expected: Vec<u64> = combos().iter().map(|&(id, ..)| id).collect();
        assert_eq!(ids, expected, "replies must come back in submit order");
    }

    // bit-exactness against the in-process path, same config + backend
    let reference =
        Coordinator::start(base_config(), |_shard| Ok(MockExecutor::full_catalog())).unwrap();
    for (client, got) in answers {
        for (id, route, degraded, outputs) in got {
            let (_, app, quality) =
                combos().into_iter().find(|&(cid, ..)| cid == id).unwrap();
            assert_eq!(route, ModelKey::route(app, quality));
            assert!(!degraded, "nothing should degrade under an empty queue");
            let want = reference
                .submit(job_for(app, (client * 100) as i32 + id as i32), quality)
                .unwrap()
                .wait()
                .unwrap();
            assert_eq!(route, want.route);
            assert_eq!(outputs, want.outputs, "client {client} id {id} ({app:?} {quality:?})");
        }
    }
    assert_eq!(coord.metrics().net_protocol_errors(), 0);
    server.shutdown();
    server.join();
}

/// A saturating client must see *typed* shed / degraded / expired
/// outcomes over the wire — every pipelined request settles with a
/// frame, none hang, and the connection never drops.
#[test]
fn overload_and_deadlines_are_typed_over_the_wire_not_hangs() {
    const BURST: usize = 12;
    let cfg = CoordinatorConfig {
        queue_capacity: 2,
        batch_size: 1,
        classify_row: ROW,
        batch_max_wait: Duration::from_millis(1),
        shards: 1,
        overload: OverloadPolicy::Degrade,
        // each tier holds at most 1 in-flight request, so the burst
        // forces both a degrade (balanced -> economy) and sheds
        fair_share: 0.5,
        autopilot: None,
    };
    let (coord, server) =
        spawn_mock(cfg, None, Duration::from_millis(50), NetServerConfig::default());
    let mut w = TcpStream::connect(server.local_addr()).unwrap();
    let _ = w.set_nodelay(true);
    let r = w.try_clone().unwrap();
    let _ = r.set_read_timeout(Some(Duration::from_millis(100)));
    for id in 0..BURST as u64 {
        let req = Request {
            id,
            job: job_for(App::Gdf, id as i32),
            quality: Quality::Balanced,
            deadline_ms: Some(5_000),
        };
        proto::write_frame(&mut w, &ClientFrame::Request(req).to_json()).unwrap();
    }
    let mut reader = FrameReader::new(r, MAX_FRAME);
    let (mut answered, mut degraded, mut shed) = (0, 0, 0);
    for _ in 0..BURST {
        match read_frame_within(&mut reader, Duration::from_secs(20)) {
            ServerFrame::Response { degraded: d, tier, quality, .. } => {
                answered += 1;
                if d {
                    degraded += 1;
                    // a degraded response names the tier that actually
                    // answered, with its measured quality
                    assert_eq!(tier, Quality::Economy, "balanced degrades one tier down");
                    assert!(quality.is_some(), "degraded tier carries its measured quality");
                }
            }
            ServerFrame::Rejected { rejection: Rejection::Shed, .. } => shed += 1,
            other => panic!("wanted response|shed, got {other:?}"),
        }
    }
    assert_eq!(answered + shed, BURST, "every request settles with a typed frame");
    assert!(shed >= 1, "a 2-slot gate must shed part of a {BURST}-deep burst");
    assert!(degraded >= 1, "the degrade policy must re-admit at least one request lower");

    // an already-expired relative deadline is a typed rejection too
    let req = Request {
        id: 100,
        job: job_for(App::Gdf, 7),
        quality: Quality::Balanced,
        deadline_ms: Some(0),
    };
    proto::write_frame(&mut w, &ClientFrame::Request(req).to_json()).unwrap();
    match read_frame_within(&mut reader, Duration::from_secs(20)) {
        ServerFrame::Rejected { id, rejection: Rejection::DeadlineExpired, .. } => {
            assert_eq!(id, 100)
        }
        other => panic!("wanted a deadline rejection, got {other:?}"),
    }
    assert_eq!(coord.metrics().net_protocol_errors(), 0);
    server.shutdown();
    server.join();
}

/// Requests routing to an unregistered key come back as typed
/// `unknown_model` rejections naming the catalog — and the connection
/// keeps serving afterwards.
#[test]
fn unknown_model_rejections_name_the_catalog_and_spare_the_connection() {
    let keys = vec![ModelKey::parse("gdf/ds16").unwrap(), ModelKey::parse("gdf/ds32").unwrap()];
    let (coord, server) = spawn_mock(
        base_config(),
        Some(keys),
        Duration::ZERO,
        NetServerConfig::default(),
    );
    let mut w = TcpStream::connect(server.local_addr()).unwrap();
    let r = w.try_clone().unwrap();
    let _ = r.set_read_timeout(Some(Duration::from_millis(100)));
    let mut reader = FrameReader::new(r, MAX_FRAME);

    let req = Request {
        id: 7,
        job: job_for(App::Frnn, 3),
        quality: Quality::Balanced,
        deadline_ms: None,
    };
    proto::write_frame(&mut w, &ClientFrame::Request(req).to_json()).unwrap();
    match read_frame_within(&mut reader, Duration::from_secs(20)) {
        ServerFrame::Rejected { id, rejection: Rejection::UnknownModel, message } => {
            assert_eq!(id, 7);
            assert!(message.contains("frnn/th48ds16"), "{message}");
            assert!(message.contains("gdf/ds16"), "{message}");
        }
        other => panic!("wanted unknown_model, got {other:?}"),
    }

    // same connection, registered key: still serving
    let req = Request {
        id: 8,
        job: job_for(App::Gdf, 11),
        quality: Quality::Economy,
        deadline_ms: None,
    };
    proto::write_frame(&mut w, &ClientFrame::Request(req).to_json()).unwrap();
    match read_frame_within(&mut reader, Duration::from_secs(20)) {
        ServerFrame::Response { id, route, .. } => {
            assert_eq!(id, 8);
            assert_eq!(route, ModelKey::parse("gdf/ds32").unwrap());
        }
        other => panic!("wanted a response, got {other:?}"),
    }
    // unknown-model is an application outcome, not a wire violation
    assert_eq!(coord.metrics().net_protocol_errors(), 0);
    server.shutdown();
    server.join();
}

/// Malformed and oversized frames get typed error frames and the
/// connection survives (the stream stays frame-aligned); truncation is
/// terminal and counted. All over a real socket, against a server
/// with a deliberately tiny frame cap.
#[test]
fn protocol_violations_are_typed_and_survivable_on_a_real_socket() {
    let net = NetServerConfig { max_frame: 1024, ..NetServerConfig::default() };
    let (coord, server) = spawn_mock(base_config(), None, Duration::ZERO, net);
    let mut w = TcpStream::connect(server.local_addr()).unwrap();
    let _ = w.set_nodelay(true);
    let r = w.try_clone().unwrap();
    let _ = r.set_read_timeout(Some(Duration::from_millis(100)));
    let mut reader = FrameReader::new(r, MAX_FRAME);

    // well-framed bytes that are not JSON: typed error, stream survives
    proto::write_raw_frame(&mut w, b"{ not json").unwrap();
    match read_frame_within(&mut reader, Duration::from_secs(20)) {
        ServerFrame::Error { id: None, kind, .. } => assert_eq!(kind, proto::ERR_MALFORMED),
        other => panic!("wanted a malformed error, got {other:?}"),
    }

    // a frame over the server's cap: drained + typed error, survives
    proto::write_raw_frame(&mut w, &[b'x'; 2000]).unwrap();
    match read_frame_within(&mut reader, Duration::from_secs(20)) {
        ServerFrame::Error { id: None, kind, .. } => assert_eq!(kind, proto::ERR_OVERSIZED),
        other => panic!("wanted an oversized error, got {other:?}"),
    }

    // the stream is still frame-aligned: a ping gets its pong
    proto::write_frame(&mut w, &ClientFrame::Ping.to_json()).unwrap();
    match read_frame_within(&mut reader, Duration::from_secs(20)) {
        ServerFrame::Pong => {}
        other => panic!("wanted a pong, got {other:?}"),
    }

    // half a header then half-close: terminal truncation, counted
    use std::io::Write;
    w.write_all(&[0u8, 1]).unwrap();
    w.flush().unwrap();
    w.shutdown(Shutdown::Write).unwrap();
    let t0 = Instant::now();
    while coord.metrics().net_active_connections() > 0 {
        assert!(t0.elapsed() < Duration::from_secs(10), "handler did not close on truncation");
        thread::sleep(Duration::from_millis(10));
    }
    assert_eq!(coord.metrics().net_protocol_errors(), 3);
    server.shutdown();
    server.join();
}
