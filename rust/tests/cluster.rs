//! Multi-node serving integration tests: an in-process ring of real
//! `NetServer` listeners (port 0, one process, no subprocesses) wired
//! together with per-node [`Cluster`] routers, driven over real
//! loopback sockets.
//!
//! What must hold, per the multi-node contract:
//!
//! - forwarded execution is **bit-exact** with local execution for
//!   every registered catalog key (the wire hop may not perturb
//!   payloads, routes, tiers, or measured quality);
//! - killing a peer mid-burst loses **zero** requests — every
//!   scheduled request settles with a typed frame (response or
//!   rejection), never a hang or a bare disconnect;
//! - draining a node over the wire (`shutdown` frame) rehomes its
//!   keys onto survivors with no protocol errors;
//! - deadline budgets **shrink across the forward hop**: time spent
//!   on a failed candidate is gone, and the local fallback refuses
//!   with a typed expiry rather than serving late.
//!
//! Fault injection goes through the seeded [`FaultPolicy`] shim
//! (delay / drop / truncate / black-hole), installed per-cluster —
//! never process-global — so the suite is deterministic and
//! order-independent at any `--test-threads`.

use ppc::catalog::{App, ModelKey, Tensor};
use ppc::coordinator::{Coordinator, CoordinatorConfig, Job, MockExecutor, Rejection};
use ppc::net::cluster::{Cluster, ClusterConfig};
use ppc::net::fault::{FaultAction, FaultPolicy};
use ppc::net::loadgen;
use ppc::net::proto::{self, ClientFrame, FrameReader, Request, ServerFrame, MAX_FRAME};
use ppc::net::server::{NetServer, NetServerConfig};
use ppc::net::PeerState;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// FRNN row length for every node (small keeps frames cheap).
const ROW: usize = 8;

fn base_config() -> CoordinatorConfig {
    CoordinatorConfig {
        queue_capacity: 64,
        batch_size: 4,
        classify_row: ROW,
        batch_max_wait: Duration::from_millis(1),
        shards: 1,
        ..CoordinatorConfig::default()
    }
}

/// One ring member: a real listener + coordinator + cluster router.
struct Node {
    addr: String,
    coord: Arc<Coordinator>,
    cluster: Arc<Cluster>,
    server: Option<NetServer>,
}

impl Node {
    /// Hard-stop the front door (drains in-flight connections, then
    /// closes the listener — new connects get refused). The
    /// coordinator and cluster stay alive, like a crashed-but-held
    /// process image.
    fn kill(&mut self) {
        if let Some(s) = self.server.take() {
            s.shutdown();
            s.join();
        }
    }
}

/// Boot an `n`-member ring in this process: bind every listener first
/// (so port 0 resolves before anyone lists peers), then start each
/// member's cluster + server with the full peer list. Every node
/// registers the full mock catalog, so any member can serve any key —
/// which keys actually forward is decided purely by ring ownership.
fn ring(n: usize) -> Vec<Node> {
    let listeners: Vec<TcpListener> =
        (0..n).map(|_| TcpListener::bind("127.0.0.1:0").unwrap()).collect();
    let addrs: Vec<String> =
        listeners.iter().map(|l| l.local_addr().unwrap().to_string()).collect();
    listeners
        .into_iter()
        .enumerate()
        .map(|(i, listener)| {
            let coord = Arc::new(
                Coordinator::start(base_config(), |_shard| Ok(MockExecutor::full_catalog()))
                    .unwrap(),
            );
            let peers: Vec<String> = addrs
                .iter()
                .enumerate()
                .filter(|&(j, _)| j != i)
                .map(|(_, a)| a.clone())
                .collect();
            let cluster = Arc::new(Cluster::start(ClusterConfig {
                node: addrs[i].clone(),
                peers,
                // liveness is driven through forwards in these tests; a
                // quiet prober keeps every state transition deterministic
                probe_interval: Duration::from_secs(3600),
                forward_connect_timeout: Duration::from_millis(300),
                forward_read_timeout: Duration::from_millis(700),
                ..ClusterConfig::default()
            }));
            let server = NetServer::spawn_cluster(
                listener,
                coord.clone(),
                NetServerConfig::default(),
                Some(cluster.clone()),
            )
            .unwrap();
            Node { addr: addrs[i].clone(), coord, cluster, server: Some(server) }
        })
        .collect()
}

/// Deterministic payload for `(app, seed)` — identical on every call,
/// so the forwarded and the local run score the exact same job.
fn job_for(app: App, seed: i32) -> Job {
    let base: Vec<i32> = (0..4).map(|i| (seed + i).rem_euclid(256)).collect();
    match app {
        App::Gdf => Job::Denoise { image: Tensor::matrix(2, 2, base).unwrap() },
        App::Blend => Job::Blend {
            p1: Tensor::matrix(2, 2, base.clone()).unwrap(),
            p2: Tensor::matrix(2, 2, base.iter().map(|v| (v + 7) % 256).collect()).unwrap(),
            alpha: 64,
        },
        App::Frnn => {
            Job::Classify { pixels: (0..ROW as i32).map(|i| (seed + i).rem_euclid(160)).collect() }
        }
    }
}

/// Read one server frame, bounded so a wedged node fails the test
/// instead of hanging it (needs a read timeout on the stream).
fn read_frame_within(reader: &mut FrameReader<TcpStream>, within: Duration) -> ServerFrame {
    let t0 = Instant::now();
    loop {
        match reader.poll_frame() {
            Ok(Some(j)) => return ServerFrame::from_json(&j).unwrap(),
            Ok(None) => assert!(t0.elapsed() < within, "no frame within {within:?}"),
            Err(e) => panic!("read failed: {e}"),
        }
    }
}

/// One request, one fresh connection, one typed reply (bounded).
fn roundtrip(addr: &str, req: Request) -> ServerFrame {
    let mut w = TcpStream::connect(addr).unwrap();
    let r = w.try_clone().unwrap();
    let _ = r.set_read_timeout(Some(Duration::from_millis(50)));
    proto::write_frame(&mut w, &ClientFrame::Request(req).to_json()).unwrap();
    let mut rd = FrameReader::new(r, MAX_FRAME);
    read_frame_within(&mut rd, Duration::from_secs(20))
}

/// Index of the ring owner of `key` in `nodes`.
fn owner_index(nodes: &[Node], key: ModelKey) -> usize {
    let owner = nodes[0].cluster.owner(key).to_string();
    nodes.iter().position(|n| n.addr == owner).expect("owner is a ring member")
}

/// Some catalog key the first node does NOT own (so sending it there
/// forwards), together with its owner's index.
fn foreign_key(nodes: &[Node], sender: usize) -> (ModelKey, usize) {
    for key in ModelKey::catalog() {
        let o = owner_index(nodes, key);
        if o != sender {
            return (key, o);
        }
    }
    panic!("rendezvous hashing put all 9 keys on one node");
}

#[test]
fn every_catalog_key_is_bit_exact_across_the_forward_hop() {
    let nodes = ring(3);
    let mut forwarded = 0u64;
    for (i, key) in ModelKey::catalog().into_iter().enumerate() {
        let owner = owner_index(&nodes, key);
        // any non-owner front door will do as the forwarding sender
        let sender = (0..nodes.len()).find(|&s| s != owner).unwrap();
        let mk_req = || Request {
            id: 7_000 + i as u64,
            job: job_for(key.app, 31 * i as i32 + 5),
            quality: key.tier(),
            deadline_ms: Some(30_000),
        };
        let via_forward = roundtrip(&nodes[sender].addr, mk_req());
        let via_local = roundtrip(&nodes[owner].addr, mk_req());
        match (via_forward, via_local) {
            (
                ServerFrame::Response {
                    id: fid,
                    route: froute,
                    tier: ftier,
                    quality: fq,
                    degraded: fdeg,
                    outputs: fout,
                },
                ServerFrame::Response {
                    id: lid,
                    route: lroute,
                    tier: ltier,
                    quality: lq,
                    degraded: ldeg,
                    outputs: lout,
                },
            ) => {
                assert_eq!(fid, lid, "{key}: the forward hop must keep the original id");
                assert_eq!(froute, lroute, "{key}: route drifted across the hop");
                assert_eq!(ftier, ltier, "{key}: tier drifted across the hop");
                assert_eq!(fq, lq, "{key}: measured quality drifted across the hop");
                assert_eq!(fdeg, ldeg, "{key}: degraded flag drifted across the hop");
                assert_eq!(fout, lout, "{key}: forwarded outputs are not bit-exact");
            }
            (f, l) => panic!("{key}: wanted two responses, got {f:?} / {l:?}"),
        }
        forwarded += 1;
    }
    // every key really crossed the wire boundary once
    let total_in: u64 = nodes.iter().map(|n| n.coord.metrics().forwards_in()).sum();
    let total_out: u64 = nodes.iter().map(|n| n.coord.metrics().forwards_out()).sum();
    assert_eq!(total_in, forwarded, "every request must have taken the forward path");
    assert_eq!(total_out, forwarded);
    for n in &nodes {
        assert_eq!(n.coord.metrics().net_protocol_errors(), 0, "{}", n.addr);
    }
}

#[test]
fn a_peer_killed_mid_burst_loses_zero_requests() {
    let mut nodes = ring(2);
    let (key, owner) = foreign_key(&nodes, 0);
    assert_eq!(owner, 1);
    let total = 40u64;
    let half = 20u64;

    let mut w = TcpStream::connect(&nodes[0].addr).unwrap();
    let r = w.try_clone().unwrap();
    let _ = r.set_read_timeout(Some(Duration::from_millis(50)));
    let mut rd = FrameReader::new(r, MAX_FRAME);
    let send = |w: &mut TcpStream, id: u64| {
        let req = Request {
            id,
            job: job_for(key.app, id as i32),
            quality: key.tier(),
            deadline_ms: None,
        };
        proto::write_frame(w, &ClientFrame::Request(req).to_json()).unwrap();
    };
    let mut got = Vec::new();
    // phase 1: everything forwards to the (live) owner
    for id in 0..half {
        send(&mut w, id);
    }
    while (got.len() as u64) < half {
        got.push(read_frame_within(&mut rd, Duration::from_secs(20)));
    }
    // kill the owner mid-burst: its listener closes, so the survivor's
    // next forward is refused, marks it dead, and serves locally
    nodes[1].kill();
    for id in half..total {
        send(&mut w, id);
    }
    let _ = w.shutdown(Shutdown::Write);
    while (got.len() as u64) < total {
        got.push(read_frame_within(&mut rd, Duration::from_secs(20)));
    }

    // zero lost: every id settled, typed, and in pipeline order
    assert_eq!(got.len() as u64, total);
    for (want_id, frame) in (0..total).zip(&got) {
        match frame {
            ServerFrame::Response { id, route, .. } => {
                assert_eq!(*id, want_id, "replies must keep pipeline order");
                assert_eq!(*route, key);
            }
            ServerFrame::Rejected { id, .. } => {
                panic!("id {id}: no request should be rejected here (no deadlines, idle queue)")
            }
            other => panic!("id {want_id}: untyped outcome {other:?}"),
        }
    }
    assert_eq!(nodes[0].coord.metrics().net_protocol_errors(), 0);
    assert_eq!(
        nodes[0].cluster.peer_state(&nodes[1].addr),
        Some(PeerState::Dead),
        "the killed owner must be failure-detected"
    );
    assert!(
        nodes[0].coord.metrics().forward_fallbacks() >= 1,
        "post-kill requests must have rehomed locally"
    );
}

#[test]
fn wire_drain_rehomes_keys_onto_survivors_without_protocol_errors() {
    let nodes = ring(2);
    let (key, owner) = foreign_key(&nodes, 0);
    // warm path: the key really lives on the other node
    let req = |id: u64| Request {
        id,
        job: job_for(key.app, id as i32),
        quality: key.tier(),
        deadline_ms: None,
    };
    assert!(matches!(roundtrip(&nodes[0].addr, req(1)), ServerFrame::Response { id: 1, .. }));
    assert_eq!(nodes[owner].coord.metrics().forwards_in(), 1);

    // drain the owner over the wire, exactly like `loadgen --shutdown`
    loadgen::send_shutdown(&nodes[owner].addr).unwrap();

    // survivors absorb the drained node's keys: every follow-up request
    // is answered, and the drained peer walks to Dead (refused connects
    // kill it instantly; a still-closing listener costs timeout misses)
    let give_up = Instant::now() + Duration::from_secs(30);
    let mut id = 100u64;
    loop {
        match roundtrip(&nodes[0].addr, req(id)) {
            ServerFrame::Response { .. } => {}
            other => panic!("rehomed request must be answered, got {other:?}"),
        }
        if nodes[0].cluster.peer_state(&nodes[owner].addr) == Some(PeerState::Dead) {
            break;
        }
        assert!(Instant::now() < give_up, "drained peer never failure-detected");
        id += 1;
    }
    // and once Dead, routing is purely local: no more forward attempts
    let retries_settled = nodes[0].coord.metrics().forward_retries();
    assert!(matches!(roundtrip(&nodes[0].addr, req(999)), ServerFrame::Response { id: 999, .. }));
    assert_eq!(nodes[0].coord.metrics().forward_retries(), retries_settled);
    assert_eq!(nodes[0].coord.metrics().net_protocol_errors(), 0);
    assert!(nodes[0].coord.metrics().forward_fallbacks() >= 1);
}

#[test]
fn a_black_holed_owner_spends_the_budget_and_expires_typed() {
    let nodes = ring(2);
    let (key, owner) = foreign_key(&nodes, 0);
    // every connection to the owner vanishes: no RST, no bytes back —
    // only the shrinking deadline budget can end the attempt
    let policy =
        Arc::new(FaultPolicy::new(0xB1AC).rule(&nodes[owner].addr, FaultAction::BlackHole));
    nodes[0].cluster.set_fault_policy(policy.clone());

    let deadline_ms = 150u64;
    let t0 = Instant::now();
    let reply = roundtrip(
        &nodes[0].addr,
        Request {
            id: 5,
            job: job_for(key.app, 9),
            quality: key.tier(),
            deadline_ms: Some(deadline_ms),
        },
    );
    let elapsed = t0.elapsed();
    // the budget died on the wire: the local fallback must refuse with
    // a typed expiry (serving late would violate the deadline contract)
    match reply {
        ServerFrame::Rejected { id: 5, rejection: Rejection::DeadlineExpired, .. } => {}
        other => panic!("wanted a typed deadline expiry, got {other:?}"),
    }
    assert!(
        elapsed >= Duration::from_millis(deadline_ms),
        "expiry cannot precede the budget ({elapsed:?})"
    );
    assert!(policy.injected() >= 1, "the fault shim never fired");
    assert_eq!(nodes[0].coord.metrics().net_protocol_errors(), 0);
    assert!(nodes[0].coord.metrics().forward_retries() >= 1);
}

#[test]
fn a_slow_wire_inside_the_budget_still_answers() {
    let nodes = ring(2);
    let (key, owner) = foreign_key(&nodes, 0);
    let stall = Duration::from_millis(60);
    let policy =
        Arc::new(FaultPolicy::new(0xDE1A).rule(&nodes[owner].addr, FaultAction::Delay(stall)));
    nodes[0].cluster.set_fault_policy(policy.clone());

    let t0 = Instant::now();
    let reply = roundtrip(
        &nodes[0].addr,
        Request {
            id: 6,
            job: job_for(key.app, 11),
            quality: key.tier(),
            deadline_ms: Some(5_000),
        },
    );
    assert!(matches!(&reply, ServerFrame::Response { id: 6, .. }), "{reply:?}");
    assert!(t0.elapsed() >= stall, "the stall must have been on the serving path");
    assert!(policy.injected() >= 1);
    assert_eq!(nodes[owner].coord.metrics().forwards_in(), 1, "still served by the owner");
}

#[test]
fn truncated_forward_streams_fail_over_to_a_typed_local_reply() {
    let nodes = ring(2);
    let (key, owner) = foreign_key(&nodes, 0);
    // first forward connection severs 10 bytes in (mid-header/body);
    // later connections run clean
    let policy = Arc::new(
        FaultPolicy::new(0x7C0C).rule_n(&nodes[owner].addr, FaultAction::Truncate(10), 1),
    );
    nodes[0].cluster.set_fault_policy(policy.clone());

    let req = |id: u64| Request {
        id,
        job: job_for(key.app, id as i32),
        quality: key.tier(),
        deadline_ms: None,
    };
    // the severed hop is retried out of candidates, then served locally
    assert!(matches!(roundtrip(&nodes[0].addr, req(21)), ServerFrame::Response { id: 21, .. }));
    assert_eq!(policy.injected(), 1);
    assert!(nodes[0].coord.metrics().forward_retries() >= 1);
    assert!(nodes[0].coord.metrics().forward_fallbacks() >= 1);
    // a truncated stream is a Suspect, not a Dead: the next request
    // forwards again over the now-clean wire and the peer recovers
    let before = nodes[owner].coord.metrics().forwards_in();
    assert!(matches!(roundtrip(&nodes[0].addr, req(22)), ServerFrame::Response { id: 22, .. }));
    assert_eq!(nodes[owner].coord.metrics().forwards_in(), before + 1);
    assert_eq!(nodes[0].cluster.peer_state(&nodes[owner].addr), Some(PeerState::Alive));
}
