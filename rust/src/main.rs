//! `ppc` — the command-line entry point: regenerate every table and
//! figure from the paper, generate the face dataset, train the FRNN,
//! synthesize ad-hoc PPC blocks, and run the serving coordinator.

use anyhow::{anyhow, bail, Result};
use ppc::apps::frnn::{dataset, io as frnn_io, net};
use ppc::logic::map::Objective;
use ppc::ppc::preprocess::{Chain, Preproc};
use ppc::tables::{figures, supp, table1, table2, table3};
use ppc::util::cli::Args;
use std::path::{Path, PathBuf};

const USAGE: &str = "ppc — Partially-Precise Computing reproduction

USAGE: ppc <command> [options]

Paper artifacts:
  table1 [--quick] [--json FILE]     Table 1  (Gaussian denoising filter)
  table2 [--quick] [--json FILE]     Table 2  (image blending)
  table3 [--quick] [--rows 1,2,4]    Table 3  (face-recognition NN)
  supp-table1                        Supp. Table 1 (8×8 mult, two processes)
  fig1                               Fig. 1   (preprocessed histograms, CSV)
  fig2                               Fig. 2   (2×3 multiplier K-maps)
  fig5 | fig7 | fig10                signal WL/sparsity summaries
  fig6 | fig8 | fig11 [--out DIR]    sample images (PGM) + PSNR
  fig12a [--quick]                   CCR/MSE vs TH threshold sweep
  fig12bc [--quick]                  CCR/MSE vs (DS img × DS wgt) heat map

Pipeline:
  gen-faces [--out FILE] [--samples N]   synthetic face dataset (JSON)
  train-frnn [--faces F] [--out F]       rust reference trainer
  serve [--backend native|pjrt] [--requests N] [--image-size N]
        [--artifacts DIR]                run the coordinator demo:
                                         native = synthesized netlists (offline),
                                         pjrt   = AOT artifacts (needs --features pjrt)
  synth --block adder|mult --wl N [--ds X | --th X,Y]  ad-hoc PPC block
";

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let code = match run(&cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn maybe_json(args: &Args, table: &ppc::tables::Table) -> Result<()> {
    if let Some(path) = args.get("json") {
        std::fs::write(path, table.to_json().to_string())?;
        println!("json -> {path}");
    }
    Ok(())
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    match cmd {
        "table1" => {
            let cfg = if quick {
                table1::Config { image_size: 64, ds_rates: vec![2, 8, 16] }
            } else {
                table1::Config::default()
            };
            let t = table1::generate(&cfg);
            println!("{}", t.render());
            maybe_json(args, &t)
        }
        "table2" => {
            let cfg = if quick {
                table2::Config {
                    image_size: 64,
                    ds_rates: vec![8, 16],
                    natural_ds_rates: vec![8],
                    flat_literals: false,
                }
            } else {
                table2::Config::default()
            };
            let t = table2::generate(&cfg);
            println!("{}", t.render());
            maybe_json(args, &t)
        }
        "table3" => {
            let rows: Vec<usize> = args
                .get("rows")
                .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
                .unwrap_or_else(|| (1..=9).collect());
            let cfg = if quick {
                table3::Config {
                    samples_per_combo: 2,
                    max_epochs: 40,
                    flat_literals: false,
                    rows,
                    ..Default::default()
                }
            } else {
                table3::Config { rows, ..Default::default() }
            };
            let t = table3::generate(&cfg);
            println!("{}", t.render());
            maybe_json(args, &t)
        }
        "supp-table1" => {
            let rows = supp::generate(&[16, 12, 8]);
            println!("{}", supp::render(&rows));
            Ok(())
        }
        "fig1" => {
            let series = figures::fig1();
            println!(
                "value,{}",
                series.iter().map(|(l, _)| l.clone()).collect::<Vec<_>>().join(",")
            );
            for v in 0..256 {
                let row: Vec<String> =
                    series.iter().map(|(_, h)| format!("{:.5}", h[v])).collect();
                println!("{v},{}", row.join(","));
            }
            Ok(())
        }
        "fig2" => {
            for (label, k) in figures::fig2(2) {
                println!("{label}  [{} DCs]", figures::kmap_dc_count(&k));
                println!("{}", figures::render_kmap(&k));
            }
            Ok(())
        }
        "fig5" | "fig7" | "fig10" => {
            let rows = match cmd {
                "fig5" => figures::fig5_signals(),
                "fig7" => figures::fig7_signals(),
                _ => figures::fig10_signals(&dataset::generate(3, 7)),
            };
            println!("{:<16} {:>4} {:>8} {:>10}", "signal", "WL", "#values", "sparsity");
            for (name, wl, n, sp) in rows {
                println!("{name:<16} {wl:>4} {n:>8} {sp:>9.1}%", sp = sp * 100.0);
            }
            Ok(())
        }
        "fig6" | "fig8" => {
            let dir = PathBuf::from(args.get_or("out", "artifacts/figures"));
            let rows = if cmd == "fig6" { figures::fig6(&dir)? } else { figures::fig8(&dir)? };
            for (label, psnr) in rows {
                println!("{label:<16} PSNR = {}", ppc::tables::fmt_psnr(psnr));
            }
            println!("images -> {}", dir.display());
            Ok(())
        }
        "fig11" => {
            let dir = PathBuf::from(args.get_or("out", "artifacts/figures"));
            for path in figures::fig11(&dir)? {
                println!("{path}");
            }
            Ok(())
        }
        "fig12a" => {
            let cfg = if quick {
                figures::SweepConfig { samples_per_combo: 2, max_epochs: 30, seed: 7 }
            } else {
                figures::SweepConfig::default()
            };
            let thresholds = [0u32, 16, 32, 48, 64, 80, 96, 112, 128];
            println!("threshold_x,ccr_percent,mse");
            for (x, ccr, mse) in figures::fig12a(&thresholds, &cfg) {
                println!("{x},{ccr:.1},{mse:.4}");
            }
            Ok(())
        }
        "fig12bc" => {
            let cfg = if quick {
                figures::SweepConfig { samples_per_combo: 2, max_epochs: 30, seed: 7 }
            } else {
                figures::SweepConfig::default()
            };
            let rates = if quick {
                vec![1u32, 8, 32, 64]
            } else {
                vec![1u32, 2, 4, 8, 16, 32, 64]
            };
            let (ri, _rw, ccr, mse) = figures::fig12bc(&rates, &cfg);
            println!("# CCR% (rows = DS on image, cols = DS on weights)");
            print_matrix(&ri, &ccr);
            println!("# MSE");
            print_matrix(&ri, &mse);
            if let Some(path) = args.get("json") {
                std::fs::write(path, figures::sweep_to_json(&ri, &ccr, &mse).to_string())?;
            }
            Ok(())
        }
        "gen-faces" => {
            let out = PathBuf::from(args.get_or("out", "artifacts/faces.json"));
            if let Some(parent) = out.parent() {
                std::fs::create_dir_all(parent)?;
            }
            let samples = args.usize_or("samples", 5);
            let ds = dataset::generate(samples, args.u64_or("seed", 7));
            frnn_io::save_dataset(&ds, &out)?;
            println!(
                "faces: {} train / {} test -> {}",
                ds.train.len(),
                ds.test.len(),
                out.display()
            );
            Ok(())
        }
        "train-frnn" => {
            let faces = args.get_or("faces", "artifacts/faces.json");
            let ds = if Path::new(faces).exists() {
                frnn_io::load_dataset(Path::new(faces))?
            } else {
                println!("{faces} not found; generating in-memory dataset");
                dataset::generate(4, 7)
            };
            let cfg = net::TrainConfig {
                max_epochs: args.usize_or("epochs", 250),
                ..Default::default()
            };
            let r = net::train(&ds, &cfg);
            let q = net::quantize(&r.net);
            let ev = net::evaluate_fx(&q, &ds.test, &Chain::id(), &Chain::id());
            println!(
                "TE={} mse={:.4} fixed-point test CCR={:.1}%",
                r.epochs,
                r.mse,
                ev.ccr * 100.0
            );
            let out = PathBuf::from(args.get_or("out", "artifacts/frnn_weights_rust.json"));
            if let Some(parent) = out.parent() {
                std::fs::create_dir_all(parent)?;
            }
            frnn_io::save_weights(&r.net, &out)?;
            println!("weights -> {}", out.display());
            Ok(())
        }
        "serve" => serve_demo(args),
        "synth" => synth_adhoc(args),
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("{USAGE}");
            bail!("unknown command {other:?}")
        }
    }
}

fn print_matrix(rates: &[u32], m: &[Vec<f64>]) {
    print!("ds\\ds,");
    println!("{}", rates.iter().map(|r| r.to_string()).collect::<Vec<_>>().join(","));
    for (i, row) in m.iter().enumerate() {
        let cells: Vec<String> = row.iter().map(|v| format!("{v:.2}")).collect();
        println!("{},{}", rates[i], cells.join(","));
    }
}

/// Run the coordinator with a mixed workload over the chosen backend.
fn serve_demo(args: &Args) -> Result<()> {
    use ppc::coordinator::{Coordinator, CoordinatorConfig, Job, Quality};
    let backend = args.get_or("backend", "native");
    let native = match backend {
        "native" => true,
        "pjrt" => false,
        other => bail!("unknown backend {other:?} (native|pjrt)"),
    };
    let n = args.usize_or("requests", if native { 24 } else { 64 });
    let side = args.usize_or("image-size", if native { 64 } else { 256 });
    let img_len = side * side;

    let coord = if native {
        // Build the offline registry: synthesized netlists for the two
        // sparse image qualities plus the FRNN tiers, with a
        // quickly-trained quantized net standing in for the deployed
        // weights.
        use ppc::apps::frnn::{dataset, net};
        println!("training a quick FRNN for the native registry…");
        let ds = dataset::generate(2, 0x5E12);
        let r = net::train(&ds, &net::TrainConfig { max_epochs: 30, ..Default::default() });
        let q = net::quantize(&r.net);
        println!("synthesizing PPC hardware (gdf/blend/frnn × ds16/ds32 tiers)…");
        let exec = ppc::runtime::NativeExecutor::new()
            .with_gdf("ds16")?
            .with_gdf("ds32")?
            .with_blend("ds16")?
            .with_blend("ds32")?
            .with_frnn("th48ds16", q.clone())?
            .with_frnn("ds32", q)?;
        println!("native registry: {:?}", exec.registered_keys());
        Coordinator::with_native(CoordinatorConfig::default(), exec)
            .map_err(|e| anyhow!("{e:#}"))?
    } else {
        let dir = artifacts_dir(args);
        Coordinator::with_artifacts(&dir, CoordinatorConfig::default())
            .map_err(|e| anyhow!("{e:#}\nhint: run `make artifacts` first"))?
    };

    let mut rng = ppc::util::prng::Rng::new(0x5E12);
    let mut tickets = Vec::new();
    let t0 = std::time::Instant::now();
    for i in 0..n {
        // the native demo registers the Balanced/Economy tiers only
        // (precise full-range blocks take the longest to synthesize)
        let quality = if native {
            if i % 2 == 0 { Quality::Balanced } else { Quality::Economy }
        } else {
            match i % 3 {
                0 => Quality::Precise,
                1 => Quality::Balanced,
                _ => Quality::Economy,
            }
        };
        let job = match i % 3 {
            0 => Job::Denoise {
                image: (0..img_len).map(|_| rng.below(256) as i32).collect(),
            },
            1 => Job::Blend {
                p1: (0..img_len).map(|_| rng.below(256) as i32).collect(),
                p2: (0..img_len).map(|_| rng.below(256) as i32).collect(),
                alpha: 64,
            },
            _ => Job::Classify {
                pixels: (0..960).map(|_| rng.below(160) as i32).collect(),
            },
        };
        tickets.push(coord.submit_blocking(job, quality).map_err(|e| anyhow!("{e:?}"))?);
    }
    for t in tickets {
        t.wait()?;
    }
    let dt = t0.elapsed();
    println!(
        "{n} requests in {:.2}s ({:.1} req/s)",
        dt.as_secs_f64(),
        n as f64 / dt.as_secs_f64()
    );
    println!("{}", coord.metrics().report());
    Ok(())
}

/// Ad-hoc PPC block synthesis (the Fig. 3 design flow as a tool).
fn synth_adhoc(args: &Args) -> Result<()> {
    use ppc::ppc::flow;
    use ppc::ppc::preprocess::ValueSet;
    let block = args.get_or("block", "adder");
    let wl = args.usize_or("wl", 8) as u32;
    let mut chain = Chain::id();
    if let Some(x) = args.get("ds") {
        chain = chain.then(Preproc::Ds(x.parse()?));
    }
    if let Some(th) = args.get("th") {
        let (x, y) = th.split_once(',').ok_or_else(|| anyhow!("--th wants X,Y"))?;
        chain = chain.then(Preproc::Th { x: x.parse()?, y: y.parse()? });
    }
    let set = ValueSet::full(wl.min(8)).map_chain(&chain);
    println!(
        "block={block} wl={wl} preprocessing={} sparsity={:.1}%",
        chain.label(),
        set.sparsity() * 100.0
    );
    let report = match block {
        "adder" => flow::segmented_adder("adhoc_adder", wl, wl, &set, &set, Objective::Area),
        "mult" => {
            if wl != 8 {
                bail!("composed multiplier supports wl=8");
            }
            flow::composed_mult8("adhoc_mult", &set, &set, Objective::Area)
        }
        other => bail!("unknown block {other} (adder|mult)"),
    };
    println!(
        "literals={} area={:.0}GE delay={:.2}ns power={:.1}uW dc={:.1}% verify_errors={}",
        report.literals,
        report.area_ge,
        report.delay_ns,
        report.power_uw,
        report.dc_fraction * 100.0,
        report.verify_errors
    );
    Ok(())
}
