//! `ppc` — the command-line entry point: regenerate every table and
//! figure from the paper, generate the face dataset, train the FRNN,
//! synthesize ad-hoc PPC blocks, and run the serving coordinator.

use anyhow::{anyhow, bail, Result};
use ppc::apps::frnn::{dataset, io as frnn_io, net};
use ppc::logic::map::Objective;
use ppc::ppc::preprocess::{Chain, Preproc};
use ppc::tables::{figures, supp, table1, table2, table3};
use ppc::util::cli::Args;
use std::path::{Path, PathBuf};

const USAGE: &str = "ppc — Partially-Precise Computing reproduction

USAGE: ppc <command> [options]

Paper artifacts:
  table1 [--quick] [--json FILE]     Table 1  (Gaussian denoising filter)
  table2 [--quick] [--json FILE]     Table 2  (image blending)
  table3 [--quick] [--rows 1,2,4]    Table 3  (face-recognition NN)
  supp-table1                        Supp. Table 1 (8×8 mult, two processes)
  fig1                               Fig. 1   (preprocessed histograms, CSV)
  fig2                               Fig. 2   (2×3 multiplier K-maps)
  fig5 | fig7 | fig10                signal WL/sparsity summaries
  fig6 | fig8 | fig11 [--out DIR]    sample images (PGM) + PSNR
  fig12a [--quick]                   CCR/MSE vs TH threshold sweep
  fig12bc [--quick]                  CCR/MSE vs (DS img × DS wgt) heat map

Pipeline:
  gen-faces [--out FILE] [--samples N]   synthetic face dataset (JSON)
  train-frnn [--faces F] [--out F]       rust reference trainer
  serve [--backend native|pjrt] [--requests N] [--image-size N]
        [--models KEY,KEY,..] [--shards N] [--replicas N]
        [--placement KEY=S+S,..] [--spill-threshold N]
        [--overload reject|wait|degrade] [--deadline-ms N]
        [--queue-capacity N] [--fair-share F]
        [--quality auto|fixed] [--quality-floor SPEC]
        [--cache-dir DIR] [--no-cache] [--list-models] [--artifacts DIR]
        [--listen ADDR] [--peer ADDR,..] [--probe-interval-ms N]
        [--probe-timeout-ms N] [--unit-backend tape|lut|auto]
        [--threads-per-shard N]
                                         run the coordinator demo:
                                         native = synthesized netlists (offline),
                                         pjrt   = AOT artifacts (needs --features pjrt).
                                         Models are typed catalog keys (app/config,
                                         e.g. gdf/ds16, frnn/th48ds16); the native
                                         backend caches synthesized netlists as BLIF
                                         under --cache-dir (default
                                         artifacts/netlist-cache) so warm starts
                                         synthesize nothing. --shards N runs N engine
                                         shards (default: available_parallelism) with
                                         *sticky placement*: each model lands on
                                         --replicas shards (default 1, consistent-hash
                                         spread; pin keys with --placement, e.g.
                                         gdf/ds16=0+2,blend/ds32=1) and each shard
                                         builds only its own subset from the shared
                                         cache. Batches route sticky-first and spill
                                         to the least-loaded shard past
                                         --spill-threshold queued batches (the
                                         receiving shard lazily registers the model).
                                         --list-models prints the catalog (build time,
                                         cached, gates, lanes, execution backend,
                                         shard set) and exits.
                                         --unit-backend picks how synthesized units
                                         execute batches: tape walks the compiled
                                         SIMD tape, lut serves precomputed
                                         word-level tables, auto (default)
                                         calibrates once per unit kind and keeps
                                         the winner. --threads-per-shard N splits
                                         each shard's 256-lane chunk loops over N
                                         worker threads (default:
                                         available_parallelism / shards; the
                                         PPC_THREADS env var overrides both).
                                         Every submit passes the admission gate:
                                         at most --queue-capacity requests in flight
                                         (one model holds at most a --fair-share
                                         fraction of them; default 1.0, or 0.5 under
                                         degrade so lower tiers keep headroom);
                                         --overload picks what happens past the cap —
                                         reject sheds, wait blocks (bounded by
                                         --deadline-ms when set), degrade retries one
                                         quality tier lower and marks the response
                                         degraded.
                                         --quality auto attaches the closed-loop
                                         quality autopilot (native backend only):
                                         every registered tier's quality is
                                         measured once (PSNR vs the precise tier
                                         for gdf/blend, top-1 accuracy for frnn;
                                         cached next to the netlists) and a
                                         per-app controller walks serving down
                                         the registered tiers under sustained
                                         queue pressure and back up when it
                                         clears — never below --quality-floor
                                         (comma-separated metric>=value terms,
                                         e.g. psnr>=30,acc>=0.9). fixed
                                         (default) serves the requested tier,
                                         subject only to --overload degrade.
                                         --listen ADDR binds the TCP front door
                                         instead of running the demo workload:
                                         length-prefixed JSON frames in, typed
                                         response/rejection frames out, until a
                                         client sends a `shutdown` control frame
                                         (then the server drains and prints the
                                         metrics report). The readiness line is
                                         `listening on HOST:PORT` (use port 0 to
                                         pick a free port).
                                         --peer ADDR,.. joins a serving ring:
                                         all members (self + peers) rank key
                                         ownership by the same rendezvous hash,
                                         requests for keys this node does not
                                         own are forwarded to the owner over
                                         the existing framing (bounded retry
                                         on the next replica, deadline budget
                                         carried across the hop), and peers
                                         are health-checked with ping frames
                                         every --probe-interval-ms (default
                                         500) with --probe-timeout-ms (default
                                         250) per probe: a silent peer walks
                                         alive -> suspect -> dead and drops
                                         out of routing until it pongs again.
  loadgen --connect HOST:PORT [--clients N] [--rps F] [--duration-s F]
          [--app gdf|blend|frnn] [--quality Q] [--deadline-ms N]
          [--image-size N] [--classify-row N] [--seed N]
          [--ramp LOW:HIGH:STEPS] [--baseline-connect HOST:PORT]
          [--quick] [--shutdown]
                                         open-loop load generator against a
                                         `serve --listen` front door: fixed
                                         arrival schedule (honest under
                                         coordinated omission), latency measured
                                         from each request's *scheduled* time.
                                         Prints p50/p99/p999 + shed/degrade
                                         rates, writes BENCH_loadgen.json and
                                         appends to BENCH_history.jsonl.
                                         --ramp LOW:HIGH:STEPS sweeps the
                                         arrival rate instead of holding --rps:
                                         --duration-s is split into STEPS
                                         phases with the rate linearly
                                         interpolated LOW..HIGH, and each
                                         phase's summary lands phase-tagged
                                         (ramp_stepN_*) in BENCH_loadgen.json.
                                         --baseline-connect runs a second,
                                         identical fixed-rate pass against a
                                         node that owns the keys locally and
                                         writes forwarded_vs_local_p99_ratio
                                         (forward-hop overhead) into
                                         BENCH_loadgen.json next to the usual
                                         loadgen metrics.
                                         --shutdown sends the control frame that
                                         drains the server afterwards (and the
                                         baseline server, when given); exits
                                         nonzero on any protocol error.
  synth --block adder|mult --wl N [--ds X | --th X,Y]  ad-hoc PPC block
";

fn main() {
    let args = Args::from_env();
    let cmd = args.positional.first().cloned().unwrap_or_default();
    let code = match run(&cmd, &args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn artifacts_dir(args: &Args) -> PathBuf {
    PathBuf::from(args.get_or("artifacts", "artifacts"))
}

fn maybe_json(args: &Args, table: &ppc::tables::Table) -> Result<()> {
    if let Some(path) = args.get("json") {
        std::fs::write(path, table.to_json().to_string())?;
        println!("json -> {path}");
    }
    Ok(())
}

fn run(cmd: &str, args: &Args) -> Result<()> {
    let quick = args.flag("quick");
    match cmd {
        "table1" => {
            let cfg = if quick {
                table1::Config { image_size: 64, ds_rates: vec![2, 8, 16] }
            } else {
                table1::Config::default()
            };
            let t = table1::generate(&cfg);
            println!("{}", t.render());
            maybe_json(args, &t)
        }
        "table2" => {
            let cfg = if quick {
                table2::Config {
                    image_size: 64,
                    ds_rates: vec![8, 16],
                    natural_ds_rates: vec![8],
                    flat_literals: false,
                }
            } else {
                table2::Config::default()
            };
            let t = table2::generate(&cfg);
            println!("{}", t.render());
            maybe_json(args, &t)
        }
        "table3" => {
            let rows: Vec<usize> = args
                .get("rows")
                .map(|s| s.split(',').filter_map(|x| x.parse().ok()).collect())
                .unwrap_or_else(|| (1..=9).collect());
            let cfg = if quick {
                table3::Config {
                    samples_per_combo: 2,
                    max_epochs: 40,
                    flat_literals: false,
                    rows,
                    ..Default::default()
                }
            } else {
                table3::Config { rows, ..Default::default() }
            };
            let t = table3::generate(&cfg);
            println!("{}", t.render());
            maybe_json(args, &t)
        }
        "supp-table1" => {
            let rows = supp::generate(&[16, 12, 8]);
            println!("{}", supp::render(&rows));
            Ok(())
        }
        "fig1" => {
            let series = figures::fig1();
            println!(
                "value,{}",
                series.iter().map(|(l, _)| l.clone()).collect::<Vec<_>>().join(",")
            );
            for v in 0..256 {
                let row: Vec<String> =
                    series.iter().map(|(_, h)| format!("{:.5}", h[v])).collect();
                println!("{v},{}", row.join(","));
            }
            Ok(())
        }
        "fig2" => {
            for (label, k) in figures::fig2(2) {
                println!("{label}  [{} DCs]", figures::kmap_dc_count(&k));
                println!("{}", figures::render_kmap(&k));
            }
            Ok(())
        }
        "fig5" | "fig7" | "fig10" => {
            let rows = match cmd {
                "fig5" => figures::fig5_signals(),
                "fig7" => figures::fig7_signals(),
                _ => figures::fig10_signals(&dataset::generate(3, 7)),
            };
            println!("{:<16} {:>4} {:>8} {:>10}", "signal", "WL", "#values", "sparsity");
            for (name, wl, n, sp) in rows {
                println!("{name:<16} {wl:>4} {n:>8} {sp:>9.1}%", sp = sp * 100.0);
            }
            Ok(())
        }
        "fig6" | "fig8" => {
            let dir = PathBuf::from(args.get_or("out", "artifacts/figures"));
            let rows = if cmd == "fig6" { figures::fig6(&dir)? } else { figures::fig8(&dir)? };
            for (label, psnr) in rows {
                println!("{label:<16} PSNR = {}", ppc::tables::fmt_psnr(psnr));
            }
            println!("images -> {}", dir.display());
            Ok(())
        }
        "fig11" => {
            let dir = PathBuf::from(args.get_or("out", "artifacts/figures"));
            for path in figures::fig11(&dir)? {
                println!("{path}");
            }
            Ok(())
        }
        "fig12a" => {
            let cfg = if quick {
                figures::SweepConfig { samples_per_combo: 2, max_epochs: 30, seed: 7 }
            } else {
                figures::SweepConfig::default()
            };
            let thresholds = [0u32, 16, 32, 48, 64, 80, 96, 112, 128];
            println!("threshold_x,ccr_percent,mse");
            for (x, ccr, mse) in figures::fig12a(&thresholds, &cfg) {
                println!("{x},{ccr:.1},{mse:.4}");
            }
            Ok(())
        }
        "fig12bc" => {
            let cfg = if quick {
                figures::SweepConfig { samples_per_combo: 2, max_epochs: 30, seed: 7 }
            } else {
                figures::SweepConfig::default()
            };
            let rates = if quick {
                vec![1u32, 8, 32, 64]
            } else {
                vec![1u32, 2, 4, 8, 16, 32, 64]
            };
            let (ri, _rw, ccr, mse) = figures::fig12bc(&rates, &cfg);
            println!("# CCR% (rows = DS on image, cols = DS on weights)");
            print_matrix(&ri, &ccr);
            println!("# MSE");
            print_matrix(&ri, &mse);
            if let Some(path) = args.get("json") {
                std::fs::write(path, figures::sweep_to_json(&ri, &ccr, &mse).to_string())?;
            }
            Ok(())
        }
        "gen-faces" => {
            let out = PathBuf::from(args.get_or("out", "artifacts/faces.json"));
            if let Some(parent) = out.parent() {
                std::fs::create_dir_all(parent)?;
            }
            let samples = args.usize_or("samples", 5);
            let ds = dataset::generate(samples, args.u64_or("seed", 7));
            frnn_io::save_dataset(&ds, &out)?;
            println!(
                "faces: {} train / {} test -> {}",
                ds.train.len(),
                ds.test.len(),
                out.display()
            );
            Ok(())
        }
        "train-frnn" => {
            let faces = args.get_or("faces", "artifacts/faces.json");
            let ds = if Path::new(faces).exists() {
                frnn_io::load_dataset(Path::new(faces))?
            } else {
                println!("{faces} not found; generating in-memory dataset");
                dataset::generate(4, 7)
            };
            let cfg = net::TrainConfig {
                max_epochs: args.usize_or("epochs", 250),
                ..Default::default()
            };
            let r = net::train(&ds, &cfg);
            let q = net::quantize(&r.net);
            let ev = net::evaluate_fx(&q, &ds.test, &Chain::id(), &Chain::id());
            println!(
                "TE={} mse={:.4} fixed-point test CCR={:.1}%",
                r.epochs,
                r.mse,
                ev.ccr * 100.0
            );
            let out = PathBuf::from(args.get_or("out", "artifacts/frnn_weights_rust.json"));
            if let Some(parent) = out.parent() {
                std::fs::create_dir_all(parent)?;
            }
            frnn_io::save_weights(&r.net, &out)?;
            println!("weights -> {}", out.display());
            Ok(())
        }
        "serve" => serve_demo(args),
        "loadgen" => loadgen_cmd(args),
        "synth" => synth_adhoc(args),
        "" | "help" | "--help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            eprintln!("{USAGE}");
            bail!("unknown command {other:?}")
        }
    }
}

fn random_pixels(rng: &mut ppc::util::prng::Rng, len: usize, max: u64) -> Vec<i32> {
    (0..len).map(|_| rng.below(max) as i32).collect()
}

fn print_matrix(rates: &[u32], m: &[Vec<f64>]) {
    print!("ds\\ds,");
    println!("{}", rates.iter().map(|r| r.to_string()).collect::<Vec<_>>().join(","));
    for (i, row) in m.iter().enumerate() {
        let cells: Vec<String> = row.iter().map(|v| format!("{v:.2}")).collect();
        println!("{},{}", rates[i], cells.join(","));
    }
}

/// Default native serving catalog: the Balanced/Economy tiers
/// (precise full-range blocks take the longest to synthesize).
const DEFAULT_NATIVE_MODELS: [&str; 6] =
    ["gdf/ds16", "gdf/ds32", "blend/ds16", "blend/ds32", "frnn/th48ds16", "frnn/ds32"];

/// Run the coordinator with a mixed workload over the chosen backend.
fn serve_demo(args: &Args) -> Result<()> {
    use ppc::catalog::{App, ModelKey};
    use ppc::coordinator::{
        Coordinator, CoordinatorConfig, Job, OverloadPolicy, Placement, Quality, Rejection,
        SubmitError, Tensor,
    };
    use std::time::{Duration, Instant};
    let backend = args.get_or("backend", "native");
    let native = match backend {
        "native" => true,
        "pjrt" => false,
        other => bail!("unknown backend {other:?} (native|pjrt)"),
    };
    let n = args.usize_or("requests", if native { 24 } else { 64 });
    let side = args.usize_or("image-size", if native { 64 } else { 256 });
    let img_len = side * side;
    let shards = args.usize_or(
        "shards",
        std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
    );
    // Unit execution backend (tape / lut / auto-calibrated), applied
    // before any executor builds its units.
    if let Some(b) = args.get("unit-backend") {
        let backend = ppc::ppc::lut::UnitBackend::parse(b)
            .ok_or_else(|| anyhow!("unknown --unit-backend {b:?} (tape|lut|auto)"))?;
        ppc::ppc::lut::set_unit_backend(backend);
    }
    // Chunk-parallel batch execution: split each shard's 256-lane chunk
    // loops over this many worker threads. PPC_THREADS (the established
    // env knob) wins over both the flag and the derived default.
    if std::env::var("PPC_THREADS").is_err() {
        let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
        let per_shard = args.usize_or("threads-per-shard", (cores / shards).max(1));
        ppc::util::pool::set_batch_threads(per_shard.max(1));
    }
    // The admission front door: every submit path goes through it.
    let overload = OverloadPolicy::parse(args.get_or("overload", "wait"))?;
    let deadline_ms: Option<u64> = match args.get("deadline-ms") {
        Some(v) => Some(v.parse()?),
        None => None,
    };
    // Adaptive quality serving: parse the mode and the floor up front
    // so a bad spec fails before anything synthesizes.
    let quality_auto = match args.get_or("quality", "fixed") {
        "auto" => true,
        "fixed" => false,
        other => bail!("unknown --quality {other:?} (auto|fixed)"),
    };
    let floor = match args.get("quality-floor") {
        Some(spec) => ppc::coordinator::QualityFloor::parse(spec)?,
        None => ppc::coordinator::QualityFloor::none(),
    };
    if quality_auto && !native {
        bail!("--quality auto needs the native backend (tier quality is measured at registration)");
    }
    // The fair share is a hard reservation, so it defaults off (1.0 =
    // cap only); the gate itself normalizes a full-pool share to 0.5
    // under `degrade`, where lower tiers must keep headroom.
    let base = CoordinatorConfig::default();
    let coord_cfg = CoordinatorConfig {
        queue_capacity: args.usize_or("queue-capacity", base.queue_capacity),
        overload,
        fair_share: args.f64_or("fair-share", base.fair_share),
        ..base
    };

    // The registered catalog (native knows it up front; PJRT discovers
    // it from the artifact manifest, so assume the full catalog there).
    let mut registered: Vec<ModelKey> = ModelKey::catalog();

    let coord = if native {
        // The typed model list: every key is parsed (and validated
        // against the catalog) before anything synthesizes.
        let keys: Vec<ModelKey> = match args.get("models") {
            Some(csv) => csv
                .split(',')
                .map(|s| ModelKey::parse(s.trim()))
                .collect::<Result<_>>()?,
            None => DEFAULT_NATIVE_MODELS
                .iter()
                .map(|s| ModelKey::parse(s).expect("default catalog keys are valid"))
                .collect(),
        };
        // Sticky placement: each model lands on --replicas shards
        // (consistent-hash spread, --placement pins individual keys),
        // and each shard builds only its assigned subset.
        let mut placement = Placement::spread(&keys, shards, args.usize_or("replicas", 1));
        if let Some(spec) = args.get("placement") {
            placement = placement.with_overrides(spec)?;
        }
        if let Some(t) = args.get("spill-threshold") {
            placement = placement.with_spill_threshold(t.parse()?);
        }
        let cache_dir: Option<String> = (!args.flag("no-cache"))
            .then(|| args.get_or("cache-dir", "artifacts/netlist-cache").to_string());
        // FRNN models carry weights: quick-train once if any requested,
        // the quantized net standing in for the deployed weights.
        let quant = if keys.iter().any(|k| k.app == App::Frnn) {
            println!("training a quick FRNN for the native registry…");
            let ds = dataset::generate(2, 0x5E12);
            let r = net::train(&ds, &net::TrainConfig { max_epochs: 30, ..Default::default() });
            Some(net::quantize(&r.net))
        } else {
            None
        };
        // --quality auto: measure every registered tier's quality once
        // (cache-backed, the same numbers the executors publish on
        // their responses) and hand the controller the registered tier
        // list, the profiles, and the floor.
        let autopilot = if quality_auto {
            use ppc::coordinator::{Autopilot, AutopilotConfig};
            let dir = cache_dir.as_deref().map(Path::new);
            let mut profiles = std::collections::BTreeMap::new();
            for key in &keys {
                let profile = match key.app {
                    App::Frnn => ppc::apps::quality::measure_frnn_cached(
                        dir,
                        key.config,
                        quant.as_ref().expect("frnn weights were trained above"),
                    ),
                    _ => ppc::apps::quality::measure_image_app_cached(dir, key.app, key.config)?,
                };
                profiles.insert(*key, profile);
            }
            Some(std::sync::Arc::new(Autopilot::new(
                AutopilotConfig { floor, ..AutopilotConfig::default() },
                keys.clone(),
                profiles,
                coord_cfg.queue_capacity,
            )))
        } else {
            None
        };
        let coord_cfg = CoordinatorConfig { autopilot, ..coord_cfg.clone() };
        // Each shard declares the whole catalog (so spill/failover
        // traffic can lazily register any key from the shared cache)
        // but eagerly builds only its assigned subset.
        let build = {
            let keys = keys.clone();
            move |_shard: usize,
                  assigned: &[ModelKey]|
                  -> Result<ppc::runtime::NativeExecutor> {
                let mut exec = ppc::runtime::NativeExecutor::new();
                if let Some(dir) = &cache_dir {
                    exec = exec.with_cache(dir)?;
                }
                for key in &keys {
                    exec = match key.app {
                        App::Frnn => exec.declare_frnn(
                            key.config,
                            quant.clone().expect("frnn weights were trained above"),
                        )?,
                        _ => exec.declare(*key)?,
                    };
                }
                exec.with_keys(assigned)
            }
        };
        if args.flag("list-models") {
            // build the full catalog once so every row has real build
            // numbers, then show each model's sticky shard set
            println!("building the native catalog…");
            let exec = build(0, &keys)?;
            println!(
                "{:<16} {:>11} {:>8} {:>9} {:>6} {:>8}  {:<12} {:<8}",
                "model", "build(ms)", "cached", "gates", "lanes", "backend", "quality", "shards"
            );
            for info in exec.model_infos() {
                println!(
                    "{:<16} {:>11.1} {:>8} {:>9} {:>6} {:>8}  {:<12} {:<8}",
                    info.key.to_string(),
                    info.build_time.as_secs_f64() * 1e3,
                    if info.cached { "yes" } else { "no" },
                    info.gates,
                    info.lanes,
                    info.backend,
                    info.quality.map(|q| q.render()).unwrap_or_else(|| "-".into()),
                    placement
                        .shards_of(info.key)
                        .map(Placement::render_shards)
                        .unwrap_or_else(|| "-".into())
                );
            }
            if let Some(cache) = exec.cache() {
                println!(
                    "netlist cache: {} hits, {} misses -> {}",
                    cache.hits(),
                    cache.misses(),
                    cache.dir().display()
                );
            }
            return Ok(());
        }
        registered = keys.clone();
        println!(
            "spinning up {shards} engine shard(s), sticky placement: {placement}\n\
             (spill past {} queued batches)",
            placement.spill_threshold()
        );
        let coord = Coordinator::with_native_placed(coord_cfg.clone(), placement, build)
            .map_err(|e| anyhow!("{e:#}"))?;
        // effective gate limits (the gate normalizes the per-key share
        // under degrade), not just the configured ones
        println!(
            "admission: policy={overload}, cap {} in flight, {} per model",
            coord.admission().cap(),
            coord.admission().key_cap()
        );
        if let Some(ap) = coord.autopilot() {
            let floor = ap.config().floor;
            println!(
                "quality autopilot: tick {:.0}ms, refractory {:.0}ms, floor {}",
                ap.config().tick.as_secs_f64() * 1e3,
                ap.config().refractory.as_secs_f64() * 1e3,
                if floor.is_empty() { "none".to_string() } else { floor.render() }
            );
        }
        // per-shard residency after the subset builds
        for (shard, resident) in coord.resident_keys()?.iter().enumerate() {
            println!(
                "shard{shard}: {} resident model(s) [{}]",
                resident.len(),
                ppc::catalog::join(resident.iter())
            );
        }
        coord
    } else {
        if args.flag("list-models") {
            bail!("--list-models needs the native backend (artifact catalogs live in the manifest)");
        }
        let dir = artifacts_dir(args);
        Coordinator::with_artifacts(&dir, coord_cfg.clone())
            .map_err(|e| anyhow!("{e:#}\nhint: run `make artifacts` first"))?
    };

    // --listen: put the TCP front door in front of the coordinator
    // instead of running the in-process demo workload. The server runs
    // until a client sends a `shutdown` control frame (there is no
    // portable std signal handling), then drains every connection and
    // flushes the metrics report.
    if let Some(listen) = args.get("listen") {
        let listener = std::net::TcpListener::bind(listen)
            .map_err(|e| anyhow!("bind {listen}: {e}"))?;
        let coord = std::sync::Arc::new(coord);
        // --peer joins the serving ring. The node advertises its
        // *resolved* bound address (port 0 only becomes a real port at
        // bind time) so every member ranks identical node strings.
        let cluster = match args.get("peer") {
            Some(spec) => {
                let peers: Vec<String> = spec
                    .split(',')
                    .map(|s| s.trim().to_string())
                    .filter(|s| !s.is_empty())
                    .collect();
                if peers.is_empty() {
                    bail!("--peer wants a comma-separated list of HOST:PORT addresses");
                }
                let node = listener.local_addr()?.to_string();
                let ccfg = ppc::net::ClusterConfig {
                    node: node.clone(),
                    peers,
                    probe_interval: Duration::from_millis(
                        args.u64_or("probe-interval-ms", 500),
                    ),
                    probe_timeout: Duration::from_millis(args.u64_or("probe-timeout-ms", 250)),
                    ..ppc::net::ClusterConfig::default()
                };
                let cluster = std::sync::Arc::new(ppc::net::Cluster::start(ccfg));
                println!(
                    "cluster: node {node}, {} member(s) [{}]",
                    cluster.members().len(),
                    cluster.members().join(", ")
                );
                Some(cluster)
            }
            None => None,
        };
        let server = ppc::net::NetServer::spawn_cluster(
            listener,
            coord.clone(),
            ppc::net::NetServerConfig::default(),
            cluster.clone(),
        )?;
        // this exact line is the readiness signal scripts poll for
        println!("listening on {}", server.local_addr());
        let _ = std::io::Write::flush(&mut std::io::stdout());
        server.join();
        println!("shutdown frame received; drained");
        if let Some(c) = &cluster {
            c.stop();
            println!("{}", c.report());
        }
        println!("{}", coord.metrics().report());
        if let Some(ap) = coord.autopilot() {
            println!("{}", ap.report());
        }
        // dropping the last Coordinator handle drains the engine pool
        return Ok(());
    }

    // Workload shaped to the registered catalog: only apps with at
    // least one model, each request routed to a quality its app serves.
    let apps: Vec<App> = App::ALL
        .iter()
        .copied()
        .filter(|&a| registered.iter().any(|k| k.app == a))
        .collect();
    if apps.is_empty() {
        bail!("no models registered — nothing to serve");
    }
    let qualities: Vec<Vec<Quality>> = apps
        .iter()
        .map(|&a| {
            [Quality::Precise, Quality::Balanced, Quality::Economy]
                .into_iter()
                .filter(|&q| registered.contains(&ModelKey::route(a, q)))
                .collect()
        })
        .collect();

    let mut rng = ppc::util::prng::Rng::new(0x5E12);
    let mut tickets = Vec::new();
    let mut shed = 0u64;
    let mut expired = 0u64;
    let t0 = Instant::now();
    for i in 0..n {
        let app = apps[i % apps.len()];
        let quals = &qualities[i % apps.len()];
        let quality = quals[(i / apps.len()) % quals.len()];
        let job = match app {
            App::Gdf => Job::Denoise {
                image: Tensor::matrix(side, side, random_pixels(&mut rng, img_len, 256))
                    .expect("square demo image"),
            },
            App::Blend => Job::Blend {
                p1: Tensor::matrix(side, side, random_pixels(&mut rng, img_len, 256))
                    .expect("square demo image"),
                p2: Tensor::matrix(side, side, random_pixels(&mut rng, img_len, 256))
                    .expect("square demo image"),
                alpha: 64,
            },
            App::Frnn => Job::Classify { pixels: random_pixels(&mut rng, 960, 160) },
        };
        let submitted = match deadline_ms {
            Some(ms) => {
                coord.submit_deadline(job, quality, Instant::now() + Duration::from_millis(ms))
            }
            None => coord.submit_blocking(job, quality),
        };
        match submitted {
            Ok(t) => tickets.push(t),
            // typed overload outcomes are part of the demo, not errors
            Err(SubmitError::Shed) | Err(SubmitError::Busy) => shed += 1,
            Err(SubmitError::Expired) => expired += 1,
            Err(SubmitError::Down) => bail!("coordinator went down mid-demo"),
        }
    }
    let mut answered = 0u64;
    let mut degraded = 0u64;
    for t in tickets {
        match t.wait() {
            Ok(r) => {
                answered += 1;
                if r.degraded {
                    degraded += 1;
                }
            }
            Err(e) => match e.downcast_ref::<Rejection>() {
                Some(Rejection::DeadlineExpired) => expired += 1,
                Some(Rejection::Shed) => shed += 1,
                // unknown-model is a wire-boundary outcome; in-process
                // demo submits always route to registered keys
                Some(Rejection::UnknownModel) | None => return Err(e),
            },
        }
    }
    let dt = t0.elapsed();
    println!(
        "{n} requests in {:.2}s ({:.1} req/s): {answered} answered \
         ({degraded} degraded), {shed} shed, {expired} expired",
        dt.as_secs_f64(),
        n as f64 / dt.as_secs_f64()
    );
    println!("{}", coord.metrics().report());
    if let Some(ap) = coord.autopilot() {
        println!("{}", ap.report());
    }
    Ok(())
}

/// Open-loop load generation against a `serve --listen` front door.
fn loadgen_cmd(args: &Args) -> Result<()> {
    use ppc::catalog::{App, Quality};
    use ppc::net::loadgen::{self, LoadgenConfig};
    use ppc::util::bench;
    use std::time::Duration;

    let addr = args
        .get("connect")
        .ok_or_else(|| anyhow!("loadgen needs --connect HOST:PORT (from `serve --listen`)"))?;
    let quick = args.flag("quick");
    let deadline_ms = match args.get("deadline-ms") {
        Some(v) => Some(v.parse().map_err(|e| anyhow!("--deadline-ms {v:?}: {e}"))?),
        None => None,
    };
    let cfg = LoadgenConfig {
        addr: addr.to_string(),
        clients: args.usize_or("clients", if quick { 2 } else { 4 }),
        rps: args.f64_or("rps", if quick { 40.0 } else { 200.0 }),
        duration: Duration::from_secs_f64(args.f64_or(
            "duration-s",
            if quick { 2.0 } else { 10.0 },
        )),
        app: App::parse(args.get_or("app", "gdf"))?,
        quality: Quality::parse(args.get_or("quality", "balanced"))?,
        deadline_ms,
        image_size: args.usize_or("image-size", if quick { 16 } else { 64 }),
        classify_row: args.usize_or("classify-row", 960),
        seed: args.u64_or("seed", 0x10AD),
    };
    if args.get("baseline-connect").is_some() && args.get("ramp").is_some() {
        bail!("--baseline-connect compares fixed-rate passes; drop --ramp");
    }
    // --ramp sweeps the arrival rate over phases; otherwise one
    // fixed-rate pass. Both paths share the shutdown/exit-code tail.
    let steps = match args.get("ramp") {
        Some(spec) => {
            let (low, high, n) = loadgen::parse_ramp(spec)?;
            println!(
                "open-loop ramp -> {}: {} clients, {:.0}->{:.0} req/s over {} steps of \
                 {:.1}s ({} @ {})",
                cfg.addr,
                cfg.clients,
                low,
                high,
                n,
                cfg.duration.as_secs_f64() / n as f64,
                cfg.app.name(),
                cfg.quality.name(),
            );
            let steps = loadgen::run_ramp(&cfg, low, high, n)?;
            for (i, step) in steps.iter().enumerate() {
                println!("-- ramp step {i} @ {:.0} req/s --", step.rps);
                print!("{}", step.report.render());
            }
            let json = loadgen::ramp_summary_json(&steps);
            bench::write_summary("BENCH_loadgen.json", &json);
            bench::append_history("BENCH_history.jsonl", &json);
            steps
        }
        None => {
            println!(
                "open-loop loadgen -> {}: {} clients, {:.0} req/s target for {:.1}s ({} @ {})",
                cfg.addr,
                cfg.clients,
                cfg.rps,
                cfg.duration.as_secs_f64(),
                cfg.app.name(),
                cfg.quality.name(),
            );
            let report = loadgen::run(&cfg)?;
            print!("{}", report.render());
            // --baseline-connect: the same fixed-rate pass against a
            // node that owns the keys locally; p99(forwarded) over
            // p99(local) is the forward-hop overhead number the
            // regression gate tracks.
            let baseline = match args.get("baseline-connect") {
                Some(baddr) => {
                    println!(
                        "baseline loadgen -> {baddr} (same schedule, locally owned keys)"
                    );
                    let base = loadgen::run(&LoadgenConfig {
                        addr: baddr.to_string(),
                        ..cfg.clone()
                    })?;
                    print!("{}", base.render());
                    println!(
                        "forwarded_vs_local_p99_ratio {:.3} (forwarded p99 {:.3}ms / \
                         local p99 {:.3}ms)",
                        loadgen::forwarded_vs_local_p99_ratio(&report, &base),
                        report.latency.p99 * 1e3,
                        base.latency.p99 * 1e3
                    );
                    Some(base)
                }
                None => None,
            };
            let json = match &baseline {
                Some(base) => loadgen::comparison_summary_json(&report, base),
                None => report.summary_json("open-loop e2e latency (scheduled->response)"),
            };
            bench::write_summary("BENCH_loadgen.json", &json);
            bench::append_history("BENCH_history.jsonl", &json);
            let mut steps = vec![loadgen::RampStep { rps: cfg.rps, report }];
            steps.extend(baseline.map(|report| loadgen::RampStep { rps: cfg.rps, report }));
            steps
        }
    };
    if args.flag("shutdown") {
        loadgen::send_shutdown(addr)?;
        if let Some(baddr) = args.get("baseline-connect") {
            loadgen::send_shutdown(baddr)?;
        }
        println!("server drained (shutdown frame acked)");
    }
    let protocol_errors: usize = steps.iter().map(|s| s.report.protocol_errors).sum();
    let sent: usize = steps.iter().map(|s| s.report.sent).sum();
    let answered: usize = steps.iter().map(|s| s.report.answered).sum();
    if protocol_errors > 0 {
        bail!("{protocol_errors} protocol error(s) across {sent} sent requests");
    }
    if answered == 0 {
        bail!("no requests answered — is the server reachable and the model registered?");
    }
    Ok(())
}

/// Ad-hoc PPC block synthesis (the Fig. 3 design flow as a tool).
fn synth_adhoc(args: &Args) -> Result<()> {
    use ppc::ppc::flow;
    use ppc::ppc::preprocess::ValueSet;
    let block = args.get_or("block", "adder");
    let wl = args.usize_or("wl", 8) as u32;
    let mut chain = Chain::id();
    if let Some(x) = args.get("ds") {
        chain = chain.then(Preproc::Ds(x.parse()?));
    }
    if let Some(th) = args.get("th") {
        let (x, y) = th.split_once(',').ok_or_else(|| anyhow!("--th wants X,Y"))?;
        chain = chain.then(Preproc::Th { x: x.parse()?, y: y.parse()? });
    }
    let set = ValueSet::full(wl.min(8)).map_chain(&chain);
    println!(
        "block={block} wl={wl} preprocessing={} sparsity={:.1}%",
        chain.label(),
        set.sparsity() * 100.0
    );
    let report = match block {
        "adder" => flow::segmented_adder("adhoc_adder", wl, wl, &set, &set, Objective::Area),
        "mult" => {
            if wl != 8 {
                bail!("composed multiplier supports wl=8");
            }
            flow::composed_mult8("adhoc_mult", &set, &set, Objective::Area)
        }
        other => bail!("unknown block {other} (adder|mult)"),
    };
    println!(
        "literals={} area={:.0}GE delay={:.2}ns power={:.1}uW dc={:.1}% verify_errors={}",
        report.literals,
        report.area_ge,
        report.delay_ns,
        report.power_uw,
        report.dc_fraction * 100.0,
        report.verify_errors
    );
    Ok(())
}
