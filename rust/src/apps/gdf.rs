//! Gaussian Denoising Filter hardware (paper Section IV, Fig. 5).
//!
//! The 3×3 window `1/16 · [1 2 1; 2 4 2; 1 2 1]` realized as the paper's
//! 8-adder tree with shift-left weights (no multipliers):
//!
//! ```text
//!  A1..A9 = window pixels (8 bit)
//!  Adder1 = A1 + A3          (9b)      Adder2 = A7 + A9        (9b)
//!  Adder3 = (A2<<1)+(A4<<1)  (10b)     Adder4 = (A6<<1)+(A8<<1)(10b)
//!  Adder5 = Adder1 + Adder2  (10b)     Adder6 = Adder3 + Adder4(11b)
//!  Adder7 = Adder5 + Adder6  (12b)     Adder8 = Adder7 + (A5<<2)(13b)
//!  out    = Adder8 >> 4
//! ```
//!
//! The 1-bit shifts give Adder-3/4 a DS₂-like input sparsity, the 2-bit
//! shift gives Adder-8's right input a DS₄-like sparsity, and the 1-bit
//! WL difference at Adder-7 produces the "natural-like" output sparsity —
//! all three observations in the paper's Fig. 5 discussion fall out of
//! the value-set propagation in [`gdf_signal_sets`].

use super::image::Image;
use crate::catalog::{Datapath, Tensor, LANES};
use crate::logic::map::Objective;
use crate::ppc::flow::{self, BlockReport};
use crate::ppc::preprocess::{Chain, ValueSet};
use crate::ppc::units::{combined_backend, AdderUnit, FreshSynth, NetlistSource};
use crate::util::pool;
use anyhow::{anyhow, bail, Result};

/// Bit-accurate GDF datapath for one window (pixels in row-major A1..A9
/// order). `pre` is applied to each primary input first (the paper's
/// intentional sparsity insertion).
#[inline]
pub fn gdf_window(px: [u8; 9], pre: &Chain) -> u8 {
    let p: Vec<u32> = px.iter().map(|&v| pre.apply(v as u32)).collect();
    let adder1 = p[0] + p[2];
    let adder2 = p[6] + p[8];
    let adder3 = (p[1] << 1) + (p[3] << 1);
    let adder4 = (p[5] << 1) + (p[7] << 1);
    let adder5 = adder1 + adder2;
    let adder6 = adder3 + adder4;
    let adder7 = adder5 + adder6;
    let adder8 = adder7 + (p[4] << 2);
    (adder8 >> 4).min(255) as u8
}

/// Filter a whole image (border-replicated).
pub fn gdf_filter(img: &Image, pre: &Chain) -> Image {
    let mut out = Image::new(img.width, img.height);
    for y in 0..img.height {
        for x in 0..img.width {
            out.set(x, y, gdf_window(gather_window(img, x, y), pre));
        }
    }
    out
}

/// Float reference filter (for PSNR sanity, not part of the hardware).
pub fn gdf_reference(img: &Image) -> Image {
    gdf_filter(img, &Chain::id())
}

/// Input value sets of the eight adders, as propagated from the primary
/// input value set. Index 0 = Adder1, etc. Each entry is
/// `(left_set, right_set, wl_left, wl_right)`.
pub struct GdfSignals {
    pub adders: Vec<(ValueSet, ValueSet, u32, u32)>,
    /// Output (post shift) value set, for histogram display.
    pub output: ValueSet,
}

/// Propagate a primary-input value set through the Fig. 5 structure.
pub fn gdf_signal_sets(input: &ValueSet) -> GdfSignals {
    let a = input.clone(); // 8b pixel set
    let a_sh1 = a.shl(1);
    let a_sh2 = a.shl(2);
    let adder1 = a.sum(&a); // 9b
    let adder2 = adder1.clone();
    let adder3 = a_sh1.sum(&a_sh1); // 10b
    let adder4 = adder3.clone();
    let adder5 = adder1.sum(&adder2); // 10b
    let adder6 = adder3.sum(&adder4); // 11b
    let adder7 = adder5.sum(&adder6); // 12b
    let adder8 = adder7.sum(&a_sh2); // 13b
    GdfSignals {
        adders: vec![
            (a.clone(), a.clone(), 8, 8),
            (a.clone(), a.clone(), 8, 8),
            (a_sh1.clone(), a_sh1.clone(), 9, 9),
            (a_sh1.clone(), a_sh1.clone(), 9, 9),
            (adder1.clone(), adder2.clone(), 9, 9),
            (adder3.clone(), adder4.clone(), 10, 10),
            (adder5.clone(), adder6.clone(), 10, 11),
            (adder7.clone(), a_sh2.clone(), 12, 10),
        ],
        output: adder8.shr(4),
    }
}

/// Netlist-backed GDF datapath: the eight Fig. 5 adders as synthesized
/// PPC [`AdderUnit`]s, executed bit-parallel
/// ([`crate::catalog::LANES`] windows per compiled-tape pass).
/// Bit-exact with [`gdf_filter`] under the same preprocessing — the
/// execution engine behind the native serving backend.
pub struct GdfHardware {
    pub pre: Chain,
    adders: Vec<AdderUnit>,
}

impl GdfHardware {
    /// Synthesize the adder tree for raw pixels drawn from `input`
    /// (pre-preprocessing; use `ValueSet::full(8)` to serve any image),
    /// with the intentional-sparsity chain `pre` applied at the inputs.
    pub fn synthesize(input: &ValueSet, pre: &Chain, objective: Objective) -> GdfHardware {
        GdfHardware::synthesize_via(input, pre, objective, &FreshSynth)
    }

    /// Like [`GdfHardware::synthesize`], with netlists drawn from
    /// `source` (fresh synthesis or the persistent cache).
    pub fn synthesize_via(
        input: &ValueSet,
        pre: &Chain,
        objective: Objective,
        source: &dyn NetlistSource,
    ) -> GdfHardware {
        let sig = gdf_signal_sets(&input.map_chain(pre));
        let adders = sig
            .adders
            .iter()
            .enumerate()
            .map(|(i, (l, r, wl, wr))| {
                AdderUnit::synthesize_via(
                    &format!("gdf_adder{}", i + 1),
                    *wl,
                    *wr,
                    l,
                    r,
                    objective,
                    source,
                )
            })
            .collect();
        GdfHardware { pre: pre.clone(), adders }
    }

    /// Total gate count across the eight adders.
    pub fn num_gates(&self) -> usize {
        self.adders.iter().map(|a| a.num_gates()).sum()
    }

    /// Which unit backend serves batches: `"lut"`, `"tape"`, or
    /// `"mixed"`.
    pub fn backend_name(&self) -> &'static str {
        combined_backend(self.adders.iter().map(|a| a.backend_name()))
    }

    /// Run one contiguous run of preprocessed windows through the tree
    /// serially; `p[k]` holds signal `A{k+1}` of every window. Each
    /// adder pools the run into [`crate::catalog::LANES`]-lane passes
    /// ([`AdderUnit::add_many_threads`] at one thread — parallelism
    /// lives one level up, in [`GdfHardware::segment_values`], so tree
    /// levels never nest parallel regions).
    fn window_tree_range(&self, p: &[Vec<u32>; 9]) -> Vec<u32> {
        let add = |unit: &AdderUnit, a: &[u32], b: &[u32]| -> Vec<u32> {
            unit.add_many_threads(a, b, 1).iter().map(|&v| v as u32).collect()
        };
        let shl = |v: &[u32], k: u32| -> Vec<u32> { v.iter().map(|&x| x << k).collect() };
        let a1 = add(&self.adders[0], &p[0], &p[2]);
        let a2 = add(&self.adders[1], &p[6], &p[8]);
        let a3 = add(&self.adders[2], &shl(&p[1], 1), &shl(&p[3], 1));
        let a4 = add(&self.adders[3], &shl(&p[5], 1), &shl(&p[7], 1));
        let a5 = add(&self.adders[4], &a1, &a2);
        let a6 = add(&self.adders[5], &a3, &a4);
        let a7 = add(&self.adders[6], &a5, &a6);
        let a8 = add(&self.adders[7], &a7, &shl(&p[4], 2));
        a8.iter().map(|&v| v >> 4).collect()
    }

    /// Filter a whole image through the synthesized netlists
    /// (border-replicated, like [`gdf_filter`]).
    pub fn filter(&self, img: &Image) -> Image {
        self.filter_many(std::slice::from_ref(img))
            .pop()
            .expect("one image in, one image out")
    }

    /// Filter a whole batch of images (shapes may differ) through one
    /// pooled window stream: the lane-batched serving path. Windows
    /// from every image share the same 256-lane tape passes, so a
    /// batch of small images costs barely more than its total pixel
    /// count — tail lanes go idle once per *segment*, not once per
    /// request. The stream is processed in bounded segments
    /// ([`SEG_WINDOWS`] windows ≈ a few hundred KB of lane buffers) so
    /// huge images cannot balloon shard memory; within a segment the
    /// gather + tree work splits across [`pool::batch_threads`] workers
    /// ([`GdfHardware::segment_values`]).
    pub fn filter_many(&self, imgs: &[Image]) -> Vec<Image> {
        let mut outs: Vec<Image> =
            imgs.iter().map(|im| Image::new(im.width, im.height)).collect();
        // flat window-index space across the whole batch: window `f` of
        // the stream is pixel `f - offs[ii]` of image `ii`
        let mut offs = Vec::with_capacity(imgs.len() + 1);
        let mut acc = 0usize;
        offs.push(0);
        for img in imgs {
            acc += img.width * img.height;
            offs.push(acc);
        }
        let total = acc;
        let mut seg = 0usize;
        while seg < total {
            let seg_end = (seg + SEG_WINDOWS).min(total);
            let vals = self.segment_values(imgs, &offs, seg, seg_end);
            // scatter the segment's results back to their pixels
            let mut ii = offs.partition_point(|&o| o <= seg) - 1;
            for (d, &v) in vals.iter().enumerate() {
                let flat = seg + d;
                while offs[ii + 1] <= flat {
                    ii += 1;
                }
                outs[ii].pixels[flat - offs[ii]] = v.min(255) as u8;
            }
            seg = seg_end;
        }
        outs
    }

    /// Gather + tree for the flat window range `[s, e)` of one segment:
    /// the range splits into [`LANES`]-aligned chunks across
    /// [`pool::batch_threads`] workers, each gathering its own window
    /// columns and running the tree serially. Alignment keeps the
    /// per-pass lane grouping identical at any thread count, so the
    /// bits can't depend on the worker count.
    fn segment_values(&self, imgs: &[Image], offs: &[usize], s: usize, e: usize) -> Vec<u32> {
        let n = e - s;
        let run = |cs: usize, ce: usize| -> Vec<u32> {
            let mut win: [Vec<u32>; 9] = Default::default();
            for w in win.iter_mut() {
                w.reserve(ce - cs);
            }
            let mut ii = offs.partition_point(|&o| o <= cs) - 1;
            for flat in cs..ce {
                while offs[ii + 1] <= flat {
                    ii += 1;
                }
                let img = &imgs[ii];
                let p = flat - offs[ii];
                let px = gather_window(img, p % img.width, p / img.width);
                for (k, w) in win.iter_mut().enumerate() {
                    w.push(self.pre.apply(px[k] as u32));
                }
            }
            self.window_tree_range(&win)
        };
        let nblocks = n.div_ceil(LANES);
        let threads = pool::batch_threads().min(nblocks.max(1));
        if threads <= 1 {
            return run(s, e);
        }
        pool::scope_chunks(nblocks, threads, |bs, be| {
            run(s + bs * LANES, s + (be * LANES).min(n))
        })
        .concat()
    }

    /// Filter one image through the *scalar* netlist walk (one minterm
    /// at a time, no bit-slicing) — the per-request baseline the
    /// lane-batched serving bench compares against. Kept wiring-for-
    /// wiring parallel to [`GdfHardware::window_tree_range`]; the
    /// `lane_batched_and_scalar_paths_agree` test pins the two
    /// together.
    pub fn filter_scalar(&self, img: &Image) -> Image {
        let mut out = Image::new(img.width, img.height);
        let add = |u: &AdderUnit, a: u32, b: u32| u.eval_scalar(a, b) as u32;
        for y in 0..img.height {
            for x in 0..img.width {
                let px = gather_window(img, x, y);
                let p: Vec<u32> = px.iter().map(|&v| self.pre.apply(v as u32)).collect();
                let a1 = add(&self.adders[0], p[0], p[2]);
                let a2 = add(&self.adders[1], p[6], p[8]);
                let a3 = add(&self.adders[2], p[1] << 1, p[3] << 1);
                let a4 = add(&self.adders[3], p[5] << 1, p[7] << 1);
                let a5 = add(&self.adders[4], a1, a2);
                let a6 = add(&self.adders[5], a3, a4);
                let a7 = add(&self.adders[6], a5, a6);
                let a8 = add(&self.adders[7], a7, p[4] << 2);
                out.set(x, y, (a8 >> 4).min(255) as u8);
            }
        }
        out
    }
}

/// Windows per pooled netlist segment: 64 full 256-lane passes, with
/// lane buffers and tree intermediates bounded to a few hundred KB no
/// matter how large the request images are.
const SEG_WINDOWS: usize = 16 * 1024;

/// The 3×3 border-replicated window around `(x, y)` in A1..A9 order —
/// the one gather shared by the sim, the lane-batched path and the
/// scalar baseline.
#[inline]
fn gather_window(img: &Image, x: usize, y: usize) -> [u8; 9] {
    let (xi, yi) = (x as isize, y as isize);
    [
        img.get_clamped(xi - 1, yi - 1),
        img.get_clamped(xi, yi - 1),
        img.get_clamped(xi + 1, yi - 1),
        img.get_clamped(xi - 1, yi),
        img.get_clamped(xi, yi),
        img.get_clamped(xi + 1, yi),
        img.get_clamped(xi - 1, yi + 1),
        img.get_clamped(xi, yi + 1),
        img.get_clamped(xi + 1, yi + 1),
    ]
}

fn decode_request(inputs: &[Tensor]) -> Result<Image> {
    if inputs.len() != 1 {
        bail!("expected 1 input tensor (the image), got {}", inputs.len());
    }
    Image::from_tensor(&inputs[0], "image")
}

impl Datapath for GdfHardware {
    /// One image tensor in (`[h, w]`, or flat square), one out.
    fn exec(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let img = decode_request(inputs)?;
        Ok(vec![self.filter(&img).to_tensor()])
    }

    /// Lane-batched path: every request's windows share the same
    /// 256-lane tape passes ([`GdfHardware::filter_many`]). Bit-exact
    /// with per-request [`Datapath::exec`].
    fn exec_batch(&self, batch: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        let mut imgs = Vec::with_capacity(batch.len());
        for (i, inputs) in batch.iter().enumerate() {
            imgs.push(decode_request(inputs).map_err(|e| anyhow!("request {i}: {e:#}"))?);
        }
        Ok(self
            .filter_many(&imgs)
            .into_iter()
            .map(|im| vec![im.to_tensor()])
            .collect())
    }

    fn num_gates(&self) -> usize {
        GdfHardware::num_gates(self)
    }

    fn backend_name(&self) -> &'static str {
        GdfHardware::backend_name(self)
    }
}

/// Hardware report for the whole GDF (8 adders), PPC path: every adder
/// synthesized with the care set its inputs actually produce.
pub fn gdf_ppc_hardware(input: &ValueSet, objective: Objective) -> Vec<BlockReport> {
    let sig = gdf_signal_sets(input);
    sig.adders
        .iter()
        .enumerate()
        .map(|(i, (l, r, wl, wr))| {
            flow::segmented_adder(&format!("gdf_adder{}", i + 1), *wl, *wr, l, r, objective)
        })
        .collect()
}

/// Conventional GDF hardware (precise ripple adders, same WLs).
pub fn gdf_conventional_hardware(objective: Objective) -> Vec<BlockReport> {
    let wls = [(8u32, 8u32), (8, 8), (9, 9), (9, 9), (9, 9), (10, 10), (10, 11), (12, 10)];
    wls.iter()
        .enumerate()
        .map(|(i, &(l, r))| flow::conventional_adder(&format!("gdf_adder{}", i + 1), l, r, objective))
        .collect()
}

/// Aggregate a per-adder report list into the table row quantities.
pub fn aggregate(reports: &[BlockReport]) -> BlockReport {
    let mut out = BlockReport { name: "gdf_total".into(), ..Default::default() };
    for r in reports {
        out.literals += r.literals;
        out.area_ge += r.area_ge;
        out.power_uw += r.power_uw;
        out.verify_errors += r.verify_errors;
    }
    // Critical path: A1→A5→A7→A8 or A3→A6→A7→A8, whichever is longer.
    let path1 = reports[0].delay_ns + reports[4].delay_ns + reports[6].delay_ns + reports[7].delay_ns;
    let path2 = reports[2].delay_ns + reports[5].delay_ns + reports[6].delay_ns + reports[7].delay_ns;
    out.delay_ns = path1.max(path2);
    out.dc_fraction = reports.iter().map(|r| r.dc_fraction).sum::<f64>() / reports.len() as f64;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::image::{add_gaussian_noise, synthetic_photo};
    use crate::ppc::preprocess::Preproc;

    #[test]
    fn window_matches_float_convolution() {
        // hardware output == floor(conv/16) for exact inputs
        let px = [10u8, 20, 30, 40, 50, 60, 70, 80, 90];
        let want = (10 + 2 * 20 + 30 + 2 * 40 + 4 * 50 + 2 * 60 + 70 + 2 * 80 + 90) / 16;
        assert_eq!(gdf_window(px, &Chain::id()) as u32, want);
    }

    #[test]
    fn filter_smooths_noise() {
        let clean = synthetic_photo(64, 64, 11);
        let noisy = add_gaussian_noise(&clean, 12.0, 12);
        let filtered = gdf_filter(&noisy, &Chain::id());
        let before = clean.psnr(&noisy);
        let after = clean.psnr(&filtered);
        assert!(after > before, "filter should denoise: {after} !> {before}");
    }

    #[test]
    fn ds_preprocessing_degrades_gracefully() {
        let img = synthetic_photo(64, 64, 13);
        let base = gdf_filter(&img, &Chain::id());
        let mut prev_psnr = f64::INFINITY;
        for k in [2u32, 8, 32] {
            let out = gdf_filter(&img, &Chain::of(Preproc::Ds(k)));
            let p = base.psnr(&out);
            assert!(p < prev_psnr, "PSNR should fall with DS rate");
            prev_psnr = p;
        }
        // DS16-class quality stays "good" in the paper's sense (>26 dB)
        let ds16 = gdf_filter(&img, &Chain::of(Preproc::Ds(16)));
        assert!(base.psnr(&ds16) > 26.0);
    }

    #[test]
    fn signal_sets_reproduce_paper_observations() {
        let full = ValueSet::full(8);
        let sig = gdf_signal_sets(&full);
        // Adder3 inputs have DS2-like sparsity (only even values)
        let (l3, _, _, _) = &sig.adders[2];
        assert!(l3.iter().all(|v| v % 2 == 0));
        assert!((l3.sparsity() - 0.5).abs() < 0.01);
        // Adder8 right input has DS4-like sparsity
        let (_, r8, _, _) = &sig.adders[7];
        assert!(r8.iter().all(|v| v % 4 == 0));
        // Adder7 output (via output set pre-shift) exists and is sparse:
        // 12-bit range but far fewer distinct values than 2^12? No —
        // sums densify; the paper's claim is about the histogram shape.
        // We check the DS2 sparsity propagated to Adder7's right input:
        let (_, r7, _, _) = &sig.adders[6];
        assert!(r7.iter().all(|v| v % 2 == 0), "adder7 right input keeps DS2 grid");
    }

    #[test]
    fn netlist_hardware_matches_bit_accurate_filter() {
        // the synthesized adder tree, executed bit-parallel, must agree
        // with the arithmetic fixed-point simulation pixel for pixel
        let img = synthetic_photo(24, 24, 5);
        let chain = Chain::of(Preproc::Ds(16));
        let hw = GdfHardware::synthesize(&ValueSet::full(8), &chain, Objective::Area);
        assert!(hw.num_gates() > 0);
        assert_eq!(hw.filter(&img), gdf_filter(&img, &chain));
    }

    #[test]
    fn datapath_serves_non_square_images() {
        let chain = Chain::of(Preproc::Ds(32));
        let hw = GdfHardware::synthesize(&ValueSet::full(8), &chain, Objective::Area);
        let img = synthetic_photo(24, 10, 6); // 24 wide, 10 tall
        let out = hw.exec(&[img.to_tensor()]).unwrap();
        assert_eq!(out[0].shape, vec![10, 24], "shape must survive the round trip");
        assert_eq!(out[0].data, gdf_filter(&img, &chain).to_tensor().data);
        // arity and flat-non-square requests are structured errors
        assert!(hw.exec(&[]).is_err());
        assert!(hw.exec(&[Tensor::vector(vec![0; 15])]).is_err());
    }

    #[test]
    fn lane_batched_and_scalar_paths_agree() {
        let chain = Chain::of(Preproc::Ds(32));
        let hw = GdfHardware::synthesize(&ValueSet::full(8), &chain, Objective::Area);
        // mixed shapes in one pooled batch — each output bit-exact with
        // both the fixed-point sim and the scalar netlist walk
        let imgs = vec![
            synthetic_photo(7, 5, 1),
            synthetic_photo(16, 16, 2),
            synthetic_photo(3, 11, 3),
        ];
        let outs = hw.filter_many(&imgs);
        for (img, out) in imgs.iter().zip(&outs) {
            assert_eq!(*out, gdf_filter(img, &chain));
            assert_eq!(*out, hw.filter_scalar(img));
        }
        // and through the Datapath batch interface
        let batch: Vec<Vec<Tensor>> = imgs.iter().map(|im| vec![im.to_tensor()]).collect();
        let got = hw.exec_batch(&batch).unwrap();
        for (i, out) in outs.iter().enumerate() {
            assert_eq!(got[i][0], out.to_tensor());
        }
        // one bad request names its index and fails the whole batch
        let mut bad = batch;
        bad[1] = vec![Tensor::vector(vec![300; 4])];
        let e = hw.exec_batch(&bad).unwrap_err();
        assert!(format!("{e:#}").contains("request 1"), "{e:#}");
    }

    #[test]
    fn pooled_segment_boundary_is_bit_exact() {
        // the pooled window stream flushes every SEG_WINDOWS (16K)
        // windows; a batch whose cumulative window count lands exactly
        // on, one short of, and one past the segment boundary must stay
        // bit-exact with the per-request path (guards the segmented
        // flush against off-by-one regressions)
        let chain = Chain::of(Preproc::Ds(32));
        let hw = GdfHardware::synthesize(&ValueSet::full(8), &chain, Objective::Area);
        assert_eq!(SEG_WINDOWS, 16 * 1024, "test is tuned to the segment size");
        // 127×129 = 16383 windows: one short of the boundary, so the
        // second request's first window lands exactly on it and its
        // remaining windows spill into the next segment
        let straddle = vec![synthetic_photo(129, 127, 21), synthetic_photo(5, 3, 22)];
        // 128×128 = 16384 windows: request one ends exactly at the
        // flush point; request two starts a fresh segment
        let exact = vec![synthetic_photo(128, 128, 23), synthetic_photo(4, 4, 24)];
        // 16385 windows split across requests: the flush cuts request
        // two in half mid-image
        let past = vec![
            synthetic_photo(129, 127, 25),
            synthetic_photo(2, 1, 26),
            synthetic_photo(7, 6, 27),
        ];
        for (name, imgs) in [("16383+", straddle), ("16384+", exact), ("16385±", past)] {
            let first = imgs[0].width * imgs[0].height;
            let total: usize = imgs.iter().map(|im| im.width * im.height).sum();
            assert!(
                (SEG_WINDOWS - 1..=SEG_WINDOWS).contains(&first) && total > SEG_WINDOWS,
                "{name}: batch must straddle the segment ({first} then {total} windows)"
            );
            let batch: Vec<Vec<Tensor>> = imgs.iter().map(|im| vec![im.to_tensor()]).collect();
            let got = hw.exec_batch(&batch).unwrap();
            for (i, img) in imgs.iter().enumerate() {
                assert_eq!(
                    got[i][0],
                    gdf_filter(img, &chain).to_tensor(),
                    "{name}: request {i} diverged across the segment boundary"
                );
            }
        }
    }

    #[test]
    fn lane_word_boundary_is_bit_exact_at_255_256_257_requests() {
        // the 256-wide lane word chunks the pooled window stream every
        // LANES windows inside add_many; request counts one short of,
        // exactly at, and one past that boundary must stay bit-exact
        // with the per-request sim (the 256-lane mirror of the 16K
        // segment-boundary test above)
        use crate::catalog::LANES;
        let chain = Chain::of(Preproc::Ds(32));
        let hw = GdfHardware::synthesize(&ValueSet::full(8), &chain, Objective::Area);
        assert_eq!(LANES, 256, "test is tuned to the lane width");
        for n in [255usize, 256, 257] {
            // n single-window (1×1) requests: window k comes from
            // request k, so the lane chunk cut falls between requests
            let imgs: Vec<Image> = (0..n).map(|i| synthetic_photo(1, 1, 31 + i as u64)).collect();
            let batch: Vec<Vec<Tensor>> = imgs.iter().map(|im| vec![im.to_tensor()]).collect();
            let got = hw.exec_batch(&batch).unwrap();
            for (i, img) in imgs.iter().enumerate() {
                assert_eq!(
                    got[i][0],
                    gdf_filter(img, &chain).to_tensor(),
                    "n={n}: request {i} diverged across the lane-word boundary"
                );
            }
        }
        // and a cut that falls mid-request: 255 single windows then a
        // 2×2 image whose four windows straddle the 256th lane
        let mut imgs: Vec<Image> = (0..255).map(|i| synthetic_photo(1, 1, 97 + i as u64)).collect();
        imgs.push(synthetic_photo(2, 2, 404));
        let batch: Vec<Vec<Tensor>> = imgs.iter().map(|im| vec![im.to_tensor()]).collect();
        let got = hw.exec_batch(&batch).unwrap();
        for (i, img) in imgs.iter().enumerate() {
            assert_eq!(got[i][0], gdf_filter(img, &chain).to_tensor(), "mid-request cut: {i}");
        }
    }

    #[test]
    fn ppc_hardware_cheaper_with_ds() {
        let full = ValueSet::full(8);
        let ds16 = full.map_chain(&Chain::of(Preproc::Ds(16)));
        let base = aggregate(&gdf_ppc_hardware(&full, Objective::Area));
        let ppc = aggregate(&gdf_ppc_hardware(&ds16, Objective::Area));
        assert_eq!(ppc.verify_errors, 0);
        assert!(ppc.literals < base.literals);
        assert!(ppc.area_ge < base.area_ge);
    }
}
