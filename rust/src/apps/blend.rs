//! Image Blending hardware (paper Section V, Fig. 7).
//!
//! `P(i,j) = α·P1(i,j) + (1−α)·P2(i,j)` with an 8-bit α: the α input of
//! Multiplier-1 is restricted to `[0,127]` and the `(1−α)` input of
//! Multiplier-2 to `[128,255]` — the *natural sparsity* rows of Table 2.
//! Each 16-bit product is truncated to its top 8 bits and the two are
//! combined by an 8-bit adder, exactly as Fig. 7 draws it.

use super::image::{pixels_from_i32, Image};
use crate::catalog::{Datapath, Tensor, LANES};
use crate::logic::map::Objective;
use crate::ppc::flow::{self, BlockReport};
use crate::ppc::preprocess::{Chain, ValueSet};
use crate::ppc::units::{combined_backend, AdderUnit, FreshSynth, MultUnit8, NetlistSource};
use crate::util::pool;
use anyhow::{anyhow, bail, Result};

/// Quantized blending ratio: `alpha ∈ [0,127]`, the complementary
/// coefficient is `255 − alpha ∈ [128,255]`.
#[derive(Clone, Copy, Debug)]
pub struct Alpha(pub u8);

impl Alpha {
    pub fn from_ratio(r: f64) -> Alpha {
        Alpha((r.clamp(0.0, 0.5) * 255.0).round() as u8)
    }
    #[inline]
    pub fn coeff1(&self) -> u32 {
        self.0 as u32
    }
    #[inline]
    pub fn coeff2(&self) -> u32 {
        255 - self.0 as u32
    }
}

/// Bit-accurate blend of one pixel pair. `pre_img` preprocesses both
/// image inputs; `pre_coef` both coefficient inputs (the paper's
/// intentional-sparsity configs preprocess *both* multiplier inputs).
#[inline]
pub fn blend_pixel(p1: u8, p2: u8, alpha: Alpha, pre_img: &Chain, pre_coef: &Chain) -> u8 {
    let c1 = pre_coef.apply(alpha.coeff1());
    let c2 = pre_coef.apply(alpha.coeff2());
    let m1 = (pre_img.apply(p1 as u32) * c1) >> 8; // truncate to 8 bits
    let m2 = (pre_img.apply(p2 as u32) * c2) >> 8;
    (m1 + m2).min(255) as u8
}

/// Blend two images of equal size.
pub fn blend_images(p1: &Image, p2: &Image, alpha: Alpha, pre_img: &Chain, pre_coef: &Chain) -> Image {
    assert_eq!(p1.width, p2.width);
    assert_eq!(p1.height, p2.height);
    let pixels = p1
        .pixels
        .iter()
        .zip(&p2.pixels)
        .map(|(&a, &b)| blend_pixel(a, b, alpha, pre_img, pre_coef))
        .collect();
    Image { width: p1.width, height: p1.height, pixels }
}

/// Value sets of the two multipliers' inputs under a configuration.
pub struct BlendSignals {
    /// (image_set, coeff_set) for Multiplier-1 and Multiplier-2.
    pub mult1: (ValueSet, ValueSet),
    pub mult2: (ValueSet, ValueSet),
    /// Adder input value sets (truncated products).
    pub adder: (ValueSet, ValueSet),
}

/// Configuration of a Table-2 row.
#[derive(Clone, Debug)]
pub struct BlendConfig {
    /// Exploit the natural half-range coefficient sparsity?
    pub natural: bool,
    /// Intentional preprocessing on image & coefficient inputs.
    pub pre: Chain,
    pub name: String,
}

impl BlendConfig {
    pub fn conventional() -> BlendConfig {
        BlendConfig { natural: false, pre: Chain::id(), name: "conventional".into() }
    }
    pub fn of(natural: bool, pre: Chain) -> BlendConfig {
        let name = match (natural, pre.0.is_empty()) {
            (false, true) => "conventional".to_string(),
            (true, true) => "natural".to_string(),
            (false, false) => format!("intentional({})", pre.label()),
            (true, false) => format!("natural+intentional({})", pre.label()),
        };
        BlendConfig { natural, pre, name }
    }
}

/// Derive the multiplier/adder input value sets for a config.
pub fn blend_signal_sets(cfg: &BlendConfig) -> BlendSignals {
    let full = ValueSet::full(8);
    let img = full.map_chain(&cfg.pre);
    let (c1_raw, c2_raw) = if cfg.natural {
        (
            ValueSet::from_values(256, 0..=127u32),
            ValueSet::from_values(256, 128..=255u32),
        )
    } else {
        (full.clone(), full.clone())
    };
    let c1 = c1_raw.map_chain(&cfg.pre);
    let c2 = c2_raw.map_chain(&cfg.pre);
    let prod1 = img.product(&c1).shr(8).truncate(8);
    let prod2 = img.product(&c2).shr(8).truncate(8);
    BlendSignals { mult1: (img.clone(), c1), mult2: (img, c2), adder: (prod1, prod2) }
}

/// Netlist-backed IB datapath: the two composed 8×8 PPC multipliers and
/// the output adder of Fig. 7 as synthesized units, executed
/// bit-parallel ([`crate::catalog::LANES`] pixel pairs per
/// compiled-tape pass). Bit-exact with
/// [`blend_pixel`] under the config's preprocessing.
pub struct BlendHardware {
    pub cfg: BlendConfig,
    m1: MultUnit8,
    m2: MultUnit8,
    add: AdderUnit,
}

impl BlendHardware {
    pub fn synthesize(cfg: &BlendConfig, objective: Objective) -> BlendHardware {
        BlendHardware::synthesize_via(cfg, objective, &FreshSynth)
    }

    /// Like [`BlendHardware::synthesize`], with netlists drawn from
    /// `source` (fresh synthesis or the persistent cache).
    pub fn synthesize_via(
        cfg: &BlendConfig,
        objective: Objective,
        source: &dyn NetlistSource,
    ) -> BlendHardware {
        let sig = blend_signal_sets(cfg);
        let m1 = MultUnit8::synthesize_via("ib_mult1", &sig.mult1.0, &sig.mult1.1, objective, source);
        let m2 = MultUnit8::synthesize_via("ib_mult2", &sig.mult2.0, &sig.mult2.1, objective, source);
        let add = AdderUnit::synthesize_via(
            "ib_adder",
            8,
            8,
            &sig.adder.0,
            &sig.adder.1,
            objective,
            source,
        );
        BlendHardware { cfg: cfg.clone(), m1, m2, add }
    }

    /// Total gate count (both multipliers + adder).
    pub fn num_gates(&self) -> usize {
        self.m1.num_gates() + self.m2.num_gates() + self.add.num_gates()
    }

    /// Which unit backend serves batches: `"lut"`, `"tape"`, or
    /// `"mixed"`.
    pub fn backend_name(&self) -> &'static str {
        combined_backend([
            self.m1.backend_name(),
            self.m2.backend_name(),
            self.add.backend_name(),
        ])
    }

    /// Blend up to [`crate::catalog::LANES`] pixel pairs through the
    /// netlists. With a `natural`
    /// config the coefficient restriction means `alpha.0` must be in
    /// `[0, 127]` (the Table-2 natural-sparsity contract). A thin
    /// fixed-capacity wrapper over [`BlendHardware::blend_many`].
    pub fn blend_batch(&self, p1: &[u8], p2: &[u8], alpha: Alpha, out: &mut [u8]) {
        let n = p1.len();
        assert!(n <= crate::catalog::LANES && p2.len() == n && out.len() >= n);
        let pixels = self.blend_many(&[(p1, p2, alpha)]);
        out[..n].copy_from_slice(&pixels[0]);
    }

    /// Blend two flat pixel buffers of equal length (chunks the work
    /// into [`crate::catalog::LANES`]-pixel tape passes).
    pub fn blend_flat(&self, p1: &[u8], p2: &[u8], alpha: Alpha) -> Vec<u8> {
        assert_eq!(p1.len(), p2.len());
        self.blend_many(&[(p1, p2, alpha)])
            .pop()
            .expect("one request in, one pixel buffer out")
    }

    /// Blend a whole batch of requests — each `(p1, p2, alpha)` with
    /// its own blending ratio — through one pooled pixel stream: the
    /// lane-batched serving path. Every 256-lane multiplier pass mixes
    /// pixels (and coefficients) from as many requests as fit, so small
    /// images stop wasting tail lanes per request. The stream is
    /// processed in bounded segments ([`SEG_PIXELS`] pixels) so huge
    /// images cannot balloon shard memory.
    pub fn blend_many(&self, reqs: &[(&[u8], &[u8], Alpha)]) -> Vec<Vec<u8>> {
        let pre = &self.cfg.pre;
        let mut outs: Vec<Vec<u8>> =
            reqs.iter().map(|(p1, _, _)| vec![0u8; p1.len()]).collect();
        let mut i1: Vec<u32> = Vec::new();
        let mut i2: Vec<u32> = Vec::new();
        let mut c1: Vec<u32> = Vec::new();
        let mut c2: Vec<u32> = Vec::new();
        // (request index, pixel index) of every pooled pixel pair
        let mut dest: Vec<(usize, usize)> = Vec::new();
        for (r, (p1, p2, alpha)) in reqs.iter().enumerate() {
            debug_assert_eq!(p1.len(), p2.len());
            debug_assert!(
                !self.cfg.natural || alpha.0 <= 127,
                "natural config needs alpha ≤ 127"
            );
            let (a1, a2) = (pre.apply(alpha.coeff1()), pre.apply(alpha.coeff2()));
            for (j, (&x, &y)) in p1.iter().zip(p2.iter()).enumerate() {
                i1.push(pre.apply(x as u32));
                c1.push(a1);
                i2.push(pre.apply(y as u32));
                c2.push(a2);
                dest.push((r, j));
                if dest.len() >= SEG_PIXELS {
                    self.flush_segment(&i1, &i2, &c1, &c2, &dest, &mut outs);
                    i1.clear();
                    i2.clear();
                    c1.clear();
                    c2.clear();
                    dest.clear();
                }
            }
        }
        self.flush_segment(&i1, &i2, &c1, &c2, &dest, &mut outs);
        outs
    }

    /// Run one pooled segment through both multipliers and the output
    /// adder, scattering results to their `(request, pixel)` slots.
    /// The segment splits into [`LANES`]-aligned chunks across
    /// [`pool::batch_threads`] workers; each worker runs mult → mult →
    /// add serially over its chunk (no nested parallel regions), and
    /// alignment keeps the per-pass lane grouping — and the bits —
    /// identical at any thread count.
    fn flush_segment(
        &self,
        i1: &[u32],
        i2: &[u32],
        c1: &[u32],
        c2: &[u32],
        dest: &[(usize, usize)],
        outs: &mut [Vec<u8>],
    ) {
        if dest.is_empty() {
            return;
        }
        let n = dest.len();
        let run = |s: usize, e: usize| -> Vec<u64> {
            let t1: Vec<u32> = self
                .m1
                .mul_many_threads(&i1[s..e], &c1[s..e], 1)
                .iter()
                .map(|&v| (v >> 8) as u32)
                .collect();
            let t2: Vec<u32> = self
                .m2
                .mul_many_threads(&i2[s..e], &c2[s..e], 1)
                .iter()
                .map(|&v| (v >> 8) as u32)
                .collect();
            self.add.add_many_threads(&t1, &t2, 1)
        };
        let nblocks = n.div_ceil(LANES);
        let threads = pool::batch_threads().min(nblocks.max(1));
        let sum: Vec<u64> = if threads <= 1 {
            run(0, n)
        } else {
            pool::scope_chunks(nblocks, threads, |bs, be| run(bs * LANES, (be * LANES).min(n)))
                .concat()
        };
        for (&(r, j), &s) in dest.iter().zip(&sum) {
            outs[r][j] = s.min(255) as u8;
        }
    }

    /// Blend two whole images through the synthesized datapath.
    pub fn blend_images(&self, p1: &Image, p2: &Image, alpha: Alpha) -> Image {
        assert_eq!(p1.width, p2.width);
        assert_eq!(p1.height, p2.height);
        let pixels = self.blend_flat(&p1.pixels, &p2.pixels, alpha);
        Image { width: p1.width, height: p1.height, pixels }
    }
}

/// Pixel pairs per pooled netlist segment: 64 full 256-lane passes,
/// bounding lane buffers and truncated-product intermediates no matter
/// how large the request images are.
const SEG_PIXELS: usize = 16 * 1024;

/// Validate one `(p1, p2, alpha)` request and decode it to pixel
/// buffers (shared by the scalar and lane-batched `Datapath` paths).
fn decode_request(inputs: &[Tensor]) -> Result<(Vec<u8>, Vec<u8>, Alpha, Vec<usize>)> {
    if inputs.len() != 3 {
        bail!("expected (p1, p2, alpha), got {} tensors", inputs.len());
    }
    let (p1, p2, al) = (&inputs[0], &inputs[1], &inputs[2]);
    if p1.shape != p2.shape {
        bail!("image shapes differ ({:?} vs {:?})", p1.shape, p2.shape);
    }
    // `Tensor` fields are public, so shape and data can disagree; a
    // length mismatch must be a structured error here, not a panic
    // deep inside a pooled multiplier pass
    let elements = p1.elements();
    if p1.data.len() != elements || p2.data.len() != elements {
        bail!(
            "image shape {:?} wants {} pixels, data has {} and {}",
            p1.shape,
            elements,
            p1.data.len(),
            p2.data.len()
        );
    }
    if al.data.len() != 1 || !(0..=127).contains(&al.data[0]) {
        bail!("alpha must be a single value in [0, 127], got {:?}", al.data);
    }
    let a = pixels_from_i32(&p1.data, "p1")?;
    let b = pixels_from_i32(&p2.data, "p2")?;
    Ok((a, b, Alpha(al.data[0] as u8), p1.shape.clone()))
}

impl Datapath for BlendHardware {
    /// `(p1, p2, alpha)` in — the images shape-identical, alpha a
    /// single value in `[0, 127]` (the natural-sparsity contract) —
    /// one blended tensor out, with `p1`'s shape.
    fn exec(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let (a, b, alpha, shape) = decode_request(inputs)?;
        let out = self.blend_flat(&a, &b, alpha);
        Ok(vec![Tensor {
            shape,
            data: out.into_iter().map(|p| p as i32).collect(),
        }])
    }

    /// Lane-batched path: every request's pixels (each with its own
    /// alpha) share the same 256-lane multiplier passes
    /// ([`BlendHardware::blend_many`]). Bit-exact with per-request
    /// [`Datapath::exec`].
    fn exec_batch(&self, batch: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        let mut decoded = Vec::with_capacity(batch.len());
        for (i, inputs) in batch.iter().enumerate() {
            decoded.push(decode_request(inputs).map_err(|e| anyhow!("request {i}: {e:#}"))?);
        }
        let reqs: Vec<(&[u8], &[u8], Alpha)> = decoded
            .iter()
            .map(|(a, b, alpha, _)| (a.as_slice(), b.as_slice(), *alpha))
            .collect();
        let outs = self.blend_many(&reqs);
        Ok(outs
            .into_iter()
            .zip(&decoded)
            .map(|(out, (_, _, _, shape))| {
                vec![Tensor {
                    shape: shape.clone(),
                    data: out.into_iter().map(|p| p as i32).collect(),
                }]
            })
            .collect())
    }

    fn num_gates(&self) -> usize {
        BlendHardware::num_gates(self)
    }

    fn backend_name(&self) -> &'static str {
        BlendHardware::backend_name(self)
    }
}

/// Hardware report of the IB datapath: two composed 8×8 multipliers plus
/// the 8-bit adder (the paper keeps the adder precise — its cost is
/// negligible next to the multipliers; we synthesize it anyway).
pub fn blend_ppc_hardware(cfg: &BlendConfig, objective: Objective) -> Vec<BlockReport> {
    let sig = blend_signal_sets(cfg);
    let m1 = flow::composed_mult8("ib_mult1", &sig.mult1.0, &sig.mult1.1, objective);
    let m2 = flow::composed_mult8("ib_mult2", &sig.mult2.0, &sig.mult2.1, objective);
    let add = flow::segmented_adder("ib_adder", 8, 8, &sig.adder.0, &sig.adder.1, objective);
    vec![m1, m2, add]
}

/// Conventional IB hardware: two array multipliers + ripple adder.
pub fn blend_conventional_hardware(objective: Objective) -> Vec<BlockReport> {
    vec![
        flow::conventional_mult("ib_mult1", 8, 8, objective),
        flow::conventional_mult("ib_mult2", 8, 8, objective),
        flow::conventional_adder("ib_adder", 8, 8, objective),
    ]
}

/// Flat two-level literal count of the whole IB datapath (the paper's
/// "# of literals" column uses the flat multiplier TTs).
pub fn blend_flat_literals(cfg: &BlendConfig) -> u64 {
    let sig = blend_signal_sets(cfg);
    let m1 = flow::flat_mult_literals(&sig.mult1.0, &sig.mult1.1);
    let m2 = flow::flat_mult_literals(&sig.mult2.0, &sig.mult2.1);
    let add = flow::segmented_adder_literals(8, 8, &sig.adder.0, &sig.adder.1);
    m1 + m2 + add
}

/// Aggregate component reports into one row.
pub fn aggregate(reports: &[BlockReport]) -> BlockReport {
    let mut out = BlockReport { name: "ib_total".into(), ..Default::default() };
    for r in reports {
        out.literals += r.literals;
        out.area_ge += r.area_ge;
        out.power_uw += r.power_uw;
        out.verify_errors += r.verify_errors;
    }
    // critical path: slower multiplier, then the adder
    let mul_delay = reports[0].delay_ns.max(reports[1].delay_ns);
    out.delay_ns = mul_delay + reports[2].delay_ns;
    out.dc_fraction = reports.iter().map(|r| r.dc_fraction).sum::<f64>() / reports.len() as f64;
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::image::synthetic_photo;
    use crate::ppc::preprocess::Preproc;

    #[test]
    fn alpha_half_blend_averages() {
        let a = Alpha::from_ratio(0.5);
        assert_eq!(a.coeff1() + a.coeff2(), 255);
        // blending identical images ~ identity (up to truncation)
        let v = blend_pixel(200, 200, a, &Chain::id(), &Chain::id());
        assert!((v as i32 - 199).abs() <= 1, "v={v}");
    }

    #[test]
    fn blend_between_sources() {
        let a = Alpha::from_ratio(0.5);
        let v = blend_pixel(0, 200, a, &Chain::id(), &Chain::id());
        assert!((90..=110).contains(&v), "v={v}");
    }

    #[test]
    fn natural_sparsity_halves_coeff_sets() {
        let cfg = BlendConfig::of(true, Chain::id());
        let sig = blend_signal_sets(&cfg);
        assert!((sig.mult1.1.sparsity() - 0.5).abs() < 0.01);
        assert!((sig.mult2.1.sparsity() - 0.5).abs() < 0.01);
        // natural sparsity leaves pixels bit-identical
        let p1 = synthetic_photo(32, 32, 1);
        let p2 = synthetic_photo(32, 32, 2);
        let alpha = Alpha::from_ratio(0.5);
        let base = blend_images(&p1, &p2, alpha, &Chain::id(), &Chain::id());
        // "natural" config has no preprocessing → identical output
        let nat = blend_images(&p1, &p2, alpha, &cfg.pre, &cfg.pre);
        assert_eq!(base, nat);
    }

    #[test]
    fn ds_degrades_psnr_monotonically() {
        let p1 = synthetic_photo(48, 48, 3);
        let p2 = synthetic_photo(48, 48, 4);
        let alpha = Alpha::from_ratio(0.5);
        let base = blend_images(&p1, &p2, alpha, &Chain::id(), &Chain::id());
        let mut prev = f64::INFINITY;
        for x in [2u32, 8, 32] {
            let c = Chain::of(Preproc::Ds(x));
            let out = blend_images(&p1, &p2, alpha, &c, &c);
            let p = base.psnr(&out);
            assert!(p < prev, "x={x}: {p} !< {prev}");
            prev = p;
        }
    }

    #[test]
    fn netlist_hardware_matches_bit_accurate_blend() {
        let cfg = BlendConfig::of(true, Chain::of(Preproc::Ds(16)));
        let hw = BlendHardware::synthesize(&cfg, Objective::Area);
        assert!(hw.num_gates() > 0);
        let p1 = synthetic_photo(32, 32, 7);
        let p2 = synthetic_photo(32, 32, 8);
        for alpha in [Alpha(0), Alpha(64), Alpha(127)] {
            let sw = blend_images(&p1, &p2, alpha, &cfg.pre, &cfg.pre);
            assert_eq!(hw.blend_images(&p1, &p2, alpha), sw, "alpha={}", alpha.0);
        }
    }

    #[test]
    fn lane_batched_blend_pools_requests_with_distinct_alphas() {
        let cfg = BlendConfig::of(true, Chain::of(Preproc::Ds(32)));
        let hw = BlendHardware::synthesize(&cfg, Objective::Area);
        let a = synthetic_photo(9, 5, 11);
        let b = synthetic_photo(9, 5, 12);
        let c = synthetic_photo(4, 7, 13);
        let d = synthetic_photo(4, 7, 14);
        // pooled batch, each request with its own alpha
        let outs = hw.blend_many(&[
            (&a.pixels, &b.pixels, Alpha(16)),
            (&c.pixels, &d.pixels, Alpha(100)),
        ]);
        assert_eq!(outs[0], hw.blend_images(&a, &b, Alpha(16)).pixels);
        assert_eq!(outs[1], hw.blend_images(&c, &d, Alpha(100)).pixels);
        // Datapath batch interface agrees with per-request exec
        let req = |p: &crate::apps::image::Image, q: &crate::apps::image::Image, al: i32| {
            vec![p.to_tensor(), q.to_tensor(), Tensor::scalar(al)]
        };
        let batch = vec![req(&a, &b, 16), req(&c, &d, 100)];
        let got = hw.exec_batch(&batch).unwrap();
        for (i, inputs) in batch.iter().enumerate() {
            assert_eq!(got[i], hw.exec(inputs).unwrap(), "request {i}");
        }
        // a bad alpha fails the batch with its request index
        let bad = vec![req(&a, &b, 16), req(&c, &d, 200)];
        let e = hw.exec_batch(&bad).unwrap_err();
        assert!(format!("{e:#}").contains("request 1"), "{e:#}");
        // shape/data disagreement (Tensor fields are public) is a
        // structured error, never a panic inside a pooled pass
        let broken = vec![
            Tensor { shape: vec![2, 2], data: vec![1, 2, 3, 4] },
            Tensor { shape: vec![2, 2], data: vec![1, 2, 3] },
            Tensor::scalar(10),
        ];
        let e = hw.exec(&broken).unwrap_err();
        assert!(format!("{e:#}").contains("wants 4 pixels"), "{e:#}");
    }

    #[test]
    fn product_truncation_sets_bounded() {
        let cfg = BlendConfig::of(true, Chain::of(Preproc::Ds(16)));
        let sig = blend_signal_sets(&cfg);
        assert!(sig.adder.0.capacity() <= 256);
        assert!(sig.adder.0.len() < 256, "truncated product set should be sparse-ish");
    }
}
