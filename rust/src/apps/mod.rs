//! The paper's three embedded applications, bit-accurate.

pub mod blend;
pub mod frnn;
pub mod gdf;
pub mod image;
pub mod quality;
