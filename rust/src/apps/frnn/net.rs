//! The 960-40-7 face-recognition network (paper Section VI, Figs. 9–10):
//! a float trainer (reference implementation of the paper's training
//! runs) and the bit-accurate fixed-point forward path built from the
//! MAC structure of Fig. 10 (8×8 multiplier + wide accumulator +
//! sigmoid transfer).
//!
//! Preprocessing enters in two places, exactly as in the paper:
//! the image input of every first-layer MAC multiplier (`TH`/`DS` on
//! pixels) and the weight input (`DS` on the quantized weight bytes).

use super::dataset::{Dataset, Face, IMG_PIXELS, NUM_OUTPUTS};
use crate::ppc::preprocess::Chain;
use crate::util::prng::Rng;

pub const HIDDEN: usize = 40;

/// Float network parameters.
#[derive(Clone, Debug)]
pub struct Frnn {
    /// `w1[j][i]`: hidden j ← input i. Row-major contiguous for speed.
    pub w1: Vec<f32>, // HIDDEN × IMG_PIXELS
    pub b1: Vec<f32>, // HIDDEN
    pub w2: Vec<f32>, // NUM_OUTPUTS × HIDDEN
    pub b2: Vec<f32>, // NUM_OUTPUTS
}

#[inline]
fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Training configuration.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    pub lr: f32,
    pub momentum: f32,
    pub max_epochs: usize,
    /// Stop when train MSE falls below this (the paper's TE measures
    /// epochs-to-convergence).
    pub target_mse: f64,
    pub seed: u64,
    /// Preprocessing applied to pixels before normalization.
    pub pre_image: Chain,
    /// Preprocessing applied to quantized weight bytes in the forward
    /// pass (straight-through in backprop).
    pub pre_weight: Chain,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            lr: 0.08,
            momentum: 0.8,
            max_epochs: 400,
            target_mse: 0.015,
            seed: 42,
            pre_image: Chain::id(),
            pre_weight: Chain::id(),
        }
    }
}

/// Training outcome: the paper's simulation metrics.
#[derive(Clone, Debug)]
pub struct TrainResult {
    pub net: Frnn,
    /// Epochs until `target_mse` (or `max_epochs` if never reached) —
    /// the paper's "TE" column.
    pub epochs: usize,
    /// Final training MSE — the paper's "MSE" column.
    pub mse: f64,
    /// Per-epoch MSE curve (for EXPERIMENTS.md loss logging).
    pub curve: Vec<f64>,
}

/// Normalized, preprocessed input vector for one face.
pub fn input_vector(face: &Face, pre: &Chain) -> Vec<f32> {
    face.pixels
        .iter()
        .map(|&p| pre.apply(p as u32) as f32 / 255.0)
        .collect()
}

/// Deterministic round-half-away-from-zero in f64 — shared convention
/// with the python layer so quantization is bit-identical across the
/// language boundary.
#[inline]
pub fn round_half_away(x: f64) -> f64 {
    x.signum() * (x.abs() + 0.5).floor()
}

/// Per-layer quantization scale: weights span the full signed byte
/// range (the paper\'s Fig. 10 weight histogram "covers the entire
/// range"). Computed in f64 for cross-language determinism.
pub fn layer_scale(w: &[f32]) -> f64 {
    let max_abs = w.iter().fold(0.0f64, |m, &x| m.max((x as f64).abs()));
    if max_abs <= 0.0 {
        64.0
    } else {
        127.0 / max_abs
    }
}

/// Quantize one weight with scale `s`.
#[inline]
pub fn quantize_weight(w: f32, s: f64) -> i32 {
    (round_half_away(w as f64 * s) as i32).clamp(-128, 127)
}

/// Apply the weight preprocessing in quantized space: quantize to a
/// signed byte (per-layer scale `s`), preprocess the *byte pattern*,
/// dequantize. With `Chain::id` this is a no-op in the float path (no
/// quantization loss is introduced during training).
fn preprocess_weight(w: f32, pre: &Chain, s: f64) -> f32 {
    if pre.0.is_empty() {
        return w;
    }
    let q = quantize_weight(w, s);
    let byte = (q & 0xff) as u32;
    let pq = pre.apply(byte) & 0xff;
    let signed = if pq >= 128 { pq as i32 - 256 } else { pq as i32 };
    (signed as f64 / s) as f32
}

impl Frnn {
    pub fn random(seed: u64) -> Frnn {
        let mut rng = Rng::new(seed);
        let mut init = |n: usize, fan_in: usize| -> Vec<f32> {
            let scale = (1.0 / fan_in as f64).sqrt();
            (0..n).map(|_| (rng.next_gaussian() * scale) as f32).collect()
        };
        Frnn {
            w1: init(HIDDEN * IMG_PIXELS, IMG_PIXELS),
            b1: vec![0.0; HIDDEN],
            w2: init(NUM_OUTPUTS * HIDDEN, HIDDEN),
            b2: vec![0.0; NUM_OUTPUTS],
        }
    }

    /// Float forward; returns (hidden, output) activations.
    pub fn forward(&self, x: &[f32], pre_w: &Chain) -> (Vec<f32>, Vec<f32>) {
        let (s1, s2) = if pre_w.0.is_empty() {
            (64.0, 64.0)
        } else {
            (layer_scale(&self.w1), layer_scale(&self.w2))
        };
        self.forward_scaled(x, pre_w, s1, s2)
    }

    /// Forward with explicit per-layer quantization scales (training
    /// precomputes them once per epoch).
    pub fn forward_scaled(
        &self,
        x: &[f32],
        pre_w: &Chain,
        s1: f64,
        s2: f64,
    ) -> (Vec<f32>, Vec<f32>) {
        let mut h = vec![0.0f32; HIDDEN];
        for j in 0..HIDDEN {
            let row = &self.w1[j * IMG_PIXELS..(j + 1) * IMG_PIXELS];
            let mut acc = self.b1[j];
            if pre_w.0.is_empty() {
                for (w, xi) in row.iter().zip(x) {
                    acc += w * xi;
                }
            } else {
                for (w, xi) in row.iter().zip(x) {
                    acc += preprocess_weight(*w, pre_w, s1) * xi;
                }
            }
            h[j] = sigmoid(acc);
        }
        let mut o = vec![0.0f32; NUM_OUTPUTS];
        for k in 0..NUM_OUTPUTS {
            let row = &self.w2[k * HIDDEN..(k + 1) * HIDDEN];
            let mut acc = self.b2[k];
            if pre_w.0.is_empty() {
                for (w, hj) in row.iter().zip(&h) {
                    acc += w * hj;
                }
            } else {
                for (w, hj) in row.iter().zip(&h) {
                    acc += preprocess_weight(*w, pre_w, s2) * hj;
                }
            }
            o[k] = sigmoid(acc);
        }
        (h, o)
    }
}

/// Train with plain SGD + momentum on MSE loss (targets 0.1/0.9, the
/// classic face-recognition setup the paper's reference [22] uses).
pub fn train(ds: &Dataset, cfg: &TrainConfig) -> TrainResult {
    let mut net = Frnn::random(cfg.seed);
    let inputs: Vec<Vec<f32>> = ds.train.iter().map(|f| input_vector(f, &cfg.pre_image)).collect();
    let targets: Vec<[f32; NUM_OUTPUTS]> = ds
        .train
        .iter()
        .map(|f| {
            let t = f.targets();
            let mut a = [0.1f32; NUM_OUTPUTS];
            for k in 0..NUM_OUTPUTS {
                if t[k] {
                    a[k] = 0.9;
                }
            }
            a
        })
        .collect();
    let mut vw1 = vec![0.0f32; net.w1.len()];
    let mut vb1 = vec![0.0f32; net.b1.len()];
    let mut vw2 = vec![0.0f32; net.w2.len()];
    let mut vb2 = vec![0.0f32; net.b2.len()];
    let mut order: Vec<usize> = (0..inputs.len()).collect();
    let mut rng = Rng::new(cfg.seed ^ 0x5EED);
    let mut curve = Vec::with_capacity(cfg.max_epochs);
    let mut epochs_to_target = cfg.max_epochs;

    // Two-phase schedule for weight preprocessing: early float weights
    // are tiny (|w·64| < x), so DS_x would zero the whole network and no
    // gradient signal survives. Warm up without the weight preprocessing,
    // then fine-tune with it (quantization-aware training with a
    // straight-through estimator). The paper's larger TE for DS configs
    // reflects the same extended convergence.
    let warmup = if cfg.pre_weight.0.is_empty() {
        0
    } else {
        (cfg.max_epochs / 2).max(1)
    };

    for epoch in 0..cfg.max_epochs {
        let fine_tune = epoch >= warmup && !cfg.pre_weight.0.is_empty();
        let wpre = if fine_tune { cfg.pre_weight.clone() } else { Chain::id() };
        // Quantization-aware fine-tuning uses a reduced step: the STE
        // gradient is noisy under coarse weight grids (DS16/DS32) and
        // the full step oscillates when combined with TH'd inputs.
        let lr = if fine_tune { cfg.lr * 0.25 } else { cfg.lr };
        // per-epoch quantization scales (weights move slowly)
        let (s1, s2) = if wpre.0.is_empty() {
            (64.0, 64.0)
        } else {
            (layer_scale(&net.w1), layer_scale(&net.w2))
        };
        rng.shuffle(&mut order);
        let mut sq_err = 0.0f64;
        for &idx in &order {
            let x = &inputs[idx];
            let t = &targets[idx];
            let (h, o) = net.forward_scaled(x, &wpre, s1, s2);
            // output deltas
            let mut delta_o = [0.0f32; NUM_OUTPUTS];
            for k in 0..NUM_OUTPUTS {
                let err = o[k] - t[k];
                sq_err += (err * err) as f64;
                delta_o[k] = err * o[k] * (1.0 - o[k]);
            }
            // hidden deltas
            let mut delta_h = vec![0.0f32; HIDDEN];
            for j in 0..HIDDEN {
                let mut s = 0.0f32;
                for k in 0..NUM_OUTPUTS {
                    s += delta_o[k] * net.w2[k * HIDDEN + j];
                }
                delta_h[j] = s * h[j] * (1.0 - h[j]);
            }
            // update layer 2
            for k in 0..NUM_OUTPUTS {
                let row = &mut net.w2[k * HIDDEN..(k + 1) * HIDDEN];
                let vrow = &mut vw2[k * HIDDEN..(k + 1) * HIDDEN];
                for j in 0..HIDDEN {
                    let g = delta_o[k] * h[j];
                    vrow[j] = cfg.momentum * vrow[j] - lr * g;
                    row[j] += vrow[j];
                }
                vb2[k] = cfg.momentum * vb2[k] - lr * delta_o[k];
                net.b2[k] += vb2[k];
            }
            // update layer 1
            for j in 0..HIDDEN {
                let d = delta_h[j];
                if d == 0.0 {
                    continue;
                }
                let row = &mut net.w1[j * IMG_PIXELS..(j + 1) * IMG_PIXELS];
                let vrow = &mut vw1[j * IMG_PIXELS..(j + 1) * IMG_PIXELS];
                for i in 0..IMG_PIXELS {
                    vrow[i] = cfg.momentum * vrow[i] - lr * d * x[i];
                    row[i] += vrow[i];
                }
                vb1[j] = cfg.momentum * vb1[j] - lr * d;
                net.b1[j] += vb1[j];
            }
        }
        let mse = sq_err / (inputs.len() * NUM_OUTPUTS) as f64;
        curve.push(mse);
        if mse < cfg.target_mse && epoch >= warmup {
            epochs_to_target = epoch + 1;
            break;
        }
    }
    let mse = *curve.last().unwrap_or(&1.0);
    TrainResult { net, epochs: epochs_to_target, mse, curve }
}

// ---------------------------------------------------------------------
// Fixed-point (hardware) forward — the Fig. 10 MAC
// ---------------------------------------------------------------------

/// Quantized network: weights as signed bytes with *per-layer dynamic
/// scales* (so the byte histogram spans the full range, as in the
/// paper\'s Fig. 10), biases in accumulator scale.
#[derive(Clone, Debug)]
pub struct QuantFrnn {
    pub w1: Vec<i8>,
    pub b1: Vec<i32>,
    pub w2: Vec<i8>,
    pub b2: Vec<i32>,
    /// Accumulator divisors per layer (sigmoid LUT stride):
    /// `idx = clamp(trunc(acc / d), -128, 127) + 128`.
    pub d1: i64,
    pub d2: i64,
    /// 256-entry sigmoid LUT shared by both layers.
    pub sigmoid_lut: Vec<u8>,
}

/// Activation scale: activations are u8 in [0, 255] ≈ [0, 1].
pub const A_SCALE: f32 = 255.0;
/// LUT resolution: index step corresponds to Δz = 16/255.
pub const LUT_Z_STEP: f64 = 16.0 / 255.0;

/// The shared sigmoid LUT (also reproduced by python kernels/ref.py).
pub fn sigmoid_lut() -> Vec<u8> {
    (0..256)
        .map(|i| {
            let idx_signed = i as i32 - 128;
            let z = (idx_signed as f64 * LUT_Z_STEP) as f32;
            (sigmoid(z) * 255.0).round() as u8
        })
        .collect()
}

/// Accumulator divisor for a layer scale: acc = S·255·z and one LUT
/// index step is Δz = 16/255 → d = S·16.
pub fn lut_divisor(s: f64) -> i64 {
    round_half_away(s * 16.0).max(1.0) as i64
}

pub fn quantize(net: &Frnn) -> QuantFrnn {
    let s1 = layer_scale(&net.w1);
    let s2 = layer_scale(&net.w2);
    let q = |s: f64| move |w: &f32| quantize_weight(*w, s) as i8;
    // bias in accumulator units: acc = Σ w_q · a_q ≈ S·255·(w·a)
    let qb = |s: f64| move |b: &f32| round_half_away(*b as f64 * s * A_SCALE as f64) as i32;
    QuantFrnn {
        w1: net.w1.iter().map(q(s1)).collect(),
        b1: net.b1.iter().map(qb(s1)).collect(),
        w2: net.w2.iter().map(q(s2)).collect(),
        b2: net.b2.iter().map(qb(s2)).collect(),
        d1: lut_divisor(s1),
        d2: lut_divisor(s2),
        sigmoid_lut: sigmoid_lut(),
    }
}

/// The Fig. 10 MAC: accumulate `pixel × weight` products into a wide
/// accumulator. The multiplier sees the *preprocessed* operands — the
/// image input through `pre_img`, the weight byte through `pre_w`.
#[inline]
pub fn mac(acc: i64, pixel: u8, weight: i8, pre_img: &Chain, pre_w: &Chain) -> i64 {
    let px = pre_img.apply(pixel as u32) as i64;
    let wb = (weight as u8) as u32; // two's-complement byte pattern
    let wq = pre_w.apply(wb) & 0xff;
    let ws = if wq >= 128 { wq as i64 - 256 } else { wq as i64 };
    acc + px * ws
}

/// Fixed-point sigmoid via the LUT (accumulator → u8 activation).
/// `d` is the layer\'s accumulator divisor; division truncates toward
/// zero (the python kernels mirror this exactly).
#[inline]
pub fn sigmoid_fx(lut: &[u8], acc: i64, d: i64) -> u8 {
    let idx = (acc / d).clamp(-128, 127) + 128;
    lut[idx as usize]
}

/// Bit-accurate forward pass; returns the 7 thresholded output bits and
/// the raw u8 outputs.
pub fn forward_fx(
    q: &QuantFrnn,
    face: &Face,
    pre_img: &Chain,
    pre_w: &Chain,
) -> ([bool; NUM_OUTPUTS], [u8; NUM_OUTPUTS]) {
    let mut h = [0u8; HIDDEN];
    for j in 0..HIDDEN {
        let mut acc = q.b1[j] as i64;
        let row = &q.w1[j * IMG_PIXELS..(j + 1) * IMG_PIXELS];
        for i in 0..IMG_PIXELS {
            acc = mac(acc, face.pixels[i], row[i], pre_img, pre_w);
        }
        h[j] = sigmoid_fx(&q.sigmoid_lut, acc, q.d1);
    }
    let mut outs = [0u8; NUM_OUTPUTS];
    let mut bits = [false; NUM_OUTPUTS];
    for k in 0..NUM_OUTPUTS {
        let mut acc = q.b2[k] as i64;
        let row = &q.w2[k * HIDDEN..(k + 1) * HIDDEN];
        for j in 0..HIDDEN {
            acc = mac(acc, h[j], row[j], &Chain::id(), pre_w);
        }
        outs[k] = sigmoid_fx(&q.sigmoid_lut, acc, q.d2);
        bits[k] = outs[k] >= 128;
    }
    (bits, outs)
}

/// Evaluation metrics on a test split.
#[derive(Clone, Copy, Debug, Default)]
pub struct EvalResult {
    /// Correct classification rate: all 7 outputs right.
    pub ccr: f64,
    /// Mean squared error of the u8 outputs vs 0.1/0.9 targets.
    pub mse: f64,
}

pub fn evaluate_fx(q: &QuantFrnn, faces: &[Face], pre_img: &Chain, pre_w: &Chain) -> EvalResult {
    let mut correct = 0usize;
    let mut sq = 0.0f64;
    for f in faces {
        let (bits, outs) = forward_fx(q, f, pre_img, pre_w);
        let t = f.targets();
        if bits == t {
            correct += 1;
        }
        for k in 0..NUM_OUTPUTS {
            let target = if t[k] { 0.9 } else { 0.1 };
            let got = outs[k] as f64 / 255.0;
            sq += (got - target) * (got - target);
        }
    }
    EvalResult {
        ccr: correct as f64 / faces.len() as f64,
        mse: sq / (faces.len() * NUM_OUTPUTS) as f64,
    }
}

/// Float-path evaluation (used to sanity-check quantization).
pub fn evaluate_float(net: &Frnn, faces: &[Face], pre_img: &Chain, pre_w: &Chain) -> EvalResult {
    let mut correct = 0usize;
    let mut sq = 0.0f64;
    for f in faces {
        let x = input_vector(f, pre_img);
        let (_, o) = net.forward(&x, pre_w);
        let t = f.targets();
        let ok = (0..NUM_OUTPUTS).all(|k| (o[k] >= 0.5) == t[k]);
        if ok {
            correct += 1;
        }
        for k in 0..NUM_OUTPUTS {
            let target = if t[k] { 0.9 } else { 0.1 };
            sq += (o[k] as f64 - target) * (o[k] as f64 - target);
        }
    }
    EvalResult {
        ccr: correct as f64 / faces.len() as f64,
        mse: sq / (faces.len() * NUM_OUTPUTS) as f64,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::frnn::dataset;
    use crate::ppc::preprocess::Preproc;

    fn tiny_dataset() -> Dataset {
        dataset::generate(3, 99)
    }

    #[test]
    fn training_reduces_mse() {
        let ds = tiny_dataset();
        let cfg = TrainConfig { max_epochs: 12, ..Default::default() };
        let r = train(&ds, &cfg);
        assert!(r.curve.len() >= 2);
        assert!(
            r.curve.last().unwrap() < &r.curve[0],
            "MSE should fall: {:?}",
            (r.curve.first(), r.curve.last())
        );
    }

    #[test]
    fn trained_net_beats_chance_on_test() {
        let ds = tiny_dataset();
        let cfg = TrainConfig { max_epochs: 60, ..Default::default() };
        let r = train(&ds, &cfg);
        let ev = evaluate_float(&r.net, &ds.test, &Chain::id(), &Chain::id());
        // chance level for 7 independent bits ≈ 0.8%; require real learning
        assert!(ev.ccr > 0.5, "float CCR too low: {}", ev.ccr);
    }

    #[test]
    fn quantized_forward_tracks_float() {
        let ds = tiny_dataset();
        let cfg = TrainConfig { max_epochs: 60, ..Default::default() };
        let r = train(&ds, &cfg);
        let q = quantize(&r.net);
        let evf = evaluate_float(&r.net, &ds.test, &Chain::id(), &Chain::id());
        let evq = evaluate_fx(&q, &ds.test, &Chain::id(), &Chain::id());
        assert!(
            (evf.ccr - evq.ccr).abs() < 0.25,
            "quantization gap too large: float {} vs fx {}",
            evf.ccr,
            evq.ccr
        );
    }

    #[test]
    fn mac_matches_arithmetic() {
        let id = Chain::id();
        assert_eq!(mac(0, 100, 50, &id, &id), 5000);
        assert_eq!(mac(10, 100, -50, &id, &id), 10 - 5000);
        // DS on the weight byte acts on the two's-complement pattern
        let dsw = Chain::of(Preproc::Ds(16));
        // -50 = 0xCE = 206; DS16 -> 192 = -64
        assert_eq!(mac(0, 1, -50, &id, &dsw), -64);
    }

    #[test]
    fn preprocessing_degrades_not_destroys() {
        let ds = tiny_dataset();
        let cfg = TrainConfig {
            max_epochs: 60,
            pre_image: Chain::of(Preproc::Th { x: 48, y: 48 }),
            ..Default::default()
        };
        let r = train(&ds, &cfg);
        let q = quantize(&r.net);
        let ev = evaluate_fx(
            &q,
            &ds.test,
            &Chain::of(Preproc::Th { x: 48, y: 48 }),
            &Chain::id(),
        );
        assert!(ev.ccr > 0.4, "TH48 CCR collapsed: {}", ev.ccr);
    }
}
