//! FRNN neuron (MAC) hardware reports — the implementation-results
//! columns of Table 3.
//!
//! A neuron (Fig. 10) = one 8×8 multiplier + one wide accumulator adder.
//! The paper synthesizes the *multiplier* as a PPC block (natural
//! sparsity: pixels never in [160,255]; intentional: TH/DS on the image
//! input and DS on the weight input) while keeping the adder precise; we
//! do the same.

use crate::apps::frnn::dataset::{Face, MAX_PIXEL, IMG_PIXELS, NUM_OUTPUTS};
use crate::apps::frnn::net::{sigmoid_fx, QuantFrnn, HIDDEN};
use crate::apps::image::pixels_from_i32;
use crate::catalog::{Datapath, Tensor};
use crate::logic::map::Objective;
use crate::ppc::flow::{self, BlockReport};
use crate::ppc::preprocess::{Chain, ValueSet};
use crate::ppc::units::{combined_backend, FreshSynth, MultUnit8, NetlistSource};
use crate::util::pool;
use anyhow::{anyhow, bail, Result};

/// A Table-3 row configuration for the MAC hardware.
#[derive(Clone, Debug)]
pub struct MacConfig {
    /// Exploit natural pixel sparsity (no pixel ≥ 160)?
    pub natural: bool,
    /// Intentional preprocessing on the image input.
    pub pre_image: Chain,
    /// Intentional preprocessing on the weight input (byte pattern).
    pub pre_weight: Chain,
    pub name: String,
}

impl MacConfig {
    pub fn conventional() -> MacConfig {
        MacConfig {
            natural: false,
            pre_image: Chain::id(),
            pre_weight: Chain::id(),
            name: "conventional".into(),
        }
    }
}

/// Image-input value set under a config.
pub fn image_value_set(cfg: &MacConfig) -> ValueSet {
    let base = if cfg.natural {
        ValueSet::from_values(256, 0..MAX_PIXEL as u32)
    } else {
        ValueSet::full(8)
    };
    base.map_chain(&cfg.pre_image)
}

/// Weight-input value set (weights cover the full byte range — the
/// paper's Fig. 10 weight histogram spans the entire range).
pub fn weight_value_set(cfg: &MacConfig) -> ValueSet {
    ValueSet::full(8).map_chain(&cfg.pre_weight)
}

/// Hardware report of a single neuron MAC: PPC multiplier (composed
/// 8×8) + precise accumulator adder (16-bit product + 23-bit feedback).
pub fn mac_hardware(cfg: &MacConfig, objective: Objective) -> (BlockReport, BlockReport) {
    let img = image_value_set(cfg);
    let wgt = weight_value_set(cfg);
    let mult = flow::composed_mult8(&format!("mac_mult[{}]", cfg.name), &img, &wgt, objective);
    let adder = flow::conventional_adder("mac_acc_adder", 16, 23, objective);
    (mult, adder)
}

/// Aggregate into the table row (single-neuron implementation results).
pub fn aggregate(mult: &BlockReport, adder: &BlockReport) -> BlockReport {
    BlockReport {
        name: mult.name.clone(),
        literals: mult.literals, // adder kept precise; flat-literal column
        area_ge: mult.area_ge + adder.area_ge,
        delay_ns: mult.delay_ns + adder.delay_ns,
        power_uw: mult.power_uw + adder.power_uw,
        dc_fraction: mult.dc_fraction,
        verify_errors: mult.verify_errors + adder.verify_errors,
    }
}

/// Flat two-level literal count of the MAC multiplier (the paper's
/// "# of literals" for Table 3).
pub fn mac_flat_literals(cfg: &MacConfig) -> u64 {
    flow::flat_mult_literals(&image_value_set(cfg), &weight_value_set(cfg))
}

// ---------------------------------------------------------------------
// Netlist-backed forward path
// ---------------------------------------------------------------------

/// Netlist-backed FRNN forward path: each layer's MAC multiplier is a
/// synthesized composed 8×8 PPC [`MultUnit8`] (layer 1 sees preprocessed
/// pixels, layer 2 the full-range u8 activations; both see preprocessed
/// weight bytes), executed bit-parallel [`crate::catalog::LANES`] MACs
/// per compiled-tape pass. The wide
/// accumulator stays precise — software `i64`, as the paper keeps the
/// accumulation adder conventional. Bit-exact with
/// [`super::net::forward_fx`].
pub struct FrnnHardware {
    pub q: QuantFrnn,
    pub pre_image: Chain,
    pub pre_weight: Chain,
    mult1: MultUnit8,
    mult2: MultUnit8,
    /// Preprocessed two's-complement weight byte patterns per layer
    /// (weights are static, so the preprocessing is baked once).
    w1p: Vec<u32>,
    w2p: Vec<u32>,
}

impl FrnnHardware {
    /// Synthesize both layer multipliers for the full serving input
    /// range (no natural-sparsity assumption — any u8 pixel is in care).
    pub fn synthesize(
        q: QuantFrnn,
        pre_image: &Chain,
        pre_weight: &Chain,
        objective: Objective,
    ) -> FrnnHardware {
        FrnnHardware::synthesize_via(q, pre_image, pre_weight, objective, &FreshSynth)
    }

    /// Like [`FrnnHardware::synthesize`], with netlists drawn from
    /// `source` (fresh synthesis or the persistent cache).
    pub fn synthesize_via(
        q: QuantFrnn,
        pre_image: &Chain,
        pre_weight: &Chain,
        objective: Objective,
        source: &dyn NetlistSource,
    ) -> FrnnHardware {
        let img = ValueSet::full(8).map_chain(pre_image);
        let act = ValueSet::full(8);
        let wgt = ValueSet::full(8).map_chain(pre_weight);
        let mult1 = MultUnit8::synthesize_via("frnn_mac1", &img, &wgt, objective, source);
        let mult2 = MultUnit8::synthesize_via("frnn_mac2", &act, &wgt, objective, source);
        let pw = |w: &i8| pre_weight.apply((*w as u8) as u32) & 0xff;
        let w1p = q.w1.iter().map(pw).collect();
        let w2p = q.w2.iter().map(pw).collect();
        FrnnHardware {
            q,
            pre_image: pre_image.clone(),
            pre_weight: pre_weight.clone(),
            mult1,
            mult2,
            w1p,
            w2p,
        }
    }

    /// Total gate count of both multipliers.
    pub fn num_gates(&self) -> usize {
        self.mult1.num_gates() + self.mult2.num_gates()
    }

    /// Execution backend combined across both layer multipliers
    /// (`"lut"`, `"tape"`, or `"mixed"`).
    pub fn backend_name(&self) -> &'static str {
        combined_backend([self.mult1.backend_name(), self.mult2.backend_name()])
    }

    /// `Σ x_i · signed(w_i)` with the product netlists: the unit
    /// multiplies unsigned byte patterns; a weight byte ≥ 128 represents
    /// `w − 256`, so the accumulator subtracts `x·256` (free wiring in
    /// hardware, exactly the two's-complement convention of
    /// [`super::net::mac`]).
    fn dot(&self, mult: &MultUnit8, xs: &[u32], ws: &[u32]) -> i64 {
        debug_assert_eq!(xs.len(), ws.len());
        let mut acc = 0i64;
        let mut out = [0u64; crate::catalog::LANES];
        let mut i = 0;
        while i < xs.len() {
            let end = (i + crate::catalog::LANES).min(xs.len());
            mult.eval_batch(&xs[i..end], &ws[i..end], &mut out);
            for (j, &u) in out[..end - i].iter().enumerate() {
                let (x, w) = (xs[i + j] as i64, ws[i + j]);
                acc += if w >= 128 { u as i64 - (x << 8) } else { u as i64 };
            }
            i = end;
        }
        acc
    }

    /// Forward many faces through the synthesized multipliers in one
    /// pooled pass — the lane-batched serving path. Layer 1 already
    /// fills the multiplier lanes per face (960-pixel dots), but
    /// layer 2's 40-element dots leave most of every pass idle when run
    /// per face; here the hidden activations of *all* faces share the
    /// layer-2 multiplier lanes. Bit-exact with per-face
    /// [`FrnnHardware::forward`].
    pub fn forward_many(&self, rows: &[&[u8]]) -> Vec<[u8; NUM_OUTPUTS]> {
        // layer 1: per face (already at full lane occupancy); faces are
        // independent, so they split across [`pool::batch_threads`]
        // workers — each face's 960-pixel dots stay serial inside its
        // worker (no nested parallel regions)
        let threads = pool::batch_threads().min(rows.len().max(1));
        let hxs: Vec<Vec<u32>> = pool::par_map_index(rows.len(), threads, |i| {
            let px: Vec<u32> =
                rows[i].iter().map(|&p| self.pre_image.apply(p as u32)).collect();
            (0..HIDDEN)
                .map(|j| {
                    let row = &self.w1p[j * IMG_PIXELS..(j + 1) * IMG_PIXELS];
                    let acc = self.q.b1[j] as i64 + self.dot(&self.mult1, &px, row);
                    sigmoid_fx(&self.q.sigmoid_lut, acc, self.q.d1) as u32
                })
                .collect()
        });
        // layer 2: lane-packed across faces — one mul_many per output
        // neuron over every face's hidden vector
        let nf = rows.len();
        let mut flat_h = Vec::with_capacity(nf * HIDDEN);
        for hx in &hxs {
            flat_h.extend_from_slice(hx);
        }
        let mut outs = vec![[0u8; NUM_OUTPUTS]; nf];
        for k in 0..NUM_OUTPUTS {
            let wrow = &self.w2p[k * HIDDEN..(k + 1) * HIDDEN];
            let ws: Vec<u32> = (0..nf * HIDDEN).map(|i| wrow[i % HIDDEN]).collect();
            let prods = self.mult2.mul_many(&flat_h, &ws);
            for f in 0..nf {
                let mut acc = self.q.b2[k] as i64;
                for j in 0..HIDDEN {
                    let idx = f * HIDDEN + j;
                    let (x, w, u) = (flat_h[idx] as i64, ws[idx], prods[idx] as i64);
                    acc += if w >= 128 { u - (x << 8) } else { u };
                }
                outs[f][k] = sigmoid_fx(&self.q.sigmoid_lut, acc, self.q.d2);
            }
        }
        outs
    }

    /// Bit-accurate forward pass through the synthesized multipliers;
    /// same return convention as [`super::net::forward_fx`].
    pub fn forward(&self, face: &Face) -> ([bool; NUM_OUTPUTS], [u8; NUM_OUTPUTS]) {
        let px: Vec<u32> = face
            .pixels
            .iter()
            .map(|&p| self.pre_image.apply(p as u32))
            .collect();
        let mut h = [0u8; HIDDEN];
        for (j, hj) in h.iter_mut().enumerate() {
            let row = &self.w1p[j * IMG_PIXELS..(j + 1) * IMG_PIXELS];
            let acc = self.q.b1[j] as i64 + self.dot(&self.mult1, &px, row);
            *hj = sigmoid_fx(&self.q.sigmoid_lut, acc, self.q.d1);
        }
        let hx: Vec<u32> = h.iter().map(|&v| v as u32).collect();
        let mut outs = [0u8; NUM_OUTPUTS];
        let mut bits = [false; NUM_OUTPUTS];
        for k in 0..NUM_OUTPUTS {
            let row = &self.w2p[k * HIDDEN..(k + 1) * HIDDEN];
            let acc = self.q.b2[k] as i64 + self.dot(&self.mult2, &hx, row);
            outs[k] = sigmoid_fx(&self.q.sigmoid_lut, acc, self.q.d2);
            bits[k] = outs[k] >= 128;
        }
        (bits, outs)
    }
}

/// Validate one face-batch request: how many 960-pixel rows it
/// carries, plus the decoded pixels.
fn decode_request(inputs: &[Tensor]) -> Result<(usize, Vec<u8>)> {
    if inputs.len() != 1 {
        bail!("expected 1 input tensor (the face batch), got {}", inputs.len());
    }
    let t = &inputs[0];
    let batch = match t.shape.as_slice() {
        [b, row] if *row == IMG_PIXELS && *b > 0 => *b,
        [n] if *n > 0 && n % IMG_PIXELS == 0 => n / IMG_PIXELS,
        other => bail!(
            "face batches are [batch, {IMG_PIXELS}] (or a flat multiple of the \
             {IMG_PIXELS}-pixel row), got shape {other:?}"
        ),
    };
    // `Tensor` fields are public, so shape and data can disagree; an
    // unchecked mismatch would shift every later request's rows in a
    // pooled batch (silent misattribution) or slice out of bounds
    if batch * IMG_PIXELS != t.data.len() {
        bail!(
            "face batch shape {:?} wants {} pixels, data has {}",
            t.shape,
            batch * IMG_PIXELS,
            t.data.len()
        );
    }
    Ok((batch, pixels_from_i32(&t.data, "pixels")?))
}

impl FrnnHardware {
    /// Forward `rows` faces (a flat pixel buffer of `rows × 960`) and
    /// flatten the activations into one `[rows, 7]` tensor.
    fn rows_tensor(&self, rows: usize, pixels: &[u8]) -> Tensor {
        let faces: Vec<&[u8]> = pixels.chunks(IMG_PIXELS).collect();
        let outs = self.forward_many(&faces);
        let data: Vec<i32> = outs
            .iter()
            .flat_map(|o| o.iter().map(|&v| v as i32))
            .collect();
        Tensor { shape: vec![rows, NUM_OUTPUTS], data }
    }
}

impl Datapath for FrnnHardware {
    /// One faces tensor in — `[batch, 960]`, or a flat multiple of the
    /// 960-pixel row — one `[batch, 7]` activation tensor out.
    fn exec(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let (batch, pixels) = decode_request(inputs)?;
        Ok(vec![self.rows_tensor(batch, &pixels)])
    }

    /// Lane-batched path: every request's faces are pooled into one
    /// forward pass ([`FrnnHardware::forward_many`]), so the layer-2
    /// multiplier lanes are shared across requests. Bit-exact with
    /// per-request [`Datapath::exec`].
    fn exec_batch(&self, batch: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        let mut rows_per = Vec::with_capacity(batch.len());
        let mut pixels: Vec<u8> = Vec::new();
        for (i, inputs) in batch.iter().enumerate() {
            let (rows, px) =
                decode_request(inputs).map_err(|e| anyhow!("request {i}: {e:#}"))?;
            rows_per.push(rows);
            pixels.extend_from_slice(&px);
        }
        let faces: Vec<&[u8]> = pixels.chunks(IMG_PIXELS).collect();
        let outs = self.forward_many(&faces);
        let mut result = Vec::with_capacity(batch.len());
        let mut off = 0;
        for &rows in &rows_per {
            let data: Vec<i32> = outs[off..off + rows]
                .iter()
                .flat_map(|o| o.iter().map(|&v| v as i32))
                .collect();
            result.push(vec![Tensor { shape: vec![rows, NUM_OUTPUTS], data }]);
            off += rows;
        }
        Ok(result)
    }

    fn num_gates(&self) -> usize {
        FrnnHardware::num_gates(self)
    }

    fn backend_name(&self) -> &'static str {
        FrnnHardware::backend_name(self)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ppc::preprocess::Preproc;

    #[test]
    fn natural_sparsity_shrinks_image_set() {
        let conv = MacConfig::conventional();
        let nat = MacConfig { natural: true, name: "natural".into(), ..MacConfig::conventional() };
        assert_eq!(image_value_set(&conv).len(), 256);
        assert_eq!(image_value_set(&nat).len(), MAX_PIXEL as u32);
    }

    #[test]
    fn ds16_much_cheaper_than_conventional() {
        let conv = MacConfig::conventional();
        let ds16 = MacConfig {
            natural: false,
            pre_image: Chain::of(Preproc::Ds(16)),
            pre_weight: Chain::of(Preproc::Ds(16)),
            name: "DS16".into(),
        };
        let (mc, ac) = mac_hardware(&conv, Objective::Area);
        let (md, ad) = mac_hardware(&ds16, Objective::Area);
        assert_eq!(md.verify_errors, 0);
        let base = aggregate(&mc, &ac);
        let ppc = aggregate(&md, &ad);
        assert!(ppc.area_ge < base.area_ge, "{} !< {}", ppc.area_ge, base.area_ge);
        assert!(ppc.power_uw < base.power_uw);
    }

    #[test]
    fn netlist_forward_matches_fixed_point() {
        use crate::apps::frnn::{dataset, net};
        let ds = dataset::generate(2, 31);
        let r = net::train(&ds, &net::TrainConfig { max_epochs: 8, ..Default::default() });
        let q = net::quantize(&r.net);
        let ci = Chain::of(Preproc::Ds(32));
        let cw = Chain::of(Preproc::Ds(32));
        let hw = FrnnHardware::synthesize(q.clone(), &ci, &cw, Objective::Area);
        assert!(hw.num_gates() > 0);
        for face in ds.test.iter().take(2) {
            let want = net::forward_fx(&q, face, &ci, &cw);
            assert_eq!(hw.forward(face), want);
        }
    }

    #[test]
    fn forward_many_lane_packs_bit_exactly() {
        use crate::apps::frnn::{dataset, net};
        let ds = dataset::generate(2, 47);
        let r = net::train(&ds, &net::TrainConfig { max_epochs: 8, ..Default::default() });
        let q = net::quantize(&r.net);
        let c = Chain::of(Preproc::Ds(32));
        let hw = FrnnHardware::synthesize(q, &c, &c, Objective::Area);
        let faces: Vec<&[u8]> = ds.test.iter().take(3).map(|f| f.pixels.as_slice()).collect();
        let many = hw.forward_many(&faces);
        for (i, f) in ds.test.iter().take(3).enumerate() {
            let (_, want) = hw.forward(f);
            assert_eq!(many[i], want, "face {i}");
        }
        // Datapath batch interface: a 2-row request and a 1-row request
        // pooled into one pass, split back per request
        let t2 = Tensor {
            shape: vec![2, 960],
            data: faces[0].iter().chain(faces[1]).map(|&p| p as i32).collect(),
        };
        let t1 = Tensor {
            shape: vec![1, 960],
            data: faces[2].iter().map(|&p| p as i32).collect(),
        };
        let batch = vec![vec![t2], vec![t1]];
        let got = hw.exec_batch(&batch).unwrap();
        for (i, inputs) in batch.iter().enumerate() {
            assert_eq!(got[i], hw.exec(inputs).unwrap(), "request {i}");
        }
        assert_eq!(got[0][0].shape, vec![2, 7]);
        assert_eq!(got[1][0].shape, vec![1, 7]);
        // shape/data disagreement (Tensor fields are public) must be a
        // structured error — an unchecked mismatch would shift every
        // later request's rows in a pooled batch
        let broken = Tensor { shape: vec![1, 960], data: vec![0; 1920] };
        let e = hw.exec(&[broken]).unwrap_err();
        assert!(format!("{e:#}").contains("wants 960 pixels"), "{e:#}");
    }

    #[test]
    fn th48_keeps_upper_range() {
        let th = MacConfig {
            natural: true,
            pre_image: Chain::of(Preproc::Th { x: 48, y: 48 }),
            pre_weight: Chain::id(),
            name: "TH48".into(),
        };
        let s = image_value_set(&th);
        assert!(!s.contains(0));
        assert!(s.contains(48));
        assert!(s.contains(MAX_PIXEL as u32 - 1));
        assert!(!s.contains(200));
        // sparsity ≈ 48/256 + (256-160)/256
        let expect = 1.0 - (MAX_PIXEL as f64 - 48.0) / 256.0;
        assert!((s.sparsity() - expect).abs() < 0.01);
    }
}
