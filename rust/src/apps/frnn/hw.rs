//! FRNN neuron (MAC) hardware reports — the implementation-results
//! columns of Table 3.
//!
//! A neuron (Fig. 10) = one 8×8 multiplier + one wide accumulator adder.
//! The paper synthesizes the *multiplier* as a PPC block (natural
//! sparsity: pixels never in [160,255]; intentional: TH/DS on the image
//! input and DS on the weight input) while keeping the adder precise; we
//! do the same.

use crate::apps::frnn::dataset::MAX_PIXEL;
use crate::logic::map::Objective;
use crate::ppc::flow::{self, BlockReport};
use crate::ppc::preprocess::{Chain, ValueSet};

/// A Table-3 row configuration for the MAC hardware.
#[derive(Clone, Debug)]
pub struct MacConfig {
    /// Exploit natural pixel sparsity (no pixel ≥ 160)?
    pub natural: bool,
    /// Intentional preprocessing on the image input.
    pub pre_image: Chain,
    /// Intentional preprocessing on the weight input (byte pattern).
    pub pre_weight: Chain,
    pub name: String,
}

impl MacConfig {
    pub fn conventional() -> MacConfig {
        MacConfig {
            natural: false,
            pre_image: Chain::id(),
            pre_weight: Chain::id(),
            name: "conventional".into(),
        }
    }
}

/// Image-input value set under a config.
pub fn image_value_set(cfg: &MacConfig) -> ValueSet {
    let base = if cfg.natural {
        ValueSet::from_values(256, 0..MAX_PIXEL as u32)
    } else {
        ValueSet::full(8)
    };
    base.map_chain(&cfg.pre_image)
}

/// Weight-input value set (weights cover the full byte range — the
/// paper's Fig. 10 weight histogram spans the entire range).
pub fn weight_value_set(cfg: &MacConfig) -> ValueSet {
    ValueSet::full(8).map_chain(&cfg.pre_weight)
}

/// Hardware report of a single neuron MAC: PPC multiplier (composed
/// 8×8) + precise accumulator adder (16-bit product + 23-bit feedback).
pub fn mac_hardware(cfg: &MacConfig, objective: Objective) -> (BlockReport, BlockReport) {
    let img = image_value_set(cfg);
    let wgt = weight_value_set(cfg);
    let mult = flow::composed_mult8(&format!("mac_mult[{}]", cfg.name), &img, &wgt, objective);
    let adder = flow::conventional_adder("mac_acc_adder", 16, 23, objective);
    (mult, adder)
}

/// Aggregate into the table row (single-neuron implementation results).
pub fn aggregate(mult: &BlockReport, adder: &BlockReport) -> BlockReport {
    BlockReport {
        name: mult.name.clone(),
        literals: mult.literals, // adder kept precise; flat-literal column
        area_ge: mult.area_ge + adder.area_ge,
        delay_ns: mult.delay_ns + adder.delay_ns,
        power_uw: mult.power_uw + adder.power_uw,
        dc_fraction: mult.dc_fraction,
        verify_errors: mult.verify_errors + adder.verify_errors,
    }
}

/// Flat two-level literal count of the MAC multiplier (the paper's
/// "# of literals" for Table 3).
pub fn mac_flat_literals(cfg: &MacConfig) -> u64 {
    flow::flat_mult_literals(&image_value_set(cfg), &weight_value_set(cfg))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ppc::preprocess::Preproc;

    #[test]
    fn natural_sparsity_shrinks_image_set() {
        let conv = MacConfig::conventional();
        let nat = MacConfig { natural: true, name: "natural".into(), ..MacConfig::conventional() };
        assert_eq!(image_value_set(&conv).len(), 256);
        assert_eq!(image_value_set(&nat).len(), MAX_PIXEL as u32);
    }

    #[test]
    fn ds16_much_cheaper_than_conventional() {
        let conv = MacConfig::conventional();
        let ds16 = MacConfig {
            natural: false,
            pre_image: Chain::of(Preproc::Ds(16)),
            pre_weight: Chain::of(Preproc::Ds(16)),
            name: "DS16".into(),
        };
        let (mc, ac) = mac_hardware(&conv, Objective::Area);
        let (md, ad) = mac_hardware(&ds16, Objective::Area);
        assert_eq!(md.verify_errors, 0);
        let base = aggregate(&mc, &ac);
        let ppc = aggregate(&md, &ad);
        assert!(ppc.area_ge < base.area_ge, "{} !< {}", ppc.area_ge, base.area_ge);
        assert!(ppc.power_uw < base.power_uw);
    }

    #[test]
    fn th48_keeps_upper_range() {
        let th = MacConfig {
            natural: true,
            pre_image: Chain::of(Preproc::Th { x: 48, y: 48 }),
            pre_weight: Chain::id(),
            name: "TH48".into(),
        };
        let s = image_value_set(&th);
        assert!(!s.contains(0));
        assert!(s.contains(48));
        assert!(s.contains(MAX_PIXEL as u32 - 1));
        assert!(!s.contains(200));
        // sparsity ≈ 48/256 + (256-160)/256
        let expect = 1.0 - (MAX_PIXEL as f64 - 48.0) / 256.0;
        assert!((s.sparsity() - expect).abs() < 0.01);
    }
}
