//! JSON interop for FRNN weights and datasets — the exchange format
//! between the rust side and the python build layer (`python/compile/
//! train_frnn.py` reads/writes the same schema).

use super::dataset::{Dataset, Face, IMG_PIXELS, NUM_OUTPUTS};
use super::net::{Frnn, HIDDEN};
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

/// Serialize float weights.
pub fn weights_to_json(net: &Frnn) -> Json {
    let f = |v: &[f32]| Json::Arr(v.iter().map(|&x| Json::Num(x as f64)).collect());
    Json::obj(vec![
        ("hidden", Json::Num(HIDDEN as f64)),
        ("inputs", Json::Num(IMG_PIXELS as f64)),
        ("outputs", Json::Num(NUM_OUTPUTS as f64)),
        ("w1", f(&net.w1)),
        ("b1", f(&net.b1)),
        ("w2", f(&net.w2)),
        ("b2", f(&net.b2)),
    ])
}

pub fn weights_from_json(j: &Json) -> Result<Frnn> {
    let get = |k: &str| -> Result<Vec<f32>> {
        Ok(j.get(k)
            .ok_or_else(|| anyhow!("missing key {k}"))?
            .flat_f64()
            .into_iter()
            .map(|x| x as f32)
            .collect())
    };
    let net = Frnn { w1: get("w1")?, b1: get("b1")?, w2: get("w2")?, b2: get("b2")? };
    if net.w1.len() != HIDDEN * IMG_PIXELS || net.w2.len() != NUM_OUTPUTS * HIDDEN {
        return Err(anyhow!(
            "weight shape mismatch: w1={} w2={}",
            net.w1.len(),
            net.w2.len()
        ));
    }
    Ok(net)
}

pub fn save_weights(net: &Frnn, path: &Path) -> Result<()> {
    std::fs::write(path, weights_to_json(net).to_string())
        .with_context(|| format!("writing {}", path.display()))
}

pub fn load_weights(path: &Path) -> Result<Frnn> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
    weights_from_json(&j)
}

/// Serialize a dataset (pixels as arrays of ints — bulky but portable;
/// the dataset is small: ~1000 × 960 bytes).
pub fn dataset_to_json(ds: &Dataset) -> Json {
    let face = |f: &Face| {
        Json::obj(vec![
            ("id", Json::Num(f.id as f64)),
            ("pose", Json::Num(f.pose as f64)),
            ("sunglasses", Json::Bool(f.sunglasses)),
            (
                "pixels",
                Json::Arr(f.pixels.iter().map(|&p| Json::Num(p as f64)).collect()),
            ),
        ])
    };
    Json::obj(vec![
        ("width", Json::Num(super::dataset::IMG_W as f64)),
        ("height", Json::Num(super::dataset::IMG_H as f64)),
        ("train", Json::Arr(ds.train.iter().map(face).collect())),
        ("test", Json::Arr(ds.test.iter().map(face).collect())),
    ])
}

pub fn dataset_from_json(j: &Json) -> Result<Dataset> {
    let faces = |k: &str| -> Result<Vec<Face>> {
        j.get(k)
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("missing {k}"))?
            .iter()
            .map(|f| {
                let pixels: Vec<u8> = f
                    .get("pixels")
                    .ok_or_else(|| anyhow!("face missing pixels"))?
                    .flat_f64()
                    .into_iter()
                    .map(|x| x as u8)
                    .collect();
                if pixels.len() != IMG_PIXELS {
                    return Err(anyhow!("face has {} pixels", pixels.len()));
                }
                Ok(Face {
                    pixels,
                    id: f.get("id").and_then(|v| v.as_f64()).unwrap_or(0.0) as u8,
                    pose: f.get("pose").and_then(|v| v.as_f64()).unwrap_or(0.0) as u8,
                    sunglasses: matches!(f.get("sunglasses"), Some(Json::Bool(true))),
                })
            })
            .collect()
    };
    Ok(Dataset { train: faces("train")?, test: faces("test")? })
}

pub fn save_dataset(ds: &Dataset, path: &Path) -> Result<()> {
    std::fs::write(path, dataset_to_json(ds).to_string())
        .with_context(|| format!("writing {}", path.display()))
}

pub fn load_dataset(path: &Path) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {}", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("parse {}: {e}", path.display()))?;
    dataset_from_json(&j)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::frnn::dataset::generate;
    use crate::apps::frnn::net::Frnn;

    #[test]
    fn weights_roundtrip() {
        let net = Frnn::random(3);
        let j = weights_to_json(&net);
        let back = weights_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(net.w1, back.w1);
        assert_eq!(net.b2, back.b2);
    }

    #[test]
    fn dataset_roundtrip() {
        let ds = generate(2, 5);
        let j = dataset_to_json(&ds);
        let back = dataset_from_json(&Json::parse(&j.to_string()).unwrap()).unwrap();
        assert_eq!(ds.train.len(), back.train.len());
        assert_eq!(ds.train[0].pixels, back.train[0].pixels);
        assert_eq!(ds.test[3].id, back.test[3].id);
        assert_eq!(ds.test[3].sunglasses, back.test[3].sunglasses);
    }

    #[test]
    fn rejects_malformed() {
        assert!(weights_from_json(&Json::parse("{}").unwrap()).is_err());
        let short = r#"{"w1":[1,2],"b1":[0],"w2":[1],"b2":[0]}"#;
        assert!(weights_from_json(&Json::parse(short).unwrap()).is_err());
    }
}
