//! Face-Recognition Neural Network application (paper Section VI).
//!
//! - [`dataset`] — the synthetic 32×30 face set (CMU-faceimages stand-in).
//! - [`net`] — float trainer + bit-accurate fixed-point forward (Fig. 10
//!   MAC semantics with preprocessed multiplier operands).
//! - [`hw`] — single-neuron MAC hardware reports (Table 3 columns).
//! - [`io`] — JSON interop with the python build layer.

pub mod dataset;
pub mod hw;
pub mod io;
pub mod net;
