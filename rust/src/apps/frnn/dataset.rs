//! Synthetic face dataset — stand-in for the CMU `faceimages` set the
//! paper uses (32×30 grayscale, person id / head direction / sunglasses
//! labels). See DESIGN.md's substitution table: the generator reproduces
//! the *distributional* facts the paper exploits —
//!
//! - dark background (< 48) → the `TH_48^48` preprocessing target,
//! - no pixel ever reaches [160, 255] → the natural sparsity on the
//!   multiplier image input (Fig. 10),
//! - id / direction / sunglasses factors that a 960-40-7 MLP can learn.

use crate::util::prng::Rng;

pub const IMG_W: usize = 32;
pub const IMG_H: usize = 30;
pub const IMG_PIXELS: usize = IMG_W * IMG_H; // 960, the paper's input count
pub const NUM_IDS: usize = 16; // 4 output bits
pub const NUM_POSES: usize = 4; // 2 output bits: left/straight/right/up
pub const NUM_OUTPUTS: usize = 7; // 4 id + 2 pose + 1 sunglasses

/// Maximum pixel value the generator emits (exclusive): reproduces the
/// paper's observed natural sparsity "values between 160 and 255 do not
/// appear on the multiplier image input".
pub const MAX_PIXEL: u8 = 160;
/// Background pixels stay strictly below the paper's threshold of 48.
pub const BG_MAX: u8 = 47;

/// One labeled face image.
#[derive(Clone, Debug)]
pub struct Face {
    pub pixels: Vec<u8>, // 960 bytes
    pub id: u8,
    pub pose: u8,
    pub sunglasses: bool,
}

impl Face {
    /// The 7 target bits in network output order: id b0..b3, pose b0..b1,
    /// sunglasses.
    pub fn targets(&self) -> [bool; NUM_OUTPUTS] {
        [
            self.id & 1 != 0,
            self.id & 2 != 0,
            self.id & 4 != 0,
            self.id & 8 != 0,
            self.pose & 1 != 0,
            self.pose & 2 != 0,
            self.sunglasses,
        ]
    }
}

/// Train/test split of the generated dataset.
#[derive(Clone, Debug)]
pub struct Dataset {
    pub train: Vec<Face>,
    pub test: Vec<Face>,
}

/// Deterministic id-specific appearance: a coarse intensity pattern
/// derived from the id bits plus an id-salted fine texture.
fn face_pixel(id: u8, fx: f64, fy: f64, rng_tex: &mut Rng) -> f64 {
    // quadrant offsets from id bits
    let qx = if fx < 0.5 { 0 } else { 1 };
    let qy = if fy < 0.5 { 0 } else { 1 };
    let q = (qy << 1) | qx;
    let bit = (id >> q) & 1;
    let base = 92.0 + if bit == 1 { 22.0 } else { -18.0 };
    // radial shading toward the ellipse rim
    let r2 = (fx - 0.5) * (fx - 0.5) + (fy - 0.5) * (fy - 0.5);
    base - 55.0 * r2 + 3.0 * rng_tex.next_gaussian()
}

/// Render one face.
pub fn render_face(id: u8, pose: u8, sunglasses: bool, noise_seed: u64) -> Face {
    let mut rng = Rng::new(
        0xFACE_0000
            ^ (id as u64)
            ^ ((pose as u64) << 8)
            ^ ((sunglasses as u64) << 16)
            ^ (noise_seed << 24),
    );
    let mut px = vec![0u8; IMG_PIXELS];
    // pose determines ellipse center
    let (cx, cy) = match pose {
        0 => (11.0, 16.0), // left
        1 => (16.0, 16.0), // straight
        2 => (21.0, 16.0), // right
        _ => (16.0, 11.0), // up
    };
    let (rx, ry) = (8.5, 11.0);
    for y in 0..IMG_H {
        for x in 0..IMG_W {
            let dx = (x as f64 - cx) / rx;
            let dy = (y as f64 - cy) / ry;
            let inside = dx * dx + dy * dy <= 1.0;
            let v = if inside {
                let fx = (dx + 1.0) / 2.0;
                let fy = (dy + 1.0) / 2.0;
                let mut v = face_pixel(id, fx, fy, &mut rng);
                // eye band
                let eye_y = cy - 0.35 * ry;
                if (y as f64 - eye_y).abs() < 1.6 {
                    if sunglasses {
                        v = 52.0 + 2.0 * rng.next_gaussian(); // dark band
                    } else if ((x as f64 - (cx - 0.4 * rx)).abs() < 1.2)
                        || ((x as f64 - (cx + 0.4 * rx)).abs() < 1.2)
                    {
                        v = 140.0 + 4.0 * rng.next_gaussian(); // bright eyes
                    }
                }
                // mouth
                let mouth_y = cy + 0.45 * ry;
                if (y as f64 - mouth_y).abs() < 1.0 && (x as f64 - cx).abs() < 0.35 * rx {
                    v = 60.0;
                }
                v.clamp(48.0, (MAX_PIXEL - 1) as f64)
            } else {
                (22.0 + 6.0 * rng.next_gaussian()).clamp(8.0, BG_MAX as f64)
            };
            px[y * IMG_W + x] = v as u8;
        }
    }
    Face { pixels: px, id, pose, sunglasses }
}

/// Generate the full dataset: every (id, pose, sunglasses) combination
/// with `samples_per_combo` noise instances; the last instance of each
/// combination goes to the test split.
pub fn generate(samples_per_combo: usize, seed: u64) -> Dataset {
    assert!(samples_per_combo >= 2);
    let mut train = Vec::new();
    let mut test = Vec::new();
    for id in 0..NUM_IDS as u8 {
        for pose in 0..NUM_POSES as u8 {
            for glasses in [false, true] {
                for s in 0..samples_per_combo {
                    let f = render_face(id, pose, glasses, seed.wrapping_add(s as u64));
                    if s + 1 == samples_per_combo {
                        test.push(f);
                    } else {
                        train.push(f);
                    }
                }
            }
        }
    }
    Dataset { train, test }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pixel_ranges_match_paper_sparsity() {
        let ds = generate(3, 1);
        for f in ds.train.iter().chain(&ds.test) {
            assert!(f.pixels.iter().all(|&p| p < MAX_PIXEL), "pixel ≥ 160 found");
        }
        // background exists and is dark
        let f = &ds.train[0];
        let dark = f.pixels.iter().filter(|&&p| p < 48).count();
        assert!(dark > 200, "expected substantial dark background, got {dark}");
    }

    #[test]
    fn deterministic() {
        let a = render_face(3, 1, true, 7);
        let b = render_face(3, 1, true, 7);
        assert_eq!(a.pixels, b.pixels);
        let c = render_face(3, 1, true, 8);
        assert_ne!(a.pixels, c.pixels);
    }

    #[test]
    fn ids_are_distinguishable() {
        // different ids must differ substantially inside the face
        let a = render_face(0, 1, false, 1);
        let b = render_face(15, 1, false, 1);
        let diff: u64 = a
            .pixels
            .iter()
            .zip(&b.pixels)
            .map(|(&x, &y)| (x as i64 - y as i64).unsigned_abs())
            .sum();
        assert!(diff > 10_000, "ids too similar: {diff}");
    }

    #[test]
    fn split_sizes() {
        let ds = generate(5, 2);
        assert_eq!(ds.train.len(), 16 * 4 * 2 * 4);
        assert_eq!(ds.test.len(), 16 * 4 * 2);
    }

    #[test]
    fn targets_encode_labels() {
        let f = render_face(0b1010, 0b10, true, 1);
        let t = f.targets();
        assert_eq!(t, [false, true, false, true, false, true, true]);
    }

    #[test]
    fn sunglasses_darken_eye_band() {
        let plain = render_face(5, 1, false, 3);
        let shades = render_face(5, 1, true, 3);
        let mean = |f: &Face| -> f64 {
            // eye band rows around y = 16 - 3.85 ≈ 12
            (0..IMG_W).map(|x| f.pixels[12 * IMG_W + x] as f64).sum::<f64>() / IMG_W as f64
        };
        assert!(mean(&shades) < mean(&plain), "sunglasses should darken the band");
    }
}
