//! The measured-quality harness: every registered `(ModelKey, tier)`
//! gets a *number*, not just an ordinal label.
//!
//! The paper's contract is that PPC trades a bounded,
//! application-measurable quality loss for implementation cost; this
//! module is where that loss is measured, per application, over a
//! deterministic in-tree eval set:
//!
//! - **GDF / blend**: PSNR of the config's fixed-point sim output vs
//!   the precise tier's output on the same synthetic photos (the
//!   paper's image metric). The precise tier compares to itself, so
//!   its PSNR is infinite — capped at [`PSNR_CAP`] to stay
//!   JSON-expressible.
//! - **FRNN**: top-1 correct-classification rate of the bit-accurate
//!   `forward_fx` on the generated test split (the paper's CCR),
//!   absolute for every tier including precise.
//!
//! Measurement runs against the fixed-point application sims, not the
//! synthesized netlists — bit-exactness between the two is the repo's
//! core invariant (asserted at synthesis time and in the integration
//! suite), so the sims are the cheap, authoritative oracle.
//!
//! Results are cached as small JSON files in the netlist cache dir
//! (same best-effort temp-file-then-rename discipline as the BLIF
//! entries) so warm starts don't re-measure; FRNN entries carry a
//! weight fingerprint and re-measure when the deployed weights change.

use crate::apps::frnn::{dataset, net, net::QuantFrnn};
use crate::apps::image::{synthetic_photo, Image};
use crate::apps::{blend, gdf};
use crate::catalog::{App, ModelKey, PpcConfig, Quality, QualityMetric, QualityProfile, PSNR_CAP};
use crate::ppc::preprocess::Chain;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::path::{Path, PathBuf};

/// Seed of the deterministic eval set (images and the FRNN eval
/// split). Changing it changes every measured number, so it is a
/// constant, not a knob.
pub const EVAL_SEED: u64 = 0x9A11;

/// Eval image edge for the image apps.
const EVAL_SIZE: usize = 64;

/// Measure the image-app quality of `config` for `app`: PSNR of the
/// config's output vs the precise chain's output over the in-tree eval
/// images. FRNN carries weights, so it goes through [`measure_frnn`].
pub fn measure_image_app(app: App, config: PpcConfig) -> Result<QualityProfile> {
    let chain = config.chain();
    let id = Chain::id();
    let psnr = match app {
        App::Gdf => {
            let img = synthetic_photo(EVAL_SIZE, EVAL_SIZE, EVAL_SEED);
            let got = gdf::gdf_filter(&img, &chain);
            let want = gdf::gdf_filter(&img, &id);
            want.psnr(&got)
        }
        App::Blend => {
            let p1 = synthetic_photo(EVAL_SIZE, EVAL_SIZE, EVAL_SEED);
            let p2 = synthetic_photo(EVAL_SIZE, EVAL_SIZE, EVAL_SEED ^ 0xB1E4D);
            let alpha = blend::Alpha(64);
            let got = blend_eval(&p1, &p2, alpha, &chain);
            let want = blend_eval(&p1, &p2, alpha, &id);
            want.psnr(&got)
        }
        App::Frnn => bail!("frnn quality needs the deployed weights — use measure_frnn"),
    };
    Ok(QualityProfile {
        metric: QualityMetric::Psnr,
        value: psnr.min(PSNR_CAP),
        reference: Quality::Precise,
    })
}

fn blend_eval(p1: &Image, p2: &Image, alpha: blend::Alpha, chain: &Chain) -> Image {
    blend::blend_images(p1, p2, alpha, chain, chain)
}

/// The deterministic FRNN eval split every measurement scores against.
pub fn frnn_eval_split() -> Vec<dataset::Face> {
    dataset::generate(2, EVAL_SEED).test
}

/// Measure the FRNN quality of `config` with the deployed quantized
/// weights: absolute top-1 CCR of the bit-accurate fixed-point forward
/// on the eval split.
pub fn measure_frnn(quant: &QuantFrnn, config: PpcConfig) -> QualityProfile {
    let faces = frnn_eval_split();
    let ev = net::evaluate_fx(quant, &faces, &config.chain(), &config.weight_chain());
    QualityProfile {
        metric: QualityMetric::Accuracy,
        value: ev.ccr,
        reference: Quality::Precise,
    }
}

/// A cheap FNV-1a fingerprint of the quantized FRNN parameters: cached
/// FRNN measurements are only valid for the exact weights they scored.
pub fn frnn_fingerprint(quant: &QuantFrnn) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    let mut eat = |b: u8| {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    };
    for &w in &quant.w1 {
        eat(w as u8);
    }
    for &b in &quant.b1 {
        b.to_le_bytes().into_iter().for_each(&mut eat);
    }
    for &w in &quant.w2 {
        eat(w as u8);
    }
    for &b in &quant.b2 {
        b.to_le_bytes().into_iter().for_each(&mut eat);
    }
    h
}

/// Fingerprint for models without weights (the eval set is fixed
/// in-tree, so the measurement is a pure function of the key).
pub const STATIC_FINGERPRINT: u64 = 0;

fn cache_path(dir: &Path, key: ModelKey) -> PathBuf {
    dir.join(format!("{}-{}.quality.json", key.app, key.config))
}

/// A cached profile is only served when its number is plausible for
/// its metric. The cache file is untrusted input (disk rot, hand
/// edits, partial writes): a garbled-but-well-formed entry must cost
/// one re-measure, never a bogus quality claim on the wire.
fn plausible(p: &QualityProfile) -> bool {
    match p.metric {
        QualityMetric::Psnr => p.value > 0.0 && p.value <= PSNR_CAP,
        QualityMetric::Accuracy => (0.0..=1.0).contains(&p.value),
    }
}

/// Load a cached measurement for `key`, if one exists, parses, its
/// fingerprint matches, and its value is in the metric's plausible
/// range. Any failure is a silent miss (the caller re-measures),
/// never an error.
pub fn load_cached(dir: &Path, key: ModelKey, fingerprint: u64) -> Option<QualityProfile> {
    let text = std::fs::read_to_string(cache_path(dir, key)).ok()?;
    let j = Json::parse(&text).ok()?;
    let fp = j.get("fingerprint").and_then(|v| v.as_str())?;
    if fp != format!("{fingerprint:016x}") {
        return None;
    }
    let p = QualityProfile::from_json(j.get("profile")?).ok()?;
    plausible(&p).then_some(p)
}

/// Best-effort cache write (temp file + rename, like the BLIF
/// entries): an unwritable cache dir degrades to re-measuring on the
/// next cold start, never to an error.
pub fn store_cached(dir: &Path, key: ModelKey, fingerprint: u64, profile: &QualityProfile) {
    if std::fs::create_dir_all(dir).is_err() {
        return;
    }
    let j = Json::obj(vec![
        ("fingerprint", Json::Str(format!("{fingerprint:016x}"))),
        ("profile", profile.to_json()),
    ]);
    let tmp = dir.join(format!(
        ".{}-{}.quality.tmp.{}",
        key.app,
        key.config,
        std::process::id()
    ));
    let path = cache_path(dir, key);
    if std::fs::write(&tmp, j.to_string()).is_ok() && std::fs::rename(&tmp, &path).is_err() {
        let _ = std::fs::remove_file(&tmp);
    }
}

/// Measure `key` (image apps only), drawing from / refilling the cache
/// when a dir is given.
pub fn measure_image_app_cached(
    dir: Option<&Path>,
    app: App,
    config: PpcConfig,
) -> Result<QualityProfile> {
    let key = ModelKey::new(app, config)
        .map_err(|e| anyhow!("quality measurement for an invalid key: {e:#}"))?;
    if let Some(dir) = dir {
        if let Some(p) = load_cached(dir, key, STATIC_FINGERPRINT) {
            return Ok(p);
        }
    }
    let profile = measure_image_app(app, config)?;
    if let Some(dir) = dir {
        store_cached(dir, key, STATIC_FINGERPRINT, &profile);
    }
    Ok(profile)
}

/// Measure `frnn/{config}` with `quant`'s weights, drawing from /
/// refilling the cache (fingerprinted by the weights) when a dir is
/// given.
pub fn measure_frnn_cached(
    dir: Option<&Path>,
    config: PpcConfig,
    quant: &QuantFrnn,
) -> QualityProfile {
    let key = ModelKey::new(App::Frnn, config).ok();
    let fp = frnn_fingerprint(quant);
    if let (Some(dir), Some(key)) = (dir, key) {
        if let Some(p) = load_cached(dir, key, fp) {
            return p;
        }
    }
    let profile = measure_frnn(quant, config);
    if let (Some(dir), Some(key)) = (dir, key) {
        store_cached(dir, key, fp, &profile);
    }
    profile
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::frnn::net::TrainConfig;

    #[test]
    fn precise_tiers_measure_at_the_cap() {
        for app in [App::Gdf, App::Blend] {
            let p = measure_image_app(app, PpcConfig::Conv).unwrap();
            assert_eq!(p.metric, QualityMetric::Psnr);
            assert_eq!(p.value, PSNR_CAP, "{app}: precise vs itself is the capped ideal");
        }
    }

    #[test]
    fn sparser_configs_measure_strictly_lower_psnr() {
        for app in [App::Gdf, App::Blend] {
            let ds16 = measure_image_app(app, PpcConfig::Ds16).unwrap().value;
            let ds32 = measure_image_app(app, PpcConfig::Ds32).unwrap().value;
            assert!(
                ds32 < ds16 && ds16 < PSNR_CAP,
                "{app}: quality must fall with sparsity (ds16={ds16:.1}, ds32={ds32:.1})"
            );
            // the paper's image tables live in the 20-45dB band;
            // anything outside means the eval harness is broken
            assert!(ds16 > 20.0 && ds32 > 15.0, "{app}: ds16={ds16:.1} ds32={ds32:.1}");
        }
    }

    #[test]
    fn frnn_accuracy_is_a_rate_and_degrades_with_sparsity() {
        let ds = dataset::generate(2, 0x7E57);
        let r = net::train(&ds, &TrainConfig { max_epochs: 25, ..Default::default() });
        let quant = net::quantize(&r.net);
        let conv = measure_frnn(&quant, PpcConfig::Conv);
        let ds32 = measure_frnn(&quant, PpcConfig::Ds32);
        assert_eq!(conv.metric, QualityMetric::Accuracy);
        for p in [&conv, &ds32] {
            assert!((0.0..=1.0).contains(&p.value), "{}", p.value);
        }
        // weights trained without preprocessing: the precise forward
        // should score at least as well as aggressive DS32
        assert!(conv.value >= ds32.value, "conv={} ds32={}", conv.value, ds32.value);
    }

    #[test]
    fn cache_round_trips_and_rejects_stale_fingerprints() {
        let dir = std::env::temp_dir().join(format!("ppc_quality_cache_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let key = ModelKey::parse("gdf/ds16").unwrap();
        assert!(load_cached(&dir, key, 7).is_none(), "empty cache is a miss");
        let p = QualityProfile {
            metric: QualityMetric::Psnr,
            value: 31.5,
            reference: Quality::Precise,
        };
        store_cached(&dir, key, 7, &p);
        assert_eq!(load_cached(&dir, key, 7), Some(p));
        assert!(load_cached(&dir, key, 8).is_none(), "fingerprint mismatch is a miss");
        // a vandalized entry is a silent miss, never a panic
        std::fs::write(dir.join("gdf-ds16.quality.json"), "not json").unwrap();
        assert!(load_cached(&dir, key, 7).is_none());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_cache_entries_are_misses_and_trigger_a_re_measure() {
        let dir = std::env::temp_dir().join(format!("ppc_quality_rot_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let key = ModelKey::parse("gdf/ds32").unwrap();
        let path = dir.join("gdf-ds32.quality.json");
        let good = QualityProfile {
            metric: QualityMetric::Psnr,
            value: 28.0,
            reference: Quality::Precise,
        };
        store_cached(&dir, key, STATIC_FINGERPRINT, &good);
        let stored = std::fs::read_to_string(&path).unwrap();

        // a truncated entry (torn write) is a miss
        std::fs::write(&path, &stored[..stored.len() / 2]).unwrap();
        assert!(load_cached(&dir, key, STATIC_FINGERPRINT).is_none(), "truncated");
        // garbled bytes are a miss
        std::fs::write(&path, "\u{1}\u{2}garbage\u{3}").unwrap();
        assert!(load_cached(&dir, key, STATIC_FINGERPRINT).is_none(), "garbled");
        // well-formed JSON with out-of-range numbers is a miss too:
        // negative or over-cap PSNR, accuracy outside [0, 1]
        for (metric, value) in
            [("psnr", -5.0), ("psnr", 500.0), ("acc", 7.5), ("acc", -0.1), ("psnr", 0.0)]
        {
            let fp = format!("{STATIC_FINGERPRINT:016x}");
            let bogus = format!(
                "{{\"fingerprint\": \"{fp}\", \"profile\": {{\"metric\": \"{metric}\", \
                 \"value\": {value}, \"reference\": \"precise\"}}}}"
            );
            std::fs::write(&path, bogus).unwrap();
            assert!(
                load_cached(&dir, key, STATIC_FINGERPRINT).is_none(),
                "{metric}={value} must not be served"
            );
            // and the cached front door re-measures a sane number
            // instead of trusting the file
            let p = measure_image_app_cached(Some(&dir), App::Gdf, PpcConfig::Ds32).unwrap();
            assert!(p.value > 0.0 && p.value <= PSNR_CAP, "re-measured {}", p.value);
            // the re-measure also repaired the cache entry in place
            assert_eq!(load_cached(&dir, key, STATIC_FINGERPRINT), Some(p));
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cached_measurement_skips_the_second_measure() {
        let dir = std::env::temp_dir().join(format!("ppc_quality_warm_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        let cold = measure_image_app_cached(Some(&dir), App::Gdf, PpcConfig::Ds32).unwrap();
        // warm load returns the identical stored profile
        let warm = measure_image_app_cached(Some(&dir), App::Gdf, PpcConfig::Ds32).unwrap();
        assert_eq!(cold, warm);
        assert!(dir.join("gdf-ds32.quality.json").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn fingerprints_track_the_weights() {
        let ds = dataset::generate(2, 1);
        let cfg = TrainConfig { max_epochs: 2, ..Default::default() };
        let a = net::quantize(&net::train(&ds, &cfg).net);
        let mut b = a.clone();
        assert_eq!(frnn_fingerprint(&a), frnn_fingerprint(&b));
        b.w1[0] = b.w1[0].wrapping_add(1);
        assert_ne!(frnn_fingerprint(&a), frnn_fingerprint(&b));
    }
}
