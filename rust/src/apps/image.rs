//! Grayscale images: PGM I/O, deterministic synthetic test images, noise,
//! and quality metrics. Stands in for the paper's sample photographs
//! (Lena/Tulips are not redistributable; the generators below produce
//! photo-like statistics — in particular the Gaussian-shaped histograms
//! Figs. 1/5/7 rely on).

use crate::catalog::Tensor;
use crate::util::prng::Rng;
use crate::util::stats;
use anyhow::{anyhow, bail, Result};
use std::io::Write as _;
use std::path::Path;

/// i32 tensor data → u8 pixels, with a clear error on out-of-range
/// values (`what` names the offending tensor in the message).
pub fn pixels_from_i32(data: &[i32], what: &str) -> Result<Vec<u8>> {
    data.iter()
        .map(|&v| {
            if (0..=255).contains(&v) {
                Ok(v as u8)
            } else {
                Err(anyhow!("{what}: value {v} outside the u8 pixel range"))
            }
        })
        .collect()
}

/// 8-bit grayscale image, row-major.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Image {
    pub width: usize,
    pub height: usize,
    pub pixels: Vec<u8>,
}

impl Image {
    pub fn new(width: usize, height: usize) -> Image {
        Image { width, height, pixels: vec![0; width * height] }
    }

    #[inline]
    pub fn get(&self, x: usize, y: usize) -> u8 {
        self.pixels[y * self.width + x]
    }

    #[inline]
    pub fn set(&mut self, x: usize, y: usize, v: u8) {
        self.pixels[y * self.width + x] = v;
    }

    /// Clamped fetch (border replication, the usual filter convention).
    #[inline]
    pub fn get_clamped(&self, x: isize, y: isize) -> u8 {
        let xc = x.clamp(0, self.width as isize - 1) as usize;
        let yc = y.clamp(0, self.height as isize - 1) as usize;
        self.get(xc, yc)
    }

    /// Build from a shape-carrying tensor: rank-2 `[height, width]`
    /// (non-square images welcome), or a rank-1 tensor as a square
    /// image — the legacy flat convention.
    pub fn from_tensor(t: &Tensor, what: &str) -> Result<Image> {
        let (height, width) = match t.shape.as_slice() {
            [h, w] => (*h, *w),
            [n] => {
                let side = (*n as f64).sqrt().round() as usize;
                if side * side != *n || *n == 0 {
                    bail!(
                        "{what}: flat tensor of {n} pixels is not square; \
                         send shape [height, width] for non-square images"
                    );
                }
                (side, side)
            }
            other => bail!("{what}: image tensors are [height, width], got shape {other:?}"),
        };
        if width * height != t.data.len() {
            bail!(
                "{what}: shape {:?} wants {} pixels, data has {}",
                t.shape,
                width * height,
                t.data.len()
            );
        }
        Ok(Image { width, height, pixels: pixels_from_i32(&t.data, what)? })
    }

    /// Shape-carrying `[height, width]` tensor of the pixels.
    pub fn to_tensor(&self) -> Tensor {
        Tensor {
            shape: vec![self.height, self.width],
            data: self.pixels.iter().map(|&p| p as i32).collect(),
        }
    }

    /// Apply a per-pixel map.
    pub fn map(&self, f: impl Fn(u8) -> u8) -> Image {
        Image {
            width: self.width,
            height: self.height,
            pixels: self.pixels.iter().map(|&p| f(p)).collect(),
        }
    }

    /// PSNR against another image of the same size.
    pub fn psnr(&self, other: &Image) -> f64 {
        stats::psnr_u8(&self.pixels, &other.pixels)
    }

    /// Write binary PGM (P5).
    pub fn write_pgm(&self, path: &Path) -> std::io::Result<()> {
        let mut f = std::fs::File::create(path)?;
        write!(f, "P5\n{} {}\n255\n", self.width, self.height)?;
        f.write_all(&self.pixels)
    }

    /// Read binary PGM (P5) — enough of the format for our own files.
    pub fn read_pgm(path: &Path) -> std::io::Result<Image> {
        let data = std::fs::read(path)?;
        let err = |m: &str| std::io::Error::new(std::io::ErrorKind::InvalidData, m.to_string());
        // header: magic, width, height, maxval, single whitespace, raster
        let mut pos = 0usize;
        let mut token = || -> Result<String, std::io::Error> {
            while pos < data.len() && (data[pos] as char).is_whitespace() {
                pos += 1;
            }
            if pos < data.len() && data[pos] == b'#' {
                while pos < data.len() && data[pos] != b'\n' {
                    pos += 1;
                }
                while pos < data.len() && (data[pos] as char).is_whitespace() {
                    pos += 1;
                }
            }
            let start = pos;
            while pos < data.len() && !(data[pos] as char).is_whitespace() {
                pos += 1;
            }
            Ok(String::from_utf8_lossy(&data[start..pos]).into_owned())
        };
        if token()? != "P5" {
            return Err(err("not a P5 PGM"));
        }
        let width: usize = token()?.parse().map_err(|_| err("bad width"))?;
        let height: usize = token()?.parse().map_err(|_| err("bad height"))?;
        let maxval: usize = token()?.parse().map_err(|_| err("bad maxval"))?;
        if maxval != 255 {
            return Err(err("only maxval 255 supported"));
        }
        pos += 1; // the single whitespace after maxval
        let need = width * height;
        if data.len() < pos + need {
            return Err(err("truncated raster"));
        }
        Ok(Image { width, height, pixels: data[pos..pos + need].to_vec() })
    }
}

/// A deterministic photo-like test image: smooth low-frequency structure
/// (objects/illumination) plus mild texture — its histogram is broad and
/// roughly Gaussian, like the natural images in the paper's figures.
pub fn synthetic_photo(width: usize, height: usize, seed: u64) -> Image {
    let mut rng = Rng::new(seed);
    // random low-frequency cosine mixture
    let n_terms = 6;
    let terms: Vec<(f64, f64, f64, f64)> = (0..n_terms)
        .map(|_| {
            (
                rng.next_f64() * 3.5 + 0.5,              // fx (cycles over image)
                rng.next_f64() * 3.5 + 0.5,              // fy
                rng.next_f64() * std::f64::consts::TAU,  // phase
                rng.next_f64() * 0.8 + 0.2,              // amplitude
            )
        })
        .collect();
    let mut img = Image::new(width, height);
    for y in 0..height {
        for x in 0..width {
            let (xf, yf) = (x as f64 / width as f64, y as f64 / height as f64);
            let mut v = 0.0;
            for &(fx, fy, ph, a) in &terms {
                v += a * (std::f64::consts::TAU * (fx * xf + fy * yf) + ph).cos();
            }
            // texture
            v += 0.25 * rng.next_gaussian();
            // normalize-ish to 0..255 around mid gray
            let p = (128.0 + 48.0 * v).clamp(0.0, 255.0);
            img.set(x, y, p as u8);
        }
    }
    img
}

/// Gaussian-histogram image used by the Fig. 1 regenerator.
pub fn gaussian_histogram_image(width: usize, height: usize, mean: f64, sigma: f64, seed: u64) -> Image {
    let mut rng = Rng::new(seed);
    let mut img = Image::new(width, height);
    for p in img.pixels.iter_mut() {
        *p = (mean + sigma * rng.next_gaussian()).clamp(0.0, 255.0) as u8;
    }
    img
}

/// Additive Gaussian noise (σ in pixel units), clamped.
pub fn add_gaussian_noise(img: &Image, sigma: f64, seed: u64) -> Image {
    let mut rng = Rng::new(seed);
    let pixels = img
        .pixels
        .iter()
        .map(|&p| (p as f64 + sigma * rng.next_gaussian()).clamp(0.0, 255.0) as u8)
        .collect();
    Image { width: img.width, height: img.height, pixels }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pgm_roundtrip() {
        let img = synthetic_photo(37, 23, 5);
        let dir = std::env::temp_dir().join("ppc_img_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.pgm");
        img.write_pgm(&path).unwrap();
        let back = Image::read_pgm(&path).unwrap();
        assert_eq!(img, back);
    }

    #[test]
    fn synthetic_photo_covers_range() {
        let img = synthetic_photo(128, 128, 1);
        let lo = img.pixels.iter().filter(|&&p| p < 100).count();
        let hi = img.pixels.iter().filter(|&&p| p > 156).count();
        assert!(lo > 500 && hi > 500, "histogram too narrow: lo={lo} hi={hi}");
    }

    #[test]
    fn noise_changes_pixels_psnr_reasonable() {
        let img = synthetic_photo(64, 64, 2);
        let noisy = add_gaussian_noise(&img, 10.0, 3);
        let psnr = img.psnr(&noisy);
        assert!(psnr > 20.0 && psnr < 35.0, "psnr={psnr}");
    }

    #[test]
    fn tensor_round_trip_and_non_square() {
        let img = synthetic_photo(24, 10, 3); // width 24, height 10
        let t = img.to_tensor();
        assert_eq!(t.shape, vec![10, 24]);
        assert_eq!(Image::from_tensor(&t, "t").unwrap(), img);
        // rank-1 square fallback (legacy flat convention)
        let sq = synthetic_photo(8, 8, 4);
        let flat = Tensor::vector(sq.pixels.iter().map(|&p| p as i32).collect());
        assert_eq!(Image::from_tensor(&flat, "sq").unwrap(), sq);
        // flat non-square is a structured error
        assert!(Image::from_tensor(&Tensor::vector(vec![0; 240]), "bad").is_err());
        // out-of-range pixel
        let t2 = Tensor::matrix(1, 2, vec![0, 300]).unwrap();
        assert!(Image::from_tensor(&t2, "px").is_err());
    }

    #[test]
    fn clamped_fetch() {
        let mut img = Image::new(4, 4);
        img.set(0, 0, 77);
        assert_eq!(img.get_clamped(-3, -3), 77);
        img.set(3, 3, 99);
        assert_eq!(img.get_clamped(10, 10), 99);
    }

    #[test]
    fn gaussian_histogram_stats() {
        let img = gaussian_histogram_image(128, 128, 128.0, 40.0, 7);
        let mean: f64 =
            img.pixels.iter().map(|&p| p as f64).sum::<f64>() / img.pixels.len() as f64;
        assert!((mean - 128.0).abs() < 3.0, "mean={mean}");
    }
}
