//! # Partially-Precise Computing (PPC)
//!
//! Reproduction of *"Partially-Precise Computing Paradigm for Efficient
//! Hardware Implementation of Application-Specific Embedded Systems"*.
//!
//! A PPC block is an adder/multiplier that is only required to be correct
//! on the task-relevant subset of its input space; omitted inputs become
//! don't-cares that the synthesis flow exploits. This crate carries:
//!
//! - [`logic`] — the full synthesis substrate (truth tables, ISOP +
//!   Espresso-style two-level minimization, algebraic factoring, AIG,
//!   technology mapping onto a 90 nm-flavored cell library, gate-level
//!   netlists with area/delay/power reports, a bit-parallel interpreted
//!   evaluator, and a levelized compiled tape serving up to 256 lanes
//!   per pass),
//! - `ppc` — the paper's contribution (DS/TH preprocessings, PPC block
//!   generators, closed-form + exhaustive error analysis, the Fig. 3
//!   design flow, and executable synthesized units),
//! - `apps` — the three applications (Gaussian denoising filter, image
//!   blending, face-recognition NN) in bit-accurate fixed point, each
//!   with a netlist-backed hardware simulator that is bit-exact with
//!   the arithmetic path,
//! - [`catalog`] — the typed model catalog (`ModelKey`, shape-carrying
//!   `Tensor`s, the `Datapath` trait) that routing, registration and
//!   CLI parsing all share,
//! - [`runtime`] + [`coordinator`] — the serving stack behind the
//!   `Executor` trait: a lane-batched, sharded pipeline where whole
//!   `ModelKey` batches are the unit of work (deadline-aware admission
//!   gate — in-flight cap, per-key fair share, reject/wait/degrade
//!   overload policy → dynamic batcher →
//!   sticky-placed `EnginePool` shard — each shard builds only its
//!   assigned model subset, spill traffic lazily registers from the
//!   shared cache → `Datapath::exec_batch` packing
//!   up to 64 requests into the bit-sliced netlist evaluator). Two
//!   backends: the default **native** backend executes the synthesized
//!   PPC netlists themselves (bit-parallel, fully offline — no Python
//!   or XLA anywhere, with a persistent BLIF netlist cache for instant
//!   cold starts), and the `pjrt` cargo feature adds the AOT-compiled
//!   JAX/Pallas artifact path,
//! - [`net`] — the wire boundary: length-prefixed JSON framing with
//!   typed rejections (`net::proto`), the threaded TCP front door in
//!   front of the coordinator (`serve --listen`), and the open-loop
//!   multi-client load generator (`loadgen`) whose percentiles stay
//!   honest under coordinated omission — all on `std::net`, no new
//!   dependencies,
//! - [`util`] — offline-friendly stand-ins for rand/serde/rayon/clap/
//!   criterion/proptest (plus the in-tree `vendor/anyhow`).
//!
//! ## Build matrix
//!
//! | build | backends | network needed |
//! |---|---|---|
//! | `cargo build` (default) | native netlist executor | none |
//! | `cargo build --features pjrt` | native + PJRT artifacts | none (needs the vendored `xla` crate on disk) |

pub mod apps;
pub mod catalog;
pub mod coordinator;
pub mod logic;
pub mod net;
pub mod ppc;
pub mod runtime;
pub mod tables;
pub mod util;
