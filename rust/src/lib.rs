//! # Partially-Precise Computing (PPC)
//!
//! Reproduction of *"Partially-Precise Computing Paradigm for Efficient
//! Hardware Implementation of Application-Specific Embedded Systems"*.
//!
//! A PPC block is an adder/multiplier that is only required to be correct
//! on the task-relevant subset of its input space; omitted inputs become
//! don't-cares that the synthesis flow exploits. This crate carries:
//!
//! - [`logic`] — the full synthesis substrate (truth tables, ISOP +
//!   Espresso-style two-level minimization, algebraic factoring, AIG,
//!   technology mapping onto a 90 nm-flavored cell library, gate-level
//!   netlists with area/delay/power reports),
//! - `ppc` — the paper's contribution (DS/TH preprocessings, PPC block
//!   generators, closed-form + exhaustive error analysis, the Fig. 3
//!   design flow),
//! - `apps` — the three applications (Gaussian denoising filter, image
//!   blending, face-recognition NN) in bit-accurate fixed point,
//! - [`runtime`] + [`coordinator`] — the embedded-inference runtime that
//!   loads the AOT-compiled JAX/Pallas artifacts and serves batched
//!   requests (python never runs on the request path),
//! - [`util`] — offline-friendly stand-ins for rand/serde/rayon/clap/
//!   criterion/proptest.

pub mod apps;
pub mod coordinator;
pub mod logic;
pub mod ppc;
pub mod runtime;
pub mod tables;
pub mod util;
