//! Sticky model placement: which engine shards own which [`ModelKey`]s.
//!
//! The paper's premise is that an application-specific deployment
//! serves a *fixed, predefined* model set, so the serving topology can
//! be specialized too: instead of replicating the whole catalog on
//! every shard (memory and warm-start cost × `shards`), a [`Placement`]
//! assigns each key to a small subset of shards — its *replicas* — and
//! the [`crate::coordinator::EnginePool`] routes that key's batches
//! sticky-first to the least-loaded replica.
//!
//! The default assignment is a deterministic rendezvous
//! (highest-random-weight) hash spread: every `(key, shard)` pair gets
//! a score from an FNV-1a hash of the key's canonical string and the
//! shard index, and the key lands on its top-`replicas` shards. The
//! spread is stable under re-runs (no RNG, no global state), balanced
//! to within the usual consistent-hashing slack, and individual keys
//! can be pinned explicitly with [`Placement::assign`] (CLI:
//! `serve --placement key=shard+shard,...`).
//!
//! Placement is a *routing preference*, not a capability boundary: a
//! shard asked for a key outside its subset — spill when every replica
//! is past [`Placement::spill_threshold`] queued batches, or failover
//! after a replica shard failed to build — lazily registers the model
//! instead of erroring (see [`crate::runtime::NativeExecutor`]); with
//! the shared netlist cache attached that is a BLIF load, without one
//! it is a full synthesis run on the shard thread.

use crate::catalog::ModelKey;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::fmt;

/// Queued-batch depth on a key's best replica above which the pool
/// spills the batch to the globally least-loaded shard.
pub const DEFAULT_SPILL_THRESHOLD: usize = 4;

/// A sticky assignment of model keys to engine-shard subsets.
#[derive(Clone, Debug)]
pub struct Placement {
    shards: usize,
    replicas: usize,
    spill_threshold: usize,
    assignments: BTreeMap<ModelKey, Vec<usize>>,
}

impl Placement {
    /// Spread `keys` over `shards` shards with `replicas` copies each
    /// (clamped to `1..=shards`), by bounded-load rendezvous hashing:
    /// each key prefers its highest-scoring shards, but no shard takes
    /// more than `ceil(keys·replicas / shards)` models, so the spread
    /// is both sticky under catalog changes and never lopsided (6
    /// models over 4 shards with one replica means every shard builds
    /// at most 2 datapaths).
    pub fn spread(keys: &[ModelKey], shards: usize, replicas: usize) -> Placement {
        let shards = shards.max(1);
        let replicas = replicas.clamp(1, shards);
        let cap = (keys.len() * replicas).div_ceil(shards).max(1);
        let mut load = vec![0usize; shards];
        let mut assignments: BTreeMap<ModelKey, Vec<usize>> = BTreeMap::new();
        for &key in keys {
            if assignments.contains_key(&key) {
                continue; // duplicate input key
            }
            let mut ranked: Vec<(u64, usize)> =
                (0..shards).map(|s| (rendezvous_score(key, s), s)).collect();
            // highest score first; shard index breaks (improbable) ties
            ranked.sort_by(|a, b| b.cmp(a));
            let mut picked: Vec<usize> = Vec::with_capacity(replicas);
            // honor the hash ranking among shards still under the cap…
            for &(_, s) in &ranked {
                if picked.len() == replicas {
                    break;
                }
                if load[s] < cap {
                    picked.push(s);
                    load[s] += 1;
                }
            }
            // …and overflow in ranking order if every shard is full
            for &(_, s) in &ranked {
                if picked.len() == replicas {
                    break;
                }
                if !picked.contains(&s) {
                    picked.push(s);
                    load[s] += 1;
                }
            }
            picked.sort_unstable();
            assignments.insert(key, picked);
        }
        Placement { shards, replicas, spill_threshold: DEFAULT_SPILL_THRESHOLD, assignments }
    }

    /// Change the spill threshold (queued batches on the best replica
    /// before a batch overflows to the least-loaded non-replica shard).
    pub fn with_spill_threshold(mut self, threshold: usize) -> Placement {
        self.spill_threshold = threshold.max(1);
        self
    }

    /// Pin `key` to an explicit shard set, overriding the hash spread.
    /// The key must be part of this placement's catalog (it got a
    /// spread assignment) — a typo'd `--placement` key fails here
    /// instead of silently dooming the pinned shard's subset build.
    pub fn assign(mut self, key: ModelKey, shards: &[usize]) -> Result<Placement> {
        if !self.assignments.contains_key(&key) {
            bail!(
                "{key}: not in the placed catalog (placed models: {})",
                crate::catalog::join(self.assignments.keys())
            );
        }
        if shards.is_empty() {
            bail!("{key}: placement override needs at least one shard");
        }
        for &s in shards {
            if s >= self.shards {
                bail!(
                    "{key}: shard {s} out of range (pool has {} shards)",
                    self.shards
                );
            }
        }
        let mut sorted = shards.to_vec();
        sorted.sort_unstable();
        sorted.dedup();
        self.assignments.insert(key, sorted);
        Ok(self)
    }

    /// Apply CLI overrides of the form `key=shard+shard,key=shard,...`
    /// (e.g. `gdf/ds16=0+2,blend/ds32=1`).
    pub fn with_overrides(mut self, spec: &str) -> Result<Placement> {
        for entry in spec.split(',').filter(|e| !e.trim().is_empty()) {
            let (key, shards) = entry
                .trim()
                .split_once('=')
                .ok_or_else(|| anyhow!("placement override {entry:?} must be key=shard+shard"))?;
            let key = ModelKey::parse(key.trim())?;
            let shards: Vec<usize> = shards
                .split('+')
                .map(|s| {
                    s.trim()
                        .parse::<usize>()
                        .map_err(|_| anyhow!("{key}: bad shard index {s:?}"))
                })
                .collect::<Result<_>>()?;
            self = self.assign(key, &shards)?;
        }
        Ok(self)
    }

    pub fn shards(&self) -> usize {
        self.shards
    }

    pub fn replicas(&self) -> usize {
        self.replicas
    }

    pub fn spill_threshold(&self) -> usize {
        self.spill_threshold
    }

    /// The replica shard set of `key` (`None` for unplaced keys, which
    /// route least-loaded like an unplaced pool).
    pub fn shards_of(&self, key: ModelKey) -> Option<&[usize]> {
        self.assignments.get(&key).map(|v| v.as_slice())
    }

    /// The keys assigned to `shard` — what that shard builds eagerly.
    pub fn keys_for(&self, shard: usize) -> Vec<ModelKey> {
        self.assignments
            .iter()
            .filter(|(_, shards)| shards.contains(&shard))
            .map(|(&k, _)| k)
            .collect()
    }

    /// Every `(key, shard set)` pair, in catalog order.
    pub fn iter(&self) -> impl Iterator<Item = (ModelKey, &[usize])> {
        self.assignments.iter().map(|(&k, v)| (k, v.as_slice()))
    }

    /// Render a shard set as the CLI/report spelling (`0+2`).
    pub fn render_shards(shards: &[usize]) -> String {
        shards
            .iter()
            .map(|s| s.to_string())
            .collect::<Vec<_>>()
            .join("+")
    }
}

impl fmt::Display for Placement {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for (i, (key, shards)) in self.iter().enumerate() {
            if i > 0 {
                f.write_str(", ")?;
            }
            write!(f, "{key}={}", Placement::render_shards(shards))?;
        }
        Ok(())
    }
}

/// FNV-1a over the key's canonical spelling and the shard index — the
/// rendezvous weight of placing `key` on `shard`.
fn rendezvous_score(key: ModelKey, shard: usize) -> u64 {
    fnv_avalanche(key.to_string().bytes().chain([b'#']).chain((shard as u64).to_le_bytes()))
}

/// The same FNV-1a + avalanche mix, over arbitrary label bytes. Shared
/// by the shard-level scores above and the node-level ring below.
fn fnv_avalanche(bytes: impl Iterator<Item = u8>) -> u64 {
    const OFFSET: u64 = 0xcbf29ce484222325;
    const PRIME: u64 = 0x100000001b3;
    let mut h = OFFSET;
    for b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(PRIME);
    }
    // final avalanche so near-identical labels decorrelate
    h ^= h >> 33;
    h = h.wrapping_mul(0xff51afd7ed558ccd);
    h ^ (h >> 33)
}

/// Rendezvous weight of `(node, slot)` for `key`: the multi-node ring
/// scores every node through `slots_per_node` virtual `(node, shard)`
/// slots (a node's weight is its best slot), hashing the node *name* —
/// not its index — so membership changes never reshuffle the survivors.
pub fn rendezvous_node_score(key: ModelKey, node: &str, slots_per_node: usize) -> u64 {
    (0..slots_per_node.max(1))
        .map(|slot| {
            fnv_avalanche(
                key.to_string()
                    .bytes()
                    .chain([b'#'])
                    .chain(node.bytes())
                    .chain([b'#'])
                    .chain((slot as u64).to_le_bytes()),
            )
        })
        .max()
        .expect("at least one slot")
}

/// Rank `nodes` for `key`, best owner first: indices into `nodes` in
/// descending [`rendezvous_node_score`] order (node name breaks the
/// improbable score tie, so every member computes the same order from
/// the same membership list regardless of how it was collected).
///
/// This is the cluster ownership rule: `nodes[rank[0]]` owns `key`,
/// and the tail is the retry-on-next-replica order when the owner is
/// down. Because scores hash node names, adding or removing a member
/// moves only the keys that member wins — the rendezvous-stability
/// property the membership tests pin down.
pub fn rank_nodes(key: ModelKey, nodes: &[String], slots_per_node: usize) -> Vec<usize> {
    let mut ranked: Vec<usize> = (0..nodes.len()).collect();
    let scores: Vec<u64> =
        nodes.iter().map(|n| rendezvous_node_score(key, n, slots_per_node)).collect();
    ranked.sort_by(|&a, &b| scores[b].cmp(&scores[a]).then_with(|| nodes[a].cmp(&nodes[b])));
    ranked
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(s: &str) -> ModelKey {
        ModelKey::parse(s).unwrap()
    }

    #[test]
    fn spread_is_deterministic_and_respects_replicas() {
        let keys = ModelKey::catalog();
        let a = Placement::spread(&keys, 4, 2);
        let b = Placement::spread(&keys, 4, 2);
        for key in &keys {
            let sa = a.shards_of(*key).unwrap();
            assert_eq!(sa, b.shards_of(*key).unwrap(), "{key} moved between runs");
            assert_eq!(sa.len(), 2, "{key} wants 2 replicas");
            assert!(sa.iter().all(|&s| s < 4));
            assert!(sa.windows(2).all(|w| w[0] < w[1]), "sorted, deduped");
        }
    }

    #[test]
    fn spread_is_load_bounded() {
        // 9 keys × 1 replica over 3 shards: the load cap forces an
        // exactly even split
        let keys = ModelKey::catalog();
        let p = Placement::spread(&keys, 3, 1);
        let counts: Vec<usize> = (0..3).map(|s| p.keys_for(s).len()).collect();
        assert_eq!(counts, vec![3, 3, 3], "cap = ceil(9/3) bounds every shard");
        // 6 keys × 1 replica over 4 shards (the acceptance shape): no
        // shard ever builds more than ceil(6/4) = 2 datapaths
        let six = &keys[..6];
        let p = Placement::spread(six, 4, 1);
        let counts: Vec<usize> = (0..4).map(|s| p.keys_for(s).len()).collect();
        assert_eq!(counts.iter().sum::<usize>(), 6);
        assert!(counts.iter().all(|&c| c <= 2), "{counts:?}");
        // replicas multiply the slots but the cap still holds
        let p = Placement::spread(&keys, 3, 2);
        let counts: Vec<usize> = (0..3).map(|s| p.keys_for(s).len()).collect();
        assert_eq!(counts, vec![6, 6, 6]);
    }

    #[test]
    fn replicas_clamp_to_shard_count() {
        let keys = [mk("gdf/ds16")];
        let p = Placement::spread(&keys, 2, 10);
        assert_eq!(p.replicas(), 2);
        assert_eq!(p.shards_of(mk("gdf/ds16")).unwrap(), &[0, 1]);
        let p = Placement::spread(&keys, 3, 0);
        assert_eq!(p.replicas(), 1);
    }

    #[test]
    fn keys_for_inverts_shards_of() {
        let keys = ModelKey::catalog();
        let p = Placement::spread(&keys, 4, 2);
        for shard in 0..4 {
            for key in p.keys_for(shard) {
                assert!(p.shards_of(key).unwrap().contains(&shard));
            }
        }
        // every key appears under each of its shards
        let total: usize = (0..4).map(|s| p.keys_for(s).len()).sum();
        assert_eq!(total, keys.len() * 2);
    }

    #[test]
    fn overrides_pin_keys() {
        let keys = ModelKey::catalog();
        let p = Placement::spread(&keys, 4, 1)
            .with_overrides("gdf/ds16=3, blend/ds32=0+2")
            .unwrap();
        assert_eq!(p.shards_of(mk("gdf/ds16")).unwrap(), &[3]);
        assert_eq!(p.shards_of(mk("blend/ds32")).unwrap(), &[0, 2]);
        // untouched keys keep their hash spread
        assert_eq!(
            p.shards_of(mk("gdf/ds32")),
            Placement::spread(&keys, 4, 1).shards_of(mk("gdf/ds32"))
        );
    }

    #[test]
    fn bad_overrides_are_structured_errors() {
        let keys = ModelKey::catalog();
        let p = Placement::spread(&keys, 2, 1);
        assert!(p.clone().with_overrides("gdf/ds16").is_err(), "missing =");
        assert!(p.clone().with_overrides("nope/x=0").is_err(), "bad key");
        assert!(p.clone().with_overrides("gdf/ds16=9").is_err(), "shard out of range");
        assert!(p.clone().with_overrides("gdf/ds16=x").is_err(), "bad index");
        let e = p.clone().with_overrides("gdf/ds16=5").unwrap_err();
        assert!(format!("{e}").contains("out of range"), "{e}");
        // a valid catalog key that is NOT part of this placement's
        // model list is a typo'd flag, not a silent dead shard
        let narrow = Placement::spread(&keys[..2], 2, 1);
        let e = narrow.with_overrides("blend/ds16=0").unwrap_err();
        assert!(format!("{e}").contains("not in the placed catalog"), "{e}");
    }

    #[test]
    fn display_renders_cli_spelling() {
        let p = Placement::spread(&[mk("gdf/ds16")], 2, 2);
        assert_eq!(format!("{p}"), "gdf/ds16=0+1");
    }

    #[test]
    fn unplaced_keys_have_no_shard_set() {
        let p = Placement::spread(&[mk("gdf/ds16")], 2, 1);
        assert!(p.shards_of(mk("blend/ds32")).is_none());
    }

    // -- node-level ring (multi-node serving) --

    fn random_members(rng: &mut crate::util::prng::Rng) -> Vec<String> {
        let n = rng.below(6) as usize + 2;
        (0..n).map(|_| format!("10.0.{}.{}:{}", rng.below(256), rng.below(256), rng.below(60000) + 1024)).collect()
    }

    #[test]
    fn node_rank_is_a_total_order_every_member_agrees_on() {
        crate::util::propcheck::forall(0xA11C, 64, random_members, |members| {
            ModelKey::catalog().iter().all(|&key| {
                let rank = rank_nodes(key, members, 8);
                // a permutation of every member: no key is ever unowned
                let mut seen = rank.clone();
                seen.sort_unstable();
                if seen != (0..members.len()).collect::<Vec<_>>() {
                    return false;
                }
                // order is a pure function of (key, names): a member
                // that collected the same membership in another order
                // ranks the same owners
                let mut shuffled: Vec<String> = members.clone();
                shuffled.rotate_left(1);
                let r2 = rank_nodes(key, &shuffled, 8);
                rank.iter().map(|&i| &members[i]).collect::<Vec<_>>()
                    == r2.iter().map(|&i| &shuffled[i]).collect::<Vec<_>>()
            })
        });
    }

    #[test]
    fn adding_a_node_moves_only_the_keys_it_wins() {
        crate::util::propcheck::forall(0x90DE, 64, random_members, |members| {
            let newcomer = "192.168.7.7:7777".to_string();
            if members.contains(&newcomer) {
                return true;
            }
            let mut grown = members.clone();
            grown.push(newcomer.clone());
            ModelKey::catalog().iter().all(|&key| {
                let before = members[rank_nodes(key, members, 8)[0]].clone();
                let after = grown[rank_nodes(key, &grown, 8)[0]].clone();
                // rendezvous stability: a key either stays put or moves
                // to the new member — never between two survivors
                after == before || after == newcomer
            })
        });
    }

    #[test]
    fn removing_a_node_moves_only_the_keys_it_owned() {
        crate::util::propcheck::forall(0xDEAD, 64, random_members, |members| {
            if members.len() < 2 {
                return true;
            }
            let gone = members[0].clone();
            let survivors: Vec<String> = members[1..].to_vec();
            ModelKey::catalog().iter().all(|&key| {
                let before = members[rank_nodes(key, members, 8)[0]].clone();
                let after = survivors[rank_nodes(key, &survivors, 8)[0]].clone();
                if before == gone {
                    // the departed member's keys land on its next
                    // replica in the old ranking — exactly the
                    // retry-on-next-replica failover order
                    let old_rank = rank_nodes(key, members, 8);
                    after == members[old_rank[1]]
                } else {
                    // survivors' keys never move
                    after == before
                }
            })
        });
    }
}
