//! Coordinator metrics: request counters, per-[`ModelKey`] latency
//! records, and per-shard batch statistics (batch size, lane occupancy,
//! batch latency, peak queue depth). Shared across threads behind a
//! mutex (request rates here are far below contention territory; the
//! hot path is model execution).

use crate::catalog::{ModelKey, LANES};
use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Batch-level record stream of one `(shard, model)` pair.
#[derive(Default)]
struct BatchStats {
    /// Requests per flushed batch.
    sizes: Vec<usize>,
    /// Wall-clock execution time per batch, seconds.
    latencies: Vec<f64>,
}

/// Aggregated view of one `(shard, model)` batch stream.
#[derive(Clone, Debug)]
pub struct BatchSummary {
    /// Batches executed.
    pub batches: usize,
    /// Mean requests per batch.
    pub mean_size: f64,
    /// Fraction of the 64 bit-slice lanes the mean batch fills.
    pub lane_occupancy: f64,
    /// Batch execution latency (seconds).
    pub latency: Summary,
}

#[derive(Default)]
struct Inner {
    /// Per model key: end-to-end request latencies in seconds.
    latencies: BTreeMap<ModelKey, Vec<f64>>,
    submitted: u64,
    completed: u64,
    rejected: u64,
    errors: u64,
    /// Per (shard, model): batch execution records.
    batches: BTreeMap<(usize, ModelKey), BatchStats>,
    /// Per shard: peak queued-batch depth observed at submit time.
    peak_depth: BTreeMap<usize, usize>,
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// One request accepted into the pipeline (the backpressure
    /// boundary counts `submitted − completed − errors` as in-flight).
    pub fn record_submitted(&self) {
        self.inner.lock().unwrap().submitted += 1;
    }

    /// Requests currently somewhere between submit and reply.
    pub fn in_flight(&self) -> u64 {
        let m = self.inner.lock().unwrap();
        m.submitted.saturating_sub(m.completed + m.errors)
    }

    /// One completed request for `key`, end-to-end latency `d`.
    pub fn record_latency(&self, key: ModelKey, d: Duration) {
        let mut m = self.inner.lock().unwrap();
        m.completed += 1;
        m.latencies.entry(key).or_default().push(d.as_secs_f64());
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// One batch of `size` requests executed on `shard` for `key` in
    /// `latency` wall-clock time.
    pub fn record_batch(&self, shard: usize, key: ModelKey, size: usize, latency: Duration) {
        let mut m = self.inner.lock().unwrap();
        let s = m.batches.entry((shard, key)).or_default();
        s.sizes.push(size);
        s.latencies.push(latency.as_secs_f64());
    }

    /// Queue depth observed on `shard` when a batch was routed to it
    /// (the peak is reported).
    pub fn record_queue_depth(&self, shard: usize, depth: usize) {
        let mut m = self.inner.lock().unwrap();
        let d = m.peak_depth.entry(shard).or_default();
        *d = (*d).max(depth);
    }

    pub fn completed(&self) -> u64 {
        self.inner.lock().unwrap().completed
    }

    pub fn rejected(&self) -> u64 {
        self.inner.lock().unwrap().rejected
    }

    pub fn errors(&self) -> u64 {
        self.inner.lock().unwrap().errors
    }

    /// Mean requests per executed batch, across every shard and model.
    pub fn mean_batch_size(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        let (mut n, mut total) = (0usize, 0usize);
        for s in m.batches.values() {
            n += s.sizes.len();
            total += s.sizes.iter().sum::<usize>();
        }
        if n == 0 {
            0.0
        } else {
            total as f64 / n as f64
        }
    }

    /// Mean fraction of the 64 bit-slice lanes a batch fills
    /// (`mean_batch_size / LANES`, capped at 1).
    pub fn lane_occupancy(&self) -> f64 {
        (self.mean_batch_size() / LANES as f64).min(1.0)
    }

    /// Per-model end-to-end latency summaries (seconds).
    pub fn latency_summaries(&self) -> BTreeMap<ModelKey, Summary> {
        let m = self.inner.lock().unwrap();
        m.latencies
            .iter()
            .map(|(k, v)| (*k, Summary::of(v.clone())))
            .collect()
    }

    /// Per-(shard, model) batch summaries.
    pub fn batch_summaries(&self) -> BTreeMap<(usize, ModelKey), BatchSummary> {
        let m = self.inner.lock().unwrap();
        m.batches
            .iter()
            .map(|(k, s)| {
                let mean_size = if s.sizes.is_empty() {
                    0.0
                } else {
                    s.sizes.iter().sum::<usize>() as f64 / s.sizes.len() as f64
                };
                (
                    *k,
                    BatchSummary {
                        batches: s.sizes.len(),
                        mean_size,
                        lane_occupancy: (mean_size / LANES as f64).min(1.0),
                        latency: Summary::of(s.latencies.clone()),
                    },
                )
            })
            .collect()
    }

    /// Peak queued-batch depth seen per shard.
    pub fn peak_queue_depths(&self) -> BTreeMap<usize, usize> {
        self.inner.lock().unwrap().peak_depth.clone()
    }

    /// Human-readable report block.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "completed={} rejected={} errors={} mean_batch={:.2} lane_occupancy={:.1}%\n",
            self.completed(),
            self.rejected(),
            self.errors(),
            self.mean_batch_size(),
            self.lane_occupancy() * 100.0
        ));
        for (route, sum) in self.latency_summaries() {
            s.push_str(&format!(
                "  {:<16} n={:<6} mean={:.3}ms p50={:.3}ms p99={:.3}ms\n",
                route.to_string(),
                sum.n,
                sum.mean * 1e3,
                sum.p50 * 1e3,
                sum.p99 * 1e3
            ));
        }
        let depths = self.peak_queue_depths();
        for ((shard, key), b) in self.batch_summaries() {
            s.push_str(&format!(
                "  shard{shard} {:<14} batches={:<5} mean_batch={:<5.1} \
                 occ={:.0}% batch_p50={:.3}ms peak_depth={}\n",
                key.to_string(),
                b.batches,
                b.mean_size,
                b.lane_occupancy * 100.0,
                b.latency.p50 * 1e3,
                depths.get(&shard).copied().unwrap_or(0)
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(s: &str) -> ModelKey {
        ModelKey::parse(s).unwrap()
    }

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.record_latency(mk("gdf/conv"), Duration::from_millis(2));
        m.record_latency(mk("gdf/conv"), Duration::from_millis(4));
        m.record_batch(0, mk("gdf/conv"), 8, Duration::from_millis(3));
        m.record_rejected();
        assert_eq!(m.completed(), 2);
        assert_eq!(m.rejected(), 1);
        assert_eq!(m.mean_batch_size(), 8.0);
        assert!((m.lane_occupancy() - 8.0 / 64.0).abs() < 1e-12);
        let sums = m.latency_summaries();
        assert!((sums[&mk("gdf/conv")].mean - 0.003).abs() < 1e-9);
        assert!(m.report().contains("gdf/conv"));
    }

    #[test]
    fn per_shard_batch_stats_partition() {
        let m = Metrics::new();
        m.record_batch(0, mk("gdf/ds16"), 4, Duration::from_millis(1));
        m.record_batch(1, mk("gdf/ds16"), 8, Duration::from_millis(2));
        m.record_batch(1, mk("frnn/ds32"), 2, Duration::from_millis(1));
        m.record_queue_depth(1, 3);
        m.record_queue_depth(1, 1);
        let b = m.batch_summaries();
        assert_eq!(b.len(), 3);
        assert_eq!(b[&(0, mk("gdf/ds16"))].batches, 1);
        assert_eq!(b[&(1, mk("gdf/ds16"))].mean_size, 8.0);
        assert!((b[&(1, mk("gdf/ds16"))].lane_occupancy - 0.125).abs() < 1e-12);
        assert_eq!(m.peak_queue_depths()[&1], 3);
        // mean over all batches: (4 + 8 + 2) / 3
        assert!((m.mean_batch_size() - 14.0 / 3.0).abs() < 1e-12);
        let rep = m.report();
        assert!(rep.contains("shard0"), "{rep}");
        assert!(rep.contains("shard1"), "{rep}");
    }
}
