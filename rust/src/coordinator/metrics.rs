//! Coordinator metrics: request counters, latency records, batch-size
//! histogram. Shared across threads behind a mutex (request rates here
//! are far below contention territory; the hot path is model execution).

use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

#[derive(Default)]
struct Inner {
    /// per route ("gdf/ds16"): latencies in seconds
    latencies: BTreeMap<String, Vec<f64>>,
    completed: u64,
    rejected: u64,
    errors: u64,
    batch_sizes: Vec<usize>,
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    pub fn record_latency(&self, route: &str, d: Duration) {
        let mut m = self.inner.lock().unwrap();
        m.completed += 1;
        m.latencies.entry(route.to_string()).or_default().push(d.as_secs_f64());
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    pub fn record_batch(&self, size: usize) {
        self.inner.lock().unwrap().batch_sizes.push(size);
    }

    pub fn completed(&self) -> u64 {
        self.inner.lock().unwrap().completed
    }

    pub fn rejected(&self) -> u64 {
        self.inner.lock().unwrap().rejected
    }

    pub fn errors(&self) -> u64 {
        self.inner.lock().unwrap().errors
    }

    pub fn mean_batch_size(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        if m.batch_sizes.is_empty() {
            0.0
        } else {
            m.batch_sizes.iter().sum::<usize>() as f64 / m.batch_sizes.len() as f64
        }
    }

    /// Per-route latency summaries (seconds).
    pub fn latency_summaries(&self) -> BTreeMap<String, Summary> {
        let m = self.inner.lock().unwrap();
        m.latencies
            .iter()
            .map(|(k, v)| (k.clone(), Summary::of(v.clone())))
            .collect()
    }

    /// Human-readable report block.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "completed={} rejected={} errors={} mean_batch={:.2}\n",
            self.completed(),
            self.rejected(),
            self.errors(),
            self.mean_batch_size()
        ));
        for (route, sum) in self.latency_summaries() {
            s.push_str(&format!(
                "  {route:<16} n={:<6} mean={:.3}ms p50={:.3}ms p99={:.3}ms\n",
                sum.n,
                sum.mean * 1e3,
                sum.p50 * 1e3,
                sum.p99 * 1e3
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.record_latency("gdf/conv", Duration::from_millis(2));
        m.record_latency("gdf/conv", Duration::from_millis(4));
        m.record_batch(8);
        m.record_rejected();
        assert_eq!(m.completed(), 2);
        assert_eq!(m.rejected(), 1);
        assert_eq!(m.mean_batch_size(), 8.0);
        let sums = m.latency_summaries();
        assert!((sums["gdf/conv"].mean - 0.003).abs() < 1e-9);
        assert!(m.report().contains("gdf/conv"));
    }
}
