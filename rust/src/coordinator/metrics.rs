//! Coordinator metrics: request counters, per-[`ModelKey`] latency
//! records, per-shard batch statistics (batch size, lane occupancy,
//! degraded batches, queue-wait and execute latency, peak queue depth),
//! and sticky-
//! placement accounting (per-key shard sets and spill counts). Shared
//! across threads behind a mutex (request rates here are far below
//! contention territory; the hot path is model execution).

use crate::catalog::{ModelKey, Quality, LANES};
use crate::util::stats::Summary;
use std::collections::BTreeMap;
use std::sync::Mutex;
use std::time::Duration;

/// Where a request's deadline expiry was detected.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum ExpiredAt {
    /// At the admission gate — already expired on arrival, or expired
    /// while waiting for capacity. The request never entered the
    /// pipeline (it was never counted as submitted).
    Admission,
    /// In the batcher queue: dropped before lane-packing.
    Queue,
    /// On a shard: the batch was dispatched but the deadline passed
    /// before execution.
    Shard,
}

/// Admission-wait sample window: the gate records one wait per admitted
/// request, so the sample store is a bounded ring (most recent wins)
/// instead of an ever-growing Vec.
pub const WAIT_SAMPLES: usize = 4096;

/// Fraction of the bit-slice lanes a batch of `size` requests fills,
/// over the compiled-tape passes it actually needs: a 257-request batch
/// takes two 256-lane words and fills 257/512 of them — not 100%.
pub fn occupancy(size: usize) -> f64 {
    if size == 0 {
        return 0.0;
    }
    let words = size.div_ceil(LANES);
    size as f64 / (words * LANES) as f64
}

/// Batch-level record stream of one `(shard, model)` pair.
#[derive(Default)]
struct BatchStats {
    /// Requests per flushed batch.
    sizes: Vec<usize>,
    /// Seconds the batch's longest-waiting request sat queued before
    /// the shard picked the batch up.
    queue_waits: Vec<f64>,
    /// Wall-clock execution time per batch, seconds (dispatch → reply).
    executes: Vec<f64>,
    /// Batches that fell back to the per-request scalar retry.
    degraded: usize,
}

/// Aggregated view of one `(shard, model)` batch stream.
#[derive(Clone, Debug)]
pub struct BatchSummary {
    /// Batches executed.
    pub batches: usize,
    /// Mean requests per batch.
    pub mean_size: f64,
    /// Mean fraction of the needed 256-lane words each batch fills.
    pub lane_occupancy: f64,
    /// Batches that degraded to the per-request retry path.
    pub degraded: usize,
    /// Time the batch's oldest request waited in the queue (seconds) —
    /// the batcher/queueing share of per-batch latency.
    pub queue_wait: Summary,
    /// Batch execution latency (seconds) — the datapath share.
    pub execute: Summary,
}

#[derive(Default)]
struct Inner {
    /// Per model key: end-to-end request latencies in seconds.
    latencies: BTreeMap<ModelKey, Vec<f64>>,
    submitted: u64,
    completed: u64,
    rejected: u64,
    errors: u64,
    /// Per (shard, model, served tier): batch execution records. The
    /// tier is the quality the batch was *served at* (the routed key's
    /// tier), not the one requested — degraded work must not pollute
    /// the original tier's latency stream, because the quality
    /// autopilot steers on exactly these per-tier signals.
    batches: BTreeMap<(usize, ModelKey, Quality), BatchStats>,
    /// Per shard: peak queued-batch depth observed at submit time.
    peak_depth: BTreeMap<usize, usize>,
    /// Sticky placement: each placed key's replica shard set.
    placements: BTreeMap<ModelKey, Vec<usize>>,
    /// Batches routed off their replica set (spill or failover).
    spills: BTreeMap<ModelKey, u64>,
    /// Batches routed through the pool (spill-rate denominator).
    routed: u64,
    /// Requests shed at the admission gate, per requested key (these
    /// never entered the pipeline).
    shed: BTreeMap<ModelKey, u64>,
    /// Deadline expiries, per (key, detection stage). Admission-stage
    /// expiries are keyed by the *requested* key; queue/shard-stage
    /// expiries by the *routed* (possibly degraded) key — past the
    /// gate, the routed key is the request's identity.
    expired: BTreeMap<(ModelKey, ExpiredAt), u64>,
    /// Overload degrades, per (requested key, degraded-to key).
    degrades: BTreeMap<(ModelKey, ModelKey), u64>,
    /// Seconds admitted requests waited at the gate for capacity — a
    /// sliding window of the most recent [`WAIT_SAMPLES`] admits (one
    /// sample lands here per admission, so an unbounded Vec would grow
    /// forever on a long-running server).
    admission_waits: Vec<f64>,
    /// Total admits recorded (ring cursor for `admission_waits`).
    wait_cursor: usize,
    /// High-water mark of concurrently admitted (permit-holding)
    /// requests — the observable proof the in-flight cap held.
    peak_in_flight: u64,
    /// TCP front-door connections ever accepted.
    net_conns_opened: u64,
    /// TCP front-door connections fully drained and closed.
    net_conns_closed: u64,
    /// High-water mark of concurrently open connections.
    net_peak_conns: u64,
    /// Well-framed requests decoded off the wire.
    net_frames_in: u64,
    /// Frames written back to clients (responses, rejections, errors).
    net_frames_out: u64,
    /// Wire-protocol violations observed (malformed / oversized /
    /// truncated frames).
    net_protocol_errors: u64,
    /// Requests this front door relayed to the owning peer.
    forwards_out: u64,
    /// `Forward` frames served locally on behalf of a peer front door.
    forwards_in: u64,
    /// Forward attempts abandoned for the next candidate peer.
    forward_retries: u64,
    /// Forwards that exhausted every candidate and fell back to local
    /// serving (or a typed rejection when the key is not local).
    forward_fallbacks: u64,
}

/// Thread-safe metrics sink.
#[derive(Default)]
pub struct Metrics {
    inner: Mutex<Inner>,
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics::default()
    }

    /// One request accepted into the pipeline (admitted by the gate and
    /// queued for dispatch).
    pub fn record_submitted(&self) {
        self.inner.lock().unwrap().submitted += 1;
    }

    /// Requests currently somewhere between submit and reply (every
    /// submitted request resolves as exactly one of completed, error,
    /// or post-admission deadline expiry).
    pub fn in_flight(&self) -> u64 {
        let m = self.inner.lock().unwrap();
        let expired_in_pipeline: u64 = m
            .expired
            .iter()
            .filter(|((_, at), _)| *at != ExpiredAt::Admission)
            .map(|(_, &n)| n)
            .sum();
        m.submitted.saturating_sub(m.completed + m.errors + expired_in_pipeline)
    }

    /// One completed request for `key`, end-to-end latency `d`.
    pub fn record_latency(&self, key: ModelKey, d: Duration) {
        let mut m = self.inner.lock().unwrap();
        m.completed += 1;
        m.latencies.entry(key).or_default().push(d.as_secs_f64());
    }

    pub fn record_rejected(&self) {
        self.inner.lock().unwrap().rejected += 1;
    }

    pub fn record_error(&self) {
        self.inner.lock().unwrap().errors += 1;
    }

    /// One request shed at the admission gate for `key` (over capacity
    /// under the active overload policy). Sheds also count as rejected
    /// — the legacy backpressure counter.
    pub fn record_shed(&self, key: ModelKey) {
        let mut m = self.inner.lock().unwrap();
        m.rejected += 1;
        *m.shed.entry(key).or_default() += 1;
    }

    /// One deadline expiry for `key`, detected `at` the given stage.
    pub fn record_expired(&self, key: ModelKey, at: ExpiredAt) {
        *self.inner.lock().unwrap().expired.entry((key, at)).or_default() += 1;
    }

    /// One overload degrade: a request for `from` admitted at the
    /// lower-tier `to` instead.
    pub fn record_degrade(&self, from: ModelKey, to: ModelKey) {
        *self.inner.lock().unwrap().degrades.entry((from, to)).or_default() += 1;
    }

    /// How long one admitted request waited at the gate for capacity.
    /// Kept as a sliding window of the last [`WAIT_SAMPLES`] admits.
    pub fn record_admission_wait(&self, d: Duration) {
        let mut m = self.inner.lock().unwrap();
        let v = d.as_secs_f64();
        let i = m.wait_cursor;
        m.wait_cursor = m.wait_cursor.wrapping_add(1);
        if m.admission_waits.len() < WAIT_SAMPLES {
            m.admission_waits.push(v);
        } else {
            m.admission_waits[i % WAIT_SAMPLES] = v;
        }
    }

    /// The number of permits held right after an admission — the peak
    /// is the observed in-flight high-water mark.
    pub fn record_in_flight(&self, depth: u64) {
        let mut m = self.inner.lock().unwrap();
        m.peak_in_flight = m.peak_in_flight.max(depth);
    }

    /// Observed in-flight high-water mark (never exceeds the gate cap).
    pub fn peak_in_flight(&self) -> u64 {
        self.inner.lock().unwrap().peak_in_flight
    }

    /// Requests shed at the admission gate, in total.
    pub fn shed(&self) -> u64 {
        self.inner.lock().unwrap().shed.values().sum()
    }

    /// Per-key shed counts.
    pub fn shed_counts(&self) -> BTreeMap<ModelKey, u64> {
        self.inner.lock().unwrap().shed.clone()
    }

    /// Overload degrades, in total.
    pub fn degrades(&self) -> u64 {
        self.inner.lock().unwrap().degrades.values().sum()
    }

    /// Per-(requested, degraded-to) degrade counts.
    pub fn degrade_counts(&self) -> BTreeMap<(ModelKey, ModelKey), u64> {
        self.inner.lock().unwrap().degrades.clone()
    }

    /// Deadline expiries, in total (every stage).
    pub fn expired(&self) -> u64 {
        self.inner.lock().unwrap().expired.values().sum()
    }

    /// Deadline expiries detected at one stage.
    pub fn expired_at(&self, at: ExpiredAt) -> u64 {
        let m = self.inner.lock().unwrap();
        m.expired.iter().filter(|((_, a), _)| *a == at).map(|(_, &n)| n).sum()
    }

    /// Per-key deadline-expiry totals (all stages).
    pub fn expired_counts(&self) -> BTreeMap<ModelKey, u64> {
        let m = self.inner.lock().unwrap();
        let mut out: BTreeMap<ModelKey, u64> = BTreeMap::new();
        for (&(key, _), &n) in &m.expired {
            *out.entry(key).or_default() += n;
        }
        out
    }

    /// Admission wait-for-capacity times (seconds) over the most
    /// recent [`WAIT_SAMPLES`] admits.
    pub fn admission_wait_summary(&self) -> Summary {
        Summary::of(self.inner.lock().unwrap().admission_waits.clone())
    }

    /// One batch of `size` requests executed on `shard` for `key`,
    /// served at `tier` (the routed key's tier — degraded work lands
    /// under the tier it actually ran at, keeping each tier's latency
    /// stream attributable). `queue_wait` is how long the batch's
    /// oldest request sat queued before dispatch; `execute` is the
    /// dispatch → reply wall-clock time; `degraded` marks a batch that
    /// fell back to the per-request scalar retry. Keeping the two
    /// halves separate tells a saturated datapath (execute grows)
    /// apart from a backed-up batcher (queue_wait grows) at a glance.
    #[allow(clippy::too_many_arguments)]
    pub fn record_batch(
        &self,
        shard: usize,
        key: ModelKey,
        tier: Quality,
        size: usize,
        queue_wait: Duration,
        execute: Duration,
        degraded: bool,
    ) {
        let mut m = self.inner.lock().unwrap();
        let s = m.batches.entry((shard, key, tier)).or_default();
        s.sizes.push(size);
        s.queue_waits.push(queue_wait.as_secs_f64());
        s.executes.push(execute.as_secs_f64());
        if degraded {
            s.degraded += 1;
        }
    }

    /// Queue depth observed on `shard` when a batch was routed to it
    /// (the peak is reported).
    pub fn record_queue_depth(&self, shard: usize, depth: usize) {
        let mut m = self.inner.lock().unwrap();
        let d = m.peak_depth.entry(shard).or_default();
        *d = (*d).max(depth);
    }

    /// One batch routed through the pool — the spill-rate denominator.
    pub fn record_routed(&self) {
        self.inner.lock().unwrap().routed += 1;
    }

    /// The sticky placement the pool was spawned with (reported per
    /// key alongside spill counts).
    pub fn record_placement(&self, key: ModelKey, shards: &[usize]) {
        self.inner.lock().unwrap().placements.insert(key, shards.to_vec());
    }

    /// One batch for `key` routed off its replica shard set (queue
    /// spill or dead-shard failover).
    pub fn record_spill(&self, key: ModelKey) {
        *self.inner.lock().unwrap().spills.entry(key).or_default() += 1;
    }

    /// Requests accepted into the pipeline (admitted + queued).
    pub fn submitted(&self) -> u64 {
        self.inner.lock().unwrap().submitted
    }

    pub fn completed(&self) -> u64 {
        self.inner.lock().unwrap().completed
    }

    pub fn rejected(&self) -> u64 {
        self.inner.lock().unwrap().rejected
    }

    pub fn errors(&self) -> u64 {
        self.inner.lock().unwrap().errors
    }

    /// Batches routed off their sticky replica set, in total.
    pub fn spills(&self) -> u64 {
        self.inner.lock().unwrap().spills.values().sum()
    }

    /// Per-key spill counts.
    pub fn spill_counts(&self) -> BTreeMap<ModelKey, u64> {
        self.inner.lock().unwrap().spills.clone()
    }

    /// Per-key replica shard sets (as recorded at pool spawn).
    pub fn placements(&self) -> BTreeMap<ModelKey, Vec<usize>> {
        self.inner.lock().unwrap().placements.clone()
    }

    /// Fraction of routed batches that left their replica set.
    pub fn spill_rate(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        let spills: u64 = m.spills.values().sum();
        if m.routed == 0 {
            0.0
        } else {
            spills as f64 / m.routed as f64
        }
    }

    /// Mean requests per executed batch, across every shard and model.
    pub fn mean_batch_size(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        let (mut n, mut total) = (0usize, 0usize);
        for s in m.batches.values() {
            n += s.sizes.len();
            total += s.sizes.iter().sum::<usize>();
        }
        if n == 0 {
            0.0
        } else {
            total as f64 / n as f64
        }
    }

    /// Mean lane occupancy over every executed batch: each batch fills
    /// `size / (ceil(size/LANES)·LANES)` of the lane words it needs, so
    /// a 257-request batch reports 257/512 — not a clamped 100%.
    pub fn lane_occupancy(&self) -> f64 {
        let m = self.inner.lock().unwrap();
        let (mut n, mut total) = (0usize, 0.0f64);
        for s in m.batches.values() {
            n += s.sizes.len();
            total += s.sizes.iter().map(|&sz| occupancy(sz)).sum::<f64>();
        }
        if n == 0 {
            0.0
        } else {
            total / n as f64
        }
    }

    /// Per-model end-to-end latency summaries (seconds).
    pub fn latency_summaries(&self) -> BTreeMap<ModelKey, Summary> {
        let m = self.inner.lock().unwrap();
        m.latencies
            .iter()
            .map(|(k, v)| (*k, Summary::of(v.clone())))
            .collect()
    }

    /// Per-(shard, model, served tier) batch summaries.
    pub fn batch_summaries(&self) -> BTreeMap<(usize, ModelKey, Quality), BatchSummary> {
        let m = self.inner.lock().unwrap();
        m.batches
            .iter()
            .map(|(k, s)| {
                let n = s.sizes.len();
                let (mean_size, lane_occupancy) = if n == 0 {
                    (0.0, 0.0)
                } else {
                    (
                        s.sizes.iter().sum::<usize>() as f64 / n as f64,
                        s.sizes.iter().map(|&sz| occupancy(sz)).sum::<f64>() / n as f64,
                    )
                };
                (
                    *k,
                    BatchSummary {
                        batches: n,
                        mean_size,
                        lane_occupancy,
                        degraded: s.degraded,
                        queue_wait: Summary::of(s.queue_waits.clone()),
                        execute: Summary::of(s.executes.clone()),
                    },
                )
            })
            .collect()
    }

    /// Peak queued-batch depth seen per shard.
    pub fn peak_queue_depths(&self) -> BTreeMap<usize, usize> {
        self.inner.lock().unwrap().peak_depth.clone()
    }

    /// One front-door TCP connection accepted.
    pub fn record_conn_opened(&self) {
        let mut m = self.inner.lock().unwrap();
        m.net_conns_opened += 1;
        let active = m.net_conns_opened - m.net_conns_closed;
        m.net_peak_conns = m.net_peak_conns.max(active);
    }

    /// One front-door TCP connection drained and closed.
    pub fn record_conn_closed(&self) {
        self.inner.lock().unwrap().net_conns_closed += 1;
    }

    /// One well-framed request decoded off the wire.
    pub fn record_net_frame_in(&self) {
        self.inner.lock().unwrap().net_frames_in += 1;
    }

    /// One frame written back to a client.
    pub fn record_net_frame_out(&self) {
        self.inner.lock().unwrap().net_frames_out += 1;
    }

    /// One wire-protocol violation (malformed / oversized / truncated).
    pub fn record_net_protocol_error(&self) {
        self.inner.lock().unwrap().net_protocol_errors += 1;
    }

    /// One request relayed to the owning peer.
    pub fn record_forward_out(&self) {
        self.inner.lock().unwrap().forwards_out += 1;
    }

    /// One `Forward` frame served locally for a peer front door.
    pub fn record_forward_in(&self) {
        self.inner.lock().unwrap().forwards_in += 1;
    }

    /// One forward attempt abandoned for the next candidate peer.
    pub fn record_forward_retry(&self) {
        self.inner.lock().unwrap().forward_retries += 1;
    }

    /// One forward that exhausted its candidates and fell back.
    pub fn record_forward_fallback(&self) {
        self.inner.lock().unwrap().forward_fallbacks += 1;
    }

    /// Requests relayed to owning peers.
    pub fn forwards_out(&self) -> u64 {
        self.inner.lock().unwrap().forwards_out
    }

    /// `Forward` frames served locally for peers.
    pub fn forwards_in(&self) -> u64 {
        self.inner.lock().unwrap().forwards_in
    }

    /// Forward attempts abandoned for the next candidate.
    pub fn forward_retries(&self) -> u64 {
        self.inner.lock().unwrap().forward_retries
    }

    /// Forwards that exhausted every candidate.
    pub fn forward_fallbacks(&self) -> u64 {
        self.inner.lock().unwrap().forward_fallbacks
    }

    /// Connections ever accepted by the front door.
    pub fn net_connections(&self) -> u64 {
        self.inner.lock().unwrap().net_conns_opened
    }

    /// Connections currently open.
    pub fn net_active_connections(&self) -> u64 {
        let m = self.inner.lock().unwrap();
        m.net_conns_opened - m.net_conns_closed
    }

    /// High-water mark of concurrently open connections.
    pub fn net_peak_connections(&self) -> u64 {
        self.inner.lock().unwrap().net_peak_conns
    }

    /// Request frames decoded off the wire.
    pub fn net_frames_in(&self) -> u64 {
        self.inner.lock().unwrap().net_frames_in
    }

    /// Frames written back to clients.
    pub fn net_frames_out(&self) -> u64 {
        self.inner.lock().unwrap().net_frames_out
    }

    /// Wire-protocol violations observed.
    pub fn net_protocol_errors(&self) -> u64 {
        self.inner.lock().unwrap().net_protocol_errors
    }

    /// Human-readable report block.
    pub fn report(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "completed={} rejected={} errors={} mean_batch={:.2} lane_occupancy={:.1}%\n",
            self.completed(),
            self.rejected(),
            self.errors(),
            self.mean_batch_size(),
            self.lane_occupancy() * 100.0
        ));
        let waits = self.admission_wait_summary();
        s.push_str(&format!(
            "admission: peak_in_flight={} shed={} degraded={} expired={} \
             (admission={} queue={} shard={}) wait_p50={:.3}ms wait_p99={:.3}ms\n",
            self.peak_in_flight(),
            self.shed(),
            self.degrades(),
            self.expired(),
            self.expired_at(ExpiredAt::Admission),
            self.expired_at(ExpiredAt::Queue),
            self.expired_at(ExpiredAt::Shard),
            waits.p50 * 1e3,
            waits.p99 * 1e3
        ));
        for (key, n) in self.shed_counts() {
            s.push_str(&format!("  {:<16} shed={n}\n", key.to_string()));
        }
        for ((from, to), n) in self.degrade_counts() {
            s.push_str(&format!("  {from} -> {to} degraded={n}\n"));
        }
        for (key, n) in self.expired_counts() {
            s.push_str(&format!("  {:<16} expired={n}\n", key.to_string()));
        }
        if self.net_connections() > 0 {
            s.push_str(&format!(
                "net: conns={} (peak {} concurrent, {} open) frames_in={} \
                 frames_out={} protocol_errors={}\n",
                self.net_connections(),
                self.net_peak_connections(),
                self.net_active_connections(),
                self.net_frames_in(),
                self.net_frames_out(),
                self.net_protocol_errors()
            ));
        }
        {
            let m = self.inner.lock().unwrap();
            if m.forwards_out + m.forwards_in + m.forward_retries + m.forward_fallbacks > 0 {
                s.push_str(&format!(
                    "cluster: forwards_out={} forwards_in={} retries={} fallbacks={}\n",
                    m.forwards_out, m.forwards_in, m.forward_retries, m.forward_fallbacks
                ));
            }
        }
        let placements = self.placements();
        if !placements.is_empty() {
            let spills = self.spill_counts();
            s.push_str(&format!(
                "placement: {} keys, spill_rate={:.1}%\n",
                placements.len(),
                self.spill_rate() * 100.0
            ));
            for (key, shards) in &placements {
                s.push_str(&format!(
                    "  {:<16} shards[{}] spills={}\n",
                    key.to_string(),
                    crate::coordinator::Placement::render_shards(shards),
                    spills.get(key).copied().unwrap_or(0)
                ));
            }
        }
        for (route, sum) in self.latency_summaries() {
            s.push_str(&format!(
                "  {:<16} n={:<6} mean={:.3}ms p50={:.3}ms p99={:.3}ms\n",
                route.to_string(),
                sum.n,
                sum.mean * 1e3,
                sum.p50 * 1e3,
                sum.p99 * 1e3
            ));
        }
        let depths = self.peak_queue_depths();
        for ((shard, key, tier), b) in self.batch_summaries() {
            s.push_str(&format!(
                "  shard{shard} {:<23} batches={:<5} mean_batch={:<5.1} \
                 occ={:.0}% degraded={} queue_p50={:.3}ms exec_p50={:.3}ms \
                 peak_depth={}\n",
                format!("{key}@{tier}"),
                b.batches,
                b.mean_size,
                b.lane_occupancy * 100.0,
                b.degraded,
                b.queue_wait.p50 * 1e3,
                b.execute.p50 * 1e3,
                depths.get(&shard).copied().unwrap_or(0)
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(s: &str) -> ModelKey {
        ModelKey::parse(s).unwrap()
    }

    #[test]
    fn records_and_reports() {
        let m = Metrics::new();
        m.record_latency(mk("gdf/conv"), Duration::from_millis(2));
        m.record_latency(mk("gdf/conv"), Duration::from_millis(4));
        m.record_batch(
            0,
            mk("gdf/conv"),
            Quality::Precise,
            8,
            Duration::from_millis(1),
            Duration::from_millis(3),
            false,
        );
        m.record_rejected();
        assert_eq!(m.completed(), 2);
        assert_eq!(m.rejected(), 1);
        assert_eq!(m.mean_batch_size(), 8.0);
        assert!((m.lane_occupancy() - 8.0 / 256.0).abs() < 1e-12);
        let sums = m.latency_summaries();
        assert!((sums[&mk("gdf/conv")].mean - 0.003).abs() < 1e-9);
        // queue wait and execute are recorded separately, not summed
        let b = &m.batch_summaries()[&(0, mk("gdf/conv"), Quality::Precise)];
        assert!((b.queue_wait.p50 - 0.001).abs() < 1e-9);
        assert!((b.execute.p50 - 0.003).abs() < 1e-9);
        let rep = m.report();
        assert!(rep.contains("gdf/conv@precise"), "{rep}");
        assert!(rep.contains("queue_p50=1.000ms"), "{rep}");
        assert!(rep.contains("exec_p50=3.000ms"), "{rep}");
    }

    #[test]
    fn occupancy_counts_the_lane_words_a_batch_actually_needs() {
        // size / (ceil(size/256)·256): a 257-request batch takes two
        // lane words and fills 257/512, never a clamped 100%
        assert_eq!(occupancy(0), 0.0);
        assert!((occupancy(1) - 1.0 / 256.0).abs() < 1e-12);
        assert!((occupancy(256) - 1.0).abs() < 1e-12);
        assert!((occupancy(257) - 257.0 / 512.0).abs() < 1e-12);
        assert!((occupancy(512) - 1.0).abs() < 1e-12);
        assert!((occupancy(513) - 513.0 / 768.0).abs() < 1e-12);

        // the same formula backs the aggregate and per-(shard,key) views
        let m = Metrics::new();
        for size in [1usize, 256, 257, 512, 513] {
            m.record_batch(
                0,
                mk("gdf/ds16"),
                Quality::Balanced,
                size,
                Duration::ZERO,
                Duration::from_millis(1),
                false,
            );
        }
        let want =
            [1usize, 256, 257, 512, 513].iter().map(|&s| occupancy(s)).sum::<f64>() / 5.0;
        assert!((m.lane_occupancy() - want).abs() < 1e-12);
        let b = &m.batch_summaries()[&(0, mk("gdf/ds16"), Quality::Balanced)];
        assert!((b.lane_occupancy - want).abs() < 1e-12);
        assert!(b.lane_occupancy < 1.0, "257/513-sized batches are not 100% occupied");
    }

    #[test]
    fn degraded_batches_are_counted() {
        let m = Metrics::new();
        let t = Quality::Balanced;
        m.record_batch(0, mk("gdf/ds16"), t, 3, Duration::ZERO, Duration::from_millis(1), true);
        m.record_batch(0, mk("gdf/ds16"), t, 4, Duration::ZERO, Duration::from_millis(1), false);
        let b = &m.batch_summaries()[&(0, mk("gdf/ds16"), t)];
        assert_eq!(b.batches, 2);
        assert_eq!(b.degraded, 1);
        assert!(m.report().contains("degraded=1"), "{}", m.report());
    }

    #[test]
    fn placement_and_spills_are_reported() {
        let m = Metrics::new();
        m.record_placement(mk("gdf/ds16"), &[0, 2]);
        m.record_placement(mk("blend/ds32"), &[1]);
        m.record_routed();
        m.record_routed();
        m.record_routed();
        m.record_spill(mk("gdf/ds16"));
        assert_eq!(m.spills(), 1);
        assert_eq!(m.spill_counts()[&mk("gdf/ds16")], 1);
        assert_eq!(m.placements()[&mk("gdf/ds16")], vec![0, 2]);
        assert!((m.spill_rate() - 1.0 / 3.0).abs() < 1e-12);
        let rep = m.report();
        assert!(rep.contains("shards[0+2]"), "{rep}");
        assert!(rep.contains("spill_rate=33.3%"), "{rep}");
    }

    #[test]
    fn admission_counters_partition_by_key_and_stage() {
        let m = Metrics::new();
        m.record_shed(mk("gdf/ds16"));
        m.record_shed(mk("gdf/ds16"));
        m.record_degrade(mk("gdf/ds16"), mk("gdf/ds32"));
        m.record_expired(mk("gdf/ds16"), ExpiredAt::Admission);
        m.record_expired(mk("gdf/ds16"), ExpiredAt::Queue);
        m.record_expired(mk("blend/ds32"), ExpiredAt::Shard);
        m.record_admission_wait(Duration::from_millis(2));
        m.record_in_flight(3);
        m.record_in_flight(1);
        assert_eq!(m.shed(), 2);
        assert_eq!(m.shed_counts()[&mk("gdf/ds16")], 2);
        assert_eq!(m.rejected(), 2, "sheds count as rejected");
        assert_eq!(m.degrades(), 1);
        assert_eq!(m.degrade_counts()[&(mk("gdf/ds16"), mk("gdf/ds32"))], 1);
        assert_eq!(m.expired(), 3);
        assert_eq!(m.expired_at(ExpiredAt::Admission), 1);
        assert_eq!(m.expired_at(ExpiredAt::Queue), 1);
        assert_eq!(m.expired_at(ExpiredAt::Shard), 1);
        assert_eq!(m.expired_counts()[&mk("gdf/ds16")], 2);
        assert_eq!(m.peak_in_flight(), 3);
        assert!((m.admission_wait_summary().p50 - 0.002).abs() < 1e-9);
        let rep = m.report();
        assert!(rep.contains("shed=2"), "{rep}");
        assert!(rep.contains("gdf/ds16 -> gdf/ds32 degraded=1"), "{rep}");
        assert!(rep.contains("expired=3"), "{rep}");
        assert!(rep.contains("peak_in_flight=3"), "{rep}");
    }

    #[test]
    fn admission_wait_window_is_bounded() {
        let m = Metrics::new();
        for i in 0..(WAIT_SAMPLES + 10) {
            m.record_admission_wait(Duration::from_nanos(i as u64));
        }
        let s = m.admission_wait_summary();
        assert_eq!(s.n, WAIT_SAMPLES, "the sample store is a bounded ring");
        // the ring keeps recent samples: the very first (0ns .. 9ns)
        // slots have been overwritten by the wrap-around
        assert!(s.min >= 10e-9 - 1e-15, "oldest samples were overwritten, min={}", s.min);
    }

    #[test]
    fn in_flight_subtracts_only_pipeline_expiries() {
        let m = Metrics::new();
        m.record_submitted();
        m.record_submitted();
        m.record_latency(mk("gdf/ds16"), Duration::from_millis(1));
        assert_eq!(m.in_flight(), 1);
        // an admission-stage expiry was never submitted — must not be
        // subtracted; a queue-stage expiry resolves a submitted request
        m.record_expired(mk("gdf/ds16"), ExpiredAt::Admission);
        assert_eq!(m.in_flight(), 1);
        m.record_expired(mk("gdf/ds16"), ExpiredAt::Queue);
        assert_eq!(m.in_flight(), 0);
    }

    #[test]
    fn per_shard_batch_stats_partition() {
        let m = Metrics::new();
        let bal = Quality::Balanced;
        m.record_batch(0, mk("gdf/ds16"), bal, 4, Duration::ZERO, Duration::from_millis(1), false);
        m.record_batch(
            1,
            mk("gdf/ds16"),
            bal,
            8,
            Duration::from_millis(5),
            Duration::from_millis(2),
            false,
        );
        m.record_batch(
            1,
            mk("frnn/ds32"),
            Quality::Economy,
            2,
            Duration::ZERO,
            Duration::from_millis(1),
            false,
        );
        m.record_queue_depth(1, 3);
        m.record_queue_depth(1, 1);
        let b = m.batch_summaries();
        assert_eq!(b.len(), 3);
        assert_eq!(b[&(0, mk("gdf/ds16"), bal)].batches, 1);
        assert_eq!(b[&(1, mk("gdf/ds16"), bal)].mean_size, 8.0);
        assert!((b[&(1, mk("gdf/ds16"), bal)].lane_occupancy - 8.0 / 256.0).abs() < 1e-12);
        // a backed-up queue shows in queue_wait without inflating execute
        assert!((b[&(1, mk("gdf/ds16"), bal)].queue_wait.p50 - 0.005).abs() < 1e-9);
        assert!((b[&(1, mk("gdf/ds16"), bal)].execute.p50 - 0.002).abs() < 1e-9);
        assert_eq!(m.peak_queue_depths()[&1], 3);
        // mean over all batches: (4 + 8 + 2) / 3
        assert!((m.mean_batch_size() - 14.0 / 3.0).abs() < 1e-12);
        let rep = m.report();
        assert!(rep.contains("shard0"), "{rep}");
        assert!(rep.contains("shard1"), "{rep}");
    }

    #[test]
    fn batch_stats_partition_by_served_tier() {
        // the autopilot's input signal: work served at economy after a
        // degrade must not pollute the precise tier's latency stream,
        // even on the same shard
        let m = Metrics::new();
        m.record_batch(
            0,
            mk("gdf/conv"),
            Quality::Precise,
            4,
            Duration::from_millis(9),
            Duration::from_millis(6),
            false,
        );
        m.record_batch(
            0,
            mk("gdf/ds32"),
            Quality::Economy,
            4,
            Duration::from_millis(1),
            Duration::from_millis(1),
            false,
        );
        let b = m.batch_summaries();
        assert_eq!(b.len(), 2);
        assert!((b[&(0, mk("gdf/conv"), Quality::Precise)].execute.p50 - 0.006).abs() < 1e-9);
        assert!((b[&(0, mk("gdf/ds32"), Quality::Economy)].execute.p50 - 0.001).abs() < 1e-9);
        let rep = m.report();
        assert!(rep.contains("gdf/conv@precise"), "{rep}");
        assert!(rep.contains("gdf/ds32@economy"), "{rep}");
    }

    #[test]
    fn forward_counters_partition_by_direction() {
        let m = Metrics::new();
        assert!(!m.report().contains("cluster:"), "{}", m.report());
        m.record_forward_out();
        m.record_forward_out();
        m.record_forward_in();
        m.record_forward_retry();
        m.record_forward_fallback();
        assert_eq!(m.forwards_out(), 2);
        assert_eq!(m.forwards_in(), 1);
        assert_eq!(m.forward_retries(), 1);
        assert_eq!(m.forward_fallbacks(), 1);
        let rep = m.report();
        assert!(rep.contains("cluster: forwards_out=2 forwards_in=1 retries=1 fallbacks=1"), "{rep}");
    }

    #[test]
    fn net_counters_track_connections_and_frames() {
        let m = Metrics::new();
        // no front-door traffic -> no net line in the report
        assert!(!m.report().contains("net:"), "{}", m.report());
        m.record_conn_opened();
        m.record_conn_opened();
        m.record_conn_closed();
        m.record_conn_opened();
        m.record_net_frame_in();
        m.record_net_frame_in();
        m.record_net_frame_out();
        m.record_net_protocol_error();
        assert_eq!(m.net_connections(), 3);
        assert_eq!(m.net_active_connections(), 2);
        assert_eq!(m.net_peak_connections(), 2);
        assert_eq!(m.net_frames_in(), 2);
        assert_eq!(m.net_frames_out(), 1);
        assert_eq!(m.net_protocol_errors(), 1);
        let rep = m.report();
        assert!(rep.contains("net: conns=3 (peak 2 concurrent, 2 open)"), "{rep}");
        assert!(rep.contains("protocol_errors=1"), "{rep}");
    }
}
