//! The quality autopilot: closed-loop precision scaling for the
//! serving stack.
//!
//! PR 5's `degrade` admission policy reacts to *instantaneous*
//! capacity — a request degrades only at the moment its tier is out of
//! permits. This module adds the telemetry-driven layer the dynamic
//! precision-scaling literature calls for: a per-[`App`] controller
//! that watches the PR 8 queue-wait/execute latency split and the
//! in-flight depth from [`Metrics`], and moves each app between its
//! *registered* tiers — descending under sustained saturation,
//! recovering to [`Quality::Precise`] when load drops.
//!
//! ```text
//!             pressure = max(queue-wait share, in-flight fraction)
//!
//!   1.0 ┤ ███ descend band (pressure ≥ descend_above)
//!       ┤
//!       ┤ ░░░ deadband — hold the current tier (hysteresis)
//!       ┤
//!   0.0 ┤ ▒▒▒ ascend band (pressure ≤ ascend_below)
//! ```
//!
//! Two mechanisms stop the controller from flapping:
//!
//! - the **hysteresis deadband** between `ascend_below` and
//!   `descend_above` — no transition happens inside it, so a pressure
//!   signal oscillating around one threshold cannot bounce tiers;
//! - the **refractory period** — after any transition the app's tier is
//!   frozen for `refractory`, so even a signal jumping across both
//!   bands moves at most one tier per window.
//!
//! Descent is additionally gated by the [`QualityFloor`]: a tier whose
//! *measured* [`QualityProfile`] (PSNR for the image apps, accuracy for
//! FRNN) falls below the configured floor is never served, no matter
//! the load — shedding is preferable to silently serving garbage.
//!
//! The controller plugs into serving at the admission gate:
//! [`Autopilot::clamp`] lowers a request's effective tier to the app's
//! current one (never raises it), and the `degrade` overload walk then
//! starts *from* that tier — so the two mechanisms compose instead of
//! fighting.

use super::metrics::Metrics;
use crate::catalog::{App, ModelKey, Quality, QualityMetric, QualityProfile};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt;
use std::sync::Mutex;
use std::time::{Duration, Instant};

/// Minimum measured quality the autopilot may serve, per metric kind.
/// Parsed from `--quality-floor psnr>=30,acc>=0.9`; an unset metric is
/// unconstrained.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct QualityFloor {
    /// Minimum PSNR in dB (image apps).
    pub psnr: Option<f64>,
    /// Minimum top-1 accuracy in [0, 1] (FRNN).
    pub acc: Option<f64>,
}

impl QualityFloor {
    /// No floor: every registered tier is fair game.
    pub fn none() -> QualityFloor {
        QualityFloor::default()
    }

    /// Parse the CLI spelling: comma-separated `metric>=value` terms,
    /// e.g. `psnr>=30,acc>=0.9`. An empty string is the empty floor.
    pub fn parse(s: &str) -> Result<QualityFloor> {
        let mut floor = QualityFloor::none();
        for term in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let Some((name, value)) = term.split_once(">=") else {
                bail!("bad quality-floor term {term:?} (want metric>=value)");
            };
            let v: f64 = value.trim().parse().map_err(|_| {
                anyhow::anyhow!("bad quality-floor value {value:?} in {term:?}")
            })?;
            if !v.is_finite() {
                bail!("quality-floor value in {term:?} must be finite");
            }
            match QualityMetric::parse(name.trim())? {
                QualityMetric::Psnr => floor.psnr = Some(v),
                QualityMetric::Accuracy => floor.acc = Some(v),
            }
        }
        Ok(floor)
    }

    /// True when no metric is constrained.
    pub fn is_empty(&self) -> bool {
        self.psnr.is_none() && self.acc.is_none()
    }

    /// May a tier with this measured profile be served? An
    /// unconstrained metric always passes; a constrained metric with
    /// *no measurement* fails closed (an unmeasured tier cannot prove
    /// it clears the floor).
    pub fn allows(&self, profile: Option<&QualityProfile>) -> bool {
        if self.is_empty() {
            return true;
        }
        let Some(p) = profile else {
            return false;
        };
        match p.metric {
            QualityMetric::Psnr => self.psnr,
            QualityMetric::Accuracy => self.acc,
        }
        .map_or(true, |min| p.value >= min)
    }

    /// The canonical CLI spelling back, e.g. `psnr>=30,acc>=0.9`.
    pub fn render(&self) -> String {
        let mut terms = Vec::new();
        if let Some(p) = self.psnr {
            terms.push(format!("psnr>={p}"));
        }
        if let Some(a) = self.acc {
            terms.push(format!("acc>={a}"));
        }
        terms.join(",")
    }
}

impl fmt::Display for QualityFloor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

/// Controller knobs. The defaults suit the in-process serving demo
/// (millisecond batches); benches and tests tighten them.
#[derive(Clone, Copy, Debug)]
pub struct AutopilotConfig {
    /// How often the dispatcher calls [`Autopilot::tick`].
    pub tick: Duration,
    /// Pressure at or above this descends one tier (when allowed).
    pub descend_above: f64,
    /// Pressure at or below this ascends one tier. Must sit below
    /// `descend_above`; the gap is the hysteresis deadband.
    pub ascend_below: f64,
    /// Minimum time between two transitions of the same app.
    pub refractory: Duration,
    /// Quality floor no served tier may fall below.
    pub floor: QualityFloor,
}

impl Default for AutopilotConfig {
    fn default() -> Self {
        AutopilotConfig {
            tick: Duration::from_millis(50),
            descend_above: 0.6,
            ascend_below: 0.2,
            refractory: Duration::from_millis(300),
            floor: QualityFloor::none(),
        }
    }
}

/// Per-app controller state.
struct TierState {
    current: Quality,
    /// Best (highest) registered tier — where recovery stops.
    best: Quality,
    last_transition: Option<Instant>,
    transitions: u64,
    /// Cumulative queue-wait / execute sums (seconds) at the last tick,
    /// so each tick steers on the *window since the previous tick*, not
    /// the whole history.
    prev_queue_sum: f64,
    prev_exec_sum: f64,
}

/// The closed-loop precision controller. One instance is shared (via
/// `Arc`) between the admission gate (which consults
/// [`Autopilot::clamp`] per request) and the dispatcher thread (which
/// drives [`Autopilot::tick`]).
pub struct Autopilot {
    cfg: AutopilotConfig,
    /// Tiers the controller may serve, per key, with their measured
    /// quality (when the backend measured one at registration).
    registered: Vec<ModelKey>,
    profiles: BTreeMap<ModelKey, QualityProfile>,
    /// The admission gate's in-flight cap — the depth-pressure
    /// denominator.
    cap: u64,
    state: Mutex<BTreeMap<App, TierState>>,
}

impl Autopilot {
    /// Build a controller over the `registered` catalog with its
    /// measured `profiles`. `cap` is the serving in-flight cap (the
    /// coordinator's `queue_capacity`). Every app present in
    /// `registered` starts at its best registered tier.
    pub fn new(
        cfg: AutopilotConfig,
        registered: Vec<ModelKey>,
        profiles: BTreeMap<ModelKey, QualityProfile>,
        cap: usize,
    ) -> Autopilot {
        let mut state = BTreeMap::new();
        for app in App::ALL {
            let best = Quality::ALL
                .into_iter()
                .find(|&q| registered.contains(&ModelKey::route(app, q)));
            if let Some(best) = best {
                state.insert(
                    app,
                    TierState {
                        current: best,
                        best,
                        last_transition: None,
                        transitions: 0,
                        prev_queue_sum: 0.0,
                        prev_exec_sum: 0.0,
                    },
                );
            }
        }
        Autopilot {
            cfg,
            registered,
            profiles,
            cap: cap.max(1) as u64,
            state: Mutex::new(state),
        }
    }

    /// The controller knobs this instance runs with.
    pub fn config(&self) -> &AutopilotConfig {
        &self.cfg
    }

    /// The measured quality of a registered key, if known.
    pub fn profile(&self, key: ModelKey) -> Option<QualityProfile> {
        self.profiles.get(&key).copied()
    }

    /// The tier `app` is currently steered to (its best registered tier
    /// for an app the controller does not manage).
    pub fn current(&self, app: App) -> Quality {
        self.state
            .lock()
            .unwrap()
            .get(&app)
            .map(|s| s.current)
            .unwrap_or(Quality::Precise)
    }

    /// Tier transitions taken so far, across all apps.
    pub fn transitions(&self) -> u64 {
        self.state.lock().unwrap().values().map(|s| s.transitions).sum()
    }

    /// The effective tier for a request: the *lower* of what was asked
    /// and where the controller currently sits. Steering never upgrades
    /// a request — a client asking for economy gets economy even when
    /// the controller idles at precise.
    pub fn clamp(&self, app: App, requested: Quality) -> Quality {
        // Quality orders best-first (Precise < Balanced < Economy), so
        // the lower tier is the Ord-larger one
        requested.max(self.current(app))
    }

    /// One controller step for `app` with an already-computed pressure
    /// in [0, 1], at time `now`. Split out from [`Autopilot::tick`] so
    /// hysteresis/refractory dynamics are unit-testable with an
    /// injected clock. Returns the transition taken, if any.
    pub fn observe(&self, app: App, pressure: f64, now: Instant) -> Option<(Quality, Quality)> {
        let mut state = self.state.lock().unwrap();
        let st = state.get_mut(&app)?;
        // refractory: freeze after any transition, whatever the signal
        if let Some(t) = st.last_transition {
            if now.saturating_duration_since(t) < self.cfg.refractory {
                return None;
            }
        }
        let next = if pressure >= self.cfg.descend_above {
            // descend one tier — but only onto a registered tier whose
            // measured quality clears the floor
            st.current.lower().filter(|&q| {
                let key = ModelKey::route(app, q);
                self.registered.contains(&key)
                    && self.cfg.floor.allows(self.profiles.get(&key))
            })
        } else if pressure <= self.cfg.ascend_below {
            // recover one tier toward the best registered one
            st.current.higher().filter(|&q| {
                q >= st.best && self.registered.contains(&ModelKey::route(app, q))
            })
        } else {
            // hysteresis deadband: hold
            None
        }?;
        let from = st.current;
        st.current = next;
        st.last_transition = Some(now);
        st.transitions += 1;
        Some((from, next))
    }

    /// One closed-loop tick: derive each managed app's pressure from
    /// the live [`Metrics`] and run [`Autopilot::observe`] on it.
    ///
    /// Pressure is the max of two signals in [0, 1]:
    ///
    /// - **queue-wait share** — of the batch latency this app accrued
    ///   since the last tick, the fraction spent waiting for dispatch
    ///   rather than executing (the PR 8 split). A saturated system
    ///   queues; a healthy one executes.
    /// - **in-flight fraction** — permits held over the admission cap.
    ///   Catches the saturated-but-not-completing case (a full gate
    ///   with no batch stream to measure).
    ///
    /// Returns every transition taken this tick.
    pub fn tick(&self, metrics: &Metrics) -> Vec<(App, Quality, Quality)> {
        let now = Instant::now();
        let depth = metrics.in_flight() as f64 / self.cap as f64;
        let sums = metrics.batch_summaries();
        // cumulative queue/execute seconds per app (sum = mean · n)
        let mut totals: BTreeMap<App, (f64, f64)> = BTreeMap::new();
        for ((_, key, _), b) in &sums {
            let t = totals.entry(key.app).or_insert((0.0, 0.0));
            t.0 += b.queue_wait.mean * b.queue_wait.n as f64;
            t.1 += b.execute.mean * b.execute.n as f64;
        }
        let apps: Vec<App> = self.state.lock().unwrap().keys().copied().collect();
        let mut out = Vec::new();
        for app in apps {
            let (qsum, esum) = totals.get(&app).copied().unwrap_or((0.0, 0.0));
            let (dq, de) = {
                let mut state = self.state.lock().unwrap();
                let st = state.get_mut(&app).unwrap();
                let dq = (qsum - st.prev_queue_sum).max(0.0);
                let de = (esum - st.prev_exec_sum).max(0.0);
                st.prev_queue_sum = qsum;
                st.prev_exec_sum = esum;
                (dq, de)
            };
            let wait_share = if dq + de > 0.0 { dq / (dq + de) } else { 0.0 };
            let pressure = wait_share.max(depth).clamp(0.0, 1.0);
            if let Some((from, to)) = self.observe(app, pressure, now) {
                out.push((app, from, to));
            }
        }
        out
    }

    /// One status line per managed app, for reports:
    /// `autopilot: gdf=economy(psnr=31.0) frnn=precise(acc=0.950) …`
    pub fn report(&self) -> String {
        let state = self.state.lock().unwrap();
        let mut parts = Vec::new();
        for (app, st) in state.iter() {
            let key = ModelKey::route(*app, st.current);
            let quality = self
                .profiles
                .get(&key)
                .map(|p| format!("({p})"))
                .unwrap_or_default();
            parts.push(format!("{app}={}{quality}[{} moves]", st.current, st.transitions));
        }
        format!("autopilot: {}", parts.join(" "))
    }
}

impl fmt::Debug for Autopilot {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Autopilot")
            .field("cfg", &self.cfg)
            .field("registered", &self.registered.len())
            .field("cap", &self.cap)
            .finish()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profiles() -> BTreeMap<ModelKey, QualityProfile> {
        // mirror the MockExecutor's deterministic stand-in numbers
        let mut out = BTreeMap::new();
        for key in ModelKey::catalog() {
            let (metric, value) = match (key.app, key.tier()) {
                (App::Frnn, Quality::Precise) => (QualityMetric::Accuracy, 0.95),
                (App::Frnn, Quality::Balanced) => (QualityMetric::Accuracy, 0.92),
                (App::Frnn, Quality::Economy) => (QualityMetric::Accuracy, 0.85),
                (_, Quality::Precise) => (QualityMetric::Psnr, crate::catalog::PSNR_CAP),
                (_, Quality::Balanced) => (QualityMetric::Psnr, 36.0),
                (_, Quality::Economy) => (QualityMetric::Psnr, 31.0),
            };
            out.insert(key, QualityProfile { metric, value, reference: Quality::Precise });
        }
        out
    }

    fn pilot(cfg: AutopilotConfig) -> Autopilot {
        Autopilot::new(cfg, ModelKey::catalog(), profiles(), 16)
    }

    #[test]
    fn quality_floor_parses_and_gates() {
        let f = QualityFloor::parse("psnr>=30,acc>=0.9").unwrap();
        assert_eq!(f.psnr, Some(30.0));
        assert_eq!(f.acc, Some(0.9));
        assert_eq!(f.render(), "psnr>=30,acc>=0.9");
        assert_eq!(QualityFloor::parse(&f.render()).unwrap(), f);
        assert!(QualityFloor::parse("").unwrap().is_empty());
        assert!(QualityFloor::parse("psnr>30").is_err(), "only >= is a floor");
        assert!(QualityFloor::parse("vibes>=1").is_err());
        assert!(QualityFloor::parse("psnr>=NaN").is_err());

        let good = QualityProfile {
            metric: QualityMetric::Psnr,
            value: 31.0,
            reference: Quality::Precise,
        };
        let bad = QualityProfile { value: 28.0, ..good };
        assert!(f.allows(Some(&good)));
        assert!(!f.allows(Some(&bad)));
        assert!(!f.allows(None), "a constrained floor fails closed on unmeasured tiers");
        assert!(QualityFloor::none().allows(None));
        // a floor on one metric leaves the other unconstrained
        let acc_only = QualityFloor::parse("acc>=0.9").unwrap();
        assert!(acc_only.allows(Some(&bad)), "psnr is unconstrained here");
    }

    #[test]
    fn no_transition_inside_the_deadband() {
        let p = pilot(AutopilotConfig::default());
        let t0 = Instant::now();
        // anywhere strictly between the bands: hold, forever
        for (i, pr) in [0.3, 0.5, 0.59, 0.21].into_iter().enumerate() {
            let now = t0 + Duration::from_secs(i as u64 + 1);
            assert_eq!(p.observe(App::Gdf, pr, now), None, "pressure {pr} is deadband");
            assert_eq!(p.current(App::Gdf), Quality::Precise);
        }
        assert_eq!(p.transitions(), 0);
    }

    #[test]
    fn sustained_pressure_descends_one_tier_per_refractory_window() {
        let cfg = AutopilotConfig::default();
        let p = pilot(cfg);
        let t0 = Instant::now();
        assert_eq!(
            p.observe(App::Gdf, 0.9, t0),
            Some((Quality::Precise, Quality::Balanced))
        );
        // the same saturating signal inside the refractory window: no flap
        let inside = t0 + cfg.refractory / 2;
        assert_eq!(p.observe(App::Gdf, 1.0, inside), None);
        assert_eq!(p.current(App::Gdf), Quality::Balanced);
        // once the window passes, the next step descends again
        let after = t0 + cfg.refractory;
        assert_eq!(
            p.observe(App::Gdf, 0.9, after),
            Some((Quality::Balanced, Quality::Economy))
        );
        // economy is the floor of the tier ladder: nowhere lower
        let later = after + cfg.refractory;
        assert_eq!(p.observe(App::Gdf, 1.0, later), None);
        assert_eq!(p.current(App::Gdf), Quality::Economy);
        assert_eq!(p.transitions(), 2);
    }

    #[test]
    fn low_pressure_recovers_to_precise_and_no_further() {
        let cfg = AutopilotConfig::default();
        let p = pilot(cfg);
        let t0 = Instant::now();
        p.observe(App::Blend, 0.9, t0).unwrap();
        p.observe(App::Blend, 0.9, t0 + cfg.refractory).unwrap();
        assert_eq!(p.current(App::Blend), Quality::Economy);
        let up1 = t0 + cfg.refractory * 2;
        assert_eq!(
            p.observe(App::Blend, 0.0, up1),
            Some((Quality::Economy, Quality::Balanced))
        );
        let up2 = up1 + cfg.refractory;
        assert_eq!(
            p.observe(App::Blend, 0.1, up2),
            Some((Quality::Balanced, Quality::Precise))
        );
        // fully recovered: zero pressure cannot ascend past the best tier
        assert_eq!(p.observe(App::Blend, 0.0, up2 + cfg.refractory), None);
        assert_eq!(p.current(App::Blend), Quality::Precise);
    }

    #[test]
    fn flapping_pressure_is_rate_limited_by_the_refractory_period() {
        let cfg = AutopilotConfig::default();
        let p = pilot(cfg);
        let t0 = Instant::now();
        // a worst-case signal alternating across both bands every
        // observation: at most one transition per refractory window
        let mut transitions = 0;
        for i in 0u32..20 {
            let pressure = if i % 2 == 0 { 1.0 } else { 0.0 };
            let now = t0 + cfg.refractory / 4 * i;
            if p.observe(App::Frnn, pressure, now).is_some() {
                transitions += 1;
            }
        }
        // 20 observations spanning ~5 refractory windows → at most 6
        // transitions ever (one per window, however the signal flaps)
        assert!(transitions <= 6, "flapped {transitions} times");
    }

    #[test]
    fn quality_floor_blocks_descent_below_it() {
        // economy measures psnr=31 (mock numbers): a 32dB floor allows
        // balanced (36dB) but pins the controller above economy
        let cfg = AutopilotConfig {
            floor: QualityFloor::parse("psnr>=32").unwrap(),
            ..AutopilotConfig::default()
        };
        let p = pilot(cfg);
        let t0 = Instant::now();
        assert_eq!(
            p.observe(App::Gdf, 1.0, t0),
            Some((Quality::Precise, Quality::Balanced))
        );
        // sustained saturation cannot push below the floor
        for i in 1u32..5 {
            let now = t0 + cfg.refractory * i;
            assert_eq!(p.observe(App::Gdf, 1.0, now), None);
        }
        assert_eq!(p.current(App::Gdf), Quality::Balanced);
        // frnn has its own metric: an accuracy floor of 0.9 stops at
        // balanced (0.92) and never serves economy (0.85)
        let cfg = AutopilotConfig {
            floor: QualityFloor::parse("acc>=0.9").unwrap(),
            ..AutopilotConfig::default()
        };
        let p = pilot(cfg);
        p.observe(App::Frnn, 1.0, t0).unwrap();
        assert_eq!(p.observe(App::Frnn, 1.0, t0 + cfg.refractory), None);
        assert_eq!(p.current(App::Frnn), Quality::Balanced);
    }

    #[test]
    fn descent_only_targets_registered_tiers() {
        // only gdf/conv + gdf/ds32 registered: balanced is not a legal
        // stop, but economy (registered, two steps down) is unreachable
        // because descent moves one *registered* tier at a time — the
        // controller holds at precise rather than route off-catalog
        let keys = vec![
            ModelKey::parse("gdf/conv").unwrap(),
            ModelKey::parse("gdf/ds32").unwrap(),
        ];
        let p = Autopilot::new(AutopilotConfig::default(), keys, profiles(), 8);
        assert_eq!(p.observe(App::Gdf, 1.0, Instant::now()), None);
        assert_eq!(p.current(App::Gdf), Quality::Precise);
    }

    #[test]
    fn clamp_never_upgrades_a_request() {
        let cfg = AutopilotConfig::default();
        let p = pilot(cfg);
        // controller idling at precise: requests pass through untouched
        assert_eq!(p.clamp(App::Gdf, Quality::Precise), Quality::Precise);
        assert_eq!(p.clamp(App::Gdf, Quality::Economy), Quality::Economy);
        // steer gdf down to balanced
        let t0 = Instant::now();
        p.observe(App::Gdf, 1.0, t0).unwrap();
        assert_eq!(p.clamp(App::Gdf, Quality::Precise), Quality::Balanced);
        assert_eq!(p.clamp(App::Gdf, Quality::Balanced), Quality::Balanced);
        // a request already below the controller stays where it asked
        assert_eq!(p.clamp(App::Gdf, Quality::Economy), Quality::Economy);
        // other apps are independent
        assert_eq!(p.clamp(App::Frnn, Quality::Precise), Quality::Precise);
    }

    #[test]
    fn tick_derives_pressure_from_the_latency_split() {
        use std::time::Duration as D;
        let cfg = AutopilotConfig { refractory: Duration::ZERO, ..AutopilotConfig::default() };
        let p = pilot(cfg);
        let m = Metrics::new();
        let key = ModelKey::parse("gdf/conv").unwrap();
        // a queue-dominated window: waits dwarf executes → descend
        m.record_batch(0, key, Quality::Precise, 4, D::from_millis(90), D::from_millis(10), false);
        let moved = p.tick(&m);
        assert_eq!(moved, vec![(App::Gdf, Quality::Precise, Quality::Balanced)]);
        // no new batches since the last tick and an empty gate → the
        // *windowed* signal is calm, so the controller recovers — the
        // historical backlog must not pin it down forever
        let moved = p.tick(&m);
        assert_eq!(moved, vec![(App::Gdf, Quality::Balanced, Quality::Precise)]);
        // an execute-dominated window is healthy: no descent
        m.record_batch(0, key, Quality::Precise, 4, D::from_millis(1), D::from_millis(99), false);
        assert_eq!(p.tick(&m), vec![]);
        assert_eq!(p.current(App::Gdf), Quality::Precise);
    }

    #[test]
    fn tick_sees_saturation_through_the_in_flight_fraction() {
        let cfg = AutopilotConfig { refractory: Duration::ZERO, ..AutopilotConfig::default() };
        let p = Autopilot::new(cfg, ModelKey::catalog(), profiles(), 4);
        let m = Metrics::new();
        // a full gate with no batch stream at all (nothing completing):
        // the depth signal alone must trigger descent
        for _ in 0..4 {
            m.record_submitted();
        }
        let moved = p.tick(&m);
        assert!(
            moved.iter().any(|&(app, from, to)| {
                app == App::Gdf && from == Quality::Precise && to == Quality::Balanced
            }),
            "{moved:?}"
        );
    }

    #[test]
    fn report_names_every_managed_app() {
        let p = pilot(AutopilotConfig::default());
        let rep = p.report();
        for app in App::ALL {
            assert!(rep.contains(&format!("{app}=precise")), "{rep}");
        }
        assert!(rep.contains("psnr=99.0"), "{rep}");
        assert!(rep.contains("acc=0.950"), "{rep}");
    }
}
