//! The coordinator: request routing, quality policy, backpressure,
//! dynamic batching, metrics — in front of the sharded engine pool.
//!
//! Routing is fully typed: a [`Job`] names its [`App`], the request's
//! [`Quality`] picks the [`crate::catalog::PpcConfig`] through
//! [`ModelKey::route`], and that one [`ModelKey`] travels unchanged
//! through the batcher, the shard and the response — the same key the
//! registry was populated under, so there is no string matching
//! anywhere between a request and its datapath.
//!
//! Batches — not single requests — are the unit of work: every job
//! type queues in the [`Batcher`] under its routed key, and due
//! batches are routed whole to the least-loaded [`EnginePool`] shard,
//! whose lane-batched [`crate::catalog::Datapath::exec_batch`] path
//! packs the requests into 256-lane compiled-tape netlist passes.
//! The dispatcher never blocks on model execution; shards scatter the
//! per-request replies themselves.

use super::admission::{AdmitError, Admission, OverloadPolicy, Permit, Rejection};
use super::autopilot::Autopilot;
use super::batcher::{Batcher, Pending};
use super::engine::{BatchItem, BatchJob, EnginePool, Executor};
use super::metrics::{ExpiredAt, Metrics};
use super::placement::Placement;
use crate::catalog::{App, ModelKey, Quality, QualityProfile, Tensor, LANES};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// A unit of work.
#[derive(Clone, Debug)]
pub enum Job {
    /// Gaussian-denoise an image (`[h, w]` tensor; non-square welcome).
    Denoise { image: Tensor },
    /// Blend two shape-identical images with quantized alpha in [0, 127].
    Blend { p1: Tensor, p2: Tensor, alpha: i32 },
    /// Classify one face (one 960-pixel row; the batcher pools rows
    /// into lane-batched `[1, 960]` requests).
    Classify { pixels: Vec<i32> },
}

impl Job {
    /// Which application datapath serves this job kind (public so the
    /// network front door can route before submitting).
    pub fn app(&self) -> App {
        match self {
            Job::Denoise { .. } => App::Gdf,
            Job::Blend { .. } => App::Blend,
            Job::Classify { .. } => App::Frnn,
        }
    }
}

/// Completed result.
#[derive(Clone, Debug)]
pub struct Response {
    pub outputs: Vec<Tensor>,
    /// The catalog key that served the request.
    pub route: ModelKey,
    /// The quality tier that actually answered (`route`'s tier) —
    /// explicit so callers need not re-derive it from the key.
    pub tier: Quality,
    /// The serving tier's *measured* quality (PSNR vs the precise tier
    /// for the image apps, top-1 accuracy for FRNN), when the backend
    /// measured one at registration.
    pub quality: Option<QualityProfile>,
    /// True when the request was answered below its requested quality
    /// tier — by the overload degrade policy or by autopilot steering
    /// (`route`/`tier` name what answered).
    pub degraded: bool,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Over capacity on a non-blocking submit — caller should back off.
    Busy,
    /// Shed by the admission gate: over capacity under the active
    /// overload policy (`reject`, or `degrade` with every tier full).
    Shed,
    /// The request deadline passed before admission.
    Expired,
    /// Coordinator shut down.
    Down,
}

impl SubmitError {
    /// Stable wire discriminant (protocol — never change for an
    /// existing variant).
    pub fn wire_name(self) -> &'static str {
        match self {
            SubmitError::Busy => "busy",
            SubmitError::Shed => "shed",
            SubmitError::Expired => "expired",
            SubmitError::Down => "down",
        }
    }
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Busy => f.write_str("submit refused: over capacity (back off)"),
            SubmitError::Shed => {
                f.write_str("submit shed: over capacity under the overload policy")
            }
            SubmitError::Expired => f.write_str("submit refused: deadline already expired"),
            SubmitError::Down => f.write_str("submit failed: coordinator is down"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Max in-flight requests — the admission gate's capacity (also
    /// sizes the bounded submit queue).
    pub queue_capacity: usize,
    /// Max requests lane-packed into one batch (clamped to
    /// [`LANES`] — the word width of the bit-sliced evaluator).
    pub batch_size: usize,
    /// Classify input row length (validated at routing time so a
    /// malformed row fails fast instead of poisoning a batch).
    pub classify_row: usize,
    /// Max time a request waits for batch-mates.
    pub batch_max_wait: Duration,
    /// Engine shards; each owns its own executor instance.
    pub shards: usize,
    /// What the admission gate does with requests it has no capacity
    /// for: reject, wait (deadline-bounded), or degrade quality.
    pub overload: OverloadPolicy,
    /// Per-[`ModelKey`] fair share of the capacity pool: one key holds
    /// at most `ceil(queue_capacity · fair_share)` in-flight requests,
    /// so a hot model cannot starve the rest of the catalog. The share
    /// is a hard reservation (not work-conserving), so the default is
    /// 1.0 — full capacity for single-model workloads; dial it down
    /// when protecting a mixed catalog, or to give the `degrade`
    /// policy per-tier headroom to degrade into.
    pub fair_share: f64,
    /// Closed-loop quality controller (`serve --quality auto`): when
    /// set, the dispatcher drives [`Autopilot::tick`] and the admission
    /// gate starts every tier walk from the controller's current tier.
    /// `None` is fixed-quality serving (the pre-autopilot behavior).
    pub autopilot: Option<Arc<Autopilot>>,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            queue_capacity: 64,
            batch_size: 16,
            classify_row: 960,
            batch_max_wait: Duration::from_millis(2),
            shards: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4),
            overload: OverloadPolicy::Wait,
            fair_share: 1.0,
            autopilot: None,
        }
    }
}

struct WorkItem {
    job: Job,
    quality: Quality,
    reply: mpsc::Sender<Result<Response>>,
    submitted: Instant,
    deadline: Option<Instant>,
    degraded: bool,
    permit: Option<Permit>,
}

/// Handle to an in-flight request.
pub struct Ticket {
    rx: mpsc::Receiver<Result<Response>>,
}

impl Ticket {
    pub fn wait(self) -> Result<Response> {
        self.rx.recv().map_err(|_| anyhow!("coordinator dropped request"))?
    }
    pub fn wait_timeout(self, d: Duration) -> Result<Response> {
        self.rx
            .recv_timeout(d)
            .map_err(|_| anyhow!("timeout waiting for response"))?
    }

    /// A ticket already resolved with a typed rejection — batch
    /// submission hands these out for jobs the gate refused, so every
    /// job keeps an observable slot in its [`BatchTicket`].
    fn rejected(r: Rejection) -> Ticket {
        let (tx, rx) = mpsc::channel();
        let _ = tx.send(Err(anyhow::Error::new(r)));
        Ticket { rx }
    }
}

/// Handle to a whole in-flight batch of requests (one future per
/// request, awaited together).
pub struct BatchTicket {
    tickets: Vec<Ticket>,
}

impl BatchTicket {
    pub fn len(&self) -> usize {
        self.tickets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tickets.is_empty()
    }

    /// Await every response, in submission order. Fails on the first
    /// failed request.
    pub fn wait(self) -> Result<Vec<Response>> {
        self.tickets.into_iter().map(|t| t.wait()).collect()
    }

    /// Await every response, keeping per-request results.
    pub fn wait_each(self) -> Vec<Result<Response>> {
        self.tickets.into_iter().map(|t| t.wait()).collect()
    }
}

/// The coordinator front-end.
pub struct Coordinator {
    tx: mpsc::SyncSender<WorkItem>,
    metrics: Arc<Metrics>,
    /// Shared with the dispatcher thread so catalog/residency queries
    /// ([`Coordinator::registered_keys`], [`Coordinator::resident_keys`])
    /// don't have to round-trip through the work queue.
    pool: Arc<EnginePool>,
    down: Arc<AtomicBool>,
    /// The one front door: every submit path acquires a capacity permit
    /// here before anything queues, so no path — blocking or not — can
    /// push the system past `queue_capacity` in-flight requests.
    admission: Arc<Admission>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start with a custom executor factory: `factory(shard_index)`
    /// runs on each of `config.shards` shard threads and builds that
    /// shard's executor (the whole catalog on every shard).
    pub fn start<E, F>(config: CoordinatorConfig, factory: F) -> Result<Coordinator>
    where
        E: Executor + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let pool = EnginePool::spawn(config.shards, metrics.clone(), factory)?;
        Coordinator::run(config, pool, metrics)
    }

    /// Start under sticky `placement`: `factory(shard_index,
    /// assigned_keys)` builds each shard's model *subset* on the
    /// shard's own thread (placement's shard count wins over
    /// `config.shards`). Batches route sticky-first with spill; shards
    /// receiving off-subset traffic lazily register the model.
    pub fn start_placed<E, F>(
        config: CoordinatorConfig,
        placement: Placement,
        factory: F,
    ) -> Result<Coordinator>
    where
        E: Executor + 'static,
        F: Fn(usize, &[ModelKey]) -> Result<E> + Send + Sync + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let pool = EnginePool::spawn_placed(placement, metrics.clone(), factory)?;
        Coordinator::run(config, pool, metrics)
    }

    fn run(
        config: CoordinatorConfig,
        pool: EnginePool,
        metrics: Arc<Metrics>,
    ) -> Result<Coordinator> {
        let pool = Arc::new(pool);
        // the servable catalog at startup — what a `degrade` admission
        // may fall back to (off-catalog tiers are never degrade targets)
        let registered = pool.keys().unwrap_or_default();
        let mut admission = Admission::new(
            config.queue_capacity,
            config.overload,
            config.fair_share,
            registered,
            metrics.clone(),
        );
        if let Some(ap) = &config.autopilot {
            admission = admission.with_autopilot(ap.clone());
        }
        let admission = Arc::new(admission);
        // the gate clamps its cap to >= 1, so the channel must match or
        // a zero-capacity (rendezvous) channel would let the
        // never-sleeps submit() block on send
        let (tx, rx) = mpsc::sync_channel::<WorkItem>(config.queue_capacity.max(1));
        let down = Arc::new(AtomicBool::new(false));
        let m = metrics.clone();
        let d = down.clone();
        let p = pool.clone();
        let dispatcher = std::thread::Builder::new()
            .name("ppc-dispatch".into())
            .spawn(move || dispatch_loop(config, p, rx, m, d))?;
        Ok(Coordinator { tx, metrics, pool, down, admission, dispatcher: Some(dispatcher) })
    }

    /// Start against the artifact directory (PJRT path; needs the
    /// `pjrt` cargo feature — without it the shard factory fails with
    /// a clear error pointing at [`Coordinator::with_native`]). The
    /// PJRT client is heavyweight, so this backend always runs one
    /// shard regardless of `config.shards`.
    pub fn with_artifacts(dir: &std::path::Path, config: CoordinatorConfig) -> Result<Coordinator> {
        let dir = dir.to_path_buf();
        let config = CoordinatorConfig { shards: 1, ..config };
        Coordinator::start(config, move |_shard| crate::runtime::Runtime::load(&dir))
    }

    /// Start over a single pre-built native executor: the synthesized
    /// PPC blocks are the execution engine, no XLA/Python anywhere on
    /// the path. One shard (the executor is moved onto it); use
    /// [`Coordinator::with_native_sharded`] to fan the catalog out
    /// over several shards.
    pub fn with_native(
        config: CoordinatorConfig,
        executor: crate::runtime::NativeExecutor,
    ) -> Result<Coordinator> {
        let config = CoordinatorConfig { shards: 1, ..config };
        let cell = Mutex::new(Some(executor));
        Coordinator::start(config, move |_shard| {
            cell.lock()
                .unwrap()
                .take()
                .ok_or_else(|| anyhow!("single-shard executor already taken"))
        })
    }

    /// Start a sharded native pool: `build(shard_index)` constructs one
    /// [`crate::runtime::NativeExecutor`] per shard, on the shard's own
    /// thread. Point every build at the same persistent netlist cache
    /// and only the first pays synthesis — the rest load BLIF.
    pub fn with_native_sharded<F>(config: CoordinatorConfig, build: F) -> Result<Coordinator>
    where
        F: Fn(usize) -> Result<crate::runtime::NativeExecutor> + Send + Sync + 'static,
    {
        Coordinator::start(config, build)
    }

    /// Start a sticky-placed native pool: `build(shard_index,
    /// assigned_keys)` constructs each shard's subset
    /// [`crate::runtime::NativeExecutor`] (declare the full catalog,
    /// [`crate::runtime::NativeExecutor::with_keys`] the assignment) on
    /// the shard's own thread.
    pub fn with_native_placed<F>(
        config: CoordinatorConfig,
        placement: Placement,
        build: F,
    ) -> Result<Coordinator>
    where
        F: Fn(usize, &[ModelKey]) -> Result<crate::runtime::NativeExecutor>
            + Send
            + Sync
            + 'static,
    {
        Coordinator::start_placed(config, placement, build)
    }

    /// The servable catalog: the union of every live shard's keys.
    pub fn registered_keys(&self) -> Result<Vec<ModelKey>> {
        self.pool.keys()
    }

    /// Per-shard resident (built) model keys — under sticky placement,
    /// each shard's assigned subset plus anything it lazily registered.
    pub fn resident_keys(&self) -> Result<Vec<Vec<ModelKey>>> {
        self.pool.resident_keys()
    }

    /// The sticky placement the engine pool routes with, if any.
    pub fn placement(&self) -> Option<&Placement> {
        self.pool.placement()
    }

    /// Non-blocking submit; `Err(Busy)` when the admission gate has no
    /// capacity right now (under `degrade`, a lower registered tier is
    /// tried first). Never sleeps.
    pub fn submit(&self, job: Job, quality: Quality) -> Result<Ticket, SubmitError> {
        self.submit_inner(job, quality, None, false)
    }

    /// Blocking submit, through the same admission gate as every other
    /// path (the old cap bypass is gone). Under the `wait` policy this
    /// sleeps until capacity frees; under `reject`/`degrade` it returns
    /// a typed [`SubmitError::Shed`] instead of growing the queues.
    pub fn submit_blocking(&self, job: Job, quality: Quality) -> Result<Ticket, SubmitError> {
        self.submit_inner(job, quality, None, true)
    }

    /// Blocking submit with an absolute deadline. An already-expired
    /// deadline is refused at the gate ([`SubmitError::Expired`])
    /// without touching any queue; a request that expires while queued
    /// resolves its ticket with a typed [`Rejection::DeadlineExpired`].
    pub fn submit_deadline(
        &self,
        job: Job,
        quality: Quality,
        deadline: Instant,
    ) -> Result<Ticket, SubmitError> {
        self.submit_inner(job, quality, Some(deadline), true)
    }

    fn submit_inner(
        &self,
        job: Job,
        quality: Quality,
        deadline: Option<Instant>,
        block: bool,
    ) -> Result<Ticket, SubmitError> {
        if self.down.load(Ordering::Relaxed) {
            return Err(SubmitError::Down);
        }
        let submitted = Instant::now();
        let admitted = Admission::admit(&self.admission, job.app(), quality, deadline, block)
            .map_err(|e| match e {
                AdmitError::Shed if block => SubmitError::Shed,
                AdmitError::Shed => SubmitError::Busy,
                AdmitError::Expired => SubmitError::Expired,
            })?;
        let (reply, rx) = mpsc::channel();
        let item = WorkItem {
            job,
            quality: admitted.quality,
            reply,
            submitted,
            deadline,
            degraded: admitted.degraded,
            permit: Some(admitted.permit),
        };
        // the gate caps in-flight requests at the queue capacity, so
        // the bounded channel always has room — send() only fails when
        // the dispatcher is gone (the dropped permit releases the slot)
        self.tx.send(item).map_err(|_| SubmitError::Down)?;
        self.metrics.record_submitted();
        Ok(Ticket { rx })
    }

    /// Submit a whole batch of jobs and await them together: the batch
    /// future of the reworked serving API. Jobs routed to the same
    /// [`ModelKey`] lane-pack into shared netlist passes. Each job
    /// passes the admission gate individually, so a batch submission
    /// cannot overrun the in-flight cap — and a job the gate refuses
    /// (shed under `reject`/`degrade`, or an expired deadline) keeps
    /// its slot in the returned [`BatchTicket`] as a ticket resolved
    /// with the typed [`Rejection`], so already-admitted batch-mates
    /// are never dropped unobserved. Only [`SubmitError::Down`] fails
    /// the whole call.
    pub fn submit_all(
        &self,
        jobs: impl IntoIterator<Item = (Job, Quality)>,
    ) -> Result<BatchTicket, SubmitError> {
        self.submit_all_inner(jobs, None)
    }

    /// [`Coordinator::submit_all`] with one absolute deadline applied
    /// to every job in the batch.
    pub fn submit_all_deadline(
        &self,
        jobs: impl IntoIterator<Item = (Job, Quality)>,
        deadline: Instant,
    ) -> Result<BatchTicket, SubmitError> {
        self.submit_all_inner(jobs, Some(deadline))
    }

    fn submit_all_inner(
        &self,
        jobs: impl IntoIterator<Item = (Job, Quality)>,
        deadline: Option<Instant>,
    ) -> Result<BatchTicket, SubmitError> {
        let mut tickets = Vec::new();
        for (job, quality) in jobs {
            match self.submit_inner(job, quality, deadline, true) {
                Ok(t) => tickets.push(t),
                Err(SubmitError::Down) => return Err(SubmitError::Down),
                Err(SubmitError::Expired) => {
                    tickets.push(Ticket::rejected(Rejection::DeadlineExpired))
                }
                Err(_) => tickets.push(Ticket::rejected(Rejection::Shed)),
            }
        }
        Ok(BatchTicket { tickets })
    }

    /// The admission gate (capacity, policy, live in-flight count).
    pub fn admission(&self) -> &Admission {
        &self.admission
    }

    /// The quality autopilot, when serving in adaptive mode.
    pub fn autopilot(&self) -> Option<&Arc<Autopilot>> {
        self.admission.autopilot()
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.down.store(true, Ordering::Relaxed);
        if let Some(h) = self.dispatcher.take() {
            // replace tx with a dummy to disconnect the queue; the
            // dispatcher drains what's left, flushes every open batch
            // to the pool, and the pool's drop drains the shards
            let (dummy, _rx) = mpsc::sync_channel(1);
            let old = std::mem::replace(&mut self.tx, dummy);
            drop(old);
            let _ = h.join();
        }
    }
}

fn dispatch_loop(
    config: CoordinatorConfig,
    pool: Arc<EnginePool>,
    rx: mpsc::Receiver<WorkItem>,
    metrics: Arc<Metrics>,
    down: Arc<AtomicBool>,
) {
    let mut batcher: Batcher<Result<Response>> =
        Batcher::new(config.batch_size.min(LANES), config.batch_max_wait);
    let mut next_tick = config.autopilot.as_ref().map(|ap| Instant::now() + ap.config().tick);
    loop {
        // wait until next batch deadline (or idle poll), bounded by the
        // next autopilot tick so steering keeps running while idle
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(20));
        let timeout = match next_tick {
            Some(t) => timeout.min(t.saturating_duration_since(Instant::now())),
            None => timeout,
        };
        match rx.recv_timeout(timeout) {
            Ok(item) => {
                handle_item(&config, &mut batcher, &metrics, item);
                // Drain everything already queued before flushing:
                // under backlog the oldest request is always past its
                // deadline, and flushing per-item would degrade batches
                // to size 1.
                while let Ok(item) = rx.try_recv() {
                    handle_item(&config, &mut batcher, &metrics, item);
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
        expire_due(&mut batcher, &metrics);
        flush_due(&pool, &mut batcher, &metrics);
        // drive the closed loop: one controller step per tick interval
        if let (Some(ap), Some(t)) = (&config.autopilot, &mut next_tick) {
            let now = Instant::now();
            if now >= *t {
                ap.tick(&metrics);
                *t = now + ap.config().tick;
            }
        }
    }
    // drain remaining batches before exit
    expire_due(&mut batcher, &metrics);
    let keys: Vec<ModelKey> = batcher.due(Instant::now() + Duration::from_secs(3600));
    for key in keys {
        while flush_model(&pool, &mut batcher, &metrics, key) {}
    }
    down.store(true, Ordering::Relaxed);
    // the dispatcher's pool handle drops here; the Coordinator's drops
    // right after the join, and the last handle drains the shards
}

/// Route one job to its model queue (batches are the unit of work, so
/// nothing executes here).
fn handle_item(
    config: &CoordinatorConfig,
    batcher: &mut Batcher<Result<Response>>,
    metrics: &Metrics,
    item: WorkItem,
) {
    let key = ModelKey::route(item.job.app(), item.quality);
    let inputs = match item.job {
        Job::Denoise { image } => vec![image],
        Job::Blend { p1, p2, alpha } => vec![p1, p2, Tensor::scalar(alpha)],
        Job::Classify { pixels } => {
            if pixels.len() != config.classify_row {
                metrics.record_error();
                let _ = item
                    .reply
                    .send(Err(anyhow!("classify row must be {} pixels", config.classify_row)));
                return;
            }
            vec![Tensor { shape: vec![1, config.classify_row], data: pixels }]
        }
    };
    batcher.push(
        key,
        Pending {
            inputs,
            reply: item.reply,
            enqueued: item.submitted,
            deadline: item.deadline,
            degraded: item.degraded,
            permit: item.permit,
        },
    );
}

/// Drop every queued entry whose deadline has passed — *before*
/// lane-packing — and answer each with a typed deadline-expired
/// response (its capacity permit releases with it).
fn expire_due(batcher: &mut Batcher<Result<Response>>, metrics: &Metrics) {
    for (key, p) in batcher.drop_expired(Instant::now()) {
        metrics.record_expired(key, ExpiredAt::Queue);
        let _ = p.reply.send(Err(anyhow::Error::new(Rejection::DeadlineExpired)));
    }
}

fn flush_due(pool: &EnginePool, batcher: &mut Batcher<Result<Response>>, metrics: &Metrics) {
    // loop until nothing is due: a burst can leave several *full*
    // batches queued behind one key, and waiting another
    // batch_max_wait per batch would idle the shards for no gain
    loop {
        let due = batcher.due(Instant::now());
        if due.is_empty() {
            break;
        }
        for key in due {
            flush_model(pool, batcher, metrics, key);
        }
    }
}

/// Hand one model's due batch to the least-loaded shard. Returns
/// whether a non-empty batch was flushed (the final drain loops until
/// each queue is empty).
fn flush_model(
    pool: &EnginePool,
    batcher: &mut Batcher<Result<Response>>,
    metrics: &Metrics,
    key: ModelKey,
) -> bool {
    let pendings = batcher.take_batch(key);
    if pendings.is_empty() {
        return false;
    }
    let size = pendings.len();
    let items: Vec<BatchItem> = pendings
        .into_iter()
        .map(|p| BatchItem {
            inputs: p.inputs,
            reply: p.reply,
            enqueued: p.enqueued,
            deadline: p.deadline,
            degraded: p.degraded,
            permit: p.permit,
        })
        .collect();
    if pool.submit(BatchJob { key, items }).is_err() {
        // pool gone: the dropped reply senders surface as disconnects
        // to the callers, but the in-flight accounting (submitted −
        // answered) must still balance or submit() would eventually
        // report Busy forever
        for _ in 0..size {
            metrics.record_error();
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockExecutor;

    fn mk(s: &str) -> ModelKey {
        ModelKey::parse(s).unwrap()
    }

    fn mock_coordinator(capacity: usize, delay_ms: u64) -> Coordinator {
        mock_coordinator_sharded(capacity, delay_ms, 1)
    }

    fn mock_coordinator_sharded(capacity: usize, delay_ms: u64, shards: usize) -> Coordinator {
        let cfg = CoordinatorConfig {
            queue_capacity: capacity,
            batch_size: 4,
            classify_row: 8,
            batch_max_wait: Duration::from_millis(2),
            shards,
            ..CoordinatorConfig::default()
        };
        Coordinator::start(cfg, move |_shard| {
            let mut m = MockExecutor::full_catalog();
            m.delay = Duration::from_millis(delay_ms);
            Ok(m)
        })
        .unwrap()
    }

    #[test]
    fn denoise_round_trip() {
        let c = mock_coordinator(8, 0);
        let t = c
            .submit(
                Job::Denoise { image: Tensor::vector(vec![10, 20, 30, 40]) },
                Quality::Balanced,
            )
            .unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.route, mk("gdf/ds16"));
        assert_eq!(r.outputs[0].data, vec![5, 10, 15, 20]);
        assert_eq!(c.metrics().completed(), 1);
    }

    #[test]
    fn denoise_keeps_request_shape() {
        // shape-carrying tensors survive the round trip (non-square)
        let c = mock_coordinator(8, 0);
        let img = Tensor::matrix(2, 3, vec![2, 4, 6, 8, 10, 12]).unwrap();
        let t = c.submit(Job::Denoise { image: img }, Quality::Precise).unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.route, mk("gdf/conv"));
        assert_eq!(r.outputs[0].shape, vec![2, 3]);
        assert_eq!(r.outputs[0].data, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn blend_routes_by_quality() {
        let c = mock_coordinator(8, 0);
        let t = c
            .submit(
                Job::Blend {
                    p1: Tensor::vector(vec![10, 20]),
                    p2: Tensor::vector(vec![30, 40]),
                    alpha: 64,
                },
                Quality::Economy,
            )
            .unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.route, mk("blend/ds32"));
        assert_eq!(r.outputs[0].data, vec![20, 30]);
    }

    #[test]
    fn classify_batches_and_scatters() {
        let c = mock_coordinator(32, 0);
        let tickets: Vec<Ticket> = (0..4)
            .map(|i| {
                c.submit(Job::Classify { pixels: vec![i * 2; 8] }, Quality::Precise).unwrap()
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let r = t.wait().unwrap();
            assert_eq!(r.route, mk("frnn/conv"));
            assert_eq!(r.outputs[0].data, vec![i as i32; 8]);
        }
        assert!(c.metrics().mean_batch_size() >= 1.0);
    }

    #[test]
    fn every_job_kind_batches() {
        // denoise jobs batch too now — 4 requests with a slow engine
        // should flush as fewer, larger batches
        let c = mock_coordinator(32, 5);
        let tickets: Vec<Ticket> = (0..8)
            .map(|i| {
                c.submit_blocking(
                    Job::Denoise { image: Tensor::vector(vec![i * 2, i * 2]) },
                    Quality::Precise,
                )
                .unwrap()
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let r = t.wait().unwrap();
            assert_eq!(r.outputs[0].data, vec![i as i32, i as i32]);
        }
        assert!(
            c.metrics().mean_batch_size() > 1.0,
            "denoise requests should share batches, got mean {}",
            c.metrics().mean_batch_size()
        );
    }

    #[test]
    fn batch_submission_api_round_trips() {
        let c = mock_coordinator(64, 0);
        let jobs: Vec<(Job, Quality)> = (0..6)
            .map(|i| {
                (
                    Job::Denoise { image: Tensor::vector(vec![i * 4]) },
                    Quality::Economy,
                )
            })
            .collect();
        let batch = c.submit_all(jobs).unwrap();
        assert_eq!(batch.len(), 6);
        let responses = batch.wait().unwrap();
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.route, mk("gdf/ds32"));
            assert_eq!(r.outputs[0].data, vec![i as i32 * 2]);
        }
    }

    #[test]
    fn sharded_coordinator_serves_concurrent_load() {
        let c = std::sync::Arc::new(mock_coordinator_sharded(256, 1, 4));
        let mut handles = Vec::new();
        for t in 0..8i32 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..8i32 {
                    let v = t * 16 + i * 2;
                    let ticket = c
                        .submit_blocking(
                            Job::Denoise { image: Tensor::vector(vec![v]) },
                            Quality::Balanced,
                        )
                        .unwrap();
                    let r = ticket.wait().unwrap();
                    assert_eq!(r.outputs[0].data, vec![v / 2]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.metrics().completed(), 64);
        assert_eq!(c.metrics().errors(), 0);
    }

    #[test]
    fn partial_batch_flushes_on_deadline() {
        let c = mock_coordinator(8, 0);
        let t = c.submit(Job::Classify { pixels: vec![6; 8] }, Quality::Balanced).unwrap();
        let r = t.wait_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(r.route, mk("frnn/th48ds16"));
        assert_eq!(r.outputs[0].data, vec![3; 8]);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // slow engine + tiny queue → Busy
        let c = mock_coordinator(1, 30);
        let _t1 = c
            .submit(Job::Denoise { image: Tensor::vector(vec![1]) }, Quality::Precise)
            .unwrap();
        let mut saw_busy = false;
        for _ in 0..50 {
            match c.submit(Job::Denoise { image: Tensor::vector(vec![1]) }, Quality::Precise) {
                Err(SubmitError::Busy) => {
                    saw_busy = true;
                    break;
                }
                Ok(_t) => {}
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(saw_busy, "bounded queue never pushed back");
        assert!(c.metrics().rejected() >= 1);
    }

    #[test]
    fn placed_coordinator_exposes_placement_and_residency() {
        use crate::coordinator::Placement;
        let keys = [mk("gdf/ds16"), mk("gdf/ds32")];
        let placement = Placement::spread(&keys, 2, 1)
            .assign(mk("gdf/ds16"), &[0])
            .unwrap()
            .assign(mk("gdf/ds32"), &[1])
            .unwrap();
        let cfg = CoordinatorConfig {
            queue_capacity: 32,
            batch_size: 4,
            classify_row: 8,
            batch_max_wait: Duration::from_millis(2),
            shards: 1, // ignored: the placement's shard count wins
            ..CoordinatorConfig::default()
        };
        let c = Coordinator::start_placed(cfg, placement, |_shard, assigned| {
            Ok(MockExecutor::new(assigned))
        })
        .unwrap();
        assert_eq!(c.placement().unwrap().shards(), 2);
        assert_eq!(c.registered_keys().unwrap(), vec![mk("gdf/ds16"), mk("gdf/ds32")]);
        let resident = c.resident_keys().unwrap();
        assert_eq!(resident[0], vec![mk("gdf/ds16")]);
        assert_eq!(resident[1], vec![mk("gdf/ds32")]);
        // requests route by quality to both subsets and round-trip
        for (q, want) in [(Quality::Balanced, "gdf/ds16"), (Quality::Economy, "gdf/ds32")] {
            let t = c
                .submit(Job::Denoise { image: Tensor::vector(vec![8, 4]) }, q)
                .unwrap();
            let r = t.wait().unwrap();
            assert_eq!(r.route, mk(want));
            assert_eq!(r.outputs[0].data, vec![4, 2]);
        }
        assert_eq!(c.metrics().spills(), 0);
    }

    #[test]
    fn bad_classify_row_errors() {
        let c = mock_coordinator(8, 0);
        let t = c.submit(Job::Classify { pixels: vec![1, 2] }, Quality::Precise).unwrap();
        assert!(t.wait().is_err());
        assert_eq!(c.metrics().errors(), 1);
    }

    /// Permits release moments *after* the reply is scattered; spin
    /// briefly instead of racing the shard thread.
    fn wait_idle(c: &Coordinator) {
        for _ in 0..500 {
            if c.admission().in_flight() == 0 {
                return;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        panic!("admission permits leaked: {} still held", c.admission().in_flight());
    }

    #[test]
    fn already_expired_deadline_rejects_at_admission() {
        let c = mock_coordinator(8, 0);
        let r = c.submit_deadline(
            Job::Denoise { image: Tensor::vector(vec![2]) },
            Quality::Balanced,
            Instant::now() - Duration::from_millis(1),
        );
        assert_eq!(r.err(), Some(SubmitError::Expired));
        // refused before touching any queue: never submitted, no permit
        assert_eq!(c.metrics().expired_at(ExpiredAt::Admission), 1);
        assert_eq!(c.metrics().submitted(), 0);
        assert_eq!(c.admission().in_flight(), 0);
        // the coordinator still serves afterwards
        let t = c
            .submit(Job::Denoise { image: Tensor::vector(vec![4]) }, Quality::Balanced)
            .unwrap();
        assert_eq!(t.wait().unwrap().outputs[0].data, vec![2]);
    }

    #[test]
    fn deadline_expiring_while_queued_is_a_typed_response_not_a_hang() {
        // batch never fills and max_wait is long, so the entry sits
        // queued past its deadline; the dispatcher must answer it with
        // a typed expiry instead of shipping it to a shard (or hanging)
        let cfg = CoordinatorConfig {
            queue_capacity: 8,
            batch_size: 64,
            classify_row: 8,
            batch_max_wait: Duration::from_millis(40),
            shards: 1,
            ..CoordinatorConfig::default()
        };
        let c = Coordinator::start(cfg, |_shard| Ok(MockExecutor::full_catalog())).unwrap();
        let t = c
            .submit_deadline(
                Job::Denoise { image: Tensor::vector(vec![6]) },
                Quality::Balanced,
                Instant::now() + Duration::from_millis(5),
            )
            .unwrap();
        let err = t.wait_timeout(Duration::from_secs(2)).unwrap_err();
        assert_eq!(err.downcast_ref::<Rejection>(), Some(&Rejection::DeadlineExpired));
        assert_eq!(c.metrics().expired_at(ExpiredAt::Queue), 1);
        assert_eq!(c.metrics().completed(), 0);
        wait_idle(&c); // the expiry released its capacity permit
    }

    #[test]
    fn degrade_policy_reroutes_overload_to_the_lower_tier() {
        // cap 2 with fair_share 0.5 → each key holds at most 1 permit.
        // A slow shard keeps the first request's permit held, so the
        // second balanced request must admit one tier down.
        let cfg = CoordinatorConfig {
            queue_capacity: 2,
            batch_size: 4,
            classify_row: 8,
            batch_max_wait: Duration::from_millis(1),
            shards: 1,
            overload: OverloadPolicy::Degrade,
            fair_share: 0.5,
            autopilot: None,
        };
        let c = Coordinator::start(cfg, |_shard| {
            let mut m = MockExecutor::full_catalog();
            m.delay = Duration::from_millis(30);
            Ok(m)
        })
        .unwrap();
        let a = c
            .submit_blocking(Job::Denoise { image: Tensor::vector(vec![8, 4]) }, Quality::Balanced)
            .unwrap();
        let b = c
            .submit_blocking(Job::Denoise { image: Tensor::vector(vec![8, 4]) }, Quality::Balanced)
            .unwrap();
        // with both tiers' permits held, degrade falls back to shedding
        // (it never waits) — the third submit resolves immediately
        let e = c.submit_blocking(
            Job::Denoise { image: Tensor::vector(vec![2]) },
            Quality::Balanced,
        );
        assert_eq!(e.err(), Some(SubmitError::Shed));
        let ra = a.wait().unwrap();
        assert_eq!(ra.route, mk("gdf/ds16"));
        assert!(!ra.degraded);
        let rb = b.wait().unwrap();
        assert_eq!(rb.route, mk("gdf/ds32"), "second request degraded one tier down");
        assert!(rb.degraded);
        assert_eq!(rb.outputs[0].data, vec![4, 2]);
        assert_eq!(c.metrics().degrades(), 1);
        assert_eq!(c.metrics().degrade_counts()[&(mk("gdf/ds16"), mk("gdf/ds32"))], 1);
        assert_eq!(c.metrics().shed(), 1);
    }

    #[test]
    fn reject_policy_sheds_blocking_submitters_typed() {
        let cfg = CoordinatorConfig {
            queue_capacity: 1,
            batch_size: 4,
            classify_row: 8,
            batch_max_wait: Duration::from_millis(1),
            shards: 1,
            overload: OverloadPolicy::Reject,
            fair_share: 1.0,
            autopilot: None,
        };
        let c = Coordinator::start(cfg, |_shard| {
            let mut m = MockExecutor::full_catalog();
            m.delay = Duration::from_millis(30);
            Ok(m)
        })
        .unwrap();
        let a = c
            .submit_blocking(Job::Denoise { image: Tensor::vector(vec![4]) }, Quality::Economy)
            .unwrap();
        let e = c.submit_blocking(
            Job::Denoise { image: Tensor::vector(vec![4]) },
            Quality::Economy,
        );
        assert_eq!(e.err(), Some(SubmitError::Shed));
        assert_eq!(c.metrics().shed(), 1);
        assert!(a.wait().is_ok());
    }

    #[test]
    fn submit_all_keeps_refused_jobs_observable() {
        // under a shedding policy, a refused mid-batch job must not
        // discard its admitted batch-mates' tickets — it keeps its slot
        // as a typed rejection
        let cfg = CoordinatorConfig {
            queue_capacity: 1,
            batch_size: 4,
            classify_row: 8,
            batch_max_wait: Duration::from_millis(1),
            shards: 1,
            overload: OverloadPolicy::Reject,
            fair_share: 1.0,
            autopilot: None,
        };
        let c = Coordinator::start(cfg, |_shard| {
            let mut m = MockExecutor::full_catalog();
            m.delay = Duration::from_millis(20);
            Ok(m)
        })
        .unwrap();
        let batch = c
            .submit_all((0..3).map(|i| {
                (Job::Denoise { image: Tensor::vector(vec![i * 2]) }, Quality::Economy)
            }))
            .unwrap();
        assert_eq!(batch.len(), 3, "refused jobs keep their slot");
        let results = batch.wait_each();
        let answered = results.iter().filter(|r| r.is_ok()).count();
        let shed = results
            .iter()
            .filter(|r| {
                r.as_ref().err().and_then(|e| e.downcast_ref::<Rejection>())
                    == Some(&Rejection::Shed)
            })
            .count();
        assert_eq!(answered, 1, "cap 1 admits exactly the first job");
        assert_eq!(shed, 2, "the refused jobs resolve as typed sheds");
        assert_eq!(c.metrics().shed(), 2);
    }

    #[test]
    fn report_counters_reconcile_with_submitted() {
        let c = mock_coordinator(16, 1);
        // answered
        let batch = c
            .submit_all((0..6).map(|i| {
                (Job::Denoise { image: Tensor::vector(vec![i * 2]) }, Quality::Economy)
            }))
            .unwrap();
        batch.wait().unwrap();
        // a routing error
        let t = c.submit(Job::Classify { pixels: vec![1, 2] }, Quality::Precise).unwrap();
        assert!(t.wait().is_err());
        // a tight deadline: answered or expired, either way terminal
        let t = c
            .submit_deadline(
                Job::Denoise { image: Tensor::vector(vec![2]) },
                Quality::Economy,
                Instant::now() + Duration::from_millis(1),
            )
            .unwrap();
        let _ = t.wait_timeout(Duration::from_secs(2));
        // an admission-stage expiry: never counted as submitted
        let r = c.submit_deadline(
            Job::Denoise { image: Tensor::vector(vec![2]) },
            Quality::Economy,
            Instant::now() - Duration::from_millis(1),
        );
        assert_eq!(r.err(), Some(SubmitError::Expired));
        // every submitted request resolved in exactly one bucket
        let m = c.metrics();
        assert_eq!(m.submitted(), 8);
        assert_eq!(
            m.submitted(),
            m.completed()
                + m.errors()
                + m.expired_at(ExpiredAt::Queue)
                + m.expired_at(ExpiredAt::Shard)
        );
        assert_eq!(m.in_flight(), 0);
        wait_idle(&c);
        // ...and the report surfaces the admission counters
        let rep = m.report();
        assert!(rep.contains("admission: peak_in_flight="), "{rep}");
        assert!(rep.contains("wait_p50="), "{rep}");
    }

    #[test]
    fn submit_errors_are_displayable_with_stable_wire_names() {
        let all = [
            SubmitError::Busy,
            SubmitError::Shed,
            SubmitError::Expired,
            SubmitError::Down,
        ];
        assert_eq!(
            all.map(SubmitError::wire_name),
            ["busy", "shed", "expired", "down"]
        );
        for e in all {
            assert!(!e.to_string().is_empty());
        }
        // shed vs expired stay distinguishable through an anyhow chain
        let chained = anyhow::Error::new(SubmitError::Shed);
        assert_eq!(chained.downcast_ref::<SubmitError>(), Some(&SubmitError::Shed));
    }
}
