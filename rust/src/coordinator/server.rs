//! The coordinator: request routing, quality policy, backpressure,
//! dynamic batching, metrics — in front of the engine thread.
//!
//! Routing is fully typed: a [`Job`] names its [`App`], the request's
//! [`Quality`] picks the [`crate::catalog::PpcConfig`] through
//! [`ModelKey::route`], and that one [`ModelKey`] travels unchanged
//! through the batcher, the engine and the response — the same key the
//! registry was populated under, so there is no string matching
//! anywhere between a request and its datapath.

use super::batcher::{Batcher, Pending};
use super::engine::{Engine, Executor};
use super::metrics::Metrics;
use crate::catalog::{App, ModelKey, Quality, Tensor};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::time::{Duration, Instant};

/// A unit of work.
#[derive(Clone, Debug)]
pub enum Job {
    /// Gaussian-denoise an image (`[h, w]` tensor; non-square welcome).
    Denoise { image: Tensor },
    /// Blend two shape-identical images with quantized alpha in [0, 127].
    Blend { p1: Tensor, p2: Tensor, alpha: i32 },
    /// Classify one face (one 960-pixel row; the batcher builds the
    /// `[batch, 960]` tensor).
    Classify { pixels: Vec<i32> },
}

impl Job {
    fn app(&self) -> App {
        match self {
            Job::Denoise { .. } => App::Gdf,
            Job::Blend { .. } => App::Blend,
            Job::Classify { .. } => App::Frnn,
        }
    }
}

/// Completed result.
#[derive(Clone, Debug)]
pub struct Response {
    pub outputs: Vec<Tensor>,
    /// The catalog key that served the request.
    pub route: ModelKey,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded queue full — caller should back off.
    Busy,
    /// Coordinator shut down.
    Down,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Bounded submit queue (backpressure boundary).
    pub queue_capacity: usize,
    /// FRNN batch dimension.
    pub batch_size: usize,
    /// FRNN input row length.
    pub classify_row: usize,
    /// Max time a classify request waits for batch-mates.
    pub batch_max_wait: Duration,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            queue_capacity: 64,
            batch_size: 16,
            classify_row: 960,
            batch_max_wait: Duration::from_millis(2),
        }
    }
}

struct WorkItem {
    job: Job,
    quality: Quality,
    reply: mpsc::Sender<Result<Response>>,
    submitted: Instant,
}

/// Handle to an in-flight request.
pub struct Ticket {
    rx: mpsc::Receiver<Result<Response>>,
}

impl Ticket {
    pub fn wait(self) -> Result<Response> {
        self.rx.recv().map_err(|_| anyhow!("coordinator dropped request"))?
    }
    pub fn wait_timeout(self, d: Duration) -> Result<Response> {
        self.rx
            .recv_timeout(d)
            .map_err(|_| anyhow!("timeout waiting for response"))?
    }
}

/// The coordinator front-end.
pub struct Coordinator {
    tx: mpsc::SyncSender<WorkItem>,
    metrics: Arc<Metrics>,
    down: Arc<AtomicBool>,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start with a custom executor factory (runs on the engine thread).
    pub fn start<E, F>(config: CoordinatorConfig, factory: F) -> Result<Coordinator>
    where
        E: Executor,
        F: FnOnce() -> Result<E> + Send + 'static,
    {
        let engine = Engine::spawn(factory)?;
        let (tx, rx) = mpsc::sync_channel::<WorkItem>(config.queue_capacity);
        let metrics = Arc::new(Metrics::new());
        let down = Arc::new(AtomicBool::new(false));
        let m = metrics.clone();
        let d = down.clone();
        let dispatcher = std::thread::Builder::new()
            .name("ppc-dispatch".into())
            .spawn(move || dispatch_loop(config, engine, rx, m, d))?;
        Ok(Coordinator { tx, metrics, down, dispatcher: Some(dispatcher) })
    }

    /// Start against the artifact directory (PJRT path; needs the
    /// `pjrt` cargo feature — without it the engine factory fails with
    /// a clear error pointing at [`Coordinator::with_native`]).
    pub fn with_artifacts(dir: &std::path::Path, config: CoordinatorConfig) -> Result<Coordinator> {
        let dir = dir.to_path_buf();
        Coordinator::start(config, move || crate::runtime::Runtime::load(&dir))
    }

    /// Start over the native netlist backend: the synthesized PPC
    /// blocks are the execution engine, no XLA/Python anywhere on the
    /// path. Build the executor (and pay its synthesis or cache-load
    /// time) before the coordinator threads spin up.
    pub fn with_native(
        config: CoordinatorConfig,
        executor: crate::runtime::NativeExecutor,
    ) -> Result<Coordinator> {
        Coordinator::start(config, move || Ok(executor))
    }

    /// Submit a job; `Err(Busy)` when the bounded queue is full.
    pub fn submit(&self, job: Job, quality: Quality) -> Result<Ticket, SubmitError> {
        if self.down.load(Ordering::Relaxed) {
            return Err(SubmitError::Down);
        }
        let (reply, rx) = mpsc::channel();
        let item = WorkItem { job, quality, reply, submitted: Instant::now() };
        match self.tx.try_send(item) {
            Ok(()) => Ok(Ticket { rx }),
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.record_rejected();
                Err(SubmitError::Busy)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Down),
        }
    }

    /// Blocking submit (waits for queue space).
    pub fn submit_blocking(&self, job: Job, quality: Quality) -> Result<Ticket, SubmitError> {
        let (reply, rx) = mpsc::channel();
        let item = WorkItem { job, quality, reply, submitted: Instant::now() };
        self.tx.send(item).map_err(|_| SubmitError::Down)?;
        Ok(Ticket { rx })
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.down.store(true, Ordering::Relaxed);
        // close the channel by replacing tx? dropping self.tx happens
        // after dispatcher join; force-disconnect by taking the handle
        // only after the sender is dropped — so drop order: we can't
        // drop tx early (borrowed), but dispatcher exits when all
        // senders are gone; the handle join happens in a scoped drop:
        if let Some(h) = self.dispatcher.take() {
            // replace tx with a dummy to disconnect the queue
            let (dummy, _rx) = mpsc::sync_channel(1);
            let old = std::mem::replace(&mut self.tx, dummy);
            drop(old);
            let _ = h.join();
        }
    }
}

fn dispatch_loop(
    config: CoordinatorConfig,
    engine: Engine,
    rx: mpsc::Receiver<WorkItem>,
    metrics: Arc<Metrics>,
    down: Arc<AtomicBool>,
) {
    let mut batcher: Batcher<Result<Response>> =
        Batcher::new(config.batch_size, config.classify_row, config.batch_max_wait);
    loop {
        // wait until next batch deadline (or idle poll)
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(20));
        match rx.recv_timeout(timeout) {
            Ok(item) => {
                handle_item(&config, &engine, &mut batcher, &metrics, item);
                // Drain everything already queued before flushing:
                // under backlog the oldest classify is always past its
                // deadline, and flushing per-item would degrade batches
                // to size 1.
                while let Ok(item) = rx.try_recv() {
                    handle_item(&config, &engine, &mut batcher, &metrics, item);
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
        flush_due(&engine, &mut batcher, &metrics);
    }
    // drain remaining batches before exit
    let keys: Vec<ModelKey> = batcher.due(Instant::now() + Duration::from_secs(3600));
    for key in keys {
        flush_model(&engine, &mut batcher, &metrics, key);
    }
    down.store(true, Ordering::Relaxed);
}

fn handle_item(
    config: &CoordinatorConfig,
    engine: &Engine,
    batcher: &mut Batcher<Result<Response>>,
    metrics: &Metrics,
    item: WorkItem,
) {
    let key = ModelKey::route(item.job.app(), item.quality);
    match item.job {
        Job::Denoise { image } => {
            let result = engine
                .exec(key, vec![image])
                .map(|outputs| Response { outputs, route: key });
            if result.is_err() {
                metrics.record_error();
            } else {
                metrics.record_latency(&key.to_string(), item.submitted.elapsed());
            }
            let _ = item.reply.send(result);
        }
        Job::Blend { p1, p2, alpha } => {
            let result = engine
                .exec(key, vec![p1, p2, Tensor::scalar(alpha)])
                .map(|outputs| Response { outputs, route: key });
            if result.is_err() {
                metrics.record_error();
            } else {
                metrics.record_latency(&key.to_string(), item.submitted.elapsed());
            }
            let _ = item.reply.send(result);
        }
        Job::Classify { pixels } => {
            if pixels.len() != config.classify_row {
                metrics.record_error();
                let _ = item
                    .reply
                    .send(Err(anyhow!("classify row must be {} pixels", config.classify_row)));
                return;
            }
            batcher.push(
                key,
                Pending { input: pixels, reply: item.reply, enqueued: item.submitted },
            );
        }
    }
}

fn flush_due(engine: &Engine, batcher: &mut Batcher<Result<Response>>, metrics: &Metrics) {
    for key in batcher.due(Instant::now()) {
        flush_model(engine, batcher, metrics, key);
    }
}

fn flush_model(
    engine: &Engine,
    batcher: &mut Batcher<Result<Response>>,
    metrics: &Metrics,
    key: ModelKey,
) {
    let (pendings, flat) = batcher.take_batch(key);
    if pendings.is_empty() {
        return;
    }
    metrics.record_batch(pendings.len());
    let rows = batcher.batch_size;
    let batch = Tensor { shape: vec![rows, batcher.row_len], data: flat };
    match engine.exec(key, vec![batch]) {
        Ok(outputs) => {
            // outputs[0] is [batch, out_row]; scatter rows back
            let out = &outputs[0];
            let out_row = if out.shape.len() == 2 {
                out.shape[1]
            } else {
                out.data.len() / rows
            };
            for (i, p) in pendings.into_iter().enumerate() {
                let row = out.data[i * out_row..(i + 1) * out_row].to_vec();
                metrics.record_latency(&key.to_string(), p.enqueued.elapsed());
                let _ = p
                    .reply
                    .send(Ok(Response { outputs: vec![Tensor::vector(row)], route: key }));
            }
        }
        Err(e) => {
            let msg = format!("{e:#}");
            for p in pendings {
                metrics.record_error();
                let _ = p.reply.send(Err(anyhow!("{msg}")));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockExecutor;

    fn mk(s: &str) -> ModelKey {
        ModelKey::parse(s).unwrap()
    }

    fn mock_coordinator(capacity: usize, delay_ms: u64) -> Coordinator {
        let cfg = CoordinatorConfig {
            queue_capacity: capacity,
            batch_size: 4,
            classify_row: 8,
            batch_max_wait: Duration::from_millis(2),
        };
        Coordinator::start(cfg, move || {
            let mut m = MockExecutor::full_catalog();
            m.delay = Duration::from_millis(delay_ms);
            Ok(m)
        })
        .unwrap()
    }

    #[test]
    fn denoise_round_trip() {
        let c = mock_coordinator(8, 0);
        let t = c
            .submit(
                Job::Denoise { image: Tensor::vector(vec![10, 20, 30, 40]) },
                Quality::Balanced,
            )
            .unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.route, mk("gdf/ds16"));
        assert_eq!(r.outputs[0].data, vec![5, 10, 15, 20]);
        assert_eq!(c.metrics().completed(), 1);
    }

    #[test]
    fn denoise_keeps_request_shape() {
        // shape-carrying tensors survive the round trip (non-square)
        let c = mock_coordinator(8, 0);
        let img = Tensor::matrix(2, 3, vec![2, 4, 6, 8, 10, 12]).unwrap();
        let t = c.submit(Job::Denoise { image: img }, Quality::Precise).unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.route, mk("gdf/conv"));
        assert_eq!(r.outputs[0].shape, vec![2, 3]);
        assert_eq!(r.outputs[0].data, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn blend_routes_by_quality() {
        let c = mock_coordinator(8, 0);
        let t = c
            .submit(
                Job::Blend {
                    p1: Tensor::vector(vec![10, 20]),
                    p2: Tensor::vector(vec![30, 40]),
                    alpha: 64,
                },
                Quality::Economy,
            )
            .unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.route, mk("blend/ds32"));
        assert_eq!(r.outputs[0].data, vec![20, 30]);
    }

    #[test]
    fn classify_batches_and_scatters() {
        let c = mock_coordinator(32, 0);
        let tickets: Vec<Ticket> = (0..4)
            .map(|i| {
                c.submit(Job::Classify { pixels: vec![i * 2; 8] }, Quality::Precise).unwrap()
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let r = t.wait().unwrap();
            assert_eq!(r.route, mk("frnn/conv"));
            assert_eq!(r.outputs[0].data, vec![i as i32; 8]);
        }
        assert!(c.metrics().mean_batch_size() >= 1.0);
    }

    #[test]
    fn partial_batch_flushes_on_deadline() {
        let c = mock_coordinator(8, 0);
        let t = c.submit(Job::Classify { pixels: vec![6; 8] }, Quality::Balanced).unwrap();
        let r = t.wait_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(r.route, mk("frnn/th48ds16"));
        assert_eq!(r.outputs[0].data, vec![3; 8]);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // slow engine + tiny queue → Busy
        let c = mock_coordinator(1, 30);
        let _t1 = c
            .submit(Job::Denoise { image: Tensor::vector(vec![1]) }, Quality::Precise)
            .unwrap();
        let mut saw_busy = false;
        for _ in 0..50 {
            match c.submit(Job::Denoise { image: Tensor::vector(vec![1]) }, Quality::Precise) {
                Err(SubmitError::Busy) => {
                    saw_busy = true;
                    break;
                }
                Ok(_t) => {}
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(saw_busy, "bounded queue never pushed back");
        assert!(c.metrics().rejected() >= 1);
    }

    #[test]
    fn bad_classify_row_errors() {
        let c = mock_coordinator(8, 0);
        let t = c.submit(Job::Classify { pixels: vec![1, 2] }, Quality::Precise).unwrap();
        assert!(t.wait().is_err());
        assert_eq!(c.metrics().errors(), 1);
    }
}
