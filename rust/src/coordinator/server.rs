//! The coordinator: request routing, quality policy, backpressure,
//! dynamic batching, metrics — in front of the sharded engine pool.
//!
//! Routing is fully typed: a [`Job`] names its [`App`], the request's
//! [`Quality`] picks the [`crate::catalog::PpcConfig`] through
//! [`ModelKey::route`], and that one [`ModelKey`] travels unchanged
//! through the batcher, the shard and the response — the same key the
//! registry was populated under, so there is no string matching
//! anywhere between a request and its datapath.
//!
//! Batches — not single requests — are the unit of work: every job
//! type queues in the [`Batcher`] under its routed key, and due
//! batches are routed whole to the least-loaded [`EnginePool`] shard,
//! whose lane-batched [`crate::catalog::Datapath::exec_batch`] path
//! packs the requests into the 64-way bit-sliced netlist evaluator.
//! The dispatcher never blocks on model execution; shards scatter the
//! per-request replies themselves.

use super::batcher::{Batcher, Pending};
use super::engine::{BatchItem, BatchJob, EnginePool, Executor};
use super::metrics::Metrics;
use super::placement::Placement;
use crate::catalog::{App, ModelKey, Quality, Tensor, LANES};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// A unit of work.
#[derive(Clone, Debug)]
pub enum Job {
    /// Gaussian-denoise an image (`[h, w]` tensor; non-square welcome).
    Denoise { image: Tensor },
    /// Blend two shape-identical images with quantized alpha in [0, 127].
    Blend { p1: Tensor, p2: Tensor, alpha: i32 },
    /// Classify one face (one 960-pixel row; the batcher pools rows
    /// into lane-batched `[1, 960]` requests).
    Classify { pixels: Vec<i32> },
}

impl Job {
    fn app(&self) -> App {
        match self {
            Job::Denoise { .. } => App::Gdf,
            Job::Blend { .. } => App::Blend,
            Job::Classify { .. } => App::Frnn,
        }
    }
}

/// Completed result.
#[derive(Clone, Debug)]
pub struct Response {
    pub outputs: Vec<Tensor>,
    /// The catalog key that served the request.
    pub route: ModelKey,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SubmitError {
    /// Bounded queue full — caller should back off.
    Busy,
    /// Coordinator shut down.
    Down,
}

/// Coordinator configuration.
#[derive(Clone, Debug)]
pub struct CoordinatorConfig {
    /// Bounded submit queue (backpressure boundary).
    pub queue_capacity: usize,
    /// Max requests lane-packed into one batch (clamped to
    /// [`LANES`] — the word width of the bit-sliced evaluator).
    pub batch_size: usize,
    /// Classify input row length (validated at routing time so a
    /// malformed row fails fast instead of poisoning a batch).
    pub classify_row: usize,
    /// Max time a request waits for batch-mates.
    pub batch_max_wait: Duration,
    /// Engine shards; each owns its own executor instance.
    pub shards: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            queue_capacity: 64,
            batch_size: 16,
            classify_row: 960,
            batch_max_wait: Duration::from_millis(2),
            shards: std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(4),
        }
    }
}

struct WorkItem {
    job: Job,
    quality: Quality,
    reply: mpsc::Sender<Result<Response>>,
    submitted: Instant,
}

/// Handle to an in-flight request.
pub struct Ticket {
    rx: mpsc::Receiver<Result<Response>>,
}

impl Ticket {
    pub fn wait(self) -> Result<Response> {
        self.rx.recv().map_err(|_| anyhow!("coordinator dropped request"))?
    }
    pub fn wait_timeout(self, d: Duration) -> Result<Response> {
        self.rx
            .recv_timeout(d)
            .map_err(|_| anyhow!("timeout waiting for response"))?
    }
}

/// Handle to a whole in-flight batch of requests (one future per
/// request, awaited together).
pub struct BatchTicket {
    tickets: Vec<Ticket>,
}

impl BatchTicket {
    pub fn len(&self) -> usize {
        self.tickets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.tickets.is_empty()
    }

    /// Await every response, in submission order. Fails on the first
    /// failed request.
    pub fn wait(self) -> Result<Vec<Response>> {
        self.tickets.into_iter().map(|t| t.wait()).collect()
    }

    /// Await every response, keeping per-request results.
    pub fn wait_each(self) -> Vec<Result<Response>> {
        self.tickets.into_iter().map(|t| t.wait()).collect()
    }
}

/// The coordinator front-end.
pub struct Coordinator {
    tx: mpsc::SyncSender<WorkItem>,
    metrics: Arc<Metrics>,
    /// Shared with the dispatcher thread so catalog/residency queries
    /// ([`Coordinator::registered_keys`], [`Coordinator::resident_keys`])
    /// don't have to round-trip through the work queue.
    pool: Arc<EnginePool>,
    down: Arc<AtomicBool>,
    /// Max in-flight requests before [`Coordinator::submit`] pushes
    /// back (the dispatcher never blocks on execution anymore, so the
    /// submit queue alone cannot provide backpressure).
    in_flight_cap: u64,
    dispatcher: Option<std::thread::JoinHandle<()>>,
}

impl Coordinator {
    /// Start with a custom executor factory: `factory(shard_index)`
    /// runs on each of `config.shards` shard threads and builds that
    /// shard's executor (the whole catalog on every shard).
    pub fn start<E, F>(config: CoordinatorConfig, factory: F) -> Result<Coordinator>
    where
        E: Executor + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let pool = EnginePool::spawn(config.shards, metrics.clone(), factory)?;
        Coordinator::run(config, pool, metrics)
    }

    /// Start under sticky `placement`: `factory(shard_index,
    /// assigned_keys)` builds each shard's model *subset* on the
    /// shard's own thread (placement's shard count wins over
    /// `config.shards`). Batches route sticky-first with spill; shards
    /// receiving off-subset traffic lazily register the model.
    pub fn start_placed<E, F>(
        config: CoordinatorConfig,
        placement: Placement,
        factory: F,
    ) -> Result<Coordinator>
    where
        E: Executor + 'static,
        F: Fn(usize, &[ModelKey]) -> Result<E> + Send + Sync + 'static,
    {
        let metrics = Arc::new(Metrics::new());
        let pool = EnginePool::spawn_placed(placement, metrics.clone(), factory)?;
        Coordinator::run(config, pool, metrics)
    }

    fn run(
        config: CoordinatorConfig,
        pool: EnginePool,
        metrics: Arc<Metrics>,
    ) -> Result<Coordinator> {
        let pool = Arc::new(pool);
        let (tx, rx) = mpsc::sync_channel::<WorkItem>(config.queue_capacity);
        let down = Arc::new(AtomicBool::new(false));
        let m = metrics.clone();
        let d = down.clone();
        let p = pool.clone();
        let in_flight_cap = config.queue_capacity as u64;
        let dispatcher = std::thread::Builder::new()
            .name("ppc-dispatch".into())
            .spawn(move || dispatch_loop(config, p, rx, m, d))?;
        Ok(Coordinator { tx, metrics, pool, down, in_flight_cap, dispatcher: Some(dispatcher) })
    }

    /// Start against the artifact directory (PJRT path; needs the
    /// `pjrt` cargo feature — without it the shard factory fails with
    /// a clear error pointing at [`Coordinator::with_native`]). The
    /// PJRT client is heavyweight, so this backend always runs one
    /// shard regardless of `config.shards`.
    pub fn with_artifacts(dir: &std::path::Path, config: CoordinatorConfig) -> Result<Coordinator> {
        let dir = dir.to_path_buf();
        let config = CoordinatorConfig { shards: 1, ..config };
        Coordinator::start(config, move |_shard| crate::runtime::Runtime::load(&dir))
    }

    /// Start over a single pre-built native executor: the synthesized
    /// PPC blocks are the execution engine, no XLA/Python anywhere on
    /// the path. One shard (the executor is moved onto it); use
    /// [`Coordinator::with_native_sharded`] to fan the catalog out
    /// over several shards.
    pub fn with_native(
        config: CoordinatorConfig,
        executor: crate::runtime::NativeExecutor,
    ) -> Result<Coordinator> {
        let config = CoordinatorConfig { shards: 1, ..config };
        let cell = Mutex::new(Some(executor));
        Coordinator::start(config, move |_shard| {
            cell.lock()
                .unwrap()
                .take()
                .ok_or_else(|| anyhow!("single-shard executor already taken"))
        })
    }

    /// Start a sharded native pool: `build(shard_index)` constructs one
    /// [`crate::runtime::NativeExecutor`] per shard, on the shard's own
    /// thread. Point every build at the same persistent netlist cache
    /// and only the first pays synthesis — the rest load BLIF.
    pub fn with_native_sharded<F>(config: CoordinatorConfig, build: F) -> Result<Coordinator>
    where
        F: Fn(usize) -> Result<crate::runtime::NativeExecutor> + Send + Sync + 'static,
    {
        Coordinator::start(config, build)
    }

    /// Start a sticky-placed native pool: `build(shard_index,
    /// assigned_keys)` constructs each shard's subset
    /// [`crate::runtime::NativeExecutor`] (declare the full catalog,
    /// [`crate::runtime::NativeExecutor::with_keys`] the assignment) on
    /// the shard's own thread.
    pub fn with_native_placed<F>(
        config: CoordinatorConfig,
        placement: Placement,
        build: F,
    ) -> Result<Coordinator>
    where
        F: Fn(usize, &[ModelKey]) -> Result<crate::runtime::NativeExecutor>
            + Send
            + Sync
            + 'static,
    {
        Coordinator::start_placed(config, placement, build)
    }

    /// The servable catalog: the union of every live shard's keys.
    pub fn registered_keys(&self) -> Result<Vec<ModelKey>> {
        self.pool.keys()
    }

    /// Per-shard resident (built) model keys — under sticky placement,
    /// each shard's assigned subset plus anything it lazily registered.
    pub fn resident_keys(&self) -> Result<Vec<Vec<ModelKey>>> {
        self.pool.resident_keys()
    }

    /// The sticky placement the engine pool routes with, if any.
    pub fn placement(&self) -> Option<&Placement> {
        self.pool.placement()
    }

    /// Submit a job; `Err(Busy)` when more than `queue_capacity`
    /// requests are already in flight — the backpressure boundary.
    pub fn submit(&self, job: Job, quality: Quality) -> Result<Ticket, SubmitError> {
        if self.down.load(Ordering::Relaxed) {
            return Err(SubmitError::Down);
        }
        if self.metrics.in_flight() >= self.in_flight_cap {
            self.metrics.record_rejected();
            return Err(SubmitError::Busy);
        }
        let (reply, rx) = mpsc::channel();
        let item = WorkItem { job, quality, reply, submitted: Instant::now() };
        match self.tx.try_send(item) {
            Ok(()) => {
                self.metrics.record_submitted();
                Ok(Ticket { rx })
            }
            Err(mpsc::TrySendError::Full(_)) => {
                self.metrics.record_rejected();
                Err(SubmitError::Busy)
            }
            Err(mpsc::TrySendError::Disconnected(_)) => Err(SubmitError::Down),
        }
    }

    /// Blocking submit (waits for queue space; never `Busy`).
    pub fn submit_blocking(&self, job: Job, quality: Quality) -> Result<Ticket, SubmitError> {
        let (reply, rx) = mpsc::channel();
        let item = WorkItem { job, quality, reply, submitted: Instant::now() };
        self.tx.send(item).map_err(|_| SubmitError::Down)?;
        self.metrics.record_submitted();
        Ok(Ticket { rx })
    }

    /// Submit a whole batch of jobs and await them together: the batch
    /// future of the reworked serving API. Jobs routed to the same
    /// [`ModelKey`] lane-pack into shared netlist passes.
    pub fn submit_all(
        &self,
        jobs: impl IntoIterator<Item = (Job, Quality)>,
    ) -> Result<BatchTicket, SubmitError> {
        let mut tickets = Vec::new();
        for (job, quality) in jobs {
            tickets.push(self.submit_blocking(job, quality)?);
        }
        Ok(BatchTicket { tickets })
    }

    pub fn metrics(&self) -> &Metrics {
        &self.metrics
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.down.store(true, Ordering::Relaxed);
        if let Some(h) = self.dispatcher.take() {
            // replace tx with a dummy to disconnect the queue; the
            // dispatcher drains what's left, flushes every open batch
            // to the pool, and the pool's drop drains the shards
            let (dummy, _rx) = mpsc::sync_channel(1);
            let old = std::mem::replace(&mut self.tx, dummy);
            drop(old);
            let _ = h.join();
        }
    }
}

fn dispatch_loop(
    config: CoordinatorConfig,
    pool: Arc<EnginePool>,
    rx: mpsc::Receiver<WorkItem>,
    metrics: Arc<Metrics>,
    down: Arc<AtomicBool>,
) {
    let mut batcher: Batcher<Result<Response>> =
        Batcher::new(config.batch_size.min(LANES), config.batch_max_wait);
    loop {
        // wait until next batch deadline (or idle poll)
        let timeout = batcher
            .next_deadline()
            .map(|d| d.saturating_duration_since(Instant::now()))
            .unwrap_or(Duration::from_millis(20));
        match rx.recv_timeout(timeout) {
            Ok(item) => {
                handle_item(&config, &mut batcher, &metrics, item);
                // Drain everything already queued before flushing:
                // under backlog the oldest request is always past its
                // deadline, and flushing per-item would degrade batches
                // to size 1.
                while let Ok(item) = rx.try_recv() {
                    handle_item(&config, &mut batcher, &metrics, item);
                }
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {}
            Err(mpsc::RecvTimeoutError::Disconnected) => break,
        }
        flush_due(&pool, &mut batcher, &metrics);
    }
    // drain remaining batches before exit
    let keys: Vec<ModelKey> = batcher.due(Instant::now() + Duration::from_secs(3600));
    for key in keys {
        while flush_model(&pool, &mut batcher, &metrics, key) {}
    }
    down.store(true, Ordering::Relaxed);
    // the dispatcher's pool handle drops here; the Coordinator's drops
    // right after the join, and the last handle drains the shards
}

/// Route one job to its model queue (batches are the unit of work, so
/// nothing executes here).
fn handle_item(
    config: &CoordinatorConfig,
    batcher: &mut Batcher<Result<Response>>,
    metrics: &Metrics,
    item: WorkItem,
) {
    let key = ModelKey::route(item.job.app(), item.quality);
    let inputs = match item.job {
        Job::Denoise { image } => vec![image],
        Job::Blend { p1, p2, alpha } => vec![p1, p2, Tensor::scalar(alpha)],
        Job::Classify { pixels } => {
            if pixels.len() != config.classify_row {
                metrics.record_error();
                let _ = item
                    .reply
                    .send(Err(anyhow!("classify row must be {} pixels", config.classify_row)));
                return;
            }
            vec![Tensor { shape: vec![1, config.classify_row], data: pixels }]
        }
    };
    batcher.push(key, Pending { inputs, reply: item.reply, enqueued: item.submitted });
}

fn flush_due(pool: &EnginePool, batcher: &mut Batcher<Result<Response>>, metrics: &Metrics) {
    // loop until nothing is due: a burst can leave several *full*
    // batches queued behind one key, and waiting another
    // batch_max_wait per batch would idle the shards for no gain
    loop {
        let due = batcher.due(Instant::now());
        if due.is_empty() {
            break;
        }
        for key in due {
            flush_model(pool, batcher, metrics, key);
        }
    }
}

/// Hand one model's due batch to the least-loaded shard. Returns
/// whether a non-empty batch was flushed (the final drain loops until
/// each queue is empty).
fn flush_model(
    pool: &EnginePool,
    batcher: &mut Batcher<Result<Response>>,
    metrics: &Metrics,
    key: ModelKey,
) -> bool {
    let pendings = batcher.take_batch(key);
    if pendings.is_empty() {
        return false;
    }
    let size = pendings.len();
    let items: Vec<BatchItem> = pendings
        .into_iter()
        .map(|p| BatchItem { inputs: p.inputs, reply: p.reply, enqueued: p.enqueued })
        .collect();
    if pool.submit(BatchJob { key, items }).is_err() {
        // pool gone: the dropped reply senders surface as disconnects
        // to the callers, but the in-flight accounting (submitted −
        // answered) must still balance or submit() would eventually
        // report Busy forever
        for _ in 0..size {
            metrics.record_error();
        }
    }
    true
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::MockExecutor;

    fn mk(s: &str) -> ModelKey {
        ModelKey::parse(s).unwrap()
    }

    fn mock_coordinator(capacity: usize, delay_ms: u64) -> Coordinator {
        mock_coordinator_sharded(capacity, delay_ms, 1)
    }

    fn mock_coordinator_sharded(capacity: usize, delay_ms: u64, shards: usize) -> Coordinator {
        let cfg = CoordinatorConfig {
            queue_capacity: capacity,
            batch_size: 4,
            classify_row: 8,
            batch_max_wait: Duration::from_millis(2),
            shards,
        };
        Coordinator::start(cfg, move |_shard| {
            let mut m = MockExecutor::full_catalog();
            m.delay = Duration::from_millis(delay_ms);
            Ok(m)
        })
        .unwrap()
    }

    #[test]
    fn denoise_round_trip() {
        let c = mock_coordinator(8, 0);
        let t = c
            .submit(
                Job::Denoise { image: Tensor::vector(vec![10, 20, 30, 40]) },
                Quality::Balanced,
            )
            .unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.route, mk("gdf/ds16"));
        assert_eq!(r.outputs[0].data, vec![5, 10, 15, 20]);
        assert_eq!(c.metrics().completed(), 1);
    }

    #[test]
    fn denoise_keeps_request_shape() {
        // shape-carrying tensors survive the round trip (non-square)
        let c = mock_coordinator(8, 0);
        let img = Tensor::matrix(2, 3, vec![2, 4, 6, 8, 10, 12]).unwrap();
        let t = c.submit(Job::Denoise { image: img }, Quality::Precise).unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.route, mk("gdf/conv"));
        assert_eq!(r.outputs[0].shape, vec![2, 3]);
        assert_eq!(r.outputs[0].data, vec![1, 2, 3, 4, 5, 6]);
    }

    #[test]
    fn blend_routes_by_quality() {
        let c = mock_coordinator(8, 0);
        let t = c
            .submit(
                Job::Blend {
                    p1: Tensor::vector(vec![10, 20]),
                    p2: Tensor::vector(vec![30, 40]),
                    alpha: 64,
                },
                Quality::Economy,
            )
            .unwrap();
        let r = t.wait().unwrap();
        assert_eq!(r.route, mk("blend/ds32"));
        assert_eq!(r.outputs[0].data, vec![20, 30]);
    }

    #[test]
    fn classify_batches_and_scatters() {
        let c = mock_coordinator(32, 0);
        let tickets: Vec<Ticket> = (0..4)
            .map(|i| {
                c.submit(Job::Classify { pixels: vec![i * 2; 8] }, Quality::Precise).unwrap()
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let r = t.wait().unwrap();
            assert_eq!(r.route, mk("frnn/conv"));
            assert_eq!(r.outputs[0].data, vec![i as i32; 8]);
        }
        assert!(c.metrics().mean_batch_size() >= 1.0);
    }

    #[test]
    fn every_job_kind_batches() {
        // denoise jobs batch too now — 4 requests with a slow engine
        // should flush as fewer, larger batches
        let c = mock_coordinator(32, 5);
        let tickets: Vec<Ticket> = (0..8)
            .map(|i| {
                c.submit_blocking(
                    Job::Denoise { image: Tensor::vector(vec![i * 2, i * 2]) },
                    Quality::Precise,
                )
                .unwrap()
            })
            .collect();
        for (i, t) in tickets.into_iter().enumerate() {
            let r = t.wait().unwrap();
            assert_eq!(r.outputs[0].data, vec![i as i32, i as i32]);
        }
        assert!(
            c.metrics().mean_batch_size() > 1.0,
            "denoise requests should share batches, got mean {}",
            c.metrics().mean_batch_size()
        );
    }

    #[test]
    fn batch_submission_api_round_trips() {
        let c = mock_coordinator(64, 0);
        let jobs: Vec<(Job, Quality)> = (0..6)
            .map(|i| {
                (
                    Job::Denoise { image: Tensor::vector(vec![i * 4]) },
                    Quality::Economy,
                )
            })
            .collect();
        let batch = c.submit_all(jobs).unwrap();
        assert_eq!(batch.len(), 6);
        let responses = batch.wait().unwrap();
        for (i, r) in responses.iter().enumerate() {
            assert_eq!(r.route, mk("gdf/ds32"));
            assert_eq!(r.outputs[0].data, vec![i as i32 * 2]);
        }
    }

    #[test]
    fn sharded_coordinator_serves_concurrent_load() {
        let c = std::sync::Arc::new(mock_coordinator_sharded(256, 1, 4));
        let mut handles = Vec::new();
        for t in 0..8i32 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..8i32 {
                    let v = t * 16 + i * 2;
                    let ticket = c
                        .submit_blocking(
                            Job::Denoise { image: Tensor::vector(vec![v]) },
                            Quality::Balanced,
                        )
                        .unwrap();
                    let r = ticket.wait().unwrap();
                    assert_eq!(r.outputs[0].data, vec![v / 2]);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(c.metrics().completed(), 64);
        assert_eq!(c.metrics().errors(), 0);
    }

    #[test]
    fn partial_batch_flushes_on_deadline() {
        let c = mock_coordinator(8, 0);
        let t = c.submit(Job::Classify { pixels: vec![6; 8] }, Quality::Balanced).unwrap();
        let r = t.wait_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(r.route, mk("frnn/th48ds16"));
        assert_eq!(r.outputs[0].data, vec![3; 8]);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        // slow engine + tiny queue → Busy
        let c = mock_coordinator(1, 30);
        let _t1 = c
            .submit(Job::Denoise { image: Tensor::vector(vec![1]) }, Quality::Precise)
            .unwrap();
        let mut saw_busy = false;
        for _ in 0..50 {
            match c.submit(Job::Denoise { image: Tensor::vector(vec![1]) }, Quality::Precise) {
                Err(SubmitError::Busy) => {
                    saw_busy = true;
                    break;
                }
                Ok(_t) => {}
                Err(e) => panic!("unexpected {e:?}"),
            }
        }
        assert!(saw_busy, "bounded queue never pushed back");
        assert!(c.metrics().rejected() >= 1);
    }

    #[test]
    fn placed_coordinator_exposes_placement_and_residency() {
        use crate::coordinator::Placement;
        let keys = [mk("gdf/ds16"), mk("gdf/ds32")];
        let placement = Placement::spread(&keys, 2, 1)
            .assign(mk("gdf/ds16"), &[0])
            .unwrap()
            .assign(mk("gdf/ds32"), &[1])
            .unwrap();
        let cfg = CoordinatorConfig {
            queue_capacity: 32,
            batch_size: 4,
            classify_row: 8,
            batch_max_wait: Duration::from_millis(2),
            shards: 1, // ignored: the placement's shard count wins
        };
        let c = Coordinator::start_placed(cfg, placement, |_shard, assigned| {
            Ok(MockExecutor::new(assigned))
        })
        .unwrap();
        assert_eq!(c.placement().unwrap().shards(), 2);
        assert_eq!(c.registered_keys().unwrap(), vec![mk("gdf/ds16"), mk("gdf/ds32")]);
        let resident = c.resident_keys().unwrap();
        assert_eq!(resident[0], vec![mk("gdf/ds16")]);
        assert_eq!(resident[1], vec![mk("gdf/ds32")]);
        // requests route by quality to both subsets and round-trip
        for (q, want) in [(Quality::Balanced, "gdf/ds16"), (Quality::Economy, "gdf/ds32")] {
            let t = c
                .submit(Job::Denoise { image: Tensor::vector(vec![8, 4]) }, q)
                .unwrap();
            let r = t.wait().unwrap();
            assert_eq!(r.route, mk(want));
            assert_eq!(r.outputs[0].data, vec![4, 2]);
        }
        assert_eq!(c.metrics().spills(), 0);
    }

    #[test]
    fn bad_classify_row_errors() {
        let c = mock_coordinator(8, 0);
        let t = c.submit(Job::Classify { pixels: vec![1, 2] }, Quality::Precise).unwrap();
        assert!(t.wait().is_err());
        assert_eq!(c.metrics().errors(), 1);
    }
}
