//! L3 coordinator: the embedded-inference runtime that serves the three
//! PPC applications from AOT-compiled artifacts.
//!
//! Architecture (the paper's contribution lives at the block level, so
//! L3 is the serving harness a deployed PPC system would ship with):
//!
//! ```text
//!   clients ──submit()──► bounded queue ──► engine thread (owns PJRT)
//!                              │                   │
//!                         backpressure      router: (job, quality) → artifact
//!                                                   │
//!                                            dynamic batcher (classify)
//!                                                   │
//!                                            PJRT execute → reply channels
//! ```
//!
//! The engine thread owns the [`crate::runtime::Runtime`] because the
//! `xla` crate's client is not `Send`; requests and replies cross
//! threads over `std::sync::mpsc` channels. Quality routing maps each
//! request to a PPC configuration — the serving-time analogue of
//! choosing how much sparsity a deployment tolerates.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use engine::{Engine, Executor, MockExecutor};
pub use metrics::Metrics;
pub use server::{Coordinator, CoordinatorConfig, Job, Quality, Response, SubmitError};
