//! L3 coordinator: the embedded-inference runtime that serves the three
//! PPC applications — from the native netlist backend by default, or
//! from AOT-compiled PJRT artifacts behind the `pjrt` feature.
//!
//! Architecture (the paper's contribution lives at the block level, so
//! L3 is the serving harness a deployed PPC system would ship with):
//!
//! ```text
//!   clients ──submit()──► bounded queue ──► engine thread (owns the executor)
//!                              │                   │
//!                         backpressure      router: (job, quality) → model key
//!                                                   │
//!                                            dynamic batcher (classify)
//!                                                   │
//!                                    Executor::exec → reply channels
//!                                    (NativeExecutor | PJRT Runtime)
//! ```
//!
//! The engine thread owns the executor exclusively (the `xla` crate's
//! client is not `Send`; the native executor simply doesn't need
//! sharing); requests and replies cross threads over `std::sync::mpsc`
//! channels. Quality routing maps each request to a PPC configuration —
//! the serving-time analogue of choosing how much sparsity a deployment
//! tolerates.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use engine::{Engine, Executor, MockExecutor};
pub use metrics::Metrics;
pub use server::{Coordinator, CoordinatorConfig, Job, Quality, Response, SubmitError};
