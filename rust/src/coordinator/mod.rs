//! L3 coordinator: the embedded-inference runtime that serves the three
//! PPC applications — from the native netlist backend by default, or
//! from AOT-compiled PJRT artifacts behind the `pjrt` feature.
//!
//! Architecture (the paper's contribution lives at the block level, so
//! L3 is the serving harness a deployed PPC system would ship with):
//!
//! ```text
//!   clients ──submit(Job, Quality)──► bounded queue ──► dispatcher
//!                  │                                        │
//!             backpressure            ModelKey::route(app, quality)
//!                                     (the one typed catalog key)
//!                                                │
//!                                     dynamic batcher (classify,
//!                                     queued per ModelKey)
//!                                                │
//!                            engine thread (owns the executor)
//!                            Executor::exec(ModelKey, &[Tensor])
//!                            (NativeExecutor | PJRT Runtime | mock)
//! ```
//!
//! Everything between a request and its datapath is typed: the router
//! produces a [`crate::catalog::ModelKey`], the batcher queues per
//! `ModelKey`, the engine executes by `ModelKey`, and the [`Response`]
//! carries the key back to the caller. Payloads are shape-carrying
//! [`crate::catalog::Tensor`]s, so non-square images flow end to end;
//! unknown keys come back as structured errors listing the registered
//! catalog.
//!
//! The engine thread owns the executor exclusively (the `xla` crate's
//! client is not `Send`; the native executor simply doesn't need
//! sharing); requests and replies cross threads over `std::sync::mpsc`
//! channels. [`Quality`] routing maps each request to a PPC
//! configuration — the serving-time analogue of choosing how much
//! sparsity a deployment tolerates.

pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod server;

pub use crate::catalog::{App, ModelKey, PpcConfig, Quality, Tensor};
pub use engine::{Engine, Executor, MockExecutor};
pub use metrics::Metrics;
pub use server::{Coordinator, CoordinatorConfig, Job, Response, SubmitError};
