//! L3 coordinator: the embedded-inference runtime that serves the three
//! PPC applications — from the native netlist backend by default, or
//! from AOT-compiled PJRT artifacts behind the `pjrt` feature.
//!
//! Architecture (the paper's contribution lives at the block level, so
//! L3 is the serving harness a deployed PPC system would ship with).
//! Batches — not single requests — are the unit of work:
//!
//! ```text
//!   clients ──submit(Job, Quality[, deadline])──► Admission gate
//!                  │                                  │
//!        every submit path            in-flight cap + per-key fair
//!        (blocking or not)            share; overload policy decides
//!        acquires a Permit            reject / wait / degrade-quality
//!                                                │
//!                                     bounded queue ──► dispatcher
//!                                                        │
//!                                     ModelKey::route(app, quality)
//!                                     (the one typed catalog key)
//!                                                │
//!                                     dynamic batcher: every job kind
//!                                     queues per ModelKey until the
//!                                     batch fills or its deadline hits
//!                                                │
//!                                     EnginePool: whole ModelKey
//!                                     batches routed sticky-first to
//!                                     the key's Placement replicas
//!                                     (least-loaded within, spill past
//!                                     the threshold), or least-loaded
//!                                     across all N shards when no
//!                                     placement is configured
//!                                        │           │
//!                                     shard 0  …  shard N−1
//!                                     (each owns its own executor and,
//!                                      under placement, only its model
//!                                      subset — off-subset traffic is
//!                                      lazily registered from the
//!                                      shared cache;
//!                                      Executor::exec_batch lane-packs
//!                                      up to 64 requests into the
//!                                      bit-sliced netlist evaluator
//!                                      and scatters the replies)
//! ```
//!
//! Everything between a request and its datapath is typed: the router
//! produces a [`crate::catalog::ModelKey`], the batcher queues per
//! `ModelKey`, the shards execute by `ModelKey`, and the [`Response`]
//! carries the key back to the caller. Payloads are shape-carrying
//! [`crate::catalog::Tensor`]s, so non-square images flow end to end;
//! unknown keys come back as structured errors listing the registered
//! catalog.
//!
//! Each shard thread owns its executor exclusively (the `xla` crate's
//! client is not `Send`; native shards each build their own
//! [`crate::runtime::NativeExecutor`], typically from the shared
//! persistent netlist cache so only the first build synthesizes).
//! Requests and replies cross threads over `std::sync::mpsc` channels.
//! [`Quality`] routing maps each request to a PPC configuration — the
//! serving-time analogue of choosing how much sparsity a deployment
//! tolerates. See `rust/src/coordinator/README.md` for the batch
//! lifecycle in detail.

pub mod admission;
pub mod autopilot;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod placement;
pub mod server;

pub use crate::catalog::{App, ModelKey, PpcConfig, Quality, Tensor};
pub use admission::{AdmitError, Admission, Admitted, OverloadPolicy, Permit, Rejection};
pub use autopilot::{Autopilot, AutopilotConfig, QualityFloor};
pub use engine::{BatchItem, BatchJob, EnginePool, Executor, MockExecutor};
pub use metrics::{BatchSummary, ExpiredAt, Metrics};
pub use placement::Placement;
pub use server::{
    BatchTicket, Coordinator, CoordinatorConfig, Job, Response, SubmitError, Ticket,
};
