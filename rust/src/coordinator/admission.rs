//! The admission gate — the coordinator's one front door.
//!
//! Every submit path ([`crate::coordinator::Coordinator::submit`],
//! `submit_blocking`, `submit_all`, and the deadline variants) acquires
//! a [`Permit`] here before anything is queued, so no path can push the
//! system past its in-flight cap (the old `submit_blocking` bypass is
//! gone). A permit is released when its request resolves — answered,
//! errored, or deadline-expired — because the permit rides inside the
//! work item and its `Drop` does the bookkeeping; there is no code path
//! that can leak capacity.
//!
//! Two limits apply to each admission:
//!
//! - **total cap**: at most `cap` permits exist at once (the
//!   `queue_capacity` backpressure boundary), and
//! - **per-key fair share**: one [`ModelKey`] holds at most
//!   `ceil(cap · fair_share)` permits, so a single hot model cannot
//!   starve the rest of the catalog out of the capacity pool.
//!
//! What happens when a request cannot be admitted is the
//! [`OverloadPolicy`] — the serving-time embodiment of the paper's
//! quality/cost trade: under load, *degrading precision* is often the
//! right answer, not rejecting work (cf. dynamic precision scaling and
//! the QoS techniques in the approximate-computing literature).
//!
//! ```text
//!   admit(app, quality, deadline)
//!     │ deadline already passed? ──► Expired (never touches a queue)
//!     │ headroom at the requested tier? ──► admitted
//!     │ policy == degrade: next-lower *registered* tier with
//!     │   headroom? ──► admitted (degraded; response says so)
//!     │ policy == wait (blocking callers): sleep until a permit frees
//!     │   or the deadline passes ──► admitted later / Expired
//!     └ otherwise ──► Shed
//! ```

use super::autopilot::Autopilot;
use super::metrics::{ExpiredAt, Metrics};
use crate::catalog::{App, ModelKey, Quality};
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::fmt;
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// What the admission gate does with a request it has no capacity for.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Shed immediately (classic load shedding).
    Reject,
    /// Blocking submitters wait for capacity, bounded by the request
    /// deadline (non-blocking submitters still shed).
    #[default]
    Wait,
    /// Re-admit at the next-lower *registered* [`Quality`] tier for the
    /// request's [`App`] — trade precision for admission, per the
    /// paper's quality knob. Sheds when every tier is out of headroom
    /// or no lower tier is registered.
    Degrade,
}

impl OverloadPolicy {
    /// Canonical lower-case name (the CLI spelling).
    pub fn name(self) -> &'static str {
        match self {
            OverloadPolicy::Reject => "reject",
            OverloadPolicy::Wait => "wait",
            OverloadPolicy::Degrade => "degrade",
        }
    }

    /// Parse the canonical name.
    pub fn parse(s: &str) -> Result<OverloadPolicy> {
        match s {
            "reject" => Ok(OverloadPolicy::Reject),
            "wait" => Ok(OverloadPolicy::Wait),
            "degrade" => Ok(OverloadPolicy::Degrade),
            other => bail!("unknown overload policy {other:?} (want reject|wait|degrade)"),
        }
    }
}

impl fmt::Display for OverloadPolicy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Typed terminal outcome of an unserved request. Travels inside the
/// `anyhow::Error` a ticket resolves with — downcast to tell overload
/// shedding and deadline expiry apart from real execution errors:
///
/// ```
/// use ppc::coordinator::Rejection;
/// let err = anyhow::Error::new(Rejection::DeadlineExpired);
/// assert_eq!(err.downcast_ref::<Rejection>(), Some(&Rejection::DeadlineExpired));
/// ```
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Rejection {
    /// Shed by the admission gate: over capacity under the active
    /// overload policy.
    Shed,
    /// The request's deadline passed before it executed.
    DeadlineExpired,
    /// The request routed to a model the serving catalog does not
    /// have registered (a wire-boundary outcome — in-process callers
    /// can only submit typed keys).
    UnknownModel,
}

impl Rejection {
    /// Every rejection kind, in wire order.
    pub const ALL: [Rejection; 3] =
        [Rejection::Shed, Rejection::DeadlineExpired, Rejection::UnknownModel];

    /// Stable wire discriminant. Clients switch on this string; it is
    /// part of the protocol and must never change for an existing
    /// variant.
    pub fn wire_name(self) -> &'static str {
        match self {
            Rejection::Shed => "shed",
            Rejection::DeadlineExpired => "expired",
            Rejection::UnknownModel => "unknown_model",
        }
    }

    /// Parse a [`Rejection::wire_name`] discriminant back.
    pub fn parse_wire(s: &str) -> anyhow::Result<Rejection> {
        Rejection::ALL
            .into_iter()
            .find(|r| r.wire_name() == s)
            .ok_or_else(|| anyhow::anyhow!("unknown rejection kind {s:?}"))
    }
}

impl fmt::Display for Rejection {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Rejection::Shed => f.write_str("request shed: coordinator over capacity"),
            Rejection::DeadlineExpired => {
                f.write_str("request deadline expired before execution")
            }
            Rejection::UnknownModel => {
                f.write_str("requested model is not in the registered catalog")
            }
        }
    }
}

impl std::error::Error for Rejection {}

/// Why an admission attempt failed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AdmitError {
    /// No capacity under the active overload policy.
    Shed,
    /// The request deadline passed (on arrival, or while waiting for
    /// capacity).
    Expired,
}

/// A successful admission: the (possibly degraded) route plus the
/// capacity permit that must ride with the request.
#[derive(Debug)]
pub struct Admitted {
    /// The admitted catalog key.
    pub key: ModelKey,
    /// The admitted quality tier (lower than requested when degraded).
    pub quality: Quality,
    /// True when the overload policy degraded the request below its
    /// requested tier.
    pub degraded: bool,
    /// One unit of in-flight capacity; released when dropped.
    pub permit: Permit,
}

/// One unit of in-flight capacity, bound to the admitted [`ModelKey`].
/// Dropping it — wherever the request ends up resolving — releases the
/// capacity and wakes admission waiters.
pub struct Permit {
    gate: Arc<Admission>,
    key: ModelKey,
}

impl Permit {
    /// The key this permit holds capacity under.
    pub fn key(&self) -> ModelKey {
        self.key
    }
}

impl fmt::Debug for Permit {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.debug_struct("Permit").field("key", &self.key).finish()
    }
}

impl Drop for Permit {
    fn drop(&mut self) {
        self.gate.release(self.key);
    }
}

#[derive(Default)]
struct State {
    total: u64,
    per_key: BTreeMap<ModelKey, u64>,
}

/// The shared admission gate. See the module docs for the decision
/// tree; [`Admission::admit`] is the only way in.
pub struct Admission {
    cap: u64,
    key_cap: u64,
    policy: OverloadPolicy,
    /// Keys a degrade may fall back to (the servable catalog at
    /// startup). The *requested* tier is always admissible — unknown
    /// keys surface as structured engine errors, not silent admission
    /// failures.
    registered: Vec<ModelKey>,
    metrics: Arc<Metrics>,
    /// When set (`serve --quality auto`), the closed-loop controller
    /// whose current tier every admission starts from — steering
    /// composes with the degrade walk rather than replacing it.
    autopilot: Option<Arc<Autopilot>>,
    state: Mutex<State>,
    freed: Condvar,
}

impl Admission {
    /// `cap` is the in-flight ceiling (the coordinator's
    /// `queue_capacity`); `fair_share` in (0, 1] caps any single key at
    /// `ceil(cap · fair_share)` permits.
    ///
    /// Under [`OverloadPolicy::Degrade`] a full-pool fair share is
    /// provably inert (whenever the requested tier is out of headroom,
    /// so is every lower tier), so the gate normalizes it to half the
    /// pool — the lower tiers must keep headroom for degrading into to
    /// mean anything. A stricter explicit share is honored as-is.
    pub fn new(
        cap: usize,
        policy: OverloadPolicy,
        fair_share: f64,
        registered: Vec<ModelKey>,
        metrics: Arc<Metrics>,
    ) -> Admission {
        let cap = cap.max(1) as u64;
        let share = fair_share.clamp(0.0, 1.0);
        let mut key_cap = (((cap as f64) * share).ceil() as u64).clamp(1, cap);
        // only the *unset/full* share is normalized — an explicit
        // stricter share (even one whose ceiling reaches the cap, like
        // 0.95 of 8) is the operator's call and honored as-is
        if policy == OverloadPolicy::Degrade && share >= 1.0 && cap > 1 {
            key_cap = cap.div_ceil(2);
        }
        Admission {
            cap,
            key_cap,
            policy,
            registered,
            metrics,
            autopilot: None,
            state: Mutex::new(State::default()),
            freed: Condvar::new(),
        }
    }

    /// Attach the quality autopilot: every subsequent admission starts
    /// its tier walk from [`Autopilot::clamp`] of the requested tier.
    pub fn with_autopilot(mut self, autopilot: Arc<Autopilot>) -> Admission {
        self.autopilot = Some(autopilot);
        self
    }

    /// The attached autopilot, if serving in adaptive-quality mode.
    pub fn autopilot(&self) -> Option<&Arc<Autopilot>> {
        self.autopilot.as_ref()
    }

    /// The total in-flight cap.
    pub fn cap(&self) -> u64 {
        self.cap
    }

    /// The per-key fair-share cap.
    pub fn key_cap(&self) -> u64 {
        self.key_cap
    }

    /// The configured overload policy.
    pub fn policy(&self) -> OverloadPolicy {
        self.policy
    }

    /// Permits currently held.
    pub fn in_flight(&self) -> u64 {
        self.state.lock().unwrap().total
    }

    fn headroom(&self, st: &State, key: ModelKey) -> bool {
        st.total < self.cap && st.per_key.get(&key).copied().unwrap_or(0) < self.key_cap
    }

    /// The admissible `(key, quality)` right now: the requested tier
    /// when it has headroom; under [`OverloadPolicy::Degrade`], the
    /// first lower *registered* tier with headroom. With an autopilot
    /// attached, the walk starts from the controller's current tier
    /// instead of the requested one (never above the request), so
    /// steady-state steering and instantaneous degrading compose.
    fn pick(&self, st: &State, app: App, quality: Quality) -> Option<(ModelKey, Quality)> {
        let mut q = match &self.autopilot {
            Some(ap) => ap.clamp(app, quality),
            None => quality,
        };
        // the autopilot only steers onto registered tiers, so a steered
        // start is held to the same registration check as a degrade
        let mut requested = q == quality;
        loop {
            let key = ModelKey::route(app, q);
            if (requested || self.registered.contains(&key)) && self.headroom(st, key) {
                return Some((key, q));
            }
            match (self.policy, q.lower()) {
                (OverloadPolicy::Degrade, Some(lower)) => {
                    q = lower;
                    requested = false;
                }
                _ => return None,
            }
        }
    }

    /// Admit one request, or decide its overload fate. `block = false`
    /// is the non-blocking `submit` path: it never sleeps, shedding
    /// whatever the wait policy would have waited for. A `deadline`
    /// bounds the wait — and an already-expired deadline is refused
    /// here, before the request touches any queue.
    pub fn admit(
        gate: &Arc<Admission>,
        app: App,
        quality: Quality,
        deadline: Option<Instant>,
        block: bool,
    ) -> Result<Admitted, AdmitError> {
        let requested_key = ModelKey::route(app, quality);
        if deadline.map_or(false, |d| Instant::now() >= d) {
            gate.metrics.record_expired(requested_key, ExpiredAt::Admission);
            return Err(AdmitError::Expired);
        }
        let t0 = Instant::now();
        let mut st = gate.state.lock().unwrap();
        loop {
            if let Some((key, q)) = gate.pick(&st, app, quality) {
                st.total += 1;
                *st.per_key.entry(key).or_insert(0) += 1;
                let depth = st.total;
                drop(st);
                gate.metrics.record_in_flight(depth);
                gate.metrics.record_admission_wait(t0.elapsed());
                let degraded = q != quality;
                if degraded {
                    gate.metrics.record_degrade(requested_key, key);
                }
                return Ok(Admitted {
                    key,
                    quality: q,
                    degraded,
                    permit: Permit { gate: gate.clone(), key },
                });
            }
            if !block || gate.policy != OverloadPolicy::Wait {
                drop(st);
                gate.metrics.record_shed(requested_key);
                return Err(AdmitError::Shed);
            }
            match deadline {
                Some(d) => {
                    let now = Instant::now();
                    if now >= d {
                        drop(st);
                        gate.metrics.record_expired(requested_key, ExpiredAt::Admission);
                        return Err(AdmitError::Expired);
                    }
                    let (guard, _) = gate.freed.wait_timeout(st, d - now).unwrap();
                    st = guard;
                }
                None => st = gate.freed.wait(st).unwrap(),
            }
        }
    }

    fn release(&self, key: ModelKey) {
        let mut st = self.state.lock().unwrap();
        st.total = st.total.saturating_sub(1);
        if let Some(c) = st.per_key.get_mut(&key) {
            *c = c.saturating_sub(1);
            if *c == 0 {
                st.per_key.remove(&key);
            }
        }
        drop(st);
        self.freed.notify_all();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn mk(s: &str) -> ModelKey {
        ModelKey::parse(s).unwrap()
    }

    fn gate(
        cap: usize,
        policy: OverloadPolicy,
        fair_share: f64,
    ) -> (Arc<Metrics>, Arc<Admission>) {
        let metrics = Arc::new(Metrics::new());
        let g = Arc::new(Admission::new(
            cap,
            policy,
            fair_share,
            ModelKey::catalog(),
            metrics.clone(),
        ));
        (metrics, g)
    }

    #[test]
    fn admits_to_the_cap_then_sheds_under_reject() {
        let (m, g) = gate(2, OverloadPolicy::Reject, 1.0);
        let p1 = Admission::admit(&g, App::Gdf, Quality::Economy, None, true).unwrap();
        let _p2 = Admission::admit(&g, App::Gdf, Quality::Economy, None, true).unwrap();
        assert_eq!(g.in_flight(), 2);
        assert_eq!(
            Admission::admit(&g, App::Gdf, Quality::Economy, None, true).unwrap_err(),
            AdmitError::Shed
        );
        assert_eq!(m.shed(), 1);
        assert_eq!(m.shed_counts()[&mk("gdf/ds32")], 1);
        // releasing a permit reopens the gate
        drop(p1);
        assert_eq!(g.in_flight(), 1);
        assert!(Admission::admit(&g, App::Gdf, Quality::Economy, None, true).is_ok());
        assert_eq!(m.peak_in_flight(), 2);
    }

    #[test]
    fn fair_share_keeps_a_hot_key_from_starving_the_pool() {
        // cap 4, fair_share 0.5 → one key holds at most 2 permits
        let (m, g) = gate(4, OverloadPolicy::Reject, 0.5);
        assert_eq!(g.key_cap(), 2);
        let _a = Admission::admit(&g, App::Gdf, Quality::Economy, None, true).unwrap();
        let _b = Admission::admit(&g, App::Gdf, Quality::Economy, None, true).unwrap();
        // the hot key is at its share…
        assert_eq!(
            Admission::admit(&g, App::Gdf, Quality::Economy, None, true).unwrap_err(),
            AdmitError::Shed
        );
        // …but the rest of the catalog still has capacity
        let _c = Admission::admit(&g, App::Blend, Quality::Economy, None, true).unwrap();
        let _d = Admission::admit(&g, App::Frnn, Quality::Economy, None, true).unwrap();
        assert_eq!(g.in_flight(), 4);
        assert_eq!(m.shed(), 1);
    }

    #[test]
    fn degrade_reroutes_to_the_next_lower_registered_tier() {
        let (m, g) = gate(4, OverloadPolicy::Degrade, 0.25); // key_cap = 1
        let a = Admission::admit(&g, App::Gdf, Quality::Balanced, None, true).unwrap();
        assert!(!a.degraded);
        assert_eq!(a.key, mk("gdf/ds16"));
        // the balanced tier is at its share → the same request admits
        // one tier down, flagged degraded
        let b = Admission::admit(&g, App::Gdf, Quality::Balanced, None, true).unwrap();
        assert!(b.degraded);
        assert_eq!(b.key, mk("gdf/ds32"));
        assert_eq!(b.quality, Quality::Economy);
        assert_eq!(m.degrades(), 1);
        assert_eq!(m.degrade_counts()[&(mk("gdf/ds16"), mk("gdf/ds32"))], 1);
        // every tier at its share → shed, even for a blocking caller
        // (degrade falls back to reject, it never waits)
        assert_eq!(
            Admission::admit(&g, App::Gdf, Quality::Balanced, None, true).unwrap_err(),
            AdmitError::Shed
        );
    }

    #[test]
    fn degrade_normalizes_a_full_pool_fair_share() {
        // fair_share 1.0 under degrade would make the policy inert
        // (identical to reject); the gate reserves half the pool per
        // key so lower tiers keep headroom to degrade into
        let (m, g) = gate(4, OverloadPolicy::Degrade, 1.0);
        assert_eq!(g.key_cap(), 2);
        let _a = Admission::admit(&g, App::Gdf, Quality::Balanced, None, true).unwrap();
        let _b = Admission::admit(&g, App::Gdf, Quality::Balanced, None, true).unwrap();
        let c = Admission::admit(&g, App::Gdf, Quality::Balanced, None, true).unwrap();
        assert!(c.degraded, "the third balanced request degrades instead of shedding");
        assert_eq!(c.key, mk("gdf/ds32"));
        assert_eq!(m.degrades(), 1);
    }

    #[test]
    fn degrade_without_a_registered_lower_tier_sheds() {
        // only the balanced tier exists: nothing lower to degrade to
        let metrics = Arc::new(Metrics::new());
        let g = Arc::new(Admission::new(
            1,
            OverloadPolicy::Degrade,
            1.0,
            vec![mk("gdf/ds16")],
            metrics,
        ));
        let _a = Admission::admit(&g, App::Gdf, Quality::Balanced, None, true).unwrap();
        assert_eq!(
            Admission::admit(&g, App::Gdf, Quality::Balanced, None, true).unwrap_err(),
            AdmitError::Shed
        );
    }

    #[test]
    fn expired_deadline_is_refused_before_any_queue() {
        let (m, g) = gate(8, OverloadPolicy::Wait, 1.0);
        let past = Instant::now() - Duration::from_millis(1);
        assert_eq!(
            Admission::admit(&g, App::Gdf, Quality::Economy, Some(past), true).unwrap_err(),
            AdmitError::Expired
        );
        assert_eq!(g.in_flight(), 0);
        assert_eq!(m.expired_at(ExpiredAt::Admission), 1);
    }

    #[test]
    fn wait_policy_blocks_until_a_permit_frees() {
        let (m, g) = gate(1, OverloadPolicy::Wait, 1.0);
        let p = Admission::admit(&g, App::Gdf, Quality::Economy, None, true).unwrap();
        let g2 = g.clone();
        let waiter = std::thread::spawn(move || {
            Admission::admit(&g2, App::Gdf, Quality::Economy, None, true)
        });
        std::thread::sleep(Duration::from_millis(20));
        drop(p);
        let admitted = waiter.join().unwrap().unwrap();
        assert_eq!(admitted.key, mk("gdf/ds32"));
        assert_eq!(g.in_flight(), 1, "the waiter holds the freed permit");
        assert!(m.admission_wait_summary().max >= 0.015, "the waiter really waited");
        drop(admitted);
        assert_eq!(g.in_flight(), 0);
    }

    #[test]
    fn wait_policy_expires_at_the_deadline_instead_of_hanging() {
        let (m, g) = gate(1, OverloadPolicy::Wait, 1.0);
        let _p = Admission::admit(&g, App::Gdf, Quality::Economy, None, true).unwrap();
        let d = Instant::now() + Duration::from_millis(15);
        let t0 = Instant::now();
        assert_eq!(
            Admission::admit(&g, App::Gdf, Quality::Economy, Some(d), true).unwrap_err(),
            AdmitError::Expired
        );
        assert!(t0.elapsed() >= Duration::from_millis(14));
        assert_eq!(m.expired_at(ExpiredAt::Admission), 1);
    }

    #[test]
    fn non_blocking_admission_never_waits() {
        let (m, g) = gate(1, OverloadPolicy::Wait, 1.0);
        let _p = Admission::admit(&g, App::Gdf, Quality::Economy, None, true).unwrap();
        let t0 = Instant::now();
        assert_eq!(
            Admission::admit(&g, App::Gdf, Quality::Economy, None, false).unwrap_err(),
            AdmitError::Shed
        );
        assert!(t0.elapsed() < Duration::from_millis(100));
        assert_eq!(m.shed(), 1);
    }

    #[test]
    fn overload_policy_round_trips_through_parse() {
        for p in [OverloadPolicy::Reject, OverloadPolicy::Wait, OverloadPolicy::Degrade] {
            assert_eq!(OverloadPolicy::parse(p.name()).unwrap(), p);
        }
        assert!(OverloadPolicy::parse("nope").is_err());
        assert_eq!(OverloadPolicy::default(), OverloadPolicy::Wait);
    }

    #[test]
    fn rejection_wire_names_are_stable_and_round_trip() {
        // these strings are protocol: clients switch on them
        assert_eq!(Rejection::Shed.wire_name(), "shed");
        assert_eq!(Rejection::DeadlineExpired.wire_name(), "expired");
        assert_eq!(Rejection::UnknownModel.wire_name(), "unknown_model");
        for r in Rejection::ALL {
            assert_eq!(Rejection::parse_wire(r.wire_name()).unwrap(), r);
            // every kind has a human Display too
            assert!(!r.to_string().is_empty());
        }
        assert!(Rejection::parse_wire("dropped").is_err());
    }
}
