//! Dynamic batcher: collects whole requests per [`ModelKey`] until a
//! batch fills or the oldest request exceeds `max_wait`, then hands the
//! batch over as the unit of work.
//!
//! Every job type batches here — not just classification. A pending
//! request carries its full shape-carrying tensor list, so the batch
//! that flushes is exactly the `&[Vec<Tensor>]` the lane-batched
//! [`crate::catalog::Datapath::exec_batch`] path consumes; there is no
//! padding and no flat `Vec<i32>` payload anywhere (the legacy
//! row-based convention is gone — datapaths carry their own shapes).

use super::admission::Permit;
use crate::catalog::{ModelKey, Tensor};
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One queued request: its input tensors, the reply channel, when it
/// entered the system, its optional deadline, and the admission state
/// it carries (degraded routing, capacity permit).
pub struct Pending<R> {
    pub inputs: Vec<Tensor>,
    pub reply: mpsc::Sender<R>,
    pub enqueued: Instant,
    /// Absolute deadline; an entry still queued past it is dropped by
    /// [`Batcher::drop_expired`] instead of lane-packed.
    pub deadline: Option<Instant>,
    /// True when admission degraded this request below its requested
    /// quality tier.
    pub degraded: bool,
    /// In-flight capacity permit; travels with the request and releases
    /// on drop, wherever the request resolves.
    pub permit: Option<Permit>,
}

/// Per-model batch queues.
pub struct Batcher<R> {
    pub batch_size: usize,
    pub max_wait: Duration,
    queues: BTreeMap<ModelKey, Vec<Pending<R>>>,
}

impl<R> Batcher<R> {
    pub fn new(batch_size: usize, max_wait: Duration) -> Batcher<R> {
        Batcher { batch_size: batch_size.max(1), max_wait, queues: BTreeMap::new() }
    }

    pub fn push(&mut self, key: ModelKey, p: Pending<R>) {
        self.queues.entry(key).or_default().push(p);
    }

    pub fn queued(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    /// Models that must flush now (full batch or deadline exceeded).
    pub fn due(&self, now: Instant) -> Vec<ModelKey> {
        self.queues
            .iter()
            .filter(|(_, q)| {
                q.len() >= self.batch_size
                    || q.first().map_or(false, |p| now.duration_since(p.enqueued) >= self.max_wait)
            })
            .map(|(&k, _)| k)
            .collect()
    }

    /// Earliest wakeup across queues (for the dispatcher's recv
    /// timeout): the soonest batch flush deadline or per-request
    /// expiry, whichever comes first.
    pub fn next_deadline(&self) -> Option<Instant> {
        let flush = self
            .queues
            .values()
            .filter_map(|q| q.first().map(|p| p.enqueued + self.max_wait))
            .min();
        let expiry = self
            .queues
            .values()
            .flat_map(|q| q.iter().filter_map(|p| p.deadline))
            .min();
        match (flush, expiry) {
            (Some(f), Some(e)) => Some(f.min(e)),
            (f, e) => f.or(e),
        }
    }

    /// Remove every entry whose deadline is at or before `now`, across
    /// all queues, and hand them back so the caller can answer them —
    /// expired requests are dropped *before* lane-packing, never
    /// shipped to a shard.
    pub fn drop_expired(&mut self, now: Instant) -> Vec<(ModelKey, Pending<R>)> {
        let expired = |p: &Pending<R>| p.deadline.map_or(false, |d| now >= d);
        let mut out = Vec::new();
        let keys: Vec<ModelKey> = self.queues.keys().copied().collect();
        for key in keys {
            let q = self.queues.get_mut(&key).expect("key listed above");
            // single linear partition pass (a mass expiry hits exactly
            // at the overload-recovery moment, so no O(expired·queued)
            // Vec::remove shuffling on the dispatcher thread),
            // preserving FIFO order of the survivors
            if q.iter().any(&expired) {
                let mut live = Vec::with_capacity(q.len());
                for p in q.drain(..) {
                    if expired(&p) {
                        out.push((key, p));
                    } else {
                        live.push(p);
                    }
                }
                *q = live;
            }
            if q.is_empty() {
                self.queues.remove(&key);
            }
        }
        out
    }

    /// Remove up to `batch_size` requests for a model — the whole
    /// batch, ready to route to a shard.
    pub fn take_batch(&mut self, key: ModelKey) -> Vec<Pending<R>> {
        let Some(q) = self.queues.get_mut(&key) else {
            return Vec::new();
        };
        let n = q.len().min(self.batch_size);
        let taken: Vec<Pending<R>> = q.drain(..n).collect();
        if q.is_empty() {
            self.queues.remove(&key);
        }
        taken
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(s: &str) -> ModelKey {
        ModelKey::parse(s).unwrap()
    }

    fn pending(v: i32) -> (Pending<Vec<i32>>, mpsc::Receiver<Vec<i32>>) {
        pending_until(v, None)
    }

    fn pending_until(
        v: i32,
        deadline: Option<Instant>,
    ) -> (Pending<Vec<i32>>, mpsc::Receiver<Vec<i32>>) {
        let (tx, rx) = mpsc::channel();
        (
            Pending {
                inputs: vec![Tensor::vector(vec![v, v])],
                reply: tx,
                enqueued: Instant::now(),
                deadline,
                degraded: false,
                permit: None,
            },
            rx,
        )
    }

    #[test]
    fn flushes_on_full_batch() {
        let mut b: Batcher<Vec<i32>> = Batcher::new(2, Duration::from_secs(10));
        let (p1, _r1) = pending(1);
        let (p2, _r2) = pending(2);
        b.push(mk("frnn/conv"), p1);
        assert!(b.due(Instant::now()).is_empty());
        b.push(mk("frnn/conv"), p2);
        assert_eq!(b.due(Instant::now()), vec![mk("frnn/conv")]);
        let taken = b.take_batch(mk("frnn/conv"));
        assert_eq!(taken.len(), 2);
        assert_eq!(taken[0].inputs[0].data, vec![1, 1]);
        assert_eq!(taken[1].inputs[0].data, vec![2, 2]);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b: Batcher<Vec<i32>> = Batcher::new(8, Duration::from_millis(1));
        let (p1, _r1) = pending(7);
        b.push(mk("frnn/ds32"), p1);
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(b.due(Instant::now()), vec![mk("frnn/ds32")]);
        // no padding: a deadline flush hands over exactly what queued
        let taken = b.take_batch(mk("frnn/ds32"));
        assert_eq!(taken.len(), 1);
        assert_eq!(taken[0].inputs[0].data, vec![7, 7]);
    }

    #[test]
    fn take_batch_of_absent_key_is_empty() {
        let mut b: Batcher<Vec<i32>> = Batcher::new(2, Duration::from_secs(1));
        assert!(b.take_batch(mk("gdf/conv")).is_empty());
    }

    #[test]
    fn separate_models_batch_separately() {
        let mut b: Batcher<Vec<i32>> = Batcher::new(2, Duration::from_secs(10));
        let (p1, _r1) = pending(1);
        let (p2, _r2) = pending(2);
        b.push(mk("frnn/conv"), p1);
        b.push(mk("frnn/ds32"), p2);
        assert!(b.due(Instant::now()).is_empty());
        assert_eq!(b.queued(), 2);
    }

    #[test]
    fn drop_expired_removes_only_expired_entries() {
        let mut b: Batcher<Vec<i32>> = Batcher::new(8, Duration::from_secs(10));
        let now = Instant::now();
        let (p1, _r1) = pending_until(1, Some(now - Duration::from_millis(1)));
        let (p2, _r2) = pending_until(2, None);
        let (p3, _r3) = pending_until(3, Some(now + Duration::from_secs(5)));
        b.push(mk("frnn/conv"), p1);
        b.push(mk("frnn/conv"), p2);
        b.push(mk("gdf/ds16"), p3);
        let dropped = b.drop_expired(Instant::now());
        assert_eq!(dropped.len(), 1);
        assert_eq!(dropped[0].0, mk("frnn/conv"));
        assert_eq!(dropped[0].1.inputs[0].data, vec![1, 1]);
        assert_eq!(b.queued(), 2, "live entries stay queued");
        // a live entry's deadline bounds the dispatcher wakeup even
        // when it is sooner than any flush deadline
        let d = b.next_deadline().unwrap();
        assert!(d <= now + Duration::from_secs(5));
    }

    #[test]
    fn next_deadline_is_earliest() {
        let mut b: Batcher<Vec<i32>> = Batcher::new(8, Duration::from_millis(50));
        assert!(b.next_deadline().is_none());
        let (p1, _r1) = pending(1);
        b.push(mk("frnn/conv"), p1);
        std::thread::sleep(Duration::from_millis(2));
        let (p2, _r2) = pending(2);
        b.push(mk("frnn/th48ds16"), p2);
        let d = b.next_deadline().unwrap();
        assert!(d <= Instant::now() + Duration::from_millis(50));
    }
}
