//! Dynamic batcher for classification requests.
//!
//! The FRNN datapath has a fixed batch dimension (the AOT shape), so
//! the batcher collects single-face requests per [`ModelKey`], flushes
//! when the batch fills or the oldest request exceeds `max_wait`, pads
//! short batches, and scatters the per-row outputs back to their reply
//! channels.

use crate::catalog::ModelKey;
use std::collections::BTreeMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One queued classification request.
pub struct Pending<R> {
    pub input: Vec<i32>,
    pub reply: mpsc::Sender<R>,
    pub enqueued: Instant,
}

/// Per-model batch queues.
pub struct Batcher<R> {
    pub batch_size: usize,
    pub row_len: usize,
    pub max_wait: Duration,
    queues: BTreeMap<ModelKey, Vec<Pending<R>>>,
}

impl<R> Batcher<R> {
    pub fn new(batch_size: usize, row_len: usize, max_wait: Duration) -> Batcher<R> {
        Batcher { batch_size, row_len, max_wait, queues: BTreeMap::new() }
    }

    pub fn push(&mut self, key: ModelKey, p: Pending<R>) {
        debug_assert_eq!(p.input.len(), self.row_len);
        self.queues.entry(key).or_default().push(p);
    }

    pub fn queued(&self) -> usize {
        self.queues.values().map(|q| q.len()).sum()
    }

    /// Models that must flush now (full batch or deadline exceeded).
    pub fn due(&self, now: Instant) -> Vec<ModelKey> {
        self.queues
            .iter()
            .filter(|(_, q)| {
                q.len() >= self.batch_size
                    || q.first().map_or(false, |p| now.duration_since(p.enqueued) >= self.max_wait)
            })
            .map(|(&k, _)| k)
            .collect()
    }

    /// Earliest deadline across queues (for the engine's recv timeout).
    pub fn next_deadline(&self) -> Option<Instant> {
        self.queues
            .values()
            .filter_map(|q| q.first().map(|p| p.enqueued + self.max_wait))
            .min()
    }

    /// Remove up to `batch_size` requests for a model and build the
    /// padded batch tensor. Returns (pending requests, flat batch).
    pub fn take_batch(&mut self, key: ModelKey) -> (Vec<Pending<R>>, Vec<i32>) {
        let q = self.queues.get_mut(&key).expect("model queue exists");
        let n = q.len().min(self.batch_size);
        let taken: Vec<Pending<R>> = q.drain(..n).collect();
        if q.is_empty() {
            self.queues.remove(&key);
        }
        let mut flat = Vec::with_capacity(self.batch_size * self.row_len);
        for p in &taken {
            flat.extend_from_slice(&p.input);
        }
        flat.resize(self.batch_size * self.row_len, 0); // pad
        (taken, flat)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(s: &str) -> ModelKey {
        ModelKey::parse(s).unwrap()
    }

    fn pending(v: i32) -> (Pending<Vec<i32>>, mpsc::Receiver<Vec<i32>>) {
        let (tx, rx) = mpsc::channel();
        (Pending { input: vec![v, v], reply: tx, enqueued: Instant::now() }, rx)
    }

    #[test]
    fn flushes_on_full_batch() {
        let mut b: Batcher<Vec<i32>> = Batcher::new(2, 2, Duration::from_secs(10));
        let (p1, _r1) = pending(1);
        let (p2, _r2) = pending(2);
        b.push(mk("frnn/conv"), p1);
        assert!(b.due(Instant::now()).is_empty());
        b.push(mk("frnn/conv"), p2);
        assert_eq!(b.due(Instant::now()), vec![mk("frnn/conv")]);
        let (taken, flat) = b.take_batch(mk("frnn/conv"));
        assert_eq!(taken.len(), 2);
        assert_eq!(flat, vec![1, 1, 2, 2]);
        assert_eq!(b.queued(), 0);
    }

    #[test]
    fn flushes_on_deadline() {
        let mut b: Batcher<Vec<i32>> = Batcher::new(8, 2, Duration::from_millis(1));
        let (p1, _r1) = pending(7);
        b.push(mk("frnn/ds32"), p1);
        std::thread::sleep(Duration::from_millis(3));
        assert_eq!(b.due(Instant::now()), vec![mk("frnn/ds32")]);
        let (taken, flat) = b.take_batch(mk("frnn/ds32"));
        assert_eq!(taken.len(), 1);
        // padded to batch 8 × row 2
        assert_eq!(flat.len(), 16);
        assert_eq!(&flat[..2], &[7, 7]);
        assert!(flat[2..].iter().all(|&x| x == 0));
    }

    #[test]
    fn separate_models_batch_separately() {
        let mut b: Batcher<Vec<i32>> = Batcher::new(2, 2, Duration::from_secs(10));
        let (p1, _r1) = pending(1);
        let (p2, _r2) = pending(2);
        b.push(mk("frnn/conv"), p1);
        b.push(mk("frnn/ds32"), p2);
        assert!(b.due(Instant::now()).is_empty());
        assert_eq!(b.queued(), 2);
    }

    #[test]
    fn next_deadline_is_earliest() {
        let mut b: Batcher<Vec<i32>> = Batcher::new(8, 2, Duration::from_millis(50));
        assert!(b.next_deadline().is_none());
        let (p1, _r1) = pending(1);
        b.push(mk("frnn/conv"), p1);
        std::thread::sleep(Duration::from_millis(2));
        let (p2, _r2) = pending(2);
        b.push(mk("frnn/th48ds16"), p2);
        let d = b.next_deadline().unwrap();
        assert!(d <= Instant::now() + Duration::from_millis(50));
    }
}
