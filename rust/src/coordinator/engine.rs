//! The engine thread: exclusive owner of the (non-`Send`) PJRT runtime.
//!
//! [`Engine::spawn`] takes a *factory* closure that constructs the
//! executor on the engine thread itself; other threads talk to it
//! through an mpsc command channel. [`Executor`] abstracts the runtime
//! so coordinator logic is testable without artifacts
//! ([`MockExecutor`]).

use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// Anything that can execute a named artifact on i32 tensors.
pub trait Executor {
    fn exec(&self, key: &str, inputs: &[&[i32]]) -> Result<Vec<Vec<i32>>>;
    /// Known artifact keys (for router validation).
    fn keys(&self) -> Vec<String>;
}

impl Executor for crate::runtime::Runtime {
    fn exec(&self, key: &str, inputs: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
        self.exec_i32(key, inputs)
    }
    fn keys(&self) -> Vec<String> {
        self.keys()
    }
}

/// Deterministic stand-in executor for coordinator tests: echoes inputs
/// through simple integer transforms per app.
pub struct MockExecutor {
    pub keys: Vec<String>,
    /// artificial per-exec latency (for batching tests)
    pub delay: std::time::Duration,
}

impl MockExecutor {
    pub fn new(keys: &[&str]) -> MockExecutor {
        MockExecutor {
            keys: keys.iter().map(|s| s.to_string()).collect(),
            delay: std::time::Duration::ZERO,
        }
    }
}

impl Executor for MockExecutor {
    fn exec(&self, key: &str, inputs: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
        if !self.keys.iter().any(|k| k == key) {
            return Err(anyhow!("unknown key {key}"));
        }
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        // denoise/classify: halve every element; blend: average inputs
        if key.starts_with("blend") {
            let out: Vec<i32> = inputs[0]
                .iter()
                .zip(inputs[1])
                .map(|(&a, &b)| (a + b) / 2)
                .collect();
            Ok(vec![out])
        } else {
            Ok(vec![inputs[0].iter().map(|&v| v / 2).collect()])
        }
    }
    fn keys(&self) -> Vec<String> {
        self.keys.clone()
    }
}

/// Command executed on the engine thread.
pub struct ExecRequest {
    pub key: String,
    pub inputs: Vec<Vec<i32>>,
    pub reply: mpsc::Sender<Result<Vec<Vec<i32>>>>,
}

enum Cmd {
    Exec(ExecRequest),
    Keys(mpsc::Sender<Vec<String>>),
    Shutdown,
}

/// Handle to the engine thread.
pub struct Engine {
    tx: mpsc::Sender<Cmd>,
    handle: Option<JoinHandle<()>>,
}

impl Engine {
    /// Spawn the engine; `factory` runs on the engine thread (the place
    /// where the non-Send PJRT client must be created). Fails if the
    /// factory fails.
    pub fn spawn<E, F>(factory: F) -> Result<Engine>
    where
        E: Executor,
        F: FnOnce() -> Result<E> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Cmd>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("ppc-engine".into())
            .spawn(move || {
                let executor = match factory() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                // simple executable-key cache of exec counts (metrics can
                // be derived by the server; kept here for debugging)
                let mut counts: HashMap<String, u64> = HashMap::new();
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Exec(req) => {
                            let refs: Vec<&[i32]> =
                                req.inputs.iter().map(|v| v.as_slice()).collect();
                            let result = executor.exec(&req.key, &refs);
                            *counts.entry(req.key).or_default() += 1;
                            let _ = req.reply.send(result);
                        }
                        Cmd::Keys(reply) => {
                            let _ = reply.send(executor.keys());
                        }
                        Cmd::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Engine { tx, handle: Some(handle) })
    }

    /// Execute synchronously (blocks the calling thread, not the engine
    /// queue — other callers' requests are serialized behind it).
    pub fn exec(&self, key: &str, inputs: Vec<Vec<i32>>) -> Result<Vec<Vec<i32>>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Exec(ExecRequest { key: key.to_string(), inputs, reply }))
            .map_err(|_| anyhow!("engine is down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    /// Fire an async execution; the reply lands on `reply`.
    pub fn exec_async(
        &self,
        key: &str,
        inputs: Vec<Vec<i32>>,
        reply: mpsc::Sender<Result<Vec<Vec<i32>>>>,
    ) -> Result<()> {
        self.tx
            .send(Cmd::Exec(ExecRequest { key: key.to_string(), inputs, reply }))
            .map_err(|_| anyhow!("engine is down"))
    }

    pub fn keys(&self) -> Result<Vec<String>> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Cmd::Keys(tx)).map_err(|_| anyhow!("engine is down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spawn_exec_shutdown() {
        let engine = Engine::spawn(|| Ok(MockExecutor::new(&["gdf/conv"]))).unwrap();
        let out = engine.exec("gdf/conv", vec![vec![10, 20, 30]]).unwrap();
        assert_eq!(out, vec![vec![5, 10, 15]]);
        assert_eq!(engine.keys().unwrap(), vec!["gdf/conv"]);
    }

    #[test]
    fn unknown_key_errors() {
        let engine = Engine::spawn(|| Ok(MockExecutor::new(&["gdf/conv"]))).unwrap();
        assert!(engine.exec("nope", vec![vec![1]]).is_err());
    }

    #[test]
    fn factory_failure_propagates() {
        let r = Engine::spawn(|| -> Result<MockExecutor> { Err(anyhow!("boom")) });
        assert!(r.is_err());
    }

    #[test]
    fn concurrent_callers_serialize() {
        let engine =
            std::sync::Arc::new(Engine::spawn(|| Ok(MockExecutor::new(&["frnn/conv"]))).unwrap());
        let mut handles = Vec::new();
        for t in 0..8 {
            let e = engine.clone();
            handles.push(std::thread::spawn(move || {
                let out = e.exec("frnn/conv", vec![vec![t * 2]]).unwrap();
                assert_eq!(out[0][0], t);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
