//! The sharded engine pool: N worker shards, each the exclusive owner
//! of its own executor, consuming whole [`ModelKey`] batches.
//!
//! [`EnginePool::spawn`] takes a *factory* closure that constructs one
//! executor per shard **on the shard's own thread** (the place where a
//! non-`Send` PJRT client must be created; for the native backend each
//! shard typically builds its own [`crate::runtime::NativeExecutor`]
//! from the shared persistent netlist cache, so only the first build
//! synthesizes anything). Other threads talk to shards through mpsc
//! command channels.
//!
//! The unit of work is a [`BatchJob`] — a whole `ModelKey` batch with
//! one reply channel per request. The receiving shard runs the batch
//! through [`Executor::exec_batch`] (the 64-way lane-packed path on
//! the native backend), records per-shard/per-key batch metrics, and
//! scatters the per-request responses itself, so no coordinator thread
//! ever blocks on model execution. Batch routing picks the shard with
//! the fewest queued batches (round-robin on ties).
//!
//! [`Executor`] abstracts the runtime — typed [`ModelKey`] in,
//! shape-carrying [`Tensor`]s through — so coordinator logic is
//! testable without artifacts ([`MockExecutor`]).

use super::metrics::Metrics;
use super::server::Response;
use crate::catalog::{self, App, ModelKey, Tensor};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

/// Anything that can execute a cataloged model on shape-carrying i32
/// tensors.
pub trait Executor {
    fn exec(&self, key: ModelKey, inputs: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Execute a whole batch of requests for one model; element `i` of
    /// the result answers `batch[i]`, bit-exact with `exec(key,
    /// &batch[i])`. The default loops over [`Executor::exec`]; the
    /// native backend overrides it with the lane-batched netlist path.
    fn exec_batch(&self, key: ModelKey, batch: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        batch.iter().map(|inputs| self.exec(key, inputs)).collect()
    }

    /// Registered model keys (for router validation / `--list-models`).
    fn keys(&self) -> Vec<ModelKey>;
}

impl Executor for crate::runtime::Runtime {
    fn exec(&self, key: ModelKey, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let route = key.to_string();
        // Bridge the AOT artifacts' fixed batch dimension: a single
        // [r, C] request against a [B, C] input port (r < B) is padded
        // with zero rows, executed, and each [B, X] output sliced back
        // to the request's r rows. The native backend has no fixed
        // batch dim; this is PJRT-only plumbing that used to live in
        // the batcher before batching went lane-oriented.
        if let Some(m) = self.meta(&route).cloned() {
            if inputs.len() == 1
                && m.inputs.len() == 1
                && m.inputs[0].dims.len() == 2
                && inputs[0].shape.len() == 2
                && inputs[0].shape[1] == m.inputs[0].dims[1]
                && inputs[0].shape[0] < m.inputs[0].dims[0]
            {
                let (b, c) = (m.inputs[0].dims[0], m.inputs[0].dims[1]);
                let r = inputs[0].shape[0];
                let mut flat = inputs[0].data.clone();
                flat.resize(b * c, 0);
                let outs = self.exec_i32(&route, &[&flat])?;
                return Ok(outs
                    .into_iter()
                    .map(|data| {
                        let out_row = data.len() / b;
                        Tensor {
                            shape: vec![r, out_row],
                            data: data[..r * out_row].to_vec(),
                        }
                    })
                    .collect());
            }
        }
        let refs: Vec<&[i32]> = inputs.iter().map(|t| t.data.as_slice()).collect();
        let outputs = self.exec_i32(&route, &refs)?;
        // artifact manifests carry output shapes; fall back to flat
        let shapes: Vec<Vec<usize>> = self
            .meta(&route)
            .map(|m| m.outputs.iter().map(|p| p.dims.clone()).collect())
            .unwrap_or_default();
        Ok(outputs
            .into_iter()
            .enumerate()
            .map(|(k, data)| match shapes.get(k) {
                Some(dims) if dims.iter().product::<usize>() == data.len() => {
                    Tensor { shape: dims.clone(), data }
                }
                _ => Tensor::vector(data),
            })
            .collect())
    }

    fn keys(&self) -> Vec<ModelKey> {
        crate::runtime::Runtime::keys(self)
            .iter()
            .filter_map(|s| ModelKey::parse(s).ok())
            .collect()
    }
}

/// Deterministic stand-in executor for coordinator tests: echoes inputs
/// through simple integer transforms per app, preserving shapes.
pub struct MockExecutor {
    pub keys: Vec<ModelKey>,
    /// artificial per-exec latency (for batching tests)
    pub delay: std::time::Duration,
}

impl MockExecutor {
    pub fn new(keys: &[ModelKey]) -> MockExecutor {
        MockExecutor { keys: keys.to_vec(), delay: std::time::Duration::ZERO }
    }

    /// A mock registered for the entire 9-key catalog.
    pub fn full_catalog() -> MockExecutor {
        MockExecutor::new(&ModelKey::catalog())
    }
}

impl Executor for MockExecutor {
    fn exec(&self, key: ModelKey, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if !self.keys.contains(&key) {
            return Err(anyhow!(
                "unknown model {key}; available models: [{}]",
                catalog::join(self.keys.iter())
            ));
        }
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        // denoise/classify: halve every element; blend: average inputs
        let data: Vec<i32> = if key.app == App::Blend {
            inputs[0]
                .data
                .iter()
                .zip(&inputs[1].data)
                .map(|(&a, &b)| (a + b) / 2)
                .collect()
        } else {
            inputs[0].data.iter().map(|&v| v / 2).collect()
        };
        Ok(vec![Tensor { shape: inputs[0].shape.clone(), data }])
    }

    fn keys(&self) -> Vec<ModelKey> {
        self.keys.clone()
    }
}

/// One request inside a [`BatchJob`]: its input tensors, where the
/// response goes, and when it entered the system (for latency
/// accounting).
pub struct BatchItem {
    pub inputs: Vec<Tensor>,
    pub reply: mpsc::Sender<Result<Response>>,
    pub enqueued: Instant,
}

/// A whole `ModelKey` batch — the unit of work a shard executes.
pub struct BatchJob {
    pub key: ModelKey,
    pub items: Vec<BatchItem>,
}

enum Cmd {
    Batch(BatchJob),
    Keys(mpsc::Sender<Vec<ModelKey>>),
    Shutdown,
}

struct Shard {
    tx: mpsc::Sender<Cmd>,
    /// Batches queued on (or running in) this shard.
    depth: Arc<AtomicUsize>,
    handle: Option<JoinHandle<()>>,
}

/// Handle to the shard pool.
pub struct EnginePool {
    shards: Vec<Shard>,
    metrics: Arc<Metrics>,
    rr: AtomicUsize,
}

impl EnginePool {
    /// Spawn `shards` worker shards; `factory(shard_index)` runs on
    /// each shard's thread to construct that shard's executor. Fails if
    /// any factory call fails.
    pub fn spawn<E, F>(shards: usize, metrics: Arc<Metrics>, factory: F) -> Result<EnginePool>
    where
        E: Executor + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        let shards = shards.max(1);
        let factory = Arc::new(factory);
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let mut out = Vec::with_capacity(shards);
        for s in 0..shards {
            let (tx, rx) = mpsc::channel::<Cmd>();
            let depth = Arc::new(AtomicUsize::new(0));
            let d = depth.clone();
            let f = factory.clone();
            let m = metrics.clone();
            let ready = ready_tx.clone();
            let handle = std::thread::Builder::new()
                .name(format!("ppc-shard{s}"))
                .spawn(move || shard_loop(s, f, m, d, rx, ready))?;
            out.push(Shard { tx, depth, handle: Some(handle) });
            if s == 0 {
                // shard 0 finishes building before the rest start, so
                // anything it warms (the shared BLIF netlist cache in
                // particular) is already on disk when shards 1..N
                // build — they load instead of re-synthesizing, and
                // never race writes against an empty cache
                ready_rx
                    .recv()
                    .map_err(|_| anyhow!("a shard died during startup"))??;
            }
        }
        drop(ready_tx);
        for _ in 1..shards {
            ready_rx
                .recv()
                .map_err(|_| anyhow!("a shard died during startup"))??;
        }
        Ok(EnginePool { shards: out, metrics, rr: AtomicUsize::new(0) })
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Batches currently queued on (or running in) each shard.
    pub fn queue_depths(&self) -> Vec<usize> {
        self.shards
            .iter()
            .map(|s| s.depth.load(Ordering::Relaxed))
            .collect()
    }

    /// Route a whole `ModelKey` batch to the least-loaded shard
    /// (round-robin on ties). The shard executes it via
    /// [`Executor::exec_batch`] and scatters the per-request replies.
    pub fn submit(&self, job: BatchJob) -> Result<()> {
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        let n = self.shards.len();
        let mut best = start % n;
        let mut best_depth = usize::MAX;
        for i in 0..n {
            let s = (start + i) % n;
            let d = self.shards[s].depth.load(Ordering::Relaxed);
            if d < best_depth {
                best = s;
                best_depth = d;
            }
        }
        let shard = &self.shards[best];
        shard.depth.fetch_add(1, Ordering::Relaxed);
        self.metrics.record_queue_depth(best, best_depth + 1);
        shard.tx.send(Cmd::Batch(job)).map_err(|_| {
            shard.depth.fetch_sub(1, Ordering::Relaxed);
            anyhow!("engine pool is down")
        })
    }

    /// Execute a single request synchronously — a batch of one (blocks
    /// the calling thread, not the pool).
    pub fn exec(&self, key: ModelKey, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.submit(BatchJob {
            key,
            items: vec![BatchItem { inputs, reply, enqueued: Instant::now() }],
        })?;
        let resp = rx.recv().map_err(|_| anyhow!("engine dropped reply"))??;
        Ok(resp.outputs)
    }

    /// The registered catalog (asked of shard 0; every shard registers
    /// the same keys).
    pub fn keys(&self) -> Result<Vec<ModelKey>> {
        let (tx, rx) = mpsc::channel();
        self.shards[0]
            .tx
            .send(Cmd::Keys(tx))
            .map_err(|_| anyhow!("engine pool is down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))
    }
}

impl Drop for EnginePool {
    /// Graceful drain: every batch already queued on a shard executes
    /// before the shard sees its shutdown command (mpsc preserves
    /// order), then all shard threads are joined.
    fn drop(&mut self) {
        for s in &self.shards {
            let _ = s.tx.send(Cmd::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn shard_loop<E, F>(
    shard: usize,
    factory: Arc<F>,
    metrics: Arc<Metrics>,
    depth: Arc<AtomicUsize>,
    rx: mpsc::Receiver<Cmd>,
    ready: mpsc::Sender<Result<()>>,
) where
    E: Executor + 'static,
    F: Fn(usize) -> Result<E> + Send + Sync + 'static,
{
    let executor = match (*factory)(shard) {
        Ok(e) => {
            let _ = ready.send(Ok(()));
            e
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Batch(job) => {
                run_batch(shard, &executor, &metrics, job);
                depth.fetch_sub(1, Ordering::Relaxed);
            }
            Cmd::Keys(reply) => {
                let _ = reply.send(executor.keys());
            }
            Cmd::Shutdown => break,
        }
    }
}

/// Execute one batch on a shard and scatter the per-request replies.
/// A failing batch is retried request-by-request so one malformed
/// request cannot poison its batch-mates; a *panicking* executor is
/// caught so one bad request cannot kill the shard thread (which would
/// silently swallow ~1/N of all later traffic).
fn run_batch<E: Executor>(shard: usize, executor: &E, metrics: &Metrics, job: BatchJob) {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let BatchJob { key, items } = job;
    if items.is_empty() {
        return;
    }
    let size = items.len();
    let mut inputs = Vec::with_capacity(size);
    let mut waiters = Vec::with_capacity(size);
    for it in items {
        inputs.push(it.inputs);
        waiters.push((it.reply, it.enqueued));
    }
    let t0 = Instant::now();
    // a panic unwinds into an Err so the batch falls through to the
    // per-request retry like any other wholesale failure
    let batch_result = catch_unwind(AssertUnwindSafe(|| executor.exec_batch(key, &inputs)))
        .unwrap_or_else(|_| Err(anyhow!("executor panicked on a {size}-request batch")));
    match batch_result {
        Ok(outs) if outs.len() == size => {
            metrics.record_batch(shard, key, size, t0.elapsed());
            for ((reply, enqueued), outputs) in waiters.into_iter().zip(outs) {
                metrics.record_latency(key, enqueued.elapsed());
                let _ = reply.send(Ok(Response { outputs, route: key }));
            }
        }
        Ok(outs) => {
            // executor contract violation — fail every request loudly
            let msg = format!(
                "{key}: executor answered {} of {size} batch requests",
                outs.len()
            );
            for (reply, _) in waiters {
                metrics.record_error();
                let _ = reply.send(Err(anyhow!("{msg}")));
            }
        }
        Err(_) => {
            for ((reply, enqueued), ins) in waiters.into_iter().zip(inputs) {
                match catch_unwind(AssertUnwindSafe(|| executor.exec(key, &ins))) {
                    Ok(Ok(outputs)) => {
                        metrics.record_latency(key, enqueued.elapsed());
                        let _ = reply.send(Ok(Response { outputs, route: key }));
                    }
                    Ok(Err(e)) => {
                        metrics.record_error();
                        let _ = reply.send(Err(e));
                    }
                    Err(_) => {
                        metrics.record_error();
                        let _ = reply
                            .send(Err(anyhow!("{key}: executor panicked on this request")));
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn mk(s: &str) -> ModelKey {
        ModelKey::parse(s).unwrap()
    }

    fn pool(shards: usize) -> (Arc<Metrics>, EnginePool) {
        let metrics = Arc::new(Metrics::new());
        let p = EnginePool::spawn(shards, metrics.clone(), |_shard| {
            Ok(MockExecutor::full_catalog())
        })
        .unwrap();
        (metrics, p)
    }

    #[test]
    fn spawn_exec_shutdown() {
        let (_, pool) = pool(2);
        assert_eq!(pool.shards(), 2);
        let out = pool
            .exec(mk("gdf/conv"), vec![Tensor::vector(vec![10, 20, 30])])
            .unwrap();
        assert_eq!(out[0].data, vec![5, 10, 15]);
        assert_eq!(out[0].shape, vec![3]);
        assert_eq!(pool.keys().unwrap(), ModelKey::catalog());
    }

    #[test]
    fn unknown_key_errors_list_the_catalog() {
        let metrics = Arc::new(Metrics::new());
        let pool = EnginePool::spawn(1, metrics.clone(), |_| {
            Ok(MockExecutor::new(&[mk("gdf/conv")]))
        })
        .unwrap();
        let e = pool
            .exec(mk("frnn/conv"), vec![Tensor::vector(vec![1])])
            .unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("unknown model frnn/conv"), "{msg}");
        assert!(msg.contains("available models: [gdf/conv]"), "{msg}");
        assert_eq!(metrics.errors(), 1);
    }

    #[test]
    fn factory_failure_propagates() {
        let r = EnginePool::spawn(3, Arc::new(Metrics::new()), |_| -> Result<MockExecutor> {
            Err(anyhow!("boom"))
        });
        assert!(r.is_err());
    }

    #[test]
    fn batches_scatter_per_request_replies() {
        let (metrics, pool) = pool(2);
        let (items, rxs): (Vec<BatchItem>, Vec<_>) = (0..5)
            .map(|i| {
                let (reply, rx) = mpsc::channel();
                (
                    BatchItem {
                        inputs: vec![Tensor::vector(vec![i * 2])],
                        reply,
                        enqueued: Instant::now(),
                    },
                    rx,
                )
            })
            .unzip();
        pool.submit(BatchJob { key: mk("gdf/ds16"), items }).unwrap();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.route, mk("gdf/ds16"));
            assert_eq!(r.outputs[0].data, vec![i as i32]);
        }
        assert_eq!(metrics.completed(), 5);
        assert!(metrics.mean_batch_size() >= 5.0);
    }

    #[test]
    fn concurrent_submitters_spread_over_shards() {
        let (metrics, pool) = pool(4);
        let pool = Arc::new(pool);
        let mut handles = Vec::new();
        for t in 0..8i32 {
            let p = pool.clone();
            handles.push(std::thread::spawn(move || {
                let out = p
                    .exec(mk("frnn/conv"), vec![Tensor::vector(vec![t * 2])])
                    .unwrap();
                assert_eq!(out[0].data[0], t);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(metrics.completed(), 8);
    }

    #[test]
    fn shutdown_drains_queued_batches_under_concurrent_submitters() {
        let metrics = Arc::new(Metrics::new());
        let pool = EnginePool::spawn(2, metrics.clone(), |_| {
            let mut m = MockExecutor::full_catalog();
            m.delay = Duration::from_millis(1); // make batches queue up
            Ok(m)
        })
        .unwrap();
        let pool = Arc::new(pool);
        let mut handles = Vec::new();
        let (rx_tx, rx_rx) = mpsc::channel();
        for t in 0..8i32 {
            let p = pool.clone();
            let sink = rx_tx.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10i32 {
                    let (reply, rx) = mpsc::channel();
                    p.submit(BatchJob {
                        key: mk("gdf/conv"),
                        items: vec![BatchItem {
                            inputs: vec![Tensor::vector(vec![(t * 10 + i) * 2])],
                            reply,
                            enqueued: Instant::now(),
                        }],
                    })
                    .unwrap();
                    sink.send((t * 10 + i, rx)).unwrap();
                }
            }));
        }
        drop(rx_tx);
        for h in handles {
            h.join().unwrap();
        }
        // drop the pool while batches are still queued: shutdown must
        // drain every queued batch, not abandon it
        drop(pool);
        let mut seen = 0;
        while let Ok((v, rx)) = rx_rx.recv() {
            let r = rx.recv().expect("reply must arrive before shutdown").unwrap();
            assert_eq!(r.outputs[0].data, vec![v]);
            seen += 1;
        }
        assert_eq!(seen, 80);
        assert_eq!(metrics.completed(), 80);
        assert_eq!(metrics.errors(), 0);
    }

    /// An executor whose batch path rejects any input containing a
    /// negative value wholesale, while the scalar path only fails the
    /// offending request — exercises the shard's per-request retry.
    struct Picky;

    impl Executor for Picky {
        fn exec(&self, _key: ModelKey, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            if inputs[0].data.iter().any(|&v| v < 0) {
                return Err(anyhow!("negative input"));
            }
            Ok(vec![inputs[0].clone()])
        }

        fn exec_batch(
            &self,
            key: ModelKey,
            batch: &[Vec<Tensor>],
        ) -> Result<Vec<Vec<Tensor>>> {
            if batch.iter().any(|ins| ins[0].data.iter().any(|&v| v < 0)) {
                return Err(anyhow!("poisoned batch"));
            }
            batch.iter().map(|ins| self.exec(key, ins)).collect()
        }

        fn keys(&self) -> Vec<ModelKey> {
            vec![mk("gdf/conv")]
        }
    }

    #[test]
    fn failing_batches_retry_per_request() {
        // one malformed request poisons the whole-batch path; the shard
        // retries one-by-one so batch-mates still succeed
        let metrics = Arc::new(Metrics::new());
        let pool = EnginePool::spawn(1, metrics.clone(), |_| Ok(Picky)).unwrap();
        let (items, rxs): (Vec<BatchItem>, Vec<_>) = (0..3i32)
            .map(|i| {
                let (reply, rx) = mpsc::channel();
                let v = if i == 1 { -5 } else { i };
                (
                    BatchItem {
                        inputs: vec![Tensor::vector(vec![v])],
                        reply,
                        enqueued: Instant::now(),
                    },
                    rx,
                )
            })
            .unzip();
        pool.submit(BatchJob { key: mk("gdf/conv"), items }).unwrap();
        let results: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert_eq!(results[0].as_ref().unwrap().outputs[0].data, vec![0]);
        assert!(results[1].is_err());
        assert_eq!(results[2].as_ref().unwrap().outputs[0].data, vec![2]);
        assert_eq!(metrics.completed(), 2);
        assert_eq!(metrics.errors(), 1);
    }
}
