//! The engine thread: exclusive owner of the (non-`Send`) PJRT runtime.
//!
//! [`Engine::spawn`] takes a *factory* closure that constructs the
//! executor on the engine thread itself; other threads talk to it
//! through an mpsc command channel. [`Executor`] abstracts the runtime
//! — typed [`ModelKey`] in, shape-carrying [`Tensor`]s through — so
//! coordinator logic is testable without artifacts ([`MockExecutor`]).

use crate::catalog::{self, App, ModelKey, Tensor};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::sync::mpsc;
use std::thread::JoinHandle;

/// Anything that can execute a cataloged model on shape-carrying i32
/// tensors.
pub trait Executor {
    fn exec(&self, key: ModelKey, inputs: &[Tensor]) -> Result<Vec<Tensor>>;
    /// Registered model keys (for router validation / `--list-models`).
    fn keys(&self) -> Vec<ModelKey>;
}

impl Executor for crate::runtime::Runtime {
    fn exec(&self, key: ModelKey, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let refs: Vec<&[i32]> = inputs.iter().map(|t| t.data.as_slice()).collect();
        let route = key.to_string();
        let outputs = self.exec_i32(&route, &refs)?;
        // artifact manifests carry output shapes; fall back to flat
        let shapes: Vec<Vec<usize>> = self
            .meta(&route)
            .map(|m| m.outputs.iter().map(|p| p.dims.clone()).collect())
            .unwrap_or_default();
        Ok(outputs
            .into_iter()
            .enumerate()
            .map(|(k, data)| match shapes.get(k) {
                Some(dims) if dims.iter().product::<usize>() == data.len() => {
                    Tensor { shape: dims.clone(), data }
                }
                _ => Tensor::vector(data),
            })
            .collect())
    }

    fn keys(&self) -> Vec<ModelKey> {
        crate::runtime::Runtime::keys(self)
            .iter()
            .filter_map(|s| ModelKey::parse(s).ok())
            .collect()
    }
}

/// Deterministic stand-in executor for coordinator tests: echoes inputs
/// through simple integer transforms per app, preserving shapes.
pub struct MockExecutor {
    pub keys: Vec<ModelKey>,
    /// artificial per-exec latency (for batching tests)
    pub delay: std::time::Duration,
}

impl MockExecutor {
    pub fn new(keys: &[ModelKey]) -> MockExecutor {
        MockExecutor { keys: keys.to_vec(), delay: std::time::Duration::ZERO }
    }

    /// A mock registered for the entire 9-key catalog.
    pub fn full_catalog() -> MockExecutor {
        MockExecutor::new(&ModelKey::catalog())
    }
}

impl Executor for MockExecutor {
    fn exec(&self, key: ModelKey, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if !self.keys.contains(&key) {
            return Err(anyhow!(
                "unknown model {key}; available models: [{}]",
                catalog::join(self.keys.iter())
            ));
        }
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        // denoise/classify: halve every element; blend: average inputs
        let data: Vec<i32> = if key.app == App::Blend {
            inputs[0]
                .data
                .iter()
                .zip(&inputs[1].data)
                .map(|(&a, &b)| (a + b) / 2)
                .collect()
        } else {
            inputs[0].data.iter().map(|&v| v / 2).collect()
        };
        Ok(vec![Tensor { shape: inputs[0].shape.clone(), data }])
    }

    fn keys(&self) -> Vec<ModelKey> {
        self.keys.clone()
    }
}

/// Command executed on the engine thread.
pub struct ExecRequest {
    pub key: ModelKey,
    pub inputs: Vec<Tensor>,
    pub reply: mpsc::Sender<Result<Vec<Tensor>>>,
}

enum Cmd {
    Exec(ExecRequest),
    Keys(mpsc::Sender<Vec<ModelKey>>),
    Shutdown,
}

/// Handle to the engine thread.
pub struct Engine {
    tx: mpsc::Sender<Cmd>,
    handle: Option<JoinHandle<()>>,
}

impl Engine {
    /// Spawn the engine; `factory` runs on the engine thread (the place
    /// where the non-Send PJRT client must be created). Fails if the
    /// factory fails.
    pub fn spawn<E, F>(factory: F) -> Result<Engine>
    where
        E: Executor,
        F: FnOnce() -> Result<E> + Send + 'static,
    {
        let (tx, rx) = mpsc::channel::<Cmd>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let handle = std::thread::Builder::new()
            .name("ppc-engine".into())
            .spawn(move || {
                let executor = match factory() {
                    Ok(e) => {
                        let _ = ready_tx.send(Ok(()));
                        e
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                        return;
                    }
                };
                // per-model exec counts (metrics can be derived by the
                // server; kept here for debugging)
                let mut counts: HashMap<ModelKey, u64> = HashMap::new();
                while let Ok(cmd) = rx.recv() {
                    match cmd {
                        Cmd::Exec(req) => {
                            let result = executor.exec(req.key, &req.inputs);
                            *counts.entry(req.key).or_default() += 1;
                            let _ = req.reply.send(result);
                        }
                        Cmd::Keys(reply) => {
                            let _ = reply.send(executor.keys());
                        }
                        Cmd::Shutdown => break,
                    }
                }
            })?;
        ready_rx
            .recv()
            .map_err(|_| anyhow!("engine thread died during startup"))??;
        Ok(Engine { tx, handle: Some(handle) })
    }

    /// Execute synchronously (blocks the calling thread, not the engine
    /// queue — other callers' requests are serialized behind it).
    pub fn exec(&self, key: ModelKey, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .send(Cmd::Exec(ExecRequest { key, inputs, reply }))
            .map_err(|_| anyhow!("engine is down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))?
    }

    /// Fire an async execution; the reply lands on `reply`.
    pub fn exec_async(
        &self,
        key: ModelKey,
        inputs: Vec<Tensor>,
        reply: mpsc::Sender<Result<Vec<Tensor>>>,
    ) -> Result<()> {
        self.tx
            .send(Cmd::Exec(ExecRequest { key, inputs, reply }))
            .map_err(|_| anyhow!("engine is down"))
    }

    pub fn keys(&self) -> Result<Vec<ModelKey>> {
        let (tx, rx) = mpsc::channel();
        self.tx.send(Cmd::Keys(tx)).map_err(|_| anyhow!("engine is down"))?;
        rx.recv().map_err(|_| anyhow!("engine dropped reply"))
    }
}

impl Drop for Engine {
    fn drop(&mut self) {
        let _ = self.tx.send(Cmd::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(s: &str) -> ModelKey {
        ModelKey::parse(s).unwrap()
    }

    #[test]
    fn spawn_exec_shutdown() {
        let engine = Engine::spawn(|| Ok(MockExecutor::new(&[mk("gdf/conv")]))).unwrap();
        let out = engine
            .exec(mk("gdf/conv"), vec![Tensor::vector(vec![10, 20, 30])])
            .unwrap();
        assert_eq!(out[0].data, vec![5, 10, 15]);
        assert_eq!(out[0].shape, vec![3]);
        assert_eq!(engine.keys().unwrap(), vec![mk("gdf/conv")]);
    }

    #[test]
    fn unknown_key_errors_list_the_catalog() {
        let engine = Engine::spawn(|| Ok(MockExecutor::new(&[mk("gdf/conv")]))).unwrap();
        let e = engine
            .exec(mk("frnn/conv"), vec![Tensor::vector(vec![1])])
            .unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("unknown model frnn/conv"), "{msg}");
        assert!(msg.contains("available models: [gdf/conv]"), "{msg}");
    }

    #[test]
    fn factory_failure_propagates() {
        let r = Engine::spawn(|| -> Result<MockExecutor> { Err(anyhow!("boom")) });
        assert!(r.is_err());
    }

    #[test]
    fn concurrent_callers_serialize() {
        let engine = std::sync::Arc::new(
            Engine::spawn(|| Ok(MockExecutor::new(&[mk("frnn/conv")]))).unwrap(),
        );
        let mut handles = Vec::new();
        for t in 0..8 {
            let e = engine.clone();
            handles.push(std::thread::spawn(move || {
                let out = e
                    .exec(mk("frnn/conv"), vec![Tensor::vector(vec![t * 2])])
                    .unwrap();
                assert_eq!(out[0].data[0], t);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
