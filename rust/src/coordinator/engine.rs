//! The sharded engine pool: N worker shards, each the exclusive owner
//! of its own executor, consuming whole [`ModelKey`] batches.
//!
//! [`EnginePool::spawn`] takes a *factory* closure that constructs one
//! executor per shard **on the shard's own thread** (the place where a
//! non-`Send` PJRT client must be created; for the native backend each
//! shard typically builds its own [`crate::runtime::NativeExecutor`]
//! from the shared persistent netlist cache, so only the first build
//! synthesizes anything). Other threads talk to shards through mpsc
//! command channels.
//!
//! The unit of work is a [`BatchJob`] — a whole `ModelKey` batch with
//! one reply channel per request. The receiving shard runs the batch
//! through [`Executor::exec_batch`] (the 256-lane compiled-tape path on
//! the native backend), records per-shard/per-key batch metrics, and
//! scatters the per-request responses itself, so no coordinator thread
//! ever blocks on model execution.
//!
//! Routing comes in two flavors. An unplaced pool ([`EnginePool::spawn`])
//! replicates the catalog on every shard and picks the shard with the
//! fewest queued batches (round-robin on ties). A *placed* pool
//! ([`EnginePool::spawn_placed`]) builds each shard only its
//! [`Placement`] subset and routes sticky-first: least-loaded among the
//! key's replica shards, spilling to the globally least-loaded shard
//! only when every replica is past the spill threshold (or dead) — the
//! receiving shard then lazily registers the model from the shared
//! netlist cache.
//!
//! [`Executor`] abstracts the runtime — typed [`ModelKey`] in,
//! shape-carrying [`Tensor`]s through — so coordinator logic is
//! testable without artifacts ([`MockExecutor`]).

use super::admission::{Permit, Rejection};
use super::metrics::{ExpiredAt, Metrics};
use super::placement::Placement;
use super::server::Response;
use crate::catalog::{self, App, ModelKey, Quality, QualityMetric, QualityProfile, Tensor, PSNR_CAP};
use anyhow::{anyhow, Result};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;
use std::time::Instant;

/// Anything that can execute a cataloged model on shape-carrying i32
/// tensors.
pub trait Executor {
    fn exec(&self, key: ModelKey, inputs: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Execute a whole batch of requests for one model; element `i` of
    /// the result answers `batch[i]`, bit-exact with `exec(key,
    /// &batch[i])`. The default loops over [`Executor::exec`]; the
    /// native backend overrides it with the lane-batched netlist path.
    fn exec_batch(&self, key: ModelKey, batch: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        batch.iter().map(|inputs| self.exec(key, inputs)).collect()
    }

    /// Registered model keys (for router validation / `--list-models`).
    fn keys(&self) -> Vec<ModelKey>;

    /// Keys whose datapaths are *built* right now. Executors with lazy
    /// registration (the native backend under sticky placement) keep
    /// this smaller than [`Executor::keys`]; everything else serves
    /// exactly what it registered.
    fn resident_keys(&self) -> Vec<ModelKey> {
        self.keys()
    }

    /// The measured quality of `key`'s tier (PSNR vs the precise tier
    /// for the image apps, absolute top-1 accuracy for FRNN), when the
    /// backend measured one at registration. Rides on every response so
    /// clients see the quality they were actually served at, and gates
    /// the autopilot's tier descent against the quality floor.
    fn quality(&self, _key: ModelKey) -> Option<QualityProfile> {
        None
    }
}

impl Executor for crate::runtime::Runtime {
    fn exec(&self, key: ModelKey, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let route = key.to_string();
        // Bridge the AOT artifacts' fixed batch dimension: a single
        // [r, C] request against a [B, C] input port (r < B) is padded
        // with zero rows, executed, and each [B, X] output sliced back
        // to the request's r rows. The native backend has no fixed
        // batch dim; this is PJRT-only plumbing that used to live in
        // the batcher before batching went lane-oriented.
        if let Some(m) = self.meta(&route).cloned() {
            if inputs.len() == 1
                && m.inputs.len() == 1
                && m.inputs[0].dims.len() == 2
                && inputs[0].shape.len() == 2
                && inputs[0].shape[1] == m.inputs[0].dims[1]
                && inputs[0].shape[0] < m.inputs[0].dims[0]
            {
                // Zero-row padding is only sound for row-independent
                // models: FRNN classifies each 960-pixel row on its
                // own, so padded rows are dead lanes whose outputs are
                // sliced away. GDF (and blend) read *across* rows —
                // their artifacts expect edge replication at the image
                // boundary, and silently zero-padding a short image
                // would corrupt the rows next to the pad. Fail loudly
                // instead.
                if key.app != App::Frnn {
                    return Err(anyhow!(
                        "{key}: request has {} rows but the artifact port is fixed at {} — \
                         zero-row padding is only valid for row-independent models (frnn); \
                         {} models expect edge replication, so submit a full-size image or \
                         compile an artifact for this shape",
                        inputs[0].shape[0],
                        m.inputs[0].dims[0],
                        key.app
                    ));
                }
                let (b, c) = (m.inputs[0].dims[0], m.inputs[0].dims[1]);
                let r = inputs[0].shape[0];
                let mut flat = inputs[0].data.clone();
                flat.resize(b * c, 0);
                let outs = self.exec_i32(&route, &[&flat])?;
                return Ok(outs
                    .into_iter()
                    .map(|data| {
                        let out_row = data.len() / b;
                        Tensor {
                            shape: vec![r, out_row],
                            data: data[..r * out_row].to_vec(),
                        }
                    })
                    .collect());
            }
        }
        let refs: Vec<&[i32]> = inputs.iter().map(|t| t.data.as_slice()).collect();
        let outputs = self.exec_i32(&route, &refs)?;
        // artifact manifests carry output shapes; fall back to flat
        let shapes: Vec<Vec<usize>> = self
            .meta(&route)
            .map(|m| m.outputs.iter().map(|p| p.dims.clone()).collect())
            .unwrap_or_default();
        Ok(outputs
            .into_iter()
            .enumerate()
            .map(|(k, data)| match shapes.get(k) {
                Some(dims) if dims.iter().product::<usize>() == data.len() => {
                    Tensor { shape: dims.clone(), data }
                }
                _ => Tensor::vector(data),
            })
            .collect())
    }

    /// Whole-batch execution against the AOT artifacts: when every
    /// request in a row-independent (frnn) batch is a single `[r_i, C]`
    /// tensor against the artifact's fixed `[B, C]` port and the rows
    /// fit (`Σ r_i <= B`), the rows are packed contiguously into ONE
    /// padded execution and each `[B, X]` output is sliced back per
    /// request — one device dispatch for the whole batch instead of one
    /// padded dispatch per request (the PJRT analogue of the native
    /// backend's 256-lane tape pass). Zero-row padding is only sound
    /// for row-independent models (see [`Executor::exec`] above), so
    /// anything else falls back to the default per-request loop.
    fn exec_batch(&self, key: ModelKey, batch: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        let route = key.to_string();
        let port = self.meta(&route).and_then(|m| {
            if m.inputs.len() == 1 && m.inputs[0].dims.len() == 2 {
                Some((m.inputs[0].dims[0], m.inputs[0].dims[1]))
            } else {
                None
            }
        });
        if let (App::Frnn, Some((b, c)), false) = (key.app, port, batch.is_empty()) {
            // total row count, or None if any request breaks the
            // single-[r, C]-tensor contract
            let rows: Option<usize> = batch.iter().try_fold(0usize, |acc, ins| {
                if ins.len() == 1 && ins[0].shape.len() == 2 && ins[0].shape[1] == c {
                    Some(acc + ins[0].shape[0])
                } else {
                    None
                }
            });
            if let Some(total) = rows {
                if total <= b {
                    let mut flat = Vec::with_capacity(b * c);
                    for ins in batch {
                        flat.extend_from_slice(&ins[0].data);
                    }
                    flat.resize(b * c, 0);
                    let outs = self.exec_i32(&route, &[&flat])?;
                    let mut results: Vec<Vec<Tensor>> =
                        batch.iter().map(|_| Vec::new()).collect();
                    for data in outs {
                        let out_row = data.len() / b;
                        let mut off = 0usize;
                        for (i, ins) in batch.iter().enumerate() {
                            let r = ins[0].shape[0];
                            results[i].push(Tensor {
                                shape: vec![r, out_row],
                                data: data[off * out_row..(off + r) * out_row].to_vec(),
                            });
                            off += r;
                        }
                    }
                    return Ok(results);
                }
            }
        }
        batch.iter().map(|inputs| self.exec(key, inputs)).collect()
    }

    fn keys(&self) -> Vec<ModelKey> {
        crate::runtime::Runtime::keys(self)
            .iter()
            .filter_map(|s| ModelKey::parse(s).ok())
            .collect()
    }
}

/// Deterministic stand-in executor for coordinator tests: echoes inputs
/// through simple integer transforms per app, preserving shapes.
pub struct MockExecutor {
    pub keys: Vec<ModelKey>,
    /// artificial per-exec latency (for batching tests)
    pub delay: std::time::Duration,
}

impl MockExecutor {
    pub fn new(keys: &[ModelKey]) -> MockExecutor {
        MockExecutor { keys: keys.to_vec(), delay: std::time::Duration::ZERO }
    }

    /// A mock registered for the entire 9-key catalog.
    pub fn full_catalog() -> MockExecutor {
        MockExecutor::new(&ModelKey::catalog())
    }
}

impl Executor for MockExecutor {
    fn exec(&self, key: ModelKey, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if !self.keys.contains(&key) {
            return Err(anyhow!(
                "unknown model {key}; available models: [{}]",
                catalog::join(self.keys.iter())
            ));
        }
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
        }
        // denoise/classify: halve every element; blend: average inputs
        let data: Vec<i32> = if key.app == App::Blend {
            inputs[0]
                .data
                .iter()
                .zip(&inputs[1].data)
                .map(|(&a, &b)| (a + b) / 2)
                .collect()
        } else {
            inputs[0].data.iter().map(|&v| v / 2).collect()
        };
        Ok(vec![Tensor { shape: inputs[0].shape.clone(), data }])
    }

    fn keys(&self) -> Vec<ModelKey> {
        self.keys.clone()
    }

    /// Deterministic stand-in quality numbers, decreasing per tier, so
    /// coordinator and wire tests can assert measured-quality plumbing
    /// without running the apps' eval harness.
    fn quality(&self, key: ModelKey) -> Option<QualityProfile> {
        if !self.keys.contains(&key) {
            return None;
        }
        let (metric, value) = match (key.app, key.tier()) {
            (App::Frnn, Quality::Precise) => (QualityMetric::Accuracy, 0.95),
            (App::Frnn, Quality::Balanced) => (QualityMetric::Accuracy, 0.92),
            (App::Frnn, Quality::Economy) => (QualityMetric::Accuracy, 0.85),
            (_, Quality::Precise) => (QualityMetric::Psnr, PSNR_CAP),
            (_, Quality::Balanced) => (QualityMetric::Psnr, 36.0),
            (_, Quality::Economy) => (QualityMetric::Psnr, 31.0),
        };
        Some(QualityProfile { metric, value, reference: Quality::Precise })
    }
}

/// One request inside a [`BatchJob`]: its input tensors, where the
/// response goes, when it entered the system (for latency accounting),
/// its optional deadline, and the admission state it carries.
pub struct BatchItem {
    pub inputs: Vec<Tensor>,
    pub reply: mpsc::Sender<Result<Response>>,
    pub enqueued: Instant,
    /// Absolute deadline: a shard skips the item (typed
    /// [`Rejection::DeadlineExpired`] reply) instead of executing past
    /// it.
    pub deadline: Option<Instant>,
    /// True when admission degraded this request below its requested
    /// quality tier (echoed on the [`Response`]).
    pub degraded: bool,
    /// In-flight capacity permit; releases on drop, after the reply is
    /// sent.
    pub permit: Option<Permit>,
}

impl BatchItem {
    /// A plain item: enqueued now, no deadline, not degraded, no
    /// admission permit (direct [`EnginePool::submit`] callers — tests,
    /// benches — bypass the gate by construction).
    pub fn new(inputs: Vec<Tensor>, reply: mpsc::Sender<Result<Response>>) -> BatchItem {
        BatchItem {
            inputs,
            reply,
            enqueued: Instant::now(),
            deadline: None,
            degraded: false,
            permit: None,
        }
    }
}

/// A whole `ModelKey` batch — the unit of work a shard executes.
pub struct BatchJob {
    pub key: ModelKey,
    pub items: Vec<BatchItem>,
}

enum Cmd {
    Batch(BatchJob),
    Keys(mpsc::Sender<Vec<ModelKey>>),
    Resident(mpsc::Sender<Vec<ModelKey>>),
    Shutdown,
}

struct Shard {
    tx: mpsc::Sender<Cmd>,
    /// Batches queued on (or running in) this shard.
    depth: Arc<AtomicUsize>,
    /// False when the shard's executor factory failed at spawn (placed
    /// pools tolerate this; routing skips dead shards).
    alive: bool,
    handle: Option<JoinHandle<()>>,
}

/// Handle to the shard pool.
pub struct EnginePool {
    shards: Vec<Shard>,
    metrics: Arc<Metrics>,
    /// Sticky model placement; `None` routes purely least-loaded (every
    /// shard holds the whole catalog).
    placement: Option<Placement>,
    rr: AtomicUsize,
}

impl EnginePool {
    /// Spawn `shards` worker shards; `factory(shard_index)` runs on
    /// each shard's thread to construct that shard's executor. Every
    /// shard holds the whole catalog and batches route least-loaded.
    /// Fails if any factory call fails.
    pub fn spawn<E, F>(shards: usize, metrics: Arc<Metrics>, factory: F) -> Result<EnginePool>
    where
        E: Executor + 'static,
        F: Fn(usize) -> Result<E> + Send + Sync + 'static,
    {
        EnginePool::spawn_inner(
            shards.max(1),
            None,
            metrics,
            move |shard: usize, _keys: &[ModelKey]| factory(shard),
        )
    }

    /// Spawn a pool under sticky `placement`: `factory(shard_index,
    /// assigned_keys)` runs on each shard's thread and builds only that
    /// shard's model subset. A shard whose factory fails is tolerated —
    /// it is marked dead, its keys fail over to the least-loaded live
    /// shard (which lazily registers them) — as long as at least one
    /// shard survives.
    pub fn spawn_placed<E, F>(
        placement: Placement,
        metrics: Arc<Metrics>,
        factory: F,
    ) -> Result<EnginePool>
    where
        E: Executor + 'static,
        F: Fn(usize, &[ModelKey]) -> Result<E> + Send + Sync + 'static,
    {
        for (key, shards) in placement.iter() {
            metrics.record_placement(key, shards);
        }
        EnginePool::spawn_inner(placement.shards(), Some(placement), metrics, factory)
    }

    fn spawn_inner<E, F>(
        shards: usize,
        placement: Option<Placement>,
        metrics: Arc<Metrics>,
        factory: F,
    ) -> Result<EnginePool>
    where
        E: Executor + 'static,
        F: Fn(usize, &[ModelKey]) -> Result<E> + Send + Sync + 'static,
    {
        let tolerate_failures = placement.is_some();
        let factory = Arc::new(factory);
        let (ready_tx, ready_rx) = mpsc::channel::<(usize, Result<()>)>();
        let mut out: Vec<Shard> = Vec::with_capacity(shards);
        let mut failures: Vec<(usize, anyhow::Error)> = Vec::new();
        fn note(
            r: Result<(usize, Result<()>), mpsc::RecvError>,
            failures: &mut Vec<(usize, anyhow::Error)>,
        ) -> Result<()> {
            let (shard, built) = r.map_err(|_| anyhow!("a shard died during startup"))?;
            if let Err(e) = built {
                failures.push((shard, e));
            }
            Ok(())
        }
        for s in 0..shards {
            let (tx, rx) = mpsc::channel::<Cmd>();
            let depth = Arc::new(AtomicUsize::new(0));
            let d = depth.clone();
            let f = factory.clone();
            let m = metrics.clone();
            let ready = ready_tx.clone();
            let assigned: Vec<ModelKey> =
                placement.as_ref().map(|p| p.keys_for(s)).unwrap_or_default();
            let handle = std::thread::Builder::new()
                .name(format!("ppc-shard{s}"))
                .spawn(move || shard_loop(s, f, assigned, m, d, rx, ready))?;
            out.push(Shard { tx, depth, alive: true, handle: Some(handle) });
            if s == 0 {
                // shard 0 finishes building before the rest start. For
                // an unplaced pool (every shard builds the whole
                // catalog) that warms the shared BLIF netlist cache, so
                // shards 1..N load instead of re-synthesizing. Under
                // placement shard 0 only warms *its own subset*: with
                // --replicas >= 2, the replicas of a key not on shard 0
                // may still synthesize it concurrently on a cold cache
                // — duplicated work bounded by the replica factor, never
                // a correctness problem (cache writes are temp+rename
                // atomic and care-set-verified on load).
                note(ready_rx.recv(), &mut failures)?;
                if !tolerate_failures && !failures.is_empty() {
                    // fail fast: don't spawn shards 1..N (each would
                    // build the whole catalog, cold) just to discard
                    // them behind an error that is already known
                    let (shard, e) = failures.swap_remove(0);
                    return Err(e.context(format!("shard {shard} failed to start")));
                }
            }
        }
        drop(ready_tx);
        for _ in 1..shards {
            note(ready_rx.recv(), &mut failures)?;
        }
        if !failures.is_empty() {
            if !tolerate_failures || failures.len() == shards {
                let (shard, e) = failures.swap_remove(0);
                return Err(e.context(format!("shard {shard} failed to start")));
            }
            for (shard, e) in failures {
                eprintln!(
                    "warning: shard {shard} failed to start ({e:#}); its models fail \
                     over to the remaining shards via lazy registration"
                );
                out[shard].alive = false;
            }
        }
        Ok(EnginePool { shards: out, metrics, placement, rr: AtomicUsize::new(0) })
    }

    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// The sticky placement this pool routes with, if any.
    pub fn placement(&self) -> Option<&Placement> {
        self.placement.as_ref()
    }

    /// Batches currently queued on (or running in) each shard.
    pub fn queue_depths(&self) -> Vec<usize> {
        self.shards
            .iter()
            .map(|s| s.depth.load(Ordering::Relaxed))
            .collect()
    }

    /// Least-loaded live shard, scanning from a rotating start so ties
    /// round-robin. `candidates` restricts the scan (replica sets).
    fn least_loaded(&self, start: usize, candidates: Option<&[usize]>) -> Option<(usize, usize)> {
        let n = candidates.map_or(self.shards.len(), |c| c.len());
        let mut best: Option<(usize, usize)> = None;
        for i in 0..n {
            let s = match candidates {
                Some(c) => c[(start + i) % n],
                None => (start + i) % n,
            };
            if !self.shards[s].alive {
                continue;
            }
            let d = self.shards[s].depth.load(Ordering::Relaxed);
            if best.map_or(true, |(_, bd)| d < bd) {
                best = Some((s, d));
            }
        }
        best
    }

    /// Pick the shard for a `key` batch: sticky-first among the key's
    /// live replicas (least-loaded, round-robin on ties), spilling to
    /// the globally least-loaded shard when every replica is at or past
    /// the spill threshold and somewhere else is strictly quieter.
    /// Returns `(shard, spilled)`.
    fn route(&self, key: ModelKey) -> Result<(usize, bool)> {
        let start = self.rr.fetch_add(1, Ordering::Relaxed);
        let global = || {
            self.least_loaded(start, None)
                .ok_or_else(|| anyhow!("engine pool has no live shards"))
        };
        let Some(placement) = &self.placement else {
            return Ok((global()?.0, false));
        };
        let Some(replicas) = placement.shards_of(key) else {
            // unplaced key (unknown to the placement): no stickiness
            return Ok((global()?.0, false));
        };
        match self.least_loaded(start, Some(replicas)) {
            Some((s, d)) if d < placement.spill_threshold() => Ok((s, false)),
            sticky => {
                let (g, gd) = global()?;
                match sticky {
                    // every replica is backed up, but nowhere else is
                    // quieter — stay sticky rather than force a lazy
                    // registration for no queueing win
                    Some((s, d)) if gd >= d => Ok((s, false)),
                    _ => Ok((g, !replicas.contains(&g))),
                }
            }
        }
    }

    /// Route a whole `ModelKey` batch to a shard (sticky placement when
    /// configured, least-loaded otherwise). The shard executes it via
    /// [`Executor::exec_batch`] and scatters the per-request replies.
    pub fn submit(&self, job: BatchJob) -> Result<()> {
        let (best, spilled) = self.route(job.key)?;
        self.metrics.record_routed();
        if spilled {
            self.metrics.record_spill(job.key);
        }
        let shard = &self.shards[best];
        // the post-increment depth is this submit's own observation of
        // the queue high-water mark: two concurrent submits get 1 and 2,
        // never a stale 1 and 1
        let depth_now = shard.depth.fetch_add(1, Ordering::Relaxed) + 1;
        self.metrics.record_queue_depth(best, depth_now);
        shard.tx.send(Cmd::Batch(job)).map_err(|_| {
            shard.depth.fetch_sub(1, Ordering::Relaxed);
            anyhow!("engine pool is down")
        })
    }

    /// Execute a single request synchronously — a batch of one (blocks
    /// the calling thread, not the pool).
    pub fn exec(&self, key: ModelKey, inputs: Vec<Tensor>) -> Result<Vec<Tensor>> {
        let (reply, rx) = mpsc::channel();
        self.submit(BatchJob { key, items: vec![BatchItem::new(inputs, reply)] })?;
        let resp = rx.recv().map_err(|_| anyhow!("engine dropped reply"))??;
        Ok(resp.outputs)
    }

    /// Ask every live shard one `Cmd` question and collect the answers
    /// as `(shard, reply)` pairs.
    fn ask_shards(
        &self,
        make: impl Fn(mpsc::Sender<Vec<ModelKey>>) -> Cmd,
    ) -> Result<Vec<(usize, Vec<ModelKey>)>> {
        let mut waiting = Vec::new();
        for (s, shard) in self.shards.iter().enumerate() {
            if !shard.alive {
                continue;
            }
            let (tx, rx) = mpsc::channel();
            shard
                .tx
                .send(make(tx))
                .map_err(|_| anyhow!("engine pool is down"))?;
            waiting.push((s, rx));
        }
        waiting
            .into_iter()
            .map(|(s, rx)| {
                Ok((s, rx.recv().map_err(|_| anyhow!("engine dropped reply"))?))
            })
            .collect()
    }

    /// The servable catalog: the union of every live shard's keys, in
    /// first-seen (catalog) order.
    pub fn keys(&self) -> Result<Vec<ModelKey>> {
        let mut union: Vec<ModelKey> = Vec::new();
        for (_, keys) in self.ask_shards(Cmd::Keys)? {
            for k in keys {
                if !union.contains(&k) {
                    union.push(k);
                }
            }
        }
        Ok(union)
    }

    /// Per-shard resident (built) model keys — dead shards report an
    /// empty set. Under sticky placement each live shard holds its
    /// assigned subset plus whatever it lazily registered.
    pub fn resident_keys(&self) -> Result<Vec<Vec<ModelKey>>> {
        let mut out = vec![Vec::new(); self.shards.len()];
        for (s, keys) in self.ask_shards(Cmd::Resident)? {
            out[s] = keys;
        }
        Ok(out)
    }
}

impl Drop for EnginePool {
    /// Graceful drain: every batch already queued on a shard executes
    /// before the shard sees its shutdown command (mpsc preserves
    /// order), then all shard threads are joined.
    fn drop(&mut self) {
        for s in &self.shards {
            let _ = s.tx.send(Cmd::Shutdown);
        }
        for s in &mut self.shards {
            if let Some(h) = s.handle.take() {
                let _ = h.join();
            }
        }
    }
}

fn shard_loop<E, F>(
    shard: usize,
    factory: Arc<F>,
    assigned: Vec<ModelKey>,
    metrics: Arc<Metrics>,
    depth: Arc<AtomicUsize>,
    rx: mpsc::Receiver<Cmd>,
    ready: mpsc::Sender<(usize, Result<()>)>,
) where
    E: Executor + 'static,
    F: Fn(usize, &[ModelKey]) -> Result<E> + Send + Sync + 'static,
{
    use std::panic::{catch_unwind, AssertUnwindSafe};
    // a *panicking* factory must still answer the ready channel — the
    // spawner holds its own sender while waiting for shard 0, so an
    // unwound thread that never sends would hang spawn forever
    let built = catch_unwind(AssertUnwindSafe(|| (*factory)(shard, &assigned)))
        .unwrap_or_else(|_| Err(anyhow!("executor factory panicked")));
    let executor = match built {
        Ok(e) => {
            let _ = ready.send((shard, Ok(())));
            e
        }
        Err(e) => {
            let _ = ready.send((shard, Err(e)));
            return;
        }
    };
    while let Ok(cmd) = rx.recv() {
        match cmd {
            Cmd::Batch(job) => {
                run_batch(shard, &executor, &metrics, job);
                depth.fetch_sub(1, Ordering::Relaxed);
            }
            Cmd::Keys(reply) => {
                let _ = reply.send(executor.keys());
            }
            Cmd::Resident(reply) => {
                let _ = reply.send(executor.resident_keys());
            }
            Cmd::Shutdown => break,
        }
    }
}

/// Execute one batch on a shard and scatter the per-request replies.
/// Items whose deadline already passed are answered with a typed
/// [`Rejection::DeadlineExpired`] instead of executed (a fully expired
/// batch skips execution entirely). A failing batch is retried
/// request-by-request so one malformed request cannot poison its
/// batch-mates; a *panicking* executor is caught so one bad request
/// cannot kill the shard thread (which would silently swallow ~1/N of
/// all later traffic).
fn run_batch<E: Executor>(shard: usize, executor: &E, metrics: &Metrics, job: BatchJob) {
    use std::panic::{catch_unwind, AssertUnwindSafe};
    let BatchJob { key, items } = job;
    // drop expired items before spending shard time on them: their
    // callers have already given up, and the lanes are better spent on
    // the live batch-mates
    let now = Instant::now();
    let mut live = Vec::with_capacity(items.len());
    for it in items {
        if it.deadline.map_or(false, |d| now >= d) {
            metrics.record_expired(key, ExpiredAt::Shard);
            let _ = it.reply.send(Err(anyhow::Error::new(Rejection::DeadlineExpired)));
            // it.permit drops here: expiry releases capacity too
        } else {
            live.push(it);
        }
    }
    let items = live;
    if items.is_empty() {
        return;
    }
    let size = items.len();
    // the tier this batch is *served at* (the routed key's tier — after
    // any degrade) and its measured quality: both ride on every reply,
    // and batch stats land under this tier so per-tier latency streams
    // stay attributable
    let tier = key.tier();
    let quality = executor.quality(key);
    let mut inputs = Vec::with_capacity(size);
    let mut waiters = Vec::with_capacity(size);
    for it in items {
        inputs.push(it.inputs);
        // the permit rides next to the reply sender so it drops (and
        // releases capacity) right after the reply is scattered
        waiters.push((it.reply, it.enqueued, it.degraded, it.permit));
    }
    let t0 = Instant::now();
    // the batch's queueing share: how long its oldest request sat
    // between submit and dispatch (reported separately from execute so
    // a backed-up batcher and a slow datapath are distinguishable)
    let queue_wait = waiters
        .iter()
        .map(|(_, enqueued, _, _)| t0.saturating_duration_since(*enqueued))
        .max()
        .unwrap_or_default();
    // a panic unwinds into an Err so the batch falls through to the
    // per-request retry like any other wholesale failure
    let batch_result = catch_unwind(AssertUnwindSafe(|| executor.exec_batch(key, &inputs)))
        .unwrap_or_else(|_| Err(anyhow!("executor panicked on a {size}-request batch")));
    match batch_result {
        Ok(outs) if outs.len() == size => {
            metrics.record_batch(shard, key, tier, size, queue_wait, t0.elapsed(), false);
            for ((reply, enqueued, degraded, _permit), outputs) in waiters.into_iter().zip(outs) {
                metrics.record_latency(key, enqueued.elapsed());
                let _ =
                    reply.send(Ok(Response { outputs, route: key, tier, quality, degraded }));
            }
        }
        Ok(outs) => {
            // executor contract violation — fail every request loudly,
            // but still record the batch (degraded) so the stream stays
            // complete in the per-shard stats
            metrics.record_batch(shard, key, tier, size, queue_wait, t0.elapsed(), true);
            let msg = format!(
                "{key}: executor answered {} of {size} batch requests",
                outs.len()
            );
            for (reply, _, _, _permit) in waiters {
                metrics.record_error();
                let _ = reply.send(Err(anyhow!("{msg}")));
            }
        }
        Err(_) => {
            for ((reply, enqueued, degraded, _permit), ins) in waiters.into_iter().zip(inputs) {
                match catch_unwind(AssertUnwindSafe(|| executor.exec(key, &ins))) {
                    Ok(Ok(outputs)) => {
                        metrics.record_latency(key, enqueued.elapsed());
                        let _ = reply
                            .send(Ok(Response { outputs, route: key, tier, quality, degraded }));
                    }
                    Ok(Err(e)) => {
                        metrics.record_error();
                        let _ = reply.send(Err(e));
                    }
                    Err(_) => {
                        metrics.record_error();
                        let _ = reply
                            .send(Err(anyhow!("{key}: executor panicked on this request")));
                    }
                }
            }
            // the retried batch still executed — record it (degraded)
            // so a shard that always falls back to the scalar path
            // shows its real batch stream instead of zero batches and
            // inflated lane stats
            metrics.record_batch(shard, key, tier, size, queue_wait, t0.elapsed(), true);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    fn mk(s: &str) -> ModelKey {
        ModelKey::parse(s).unwrap()
    }

    fn pool(shards: usize) -> (Arc<Metrics>, EnginePool) {
        let metrics = Arc::new(Metrics::new());
        let p = EnginePool::spawn(shards, metrics.clone(), |_shard| {
            Ok(MockExecutor::full_catalog())
        })
        .unwrap();
        (metrics, p)
    }

    #[test]
    fn spawn_exec_shutdown() {
        let (_, pool) = pool(2);
        assert_eq!(pool.shards(), 2);
        let out = pool
            .exec(mk("gdf/conv"), vec![Tensor::vector(vec![10, 20, 30])])
            .unwrap();
        assert_eq!(out[0].data, vec![5, 10, 15]);
        assert_eq!(out[0].shape, vec![3]);
        assert_eq!(pool.keys().unwrap(), ModelKey::catalog());
    }

    #[test]
    fn unknown_key_errors_list_the_catalog() {
        let metrics = Arc::new(Metrics::new());
        let pool = EnginePool::spawn(1, metrics.clone(), |_| {
            Ok(MockExecutor::new(&[mk("gdf/conv")]))
        })
        .unwrap();
        let e = pool
            .exec(mk("frnn/conv"), vec![Tensor::vector(vec![1])])
            .unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("unknown model frnn/conv"), "{msg}");
        assert!(msg.contains("available models: [gdf/conv]"), "{msg}");
        assert_eq!(metrics.errors(), 1);
    }

    #[test]
    fn factory_failure_propagates() {
        let r = EnginePool::spawn(3, Arc::new(Metrics::new()), |_| -> Result<MockExecutor> {
            Err(anyhow!("boom"))
        });
        assert!(r.is_err());
    }

    #[test]
    fn panicking_factory_is_an_error_not_a_hang() {
        // a factory that panics (instead of returning Err) must still
        // surface as a spawn error — shard 0's ready reply would
        // otherwise never arrive and spawn would block forever
        let r = EnginePool::spawn(2, Arc::new(Metrics::new()), |shard| -> Result<MockExecutor> {
            if shard == 0 {
                panic!("factory exploded");
            }
            Ok(MockExecutor::full_catalog())
        });
        let e = r.err().expect("panicking factory must be an error");
        assert!(format!("{e:#}").contains("factory panicked"), "{e:#}");
    }

    #[test]
    fn batches_scatter_per_request_replies() {
        let (metrics, pool) = pool(2);
        let (items, rxs): (Vec<BatchItem>, Vec<_>) = (0..5)
            .map(|i| {
                let (reply, rx) = mpsc::channel();
                (BatchItem::new(vec![Tensor::vector(vec![i * 2])], reply), rx)
            })
            .unzip();
        pool.submit(BatchJob { key: mk("gdf/ds16"), items }).unwrap();
        for (i, rx) in rxs.into_iter().enumerate() {
            let r = rx.recv().unwrap().unwrap();
            assert_eq!(r.route, mk("gdf/ds16"));
            assert_eq!(r.outputs[0].data, vec![i as i32]);
        }
        assert_eq!(metrics.completed(), 5);
        assert!(metrics.mean_batch_size() >= 5.0);
    }

    #[test]
    fn concurrent_submitters_spread_over_shards() {
        let (metrics, pool) = pool(4);
        let pool = Arc::new(pool);
        let mut handles = Vec::new();
        for t in 0..8i32 {
            let p = pool.clone();
            handles.push(std::thread::spawn(move || {
                let out = p
                    .exec(mk("frnn/conv"), vec![Tensor::vector(vec![t * 2])])
                    .unwrap();
                assert_eq!(out[0].data[0], t);
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(metrics.completed(), 8);
    }

    #[test]
    fn shutdown_drains_queued_batches_under_concurrent_submitters() {
        let metrics = Arc::new(Metrics::new());
        let pool = EnginePool::spawn(2, metrics.clone(), |_| {
            let mut m = MockExecutor::full_catalog();
            m.delay = Duration::from_millis(1); // make batches queue up
            Ok(m)
        })
        .unwrap();
        let pool = Arc::new(pool);
        let mut handles = Vec::new();
        let (rx_tx, rx_rx) = mpsc::channel();
        for t in 0..8i32 {
            let p = pool.clone();
            let sink = rx_tx.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..10i32 {
                    let (reply, rx) = mpsc::channel();
                    p.submit(BatchJob {
                        key: mk("gdf/conv"),
                        items: vec![BatchItem::new(
                            vec![Tensor::vector(vec![(t * 10 + i) * 2])],
                            reply,
                        )],
                    })
                    .unwrap();
                    sink.send((t * 10 + i, rx)).unwrap();
                }
            }));
        }
        drop(rx_tx);
        for h in handles {
            h.join().unwrap();
        }
        // drop the pool while batches are still queued: shutdown must
        // drain every queued batch, not abandon it
        drop(pool);
        let mut seen = 0;
        while let Ok((v, rx)) = rx_rx.recv() {
            let r = rx.recv().expect("reply must arrive before shutdown").unwrap();
            assert_eq!(r.outputs[0].data, vec![v]);
            seen += 1;
        }
        assert_eq!(seen, 80);
        assert_eq!(metrics.completed(), 80);
        assert_eq!(metrics.errors(), 0);
    }

    /// An executor whose batch path rejects any input containing a
    /// negative value wholesale, while the scalar path only fails the
    /// offending request — exercises the shard's per-request retry.
    struct Picky;

    impl Executor for Picky {
        fn exec(&self, _key: ModelKey, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            if inputs[0].data.iter().any(|&v| v < 0) {
                return Err(anyhow!("negative input"));
            }
            Ok(vec![inputs[0].clone()])
        }

        fn exec_batch(
            &self,
            key: ModelKey,
            batch: &[Vec<Tensor>],
        ) -> Result<Vec<Vec<Tensor>>> {
            if batch.iter().any(|ins| ins[0].data.iter().any(|&v| v < 0)) {
                return Err(anyhow!("poisoned batch"));
            }
            batch.iter().map(|ins| self.exec(key, ins)).collect()
        }

        fn keys(&self) -> Vec<ModelKey> {
            vec![mk("gdf/conv")]
        }
    }

    #[test]
    fn failing_batches_retry_per_request() {
        // one malformed request poisons the whole-batch path; the shard
        // retries one-by-one so batch-mates still succeed
        let metrics = Arc::new(Metrics::new());
        let pool = EnginePool::spawn(1, metrics.clone(), |_| Ok(Picky)).unwrap();
        let (items, rxs): (Vec<BatchItem>, Vec<_>) = (0..3i32)
            .map(|i| {
                let (reply, rx) = mpsc::channel();
                let v = if i == 1 { -5 } else { i };
                (BatchItem::new(vec![Tensor::vector(vec![v])], reply), rx)
            })
            .unzip();
        pool.submit(BatchJob { key: mk("gdf/conv"), items }).unwrap();
        let results: Vec<_> = rxs.into_iter().map(|rx| rx.recv().unwrap()).collect();
        assert_eq!(results[0].as_ref().unwrap().outputs[0].data, vec![0]);
        assert!(results[1].is_err());
        assert_eq!(results[2].as_ref().unwrap().outputs[0].data, vec![2]);
        assert_eq!(metrics.completed(), 2);
        assert_eq!(metrics.errors(), 1);
        // the retried batch is still a batch: it must appear in the
        // stream (size 3, degraded), not vanish from the lane stats
        let b = &metrics.batch_summaries()[&(0, mk("gdf/conv"), Quality::Precise)];
        assert_eq!(b.batches, 1);
        assert_eq!(b.degraded, 1);
        assert_eq!(b.mean_size, 3.0);
    }

    #[test]
    fn shards_skip_expired_items_with_typed_replies() {
        let (metrics, pool) = pool(1);
        let mk_item = |v: i32, deadline: Option<Instant>| {
            let (reply, rx) = mpsc::channel();
            let mut item = BatchItem::new(vec![Tensor::vector(vec![v])], reply);
            item.deadline = deadline;
            (item, rx)
        };
        // a deadline of "now" is already past by the time the shard
        // picks the batch up; its batch-mate must still execute
        let (dead, dead_rx) = mk_item(4, Some(Instant::now()));
        let (live, live_rx) = mk_item(6, None);
        pool.submit(BatchJob { key: mk("gdf/conv"), items: vec![dead, live] }).unwrap();
        let err = dead_rx.recv().unwrap().unwrap_err();
        assert_eq!(err.downcast_ref::<Rejection>(), Some(&Rejection::DeadlineExpired));
        let r = live_rx.recv().unwrap().unwrap();
        assert_eq!(r.outputs[0].data, vec![3]);
        assert!(!r.degraded);
        assert_eq!(metrics.expired_at(ExpiredAt::Shard), 1);
        assert_eq!(metrics.completed(), 1);
        assert_eq!(metrics.errors(), 0, "expiry is typed, not an error");

        // a batch whose every item expired skips execution entirely:
        // no batch record is added for it
        let batches_before: usize =
            metrics.batch_summaries().values().map(|b| b.batches).sum();
        let (d1, r1) = mk_item(2, Some(Instant::now()));
        let (d2, r2) = mk_item(8, Some(Instant::now()));
        pool.submit(BatchJob { key: mk("gdf/conv"), items: vec![d1, d2] }).unwrap();
        assert!(r1.recv().unwrap().is_err());
        assert!(r2.recv().unwrap().is_err());
        let batches_after: usize =
            metrics.batch_summaries().values().map(|b| b.batches).sum();
        assert_eq!(batches_after, batches_before, "expired batch must not execute");
        assert_eq!(metrics.expired_at(ExpiredAt::Shard), 3);
    }

    /// An executor that blocks inside `exec` until the test hands it a
    /// permit — lets a test pin batches inside (and behind) a shard.
    struct Gated {
        keys: Vec<ModelKey>,
        permits: mpsc::Receiver<()>,
    }

    impl Executor for Gated {
        fn exec(&self, _key: ModelKey, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
            self.permits.recv().map_err(|_| anyhow!("gate closed"))?;
            Ok(vec![inputs[0].clone()])
        }

        fn keys(&self) -> Vec<ModelKey> {
            self.keys.clone()
        }
    }

    /// Build a pool of [`Gated`] shards. Each `send(())` on the returned
    /// sender is broadcast to every shard's gate, releasing one blocked
    /// `exec` per shard that is waiting (extra permits to idle shards
    /// sit unread and are dropped with the pool).
    fn gated_pool(
        shards: usize,
        placement: Option<Placement>,
        metrics: Arc<Metrics>,
    ) -> (EnginePool, mpsc::Sender<()>) {
        let (permit_tx, permit_rx) = mpsc::channel::<()>();
        let mut shard_txs = Vec::new();
        let mut shard_rxs = Vec::new();
        for _ in 0..shards {
            let (tx, rx) = mpsc::channel::<()>();
            shard_txs.push(tx);
            shard_rxs.push(Mutex::new(Some(rx)));
        }
        let rxs = Arc::new(shard_rxs);
        let take = move |shard: usize| -> Result<Gated> {
            let permits = rxs[shard].lock().unwrap().take().unwrap();
            Ok(Gated { keys: vec![mk("gdf/conv")], permits })
        };
        let pool = match placement {
            Some(p) => EnginePool::spawn_placed(
                p,
                metrics,
                move |shard: usize, _keys: &[ModelKey]| take(shard),
            )
            .unwrap(),
            None => EnginePool::spawn(shards, metrics, take).unwrap(),
        };
        std::thread::spawn(move || {
            while permit_rx.recv().is_ok() {
                for tx in &shard_txs {
                    let _ = tx.send(());
                }
            }
        });
        (pool, permit_tx)
    }

    use std::sync::Mutex;

    #[test]
    fn concurrent_submitters_record_the_true_peak_depth() {
        // 12 threads each queue one batch on a single gated shard: the
        // executor holds the first batch, so the real high-water mark is
        // 12 queued batches. The recorded peak must not under-report it
        // (the old stale pre-fetch_add read let two submits both record
        // depth 1).
        let metrics = Arc::new(Metrics::new());
        let (pool, permits) = gated_pool(1, None, metrics.clone());
        let pool = Arc::new(pool);
        let mut handles = Vec::new();
        let (rx_tx, rx_rx) = mpsc::channel();
        for i in 0..12i32 {
            let p = pool.clone();
            let sink = rx_tx.clone();
            handles.push(std::thread::spawn(move || {
                let (reply, rx) = mpsc::channel();
                p.submit(BatchJob {
                    key: mk("gdf/conv"),
                    items: vec![BatchItem::new(vec![Tensor::vector(vec![i])], reply)],
                })
                .unwrap();
                sink.send(rx).unwrap();
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        drop(rx_tx);
        // all 12 submits incremented before any batch finished → the
        // concurrent high-water mark is exactly 12
        assert_eq!(metrics.peak_queue_depths()[&0], 12);
        for _ in 0..12 {
            permits.send(()).unwrap();
        }
        let mut seen = 0;
        while let Ok(rx) = rx_rx.recv() {
            rx.recv().unwrap().unwrap();
            seen += 1;
        }
        assert_eq!(seen, 12);
        drop(pool);
    }

    #[test]
    fn sticky_placement_routes_to_the_replica_shard() {
        let metrics = Arc::new(Metrics::new());
        let placement = Placement::spread(&[mk("gdf/conv")], 4, 1)
            .assign(mk("gdf/conv"), &[2])
            .unwrap();
        let pool = EnginePool::spawn_placed(placement, metrics.clone(), |_shard, keys| {
            Ok(MockExecutor::new(keys))
        })
        .unwrap();
        for i in 0..6i32 {
            let out = pool.exec(mk("gdf/conv"), vec![Tensor::vector(vec![i * 2])]).unwrap();
            assert_eq!(out[0].data, vec![i]);
        }
        // every batch landed on the sticky shard, none spilled
        let b = metrics.batch_summaries();
        assert_eq!(b.len(), 1);
        assert_eq!(b[&(2, mk("gdf/conv"), Quality::Precise)].batches, 6);
        assert_eq!(metrics.spills(), 0);
        assert_eq!(metrics.placements()[&mk("gdf/conv")], vec![2]);
        // per-shard residency reflects the subset build
        let resident = pool.resident_keys().unwrap();
        assert_eq!(resident[2], vec![mk("gdf/conv")]);
        assert!(resident[0].is_empty() && resident[1].is_empty() && resident[3].is_empty());
        // the servable catalog is the union across shards
        assert_eq!(pool.keys().unwrap(), vec![mk("gdf/conv")]);
    }

    #[test]
    fn backed_up_replica_spills_past_the_threshold() {
        let metrics = Arc::new(Metrics::new());
        let placement = Placement::spread(&[mk("gdf/conv")], 2, 1)
            .assign(mk("gdf/conv"), &[0])
            .unwrap()
            .with_spill_threshold(1);
        let (pool, permits) = gated_pool(2, Some(placement), metrics.clone());
        let submit_one = |v: i32| {
            let (reply, rx) = mpsc::channel();
            pool.submit(BatchJob {
                key: mk("gdf/conv"),
                items: vec![BatchItem::new(vec![Tensor::vector(vec![v])], reply)],
            })
            .unwrap();
            rx
        };
        // batch A occupies the sticky shard 0 (depth 1 = threshold)
        let a = submit_one(1);
        // batch B: sticky shard is at the threshold, shard 1 is idle →
        // spill
        let b = submit_one(2);
        assert_eq!(metrics.spills(), 1);
        // batch C: both shards now hold one batch — nowhere quieter, so
        // it stays sticky instead of spilling again
        let c = submit_one(3);
        assert_eq!(metrics.spills(), 1);
        for _ in 0..3 {
            permits.send(()).unwrap();
        }
        for rx in [a, b, c] {
            rx.recv().unwrap().unwrap();
        }
        drop(pool);
        let sums = metrics.batch_summaries();
        let q = Quality::Precise;
        assert_eq!(sums[&(0, mk("gdf/conv"), q)].batches, 2, "sticky shard ran A and C");
        assert_eq!(sums[&(1, mk("gdf/conv"), q)].batches, 1, "spill shard ran B");
    }

    #[test]
    fn dead_shard_fails_over_to_a_live_one() {
        // shard 1 owns the key but its factory fails: the placed pool
        // tolerates it, routes the key's batches to a live shard, and
        // counts them as spills (off-replica traffic)
        let metrics = Arc::new(Metrics::new());
        let placement = Placement::spread(&[mk("gdf/conv")], 2, 1)
            .assign(mk("gdf/conv"), &[1])
            .unwrap();
        let pool = EnginePool::spawn_placed(placement, metrics.clone(), |shard, _keys| {
            if shard == 1 {
                Err(anyhow!("boom"))
            } else {
                Ok(MockExecutor::full_catalog())
            }
        })
        .unwrap();
        let out = pool.exec(mk("gdf/conv"), vec![Tensor::vector(vec![8])]).unwrap();
        assert_eq!(out[0].data, vec![4]);
        assert_eq!(metrics.spills(), 1);
        assert_eq!(metrics.batch_summaries()[&(0, mk("gdf/conv"), Quality::Precise)].batches, 1);
        // keys()/resident_keys() skip the dead shard instead of hanging
        assert_eq!(pool.keys().unwrap(), ModelKey::catalog());
        assert!(pool.resident_keys().unwrap()[1].is_empty());
    }

    #[test]
    fn placed_pool_with_all_shards_dead_fails_to_spawn() {
        let placement = Placement::spread(&[mk("gdf/conv")], 2, 1);
        let r = EnginePool::spawn_placed(
            placement,
            Arc::new(Metrics::new()),
            |_shard, _keys| -> Result<MockExecutor> { Err(anyhow!("boom")) },
        );
        assert!(r.is_err());
    }
}
