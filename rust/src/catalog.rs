//! The typed model catalog — the single source of truth for what a PPC
//! deployment serves.
//!
//! The paper's contract is that a PPC block is exact on a predefined
//! care set, so a deployed system is really a *catalog* of
//! (application, preprocessing-config) datapaths. This module makes
//! that catalog first-class:
//!
//! - [`App`] × [`PpcConfig`] → [`ModelKey`]: one typed key used by the
//!   router, the native registry, the CLI parser, and every display
//!   path (it prints as the canonical `"{app}/{config}"` string).
//! - [`Quality`]: the serving-time sparsity-tolerance knob; routing is
//!   [`ModelKey::route`], the only place the (app, quality) → config
//!   mapping exists.
//! - [`Tensor`]: the shape-carrying request/response payload (so
//!   non-square images survive the trip through the serving stack).
//! - [`Datapath`]: the one trait every netlist-backed application
//!   hardware implements, so executors hold a single
//!   `BTreeMap<ModelKey, Box<dyn Datapath>>` instead of one map per
//!   application.

use crate::ppc::preprocess::{Chain, Preproc};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::fmt;

/// One of the paper's three embedded applications.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum App {
    /// Gaussian denoising filter (Fig. 5 adder tree).
    Gdf,
    /// Image blending (Fig. 7 multiplier pair + adder).
    Blend,
    /// Face-recognition neural network (Fig. 10 MACs).
    Frnn,
}

impl App {
    pub const ALL: [App; 3] = [App::Gdf, App::Blend, App::Frnn];

    /// Canonical lower-case name (the wire/CLI spelling).
    pub fn name(self) -> &'static str {
        match self {
            App::Gdf => "gdf",
            App::Blend => "blend",
            App::Frnn => "frnn",
        }
    }

    /// Parse the canonical name.
    pub fn parse(s: &str) -> Result<App> {
        match s {
            "gdf" => Ok(App::Gdf),
            "blend" => Ok(App::Blend),
            "frnn" => Ok(App::Frnn),
            other => bail!("unknown app {other:?} (want gdf|blend|frnn)"),
        }
    }

    /// The preprocessing configs this application ships with.
    pub fn configs(self) -> &'static [PpcConfig] {
        match self {
            App::Gdf | App::Blend => &[PpcConfig::Conv, PpcConfig::Ds16, PpcConfig::Ds32],
            App::Frnn => &[PpcConfig::Conv, PpcConfig::Th48Ds16, PpcConfig::Ds32],
        }
    }
}

impl fmt::Display for App {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A PPC preprocessing configuration — which intentional-sparsity
/// chain the datapath was synthesized for.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum PpcConfig {
    /// Conventional precise datapath (full-range care set).
    Conv,
    /// `DS_16` down-sampling on every preprocessed input.
    Ds16,
    /// `DS_32` down-sampling on every preprocessed input.
    Ds32,
    /// `TH_48^48 + DS_16` on the image input, `DS_16` on the weights
    /// (the paper's Table-3 balanced FRNN row).
    Th48Ds16,
}

impl PpcConfig {
    pub const ALL: [PpcConfig; 4] =
        [PpcConfig::Conv, PpcConfig::Ds16, PpcConfig::Ds32, PpcConfig::Th48Ds16];

    /// Canonical lower-case name (the wire/CLI spelling).
    pub fn name(self) -> &'static str {
        match self {
            PpcConfig::Conv => "conv",
            PpcConfig::Ds16 => "ds16",
            PpcConfig::Ds32 => "ds32",
            PpcConfig::Th48Ds16 => "th48ds16",
        }
    }

    /// Parse the canonical name.
    pub fn parse(s: &str) -> Result<PpcConfig> {
        match s {
            "conv" => Ok(PpcConfig::Conv),
            "ds16" => Ok(PpcConfig::Ds16),
            "ds32" => Ok(PpcConfig::Ds32),
            "th48ds16" => Ok(PpcConfig::Th48Ds16),
            other => bail!("unknown PPC config {other:?} (want conv|ds16|ds32|th48ds16)"),
        }
    }

    /// Preprocessing chain applied to the primary (image/pixel) input.
    pub fn chain(self) -> Chain {
        match self {
            PpcConfig::Conv => Chain::id(),
            PpcConfig::Ds16 => Chain::of(Preproc::Ds(16)),
            PpcConfig::Ds32 => Chain::of(Preproc::Ds(32)),
            PpcConfig::Th48Ds16 => {
                Chain::of(Preproc::Th { x: 48, y: 48 }).then(Preproc::Ds(16))
            }
        }
    }

    /// Preprocessing chain applied to the FRNN weight input (the
    /// threshold half of `TH48+DS16` only applies to pixels).
    pub fn weight_chain(self) -> Chain {
        match self {
            PpcConfig::Conv => Chain::id(),
            PpcConfig::Ds16 | PpcConfig::Th48Ds16 => Chain::of(Preproc::Ds(16)),
            PpcConfig::Ds32 => Chain::of(Preproc::Ds(32)),
        }
    }
}

impl fmt::Display for PpcConfig {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Serving quality tier — the deployment's sparsity-tolerance knob.
/// [`ModelKey::route`] maps it to the PPC configuration each
/// application answers with.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Quality {
    /// Conventional precise datapath.
    Precise,
    /// Moderate sparsity (DS16-class; FRNN uses TH48+DS16).
    Balanced,
    /// Aggressive sparsity (DS32-class).
    Economy,
}

impl Quality {
    /// Every tier, best-first (the degrade order).
    pub const ALL: [Quality; 3] = [Quality::Precise, Quality::Balanced, Quality::Economy];

    /// Canonical lower-case name (the wire/CLI spelling).
    pub fn name(self) -> &'static str {
        match self {
            Quality::Precise => "precise",
            Quality::Balanced => "balanced",
            Quality::Economy => "economy",
        }
    }

    /// Parse the canonical [`Quality::name`] spelling (wire and CLI).
    pub fn parse(s: &str) -> Result<Quality> {
        Quality::ALL
            .into_iter()
            .find(|q| q.name() == s)
            .ok_or_else(|| {
                anyhow!(
                    "unknown quality {s:?} (valid: {})",
                    join(Quality::ALL.iter().map(|q| q.name()))
                )
            })
    }

    /// The next-lower tier — what an overloaded `degrade` admission
    /// policy falls back to. `Economy` has nowhere lower to go.
    pub fn lower(self) -> Option<Quality> {
        match self {
            Quality::Precise => Some(Quality::Balanced),
            Quality::Balanced => Some(Quality::Economy),
            Quality::Economy => None,
        }
    }

    /// The next-higher tier — what the quality autopilot recovers
    /// toward once load drops. `Precise` has nowhere higher to go.
    pub fn higher(self) -> Option<Quality> {
        match self {
            Quality::Precise => None,
            Quality::Balanced => Some(Quality::Precise),
            Quality::Economy => Some(Quality::Balanced),
        }
    }
}

impl fmt::Display for Quality {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Which application-level quality metric a [`QualityProfile`] value
/// is measured in — the paper's per-application figures of merit.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum QualityMetric {
    /// Peak signal-to-noise ratio in dB vs the precise tier (GDF and
    /// blend — the paper's image-app metric).
    Psnr,
    /// Top-1 correct-classification rate in [0, 1] on the eval split
    /// (FRNN — the paper's CCR).
    Accuracy,
}

impl QualityMetric {
    /// Canonical lower-case name (the wire/CLI spelling).
    pub fn name(self) -> &'static str {
        match self {
            QualityMetric::Psnr => "psnr",
            QualityMetric::Accuracy => "acc",
        }
    }

    /// Parse the canonical [`QualityMetric::name`] spelling.
    pub fn parse(s: &str) -> Result<QualityMetric> {
        match s {
            "psnr" => Ok(QualityMetric::Psnr),
            "acc" => Ok(QualityMetric::Accuracy),
            other => bail!("unknown quality metric {other:?} (want psnr|acc)"),
        }
    }
}

impl fmt::Display for QualityMetric {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// PSNR values are capped here so the precise tier's self-comparison
/// (infinite PSNR — the paper reports it as "Ideal") stays a finite,
/// JSON-expressible number.
pub const PSNR_CAP: f64 = 99.0;

/// A *measured* quality number for one servable model: metric kind,
/// value, and the reference tier the measurement compared against.
/// Attached to [`crate::runtime::ModelInfo`] at registration and
/// carried on the wire next to the served tier.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QualityProfile {
    pub metric: QualityMetric,
    pub value: f64,
    /// The tier the measurement is relative to (PSNR is "vs this
    /// tier's output"; accuracy is absolute but keeps the field so
    /// every profile names its baseline).
    pub reference: Quality,
}

impl QualityProfile {
    /// Compact `metric=value` rendering (the `--list-models` cell and
    /// log spelling).
    pub fn render(&self) -> String {
        match self.metric {
            QualityMetric::Psnr => format!("psnr={:.1}", self.value),
            QualityMetric::Accuracy => format!("acc={:.3}", self.value),
        }
    }

    /// Wire form: `{"metric": "...", "value": N, "reference": "..."}`.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("metric", Json::Str(self.metric.name().to_string())),
            ("value", Json::Num(self.value)),
            ("reference", Json::Str(self.reference.name().to_string())),
        ])
    }

    /// Decode the wire form (inverse of [`QualityProfile::to_json`]).
    pub fn from_json(j: &Json) -> Result<QualityProfile> {
        let metric = QualityMetric::parse(
            j.get("metric")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("quality profile wants a \"metric\" string"))?,
        )?;
        let value = j
            .get("value")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("quality profile wants a \"value\" number"))?;
        if !value.is_finite() {
            bail!("quality profile value {value} is not finite");
        }
        let reference = Quality::parse(
            j.get("reference")
                .and_then(|v| v.as_str())
                .ok_or_else(|| anyhow!("quality profile wants a \"reference\" string"))?,
        )?;
        Ok(QualityProfile { metric, value, reference })
    }
}

impl fmt::Display for QualityProfile {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.render())
    }
}

/// The typed model key: which application datapath, synthesized for
/// which preprocessing config. Displays as the canonical
/// `"{app}/{config}"` string, and that string parses back.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct ModelKey {
    pub app: App,
    pub config: PpcConfig,
}

impl ModelKey {
    /// Build a key, rejecting configs the application does not ship
    /// (e.g. `th48ds16` only exists for the FRNN).
    pub fn new(app: App, config: PpcConfig) -> Result<ModelKey> {
        if !app.configs().contains(&config) {
            bail!(
                "config {config} is not in the {app} catalog (valid: {})",
                join(app.configs().iter().map(|c| c.name()))
            );
        }
        Ok(ModelKey { app, config })
    }

    /// Parse the canonical `"{app}/{config}"` spelling.
    pub fn parse(s: &str) -> Result<ModelKey> {
        let (app, config) = s
            .split_once('/')
            .ok_or_else(|| anyhow!("model key {s:?} must be \"app/config\" (e.g. gdf/ds16)"))?;
        ModelKey::new(App::parse(app)?, PpcConfig::parse(config)?)
    }

    /// The router: map (app, quality) to the serving config — the only
    /// place this policy exists.
    pub fn route(app: App, quality: Quality) -> ModelKey {
        let config = match (app, quality) {
            (_, Quality::Precise) => PpcConfig::Conv,
            (App::Frnn, Quality::Balanced) => PpcConfig::Th48Ds16,
            (_, Quality::Balanced) => PpcConfig::Ds16,
            (_, Quality::Economy) => PpcConfig::Ds32,
        };
        ModelKey { app, config }
    }

    /// The quality tier this key serves — the inverse of
    /// [`ModelKey::route`], total on the catalog because every config
    /// belongs to exactly one tier.
    pub fn tier(self) -> Quality {
        match self.config {
            PpcConfig::Conv => Quality::Precise,
            PpcConfig::Ds16 | PpcConfig::Th48Ds16 => Quality::Balanced,
            PpcConfig::Ds32 => Quality::Economy,
        }
    }

    /// Every valid key, in catalog order (apps × their configs).
    pub fn catalog() -> Vec<ModelKey> {
        App::ALL
            .iter()
            .flat_map(|&app| app.configs().iter().map(move |&config| ModelKey { app, config }))
            .collect()
    }
}

impl fmt::Display for ModelKey {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}/{}", self.app, self.config)
    }
}

/// Render a key list for error messages ("gdf/ds16, gdf/ds32, …").
pub fn join<I: IntoIterator<Item = T>, T: fmt::Display>(keys: I) -> String {
    let mut s = String::new();
    for (i, k) in keys.into_iter().enumerate() {
        if i > 0 {
            s.push_str(", ");
        }
        s.push_str(&k.to_string());
    }
    if s.is_empty() {
        s.push_str("(none)");
    }
    s
}

/// A shape-carrying i32 tensor — the one request/response payload of
/// the serving stack. Shape is row-major; images are `[height, width]`.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<i32>,
}

impl Tensor {
    /// Build with a shape check (`∏shape == data.len()`).
    pub fn new(shape: Vec<usize>, data: Vec<i32>) -> Result<Tensor> {
        let elements: usize = shape.iter().product();
        if elements != data.len() {
            bail!(
                "tensor shape {shape:?} wants {elements} elements, data has {}",
                data.len()
            );
        }
        Ok(Tensor { shape, data })
    }

    /// 1-D tensor over the data.
    pub fn vector(data: Vec<i32>) -> Tensor {
        Tensor { shape: vec![data.len()], data }
    }

    /// 2-D row-major tensor (`rows` first — images are `[h, w]`).
    pub fn matrix(rows: usize, cols: usize, data: Vec<i32>) -> Result<Tensor> {
        Tensor::new(vec![rows, cols], data)
    }

    /// 0-D tensor holding one value.
    pub fn scalar(v: i32) -> Tensor {
        Tensor { shape: Vec::new(), data: vec![v] }
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// Wire form: `{"shape": [...], "data": [...]}`. The inverse of
    /// [`Tensor::from_json`].
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("shape", Json::Arr(self.shape.iter().map(|&d| Json::Num(d as f64)).collect())),
            ("data", Json::Arr(self.data.iter().map(|&v| Json::Num(v as f64)).collect())),
        ])
    }

    /// Decode the wire form, re-running the `∏shape == data.len()`
    /// check so a malformed peer cannot smuggle in an inconsistent
    /// tensor.
    pub fn from_json(j: &Json) -> Result<Tensor> {
        let dims = j
            .get("shape")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("tensor wants a \"shape\" array"))?;
        let vals = j
            .get("data")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("tensor wants a \"data\" array"))?;
        let mut shape = Vec::with_capacity(dims.len());
        for d in dims {
            let x = d.as_f64().ok_or_else(|| anyhow!("tensor shape entry is not a number"))?;
            if x < 0.0 || x.fract() != 0.0 || x > u32::MAX as f64 {
                bail!("tensor dimension {x} is not a valid extent");
            }
            shape.push(x as usize);
        }
        let mut data = Vec::with_capacity(vals.len());
        for v in vals {
            let x = v.as_f64().ok_or_else(|| anyhow!("tensor data entry is not a number"))?;
            if x.fract() != 0.0 || x < i32::MIN as f64 || x > i32::MAX as f64 {
                bail!("tensor element {x} is not an i32");
            }
            data.push(x as i32);
        }
        Tensor::new(shape, data)
    }
}

/// Lane width of the compiled bit-sliced netlist evaluator: one
/// `[u64; 4]` lane word per signal bit carries up to this many
/// concurrent evaluations per tape pass
/// ([`crate::logic::compiled::CompiledNetlist`]), so it is also the
/// natural request-batch capacity of one netlist pass. Batches of ≤ 64
/// automatically drop to the narrow `u64` word.
pub const LANES: usize = 256;

/// A servable application datapath built from synthesized PPC
/// netlists: one shape-carrying request in, shape-carrying responses
/// out. [`crate::apps::gdf::GdfHardware`],
/// [`crate::apps::blend::BlendHardware`] and
/// [`crate::apps::frnn::hw::FrnnHardware`] all implement it, which is
/// what lets the native registry hold every model in a single
/// `BTreeMap<ModelKey, Box<dyn Datapath>>`.
pub trait Datapath: Send + Sync {
    /// Execute one request. Implementations validate arity, shapes and
    /// value ranges and return structured errors.
    fn exec(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>>;

    /// Execute a whole batch of requests — `batch[i]` is the input
    /// tensor list of request `i`, and element `i` of the result is its
    /// output list, bit-exact with `self.exec(&batch[i])`.
    ///
    /// The default implementation loops over [`Datapath::exec`]; the
    /// netlist-backed hardwares override it to pool the work of up to
    /// [`LANES`] concurrent requests into the 256-wide bit-parallel
    /// compiled-tape evaluator — the serving-side analogue of the
    /// paper's hardware parallelism, and the hot path of the sharded
    /// engine pool.
    ///
    /// # Example
    ///
    /// ```
    /// use ppc::catalog::{Datapath, Tensor};
    ///
    /// /// A toy datapath that doubles every element.
    /// struct Doubler;
    /// impl Datapath for Doubler {
    ///     fn exec(&self, inputs: &[Tensor]) -> anyhow::Result<Vec<Tensor>> {
    ///         Ok(vec![Tensor::vector(inputs[0].data.iter().map(|v| v * 2).collect())])
    ///     }
    ///     fn num_gates(&self) -> usize {
    ///         0
    ///     }
    /// }
    ///
    /// let batch = vec![
    ///     vec![Tensor::vector(vec![1, 2])],
    ///     vec![Tensor::vector(vec![30])],
    /// ];
    /// let outs = Doubler.exec_batch(&batch).unwrap();
    /// assert_eq!(outs[0][0].data, vec![2, 4]);
    /// assert_eq!(outs[1][0].data, vec![60]);
    /// ```
    fn exec_batch(&self, batch: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        batch.iter().map(|inputs| self.exec(inputs)).collect()
    }

    /// Total mapped-gate count across the datapath's netlists.
    fn num_gates(&self) -> usize;

    /// Which unit execution backend serves batches: `"tape"`, `"lut"`,
    /// `"mixed"`, or `"-"` for datapaths without synthesized units
    /// (shown per model in `serve --list-models`).
    fn backend_name(&self) -> &'static str {
        "-"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn keys_round_trip_through_display() {
        for key in ModelKey::catalog() {
            let back = ModelKey::parse(&key.to_string()).unwrap();
            assert_eq!(back, key);
        }
        assert_eq!(ModelKey::catalog().len(), 9);
    }

    #[test]
    fn invalid_combinations_are_rejected() {
        assert!(ModelKey::parse("gdf/th48ds16").is_err());
        assert!(ModelKey::parse("frnn/ds16").is_err());
        assert!(ModelKey::parse("nope/conv").is_err());
        assert!(ModelKey::parse("gdf/np").is_err());
        assert!(ModelKey::parse("gdfds16").is_err());
        let e = ModelKey::parse("gdf/th48ds16").unwrap_err();
        assert!(format!("{e}").contains("valid: conv, ds16, ds32"), "{e}");
    }

    #[test]
    fn routing_matches_the_quality_policy() {
        let mk = |s: &str| ModelKey::parse(s).unwrap();
        assert_eq!(ModelKey::route(App::Gdf, Quality::Precise), mk("gdf/conv"));
        assert_eq!(ModelKey::route(App::Gdf, Quality::Balanced), mk("gdf/ds16"));
        assert_eq!(ModelKey::route(App::Blend, Quality::Economy), mk("blend/ds32"));
        assert_eq!(ModelKey::route(App::Frnn, Quality::Balanced), mk("frnn/th48ds16"));
        assert_eq!(ModelKey::route(App::Frnn, Quality::Economy), mk("frnn/ds32"));
        // every routed key is in the catalog
        for &app in &App::ALL {
            for q in [Quality::Precise, Quality::Balanced, Quality::Economy] {
                let key = ModelKey::route(app, q);
                assert!(ModelKey::catalog().contains(&key), "{key} not in catalog");
            }
        }
    }

    #[test]
    fn quality_tiers_degrade_downward_and_bottom_out() {
        assert_eq!(Quality::Precise.lower(), Some(Quality::Balanced));
        assert_eq!(Quality::Balanced.lower(), Some(Quality::Economy));
        assert_eq!(Quality::Economy.lower(), None);
        // the declared order is exactly the lower() walk from Precise
        let mut walk = vec![Quality::Precise];
        while let Some(q) = walk.last().unwrap().lower() {
            walk.push(q);
        }
        assert_eq!(walk, Quality::ALL.to_vec());
        assert_eq!(Quality::Balanced.to_string(), "balanced");
    }

    #[test]
    fn higher_is_the_exact_inverse_of_lower() {
        assert_eq!(Quality::Precise.higher(), None);
        for q in Quality::ALL {
            if let Some(lower) = q.lower() {
                assert_eq!(lower.higher(), Some(q), "{q} -> {lower} must walk back up");
            }
            if let Some(higher) = q.higher() {
                assert_eq!(higher.lower(), Some(q), "{q} -> {higher} must walk back down");
            }
        }
    }

    #[test]
    fn tier_inverts_route_for_the_whole_catalog() {
        // route(app, key.tier()) == key for every key the router can
        // produce, and tier() is total on the full catalog
        for key in ModelKey::catalog() {
            let q = key.tier();
            assert_eq!(ModelKey::route(key.app, q), key, "{key} must be its tier's route");
        }
        assert_eq!(ModelKey::parse("frnn/th48ds16").unwrap().tier(), Quality::Balanced);
        assert_eq!(ModelKey::parse("gdf/conv").unwrap().tier(), Quality::Precise);
    }

    #[test]
    fn quality_profiles_round_trip_the_wire_form() {
        for profile in [
            QualityProfile {
                metric: QualityMetric::Psnr,
                value: 34.25,
                reference: Quality::Precise,
            },
            QualityProfile {
                metric: QualityMetric::Accuracy,
                value: 0.921875,
                reference: Quality::Precise,
            },
            QualityProfile {
                metric: QualityMetric::Psnr,
                value: PSNR_CAP,
                reference: Quality::Balanced,
            },
        ] {
            let j = profile.to_json();
            assert_eq!(QualityProfile::from_json(&j).unwrap(), profile);
            let reparsed = Json::parse(&j.to_string()).unwrap();
            assert_eq!(QualityProfile::from_json(&reparsed).unwrap(), profile);
        }
        // malformed wire forms are structured errors, not panics
        assert!(QualityProfile::from_json(&Json::Null).is_err());
        let bad_metric = Json::obj(vec![
            ("metric", Json::Str("vibes".into())),
            ("value", Json::Num(1.0)),
            ("reference", Json::Str("precise".into())),
        ]);
        assert!(QualityProfile::from_json(&bad_metric).is_err());
        let non_finite = Json::obj(vec![
            ("metric", Json::Str("psnr".into())),
            ("value", Json::Num(f64::INFINITY)),
            ("reference", Json::Str("precise".into())),
        ]);
        assert!(QualityProfile::from_json(&non_finite).is_err());
        let acc = QualityProfile {
            metric: QualityMetric::Accuracy,
            value: 0.9,
            reference: Quality::Precise,
        };
        assert_eq!(acc.render(), "acc=0.900");
    }

    #[test]
    fn config_chains_match_the_paper_labels() {
        assert_eq!(PpcConfig::Conv.chain().label(), "none");
        assert_eq!(PpcConfig::Ds16.chain().label(), "DS16");
        assert_eq!(PpcConfig::Th48Ds16.chain().label(), "TH48^48+DS16");
        assert_eq!(PpcConfig::Th48Ds16.weight_chain().label(), "DS16");
        assert_eq!(PpcConfig::Ds32.weight_chain().label(), "DS32");
    }

    #[test]
    fn tensor_shape_checks() {
        assert!(Tensor::new(vec![2, 3], vec![0; 6]).is_ok());
        assert!(Tensor::new(vec![2, 3], vec![0; 5]).is_err());
        let t = Tensor::vector(vec![1, 2, 3]);
        assert_eq!(t.shape, vec![3]);
        assert_eq!(t.rank(), 1);
        assert_eq!(t.elements(), 3);
        let s = Tensor::scalar(7);
        assert_eq!(s.elements(), 1);
        assert_eq!(s.data, vec![7]);
    }

    #[test]
    fn join_renders_lists() {
        assert_eq!(join(ModelKey::catalog().iter().take(2)), "gdf/conv, gdf/ds16");
        assert_eq!(join(Vec::<ModelKey>::new()), "(none)");
    }

    #[test]
    fn quality_parses_every_canonical_name() {
        for q in Quality::ALL {
            assert_eq!(Quality::parse(q.name()).unwrap(), q);
        }
        let e = Quality::parse("ultra").unwrap_err();
        assert!(format!("{e}").contains("precise, balanced, economy"), "{e}");
    }

    #[test]
    fn tensor_json_round_trips() {
        for t in [
            Tensor::scalar(-7),
            Tensor::vector(vec![]),
            Tensor::vector(vec![1, -2, 3]),
            Tensor::matrix(2, 3, vec![0, 1, 2, 3, 4, 5]).unwrap(),
        ] {
            let j = t.to_json();
            assert_eq!(Tensor::from_json(&j).unwrap(), t);
            // and the textual wire form survives a parse cycle too
            let reparsed = Json::parse(&j.to_string()).unwrap();
            assert_eq!(Tensor::from_json(&reparsed).unwrap(), t);
        }
    }

    #[test]
    fn tensor_from_json_rejects_inconsistent_wire_forms() {
        let bad_shape = Json::obj(vec![
            ("shape", Json::num_arr(&[2.0, 2.0])),
            ("data", Json::num_arr(&[1.0, 2.0, 3.0])),
        ]);
        assert!(Tensor::from_json(&bad_shape).is_err());
        let not_i32 = Json::obj(vec![
            ("shape", Json::num_arr(&[1.0])),
            ("data", Json::num_arr(&[0.5])),
        ]);
        assert!(Tensor::from_json(&not_i32).is_err());
        let negative_dim = Json::obj(vec![
            ("shape", Json::num_arr(&[-1.0])),
            ("data", Json::Arr(Vec::new())),
        ]);
        assert!(Tensor::from_json(&negative_dim).is_err());
        assert!(Tensor::from_json(&Json::Null).is_err());
    }
}
