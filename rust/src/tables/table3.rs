//! Table 3 — cost-accuracy trade-off of the face-recognition network.
//!
//! Simulation columns (CCR / TE / MSE) come from training the 960-40-7
//! network under each preprocessing configuration and evaluating the
//! bit-accurate fixed-point forward; implementation columns are the
//! single-neuron MAC hardware (flat multiplier literals; composed
//! multiplier + precise accumulator physicals).

use super::{Row, Table};
use crate::apps::frnn::dataset::{self, Dataset};
use crate::apps::frnn::hw::{self, MacConfig};
use crate::apps::frnn::net::{self, TrainConfig};
use crate::logic::map::Objective;
use crate::ppc::preprocess::{Chain, Preproc};

pub struct Config {
    /// Noise instances per (id, pose, glasses) combination.
    pub samples_per_combo: usize,
    pub max_epochs: usize,
    pub target_mse: f64,
    pub seed: u64,
    /// Use flat 16-input literal counts (paper metric) — adds seconds/row.
    pub flat_literals: bool,
    /// Which paper rows to include (1-based ids from Table 3).
    pub rows: Vec<usize>,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            samples_per_combo: 4,
            max_epochs: 600,
            target_mse: 0.012,
            seed: 7,
            flat_literals: true,
            rows: (1..=9).collect(),
        }
    }
}

fn th48() -> Preproc {
    Preproc::Th { x: 48, y: 48 }
}

/// The nine Table-3 configurations: (natural, image chain, weight chain).
pub fn paper_configs() -> Vec<(usize, MacConfig)> {
    let mk = |natural: bool, img: Chain, wgt: Chain, name: &str| MacConfig {
        natural,
        pre_image: img,
        pre_weight: wgt,
        name: name.into(),
    };
    vec![
        (1, MacConfig::conventional()),
        (2, mk(true, Chain::id(), Chain::id(), "natural")),
        (3, mk(false, Chain::of(th48()), Chain::id(), "TH48^48")),
        (4, mk(false, Chain::of(Preproc::Ds(16)), Chain::of(Preproc::Ds(16)), "DS16")),
        (5, mk(false, Chain::of(Preproc::Ds(32)), Chain::of(Preproc::Ds(32)), "DS32")),
        (6, mk(true, Chain::of(Preproc::Ds(16)), Chain::of(Preproc::Ds(16)), "natural&DS16")),
        (7, mk(true, Chain::of(Preproc::Ds(32)), Chain::of(Preproc::Ds(32)), "natural&DS32")),
        (
            8,
            mk(
                true,
                Chain::of(th48()).then(Preproc::Ds(16)),
                Chain::of(Preproc::Ds(16)),
                "natural&TH48+DS16",
            ),
        ),
        (
            9,
            mk(
                true,
                Chain::of(th48()).then(Preproc::Ds(32)),
                Chain::of(Preproc::Ds(32)),
                "natural&TH48+DS32",
            ),
        ),
    ]
}

/// Train + evaluate one configuration; returns (ccr%, TE, mse).
pub fn simulate(ds: &Dataset, mac: &MacConfig, cfg: &Config) -> (f64, usize, f64) {
    // "natural" rows don't change the computation — reuse conventional
    // training semantics (the natural sparsity is free).
    let tc = TrainConfig {
        max_epochs: cfg.max_epochs,
        target_mse: cfg.target_mse,
        seed: cfg.seed,
        pre_image: mac.pre_image.clone(),
        pre_weight: mac.pre_weight.clone(),
        ..Default::default()
    };
    let r = net::train(ds, &tc);
    let q = net::quantize(&r.net);
    let ev = net::evaluate_fx(&q, &ds.test, &mac.pre_image, &mac.pre_weight);
    (ev.ccr * 100.0, r.epochs, r.mse)
}

pub fn generate(cfg: &Config) -> Table {
    let ds = dataset::generate(cfg.samples_per_combo, cfg.seed);
    let mut table = Table {
        title: "Table 3 — Face-recognition NN (FRNN): accuracy + single-neuron MAC".into(),
        rows: Vec::new(),
    };

    // cache training results by computation signature (natural rows share
    // the conventional computation; natural&X shares X's computation)
    let mut sim_cache: std::collections::BTreeMap<String, (f64, usize, f64)> =
        std::collections::BTreeMap::new();

    for (row_id, mac) in paper_configs() {
        if !cfg.rows.contains(&row_id) {
            continue;
        }
        let sim_key = format!("{}|{}", mac.pre_image.label(), mac.pre_weight.label());
        let (ccr, te, mse) = *sim_cache
            .entry(sim_key)
            .or_insert_with(|| simulate(&ds, &mac, cfg));
        let accuracy = format!("{ccr:.0}%/{te}ep/{mse:.3}");

        let (mult, adder) = hw::mac_hardware(&mac, Objective::Area);
        let mut agg = hw::aggregate(&mult, &adder);
        assert_eq!(agg.verify_errors, 0, "{} synthesis mismatch", mac.name);
        if cfg.flat_literals {
            agg.literals = hw::mac_flat_literals(&mac);
        }
        // row 1 physicals: conventional structural baseline
        if row_id == 1 {
            let conv_mult =
                crate::ppc::flow::conventional_mult("mac_mult_conv", 8, 8, Objective::Area);
            agg.area_ge = conv_mult.area_ge + adder.area_ge;
            agg.delay_ns = conv_mult.delay_ns + adder.delay_ns;
            agg.power_uw = conv_mult.power_uw + adder.power_uw;
        }
        table.rows.push(Row::from_report(
            &format!("row{row_id} / {}", mac.name),
            accuracy,
            agg.literals,
            &agg,
        ));
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_core_rows_shape() {
        // rows 1, 2, 4 with a tiny training budget — checks orderings,
        // not absolute CCR
        let cfg = Config {
            samples_per_combo: 2,
            max_epochs: 25,
            flat_literals: false,
            rows: vec![1, 2, 4],
            ..Default::default()
        };
        let t = generate(&cfg);
        assert_eq!(t.rows.len(), 3);
        let (conv, nat, ds16) = (&t.rows[0], &t.rows[1], &t.rows[2]);
        // natural: same accuracy as conventional (shared computation)
        assert_eq!(conv.accuracy, nat.accuracy);
        // natural reduces literals (paper row 2: 0.625×)
        assert!(nat.literals < conv.literals);
        // DS16 slashes literals (paper row 4: 0.019×) and area
        assert!(ds16.literals * 2 < conv.literals);
        assert!(ds16.area_ge < conv.area_ge);
        assert!(ds16.power_uw < conv.power_uw);
    }

    #[test]
    fn paper_configs_complete() {
        let cfgs = paper_configs();
        assert_eq!(cfgs.len(), 9);
        assert_eq!(cfgs[7].1.pre_image.label(), "TH48^48+DS16");
        assert!(cfgs[6].1.natural);
    }
}
