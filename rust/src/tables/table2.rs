//! Table 2 — cost-accuracy trade-off of the image-blending hardware.
//!
//! Paper rows: conventional; natural; DS2..DS32; natural+DS2..DS16.
//! Natural sparsity = the blending coefficients' half ranges (Fig. 7);
//! it costs nothing in accuracy, so its PSNR is "Ideal".

use super::{fmt_psnr, Row, Table};
use crate::apps::blend::{self, Alpha, BlendConfig};
use crate::apps::image::synthetic_photo;
use crate::logic::map::Objective;
use crate::ppc::preprocess::{Chain, Preproc};

pub struct Config {
    pub image_size: usize,
    pub ds_rates: Vec<u32>,
    pub natural_ds_rates: Vec<u32>,
    /// Include the flat 16-input two-level literal counts (the paper's
    /// metric; dominated by the two flat multipliers — a few seconds per
    /// row). When false, composed-structure literals are used.
    pub flat_literals: bool,
}

impl Default for Config {
    fn default() -> Self {
        Config {
            image_size: 128,
            ds_rates: vec![2, 4, 8, 16, 32],
            natural_ds_rates: vec![2, 4, 8, 16],
            flat_literals: true,
        }
    }
}

fn row(
    cfg: &Config,
    bc: &BlendConfig,
    accuracy: String,
) -> Row {
    let reports = blend::blend_ppc_hardware(bc, Objective::Area);
    let agg = blend::aggregate(&reports);
    assert_eq!(agg.verify_errors, 0, "{} synthesis mismatch", bc.name);
    let literals = if cfg.flat_literals {
        blend::blend_flat_literals(bc)
    } else {
        agg.literals
    };
    Row::from_report(&format!("PPC / {}", bc.name), accuracy, literals, &agg)
}

pub fn generate(cfg: &Config) -> Table {
    let p1 = synthetic_photo(cfg.image_size, cfg.image_size, 0x1E7A);
    let p2 = synthetic_photo(cfg.image_size, cfg.image_size, 0x70FF);
    let alpha = Alpha::from_ratio(0.5);
    let reference = blend::blend_images(&p1, &p2, alpha, &Chain::id(), &Chain::id());

    let mut table = Table {
        title: "Table 2 — Image blending (IB) hardware".into(),
        rows: Vec::new(),
    };

    // Row 1: conventional (structural physicals; flat literals, no DCs).
    let conv = BlendConfig::conventional();
    let conv_phys = blend::aggregate(&blend::blend_conventional_hardware(Objective::Area));
    let conv_literals = if cfg.flat_literals {
        blend::blend_flat_literals(&conv)
    } else {
        blend::aggregate(&blend::blend_ppc_hardware(&conv, Objective::Area)).literals
    };
    table.rows.push(Row::from_report(
        "Conventional / none",
        "Ideal".into(),
        conv_literals,
        &conv_phys,
    ));

    // Row 2: natural only — zero accuracy cost.
    let nat = BlendConfig::of(true, Chain::id());
    table.rows.push(row(cfg, &nat, "Ideal".into()));

    // Rows 3–7: intentional DS.
    for &x in &cfg.ds_rates {
        let chain = Chain::of(Preproc::Ds(x));
        let out = blend::blend_images(&p1, &p2, alpha, &chain, &chain);
        let psnr = reference.psnr(&out);
        let bc = BlendConfig::of(false, chain);
        table.rows.push(row(cfg, &bc, fmt_psnr(psnr)));
    }

    // Rows 8–11: natural + intentional (same accuracy as intentional-only).
    for &x in &cfg.natural_ds_rates {
        let chain = Chain::of(Preproc::Ds(x));
        let out = blend::blend_images(&p1, &p2, alpha, &chain, &chain);
        let psnr = reference.psnr(&out);
        let bc = BlendConfig::of(true, chain);
        table.rows.push(row(cfg, &bc, fmt_psnr(psnr)));
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_shape_matches_paper() {
        // small config to keep test time down; composed literals
        let cfg = Config {
            image_size: 48,
            ds_rates: vec![8],
            natural_ds_rates: vec![8],
            flat_literals: false,
        };
        let t = generate(&cfg);
        assert_eq!(t.rows.len(), 4);
        let (conv, nat, ds8, nat_ds8) = (&t.rows[0], &t.rows[1], &t.rows[2], &t.rows[3]);
        // natural costs nothing in accuracy
        assert_eq!(nat.accuracy, "Ideal");
        // natural reduces literals vs conventional (paper: 0.49×)
        assert!(nat.literals < conv.literals);
        // natural+DS8 beats DS8 alone on literals & area at equal accuracy
        assert_eq!(ds8.accuracy, nat_ds8.accuracy);
        assert!(nat_ds8.literals < ds8.literals);
        assert!(nat_ds8.area_ge < ds8.area_ge, "{} !< {}", nat_ds8.area_ge, ds8.area_ge);
        // power ordering: ds8 < conventional (paper 0.40×)
        assert!(ds8.power_uw < conv.power_uw);
    }
}
