//! Regenerators for the paper's figures (1, 2, 5, 6, 7, 8, 10, 11, 12).
//!
//! Numeric figures return series/matrices (printed as CSV by the CLI);
//! image figures write PGM files.

use crate::apps::blend::{self, Alpha};
use crate::apps::frnn::dataset::{self, Dataset};
use crate::apps::frnn::net::{self, TrainConfig};
use crate::apps::gdf;
use crate::apps::image::{add_gaussian_noise, gaussian_histogram_image, synthetic_photo, Image};
use crate::ppc::preprocess::{histogram256, Chain, Preproc, ValueSet};
use crate::util::json::Json;
use std::path::Path;

// ---------------------------------------------------------------------
// Fig. 1 — histograms of an image under DS/TH preprocessing
// ---------------------------------------------------------------------

/// Returns (label, 256-bin normalized histogram) series.
pub fn fig1() -> Vec<(String, Vec<f64>)> {
    let img = gaussian_histogram_image(256, 256, 128.0, 40.0, 0xF16);
    let mk = |label: &str, chain: Chain| {
        let h = histogram256(img.pixels.iter().map(|&p| chain.apply(p as u32)));
        (label.to_string(), h)
    };
    vec![
        mk("(a) original", Chain::id()),
        mk("(b) DS2", Chain::of(Preproc::Ds(2))),
        mk("(c) DS4", Chain::of(Preproc::Ds(4))),
        mk("(d) DS8", Chain::of(Preproc::Ds(8))),
        mk("(e) TH48^0", Chain::of(Preproc::Th { x: 48, y: 0 })),
        mk("(f) TH48^48", Chain::of(Preproc::Th { x: 48, y: 48 })),
    ]
}

// ---------------------------------------------------------------------
// Fig. 2 — Karnaugh maps of the 2×3 multiplier's third output bit
// ---------------------------------------------------------------------

/// One K-map cell: Some(bit) or None for don't-care.
pub type Kmap = Vec<Vec<Option<bool>>>; // 4 rows (a1a0) × 8 cols (b2b1b0)

fn kmap_of(bit: usize, care: impl Fn(u64, u64) -> bool) -> Kmap {
    // gray-code order, paper-style
    let gray2 = [0b00u64, 0b01, 0b11, 0b10];
    let gray3 = [0b000u64, 0b001, 0b011, 0b010, 0b110, 0b111, 0b101, 0b100];
    gray2
        .iter()
        .map(|&a| {
            gray3
                .iter()
                .map(|&b| {
                    if care(a, b) {
                        Some(((a * b) >> bit) & 1 == 1)
                    } else {
                        None
                    }
                })
                .collect()
        })
        .collect()
}

/// The four Fig. 2 K-maps for output bit `bit` (paper shows bit index 2,
/// "the third output bit").
pub fn fig2(bit: usize) -> Vec<(String, Kmap)> {
    vec![
        ("(a) precise".into(), kmap_of(bit, |_, _| true)),
        (
            "(b) PPM, DS2 on both inputs".into(),
            kmap_of(bit, |a, b| a % 2 == 0 && b % 2 == 0),
        ),
        (
            "(c) PPM, TH5^0 on 3-bit input".into(),
            kmap_of(bit, |_, b| b >= 5 || b == 0),
        ),
        (
            "(d) PPM, TH5^6 on 3-bit input".into(),
            kmap_of(bit, |_, b| b >= 5),
        ),
    ]
}

/// Count DCs in a K-map (the eq. 1/6 cross-check).
pub fn kmap_dc_count(k: &Kmap) -> usize {
    k.iter().flatten().filter(|c| c.is_none()).count()
}

/// Render a K-map as ASCII (1/0/- per cell).
pub fn render_kmap(k: &Kmap) -> String {
    let mut s = String::from("        b2b1b0: 000 001 011 010 110 111 101 100\n");
    let rows = ["00", "01", "11", "10"];
    for (i, row) in k.iter().enumerate() {
        s.push_str(&format!("  a1a0={}:      ", rows[i]));
        for cell in row {
            s.push_str(match cell {
                Some(true) => "  1 ",
                Some(false) => "  0 ",
                None => "  - ",
            });
        }
        s.push('\n');
    }
    s
}

// ---------------------------------------------------------------------
// Figs. 5 / 7 / 10 — signal word-lengths and sparsity summaries
// ---------------------------------------------------------------------

/// Per-signal summary row: name, WL, #values, sparsity.
pub fn fig5_signals() -> Vec<(String, u32, u32, f64)> {
    let full = ValueSet::full(8);
    let sig = gdf::gdf_signal_sets(&full);
    let mut out = Vec::new();
    for (i, (l, r, wl_l, wl_r)) in sig.adders.iter().enumerate() {
        out.push((format!("adder{} left", i + 1), *wl_l, l.len(), l.sparsity()));
        out.push((format!("adder{} right", i + 1), *wl_r, r.len(), r.sparsity()));
    }
    out.push(("output".into(), 8, sig.output.len(), sig.output.sparsity()));
    out
}

pub fn fig7_signals() -> Vec<(String, u32, u32, f64)> {
    let cfg = blend::BlendConfig::of(true, Chain::id());
    let sig = blend::blend_signal_sets(&cfg);
    vec![
        ("mult1 image".into(), 8, sig.mult1.0.len(), sig.mult1.0.sparsity()),
        ("mult1 coeff".into(), 8, sig.mult1.1.len(), sig.mult1.1.sparsity()),
        ("mult2 image".into(), 8, sig.mult2.0.len(), sig.mult2.0.sparsity()),
        ("mult2 coeff".into(), 8, sig.mult2.1.len(), sig.mult2.1.sparsity()),
        ("adder left".into(), 8, sig.adder.0.len(), sig.adder.0.sparsity()),
        ("adder right".into(), 8, sig.adder.1.len(), sig.adder.1.sparsity()),
    ]
}

pub fn fig10_signals(ds: &Dataset) -> Vec<(String, u32, u32, f64)> {
    // union of pixel histograms across the dataset (the paper's image
    // input histogram for the MAC multiplier)
    let mut pixels = ValueSet::empty(256);
    for f in ds.train.iter().chain(&ds.test) {
        for &p in &f.pixels {
            pixels.insert(p as u32);
        }
    }
    let weights = ValueSet::full(8); // weight bytes span the range
    vec![
        ("mult image in".into(), 8, pixels.len(), pixels.sparsity()),
        ("mult weight in".into(), 8, weights.len(), weights.sparsity()),
    ]
}

// ---------------------------------------------------------------------
// Figs. 6 / 8 / 11 — sample input/output images
// ---------------------------------------------------------------------

/// Fig. 6: GDF input/output for conventional, DS16, DS32. Writes PGMs
/// into `out_dir`; returns (config, psnr-vs-conventional).
pub fn fig6(out_dir: &Path) -> anyhow::Result<Vec<(String, f64)>> {
    std::fs::create_dir_all(out_dir)?;
    let clean = synthetic_photo(256, 256, 0xF6);
    let noisy = add_gaussian_noise(&clean, 10.0, 0xF7);
    noisy.write_pgm(&out_dir.join("fig6_input.pgm"))?;
    let reference = gdf::gdf_filter(&noisy, &Chain::id());
    reference.write_pgm(&out_dir.join("fig6_out_conventional.pgm"))?;
    let mut rows = vec![("conventional".to_string(), f64::INFINITY)];
    for x in [16u32, 32] {
        let chain = Chain::of(Preproc::Ds(x));
        let pre: Image = noisy.map(|p| chain.apply(p as u32) as u8);
        pre.write_pgm(&out_dir.join(format!("fig6_input_ds{x}.pgm")))?;
        let out = gdf::gdf_filter(&noisy, &chain);
        out.write_pgm(&out_dir.join(format!("fig6_out_ds{x}.pgm")))?;
        rows.push((format!("DS{x}"), reference.psnr(&out)));
    }
    Ok(rows)
}

/// Fig. 8: blending inputs/outputs for conventional, DS16, DS32.
pub fn fig8(out_dir: &Path) -> anyhow::Result<Vec<(String, f64)>> {
    std::fs::create_dir_all(out_dir)?;
    let p1 = synthetic_photo(256, 256, 0xF8);
    let p2 = synthetic_photo(256, 256, 0xF9);
    let alpha = Alpha::from_ratio(0.5);
    p1.write_pgm(&out_dir.join("fig8_input1.pgm"))?;
    p2.write_pgm(&out_dir.join("fig8_input2.pgm"))?;
    let reference = blend::blend_images(&p1, &p2, alpha, &Chain::id(), &Chain::id());
    reference.write_pgm(&out_dir.join("fig8_out_conventional.pgm"))?;
    let mut rows = vec![("conventional".to_string(), f64::INFINITY)];
    for x in [16u32, 32] {
        let chain = Chain::of(Preproc::Ds(x));
        let out = blend::blend_images(&p1, &p2, alpha, &chain, &chain);
        out.write_pgm(&out_dir.join(format!("fig8_out_ds{x}.pgm")))?;
        rows.push((format!("DS{x}"), reference.psnr(&out)));
    }
    Ok(rows)
}

/// Fig. 11: one face under the six preprocessing views.
pub fn fig11(out_dir: &Path) -> anyhow::Result<Vec<String>> {
    std::fs::create_dir_all(out_dir)?;
    let face = dataset::render_face(5, 1, false, 3);
    let th48 = Chain::of(Preproc::Th { x: 48, y: 48 });
    let views: Vec<(&str, Chain)> = vec![
        ("a_precise", Chain::id()),
        ("b_th48", th48.clone()),
        ("c_ds16", Chain::of(Preproc::Ds(16))),
        ("d_ds32", Chain::of(Preproc::Ds(32))),
        ("e_th48_ds16", th48.clone().then(Preproc::Ds(16))),
        ("f_th48_ds32", th48.then(Preproc::Ds(32))),
    ];
    let mut written = Vec::new();
    for (name, chain) in views {
        let img = Image {
            width: dataset::IMG_W,
            height: dataset::IMG_H,
            pixels: face.pixels.iter().map(|&p| chain.apply(p as u32) as u8).collect(),
        };
        let path = out_dir.join(format!("fig11_{name}.pgm"));
        img.write_pgm(&path)?;
        written.push(path.display().to_string());
    }
    Ok(written)
}

// ---------------------------------------------------------------------
// Fig. 12 — FRNN accuracy sweeps
// ---------------------------------------------------------------------

pub struct SweepConfig {
    pub samples_per_combo: usize,
    pub max_epochs: usize,
    pub seed: u64,
}

impl Default for SweepConfig {
    fn default() -> Self {
        SweepConfig { samples_per_combo: 3, max_epochs: 120, seed: 7 }
    }
}

/// Fig. 12(a): CCR and MSE vs TH_x^0 threshold on the image input.
pub fn fig12a(thresholds: &[u32], cfg: &SweepConfig) -> Vec<(u32, f64, f64)> {
    let ds = dataset::generate(cfg.samples_per_combo, cfg.seed);
    thresholds
        .iter()
        .map(|&x| {
            let chain = if x == 0 {
                Chain::id()
            } else {
                Chain::of(Preproc::Th { x, y: 0 })
            };
            let tc = TrainConfig {
                max_epochs: cfg.max_epochs,
                seed: cfg.seed,
                pre_image: chain.clone(),
                ..Default::default()
            };
            let r = net::train(&ds, &tc);
            let q = net::quantize(&r.net);
            let ev = net::evaluate_fx(&q, &ds.test, &chain, &Chain::id());
            (x, ev.ccr * 100.0, r.mse)
        })
        .collect()
}

/// Fig. 12(b,c): CCR and MSE heat maps over (DS on image) × (DS on
/// weights). Returns (img_rates, wgt_rates, ccr_matrix, mse_matrix).
#[allow(clippy::type_complexity)]
pub fn fig12bc(
    rates: &[u32],
    cfg: &SweepConfig,
) -> (Vec<u32>, Vec<u32>, Vec<Vec<f64>>, Vec<Vec<f64>>) {
    let ds = dataset::generate(cfg.samples_per_combo, cfg.seed);
    let mut ccr = Vec::new();
    let mut mse = Vec::new();
    for &xi in rates {
        let mut ccr_row = Vec::new();
        let mut mse_row = Vec::new();
        for &xw in rates {
            let ci = if xi <= 1 { Chain::id() } else { Chain::of(Preproc::Ds(xi)) };
            let cw = if xw <= 1 { Chain::id() } else { Chain::of(Preproc::Ds(xw)) };
            let tc = TrainConfig {
                max_epochs: cfg.max_epochs,
                seed: cfg.seed,
                pre_image: ci.clone(),
                pre_weight: cw.clone(),
                ..Default::default()
            };
            let r = net::train(&ds, &tc);
            let q = net::quantize(&r.net);
            let ev = net::evaluate_fx(&q, &ds.test, &ci, &cw);
            ccr_row.push(ev.ccr * 100.0);
            mse_row.push(r.mse);
        }
        ccr.push(ccr_row);
        mse.push(mse_row);
    }
    (rates.to_vec(), rates.to_vec(), ccr, mse)
}

/// Serialize a sweep to JSON for plotting.
pub fn sweep_to_json(rates: &[u32], ccr: &[Vec<f64>], mse: &[Vec<f64>]) -> Json {
    Json::obj(vec![
        ("rates", Json::Arr(rates.iter().map(|&r| Json::Num(r as f64)).collect())),
        ("ccr", Json::Arr(ccr.iter().map(|row| Json::num_arr(row.iter())).collect())),
        ("mse", Json::Arr(mse.iter().map(|row| Json::num_arr(row.iter())).collect())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig1_histograms_shape() {
        let series = fig1();
        assert_eq!(series.len(), 6);
        for (label, h) in &series {
            assert!((h.iter().sum::<f64>() - 1.0).abs() < 1e-9, "{label} not normalized");
        }
        // DS8 leaves only multiples of 8
        let ds8 = &series[3].1;
        for (v, &p) in ds8.iter().enumerate() {
            if v % 8 != 0 {
                assert_eq!(p, 0.0, "DS8 histogram has mass at {v}");
            }
        }
        // TH48^0 has no mass in (0, 48)
        let th = &series[4].1;
        assert!(th[0] > 0.0);
        assert!(th[1..48].iter().all(|&p| p == 0.0));
    }

    #[test]
    fn fig2_dc_counts_match_equations() {
        let maps = fig2(2);
        // precise: no DCs
        assert_eq!(kmap_dc_count(&maps[0].1), 0);
        // DS2 both inputs: eq. (1) → 75% of 32 cells = 24 DCs
        assert_eq!(kmap_dc_count(&maps[1].1), 24);
        // TH5^0 on the 3-bit input keeps b ∈ {0, 5, 6, 7} → 4·4 care = 16 DC
        assert_eq!(kmap_dc_count(&maps[2].1), 16);
        // TH5^6 keeps b ∈ {5, 6, 7} → 12 care cells, 20 DCs
        assert_eq!(kmap_dc_count(&maps[3].1), 20);
        // renders
        assert!(render_kmap(&maps[1].1).contains('-'));
    }

    #[test]
    fn fig5_reproduces_shift_sparsity() {
        let rows = fig5_signals();
        // adder3 (index 2) left input: DS2-like → sparsity 0.5
        let (_, _, n, s) = &rows[4];
        assert_eq!(*n, 256);
        assert!((s - 0.5).abs() < 0.01);
    }

    #[test]
    fn fig12a_th48_tolerated() {
        // tiny sweep: threshold 48 must not collapse accuracy vs 0
        let cfg = SweepConfig { samples_per_combo: 2, max_epochs: 30, seed: 3 };
        let rows = fig12a(&[0, 48], &cfg);
        assert_eq!(rows.len(), 2);
        let (base, th48) = (rows[0].1, rows[1].1);
        assert!(th48 > base - 25.0, "TH48 collapsed: {th48} vs {base}");
    }
}
