//! Regenerators for every table and figure in the paper's evaluation.
//!
//! Each submodule produces the same rows/series the paper reports;
//! `cargo bench --bench table*` and the `ppc` CLI subcommands call the
//! same entry points. Absolute numbers come from our substitute
//! synthesis substrate (see DESIGN.md), so EXPERIMENTS.md compares
//! *shapes* — orderings, rough factors, crossovers — against the paper.

pub mod figures;
pub mod supp;
pub mod table1;
pub mod table2;
pub mod table3;

use crate::ppc::flow::BlockReport;

/// One row of a cost-accuracy table, normalized against the
/// conventional row like the paper's Tables 1–3.
#[derive(Clone, Debug)]
pub struct Row {
    pub label: String,
    /// Accuracy column: "Ideal", PSNR in dB, or CCR/TE/MSE triple.
    pub accuracy: String,
    pub literals: u64,
    pub area_ge: f64,
    pub delay_ns: f64,
    pub power_uw: f64,
}

impl Row {
    pub fn from_report(label: &str, accuracy: String, literals: u64, r: &BlockReport) -> Row {
        Row {
            label: label.to_string(),
            accuracy,
            literals,
            area_ge: r.area_ge,
            delay_ns: r.delay_ns,
            power_uw: r.power_uw,
        }
    }
}

/// A rendered table: rows plus the normalization base.
#[derive(Clone, Debug, Default)]
pub struct Table {
    pub title: String,
    pub rows: Vec<Row>,
}

impl Table {
    /// Format with both normalized and absolute columns, paper-style.
    pub fn render(&self) -> String {
        let base = &self.rows[0];
        let mut s = format!("== {} ==\n", self.title);
        s.push_str(&format!(
            "{:<34} {:>14} {:>18} {:>14} {:>14} {:>14}\n",
            "Realization / Sparsity", "Accuracy", "#literals (norm)", "Area (norm)", "Delay (norm)", "Power (norm)"
        ));
        for r in &self.rows {
            let nl = if base.literals > 0 {
                r.literals as f64 / base.literals as f64
            } else {
                f64::NAN
            };
            s.push_str(&format!(
                "{:<34} {:>14} {:>8} ({:>5.3}) {:>7.0} ({:>4.2}) {:>7.2} ({:>4.2}) {:>7.1} ({:>4.2})\n",
                r.label,
                r.accuracy,
                r.literals,
                nl,
                r.area_ge,
                r.area_ge / base.area_ge,
                r.delay_ns,
                r.delay_ns / base.delay_ns,
                r.power_uw,
                r.power_uw / base.power_uw,
            ));
        }
        s
    }

    /// Machine-readable JSON (EXPERIMENTS.md tooling).
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::Json;
        Json::obj(vec![
            ("title", Json::Str(self.title.clone())),
            (
                "rows",
                Json::Arr(
                    self.rows
                        .iter()
                        .map(|r| {
                            Json::obj(vec![
                                ("label", Json::Str(r.label.clone())),
                                ("accuracy", Json::Str(r.accuracy.clone())),
                                ("literals", Json::Num(r.literals as f64)),
                                ("area_ge", Json::Num(r.area_ge)),
                                ("delay_ns", Json::Num(r.delay_ns)),
                                ("power_uw", Json::Num(r.power_uw)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Format a PSNR value the way the paper does ("Ideal" for ∞).
pub fn fmt_psnr(psnr: f64) -> String {
    if psnr.is_infinite() {
        "Ideal".to_string()
    } else {
        format!("{psnr:.0} dB")
    }
}
