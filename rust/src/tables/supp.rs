//! Supplementary Table 1 — 8×8 multiplier, conventional vs proposed
//! synthesis process, with output word-lengths 16 / 12 / 8 (i.e. 0, 4 or
//! 8 least-significant output bits are don't-care).
//!
//! Conventional path = structural array (library-style): output DCs
//! change almost nothing because the predesigned structure is kept.
//! Proposed path = the supplementary-Fig. 2 composition (four 4×4 TT
//! quadrants + adder tree); output DCs propagate into the quadrant and
//! adder-segment truth tables and shrink them.
//!
//! The signed/proposed cells use the same composed machinery via
//! sign-extended quadrant TTs; signed/conventional uses the structural
//! Baugh-Wooley-equivalent multiplier.

use crate::logic::map::{map_aig, Objective};
use crate::logic::library::cells90;
use crate::logic::synth::BlockSpec;
use crate::ppc::blocks;
use crate::ppc::flow::{self, BlockReport};
use crate::ppc::preprocess::ValueSet;

/// One supplementary-table row.
#[derive(Clone, Debug)]
pub struct SuppRow {
    pub operand_type: &'static str, // "unsigned" | "signed"
    pub out_wl: u32,
    pub conv_area: f64,
    pub conv_delay: f64,
    pub prop_area: f64,
    pub prop_delay: f64,
}

/// Drop the sum outputs of adder-segment specs whose global bit position
/// is below `drop_n` (keeping couts — carries still propagate upward).
fn drop_segment_outputs(mut specs: Vec<BlockSpec>, drop_n: u32, shift: u32) -> Vec<BlockSpec> {
    for (s, spec) in specs.iter_mut().enumerate() {
        let base = shift + (s as u32) * blocks::SEG_BITS;
        // outputs 0..SEG_BITS are sum bits at global positions base+k;
        // the last output is cout.
        let keep: Vec<usize> = (0..spec.on.len())
            .filter(|&k| {
                if k as u32 == blocks::SEG_BITS {
                    true // cout
                } else {
                    base + k as u32 >= drop_n
                }
            })
            .collect();
        spec.on = keep.iter().map(|&k| spec.on[k].clone()).collect();
    }
    specs
}

/// Drop outputs of a flat block spec below `drop_n` (for the LL
/// quadrant, whose low nibble feeds the final output directly).
fn drop_block_outputs(mut spec: BlockSpec, drop_n: u32) -> BlockSpec {
    let keep: Vec<usize> = (0..spec.on.len()).filter(|&k| k as u32 >= drop_n).collect();
    spec.on = keep.iter().map(|&k| spec.on[k].clone()).collect();
    spec
}

/// Proposed-process composed 8×8 multiplier with `drop_n` DC low output
/// bits. Works for unsigned operands (the paper's signed variant uses
/// sign-extended quadrants; same machinery — see [`generate`]).
pub fn proposed_mult8(drop_n: u32, objective: Objective) -> BlockReport {
    let full = ValueSet::full(8);
    let q = blocks::mult_quadrant_specs(&full, &full);
    let mut out = BlockReport { name: format!("prop_mult8_drop{drop_n}"), ..Default::default() };
    let mut quad_delay: f64 = 0.0;
    let [ll, lh, hl, hh]: [BlockSpec; 4] = q.quads.try_into().unwrap();
    // LL's low output bits below drop_n (≤ 4 of them) are final outputs
    // only — drop them from the quadrant TT.
    let ll = drop_block_outputs(ll, drop_n.min(4));
    for spec in [ll, lh, hl, hh] {
        let sb = flow::synth_block(spec, objective);
        out.literals += sb.report.literals;
        out.area_ge += sb.report.area_ge;
        out.power_uw += sb.report.power_uw;
        quad_delay = quad_delay.max(sb.report.delay_ns);
    }
    // adder tree with dropped outputs
    let lh_s = &q.quad_out_sets[1];
    let hl_s = &q.quad_out_sets[2];
    let ll_s = &q.quad_out_sets[0];
    let hh_s = &q.quad_out_sets[3];
    let mid = lh_s.sum(hl_s);
    let mid_shift = mid.shl(4);
    let lo = mid_shift.sum(ll_s);
    let hh_shift = hh_s.shl(8);

    let mut tree_delay = 0.0;
    // a1 = LH + HL (bits 4.. of the product): its global shift is 4
    let a1 = blocks::adder_segment_specs(8, 8, lh_s, hl_s);
    let a1 = drop_segment_outputs(a1, drop_n, 4);
    // a2 = (mid<<4) + LL (bits 0..): shift 0
    let a2 = blocks::adder_segment_specs(13, 8, &mid_shift, ll_s);
    let a2 = drop_segment_outputs(a2, drop_n, 0);
    // a3 = (HH<<8) + lo (bits 0..): shift 0
    let a3 = blocks::adder_segment_specs(16, 14, &hh_shift, &lo);
    let a3 = drop_segment_outputs(a3, drop_n, 0);
    for stage in [a1, a2, a3] {
        let mut stage_delay = 0.0;
        for spec in stage {
            if spec.on.is_empty() {
                continue; // segment fully dead
            }
            let sb = flow::synth_block(spec, objective);
            out.literals += sb.report.literals;
            out.area_ge += sb.report.area_ge;
            out.power_uw += sb.report.power_uw;
            stage_delay += sb.report.delay_ns;
        }
        tree_delay += stage_delay;
    }
    out.delay_ns = quad_delay + tree_delay;
    out
}

/// Conventional structural multiplier with output truncation: gates stay
/// (library structure), only the measured critical path shrinks to the
/// exposed outputs.
pub fn conventional_mult8(signed: bool, out_wl: u32, objective: Objective) -> BlockReport {
    let g = if signed {
        blocks::signed_multiplier_aig(8, 8)
    } else {
        blocks::array_multiplier_aig(8, 8)
    };
    let mut nl = map_aig(&g, &cells90(), objective);
    // expose only the top out_wl outputs for delay purposes
    let drop_n = (16 - out_wl) as usize;
    nl.outputs = nl.outputs[drop_n..].to_vec();
    let power = nl.power_uw(flow::POWER_VECTORS, |r| r.next_u64() & 0xffff);
    BlockReport {
        name: format!("conv_mult8_{}_wl{out_wl}", if signed { "s" } else { "u" }),
        literals: 0,
        area_ge: nl.area_ge(),
        delay_ns: nl.delay_ns(),
        power_uw: power,
        dc_fraction: 0.0,
        verify_errors: 0,
    }
}

/// Generate the supplementary table (unsigned fully; signed rows carry
/// the conventional columns and reuse the unsigned proposed columns —
/// the TT-based process is insensitive to signedness, which is exactly
/// the paper's last observation about this table).
pub fn generate(out_wls: &[u32]) -> Vec<SuppRow> {
    let mut rows = Vec::new();
    for &signed in &[false, true] {
        for &wl in out_wls {
            let conv = conventional_mult8(signed, wl, Objective::Area);
            let prop = proposed_mult8(16 - wl, Objective::Area);
            rows.push(SuppRow {
                operand_type: if signed { "signed" } else { "unsigned" },
                out_wl: wl,
                conv_area: conv.area_ge,
                conv_delay: conv.delay_ns,
                prop_area: prop.area_ge,
                prop_delay: prop.delay_ns,
            });
        }
    }
    rows
}

pub fn render(rows: &[SuppRow]) -> String {
    let mut s = String::from(
        "== Supplementary Table 1 — 8×8 multiplier, conventional vs proposed synthesis ==\n",
    );
    s.push_str(&format!(
        "{:<10} {:>6} {:>14} {:>14} {:>14} {:>14}\n",
        "operands", "outWL", "conv area(GE)", "conv delay", "prop area(GE)", "prop delay"
    ));
    for r in rows {
        s.push_str(&format!(
            "{:<10} {:>6} {:>14.0} {:>11.2}ns {:>14.0} {:>11.2}ns\n",
            r.operand_type, r.out_wl, r.conv_area, r.conv_delay, r.prop_area, r.prop_delay
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conventional_insensitive_to_output_truncation() {
        let full = conventional_mult8(false, 16, Objective::Area);
        let trunc = conventional_mult8(false, 8, Objective::Area);
        // library structure retained → area identical
        assert!((full.area_ge - trunc.area_ge).abs() < 1e-9);
        // delay cannot grow when dropping outputs
        assert!(trunc.delay_ns <= full.delay_ns + 1e-9);
    }

    #[test]
    fn proposed_shrinks_with_output_dcs() {
        let full = proposed_mult8(0, Objective::Area);
        let drop8 = proposed_mult8(8, Objective::Area);
        assert!(
            drop8.area_ge < full.area_ge,
            "{} !< {}",
            drop8.area_ge,
            full.area_ge
        );
        assert!(drop8.literals < full.literals);
    }

    #[test]
    fn signed_conventional_not_smaller_than_unsigned() {
        let u = conventional_mult8(false, 16, Objective::Area);
        let s = conventional_mult8(true, 16, Objective::Area);
        // paper: signed slightly more area in the conventional process
        assert!(s.area_ge >= u.area_ge * 0.95, "{} vs {}", s.area_ge, u.area_ge);
    }
}
