//! Table 1 — cost-accuracy trade-off of the Gaussian denoising filter.
//!
//! Paper rows: Conventional, DS2, DS4, DS8, DS16 (we add DS32, the
//! Fig. 6(c) configuration). Accuracy = output PSNR of the PPC filter
//! against the conventional filter on a photo-like test image;
//! implementation costs = the 8-adder bank (segmented two-level
//! literals; mapped area/delay/power).

use super::{fmt_psnr, Row, Table};
use crate::apps::gdf;
use crate::apps::image::synthetic_photo;
use crate::logic::map::Objective;
use crate::ppc::preprocess::{Chain, Preproc, ValueSet};

pub struct Config {
    /// Image edge for PSNR measurement.
    pub image_size: usize,
    /// DS rates to include (paper: 2..16).
    pub ds_rates: Vec<u32>,
}

impl Default for Config {
    fn default() -> Self {
        Config { image_size: 128, ds_rates: vec![2, 4, 8, 16, 32] }
    }
}

pub fn generate(cfg: &Config) -> Table {
    let img = synthetic_photo(cfg.image_size, cfg.image_size, 0xD5);
    let reference = gdf::gdf_filter(&img, &Chain::id());

    let mut table = Table {
        title: "Table 1 — Gaussian denoising filter (GDF) hardware".into(),
        rows: Vec::new(),
    };

    // Row 1: conventional. Literals from the no-DC TT path (the paper's
    // two-level column always comes from the TT flow); physicals from
    // the structural library-style synthesis.
    let full = ValueSet::full(8);
    let conv_literals: u64 = gdf::gdf_ppc_hardware(&full, Objective::Area)
        .iter()
        .map(|r| r.literals)
        .sum();
    let conv_phys = gdf::aggregate(&gdf::gdf_conventional_hardware(Objective::Area));
    table.rows.push(Row::from_report(
        "Conventional / none",
        "Ideal".into(),
        conv_literals,
        &conv_phys,
    ));

    for &x in &cfg.ds_rates {
        let chain = Chain::of(Preproc::Ds(x));
        let out = gdf::gdf_filter(&img, &chain);
        let psnr = reference.psnr(&out);
        let input_set = full.map_chain(&chain);
        let reports = gdf::gdf_ppc_hardware(&input_set, Objective::Area);
        let agg = gdf::aggregate(&reports);
        assert_eq!(agg.verify_errors, 0, "DS{x} synthesis mismatch");
        table.rows.push(Row::from_report(
            &format!("PPC / Intentional(DS{x})"),
            fmt_psnr(psnr),
            agg.literals,
            &agg,
        ));
    }
    table
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_shape_matches_paper() {
        let cfg = Config { image_size: 48, ds_rates: vec![2, 16] };
        let t = generate(&cfg);
        assert_eq!(t.rows.len(), 3);
        // conventional is ideal
        assert_eq!(t.rows[0].accuracy, "Ideal");
        // literals fall monotonically with DS rate
        assert!(t.rows[1].literals < t.rows[0].literals);
        assert!(t.rows[2].literals < t.rows[1].literals);
        // PSNR decreases with DS rate; DS16 stays above 26 dB on our image
        let ds16_psnr: f64 = t.rows[2].accuracy.trim_end_matches(" dB").parse().unwrap();
        assert!(ds16_psnr > 26.0, "DS16 PSNR {ds16_psnr}");
        // DS16 power below conventional (paper: 0.61×)
        assert!(t.rows[2].power_uw < t.rows[0].power_uw);
        // render works
        assert!(t.render().contains("DS16"));
    }
}
