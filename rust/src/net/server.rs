//! The threaded TCP front door: one connection handler per client in
//! front of a shared [`Coordinator`].
//!
//! Per connection, a reader thread decodes frames and submits jobs
//! (blocking inside the connection, so one slow client never stalls
//! another), and a writer thread resolves tickets **in submit order**
//! and streams the replies back — which is what gives clients
//! pipelining: any number of requests may be in flight per connection,
//! and replies carry the client's own ids.
//!
//! Shutdown is a control frame rather than a signal (`std` has no
//! portable signal handling): any client may send
//! `{"type":"shutdown"}`; the server acks it *after* every reply
//! already queued on that connection, stops accepting, drains every
//! other connection's in-flight work, and joins. The caller then
//! flushes [`Metrics::report`] and drops the coordinator, which drains
//! the engine pool — nothing dies mid-batch.

use crate::catalog::{join, ModelKey};
use crate::coordinator::{Coordinator, Rejection, SubmitError, Ticket};
use crate::net::cluster::{Cluster, ForwardOutcome, RoutePlan};
use crate::net::proto::{
    self, ClientFrame, FrameError, FrameReader, Request, ServerFrame, MAX_FRAME,
};
use crate::util::json::Json;
use anyhow::Result;
use std::io;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Front-door tuning knobs.
#[derive(Clone, Debug)]
pub struct NetServerConfig {
    /// Largest accepted frame body in bytes; larger payloads are
    /// drained and answered with a typed `oversized` error (the
    /// connection survives).
    pub max_frame: usize,
    /// How often blocked accepts/reads wake to check the stop flag.
    pub poll: Duration,
}

impl Default for NetServerConfig {
    fn default() -> Self {
        NetServerConfig { max_frame: MAX_FRAME, poll: Duration::from_millis(50) }
    }
}

/// A running TCP server. Dropping it (or calling [`NetServer::join`])
/// stops accepting and joins every connection handler.
pub struct NetServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
}

impl NetServer {
    /// Start serving `coord` on `listener`. The listener may be bound
    /// to port 0; [`NetServer::local_addr`] reports what the OS chose.
    pub fn spawn(
        listener: TcpListener,
        coord: Arc<Coordinator>,
        cfg: NetServerConfig,
    ) -> Result<NetServer> {
        NetServer::spawn_cluster(listener, coord, cfg, None)
    }

    /// Like [`NetServer::spawn`], but as a member of a multi-node
    /// cluster: requests for keys the ring assigns to a peer are
    /// forwarded to it (and incoming `Forward` frames from peers are
    /// served locally). Note every server — clustered or not — answers
    /// `Forward` frames: a member may receive forwarded traffic before
    /// it has been told about any peers.
    pub fn spawn_cluster(
        listener: TcpListener,
        coord: Arc<Coordinator>,
        cfg: NetServerConfig,
        cluster: Option<Arc<Cluster>>,
    ) -> Result<NetServer> {
        listener.set_nonblocking(true)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let registered = Arc::new(coord.registered_keys().unwrap_or_default());
        // the name this node signs Forwarded replies with: its
        // advertised cluster address, or the bound one when peerless
        let node_name =
            cluster.as_ref().map(|c| c.node().to_string()).unwrap_or_else(|| addr.to_string());
        let accept = {
            let stop = stop.clone();
            thread::Builder::new().name("ppc-net-accept".to_string()).spawn(move || {
                accept_loop(listener, coord, registered, cfg, stop, cluster, node_name)
            })?
        };
        Ok(NetServer { addr, stop, accept: Some(accept) })
    }

    /// The bound address (resolves port 0 to the real port).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Ask the server to stop accepting and drain (same effect as a
    /// client `shutdown` frame).
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::Relaxed);
    }

    /// Block until the server has drained every connection and exited.
    /// Returns when a `shutdown` control frame arrives (or after
    /// [`NetServer::shutdown`]).
    pub fn join(mut self) {
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn accept_loop(
    listener: TcpListener,
    coord: Arc<Coordinator>,
    registered: Arc<Vec<ModelKey>>,
    cfg: NetServerConfig,
    stop: Arc<AtomicBool>,
    cluster: Option<Arc<Cluster>>,
    node_name: String,
) {
    let mut conns: Vec<JoinHandle<()>> = Vec::new();
    let nap = cfg.poll.min(Duration::from_millis(20));
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, peer)) => {
                coord.metrics().record_conn_opened();
                let conn_coord = coord.clone();
                let registered = registered.clone();
                let cfg = cfg.clone();
                let stop = stop.clone();
                let cluster = cluster.clone();
                let node_name = node_name.clone();
                let spawned = thread::Builder::new().name(format!("ppc-net-conn-{peer}")).spawn(
                    move || {
                        handle_connection(
                            stream, conn_coord, registered, cfg, stop, cluster, node_name,
                        )
                    },
                );
                match spawned {
                    Ok(h) => conns.push(h),
                    // thread exhaustion: count the connection closed and
                    // drop the stream (the client sees EOF)
                    Err(_) => coord.metrics().record_conn_closed(),
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => thread::sleep(nap),
            Err(_) => thread::sleep(nap),
        }
        // reap finished handlers so a long-lived server does not
        // accumulate dead JoinHandles
        conns.retain(|h| !h.is_finished());
    }
    for h in conns {
        let _ = h.join();
    }
}

/// What the reader queues for the writer: an immediate frame, a ticket
/// whose response is still in flight, or a forward worker's pending
/// reply (FIFO per connection — this ordering is the pipelining
/// contract). `Later`'s optional node name wraps the resolved reply in
/// a [`ServerFrame::Forwarded`] — set when the request arrived as a
/// peer's `Forward` frame.
enum Out {
    Now(Json),
    Later(u64, Ticket, Option<String>),
    Wait(u64, mpsc::Receiver<Json>),
}

#[allow(clippy::too_many_arguments)]
fn handle_connection(
    stream: TcpStream,
    coord: Arc<Coordinator>,
    registered: Arc<Vec<ModelKey>>,
    cfg: NetServerConfig,
    stop: Arc<AtomicBool>,
    cluster: Option<Arc<Cluster>>,
    node_name: String,
) {
    let _ = stream.set_read_timeout(Some(cfg.poll));
    let _ = stream.set_nodelay(true);
    let write_half = match stream.try_clone() {
        Ok(s) => s,
        Err(_) => {
            coord.metrics().record_conn_closed();
            return;
        }
    };
    let (out_tx, out_rx) = mpsc::channel::<Out>();
    let writer = {
        let coord = coord.clone();
        thread::spawn(move || writer_loop(write_half, out_rx, coord))
    };
    let mut reader = FrameReader::new(stream, cfg.max_frame);
    while !stop.load(Ordering::Relaxed) {
        match reader.poll_frame() {
            Ok(None) => continue,
            Ok(Some(json)) => {
                coord.metrics().record_net_frame_in();
                match ClientFrame::from_json(&json) {
                    Ok(ClientFrame::Request(req)) => {
                        let received = Instant::now();
                        let route = ModelKey::route(req.job.app(), req.quality);
                        let plan = match &cluster {
                            Some(c) => c.plan(route, registered.contains(&route)),
                            None => RoutePlan::Local,
                        };
                        match plan {
                            RoutePlan::Local => {
                                handle_request(&coord, &registered, &out_tx, req, received, None)
                            }
                            RoutePlan::Forward(tries) => spawn_forward(
                                cluster.as_ref().expect("forward plans need a cluster").clone(),
                                &coord,
                                &registered,
                                &out_tx,
                                req,
                                received,
                                tries,
                            ),
                        }
                    }
                    Ok(ClientFrame::Forward { from: _, req }) => {
                        // a peer front door relayed this: serve it
                        // locally (never re-forward — at most one hop)
                        // and sign the reply with our node name
                        coord.metrics().record_forward_in();
                        handle_request(
                            &coord,
                            &registered,
                            &out_tx,
                            req,
                            Instant::now(),
                            Some(node_name.clone()),
                        );
                    }
                    Ok(ClientFrame::Shutdown) => {
                        // ack *after* every reply already queued, then
                        // stop the whole server
                        let _ = out_tx.send(Out::Now(ServerFrame::ShutdownAck.to_json()));
                        stop.store(true, Ordering::Relaxed);
                        break;
                    }
                    Ok(ClientFrame::Ping) => {
                        let _ = out_tx.send(Out::Now(ServerFrame::Pong.to_json()));
                    }
                    Err(e) => {
                        coord.metrics().record_net_protocol_error();
                        let _ = out_tx.send(Out::Now(
                            ServerFrame::Error {
                                id: None,
                                kind: proto::ERR_BAD_REQUEST.to_string(),
                                message: format!("{e:#}"),
                            }
                            .to_json(),
                        ));
                    }
                }
            }
            Err(e @ FrameError::Oversized { .. }) | Err(e @ FrameError::Malformed(_)) => {
                // survivable: the stream is still frame-aligned
                coord.metrics().record_net_protocol_error();
                let kind = match e {
                    FrameError::Oversized { .. } => proto::ERR_OVERSIZED,
                    _ => proto::ERR_MALFORMED,
                };
                let _ = out_tx.send(Out::Now(
                    ServerFrame::Error { id: None, kind: kind.to_string(), message: e.to_string() }
                        .to_json(),
                ));
            }
            Err(FrameError::Closed) => break,
            Err(FrameError::Truncated) | Err(FrameError::Io(_)) => {
                coord.metrics().record_net_protocol_error();
                break;
            }
        }
    }
    // closing the channel lets the writer drain every queued reply
    // (including still-running tickets) before the connection closes
    drop(out_tx);
    let _ = writer.join();
    coord.metrics().record_conn_closed();
}

/// Submit `req` to the local coordinator, queueing the outcome on the
/// writer. The relative deadline is anchored at `received` — for a
/// forwarded request that is the *remaining* budget the forwarder sent,
/// re-anchored at local receipt. `wrap` (a node name) marks a reply
/// that must travel back inside a [`ServerFrame::Forwarded`].
fn handle_request(
    coord: &Coordinator,
    registered: &[ModelKey],
    out_tx: &mpsc::Sender<Out>,
    req: Request,
    received: Instant,
    wrap: Option<String>,
) {
    let wrapped = |frame: ServerFrame| match &wrap {
        Some(node) => {
            ServerFrame::Forwarded { node: node.clone(), frame: Box::new(frame) }.to_json()
        }
        None => frame.to_json(),
    };
    let route = ModelKey::route(req.job.app(), req.quality);
    if !registered.contains(&route) {
        let _ = out_tx.send(Out::Now(wrapped(ServerFrame::Rejected {
            id: req.id,
            rejection: Rejection::UnknownModel,
            message: format!(
                "no {route} in the registered catalog (registered: {})",
                join(registered.iter())
            ),
        })));
        return;
    }
    let submitted = match req.deadline_ms {
        Some(ms) => {
            coord.submit_deadline(req.job, req.quality, received + Duration::from_millis(ms))
        }
        None => coord.submit_blocking(req.job, req.quality),
    };
    let frame = match submitted {
        Ok(ticket) => {
            let _ = out_tx.send(Out::Later(req.id, ticket, wrap));
            return;
        }
        Err(e @ SubmitError::Shed) | Err(e @ SubmitError::Busy) => ServerFrame::Rejected {
            id: req.id,
            rejection: Rejection::Shed,
            message: e.to_string(),
        },
        Err(e @ SubmitError::Expired) => ServerFrame::Rejected {
            id: req.id,
            rejection: Rejection::DeadlineExpired,
            message: e.to_string(),
        },
        Err(e @ SubmitError::Down) => ServerFrame::Error {
            id: Some(req.id),
            kind: proto::ERR_DOWN.to_string(),
            message: e.to_string(),
        },
    };
    let _ = out_tx.send(Out::Now(wrapped(frame)));
}

/// Relay `req` to the owning peer on a worker thread. The writer gets
/// an [`Out::Wait`] slot *first* (still on the reader thread, so the
/// per-connection reply order is preserved); the worker fills it with
/// whatever the forward walk produces — a peer's reply, a typed
/// expiry, or the local fallback when every candidate is down.
fn spawn_forward(
    cluster: Arc<Cluster>,
    coord: &Arc<Coordinator>,
    registered: &Arc<Vec<ModelKey>>,
    out_tx: &mpsc::Sender<Out>,
    req: Request,
    received: Instant,
    tries: Vec<String>,
) {
    coord.metrics().record_forward_out();
    let (tx, rx) = mpsc::channel::<Json>();
    let _ = out_tx.send(Out::Wait(req.id, rx));
    let coord = coord.clone();
    let registered = registered.clone();
    let worker = thread::Builder::new().name("ppc-net-forward".to_string()).spawn(move || {
        let metrics = coord.metrics();
        let reply = match cluster.forward(&req, received, &tries) {
            ForwardOutcome::Replied { frame, retries, .. } => {
                for _ in 0..retries {
                    metrics.record_forward_retry();
                }
                frame.to_json()
            }
            ForwardOutcome::Expired => ServerFrame::Rejected {
                id: req.id,
                rejection: Rejection::DeadlineExpired,
                message: "deadline budget spent before the forward hop".to_string(),
            }
            .to_json(),
            ForwardOutcome::Exhausted { retries } => {
                for _ in 0..retries {
                    metrics.record_forward_retry();
                }
                metrics.record_forward_fallback();
                if registered.contains(&ModelKey::route(req.job.app(), req.quality)) {
                    // every replica is down but we can serve the key:
                    // survivors absorb the dead peer's traffic
                    serve_fallback(&coord, req, received)
                } else {
                    ServerFrame::Rejected {
                        id: req.id,
                        rejection: Rejection::UnknownModel,
                        message: format!(
                            "no reachable peer serves this key (tried {})",
                            tries.join(", ")
                        ),
                    }
                    .to_json()
                }
            }
        };
        let _ = tx.send(reply);
    });
    if worker.is_err() {
        // thread exhaustion: the Wait slot's sender is gone; the writer
        // answers with a typed exec error
        coord.metrics().record_forward_fallback();
    }
}

/// The local fallback of an exhausted forward walk: submit here and
/// block for the outcome (the worker thread owns the wait).
fn serve_fallback(coord: &Coordinator, req: Request, received: Instant) -> Json {
    let submitted = match req.deadline_ms {
        Some(ms) => {
            coord.submit_deadline(req.job, req.quality, received + Duration::from_millis(ms))
        }
        None => coord.submit_blocking(req.job, req.quality),
    };
    let ticket = match submitted {
        Ok(t) => t,
        Err(e @ SubmitError::Shed) | Err(e @ SubmitError::Busy) => {
            return ServerFrame::Rejected {
                id: req.id,
                rejection: Rejection::Shed,
                message: e.to_string(),
            }
            .to_json()
        }
        Err(e @ SubmitError::Expired) => {
            return ServerFrame::Rejected {
                id: req.id,
                rejection: Rejection::DeadlineExpired,
                message: e.to_string(),
            }
            .to_json()
        }
        Err(e @ SubmitError::Down) => {
            return ServerFrame::Error {
                id: Some(req.id),
                kind: proto::ERR_DOWN.to_string(),
                message: e.to_string(),
            }
            .to_json()
        }
    };
    resolve_ticket(req.id, ticket).to_json()
}

/// Wait out a ticket and translate the outcome into its reply frame
/// (shared by the writer loop and the forward fallback path).
fn resolve_ticket(id: u64, ticket: Ticket) -> ServerFrame {
    match ticket.wait() {
        Ok(r) => ServerFrame::Response {
            id,
            route: r.route,
            tier: r.tier,
            quality: r.quality,
            degraded: r.degraded,
            outputs: r.outputs,
        },
        Err(e) => match e.downcast_ref::<Rejection>() {
            Some(&rej) => ServerFrame::Rejected { id, rejection: rej, message: format!("{e:#}") },
            None => ServerFrame::Error {
                id: Some(id),
                kind: proto::ERR_EXEC.to_string(),
                message: format!("{e:#}"),
            },
        },
    }
}

fn writer_loop(mut stream: TcpStream, rx: mpsc::Receiver<Out>, coord: Arc<Coordinator>) {
    let mut alive = true;
    while let Ok(out) = rx.recv() {
        let frame = match out {
            Out::Now(j) => j,
            Out::Later(id, ticket, wrap) => {
                let frame = resolve_ticket(id, ticket);
                match wrap {
                    Some(node) => {
                        ServerFrame::Forwarded { node, frame: Box::new(frame) }.to_json()
                    }
                    None => frame.to_json(),
                }
            }
            // a forward worker's pending reply; a dead worker (thread
            // exhaustion) degrades to a typed exec error
            Out::Wait(id, worker_rx) => worker_rx.recv().unwrap_or_else(|_| {
                ServerFrame::Error {
                    id: Some(id),
                    kind: proto::ERR_EXEC.to_string(),
                    message: "forward worker died before replying".to_string(),
                }
                .to_json()
            }),
        };
        // even after a dead client we keep draining the channel so
        // every in-flight ticket resolves (permits release on drop)
        if alive && proto::write_frame(&mut stream, &frame).is_err() {
            alive = false;
        }
        if alive {
            coord.metrics().record_net_frame_out();
        }
    }
    let _ = stream.shutdown(Shutdown::Write);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{CoordinatorConfig, MockExecutor};

    fn mock_server() -> (Arc<Coordinator>, NetServer) {
        let cfg = CoordinatorConfig { queue_capacity: 16, ..CoordinatorConfig::default() };
        let coord =
            Arc::new(Coordinator::start(cfg, |_s| Ok(MockExecutor::full_catalog())).unwrap());
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let server =
            NetServer::spawn(listener, coord.clone(), NetServerConfig::default()).unwrap();
        (coord, server)
    }

    #[test]
    fn ping_pong_over_loopback() {
        let (coord, server) = mock_server();
        let mut w = TcpStream::connect(server.local_addr()).unwrap();
        let r = w.try_clone().unwrap();
        proto::write_frame(&mut w, &ClientFrame::Ping.to_json()).unwrap();
        let mut rd = FrameReader::new(r, MAX_FRAME);
        let frame = ServerFrame::from_json(&rd.next_frame().unwrap()).unwrap();
        assert!(matches!(frame, ServerFrame::Pong), "{frame:?}");
        server.shutdown();
        server.join();
        assert_eq!(coord.metrics().net_frames_in(), 1);
        assert_eq!(coord.metrics().net_frames_out(), 1);
        assert_eq!(coord.metrics().net_protocol_errors(), 0);
    }

    #[test]
    fn peerless_servers_answer_forward_frames_with_wrapped_replies() {
        use crate::catalog::{Quality, Tensor};
        use crate::coordinator::Job;
        // node A of the two-process bootstrap: it has no --peer flags
        // yet, but node B already forwards to it
        let (coord, server) = mock_server();
        let mut w = TcpStream::connect(server.local_addr()).unwrap();
        let r = w.try_clone().unwrap();
        let req = Request {
            id: 41,
            job: Job::Denoise { image: Tensor::scalar(8) },
            quality: Quality::Balanced,
            deadline_ms: Some(5_000),
        };
        let f = ClientFrame::Forward { from: "10.0.0.9:4500".to_string(), req };
        proto::write_frame(&mut w, &f.to_json()).unwrap();
        let mut rd = FrameReader::new(r, MAX_FRAME);
        match ServerFrame::from_json(&rd.next_frame().unwrap()).unwrap() {
            ServerFrame::Forwarded { node, frame } => {
                assert_eq!(node, server.local_addr().to_string());
                assert!(
                    matches!(*frame, ServerFrame::Response { id: 41, .. }),
                    "wanted the original id back, got {frame:?}"
                );
            }
            other => panic!("wanted a Forwarded reply, got {other:?}"),
        }
        assert_eq!(coord.metrics().forwards_in(), 1);
        server.shutdown();
        server.join();
    }

    #[test]
    fn shutdown_frame_acks_then_drains_the_server() {
        let (coord, server) = mock_server();
        let mut w = TcpStream::connect(server.local_addr()).unwrap();
        let r = w.try_clone().unwrap();
        proto::write_frame(&mut w, &ClientFrame::Shutdown.to_json()).unwrap();
        let mut rd = FrameReader::new(r, MAX_FRAME);
        let frame = ServerFrame::from_json(&rd.next_frame().unwrap()).unwrap();
        assert!(matches!(frame, ServerFrame::ShutdownAck), "{frame:?}");
        // the accept loop exits on its own — join returns
        server.join();
        assert_eq!(coord.metrics().net_active_connections(), 0);
    }
}
