//! Multi-node serving: ring membership, request forwarding, and peer
//! health for a set of `serve --listen` processes naming each other
//! with `--peer`.
//!
//! **Ownership.** Every member sorts the full membership (its own
//! advertised address plus its peers) and ranks it per [`ModelKey`]
//! with [`placement::rank_nodes`] — the same rendezvous hash the
//! engine-shard placement uses, scored over `(node, shard)` virtual
//! slots. The top-ranked member owns the key; the rest of the ranking
//! is the retry-on-next-replica order. Because scores hash node
//! *names*, every member computes the same ranking from the same
//! membership, with no coordination traffic.
//!
//! **Forwarding.** A front door that receives a request for a key it
//! does not own opens a connection to the owner and relays the request
//! as a [`ClientFrame::Forward`] — original id, *remaining* deadline
//! budget, quality hint intact — and unwraps the peer's
//! [`ServerFrame::Forwarded`] reply. Transport failures and
//! unknown-model rejections walk down the ranking (bounded by
//! `max_forward_tries`); when every candidate fails, the caller serves
//! locally if it can, or answers a typed rejection. Forwards are never
//! re-forwarded, so the hop count is at most one.
//!
//! **Health.** A prober thread pings every peer each `probe_interval`
//! with the ordinary `ping` control frame. A missed probe (connect
//! failure or no `pong` within `probe_timeout`) moves the peer
//! `Alive → Suspect`; `dead_after_misses` consecutive misses move it
//! to `Dead`, which removes it from forward candidate lists until a
//! probe succeeds again (`→ Alive`, misses reset). A refused forward
//! connection marks the peer `Dead` immediately — that is what makes
//! drain-on-shutdown rehome keys promptly: the drained process closed
//! its listener, the next forward gets `ECONNREFUSED`, and survivors
//! take over its keys on the spot.
//!
//! Every outbound connection (forward and probe alike) passes through
//! the [`FaultPolicy`] installed with [`Cluster::set_fault_policy`] —
//! the deterministic fault-injection shim the cluster test harness
//! drives (see [`crate::net::fault`]).

use crate::catalog::ModelKey;
use crate::coordinator::{placement, Rejection};
use crate::net::fault::{FaultPolicy, FaultedStream};
use crate::net::proto::{self, ClientFrame, FrameReader, Request, ServerFrame, MAX_FRAME};
use std::collections::BTreeMap;
use std::io;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::{self, JoinHandle};
use std::time::{Duration, Instant};

/// Cluster membership and failure-detection knobs.
#[derive(Clone, Debug)]
pub struct ClusterConfig {
    /// This node's advertised `host:port` (what peers dial).
    pub node: String,
    /// The other members' advertised addresses.
    pub peers: Vec<String>,
    /// Virtual `(node, shard)` slots per member on the ownership ring.
    pub slots_per_node: usize,
    /// How often the prober pings every peer.
    pub probe_interval: Duration,
    /// Connect + pong budget of one probe.
    pub probe_timeout: Duration,
    /// Consecutive missed probes before a `Suspect` peer is `Dead`.
    pub dead_after_misses: u32,
    /// TCP connect budget of one forward attempt.
    pub forward_connect_timeout: Duration,
    /// Reply budget of one forward attempt (clamped to the request's
    /// remaining deadline when it has one).
    pub forward_read_timeout: Duration,
    /// Upper bound on peers tried per request (the "bounded" in
    /// bounded retry-on-next-replica).
    pub max_forward_tries: usize,
    /// Largest accepted reply frame.
    pub max_frame: usize,
}

impl Default for ClusterConfig {
    fn default() -> Self {
        ClusterConfig {
            node: String::new(),
            peers: Vec::new(),
            slots_per_node: 8,
            probe_interval: Duration::from_millis(500),
            probe_timeout: Duration::from_millis(250),
            dead_after_misses: 2,
            forward_connect_timeout: Duration::from_millis(500),
            forward_read_timeout: Duration::from_secs(5),
            max_forward_tries: 2,
            max_frame: MAX_FRAME,
        }
    }
}

/// Failure-detector verdict on one peer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PeerState {
    /// Answering probes; a full forward candidate.
    Alive,
    /// Missed at least one probe; still routed to (it may just be
    /// slow), but one more miss streak away from `Dead`.
    Suspect,
    /// Missed `dead_after_misses` probes (or refused a connection);
    /// removed from candidate lists until it pongs again.
    Dead,
}

struct PeerInfo {
    state: PeerState,
    misses: u32,
}

/// Counters the cluster tests and the metrics report read.
#[derive(Default)]
pub struct ClusterStats {
    /// Forward attempts that got a `Forwarded` reply back.
    pub forwards_ok: AtomicU64,
    /// Attempts abandoned for the next candidate (transport failure,
    /// timeout, or an unknown-model rejection from the peer).
    pub forward_retries: AtomicU64,
    /// Requests whose deadline budget ran out before or during the
    /// forward hop.
    pub forward_expired: AtomicU64,
    /// Requests that exhausted every candidate (the caller falls back
    /// to local serving or a typed rejection).
    pub forward_exhausted: AtomicU64,
    /// Successful probe round-trips.
    pub probes_ok: AtomicU64,
    /// Missed probes.
    pub probes_missed: AtomicU64,
    /// `Dead → Alive` recoveries observed (probe or forward).
    pub peer_recoveries: AtomicU64,
}

struct Inner {
    cfg: ClusterConfig,
    /// Sorted full membership (self included) — the canonical slot
    /// order every member agrees on.
    members: Vec<String>,
    peers: Mutex<BTreeMap<String, PeerInfo>>,
    fault: Mutex<Option<Arc<FaultPolicy>>>,
    stop: AtomicBool,
    stats: ClusterStats,
}

/// How one request should be served, per the ring.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum RoutePlan {
    /// This node owns the key (or is its best live fallback).
    Local,
    /// Try these peers in order; on exhaustion fall back to local
    /// serving when the key is registered here.
    Forward(Vec<String>),
}

/// Terminal outcome of a forward walk. `retries` counts the candidates
/// abandoned along the way (transport failures or unknown-model
/// refusals) so the caller can mirror them into its own metrics.
pub enum ForwardOutcome {
    /// A peer answered: the unwrapped reply to relay (original id).
    Replied { node: String, frame: ServerFrame, retries: usize },
    /// The deadline budget ran out en route.
    Expired,
    /// Every candidate failed or refused the key.
    Exhausted { retries: usize },
}

/// A running cluster member: ring routing + health prober. Dropping it
/// stops and joins the prober.
pub struct Cluster {
    inner: Arc<Inner>,
    prober: Mutex<Option<JoinHandle<()>>>,
}

impl Cluster {
    /// Start a member: membership is `cfg.node` + `cfg.peers`, and the
    /// prober begins pinging immediately (peers start `Alive` — a new
    /// member assumes the ring is up until told otherwise).
    pub fn start(cfg: ClusterConfig) -> Cluster {
        let mut members: Vec<String> = cfg.peers.iter().cloned().chain([cfg.node.clone()]).collect();
        members.sort();
        members.dedup();
        let peers: BTreeMap<String, PeerInfo> = cfg
            .peers
            .iter()
            .filter(|p| **p != cfg.node)
            .map(|p| (p.clone(), PeerInfo { state: PeerState::Alive, misses: 0 }))
            .collect();
        let inner = Arc::new(Inner {
            cfg,
            members,
            peers: Mutex::new(peers),
            fault: Mutex::new(None),
            stop: AtomicBool::new(false),
            stats: ClusterStats::default(),
        });
        let prober = if inner.cfg.peers.is_empty() {
            None
        } else {
            let probe_inner = inner.clone();
            Some(
                thread::Builder::new()
                    .name("ppc-cluster-probe".to_string())
                    .spawn(move || probe_loop(probe_inner))
                    .expect("spawn prober"),
            )
        };
        Cluster { inner, prober: Mutex::new(prober) }
    }

    /// This node's advertised address.
    pub fn node(&self) -> &str {
        &self.inner.cfg.node
    }

    /// The sorted full membership, self included.
    pub fn members(&self) -> &[String] {
        &self.inner.members
    }

    /// Counters for tests and the report line.
    pub fn stats(&self) -> &ClusterStats {
        &self.inner.stats
    }

    /// Failure-detector verdict on `peer` (`None` for non-members).
    pub fn peer_state(&self, peer: &str) -> Option<PeerState> {
        self.inner.peers.lock().unwrap_or_else(|e| e.into_inner()).get(peer).map(|p| p.state)
    }

    /// Install the deterministic fault shim on every future outbound
    /// connection (tests only; production never calls this).
    pub fn set_fault_policy(&self, policy: Arc<FaultPolicy>) {
        *self.inner.fault.lock().unwrap_or_else(|e| e.into_inner()) = Some(policy);
    }

    /// Stop and join the prober (also done on drop). Forwarding keeps
    /// working — a draining node may still need to flush in-flight
    /// forwards — but no more probes are sent.
    pub fn stop(&self) {
        self.inner.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.prober.lock().unwrap_or_else(|e| e.into_inner()).take() {
            let _ = h.join();
        }
    }

    /// The ring owner of `key` — purely positional, ignoring liveness
    /// (every member answers the same; the liveness-aware view is
    /// [`Cluster::plan`]).
    pub fn owner(&self, key: ModelKey) -> &str {
        let rank = placement::rank_nodes(key, &self.inner.members, self.inner.cfg.slots_per_node);
        &self.inner.members[rank[0]]
    }

    /// Decide how to serve `key` given whether this node registers it:
    /// walk the ring ranking, skipping `Dead` peers and (when
    /// unregistered) ourselves; the first live stop is either us
    /// (`Local`) or a bounded candidate list (`Forward`).
    pub fn plan(&self, key: ModelKey, locally_registered: bool) -> RoutePlan {
        let rank = placement::rank_nodes(key, &self.inner.members, self.inner.cfg.slots_per_node);
        let peers = self.inner.peers.lock().unwrap_or_else(|e| e.into_inner());
        let mut tries = Vec::new();
        for &idx in &rank {
            let member = &self.inner.members[idx];
            if *member == self.inner.cfg.node {
                if locally_registered && tries.is_empty() {
                    return RoutePlan::Local;
                }
                continue;
            }
            let dead = peers.get(member).map(|p| p.state == PeerState::Dead).unwrap_or(false);
            if !dead {
                tries.push(member.clone());
                if tries.len() >= self.inner.cfg.max_forward_tries {
                    break;
                }
            }
        }
        if tries.is_empty() {
            // every peer ahead of us is dead: we are the survivor
            RoutePlan::Local
        } else {
            RoutePlan::Forward(tries)
        }
    }

    /// Walk `candidates` with `req`, shrinking the deadline budget by
    /// the time already spent (`received` is when the front door took
    /// the request in). Returns the first peer reply, or a typed
    /// expiry/exhaustion for the caller to translate.
    pub fn forward(&self, req: &Request, received: Instant, candidates: &[String]) -> ForwardOutcome {
        let mut retries = 0usize;
        for peer in candidates {
            // the budget shrinks at every hop: what is left when this
            // attempt starts is what the peer gets to spend
            let remaining_ms = match req.deadline_ms {
                Some(ms) => {
                    let spent = received.elapsed().as_millis() as u64;
                    if spent >= ms {
                        self.inner.stats.forward_expired.fetch_add(1, Ordering::Relaxed);
                        return ForwardOutcome::Expired;
                    }
                    Some(ms - spent)
                }
                None => None,
            };
            match self.forward_once(req, remaining_ms, peer) {
                Ok(ServerFrame::Forwarded { node, frame }) => {
                    self.mark_alive(peer);
                    if let ServerFrame::Rejected { rejection: Rejection::UnknownModel, .. } = *frame
                    {
                        // the peer is healthy but does not serve this
                        // key: keep walking the ranking
                        retries += 1;
                        self.inner.stats.forward_retries.fetch_add(1, Ordering::Relaxed);
                        continue;
                    }
                    self.inner.stats.forwards_ok.fetch_add(1, Ordering::Relaxed);
                    return ForwardOutcome::Replied { node, frame: *frame, retries };
                }
                Ok(_) => {
                    // a peer that answers a Forward with anything but
                    // Forwarded is not speaking the cluster protocol
                    retries += 1;
                    self.inner.stats.forward_retries.fetch_add(1, Ordering::Relaxed);
                    self.mark_suspect(peer);
                }
                Err(e) => {
                    retries += 1;
                    self.inner.stats.forward_retries.fetch_add(1, Ordering::Relaxed);
                    if e.kind() == io::ErrorKind::ConnectionRefused {
                        // nothing is listening: the peer drained or
                        // died — rehome its keys immediately
                        self.mark_dead(peer);
                    } else {
                        self.mark_suspect(peer);
                    }
                }
            }
        }
        self.inner.stats.forward_exhausted.fetch_add(1, Ordering::Relaxed);
        ForwardOutcome::Exhausted { retries }
    }

    /// One attempt against one peer: connect, send the `Forward`
    /// frame (with the shrunk budget), wait for the `Forwarded` reply.
    fn forward_once(
        &self,
        req: &Request,
        remaining_ms: Option<u64>,
        peer: &str,
    ) -> io::Result<ServerFrame> {
        let fault = self.inner.fault.lock().unwrap_or_else(|e| e.into_inner()).clone();
        let poll = Duration::from_millis(50);
        let mut stream = FaultedStream::connect(
            peer,
            fault.as_deref(),
            self.inner.cfg.forward_connect_timeout,
            poll,
        )?;
        stream.set_read_timeout(Some(poll))?;
        let hop = Request {
            id: req.id,
            job: req.job.clone(),
            quality: req.quality,
            deadline_ms: remaining_ms,
        };
        let frame = ClientFrame::Forward { from: self.inner.cfg.node.clone(), req: hop };
        proto::write_frame(&mut stream, &frame.to_json())?;
        // the reply budget is the smaller of the configured forward
        // timeout and the request's remaining deadline
        let budget = match remaining_ms {
            Some(ms) => self.inner.cfg.forward_read_timeout.min(Duration::from_millis(ms)),
            None => self.inner.cfg.forward_read_timeout,
        };
        let give_up = Instant::now() + budget;
        let mut reader = FrameReader::new(stream, self.inner.cfg.max_frame);
        loop {
            match reader.poll_frame() {
                Ok(Some(json)) => match ServerFrame::from_json(&json) {
                    Ok(f @ ServerFrame::Forwarded { .. }) => return Ok(f),
                    Ok(_) | Err(_) => {
                        return Err(io::Error::new(
                            io::ErrorKind::InvalidData,
                            "peer answered a forward with a non-forwarded frame",
                        ))
                    }
                },
                Ok(None) => {
                    if Instant::now() >= give_up {
                        return Err(io::Error::new(
                            io::ErrorKind::TimedOut,
                            format!("no forwarded reply from {peer} within {budget:?}"),
                        ));
                    }
                }
                Err(e) => {
                    return Err(io::Error::new(
                        io::ErrorKind::UnexpectedEof,
                        format!("forward reply stream from {peer}: {e}"),
                    ))
                }
            }
        }
    }

    fn mark_alive(&self, peer: &str) {
        let mut peers = self.inner.peers.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(p) = peers.get_mut(peer) {
            if p.state == PeerState::Dead {
                self.inner.stats.peer_recoveries.fetch_add(1, Ordering::Relaxed);
            }
            p.state = PeerState::Alive;
            p.misses = 0;
        }
    }

    fn mark_suspect(&self, peer: &str) {
        let mut peers = self.inner.peers.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(p) = peers.get_mut(peer) {
            p.misses += 1;
            p.state = if p.misses >= self.inner.cfg.dead_after_misses {
                PeerState::Dead
            } else {
                PeerState::Suspect
            };
        }
    }

    fn mark_dead(&self, peer: &str) {
        let mut peers = self.inner.peers.lock().unwrap_or_else(|e| e.into_inner());
        if let Some(p) = peers.get_mut(peer) {
            p.misses = p.misses.max(self.inner.cfg.dead_after_misses);
            p.state = PeerState::Dead;
        }
    }

    /// One-line health + forwarding summary for the metrics report.
    pub fn report(&self) -> String {
        let peers = self.inner.peers.lock().unwrap_or_else(|e| e.into_inner());
        let states: Vec<String> =
            peers.iter().map(|(a, p)| format!("{a}={:?}", p.state).to_lowercase()).collect();
        let s = &self.inner.stats;
        format!(
            "cluster: node={} peers=[{}] forwards_ok={} retries={} expired={} exhausted={} \
             probes_ok={} probes_missed={} recoveries={}",
            self.inner.cfg.node,
            states.join(", "),
            s.forwards_ok.load(Ordering::Relaxed),
            s.forward_retries.load(Ordering::Relaxed),
            s.forward_expired.load(Ordering::Relaxed),
            s.forward_exhausted.load(Ordering::Relaxed),
            s.probes_ok.load(Ordering::Relaxed),
            s.probes_missed.load(Ordering::Relaxed),
            s.peer_recoveries.load(Ordering::Relaxed),
        )
    }
}

impl Drop for Cluster {
    fn drop(&mut self) {
        self.stop();
    }
}

/// The failure detector: ping every peer each interval, walking the
/// `Alive → Suspect → Dead` machine on misses and straight back to
/// `Alive` on a pong.
fn probe_loop(inner: Arc<Inner>) {
    let nap = Duration::from_millis(20);
    loop {
        if inner.stop.load(Ordering::Relaxed) {
            return;
        }
        let peers: Vec<String> = {
            inner.peers.lock().unwrap_or_else(|e| e.into_inner()).keys().cloned().collect()
        };
        for peer in &peers {
            if inner.stop.load(Ordering::Relaxed) {
                return;
            }
            if probe_once(&inner, peer) {
                inner.stats.probes_ok.fetch_add(1, Ordering::Relaxed);
                let mut map = inner.peers.lock().unwrap_or_else(|e| e.into_inner());
                if let Some(p) = map.get_mut(peer) {
                    if p.state == PeerState::Dead {
                        inner.stats.peer_recoveries.fetch_add(1, Ordering::Relaxed);
                    }
                    p.state = PeerState::Alive;
                    p.misses = 0;
                }
            } else {
                inner.stats.probes_missed.fetch_add(1, Ordering::Relaxed);
                let mut map = inner.peers.lock().unwrap_or_else(|e| e.into_inner());
                if let Some(p) = map.get_mut(peer) {
                    p.misses += 1;
                    p.state = if p.misses >= inner.cfg.dead_after_misses {
                        PeerState::Dead
                    } else {
                        PeerState::Suspect
                    };
                }
            }
        }
        // nap in small slices so stop() never waits a whole interval
        let wake = Instant::now() + inner.cfg.probe_interval;
        while Instant::now() < wake {
            if inner.stop.load(Ordering::Relaxed) {
                return;
            }
            thread::sleep(nap);
        }
    }
}

/// One ping/pong round trip under the probe budget.
fn probe_once(inner: &Inner, peer: &str) -> bool {
    let fault = inner.fault.lock().unwrap_or_else(|e| e.into_inner()).clone();
    let poll = Duration::from_millis(20);
    let mut stream =
        match FaultedStream::connect(peer, fault.as_deref(), inner.cfg.probe_timeout, poll) {
            Ok(s) => s,
            Err(_) => return false,
        };
    if stream.set_read_timeout(Some(poll)).is_err() {
        return false;
    }
    if proto::write_frame(&mut stream, &ClientFrame::Ping.to_json()).is_err() {
        return false;
    }
    let give_up = Instant::now() + inner.cfg.probe_timeout;
    let mut reader = FrameReader::new(stream, inner.cfg.max_frame);
    loop {
        match reader.poll_frame() {
            Ok(Some(json)) => {
                return matches!(ServerFrame::from_json(&json), Ok(ServerFrame::Pong))
            }
            Ok(None) => {
                if Instant::now() >= give_up {
                    return false;
                }
            }
            Err(_) => return false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::{Quality, Tensor};
    use crate::coordinator::Job;
    use std::net::TcpListener;

    fn fast_cfg(node: &str, peers: Vec<String>) -> ClusterConfig {
        ClusterConfig {
            node: node.to_string(),
            peers,
            probe_interval: Duration::from_millis(30),
            probe_timeout: Duration::from_millis(120),
            forward_connect_timeout: Duration::from_millis(200),
            forward_read_timeout: Duration::from_millis(500),
            ..ClusterConfig::default()
        }
    }

    #[test]
    fn members_agree_on_owners_regardless_of_peer_listing_order() {
        let addrs =
            ["127.0.0.1:4501".to_string(), "127.0.0.1:4502".to_string(), "127.0.0.1:4503".to_string()];
        // no prober traffic: peers are unreachable, but owner() is
        // positional and never dials
        let a = Cluster::start(ClusterConfig {
            node: addrs[0].clone(),
            peers: vec![addrs[2].clone(), addrs[1].clone()],
            probe_interval: Duration::from_secs(3600),
            ..ClusterConfig::default()
        });
        let b = Cluster::start(ClusterConfig {
            node: addrs[1].clone(),
            peers: vec![addrs[0].clone(), addrs[2].clone()],
            probe_interval: Duration::from_secs(3600),
            ..ClusterConfig::default()
        });
        assert_eq!(a.members(), b.members(), "sorted membership is canonical");
        let mut owners = std::collections::BTreeSet::new();
        for key in ModelKey::catalog() {
            assert_eq!(a.owner(key), b.owner(key), "{key}: split-brain ownership");
            owners.insert(a.owner(key).to_string());
        }
        assert!(owners.len() > 1, "9 keys over 3 nodes should spread, got {owners:?}");
    }

    #[test]
    fn plan_routes_owned_keys_local_and_foreign_keys_to_the_owner() {
        let me = "127.0.0.1:4601".to_string();
        let other = "127.0.0.1:4602".to_string();
        let c = Cluster::start(ClusterConfig {
            node: me.clone(),
            peers: vec![other.clone()],
            probe_interval: Duration::from_secs(3600),
            ..ClusterConfig::default()
        });
        for key in ModelKey::catalog() {
            let plan = c.plan(key, true);
            if c.owner(key) == me {
                assert_eq!(plan, RoutePlan::Local, "{key} is ours");
            } else {
                assert_eq!(plan, RoutePlan::Forward(vec![other.clone()]), "{key} is theirs");
            }
            // a key we do not register never plans Local while a live
            // peer exists
            assert_eq!(c.plan(key, false), RoutePlan::Forward(vec![other.clone()]));
        }
    }

    #[test]
    fn dead_peers_drop_out_of_plans_until_they_recover() {
        let me = "127.0.0.1:4701".to_string();
        let other = "127.0.0.1:4702".to_string();
        let c = Cluster::start(ClusterConfig {
            node: me.clone(),
            peers: vec![other.clone()],
            probe_interval: Duration::from_secs(3600),
            ..ClusterConfig::default()
        });
        let theirs = ModelKey::catalog()
            .into_iter()
            .find(|&k| c.owner(k) != me)
            .expect("some key lands on the peer");
        assert_eq!(c.plan(theirs, true), RoutePlan::Forward(vec![other.clone()]));
        c.mark_dead(&other);
        assert_eq!(c.plan(theirs, true), RoutePlan::Local, "dead owner: we are the survivor");
        c.mark_alive(&other);
        assert_eq!(c.plan(theirs, true), RoutePlan::Forward(vec![other]), "recovered");
    }

    /// A scripted peer for the failure-detector test: answers pings
    /// while `answer` is set, otherwise accepts and stays silent.
    fn scripted_pinger(answer: Arc<AtomicBool>) -> (String, Arc<AtomicBool>, thread::JoinHandle<()>) {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let stop = Arc::new(AtomicBool::new(false));
        let t_stop = stop.clone();
        listener.set_nonblocking(true).unwrap();
        let h = thread::spawn(move || {
            while !t_stop.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((mut s, _)) => {
                        if answer.load(Ordering::Relaxed) {
                            let _ = s.set_read_timeout(Some(Duration::from_millis(100)));
                            let mut rd = FrameReader::new(s.try_clone().unwrap(), MAX_FRAME);
                            if rd.next_frame().is_ok() {
                                let _ = proto::write_frame(&mut s, &ServerFrame::Pong.to_json());
                            }
                        }
                        // silent mode: accept and drop replies entirely
                    }
                    Err(_) => thread::sleep(Duration::from_millis(5)),
                }
            }
        });
        (addr, stop, h)
    }

    #[test]
    fn probe_misses_walk_alive_suspect_dead_and_a_pong_recovers() {
        let answer = Arc::new(AtomicBool::new(true));
        let (addr, stop, h) = scripted_pinger(answer.clone());
        let c = Cluster::start(fast_cfg("127.0.0.1:1", vec![addr.clone()]));
        let wait_for = |want: PeerState, within: Duration| {
            let give_up = Instant::now() + within;
            while Instant::now() < give_up {
                if c.peer_state(&addr) == Some(want) {
                    return true;
                }
                thread::sleep(Duration::from_millis(10));
            }
            false
        };
        // answering: stays (or becomes) Alive
        assert!(wait_for(PeerState::Alive, Duration::from_secs(5)), "never alive");
        // go silent: Suspect after one miss, Dead after the streak
        answer.store(false, Ordering::Relaxed);
        assert!(wait_for(PeerState::Dead, Duration::from_secs(10)), "never died");
        // resume: straight back to Alive, recovery counted
        answer.store(true, Ordering::Relaxed);
        assert!(wait_for(PeerState::Alive, Duration::from_secs(10)), "never recovered");
        assert!(c.stats().peer_recoveries.load(Ordering::Relaxed) >= 1);
        assert!(c.stats().probes_missed.load(Ordering::Relaxed) >= 2);
        c.stop();
        stop.store(true, Ordering::Relaxed);
        h.join().unwrap();
    }

    #[test]
    fn forwarding_an_already_expired_budget_is_a_typed_expiry_without_dialing() {
        let c = Cluster::start(ClusterConfig {
            node: "127.0.0.1:1".to_string(),
            peers: vec!["127.0.0.1:2".to_string()],
            probe_interval: Duration::from_secs(3600),
            ..ClusterConfig::default()
        });
        let req = Request {
            id: 9,
            job: Job::Denoise { image: Tensor::scalar(4) },
            quality: Quality::Balanced,
            deadline_ms: Some(5),
        };
        let received = Instant::now() - Duration::from_millis(50);
        match c.forward(&req, received, &["127.0.0.1:2".to_string()]) {
            ForwardOutcome::Expired => {}
            _ => panic!("a spent budget must expire, not dial"),
        }
        assert_eq!(c.stats().forward_expired.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn refused_forwards_mark_the_peer_dead_and_exhaust() {
        // bind-then-drop guarantees nothing listens on the port
        let dead_addr = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let c = Cluster::start(ClusterConfig {
            node: "127.0.0.1:1".to_string(),
            peers: vec![dead_addr.clone()],
            probe_interval: Duration::from_secs(3600),
            ..ClusterConfig::default()
        });
        let req = Request {
            id: 1,
            job: Job::Denoise { image: Tensor::scalar(2) },
            quality: Quality::Economy,
            deadline_ms: None,
        };
        match c.forward(&req, Instant::now(), &[dead_addr.clone()]) {
            ForwardOutcome::Exhausted { retries: 1 } => {}
            _ => panic!("refused peer must exhaust after one retry"),
        }
        assert_eq!(c.peer_state(&dead_addr), Some(PeerState::Dead), "refused => dead");
        assert_eq!(c.stats().forward_exhausted.load(Ordering::Relaxed), 1);
    }
}
