//! The network front door — the wire boundary of the serving stack.
//!
//! ```text
//!  client                      server (`serve --listen ADDR`)
//!  ──────                      ───────────────────────────────
//!  ClientFrame::Request ──►  FrameReader ─► route check ─► Coordinator::submit_*
//!   (id, app, quality,          │ (per-conn reader thread)        │
//!    deadline_ms, tensors)      │                                 ▼
//!                               │                        Admission ─► Batcher ─► EnginePool
//!  ServerFrame::{Response, ◄── writer thread ◄─ Ticket::wait ◄────┘
//!    Rejected, Error}          (replies in submit order = pipelining)
//! ```
//!
//! Three layers, all std-only (`std::net` + the in-tree JSON):
//!
//! - [`proto`] — length-prefixed JSON framing with typed payloads and
//!   survivable oversized/malformed outcomes;
//! - [`server`] — the threaded TCP server in front of a shared
//!   [`crate::coordinator::Coordinator`], with graceful control-frame
//!   shutdown and per-connection metrics folded into
//!   [`crate::coordinator::Metrics::report`];
//! - [`loadgen`] — the multi-client open-loop load generator
//!   (`loadgen` subcommand) whose percentiles stay honest under
//!   coordinated omission;
//! - [`cluster`] — multi-node serving: rendezvous-ring key ownership
//!   over the members, peer-to-peer request forwarding
//!   (`Forward`/`Forwarded` frames, bounded retry-on-next-replica),
//!   and ping-based health checking (alive → suspect → dead);
//! - [`fault`] — the deterministic fault-injection shim the cluster
//!   test harness installs on outbound connections (delay, drop,
//!   truncate, black-hole — by seeded rule table).

pub mod cluster;
pub mod fault;
pub mod loadgen;
pub mod proto;
pub mod server;

pub use cluster::{Cluster, ClusterConfig, ForwardOutcome, PeerState, RoutePlan};
pub use fault::{FaultAction, FaultPolicy, FaultedStream};
pub use loadgen::{LoadReport, LoadgenConfig};
pub use proto::{ClientFrame, FrameError, FrameReader, Request, ServerFrame, MAX_FRAME};
pub use server::{NetServer, NetServerConfig};
