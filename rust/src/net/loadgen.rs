//! Multi-client **open-loop** load generator for the TCP front door.
//!
//! Open loop means arrivals follow a fixed schedule: client `c` sends
//! request `k` at `start + offset_c + k·interval`, whether or not
//! earlier responses came back, and each latency sample is measured
//! from the request's *scheduled* time — not from when the socket
//! write happened. A closed-loop generator (request-after-response)
//! silently stops offering load exactly when the server stalls, which
//! is the coordinated-omission trap; this harness keeps the pressure
//! on, so a stalled server shows up as a fat p99/p999 tail instead of
//! a flattering mean.
//!
//! Each client owns one connection with a sender and a receiver
//! thread (responses are pipelined, so the receiver drains
//! continuously while the sender keeps the schedule). Typed outcomes
//! — answered / degraded / shed / expired / unknown-model / error —
//! are tallied per frame and merged into a [`LoadReport`], which
//! renders the human block and the `BENCH_*.json`-style summary.

use crate::catalog::{App, Quality, Tensor};
use crate::coordinator::{Job, Rejection};
use crate::net::proto::{
    self, ClientFrame, FrameError, FrameReader, Request, ServerFrame, ERR_EXEC, MAX_FRAME,
};
use crate::util::bench::{self, BenchResult};
use crate::util::json::Json;
use crate::util::prng::Rng;
use crate::util::stats::Summary;
use anyhow::{anyhow, Context, Result};
use std::net::{Shutdown, TcpStream};
use std::thread;
use std::time::{Duration, Instant};

/// Load-run parameters.
#[derive(Clone, Debug)]
pub struct LoadgenConfig {
    /// Server address (`host:port`).
    pub addr: String,
    /// Concurrent connections.
    pub clients: usize,
    /// Aggregate target arrival rate across all clients, requests/s.
    pub rps: f64,
    /// How long to keep offering load.
    pub duration: Duration,
    /// Application every request targets.
    pub app: App,
    /// Quality hint on every request.
    pub quality: Quality,
    /// Relative per-request deadline, if any.
    pub deadline_ms: Option<u64>,
    /// Square image edge for gdf/blend payloads.
    pub image_size: usize,
    /// FRNN pixel-row length (must match the server's `classify_row`).
    pub classify_row: usize,
    /// Payload PRNG seed.
    pub seed: u64,
}

impl Default for LoadgenConfig {
    fn default() -> Self {
        LoadgenConfig {
            addr: "127.0.0.1:0".to_string(),
            clients: 4,
            rps: 200.0,
            duration: Duration::from_secs(2),
            app: App::Gdf,
            quality: Quality::Balanced,
            deadline_ms: None,
            image_size: 32,
            classify_row: 960,
            seed: 0x10AD,
        }
    }
}

/// Aggregate outcome of one load run.
#[derive(Clone, Debug, Default)]
pub struct LoadReport {
    /// Requests on the arrival schedule (clients × per-client count).
    pub scheduled: usize,
    /// Requests actually written to a socket.
    pub sent: usize,
    /// Typed `response` frames received.
    pub answered: usize,
    /// ...of which served below the requested tier.
    pub degraded: usize,
    /// Typed shed rejections.
    pub shed: usize,
    /// Typed deadline-expired rejections.
    pub expired: usize,
    /// Typed unknown-model rejections.
    pub unknown_model: usize,
    /// Execution errors (the request ran and failed).
    pub exec_errors: usize,
    /// Wire-protocol violations seen by the clients (malformed frames,
    /// early disconnects, receiver stalls).
    pub protocol_errors: usize,
    /// Scheduled-time → response latency, seconds, answered only.
    pub latency: Summary,
    /// Wall-clock of the whole run.
    pub wall: Duration,
}

impl LoadReport {
    pub fn shed_rate(&self) -> f64 {
        self.shed as f64 / self.sent.max(1) as f64
    }

    pub fn degrade_rate(&self) -> f64 {
        self.degraded as f64 / self.sent.max(1) as f64
    }

    pub fn expired_rate(&self) -> f64 {
        self.expired as f64 / self.sent.max(1) as f64
    }

    /// Answered requests per wall-clock second.
    pub fn throughput_rps(&self) -> f64 {
        self.answered as f64 / self.wall.as_secs_f64().max(1e-9)
    }

    /// Human-readable block.
    pub fn render(&self) -> String {
        let mut s = String::new();
        s.push_str(&format!(
            "open-loop: {} scheduled, {} sent, {} answered ({} degraded), {} shed, \
             {} expired, {} unknown-model, {} exec errors, {} protocol errors \
             in {:.2}s ({:.1} answered/s)\n",
            self.scheduled,
            self.sent,
            self.answered,
            self.degraded,
            self.shed,
            self.expired,
            self.unknown_model,
            self.exec_errors,
            self.protocol_errors,
            self.wall.as_secs_f64(),
            self.throughput_rps()
        ));
        s.push_str(&format!(
            "latency (scheduled->response): p50={:.3}ms p90={:.3}ms p99={:.3}ms \
             p999={:.3}ms max={:.3}ms (n={})\n",
            self.latency.p50 * 1e3,
            self.latency.p90 * 1e3,
            self.latency.p99 * 1e3,
            self.latency.p999 * 1e3,
            self.latency.max * 1e3,
            self.latency.n
        ));
        s
    }

    /// The `BENCH_native_exec.json`-shaped machine summary
    /// (`{"results": [...], "metrics": {...}}`), ready for
    /// [`bench::write_summary`] / [`bench::append_history`].
    pub fn summary_json(&self, name: &str) -> Json {
        let row =
            BenchResult { name: name.to_string(), iters: self.latency.n, summary: self.latency.clone() };
        bench::summary_json(
            &[&row],
            &[
                ("loadgen_throughput_rps", self.throughput_rps()),
                ("loadgen_p50_ms", self.latency.p50 * 1e3),
                ("loadgen_p99_ms", self.latency.p99 * 1e3),
                ("loadgen_p999_ms", self.latency.p999 * 1e3),
                ("loadgen_shed_rate", self.shed_rate()),
                ("loadgen_degrade_rate", self.degrade_rate()),
                ("loadgen_expired_rate", self.expired_rate()),
                ("loadgen_answered", self.answered as f64),
                ("loadgen_protocol_errors", self.protocol_errors as f64),
            ],
        )
    }
}

/// p99 of the forwarded-path run over the p99 of the local-baseline
/// run — the headline overhead number for the cluster smoke step. A
/// degenerate baseline (no samples, zero p99) yields 0.0 rather than
/// an infinity that would wreck the regression gate's history math.
pub fn forwarded_vs_local_p99_ratio(forwarded: &LoadReport, local: &LoadReport) -> f64 {
    if local.latency.p99 <= 0.0 || forwarded.latency.n == 0 || local.latency.n == 0 {
        return 0.0;
    }
    forwarded.latency.p99 / local.latency.p99
}

/// Machine summary for a forwarded-vs-local comparison (`loadgen
/// --baseline-connect`): the usual fixed-rate metrics for the
/// forwarded run, one latency row per side, plus the
/// `forwarded_vs_local_p99_ratio` the regression gate tracks.
pub fn comparison_summary_json(forwarded: &LoadReport, local: &LoadReport) -> Json {
    let fwd_row = BenchResult {
        name: "forwarded (scheduled->response)".to_string(),
        iters: forwarded.latency.n,
        summary: forwarded.latency.clone(),
    };
    let local_row = BenchResult {
        name: "local baseline (scheduled->response)".to_string(),
        iters: local.latency.n,
        summary: local.latency.clone(),
    };
    bench::summary_json(
        &[&fwd_row, &local_row],
        &[
            ("loadgen_throughput_rps", forwarded.throughput_rps()),
            ("loadgen_p50_ms", forwarded.latency.p50 * 1e3),
            ("loadgen_p99_ms", forwarded.latency.p99 * 1e3),
            ("loadgen_p999_ms", forwarded.latency.p999 * 1e3),
            ("loadgen_shed_rate", forwarded.shed_rate()),
            ("loadgen_degrade_rate", forwarded.degrade_rate()),
            ("loadgen_expired_rate", forwarded.expired_rate()),
            ("loadgen_answered", forwarded.answered as f64),
            (
                "loadgen_protocol_errors",
                (forwarded.protocol_errors + local.protocol_errors) as f64,
            ),
            ("baseline_p99_ms", local.latency.p99 * 1e3),
            ("forwarded_vs_local_p99_ratio", forwarded_vs_local_p99_ratio(forwarded, local)),
        ],
    )
}

/// One phase of an arrival-rate ramp: the offered rate and the full
/// open-loop report measured while it held.
#[derive(Clone, Debug)]
pub struct RampStep {
    /// Offered aggregate arrival rate during this phase, requests/s.
    pub rps: f64,
    /// Outcome of the phase.
    pub report: LoadReport,
}

/// Parse the CLI ramp spelling `LOW:HIGH:STEPS` (e.g. `50:400:4`).
pub fn parse_ramp(spec: &str) -> Result<(f64, f64, usize)> {
    let mut it = spec.split(':');
    let (low, high, steps) = match (it.next(), it.next(), it.next(), it.next()) {
        (Some(l), Some(h), Some(s), None) => (
            l.trim().parse::<f64>().map_err(|e| anyhow!("ramp LOW {l:?}: {e}"))?,
            h.trim().parse::<f64>().map_err(|e| anyhow!("ramp HIGH {h:?}: {e}"))?,
            s.trim().parse::<usize>().map_err(|e| anyhow!("ramp STEPS {s:?}: {e}"))?,
        ),
        _ => return Err(anyhow!("--ramp wants LOW:HIGH:STEPS (e.g. 50:400:4)")),
    };
    if !low.is_finite() || !high.is_finite() || low <= 0.0 || high <= 0.0 {
        return Err(anyhow!("ramp rates must be positive and finite (got {low}:{high})"));
    }
    if steps == 0 {
        return Err(anyhow!("ramp wants at least one step"));
    }
    Ok((low, high, steps))
}

/// Run an arrival-rate ramp: `steps` open-loop passes with the target
/// rate linearly interpolated from `low` to `high`, each holding for
/// `cfg.duration / steps`. Every step is a complete [`run`] — its own
/// schedule, connections, and report — so per-phase shed/degrade/
/// latency stay attributable to the rate that produced them; that
/// phase split is the raw material for the adaptive-vs-static serving
/// comparison.
pub fn run_ramp(cfg: &LoadgenConfig, low: f64, high: f64, steps: usize) -> Result<Vec<RampStep>> {
    let per_step = cfg.duration.div_f64(steps.max(1) as f64);
    let mut out = Vec::with_capacity(steps);
    for i in 0..steps {
        let frac = if steps > 1 { i as f64 / (steps - 1) as f64 } else { 0.0 };
        let rps = low + (high - low) * frac;
        // distinct payload streams per phase, deterministic overall
        let step_cfg = LoadgenConfig {
            rps,
            duration: per_step,
            seed: cfg.seed.wrapping_add(i as u64),
            ..cfg.clone()
        };
        out.push(RampStep { rps, report: run(&step_cfg)? });
    }
    Ok(out)
}

/// Phase-tagged machine summary for a ramp run: one latency row and a
/// `ramp_stepN_*` metric group per phase, plus whole-ramp totals —
/// same `{"results", "metrics"}` shape the fixed-rate summary uses.
pub fn ramp_summary_json(steps: &[RampStep]) -> Json {
    let rows: Vec<BenchResult> = steps
        .iter()
        .enumerate()
        .map(|(i, s)| BenchResult {
            name: format!("ramp_step{i} @ {:.0} req/s (scheduled->response)", s.rps),
            iters: s.report.latency.n,
            summary: s.report.latency.clone(),
        })
        .collect();
    let mut metrics: Vec<(String, f64)> = Vec::new();
    for (i, s) in steps.iter().enumerate() {
        metrics.push((format!("ramp_step{i}_rps"), s.rps));
        metrics.push((format!("ramp_step{i}_throughput_rps"), s.report.throughput_rps()));
        metrics.push((format!("ramp_step{i}_shed_rate"), s.report.shed_rate()));
        metrics.push((format!("ramp_step{i}_degrade_rate"), s.report.degrade_rate()));
        metrics.push((format!("ramp_step{i}_p99_ms"), s.report.latency.p99 * 1e3));
    }
    let sent: usize = steps.iter().map(|s| s.report.sent).sum();
    let shed: usize = steps.iter().map(|s| s.report.shed).sum();
    let answered: usize = steps.iter().map(|s| s.report.answered).sum();
    let protocol: usize = steps.iter().map(|s| s.report.protocol_errors).sum();
    metrics.push(("ramp_steps".to_string(), steps.len() as f64));
    metrics.push(("ramp_shed_rate".to_string(), shed as f64 / sent.max(1) as f64));
    metrics.push(("ramp_answered".to_string(), answered as f64));
    metrics.push(("ramp_protocol_errors".to_string(), protocol as f64));
    let row_refs: Vec<&BenchResult> = rows.iter().collect();
    let metric_refs: Vec<(&str, f64)> = metrics.iter().map(|(k, v)| (k.as_str(), *v)).collect();
    bench::summary_json(&row_refs, &metric_refs)
}

#[derive(Default)]
struct ClientStats {
    sent: usize,
    answered: usize,
    degraded: usize,
    shed: usize,
    expired: usize,
    unknown_model: usize,
    exec_errors: usize,
    protocol_errors: usize,
    latencies: Vec<f64>,
}

impl ClientStats {
    /// Frames that terminally settle one request.
    fn terminal(&self) -> usize {
        self.answered + self.shed + self.expired + self.unknown_model + self.exec_errors
    }

    fn merge(&mut self, o: ClientStats) {
        self.sent += o.sent;
        self.answered += o.answered;
        self.degraded += o.degraded;
        self.shed += o.shed;
        self.expired += o.expired;
        self.unknown_model += o.unknown_model;
        self.exec_errors += o.exec_errors;
        self.protocol_errors += o.protocol_errors;
        self.latencies.extend(o.latencies);
    }
}

/// Run one open-loop load generation pass against a serving address.
pub fn run(cfg: &LoadgenConfig) -> Result<LoadReport> {
    if cfg.clients == 0 {
        return Err(anyhow!("loadgen wants at least one client"));
    }
    let per_client =
        (((cfg.rps * cfg.duration.as_secs_f64()) / cfg.clients as f64).ceil() as usize).max(1);
    let interval = Duration::from_secs_f64(cfg.clients as f64 / cfg.rps.max(1e-9));
    let t0 = Instant::now();
    // let every client connect before the schedule starts ticking
    let start = t0 + Duration::from_millis(50);
    let mut handles = Vec::new();
    for c in 0..cfg.clients {
        let cfg = cfg.clone();
        handles.push(
            thread::Builder::new()
                .name(format!("ppc-loadgen-{c}"))
                .spawn(move || client_run(&cfg, c, per_client, interval, start))?,
        );
    }
    let mut agg = ClientStats::default();
    for h in handles {
        agg.merge(h.join().map_err(|_| anyhow!("loadgen client panicked"))??);
    }
    let wall = t0.elapsed();
    Ok(LoadReport {
        scheduled: per_client * cfg.clients,
        sent: agg.sent,
        answered: agg.answered,
        degraded: agg.degraded,
        shed: agg.shed,
        expired: agg.expired,
        unknown_model: agg.unknown_model,
        exec_errors: agg.exec_errors,
        protocol_errors: agg.protocol_errors,
        latency: Summary::of(agg.latencies),
        wall,
    })
}

fn client_run(
    cfg: &LoadgenConfig,
    client: usize,
    n: usize,
    interval: Duration,
    start: Instant,
) -> Result<ClientStats> {
    let stream =
        TcpStream::connect(&cfg.addr).with_context(|| format!("connect {}", cfg.addr))?;
    let _ = stream.set_nodelay(true);
    let read_half = stream.try_clone()?;
    // phase-offset the clients so aggregate arrivals are evenly spaced
    let offset = interval.mul_f64(client as f64 / cfg.clients as f64);
    let receiver = thread::spawn(move || receive_loop(read_half, n, start, offset, interval));
    let mut rng = Rng::new(cfg.seed ^ (client as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    let mut w = stream;
    let mut sent = 0usize;
    for k in 0..n {
        let due = start + offset + interval.mul_f64(k as f64);
        let now = Instant::now();
        if due > now {
            thread::sleep(due - now);
        }
        let req = Request {
            id: k as u64,
            job: random_job(cfg, &mut rng),
            quality: cfg.quality,
            deadline_ms: cfg.deadline_ms,
        };
        if proto::write_frame(&mut w, &ClientFrame::Request(req).to_json()).is_err() {
            // server gone mid-run; the receiver will tally the EOF
            break;
        }
        sent += 1;
    }
    // half-close: the server answers everything it got, then EOFs us
    let _ = w.shutdown(Shutdown::Write);
    let mut st = receiver.join().map_err(|_| anyhow!("loadgen receiver panicked"))?;
    st.sent = sent;
    if st.terminal() < sent {
        // some requests never settled (server stall or disconnect)
        st.protocol_errors += sent - st.terminal();
    }
    Ok(st)
}

fn receive_loop(
    stream: TcpStream,
    n: usize,
    start: Instant,
    offset: Duration,
    interval: Duration,
) -> ClientStats {
    // a finite read timeout lets the receiver give up on a stalled
    // server instead of wedging the harness (and CI) forever
    let _ = stream.set_read_timeout(Some(Duration::from_millis(250)));
    let idle_limit = Duration::from_secs(60);
    let mut last_frame = Instant::now();
    let mut reader = FrameReader::new(stream, MAX_FRAME);
    let mut st = ClientStats::default();
    loop {
        if st.terminal() >= n {
            break;
        }
        match reader.poll_frame() {
            Ok(None) => {
                if last_frame.elapsed() > idle_limit {
                    st.protocol_errors += 1;
                    break;
                }
            }
            Ok(Some(json)) => {
                last_frame = Instant::now();
                match ServerFrame::from_json(&json) {
                    Ok(ServerFrame::Response { id, degraded, .. }) => {
                        let scheduled = start + offset + interval.mul_f64(id as f64);
                        let lat = Instant::now().saturating_duration_since(scheduled);
                        st.latencies.push(lat.as_secs_f64());
                        st.answered += 1;
                        if degraded {
                            st.degraded += 1;
                        }
                    }
                    Ok(ServerFrame::Rejected { rejection, .. }) => match rejection {
                        Rejection::Shed => st.shed += 1,
                        Rejection::DeadlineExpired => st.expired += 1,
                        Rejection::UnknownModel => st.unknown_model += 1,
                    },
                    Ok(ServerFrame::Error { kind, .. }) => {
                        if kind == ERR_EXEC {
                            st.exec_errors += 1;
                        } else {
                            st.protocol_errors += 1;
                        }
                    }
                    Ok(ServerFrame::ShutdownAck) | Ok(ServerFrame::Pong) => {}
                    Err(_) => st.protocol_errors += 1,
                }
            }
            Err(FrameError::Closed) | Err(FrameError::Truncated) => break,
            Err(_) => {
                st.protocol_errors += 1;
                break;
            }
        }
    }
    st
}

fn random_job(cfg: &LoadgenConfig, rng: &mut Rng) -> Job {
    let pixels = |rng: &mut Rng, len: usize, max: u64| -> Vec<i32> {
        (0..len).map(|_| rng.below(max) as i32).collect()
    };
    let side = cfg.image_size.max(1);
    match cfg.app {
        App::Gdf => Job::Denoise {
            image: Tensor::matrix(side, side, pixels(rng, side * side, 256))
                .expect("square loadgen image"),
        },
        App::Blend => Job::Blend {
            p1: Tensor::matrix(side, side, pixels(rng, side * side, 256))
                .expect("square loadgen image"),
            p2: Tensor::matrix(side, side, pixels(rng, side * side, 256))
                .expect("square loadgen image"),
            alpha: 64,
        },
        App::Frnn => Job::Classify { pixels: pixels(rng, cfg.classify_row, 160) },
    }
}

/// Send a `shutdown` control frame on a fresh connection and wait for
/// the ack (or the drain-close) — how `loadgen --shutdown` and the CI
/// smoke step stop a `serve --listen` process cleanly.
pub fn send_shutdown(addr: &str) -> Result<()> {
    let mut stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
    proto::write_frame(&mut stream, &ClientFrame::Shutdown.to_json())?;
    let mut reader = FrameReader::new(stream, MAX_FRAME);
    loop {
        match reader.next_frame() {
            Ok(j) => {
                if matches!(ServerFrame::from_json(&j), Ok(ServerFrame::ShutdownAck)) {
                    return Ok(());
                }
            }
            // the server may close right after draining — that is a
            // successful shutdown too
            Err(FrameError::Closed) | Err(FrameError::Truncated) => return Ok(()),
            Err(e) => return Err(anyhow!("waiting for shutdown ack: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ramp_spec_parses_and_rejects_nonsense() {
        assert_eq!(parse_ramp("50:400:4").unwrap(), (50.0, 400.0, 4));
        assert_eq!(parse_ramp(" 10 : 20 : 1 ").unwrap(), (10.0, 20.0, 1));
        for bad in ["", "50:400", "50:400:4:9", "0:400:4", "50:-1:4", "50:400:0", "a:b:c"] {
            assert!(parse_ramp(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn comparison_summary_carries_the_ratio_and_both_rows() {
        let mk = |p99: f64, n: usize| LoadReport {
            sent: n,
            answered: n,
            latency: Summary::of((0..n).map(|_| p99).collect::<Vec<_>>()),
            wall: Duration::from_secs(1),
            ..LoadReport::default()
        };
        let fwd = mk(0.004, 50);
        let local = mk(0.002, 50);
        let r = forwarded_vs_local_p99_ratio(&fwd, &local);
        assert!((r - 2.0).abs() < 1e-9, "ratio {r}");
        let j = comparison_summary_json(&fwd, &local);
        let m = |k: &str| j.get("metrics").unwrap().get(k).unwrap().as_f64().unwrap();
        assert!((m("forwarded_vs_local_p99_ratio") - 2.0).abs() < 1e-9);
        assert!((m("baseline_p99_ms") - 2.0).abs() < 1e-9);
        assert_eq!(j.get("results").unwrap().as_arr().unwrap().len(), 2);
        // degenerate baseline must not divide by zero
        let empty = LoadReport::default();
        assert_eq!(forwarded_vs_local_p99_ratio(&fwd, &empty), 0.0);
    }

    #[test]
    fn ramp_summary_tags_every_phase() {
        let mk = |rps: f64, shed: usize| RampStep {
            rps,
            report: LoadReport {
                sent: 100,
                answered: 100 - shed,
                shed,
                latency: Summary::of(vec![0.001, 0.002, 0.003]),
                wall: Duration::from_secs(1),
                ..LoadReport::default()
            },
        };
        let steps = vec![mk(50.0, 0), mk(400.0, 30)];
        let j = ramp_summary_json(&steps);
        let m = |k: &str| j.get("metrics").unwrap().get(k).unwrap().as_f64().unwrap();
        assert_eq!(m("ramp_steps"), 2.0);
        assert_eq!(m("ramp_step0_rps"), 50.0);
        assert_eq!(m("ramp_step0_shed_rate"), 0.0);
        assert!((m("ramp_step1_shed_rate") - 0.3).abs() < 1e-12);
        assert!((m("ramp_shed_rate") - 30.0 / 200.0).abs() < 1e-12);
        assert_eq!(j.get("results").unwrap().as_arr().unwrap().len(), 2);
    }
}
