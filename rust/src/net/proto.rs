//! Wire protocol of the network front door: length-prefixed JSON
//! frames carrying typed requests and responses.
//!
//! Every frame is a 4-byte big-endian `u32` body length followed by a
//! UTF-8 JSON object (the in-tree [`crate::util::json::Json`] — no
//! external serialization deps). The framing layer and the payload
//! layer fail independently on purpose:
//!
//! - a frame whose declared length exceeds the reader's cap is
//!   **drained** (the bytes are consumed and discarded) and surfaced
//!   as [`FrameError::Oversized`] — the stream stays frame-aligned
//!   and the connection survives;
//! - a well-framed body that is not UTF-8 JSON is
//!   [`FrameError::Malformed`] — again survivable;
//! - EOF mid-frame is [`FrameError::Truncated`]; EOF on a frame
//!   boundary is the clean [`FrameError::Closed`].
//!
//! Payloads are typed: [`ClientFrame`] (requests with pipelined ids,
//! quality hints and relative deadlines, plus `shutdown`/`ping`
//! control frames) and [`ServerFrame`] (responses with the serving
//! route and `degraded` flag, typed rejections keyed by
//! [`Rejection::wire_name`], protocol/execution errors, and the
//! control acks). Tensors travel as `{"shape": [...], "data": [...]}`
//! via [`Tensor::to_json`] / [`Tensor::from_json`].

use crate::catalog::{App, ModelKey, Quality, QualityProfile, Tensor};
use crate::coordinator::{Job, Rejection};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::fmt;
use std::io::{self, Read, Write};

/// Default largest accepted frame body, in bytes. Generous enough for
/// a few thousand-element tensors spelled out as JSON; small enough
/// that one hostile connection cannot balloon server memory.
pub const MAX_FRAME: usize = 8 << 20;

/// Stable `kind` discriminant of an oversized-frame [`ServerFrame::Error`].
pub const ERR_OVERSIZED: &str = "oversized";
/// Stable `kind` discriminant of a malformed-frame [`ServerFrame::Error`].
pub const ERR_MALFORMED: &str = "malformed";
/// Stable `kind`: the frame was valid JSON but not a valid request.
pub const ERR_BAD_REQUEST: &str = "bad_request";
/// Stable `kind`: the request executed and failed (not a wire problem).
pub const ERR_EXEC: &str = "exec";
/// Stable `kind`: the coordinator is shutting down.
pub const ERR_DOWN: &str = "down";

/// How reading a frame can fail. `Oversized` and `Malformed` leave the
/// stream frame-aligned — the reader can keep going; the rest are
/// terminal for the connection.
#[derive(Debug)]
pub enum FrameError {
    /// Clean EOF on a frame boundary.
    Closed,
    /// EOF in the middle of a frame.
    Truncated,
    /// The declared body length exceeded the reader's cap; the body
    /// was drained so the next frame still parses.
    Oversized { len: usize, max: usize },
    /// Well-framed bytes that are not UTF-8 JSON.
    Malformed(String),
    /// Transport error.
    Io(io::Error),
}

impl fmt::Display for FrameError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FrameError::Closed => f.write_str("connection closed at a frame boundary"),
            FrameError::Truncated => f.write_str("connection closed mid-frame"),
            FrameError::Oversized { len, max } => {
                write!(f, "frame of {len} bytes exceeds the {max}-byte cap")
            }
            FrameError::Malformed(m) => write!(f, "malformed frame: {m}"),
            FrameError::Io(e) => write!(f, "frame io error: {e}"),
        }
    }
}

impl std::error::Error for FrameError {}

/// Incremental frame decoder over any [`Read`]. Tolerates arbitrarily
/// split delivery (state survives across `poll_frame` calls) and read
/// timeouts (`WouldBlock`/`TimedOut` surface as `Ok(None)` so a server
/// thread can interleave a shutdown-flag check between polls).
pub struct FrameReader<R> {
    r: R,
    max: usize,
    hdr: [u8; 4],
    hdr_got: usize,
    body: Vec<u8>,
    body_got: usize,
    /// Body length of the frame in progress (`None` = reading header).
    want: Option<usize>,
    /// Bytes left to discard of an oversized body.
    drain_left: usize,
    /// Original declared length of the frame being drained.
    drain_len: usize,
}

impl<R: Read> FrameReader<R> {
    /// Wrap `r`, rejecting (and draining) bodies larger than `max`.
    pub fn new(r: R, max: usize) -> FrameReader<R> {
        FrameReader {
            r,
            max,
            hdr: [0; 4],
            hdr_got: 0,
            body: Vec::new(),
            body_got: 0,
            want: None,
            drain_left: 0,
            drain_len: 0,
        }
    }

    /// Advance the decoder. Returns `Ok(Some(json))` when a frame
    /// completed, `Ok(None)` when the underlying read timed out (poll
    /// again), or a [`FrameError`].
    pub fn poll_frame(&mut self) -> Result<Option<Json>, FrameError> {
        loop {
            if self.drain_left > 0 {
                let mut scratch = [0u8; 4096];
                let want = self.drain_left.min(scratch.len());
                match self.r.read(&mut scratch[..want]) {
                    Ok(0) => return Err(FrameError::Truncated),
                    Ok(n) => {
                        self.drain_left -= n;
                        if self.drain_left == 0 {
                            let len = self.drain_len;
                            self.drain_len = 0;
                            return Err(FrameError::Oversized { len, max: self.max });
                        }
                    }
                    Err(e) => match e.kind() {
                        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => return Ok(None),
                        io::ErrorKind::Interrupted => continue,
                        _ => return Err(FrameError::Io(e)),
                    },
                }
            } else if self.want.is_none() {
                match self.r.read(&mut self.hdr[self.hdr_got..]) {
                    Ok(0) => {
                        return Err(if self.hdr_got == 0 {
                            FrameError::Closed
                        } else {
                            FrameError::Truncated
                        });
                    }
                    Ok(n) => {
                        self.hdr_got += n;
                        if self.hdr_got == 4 {
                            self.hdr_got = 0;
                            let len = u32::from_be_bytes(self.hdr) as usize;
                            if len > self.max {
                                self.drain_left = len;
                                self.drain_len = len;
                            } else {
                                self.want = Some(len);
                                self.body.resize(len, 0);
                                self.body_got = 0;
                            }
                        }
                    }
                    Err(e) => match e.kind() {
                        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => return Ok(None),
                        io::ErrorKind::Interrupted => continue,
                        _ => return Err(FrameError::Io(e)),
                    },
                }
            } else {
                let len = self.want.unwrap();
                if self.body_got == len {
                    self.want = None;
                    let text = match std::str::from_utf8(&self.body[..len]) {
                        Ok(t) => t,
                        Err(e) => {
                            return Err(FrameError::Malformed(format!("body is not utf-8: {e}")))
                        }
                    };
                    return match Json::parse(text) {
                        Ok(j) => Ok(Some(j)),
                        Err(e) => Err(FrameError::Malformed(format!("body is not json: {e}"))),
                    };
                }
                match self.r.read(&mut self.body[self.body_got..len]) {
                    Ok(0) => return Err(FrameError::Truncated),
                    Ok(n) => self.body_got += n,
                    Err(e) => match e.kind() {
                        io::ErrorKind::WouldBlock | io::ErrorKind::TimedOut => return Ok(None),
                        io::ErrorKind::Interrupted => continue,
                        _ => return Err(FrameError::Io(e)),
                    },
                }
            }
        }
    }

    /// Block until a whole frame arrives (re-polls through timeouts).
    pub fn next_frame(&mut self) -> Result<Json, FrameError> {
        loop {
            if let Some(j) = self.poll_frame()? {
                return Ok(j);
            }
        }
    }
}

/// Write one frame: 4-byte big-endian length, then the JSON body.
pub fn write_frame<W: Write>(w: &mut W, frame: &Json) -> io::Result<()> {
    write_raw_frame(w, frame.to_string().as_bytes())
}

/// Write arbitrary bytes under a valid frame header — the tests use
/// this to craft well-framed-but-malformed payloads.
pub fn write_raw_frame<W: Write>(w: &mut W, body: &[u8]) -> io::Result<()> {
    let len = u32::try_from(body.len())
        .map_err(|_| io::Error::new(io::ErrorKind::InvalidInput, "frame body over 4 GiB"))?;
    w.write_all(&len.to_be_bytes())?;
    w.write_all(body)?;
    w.flush()
}

/// One serving request as it travels over the wire. `id` is chosen by
/// the client and echoed on the reply, which is what makes pipelining
/// work: many requests may be in flight on one connection, and the
/// server answers in submit order. Keep ids within 2^53 — they ride a
/// JSON number.
#[derive(Clone, Debug)]
pub struct Request {
    pub id: u64,
    pub job: Job,
    pub quality: Quality,
    /// Relative deadline in milliseconds, anchored at server receipt
    /// (clients and servers do not share a clock).
    pub deadline_ms: Option<u64>,
}

impl Request {
    pub fn to_json(&self) -> Json {
        let (app, inputs, alpha) = match &self.job {
            Job::Denoise { image } => (App::Gdf, vec![image.to_json()], None),
            Job::Blend { p1, p2, alpha } => {
                (App::Blend, vec![p1.to_json(), p2.to_json()], Some(*alpha))
            }
            Job::Classify { pixels } => {
                (App::Frnn, vec![Tensor::vector(pixels.clone()).to_json()], None)
            }
        };
        let mut pairs = vec![
            ("type", Json::Str("request".to_string())),
            ("id", Json::Num(self.id as f64)),
            ("app", Json::Str(app.name().to_string())),
            ("quality", Json::Str(self.quality.name().to_string())),
        ];
        if let Some(ms) = self.deadline_ms {
            pairs.push(("deadline_ms", Json::Num(ms as f64)));
        }
        if let Some(a) = alpha {
            pairs.push(("alpha", Json::Num(a as f64)));
        }
        pairs.push(("inputs", Json::Arr(inputs)));
        Json::obj(pairs)
    }

    pub fn from_json(j: &Json) -> Result<Request> {
        let id = u64_field(j, "id")?;
        let app = App::parse(str_field(j, "app")?)?;
        let quality = Quality::parse(str_field(j, "quality")?)?;
        let deadline_ms = match j.get("deadline_ms") {
            None | Some(Json::Null) => None,
            Some(v) => Some(num_u64(v, "deadline_ms")?),
        };
        let raw = j
            .get("inputs")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("request wants an \"inputs\" array"))?;
        let mut inputs = Vec::with_capacity(raw.len());
        for t in raw {
            inputs.push(Tensor::from_json(t)?);
        }
        let job = match app {
            App::Gdf => {
                let [image] = fixed_arity(inputs, app, 1)?;
                Job::Denoise { image }
            }
            App::Blend => {
                let alpha = i32_field(j, "alpha")?;
                let [p1, p2] = fixed_arity(inputs, app, 2)?;
                Job::Blend { p1, p2, alpha }
            }
            App::Frnn => {
                let [pixels] = fixed_arity(inputs, app, 1)?;
                Job::Classify { pixels: pixels.data }
            }
        };
        Ok(Request { id, job, quality, deadline_ms })
    }
}

/// Everything a client may send.
#[derive(Clone, Debug)]
pub enum ClientFrame {
    Request(Request),
    /// A request relayed peer-to-peer by a cluster front door. Carries
    /// the forwarding node's advertised address and the original
    /// request with its **original id** and the **remaining** deadline
    /// budget (the forwarder subtracts the time the request already
    /// spent on its floor before re-anchoring, so budgets shrink across
    /// every hop). The quality hint rides inside the request unchanged.
    /// Never re-forwarded: the receiver serves it locally or answers
    /// with a typed rejection.
    Forward {
        /// Advertised `host:port` of the forwarding node.
        from: String,
        req: Request,
    },
    /// Ask the server to drain and exit (answered with
    /// [`ServerFrame::ShutdownAck`] after all pipelined replies).
    Shutdown,
    /// Liveness probe (answered with [`ServerFrame::Pong`]).
    Ping,
}

impl ClientFrame {
    pub fn to_json(&self) -> Json {
        match self {
            ClientFrame::Request(r) => r.to_json(),
            ClientFrame::Forward { from, req } => {
                let mut j = req.to_json();
                if let Json::Obj(o) = &mut j {
                    o.insert("type".to_string(), Json::Str("forward".to_string()));
                    o.insert("from".to_string(), Json::Str(from.clone()));
                }
                j
            }
            ClientFrame::Shutdown => Json::obj(vec![("type", Json::Str("shutdown".to_string()))]),
            ClientFrame::Ping => Json::obj(vec![("type", Json::Str("ping".to_string()))]),
        }
    }

    pub fn from_json(j: &Json) -> Result<ClientFrame> {
        match str_field(j, "type")? {
            "request" => Ok(ClientFrame::Request(Request::from_json(j)?)),
            "forward" => Ok(ClientFrame::Forward {
                from: str_field(j, "from")?.to_string(),
                req: Request::from_json(j)?,
            }),
            "shutdown" => Ok(ClientFrame::Shutdown),
            "ping" => Ok(ClientFrame::Ping),
            other => bail!("unknown client frame type {other:?}"),
        }
    }
}

/// Everything a server may send back.
#[derive(Clone, Debug)]
pub enum ServerFrame {
    /// The request executed; `route` names the catalog key that
    /// answered, `tier` its quality tier, and `quality` that tier's
    /// *measured* quality (when the backend measured one at
    /// registration). `degraded` is set when the overload policy or
    /// the quality autopilot served a lower tier than requested.
    Response {
        id: u64,
        route: ModelKey,
        tier: Quality,
        quality: Option<QualityProfile>,
        degraded: bool,
        outputs: Vec<Tensor>,
    },
    /// The request was refused with a typed [`Rejection`]
    /// (shed / expired / unknown-model — see [`Rejection::wire_name`]).
    Rejected { id: u64, rejection: Rejection, message: String },
    /// A protocol or execution error; `id` is `None` when the frame
    /// could not be tied to a request (e.g. malformed bytes). `kind`
    /// is one of the stable `ERR_*` discriminants.
    Error { id: Option<u64>, kind: String, message: String },
    /// The peer-to-peer reply to a [`ClientFrame::Forward`]: the
    /// answering node's advertised address wrapped around the ordinary
    /// reply frame (response / rejection / error, original id intact).
    /// The forwarding front door unwraps it and relays `frame` to the
    /// client, so forwarding is invisible on the client's wire.
    Forwarded { node: String, frame: Box<ServerFrame> },
    ShutdownAck,
    Pong,
}

impl ServerFrame {
    pub fn to_json(&self) -> Json {
        match self {
            ServerFrame::Response { id, route, tier, quality, degraded, outputs } => {
                Json::obj(vec![
                    ("type", Json::Str("response".to_string())),
                    ("id", Json::Num(*id as f64)),
                    ("route", Json::Str(route.to_string())),
                    ("tier", Json::Str(tier.to_string())),
                    // an unmeasured tier travels as null, not absent,
                    // so the wire form round-trips exactly
                    ("quality", quality.as_ref().map_or(Json::Null, QualityProfile::to_json)),
                    ("degraded", Json::Bool(*degraded)),
                    ("outputs", Json::Arr(outputs.iter().map(Tensor::to_json).collect())),
                ])
            }
            ServerFrame::Rejected { id, rejection, message } => Json::obj(vec![
                ("type", Json::Str("rejection".to_string())),
                ("id", Json::Num(*id as f64)),
                ("rejection", Json::Str(rejection.wire_name().to_string())),
                ("message", Json::Str(message.clone())),
            ]),
            ServerFrame::Error { id, kind, message } => Json::obj(vec![
                ("type", Json::Str("error".to_string())),
                ("id", id.map_or(Json::Null, |v| Json::Num(v as f64))),
                ("kind", Json::Str(kind.clone())),
                ("message", Json::Str(message.clone())),
            ]),
            ServerFrame::Forwarded { node, frame } => Json::obj(vec![
                ("type", Json::Str("forwarded".to_string())),
                ("node", Json::Str(node.clone())),
                ("frame", frame.to_json()),
            ]),
            ServerFrame::ShutdownAck => {
                Json::obj(vec![("type", Json::Str("shutdown_ack".to_string()))])
            }
            ServerFrame::Pong => Json::obj(vec![("type", Json::Str("pong".to_string()))]),
        }
    }

    pub fn from_json(j: &Json) -> Result<ServerFrame> {
        match str_field(j, "type")? {
            "response" => {
                let raw = j
                    .get("outputs")
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| anyhow!("response wants an \"outputs\" array"))?;
                let mut outputs = Vec::with_capacity(raw.len());
                for t in raw {
                    outputs.push(Tensor::from_json(t)?);
                }
                let route = ModelKey::parse(str_field(j, "route")?)?;
                Ok(ServerFrame::Response {
                    id: u64_field(j, "id")?,
                    route,
                    // tolerate pre-quality-plumbing peers: an absent
                    // tier is derivable from the serving key
                    tier: match j.get("tier") {
                        Some(t) => Quality::parse(
                            t.as_str().ok_or_else(|| anyhow!("response \"tier\" is not a string"))?,
                        )?,
                        None => route.tier(),
                    },
                    quality: match j.get("quality") {
                        None | Some(Json::Null) => None,
                        Some(q) => Some(QualityProfile::from_json(q)?),
                    },
                    degraded: matches!(j.get("degraded"), Some(Json::Bool(true))),
                    outputs,
                })
            }
            "rejection" => Ok(ServerFrame::Rejected {
                id: u64_field(j, "id")?,
                rejection: Rejection::parse_wire(str_field(j, "rejection")?)?,
                message: str_field(j, "message").unwrap_or_default().to_string(),
            }),
            "error" => Ok(ServerFrame::Error {
                id: match j.get("id") {
                    Some(v) if v.as_f64().is_some() => Some(num_u64(v, "id")?),
                    _ => None,
                },
                kind: str_field(j, "kind").unwrap_or("protocol").to_string(),
                message: str_field(j, "message").unwrap_or_default().to_string(),
            }),
            "forwarded" => {
                let inner = j
                    .get("frame")
                    .ok_or_else(|| anyhow!("forwarded frame wants an inner \"frame\""))?;
                let frame = Box::new(ServerFrame::from_json(inner)?);
                // a nested forwarded-in-forwarded would mean a routing
                // loop: forwards are never re-forwarded
                if matches!(*frame, ServerFrame::Forwarded { .. }) {
                    bail!("forwarded frames do not nest");
                }
                Ok(ServerFrame::Forwarded {
                    node: str_field(j, "node")?.to_string(),
                    frame,
                })
            }
            "shutdown_ack" => Ok(ServerFrame::ShutdownAck),
            "pong" => Ok(ServerFrame::Pong),
            other => bail!("unknown server frame type {other:?}"),
        }
    }
}

fn str_field<'a>(j: &'a Json, k: &str) -> Result<&'a str> {
    j.get(k)
        .and_then(|v| v.as_str())
        .ok_or_else(|| anyhow!("frame is missing string field {k:?}"))
}

fn num_u64(v: &Json, k: &str) -> Result<u64> {
    let x = v.as_f64().ok_or_else(|| anyhow!("frame field {k:?} is not a number"))?;
    if x < 0.0 || x.fract() != 0.0 || x > u64::MAX as f64 {
        bail!("frame field {k:?} is not a non-negative integer: {x}");
    }
    Ok(x as u64)
}

fn u64_field(j: &Json, k: &str) -> Result<u64> {
    num_u64(j.get(k).ok_or_else(|| anyhow!("frame is missing field {k:?}"))?, k)
}

fn i32_field(j: &Json, k: &str) -> Result<i32> {
    let x = j
        .get(k)
        .and_then(|v| v.as_f64())
        .ok_or_else(|| anyhow!("frame is missing numeric field {k:?}"))?;
    if x.fract() != 0.0 || x < i32::MIN as f64 || x > i32::MAX as f64 {
        bail!("frame field {k:?} is not an i32: {x}");
    }
    Ok(x as i32)
}

fn fixed_arity<const N: usize>(v: Vec<Tensor>, app: App, n: usize) -> Result<[Tensor; N]> {
    let got = v.len();
    v.try_into()
        .map_err(|_| anyhow!("{app} request wants {n} input tensors, got {got}"))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;
    use crate::util::prng::Rng;
    use std::io::Cursor;

    fn frame_bytes(j: &Json) -> Vec<u8> {
        let mut buf = Vec::new();
        write_frame(&mut buf, j).unwrap();
        buf
    }

    /// Delivers at most one byte per read — the harshest split.
    struct Trickle<R>(R);
    impl<R: Read> Read for Trickle<R> {
        fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
            let n = buf.len().min(1);
            self.0.read(&mut buf[..n])
        }
    }

    fn random_tensor(rng: &mut Rng) -> Tensor {
        match rng.below(3) {
            0 => Tensor::scalar(rng.below(512) as i32 - 256),
            1 => Tensor::vector((0..rng.below(8)).map(|_| rng.below(512) as i32 - 256).collect()),
            _ => {
                let r = rng.below(4) as usize + 1;
                let c = rng.below(4) as usize + 1;
                Tensor::matrix(r, c, (0..r * c).map(|_| rng.below(256) as i32).collect()).unwrap()
            }
        }
    }

    fn random_request(rng: &mut Rng) -> Request {
        let app = App::ALL[rng.below(3) as usize];
        let quality = Quality::ALL[rng.below(3) as usize];
        let job = match app {
            App::Gdf => Job::Denoise { image: random_tensor(rng) },
            App::Blend => Job::Blend {
                p1: random_tensor(rng),
                p2: random_tensor(rng),
                alpha: rng.below(128) as i32,
            },
            App::Frnn => Job::Classify {
                pixels: (0..rng.below(16)).map(|_| rng.below(256) as i32).collect(),
            },
        };
        Request {
            id: rng.below(1 << 32),
            job,
            quality,
            deadline_ms: if rng.below(2) == 0 { None } else { Some(rng.below(100_000)) },
        }
    }

    fn random_server_frame(rng: &mut Rng) -> ServerFrame {
        use crate::catalog::QualityMetric;
        let keys = ModelKey::catalog();
        match rng.below(3) {
            0 => {
                let route = keys[rng.below(keys.len() as u64) as usize];
                ServerFrame::Response {
                    id: rng.below(1 << 32),
                    route,
                    tier: route.tier(),
                    // unmeasured tiers travel as null; measured ones
                    // carry metric + value + reference tier
                    quality: if rng.below(2) == 0 {
                        None
                    } else {
                        Some(QualityProfile {
                            metric: if rng.below(2) == 0 {
                                QualityMetric::Psnr
                            } else {
                                QualityMetric::Accuracy
                            },
                            value: rng.below(1000) as f64 / 10.0,
                            reference: Quality::Precise,
                        })
                    },
                    degraded: rng.below(2) == 0,
                    outputs: (0..rng.below(3)).map(|_| random_tensor(rng)).collect(),
                }
            }
            1 => ServerFrame::Rejected {
                id: rng.below(1 << 32),
                rejection: Rejection::ALL[rng.below(3) as usize],
                message: "tricky \"message\"\nwith\tescapes \\".to_string(),
            },
            _ => ServerFrame::Error {
                id: if rng.below(2) == 0 { None } else { Some(rng.below(1 << 32)) },
                kind: ERR_EXEC.to_string(),
                message: "boom".to_string(),
            },
        }
    }

    #[test]
    fn request_wire_form_round_trips() {
        forall(0xF7A3, 128, random_request, |req| {
            let j1 = ClientFrame::Request(req.clone()).to_json();
            let mut rd = FrameReader::new(Cursor::new(frame_bytes(&j1)), MAX_FRAME);
            let j2 = rd.next_frame().unwrap();
            if j2 != j1 {
                return false;
            }
            match ClientFrame::from_json(&j2) {
                Ok(decoded) => decoded.to_json() == j1,
                Err(_) => false,
            }
        });
    }

    #[test]
    fn server_frame_wire_form_round_trips() {
        forall(0xBEEF, 128, random_server_frame, |frame| {
            let j1 = frame.to_json();
            let mut rd = FrameReader::new(Cursor::new(frame_bytes(&j1)), MAX_FRAME);
            let j2 = rd.next_frame().unwrap();
            if j2 != j1 {
                return false;
            }
            match ServerFrame::from_json(&j2) {
                Ok(decoded) => decoded.to_json() == j1,
                Err(_) => false,
            }
        });
    }

    #[test]
    fn control_frames_round_trip() {
        for f in [ClientFrame::Shutdown, ClientFrame::Ping] {
            let j = f.to_json();
            assert_eq!(ClientFrame::from_json(&j).unwrap().to_json(), j);
        }
        for f in [ServerFrame::ShutdownAck, ServerFrame::Pong] {
            let j = f.to_json();
            assert_eq!(ServerFrame::from_json(&j).unwrap().to_json(), j);
        }
    }

    #[test]
    fn reader_reassembles_byte_by_byte_delivery() {
        let a = ClientFrame::Ping.to_json();
        let b = ClientFrame::Shutdown.to_json();
        let mut bytes = frame_bytes(&a);
        bytes.extend(frame_bytes(&b));
        let mut rd = FrameReader::new(Trickle(Cursor::new(bytes)), MAX_FRAME);
        assert_eq!(rd.next_frame().unwrap(), a);
        assert_eq!(rd.next_frame().unwrap(), b);
        assert!(matches!(rd.next_frame(), Err(FrameError::Closed)));
    }

    #[test]
    fn oversized_frame_is_drained_and_the_stream_stays_aligned() {
        let big = Json::Str("x".repeat(200));
        let mut bytes = frame_bytes(&big);
        let ok = ClientFrame::Ping.to_json();
        bytes.extend(frame_bytes(&ok));
        let mut rd = FrameReader::new(Cursor::new(bytes), 64);
        match rd.next_frame() {
            Err(FrameError::Oversized { len, max: 64 }) => assert!(len > 64),
            other => panic!("wanted Oversized, got {other:?}"),
        }
        // the oversized body was fully consumed: the next frame parses
        assert_eq!(rd.next_frame().unwrap(), ok);
    }

    #[test]
    fn malformed_bodies_fail_typed_but_keep_the_stream_alive() {
        let mut bytes = Vec::new();
        write_raw_frame(&mut bytes, b"{not json").unwrap();
        write_raw_frame(&mut bytes, &[0xFF, 0xFE, 0x00]).unwrap();
        let ok = ClientFrame::Ping.to_json();
        bytes.extend(frame_bytes(&ok));
        let mut rd = FrameReader::new(Cursor::new(bytes), MAX_FRAME);
        assert!(matches!(rd.next_frame(), Err(FrameError::Malformed(_))));
        assert!(matches!(rd.next_frame(), Err(FrameError::Malformed(_))));
        assert_eq!(rd.next_frame().unwrap(), ok);
    }

    #[test]
    fn truncation_is_distinguished_from_clean_close() {
        // EOF inside the header
        let mut rd = FrameReader::new(Cursor::new(vec![0u8, 0]), MAX_FRAME);
        assert!(matches!(rd.next_frame(), Err(FrameError::Truncated)));
        // EOF inside the body
        let mut bytes = frame_bytes(&ClientFrame::Ping.to_json());
        bytes.truncate(bytes.len() - 2);
        let mut rd = FrameReader::new(Cursor::new(bytes), MAX_FRAME);
        assert!(matches!(rd.next_frame(), Err(FrameError::Truncated)));
        // EOF on the boundary
        let mut rd = FrameReader::new(Cursor::new(Vec::new()), MAX_FRAME);
        assert!(matches!(rd.next_frame(), Err(FrameError::Closed)));
    }

    #[test]
    fn forward_wire_form_round_trips_with_the_original_id() {
        forall(0xF0E4, 128, random_request, |req| {
            let f = ClientFrame::Forward { from: "10.1.2.3:4000".to_string(), req: req.clone() };
            let j = f.to_json();
            match ClientFrame::from_json(&j) {
                Ok(ClientFrame::Forward { from, req: back }) => {
                    from == "10.1.2.3:4000"
                        && back.id == req.id
                        && back.deadline_ms == req.deadline_ms
                        && back.quality == req.quality
                        && ClientFrame::Forward { from, req: back }.to_json() == j
                }
                _ => false,
            }
        });
    }

    #[test]
    fn forwarded_wraps_any_reply_and_refuses_to_nest() {
        forall(0xFAD0, 64, random_server_frame, |inner| {
            let f = ServerFrame::Forwarded {
                node: "10.9.9.9:4501".to_string(),
                frame: Box::new(inner.clone()),
            };
            let j = f.to_json();
            match ServerFrame::from_json(&j) {
                Ok(decoded) => decoded.to_json() == j,
                Err(_) => false,
            }
        });
        // nesting is a routing loop, not a valid wire form
        let once = ServerFrame::Forwarded {
            node: "a:1".to_string(),
            frame: Box::new(ServerFrame::Pong),
        };
        let twice = Json::obj(vec![
            ("type", Json::Str("forwarded".to_string())),
            ("node", Json::Str("b:2".to_string())),
            ("frame", once.to_json()),
        ]);
        assert!(ServerFrame::from_json(&twice).is_err());
    }

    /// The satellite fuzz harness: a seeded byte-level mutator over
    /// valid frame streams. Whatever the mutation — bit flips,
    /// truncations, or a length prefix lying anywhere up to (and past)
    /// `MAX_FRAME` — the reader must always terminate with a typed
    /// error, a clean close, or a (possibly garbage but well-framed)
    /// frame. Never a panic, never a busy loop.
    #[test]
    fn mutated_byte_streams_always_yield_typed_errors_or_clean_close() {
        forall(0xB17F, 512, |rng: &mut Rng| {
            // a couple of honest frames to mutate
            let mut bytes = frame_bytes(&ClientFrame::Request(random_request(rng)).to_json());
            bytes.extend(frame_bytes(&random_server_frame(rng).to_json()));
            bytes.extend(frame_bytes(&ClientFrame::Ping.to_json()));
            let mutations = rng.below(6) + 1;
            for _ in 0..mutations {
                if bytes.is_empty() {
                    break;
                }
                match rng.below(3) {
                    // bit flip anywhere (header or body)
                    0 => {
                        let i = rng.below(bytes.len() as u64) as usize;
                        bytes[i] ^= 1 << rng.below(8);
                    }
                    // truncation
                    1 => {
                        let keep = rng.below(bytes.len() as u64 + 1) as usize;
                        bytes.truncate(keep);
                    }
                    // length-prefix lie: rewrite a 4-byte window with a
                    // claimed length anywhere up to just past MAX_FRAME
                    _ => {
                        let lie = rng.below(MAX_FRAME as u64 + 2) as u32;
                        let i = rng.below(bytes.len().saturating_sub(3).max(1) as u64) as usize;
                        let end = (i + 4).min(bytes.len());
                        bytes[i..end].copy_from_slice(&lie.to_be_bytes()[..end - i]);
                    }
                }
            }
            (bytes, rng.below(2) == 0)
        }, |(bytes, trickle)| {
            let run = |mut poll: Box<dyn FnMut() -> Result<Option<Json>, FrameError>>| {
                // a finite stream yields at most len/4 well-formed
                // headers plus errors; 4 × frames + slack bounds any
                // non-busy-looping reader. `Ok(None)` can only come
                // from WouldBlock/TimedOut, which a Cursor never
                // returns — seeing it would itself be a bug.
                let budget = bytes.len() / 4 + 16;
                for _ in 0..budget {
                    match poll() {
                        Ok(Some(_)) => {}                          // a surviving frame
                        Ok(None) => return false,                  // impossible on EOF streams
                        Err(FrameError::Closed) => return true,    // clean close
                        Err(FrameError::Truncated) => return true, // typed, terminal
                        Err(FrameError::Io(_)) => return true,     // typed, terminal
                        // survivable: the reader must keep going and
                        // still terminate within budget
                        Err(FrameError::Oversized { .. }) | Err(FrameError::Malformed(_)) => {}
                    }
                }
                false // never terminated: busy loop
            };
            let whole = {
                let mut rd = FrameReader::new(Cursor::new(bytes.clone()), MAX_FRAME);
                run(Box::new(move || rd.poll_frame()))
            };
            if !*trickle {
                return whole;
            }
            // the same stream delivered one byte at a time must settle
            // identically-typed (state machine is split-invariant)
            let dribble = {
                let mut rd = FrameReader::new(Trickle(Cursor::new(bytes.clone())), MAX_FRAME);
                run(Box::new(move || rd.poll_frame()))
            };
            whole && dribble
        });
    }

    #[test]
    fn bad_requests_decode_to_typed_errors() {
        // wrong arity for blend
        let req = Request {
            id: 1,
            job: Job::Denoise { image: Tensor::scalar(1) },
            quality: Quality::Balanced,
            deadline_ms: None,
        };
        let mut j = req.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("app".to_string(), Json::Str("blend".to_string()));
            o.insert("alpha".to_string(), Json::Num(64.0));
        }
        let e = ClientFrame::from_json(&j).unwrap_err();
        assert!(format!("{e}").contains("input tensors"), "{e}");
        // unknown quality
        let mut j = req.to_json();
        if let Json::Obj(o) = &mut j {
            o.insert("quality".to_string(), Json::Str("ultra".to_string()));
        }
        assert!(ClientFrame::from_json(&j).is_err());
        // unknown frame type
        let j = Json::obj(vec![("type", Json::Str("gossip".to_string()))]);
        assert!(ClientFrame::from_json(&j).is_err());
    }
}
