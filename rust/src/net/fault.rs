//! Deterministic fault injection for cluster connections.
//!
//! The cluster test harness needs to ask "what happens to forwarded
//! traffic when the wire misbehaves?" without depending on timing luck.
//! A [`FaultPolicy`] is a small ordered rule table a test installs on a
//! [`crate::net::cluster::Cluster`]; every **outbound** cluster
//! connection (forward or health probe) consults it at connect time and
//! gets a [`FaultAction`]:
//!
//! - `Delay(d)` — the connection works, but its first write stalls `d`
//!   (one stall per connection = one per forwarded request, since the
//!   cluster opens a fresh link per forward; the deadline-budget tests
//!   use it to burn the forward hop's budget).
//! - `Drop` — the connect fails immediately with a refused-style error
//!   (models a dead peer before SYN).
//! - `Truncate(n)` — the connection delivers `n` bytes and is then
//!   severed mid-frame (models a crash between header and body).
//! - `BlackHole` — the connect "succeeds" but writes go nowhere and
//!   reads time out forever (models a partitioned peer: no RST, no
//!   data; only probe/read timeouts can detect it).
//!
//! Rules match on a peer-address substring and carry a use budget and a
//! seeded probability, so a test can say "the first 2 connections to
//! 127.0.0.1:4501 black-hole, everything else is clean" and get exactly
//! that on every run. With probability 1.0 (the default) the policy is
//! fully deterministic; fractional probabilities draw from the policy's
//! own seeded [`Rng`], so a run is reproducible for a fixed seed and
//! connect order.

use crate::util::prng::Rng;
use std::io::{self, Read, Write};
use std::net::{Shutdown, TcpStream};
use std::sync::Mutex;
use std::time::Duration;

/// What to do to one matched connection.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FaultAction {
    /// Stall every frame write by this much.
    Delay(Duration),
    /// Refuse the connection outright.
    Drop,
    /// Deliver this many bytes (writes), then sever the connection.
    Truncate(usize),
    /// Accept writes into the void and never produce a byte back.
    BlackHole,
}

struct Rule {
    /// Substring of the peer address this rule applies to ("" = all).
    peer: String,
    action: FaultAction,
    /// Connections left for this rule (`usize::MAX` = unlimited).
    remaining: usize,
    /// Chance the rule fires on a matched connection, 0.0..=1.0.
    probability: f64,
}

/// An ordered, seeded fault-rule table. First matching rule with budget
/// left wins; unmatched connections pass through untouched.
pub struct FaultPolicy {
    inner: Mutex<PolicyState>,
}

struct PolicyState {
    rules: Vec<Rule>,
    rng: Rng,
    injected: u64,
}

impl FaultPolicy {
    /// An empty policy (every connection clean) drawing probability
    /// coins from `seed`.
    pub fn new(seed: u64) -> FaultPolicy {
        FaultPolicy { inner: Mutex::new(PolicyState { rules: Vec::new(), rng: Rng::new(seed), injected: 0 }) }
    }

    /// Apply `action` to every connection whose peer address contains
    /// `peer` (empty string matches all), without a use limit.
    pub fn rule(self, peer: &str, action: FaultAction) -> FaultPolicy {
        self.rule_n(peer, action, usize::MAX)
    }

    /// Like [`FaultPolicy::rule`], but the rule expires after `n`
    /// matched connections (later connections fall through to the next
    /// rule, or run clean).
    pub fn rule_n(self, peer: &str, action: FaultAction, n: usize) -> FaultPolicy {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).rules.push(Rule {
            peer: peer.to_string(),
            action,
            remaining: n,
            probability: 1.0,
        });
        self
    }

    /// Like [`FaultPolicy::rule`], but the rule only fires with
    /// probability `p` per matched connection (seeded: same seed, same
    /// connect order, same outcome).
    pub fn rule_p(self, peer: &str, action: FaultAction, p: f64) -> FaultPolicy {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).rules.push(Rule {
            peer: peer.to_string(),
            action,
            remaining: usize::MAX,
            probability: p.clamp(0.0, 1.0),
        });
        self
    }

    /// Decide the fate of one outbound connection to `peer`.
    pub fn decide(&self, peer: &str) -> Option<FaultAction> {
        let mut st = self.inner.lock().unwrap_or_else(|e| e.into_inner());
        for i in 0..st.rules.len() {
            if st.rules[i].remaining == 0 || !peer.contains(st.rules[i].peer.as_str()) {
                continue;
            }
            if st.rules[i].probability < 1.0 {
                let coin = st.rng.below(1 << 24) as f64 / (1u64 << 24) as f64;
                if coin >= st.rules[i].probability {
                    continue;
                }
            }
            if st.rules[i].remaining != usize::MAX {
                st.rules[i].remaining -= 1;
            }
            st.injected += 1;
            return Some(st.rules[i].action);
        }
        None
    }

    /// How many connections a rule has been applied to so far.
    pub fn injected(&self) -> u64 {
        self.inner.lock().unwrap_or_else(|e| e.into_inner()).injected
    }
}

/// A cluster-side connection with a [`FaultAction`] applied. Created by
/// [`FaultedStream::connect`]; behaves like a `TcpStream` for the clean
/// and `Delay` cases and emulates the failure for the rest.
pub enum FaultedStream {
    Real {
        stream: TcpStream,
        /// Per-write stall, if any.
        delay: Option<Duration>,
        /// Bytes still deliverable before the connection severs.
        truncate_left: Option<usize>,
    },
    /// Writes vanish; reads time out forever (after `poll` per call, so
    /// a reader with a deadline can give up instead of spinning).
    BlackHole { poll: Duration },
}

impl FaultedStream {
    /// Connect to `addr` under `policy` (pass `None` for a clean
    /// production connection). `timeout` bounds the TCP connect;
    /// `poll` is the simulated read-timeout cadence of a black hole.
    pub fn connect(
        addr: &str,
        policy: Option<&FaultPolicy>,
        timeout: Duration,
        poll: Duration,
    ) -> io::Result<FaultedStream> {
        let action = policy.and_then(|p| p.decide(addr));
        if action == Some(FaultAction::Drop) {
            return Err(io::Error::new(
                io::ErrorKind::ConnectionRefused,
                format!("fault injection: connection to {addr} dropped"),
            ));
        }
        if action == Some(FaultAction::BlackHole) {
            // no real socket at all: the peer never sees this "connection"
            return Ok(FaultedStream::BlackHole { poll });
        }
        let sock_addr = addr
            .parse::<std::net::SocketAddr>()
            .map_err(|e| io::Error::new(io::ErrorKind::InvalidInput, format!("{addr}: {e}")))?;
        let stream = TcpStream::connect_timeout(&sock_addr, timeout)?;
        let _ = stream.set_nodelay(true);
        Ok(FaultedStream::Real {
            stream,
            delay: match action {
                Some(FaultAction::Delay(d)) => Some(d),
                _ => None,
            },
            truncate_left: match action {
                Some(FaultAction::Truncate(n)) => Some(n),
                _ => None,
            },
        })
    }

    /// Set the read timeout of the underlying socket (no-op for a
    /// black hole, whose reads always time out).
    pub fn set_read_timeout(&self, t: Option<Duration>) -> io::Result<()> {
        match self {
            FaultedStream::Real { stream, .. } => stream.set_read_timeout(t),
            FaultedStream::BlackHole { .. } => Ok(()),
        }
    }
}

impl Write for FaultedStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        match self {
            FaultedStream::Real { stream, delay, truncate_left } => {
                if let Some(d) = delay {
                    std::thread::sleep(*d);
                    // one stall per connection: the cluster opens a
                    // fresh link per forward, so this is one stall per
                    // forwarded request
                    *delay = None;
                }
                if let Some(left) = truncate_left {
                    if *left == 0 {
                        let _ = stream.shutdown(Shutdown::Both);
                        return Err(io::Error::new(
                            io::ErrorKind::BrokenPipe,
                            "fault injection: connection truncated",
                        ));
                    }
                    let n = stream.write(&buf[..buf.len().min(*left)])?;
                    *left -= n;
                    if *left == 0 {
                        let _ = stream.shutdown(Shutdown::Both);
                    }
                    return Ok(n);
                }
                stream.write(buf)
            }
            FaultedStream::BlackHole { .. } => Ok(buf.len()),
        }
    }

    fn flush(&mut self) -> io::Result<()> {
        match self {
            FaultedStream::Real { stream, .. } => stream.flush(),
            FaultedStream::BlackHole { .. } => Ok(()),
        }
    }
}

impl Read for FaultedStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        match self {
            FaultedStream::Real { stream, .. } => stream.read(buf),
            FaultedStream::BlackHole { poll } => {
                std::thread::sleep(*poll);
                Err(io::Error::new(io::ErrorKind::TimedOut, "fault injection: black hole"))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    #[test]
    fn rules_match_in_order_with_budgets() {
        let p = FaultPolicy::new(7)
            .rule_n("127.0.0.1:9999", FaultAction::Drop, 2)
            .rule("", FaultAction::Delay(Duration::from_millis(1)));
        assert_eq!(p.decide("127.0.0.1:9999"), Some(FaultAction::Drop));
        assert_eq!(p.decide("127.0.0.1:9999"), Some(FaultAction::Drop));
        // budget exhausted: falls through to the catch-all
        assert_eq!(p.decide("127.0.0.1:9999"), Some(FaultAction::Delay(Duration::from_millis(1))));
        assert_eq!(p.decide("10.0.0.1:1"), Some(FaultAction::Delay(Duration::from_millis(1))));
        assert_eq!(p.injected(), 4);
    }

    #[test]
    fn seeded_probability_is_reproducible() {
        let run = || {
            let p = FaultPolicy::new(0x5EED).rule_p("", FaultAction::Drop, 0.5);
            (0..64).map(|_| p.decide("x").is_some()).collect::<Vec<bool>>()
        };
        let a = run();
        let b = run();
        assert_eq!(a, b, "same seed, same coin flips");
        let hits = a.iter().filter(|&&h| h).count();
        assert!(hits > 10 && hits < 54, "p=0.5 over 64 draws lands mid-range, got {hits}");
    }

    #[test]
    fn drop_refuses_and_black_hole_times_out() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let p = FaultPolicy::new(1).rule_n(&addr, FaultAction::Drop, 1).rule(&addr, FaultAction::BlackHole);
        let e = FaultedStream::connect(&addr, Some(&p), Duration::from_secs(1), Duration::from_millis(5))
            .unwrap_err();
        assert_eq!(e.kind(), io::ErrorKind::ConnectionRefused);
        let mut bh =
            FaultedStream::connect(&addr, Some(&p), Duration::from_secs(1), Duration::from_millis(5))
                .unwrap();
        assert!(bh.write(b"hello").is_ok(), "black-hole writes are swallowed");
        let mut buf = [0u8; 4];
        assert_eq!(bh.read(&mut buf).unwrap_err().kind(), io::ErrorKind::TimedOut);
    }

    #[test]
    fn truncate_severs_after_the_budget() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let p = FaultPolicy::new(1).rule(&addr, FaultAction::Truncate(3));
        let mut s =
            FaultedStream::connect(&addr, Some(&p), Duration::from_secs(1), Duration::from_millis(5))
                .unwrap();
        let (mut peer, _) = listener.accept().unwrap();
        assert_eq!(s.write(b"abcdef").unwrap(), 3, "only the budget goes through");
        let mut got = [0u8; 8];
        let n = peer.read(&mut got).unwrap();
        assert_eq!(&got[..n], b"abc");
        assert!(s.write(b"more").is_err(), "severed after the budget");
    }

    #[test]
    fn clean_connections_pass_through() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let mut s =
            FaultedStream::connect(&addr, None, Duration::from_secs(1), Duration::from_millis(5))
                .unwrap();
        let (mut peer, _) = listener.accept().unwrap();
        s.write_all(b"ping").unwrap();
        let mut got = [0u8; 4];
        peer.read_exact(&mut got).unwrap();
        assert_eq!(&got, b"ping");
    }
}
