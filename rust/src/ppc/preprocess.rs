//! Preprocessings and value sets — Section II of the paper.
//!
//! A *preprocessing* is the cheap input transform that creates
//! intentional sparsity: [`Preproc::Ds`] (down-sampling, `i → i - (i mod
//! x)`) and [`Preproc::Th`] (thresholding, `i < x → y`), composable and
//! parameterized exactly as `DS_x` / `TH_x^y` in the paper.
//!
//! A [`ValueSet`] tracks which values a signal can actually take — the
//! machinery behind both *natural sparsity* (range analysis of Fig. 3(a))
//! and its *propagation to deeper blocks* (Section II.A): sets flow
//! through adds/shifts/products so inner blocks inherit their care sets.

/// A preprocessing applied to an unsigned fixed-point input.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Preproc {
    /// Identity (conventional path).
    Id,
    /// `DS_x`: map `i` to `i - (i mod x)`; `x` must be a power of two.
    Ds(u32),
    /// `TH_x^y`: map `i < x` to `y`.
    Th { x: u32, y: u32 },
}

impl Preproc {
    /// Apply to a value.
    #[inline]
    pub fn apply(&self, v: u32) -> u32 {
        match *self {
            Preproc::Id => v,
            Preproc::Ds(x) => {
                debug_assert!(x.is_power_of_two());
                v & !(x - 1)
            }
            Preproc::Th { x, y } => {
                if v < x {
                    y
                } else {
                    v
                }
            }
        }
    }

    /// Human-readable name matching the paper's notation.
    pub fn label(&self) -> String {
        match *self {
            Preproc::Id => "none".into(),
            Preproc::Ds(x) => format!("DS{x}"),
            Preproc::Th { x, y } => format!("TH{x}^{y}"),
        }
    }
}

/// A chain of preprocessings (e.g. the paper's `TH_48^48 + DS_32`).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct Chain(pub Vec<Preproc>);

impl Chain {
    pub fn id() -> Chain {
        Chain(Vec::new())
    }
    pub fn of(p: Preproc) -> Chain {
        Chain(vec![p])
    }
    pub fn then(mut self, p: Preproc) -> Chain {
        self.0.push(p);
        self
    }
    #[inline]
    pub fn apply(&self, v: u32) -> u32 {
        self.0.iter().fold(v, |acc, p| p.apply(acc))
    }
    pub fn label(&self) -> String {
        if self.0.is_empty() {
            "none".into()
        } else {
            self.0
                .iter()
                .map(|p| p.label())
                .collect::<Vec<_>>()
                .join("+")
        }
    }
}

/// The set of values a signal can take (bitset over `0..capacity`).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ValueSet {
    bits: Vec<u64>,
    capacity: u32,
}

impl ValueSet {
    pub fn empty(capacity: u32) -> ValueSet {
        ValueSet { bits: vec![0; (capacity as usize).div_ceil(64)], capacity }
    }

    /// Full range `0..2^wl`.
    pub fn full(wl: u32) -> ValueSet {
        let capacity = 1u32 << wl;
        let mut s = ValueSet::empty(capacity);
        for w in s.bits.iter_mut() {
            *w = u64::MAX;
        }
        s.trim();
        s
    }

    pub fn from_values(capacity: u32, values: impl IntoIterator<Item = u32>) -> ValueSet {
        let mut s = ValueSet::empty(capacity);
        for v in values {
            s.insert(v);
        }
        s
    }

    fn trim(&mut self) {
        let cap = self.capacity as usize;
        let last_bits = cap % 64;
        if last_bits != 0 {
            let n = self.bits.len();
            self.bits[n - 1] &= (1u64 << last_bits) - 1;
        }
    }

    pub fn capacity(&self) -> u32 {
        self.capacity
    }

    #[inline]
    pub fn insert(&mut self, v: u32) {
        assert!(v < self.capacity, "value {v} out of range {}", self.capacity);
        self.bits[(v / 64) as usize] |= 1 << (v % 64);
    }

    #[inline]
    pub fn contains(&self, v: u32) -> bool {
        v < self.capacity && (self.bits[(v / 64) as usize] >> (v % 64)) & 1 == 1
    }

    pub fn len(&self) -> u32 {
        self.bits.iter().map(|w| w.count_ones()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.bits.iter().all(|&w| w == 0)
    }

    /// Sparsity = fraction of the range that never occurs.
    pub fn sparsity(&self) -> f64 {
        1.0 - self.len() as f64 / self.capacity as f64
    }

    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        (0..self.capacity).filter(move |&v| self.contains(v))
    }

    /// Image under a preprocessing chain.
    pub fn map_chain(&self, chain: &Chain) -> ValueSet {
        let mut out = ValueSet::empty(self.capacity);
        for v in self.iter() {
            out.insert(chain.apply(v).min(self.capacity - 1));
        }
        out
    }

    /// Minkowski sum (value set of `a + b`), capacity grows to cover it.
    pub fn sum(&self, other: &ValueSet) -> ValueSet {
        let cap = self.capacity + other.capacity - 1;
        let mut out = ValueSet::empty(cap);
        for a in self.iter() {
            for b in other.iter() {
                out.insert(a + b);
            }
        }
        out
    }

    /// Value set of `a * b`.
    pub fn product(&self, other: &ValueSet) -> ValueSet {
        let cap = ((self.capacity as u64 - 1) * (other.capacity as u64 - 1) + 1) as u32;
        let mut out = ValueSet::empty(cap.max(1));
        for a in self.iter() {
            for b in other.iter() {
                out.insert(a * b);
            }
        }
        out
    }

    /// Value set of `v << k` (capacity grows).
    pub fn shl(&self, k: u32) -> ValueSet {
        let cap = ((self.capacity as u64 - 1) << k) + 1;
        let mut out = ValueSet::empty(cap as u32);
        for v in self.iter() {
            out.insert(v << k);
        }
        out
    }

    /// Value set of `v >> k`.
    pub fn shr(&self, k: u32) -> ValueSet {
        let cap = ((self.capacity - 1) >> k) + 1;
        let mut out = ValueSet::empty(cap.max(1));
        for v in self.iter() {
            out.insert(v >> k);
        }
        out
    }

    /// Value set of the low `wl` bits (truncation).
    pub fn truncate(&self, wl: u32) -> ValueSet {
        let cap = 1u32 << wl;
        let mut out = ValueSet::empty(cap);
        for v in self.iter() {
            out.insert(v & (cap - 1));
        }
        out
    }

    /// Histogram of a `u8` sample restricted/normalized — used by the
    /// Fig. 1 regenerator.
    pub fn of_samples(samples: &[u8]) -> ValueSet {
        let mut s = ValueSet::empty(256);
        for &v in samples {
            s.insert(v as u32);
        }
        s
    }
}

/// Normalized 256-bin histogram (Fig. 1 / Figs. 5,7,10 signal views).
pub fn histogram256(samples: impl Iterator<Item = u32>) -> Vec<f64> {
    let mut h = vec![0u64; 256];
    let mut n = 0u64;
    for v in samples {
        h[(v.min(255)) as usize] += 1;
        n += 1;
    }
    if n == 0 {
        return vec![0.0; 256];
    }
    h.into_iter().map(|c| c as f64 / n as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::forall;

    #[test]
    fn ds_matches_definition() {
        // DS_x maps i -> i - (i MOD x)
        forall(1, 2000, |r| (r.below(256) as u32, 1u32 << r.below(6)), |&(v, x)| {
            Preproc::Ds(x).apply(v) == v - (v % x)
        });
    }

    #[test]
    fn ds_idempotent() {
        forall(2, 2000, |r| (r.below(1 << 12) as u32, 1u32 << r.below(8)), |&(v, x)| {
            let p = Preproc::Ds(x);
            p.apply(p.apply(v)) == p.apply(v)
        });
    }

    #[test]
    fn th_matches_definition() {
        let p = Preproc::Th { x: 48, y: 48 };
        assert_eq!(p.apply(0), 48);
        assert_eq!(p.apply(47), 48);
        assert_eq!(p.apply(48), 48);
        assert_eq!(p.apply(49), 49);
        assert_eq!(p.apply(255), 255);
    }

    #[test]
    fn chain_label_and_apply() {
        let c = Chain::of(Preproc::Th { x: 48, y: 48 }).then(Preproc::Ds(32));
        assert_eq!(c.label(), "TH48^48+DS32");
        assert_eq!(c.apply(5), 32); // th -> 48, ds32 -> 32
        assert_eq!(c.apply(100), 96);
    }

    #[test]
    fn ds_reduces_count_by_x() {
        // paper: "applying DS_x decreases the number of values by 1/x"
        for k in 0..6 {
            let x = 1u32 << k;
            let s = ValueSet::full(8).map_chain(&Chain::of(Preproc::Ds(x)));
            assert_eq!(s.len(), 256 / x);
        }
    }

    #[test]
    fn th_sparsity_matches_eq6_factor() {
        // TH_x leaves (2^WL - x + 1) values (y = x maps into the kept range)
        let s = ValueSet::full(8).map_chain(&Chain::of(Preproc::Th { x: 48, y: 48 }));
        assert_eq!(s.len(), 256 - 48);
        let s0 = ValueSet::full(8).map_chain(&Chain::of(Preproc::Th { x: 48, y: 0 }));
        assert_eq!(s0.len(), 256 - 48 + 1);
    }

    #[test]
    fn value_set_ops() {
        let a = ValueSet::from_values(4, [0, 2]);
        let b = ValueSet::from_values(4, [1, 3]);
        let s = a.sum(&b);
        assert_eq!(s.iter().collect::<Vec<_>>(), vec![1, 3, 5]);
        let p = a.product(&b);
        assert_eq!(p.iter().collect::<Vec<_>>(), vec![0, 2, 6]);
        let sh = a.shl(2);
        assert_eq!(sh.iter().collect::<Vec<_>>(), vec![0, 8]);
        assert_eq!(sh.shr(2), ValueSet::from_values(sh.shr(2).capacity(), [0, 2]));
    }

    #[test]
    fn truncate_wraps() {
        let a = ValueSet::from_values(1 << 10, [255, 256, 511, 513]);
        let t = a.truncate(8);
        assert_eq!(t.iter().collect::<Vec<_>>(), vec![0, 1, 255]);
    }

    #[test]
    fn sparsity_value() {
        let half = ValueSet::from_values(256, 0..128u32);
        assert!((half.sparsity() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn histogram_normalized() {
        let h = histogram256([0u32, 0, 1, 3].into_iter());
        assert!((h.iter().sum::<f64>() - 1.0).abs() < 1e-12);
        assert!((h[0] - 0.5).abs() < 1e-12);
    }
}
