//! Executable synthesized arithmetic units.
//!
//! [`super::flow`] synthesizes composite PPC blocks but only keeps their
//! *reports*; this module keeps the mapped netlists themselves and wires
//! them into runnable adders and multipliers:
//!
//! - [`AdderUnit`] — the segmented (ripple-of-4-bit-slices) PPC adder of
//!   supplementary Fig. 3, each segment a mapped netlist, the carry
//!   chain stitched in software (zero-cost wiring in hardware).
//! - [`MultUnit8`] — the composed 8×8 PPC multiplier of supplementary
//!   Fig. 2: four 4×4 quadrant netlists plus the adder tree
//!   `LL + ((LH + HL) << 4) + (HH << 8)`.
//!
//! Every unit offers two evaluation paths: a one-pair scalar walk
//! ([`AdderUnit::eval_scalar`]) and the lane-batched bit-parallel path
//! ([`AdderUnit::eval_batch`]): each netlist is lowered once at
//! construction to a levelized instruction tape
//! ([`crate::logic::compiled::CompiledNetlist`]) that evaluates
//! [`crate::catalog::LANES`] operand pairs per pass — the hot path of
//! exhaustive verification and of the native serving backend
//! ([`crate::runtime::NativeExecutor`]). Batches of ≤ 64 pairs run the
//! tape at the narrow `u64` word so small batches don't pay for lanes
//! they don't fill.
//!
//! Units are exact **on their care sets only**: operands must come from
//! the value sets the unit was synthesized with (for a serving backend
//! that means "preprocess first, then multiply/add" — exactly the
//! paper's datapath order). Off the care set the output is unspecified
//! but **deterministic** — every backend (interpreted netlist walk,
//! compiled tape, LUT) realizes the same logic network and therefore
//! agrees bit-for-bit on every input, care or don't-care (the don't-care
//! contract; see [`super::lut`]).
//!
//! Each unit additionally carries an optional word-level LUT backend
//! ([`super::lut`]): when active, `eval_batch`/`add_many`/`mul_many`
//! serve table lookups instead of tape passes. `add_many`/`mul_many`
//! also split large batches across [`crate::util::pool::batch_threads`]
//! threads, [`LANES`]-aligned so the pass structure (and the bits) are
//! identical at any thread count.

use super::blocks::{self, SEG_BITS};
use super::lut::{self, PairLut, SegmentedLut, UnitBackend, UnitKind};
use super::preprocess::ValueSet;
use crate::catalog::LANES;
use crate::logic::compiled::{unpack_lanes_w, CompiledNetlist, LaneWord};
use crate::logic::map::Objective;
use crate::logic::netlist::Netlist;
use crate::logic::synth::{self, BlockSpec};
use crate::util::pool;

/// Where a unit obtains the mapped netlist for a block spec: fresh
/// synthesis ([`FreshSynth`]) or a persistent on-disk cache
/// ([`crate::runtime::NetlistCache`]). `unit` scopes the spec name —
/// segment/quadrant names repeat across units (every adder has a
/// `ppa_seg0`), so cache keys are `(unit, spec.name)` pairs.
///
/// Whatever the source returns is re-verified against the spec's care
/// set by the unit constructors, so a stale or corrupt cached netlist
/// can never serve wrong bits.
pub trait NetlistSource {
    fn netlist(&self, unit: &str, spec: &BlockSpec, objective: Objective) -> Netlist;
}

/// The default source: always run the full two-level → multi-level →
/// tech-map flow.
pub struct FreshSynth;

impl NetlistSource for FreshSynth {
    fn netlist(&self, _unit: &str, spec: &BlockSpec, objective: Objective) -> Netlist {
        synth::synthesize(spec, objective).1
    }
}

/// A batched arithmetic operation over two unsigned operands — the
/// interface [`crate::ppc::error::exhaustive_unit`] measures against.
pub trait BatchOp: Sync {
    /// Evaluate up to [`LANES`] operand pairs bit-parallel into
    /// `out[..a.len()]`.
    fn batch(&self, a: &[u32], b: &[u32], out: &mut [u64]);
    /// Evaluate one pair through the scalar netlist walk (the baseline
    /// the `native_exec` bench compares the bit-parallel path against).
    fn scalar(&self, a: u32, b: u32) -> u64;
}

/// Pack up to [`LaneWord::BITS`] `u32` operand values into `nlanes` bit
/// lanes (lane `i`, bit `j` = bit `i` of `vals[j]`).
pub fn pack_values_w<W: LaneWord>(vals: &[u32], nlanes: usize) -> Vec<W> {
    debug_assert!(vals.len() <= W::BITS);
    let mut lanes = vec![W::ZERO; nlanes];
    for (j, &v) in vals.iter().enumerate() {
        debug_assert!(nlanes >= 32 || (v >> nlanes) == 0, "operand {v} exceeds {nlanes} bits");
        let (wi, bj) = (j / 64, j % 64);
        for (i, lane) in lanes.iter_mut().enumerate() {
            let w = lane.word(wi) | ((((v as u64) >> i) & 1) << bj);
            lane.set_word(wi, w);
        }
    }
    lanes
}

/// [`pack_values_w`] at the narrow 64-lane word (kept for callers that
/// stay within one machine word).
pub fn pack_values(vals: &[u32], nlanes: usize) -> Vec<u64> {
    pack_values_w::<u64>(vals, nlanes)
}

/// Chunk an arbitrarily long operand stream into ≤ [`LANES`]-lane
/// passes of `eval` — the one chunking loop behind
/// [`AdderUnit::add_many`] and [`MultUnit8::mul_many`]. With
/// `threads > 1` the [`LANES`]-aligned blocks are split across
/// [`pool::scope_chunks`] workers; alignment keeps the per-pass lane
/// grouping (and therefore the bits) identical at any thread count.
fn eval_many(
    a: &[u32],
    b: &[u32],
    threads: usize,
    eval: impl Fn(&[u32], &[u32], &mut [u64]) + Sync,
) -> Vec<u64> {
    assert_eq!(a.len(), b.len());
    let n = a.len();
    let nblocks = n.div_ceil(LANES);
    let threads = threads.min(nblocks.max(1));
    if threads <= 1 {
        let mut out = vec![0u64; n];
        eval_range(a, b, &eval, &mut out);
        return out;
    }
    pool::scope_chunks(nblocks, threads, |bs, be| {
        let (s, e) = (bs * LANES, (be * LANES).min(n));
        let mut out = vec![0u64; e - s];
        eval_range(&a[s..e], &b[s..e], &eval, &mut out);
        out
    })
    .concat()
}

/// The serial ≤ [`LANES`]-per-pass loop over one contiguous range.
fn eval_range(
    a: &[u32],
    b: &[u32],
    eval: &(impl Fn(&[u32], &[u32], &mut [u64]) + Sync),
    out: &mut [u64],
) {
    let mut buf = [0u64; LANES];
    let mut i = 0;
    while i < a.len() {
        let end = (i + LANES).min(a.len());
        eval(&a[i..end], &b[i..end], &mut buf);
        out[i..end].copy_from_slice(&buf[..end - i]);
        i = end;
    }
}

/// Resize a lane vector, asserting (in debug) that no nonzero lane is
/// dropped — lanes past a value's width must be all-zero wiring.
fn pad_lanes<W: LaneWord>(lanes: &[W], n: usize) -> Vec<W> {
    let mut out = vec![W::ZERO; n];
    let k = lanes.len().min(n);
    out[..k].copy_from_slice(&lanes[..k]);
    debug_assert!(lanes[k..].iter().all(|&l| l == W::ZERO), "nonzero lane dropped by pad");
    out
}

/// A segmented PPC adder: `ceil(max(wl_a, wl_b) / 4)` synthesized 4-bit
/// slices with carry-in, exact on the `(a_set, b_set)` product it was
/// synthesized for.
pub struct AdderUnit {
    pub name: String,
    pub wl_a: u32,
    pub wl_b: u32,
    segs: Vec<Netlist>,
    /// One compiled tape per segment, lowered at construction — what
    /// the lane-batched paths run on the tape backend (and the oracle
    /// the LUT backend is swept from).
    tapes: Vec<CompiledNetlist>,
    /// Word-level per-segment lookup tables; when present,
    /// [`AdderUnit::eval_batch`] serves lookups instead of tape passes.
    lut: Option<SegmentedLut>,
}

impl AdderUnit {
    /// Run the full design flow on every segment (care sets propagated
    /// along the carry chain from the operand value sets) and keep the
    /// mapped netlists. Panics if any segment fails care-set
    /// verification — a synthesized unit must be exact by construction.
    pub fn synthesize(
        name: &str,
        wl_a: u32,
        wl_b: u32,
        a_set: &ValueSet,
        b_set: &ValueSet,
        objective: Objective,
    ) -> AdderUnit {
        AdderUnit::synthesize_via(name, wl_a, wl_b, a_set, b_set, objective, &FreshSynth)
    }

    /// Like [`AdderUnit::synthesize`], but netlists come from `source`
    /// (fresh synthesis or the persistent cache). Every netlist is
    /// verified on the segment's care set regardless of where it came
    /// from.
    pub fn synthesize_via(
        name: &str,
        wl_a: u32,
        wl_b: u32,
        a_set: &ValueSet,
        b_set: &ValueSet,
        objective: Objective,
        source: &dyn NetlistSource,
    ) -> AdderUnit {
        let specs = blocks::adder_segment_specs(wl_a, wl_b, a_set, b_set);
        let segs: Vec<Netlist> = specs
            .iter()
            .map(|spec| {
                let nl = source.netlist(name, spec, objective);
                assert_eq!(
                    synth::verify_on_care_set(spec, &nl),
                    0,
                    "{name}/{}: netlist not exact on care set",
                    spec.name
                );
                nl
            })
            .collect();
        let tapes = segs.iter().map(CompiledNetlist::from_netlist).collect();
        let mut unit = AdderUnit { name: name.to_string(), wl_a, wl_b, segs, tapes, lut: None };
        unit.apply_backend(lut::unit_backend());
        unit
    }

    /// (Re)resolve the execution backend: `Tape` drops any table, `Lut`
    /// always builds one, `Auto` applies the width heuristic plus the
    /// one-shot per-kind calibration microbench.
    pub fn apply_backend(&mut self, backend: UnitBackend) {
        self.lut = match backend {
            UnitBackend::Tape => None,
            UnitBackend::Lut => Some(self.build_lut()),
            UnitBackend::Auto => self.auto_lut(),
        };
    }

    /// Which backend batches run: `"lut"` or `"tape"`.
    pub fn backend_name(&self) -> &'static str {
        if self.lut.is_some() {
            "lut"
        } else {
            "tape"
        }
    }

    fn build_lut(&self) -> SegmentedLut {
        SegmentedLut::from_tapes(&self.tapes, SEG_BITS)
    }

    fn auto_lut(&self) -> Option<SegmentedLut> {
        // width heuristic: the per-segment table space (2·SEG_BITS+1
        // input bits) must stay under the ceiling
        if 2 * SEG_BITS as usize + 1 > lut::MAX_TABLE_BITS {
            return None;
        }
        // skip building a candidate the microbench already rejected
        if lut::cached_verdict(UnitKind::Adder) == Some(false) {
            return None;
        }
        let cand = self.build_lut();
        let mask = (1u32 << self.lane_width().min(16)) - 1;
        let a: Vec<u32> = (0..LANES as u32).map(|i| (i * 17 + 3) & mask).collect();
        let b: Vec<u32> = (0..LANES as u32).map(|i| (i * 11 + 7) & mask).collect();
        let wins = lut::calibrate(
            UnitKind::Adder,
            || {
                let mut out = [0u64; LANES];
                self.eval_batch_tape(&a, &b, &mut out);
                std::hint::black_box(&out);
            },
            || {
                let mut out = [0u64; LANES];
                for (j, o) in out.iter_mut().enumerate() {
                    *o = cand.eval(a[j], b[j]);
                }
                std::hint::black_box(&out);
            },
        );
        wins.then_some(cand)
    }

    /// Operand width in lanes (`num_segments × 4`); the sum adds one
    /// carry lane on top.
    pub fn lane_width(&self) -> usize {
        self.segs.len() * SEG_BITS as usize
    }

    /// Total gate count across segments.
    pub fn num_gates(&self) -> usize {
        self.segs.iter().map(|s| s.gates.len()).sum()
    }

    /// Lane-level bit-parallel sum: `a_lanes`/`b_lanes` hold
    /// [`AdderUnit::lane_width`] lanes each (operand bit `i` in lane
    /// `i`, upper lanes zero); returns `lane_width() + 1` sum lanes.
    /// Generic over the lane word: 64 patterns per pass at `u64`, 256
    /// at `[u64; 4]`.
    pub fn eval_lanes<W: LaneWord>(&self, a_lanes: &[W], b_lanes: &[W]) -> Vec<W> {
        let sb = SEG_BITS as usize;
        debug_assert_eq!(a_lanes.len(), self.lane_width());
        debug_assert_eq!(b_lanes.len(), self.lane_width());
        let mut sum = vec![W::ZERO; self.lane_width() + 1];
        let mut carry = W::ZERO;
        let mut in_lanes = vec![W::ZERO; 2 * sb + 1];
        let mut slots = Vec::new();
        let mut outs = vec![W::ZERO; sb + 1];
        for (s, tape) in self.tapes.iter().enumerate() {
            in_lanes[..sb].copy_from_slice(&a_lanes[s * sb..(s + 1) * sb]);
            in_lanes[sb..2 * sb].copy_from_slice(&b_lanes[s * sb..(s + 1) * sb]);
            in_lanes[2 * sb] = carry;
            tape.eval_into(&in_lanes, &mut slots, &mut outs);
            sum[s * sb..(s + 1) * sb].copy_from_slice(&outs[..sb]);
            carry = outs[sb];
        }
        let w = self.lane_width();
        sum[w] = carry;
        sum
    }

    /// Bit-parallel sum of up to [`LANES`] operand pairs, dispatched to
    /// the active backend: word-level table lookups when the LUT is
    /// resident, otherwise tape passes (batches of ≤ 64 run the narrow
    /// `u64` word; wider ones the `[u64; 4]` word).
    pub fn eval_batch(&self, a: &[u32], b: &[u32], out: &mut [u64]) {
        let n = a.len();
        // hard contract: lane capacity is LANES (a wider batch would
        // silently wrap the pack shift in release builds)
        assert!(n <= LANES && b.len() == n && out.len() >= n);
        if let Some(l) = &self.lut {
            for (j, o) in out[..n].iter_mut().enumerate() {
                *o = l.eval(a[j], b[j]);
            }
            return;
        }
        self.eval_batch_tape(a, b, out);
    }

    /// The compiled-tape batch path (always available; the oracle the
    /// LUT is swept from).
    fn eval_batch_tape(&self, a: &[u32], b: &[u32], out: &mut [u64]) {
        let n = a.len();
        if n <= 64 {
            let al = pack_values_w::<u64>(a, self.lane_width());
            let bl = pack_values_w::<u64>(b, self.lane_width());
            let sum = self.eval_lanes(&al, &bl);
            out[..n].copy_from_slice(&unpack_lanes_w(&sum, n));
        } else {
            let al = pack_values_w::<[u64; 4]>(a, self.lane_width());
            let bl = pack_values_w::<[u64; 4]>(b, self.lane_width());
            let sum = self.eval_lanes(&al, &bl);
            out[..n].copy_from_slice(&unpack_lanes_w(&sum, n));
        }
    }

    /// Sum arbitrarily many operand pairs, [`LANES`] lanes per pass —
    /// the batch entry point the lane-batched serving path pools
    /// requests through (only the single global tail chunk runs with
    /// idle lanes). Large batches split across
    /// [`pool::batch_threads`] workers.
    pub fn add_many(&self, a: &[u32], b: &[u32]) -> Vec<u64> {
        self.add_many_threads(a, b, pool::batch_threads())
    }

    /// [`AdderUnit::add_many`] with an explicit thread count — callers
    /// already running inside a parallel region pass `1` to avoid
    /// nested parallelism.
    pub fn add_many_threads(&self, a: &[u32], b: &[u32], threads: usize) -> Vec<u64> {
        eval_many(a, b, threads, |x, y, out| self.eval_batch(x, y, out))
    }

    /// One sum through the scalar netlist walk.
    pub fn eval_scalar(&self, a: u32, b: u32) -> u64 {
        let sb = SEG_BITS;
        let seg_mask = (1u64 << sb) - 1;
        let mut sum = 0u64;
        let mut carry = 0u64;
        for (s, seg) in self.segs.iter().enumerate() {
            let sh = s as u32 * sb;
            let m = (((a as u64) >> sh) & seg_mask)
                | ((((b as u64) >> sh) & seg_mask) << sb)
                | (carry << (2 * sb));
            let o = seg.eval(m);
            sum |= (o & seg_mask) << sh;
            carry = (o >> sb) & 1;
        }
        sum | (carry << (self.segs.len() as u32 * sb))
    }
}

impl BatchOp for AdderUnit {
    fn batch(&self, a: &[u32], b: &[u32], out: &mut [u64]) {
        self.eval_batch(a, b, out)
    }
    fn scalar(&self, a: u32, b: u32) -> u64 {
        self.eval_scalar(a, b)
    }
}

/// The composed 8×8 PPC multiplier: four 4×4 quadrant netlists plus the
/// supplementary-Fig. 2 adder tree, exact on `a_set × b_set`.
pub struct MultUnit8 {
    pub name: String,
    /// Quadrant netlists in LL, LH, HL, HH order (inputs: the a-nibble
    /// in bits 0..4, the b-nibble in bits 4..8).
    quads: Vec<Netlist>,
    /// Compiled quadrant tapes, lowered at construction.
    qtapes: Vec<CompiledNetlist>,
    a1: AdderUnit, // LH + HL
    a2: AdderUnit, // (mid << 4) + LL
    a3: AdderUnit, // (HH << 8) + lo
    /// Whole-unit 64Ki × u16 product table; when present,
    /// [`MultUnit8::eval_batch`] serves one lookup per pair.
    lut: Option<PairLut>,
}

impl MultUnit8 {
    /// Synthesize the quadrants and adder tree with care sets propagated
    /// from the operand value sets (mirrors
    /// [`super::flow::composed_mult8`], but keeps the netlists).
    pub fn synthesize(
        name: &str,
        a_set: &ValueSet,
        b_set: &ValueSet,
        objective: Objective,
    ) -> MultUnit8 {
        MultUnit8::synthesize_via(name, a_set, b_set, objective, &FreshSynth)
    }

    /// Like [`MultUnit8::synthesize`], but netlists come from `source`
    /// (fresh synthesis or the persistent cache); every quadrant and
    /// tree-adder segment is verified on its care set either way.
    pub fn synthesize_via(
        name: &str,
        a_set: &ValueSet,
        b_set: &ValueSet,
        objective: Objective,
        source: &dyn NetlistSource,
    ) -> MultUnit8 {
        let q = blocks::mult_quadrant_specs(a_set, b_set);
        let quads: Vec<Netlist> = q
            .quads
            .iter()
            .map(|spec| {
                let nl = source.netlist(name, spec, objective);
                assert_eq!(
                    synth::verify_on_care_set(spec, &nl),
                    0,
                    "{name}/{}: netlist not exact on care set",
                    spec.name
                );
                nl
            })
            .collect();
        let (ll, lh, hl, hh) = (
            &q.quad_out_sets[0],
            &q.quad_out_sets[1],
            &q.quad_out_sets[2],
            &q.quad_out_sets[3],
        );
        let mid = lh.sum(hl);
        let a1 =
            AdderUnit::synthesize_via(&format!("{name}_a1"), 8, 8, lh, hl, objective, source);
        let mid_shift = mid.shl(4);
        let a2 = AdderUnit::synthesize_via(
            &format!("{name}_a2"),
            13,
            8,
            &mid_shift,
            ll,
            objective,
            source,
        );
        let lo = mid_shift.sum(ll);
        let hh_shift = hh.shl(8);
        let a3 = AdderUnit::synthesize_via(
            &format!("{name}_a3"),
            16,
            14,
            &hh_shift,
            &lo,
            objective,
            source,
        );
        let qtapes = quads.iter().map(CompiledNetlist::from_netlist).collect();
        let mut unit = MultUnit8 { name: name.to_string(), quads, qtapes, a1, a2, a3, lut: None };
        unit.apply_backend(lut::unit_backend());
        unit
    }

    /// (Re)resolve the execution backend (see
    /// [`AdderUnit::apply_backend`]).
    pub fn apply_backend(&mut self, backend: UnitBackend) {
        self.lut = match backend {
            UnitBackend::Tape => None,
            UnitBackend::Lut => Some(self.build_lut()),
            UnitBackend::Auto => self.auto_lut(),
        };
    }

    /// Which backend batches run: `"lut"` or `"tape"`.
    pub fn backend_name(&self) -> &'static str {
        if self.lut.is_some() {
            "lut"
        } else {
            "tape"
        }
    }

    /// Sweep the whole unit's 16-bit operand-pair space through the
    /// tape path ([`LANES`] pairs per pass) into one product table —
    /// don't-care pairs included, so the table agrees with the tape
    /// everywhere.
    fn build_lut(&self) -> PairLut {
        let mut table = vec![0u16; 1 << 16];
        let bvals: Vec<u32> = (0..256).collect();
        let mut out = [0u64; LANES];
        for a in 0..256u32 {
            let avals = [a; 256];
            let mut j = 0usize;
            while j < 256 {
                let end = (j + LANES).min(256);
                self.eval_batch_tape(&avals[j..end], &bvals[j..end], &mut out);
                for (k, &p) in out[..end - j].iter().enumerate() {
                    table[((a as usize) << 8) | (j + k)] = p as u16;
                }
                j = end;
            }
        }
        PairLut::new(table)
    }

    fn auto_lut(&self) -> Option<PairLut> {
        // width heuristic: the pair table's 16 input bits must stay
        // under the ceiling
        if 16 > lut::MAX_TABLE_BITS {
            return None;
        }
        if lut::cached_verdict(UnitKind::Mult) == Some(false) {
            return None;
        }
        let cand = self.build_lut();
        let a: Vec<u32> = (0..LANES as u32).map(|i| (i * 29 + 5) & 0xff).collect();
        let b: Vec<u32> = (0..LANES as u32).map(|i| (i * 13 + 11) & 0xff).collect();
        let wins = lut::calibrate(
            UnitKind::Mult,
            || {
                let mut out = [0u64; LANES];
                self.eval_batch_tape(&a, &b, &mut out);
                std::hint::black_box(&out);
            },
            || {
                let mut out = [0u64; LANES];
                for (j, o) in out.iter_mut().enumerate() {
                    *o = cand.eval(a[j], b[j]);
                }
                std::hint::black_box(&out);
            },
        );
        wins.then_some(cand)
    }

    /// Total gate count (quadrants + adder tree).
    pub fn num_gates(&self) -> usize {
        self.quads.iter().map(|n| n.gates.len()).sum::<usize>()
            + self.a1.num_gates()
            + self.a2.num_gates()
            + self.a3.num_gates()
    }

    /// Lane-level bit-parallel product: 8 operand lanes each side,
    /// 16 product lanes back. Generic over the lane word like
    /// [`AdderUnit::eval_lanes`].
    pub fn eval_lanes<W: LaneWord>(&self, a_lanes: &[W], b_lanes: &[W]) -> Vec<W> {
        debug_assert_eq!(a_lanes.len(), 8);
        debug_assert_eq!(b_lanes.len(), 8);
        // quadrant products: (a half, b half) per LL, LH, HL, HH
        let pairs = [(0usize, 0usize), (0, 4), (4, 0), (4, 4)];
        let mut qin = [W::ZERO; 8];
        let mut qouts: Vec<Vec<W>> = Vec::with_capacity(4);
        for (k, &(ai, bi)) in pairs.iter().enumerate() {
            qin[..4].copy_from_slice(&a_lanes[ai..ai + 4]);
            qin[4..].copy_from_slice(&b_lanes[bi..bi + 4]);
            qouts.push(self.qtapes[k].eval(&qin));
        }
        // mid = LH + HL (9 bits)
        let w1 = self.a1.lane_width();
        let mid = self.a1.eval_lanes(&pad_lanes(&qouts[1], w1), &pad_lanes(&qouts[2], w1));
        // lo = (mid << 4) + LL (13 bits)
        let w2 = self.a2.lane_width();
        let mut mid_shift = vec![W::ZERO; w2];
        mid_shift[4..4 + mid.len()].copy_from_slice(&mid);
        let lo = self.a2.eval_lanes(&mid_shift, &pad_lanes(&qouts[0], w2));
        // product = (HH << 8) + lo (16 bits)
        let w3 = self.a3.lane_width();
        let mut hh_shift = vec![W::ZERO; w3];
        hh_shift[8..16].copy_from_slice(&qouts[3]);
        let prod = self.a3.eval_lanes(&hh_shift, &pad_lanes(&lo, w3));
        prod[..16].to_vec()
    }

    /// Bit-parallel product of up to [`LANES`] operand pairs,
    /// dispatched to the active backend: one table lookup per pair when
    /// the LUT is resident, otherwise tape passes (≤ 64 run the narrow
    /// `u64` word; wider batches the `[u64; 4]` word).
    pub fn eval_batch(&self, a: &[u32], b: &[u32], out: &mut [u64]) {
        let n = a.len();
        // hard contract: lane capacity is LANES (see AdderUnit::eval_batch)
        assert!(n <= LANES && b.len() == n && out.len() >= n);
        if let Some(l) = &self.lut {
            for (j, o) in out[..n].iter_mut().enumerate() {
                *o = l.eval(a[j], b[j]);
            }
            return;
        }
        self.eval_batch_tape(a, b, out);
    }

    /// The compiled-tape batch path (always available; the oracle the
    /// LUT is swept from).
    fn eval_batch_tape(&self, a: &[u32], b: &[u32], out: &mut [u64]) {
        let n = a.len();
        if n <= 64 {
            let al = pack_values_w::<u64>(a, 8);
            let bl = pack_values_w::<u64>(b, 8);
            let prod = self.eval_lanes(&al, &bl);
            out[..n].copy_from_slice(&unpack_lanes_w(&prod, n));
        } else {
            let al = pack_values_w::<[u64; 4]>(a, 8);
            let bl = pack_values_w::<[u64; 4]>(b, 8);
            let prod = self.eval_lanes(&al, &bl);
            out[..n].copy_from_slice(&unpack_lanes_w(&prod, n));
        }
    }

    /// Multiply arbitrarily many operand pairs, [`LANES`] lanes per
    /// pass — the batch entry point the lane-batched serving path
    /// pools requests through. Large batches split across
    /// [`pool::batch_threads`] workers.
    pub fn mul_many(&self, a: &[u32], b: &[u32]) -> Vec<u64> {
        self.mul_many_threads(a, b, pool::batch_threads())
    }

    /// [`MultUnit8::mul_many`] with an explicit thread count — callers
    /// already running inside a parallel region pass `1` to avoid
    /// nested parallelism.
    pub fn mul_many_threads(&self, a: &[u32], b: &[u32], threads: usize) -> Vec<u64> {
        eval_many(a, b, threads, |x, y, out| self.eval_batch(x, y, out))
    }

    /// One product through the scalar netlist walk.
    pub fn eval_scalar(&self, a: u32, b: u32) -> u64 {
        debug_assert!(a < 256 && b < 256);
        let (al, ah) = ((a & 15) as u64, (a >> 4) as u64);
        let (bl, bh) = ((b & 15) as u64, (b >> 4) as u64);
        let q = |k: usize, x: u64, y: u64| self.quads[k].eval(x | (y << 4));
        let ll = q(0, al, bl);
        let lh = q(1, al, bh);
        let hl = q(2, ah, bl);
        let hh = q(3, ah, bh);
        let mid = self.a1.eval_scalar(lh as u32, hl as u32);
        let lo = self.a2.eval_scalar((mid as u32) << 4, ll as u32);
        self.a3.eval_scalar((hh as u32) << 8, lo as u32)
    }
}

impl BatchOp for MultUnit8 {
    fn batch(&self, a: &[u32], b: &[u32], out: &mut [u64]) {
        self.eval_batch(a, b, out)
    }
    fn scalar(&self, a: u32, b: u32) -> u64 {
        self.eval_scalar(a, b)
    }
}

/// Aggregate several units' backend names for display: the common name
/// when uniform (`"lut"`/`"tape"`), `"mixed"` otherwise — how an app
/// hardware built from several units reports itself in `--list-models`.
pub fn combined_backend<'a>(names: impl IntoIterator<Item = &'a str>) -> &'static str {
    let mut it = names.into_iter();
    let Some(first) = it.next() else {
        return "-";
    };
    let uniform = it.all(|n| n == first);
    match (uniform, first) {
        (true, "lut") => "lut",
        (true, "tape") => "tape",
        (true, _) => "-",
        (false, _) => "mixed",
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ppc::error;
    use crate::ppc::preprocess::{Chain, Preproc};

    fn ds(x: u32) -> Chain {
        Chain::of(Preproc::Ds(x))
    }

    #[test]
    fn adder_unit_exact_on_care_set() {
        let set = ValueSet::full(8).map_chain(&ds(16));
        let unit = AdderUnit::synthesize("add8_ds16", 8, 8, &set, &set, Objective::Area);
        for a in set.iter() {
            for b in set.iter() {
                assert_eq!(unit.eval_scalar(a, b), (a + b) as u64, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn adder_unit_batch_matches_scalar() {
        let set = ValueSet::full(8).map_chain(&ds(8));
        let unit = AdderUnit::synthesize("add8_ds8", 8, 8, &set, &set, Objective::Area);
        let vals: Vec<u32> = set.iter().collect();
        let a: Vec<u32> = (0..64).map(|i| vals[i % vals.len()]).collect();
        let b: Vec<u32> = (0..64).map(|i| vals[(i * 7 + 3) % vals.len()]).collect();
        let mut out = [0u64; 64];
        unit.eval_batch(&a, &b, &mut out);
        for j in 0..64 {
            assert_eq!(out[j], unit.eval_scalar(a[j], b[j]), "j={j}");
            assert_eq!(out[j], (a[j] + b[j]) as u64);
        }
    }

    #[test]
    fn adder_unit_wide_batch_matches_scalar() {
        // a single eval_batch past 64 pairs runs the [u64; 4] word —
        // check it against the scalar walk lane by lane
        let set = ValueSet::full(8).map_chain(&ds(8));
        let unit = AdderUnit::synthesize("add8_wide", 8, 8, &set, &set, Objective::Area);
        let vals: Vec<u32> = set.iter().collect();
        let n = 200usize;
        let a: Vec<u32> = (0..n).map(|i| vals[i % vals.len()]).collect();
        let b: Vec<u32> = (0..n).map(|i| vals[(i * 13 + 2) % vals.len()]).collect();
        let mut out = vec![0u64; n];
        unit.eval_batch(&a, &b, &mut out);
        for j in 0..n {
            assert_eq!(out[j], unit.eval_scalar(a[j], b[j]), "j={j}");
            assert_eq!(out[j], (a[j] + b[j]) as u64);
        }
    }

    #[test]
    fn mult_unit_exact_on_care_set() {
        let set = ValueSet::full(8).map_chain(&ds(16));
        let unit = MultUnit8::synthesize("mul8_ds16", &set, &set, Objective::Area);
        for a in set.iter() {
            for b in set.iter() {
                assert_eq!(unit.eval_scalar(a, b), (a as u64) * (b as u64), "a={a} b={b}");
            }
        }
    }

    #[test]
    fn mult_unit_batch_matches_scalar() {
        let a_set = ValueSet::full(8).map_chain(&ds(32));
        let b_set = ValueSet::from_values(256, 0..128u32).map_chain(&ds(16));
        let unit = MultUnit8::synthesize("mul8_mix", &a_set, &b_set, Objective::Area);
        let av: Vec<u32> = a_set.iter().collect();
        let bv: Vec<u32> = b_set.iter().collect();
        let a: Vec<u32> = (0..60).map(|i| av[i % av.len()]).collect();
        let b: Vec<u32> = (0..60).map(|i| bv[(i * 5 + 1) % bv.len()]).collect();
        let mut out = [0u64; 64];
        unit.eval_batch(&a, &b, &mut out);
        for j in 0..60 {
            assert_eq!(out[j], (a[j] as u64) * (b[j] as u64), "j={j}");
        }
    }

    #[test]
    fn add_many_matches_scalar_past_the_lane_boundary() {
        let set = ValueSet::full(8).map_chain(&ds(16));
        let unit = AdderUnit::synthesize("add8_many", 8, 8, &set, &set, Objective::Area);
        let vals: Vec<u32> = set.iter().collect();
        // 0, 1, the u64-word boundary, the full 256-lane word, and
        // straddles of both
        for n in [0usize, 1, 63, 64, 65, 150, 255, 256, 257, 300] {
            let a: Vec<u32> = (0..n).map(|i| vals[i % vals.len()]).collect();
            let b: Vec<u32> = (0..n).map(|i| vals[(i * 11 + 5) % vals.len()]).collect();
            let out = unit.add_many(&a, &b);
            assert_eq!(out.len(), n);
            for j in 0..n {
                assert_eq!(out[j], unit.eval_scalar(a[j], b[j]), "n={n} j={j}");
                assert_eq!(out[j], (a[j] + b[j]) as u64);
            }
        }
    }

    #[test]
    fn mul_many_matches_scalar_past_the_lane_boundary() {
        let set = ValueSet::full(8).map_chain(&ds(32));
        let unit = MultUnit8::synthesize("mul8_many", &set, &set, Objective::Area);
        let vals: Vec<u32> = set.iter().collect();
        for n in [1usize, 64, 65, 130, 255, 256, 257] {
            let a: Vec<u32> = (0..n).map(|i| vals[i % vals.len()]).collect();
            let b: Vec<u32> = (0..n).map(|i| vals[(i * 3 + 1) % vals.len()]).collect();
            let out = unit.mul_many(&a, &b);
            for j in 0..n {
                assert_eq!(out[j], (a[j] as u64) * (b[j] as u64), "n={n} j={j}");
            }
        }
    }

    /// The chains behind every registered serving config (`ds16`,
    /// `ds32`, `th48+ds16` — `conv` serves the full value set, which
    /// `ds16`'s domain superset covers at unit level).
    fn registered_chains() -> Vec<(&'static str, Chain)> {
        vec![
            ("ds16", ds(16)),
            ("ds32", ds(32)),
            ("th48ds16", Chain::of(Preproc::Th { x: 48, y: 48 }).then(Preproc::Ds(16))),
        ]
    }

    #[test]
    fn adder_lut_tape_and_interpreted_agree_on_every_input() {
        // The don't-care contract: off the care set the output is
        // unspecified but deterministic — netlist walk, tape, and LUT
        // realize the same logic network, so all three must agree
        // bit-for-bit on EVERY 8-bit pair, care or not. Exhaustive.
        for (label, chain) in registered_chains() {
            let set = ValueSet::full(8).map_chain(&chain);
            let name = format!("pt_add_{label}");
            let mut unit = AdderUnit::synthesize(&name, 8, 8, &set, &set, Objective::Area);
            let all: Vec<u32> = (0..256u32).collect();
            let mut pairs_a = Vec::with_capacity(1 << 16);
            let mut pairs_b = Vec::with_capacity(1 << 16);
            for &a in &all {
                for &b in &all {
                    pairs_a.push(a);
                    pairs_b.push(b);
                }
            }
            unit.apply_backend(UnitBackend::Tape);
            assert_eq!(unit.backend_name(), "tape");
            let tape = unit.add_many_threads(&pairs_a, &pairs_b, 1);
            unit.apply_backend(UnitBackend::Lut);
            assert_eq!(unit.backend_name(), "lut");
            let lut = unit.add_many_threads(&pairs_a, &pairs_b, 1);
            for j in 0..pairs_a.len() {
                let interp = unit.eval_scalar(pairs_a[j], pairs_b[j]);
                assert_eq!(tape[j], interp, "{label} tape a={} b={}", pairs_a[j], pairs_b[j]);
                assert_eq!(lut[j], interp, "{label} lut a={} b={}", pairs_a[j], pairs_b[j]);
            }
            // and on the care set all of them are the exact sum
            for a in set.iter() {
                for b in set.iter() {
                    assert_eq!(unit.eval_scalar(a, b), (a + b) as u64, "{label} a={a} b={b}");
                }
            }
        }
    }

    #[test]
    fn mult_lut_tape_and_interpreted_agree_on_and_off_the_care_set() {
        // Same three-way agreement for the composed multiplier: the
        // full care-set product exhaustively, plus pseudorandom
        // off-care-set pairs over the whole 8×8 operand space.
        for (label, chain) in registered_chains() {
            let set = ValueSet::full(8).map_chain(&chain);
            let name = format!("pt_mul_{label}");
            let mut unit = MultUnit8::synthesize(&name, &set, &set, Objective::Area);
            let care: Vec<u32> = set.iter().collect();
            let mut pairs_a: Vec<u32> = Vec::new();
            let mut pairs_b: Vec<u32> = Vec::new();
            for &a in &care {
                for &b in &care {
                    pairs_a.push(a);
                    pairs_b.push(b);
                }
            }
            // xorshift off-care samples (deterministic seed)
            let mut s = 0x9e3779b97f4a7c15u64 ^ (label.len() as u64);
            for _ in 0..2048 {
                s ^= s << 13;
                s ^= s >> 7;
                s ^= s << 17;
                pairs_a.push((s & 0xff) as u32);
                pairs_b.push(((s >> 8) & 0xff) as u32);
            }
            unit.apply_backend(UnitBackend::Tape);
            let tape = unit.mul_many_threads(&pairs_a, &pairs_b, 1);
            unit.apply_backend(UnitBackend::Lut);
            let lut = unit.mul_many_threads(&pairs_a, &pairs_b, 1);
            for j in 0..pairs_a.len() {
                let interp = unit.eval_scalar(pairs_a[j], pairs_b[j]);
                assert_eq!(tape[j], interp, "{label} tape a={} b={}", pairs_a[j], pairs_b[j]);
                assert_eq!(lut[j], interp, "{label} lut a={} b={}", pairs_a[j], pairs_b[j]);
            }
            // care-set pairs are the exact product on every backend
            for j in 0..care.len() * care.len() {
                let (a, b) = (pairs_a[j], pairs_b[j]);
                assert_eq!(lut[j], (a as u64) * (b as u64), "{label} a={a} b={b}");
            }
        }
    }

    #[test]
    fn batch_entry_points_bit_exact_at_1_and_4_threads() {
        let _guard = pool::batch_threads_test_lock();
        let set = ValueSet::full(8).map_chain(&ds(16));
        let add = AdderUnit::synthesize("pt_add_thr", 8, 8, &set, &set, Objective::Area);
        let mul = MultUnit8::synthesize("pt_mul_thr", &set, &set, Objective::Area);
        let vals: Vec<u32> = set.iter().collect();
        // crosses several 256-lane blocks with a ragged tail
        let n = 1029usize;
        let a: Vec<u32> = (0..n).map(|i| vals[i % vals.len()]).collect();
        let b: Vec<u32> = (0..n).map(|i| vals[(i * 7 + 3) % vals.len()]).collect();
        let mut sums = Vec::new();
        let mut prods = Vec::new();
        for t in [1usize, 4] {
            pool::set_batch_threads(t);
            sums.push(add.add_many(&a, &b));
            prods.push(mul.mul_many(&a, &b));
        }
        pool::set_batch_threads(0);
        assert_eq!(sums[0], sums[1]);
        assert_eq!(prods[0], prods[1]);
        for j in 0..n {
            assert_eq!(sums[0][j], (a[j] + b[j]) as u64, "j={j}");
            assert_eq!(prods[0][j], (a[j] as u64) * (b[j] as u64), "j={j}");
        }
    }

    #[test]
    fn exhaustive_unit_matches_error_model() {
        // hardware (netlists, bit-parallel) and model (value maps) must
        // report the *same* PE/ME/MAE — eq. (4)/(5) end to end.
        let chain = ds(16);
        let set = ValueSet::full(8).map_chain(&chain);
        let unit = MultUnit8::synthesize("mul8_err", &set, &set, Objective::Area);
        let hw = error::exhaustive_unit(8, &unit, &chain, &chain, |a, b| a as i64 * b as i64);
        let model = error::exhaustive_mult(8, &chain, &chain);
        assert!((hw.pe - model.pe).abs() < 1e-12, "{} vs {}", hw.pe, model.pe);
        assert!((hw.me - model.me).abs() < 1e-9);
        assert!((hw.mae - model.mae).abs() < 1e-9);
        let closed = error::ds_mult(8, 16);
        assert!((hw.pe - closed.pe).abs() < 1e-12);
    }
}
