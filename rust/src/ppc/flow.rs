//! The PPC design flow (paper Fig. 3) and composite block reports.
//!
//! [`synth_block`] runs one incompletely-specified block through the
//! whole pipeline (two-level → factoring → AIG → tech map → verify →
//! area/delay/power). [`segmented_adder`] and [`composed_mult8`]
//! assemble the paper's scalable structures (supplementary Figs. 2–3):
//! adders cascaded from 4-bit segments and the 8×8 multiplier from four
//! 4×4 quadrants plus an adder tree, with care sets propagated through
//! the structure via value sets.

use super::blocks;
use super::preprocess::ValueSet;
use crate::logic::espresso::Options;
use crate::logic::map::Objective;
use crate::logic::netlist::Netlist;
use crate::logic::synth::{self, BlockSpec};
use crate::util::prng::Rng;

/// Number of vectors for switching-power simulation.
pub const POWER_VECTORS: usize = 4000;

/// Physical + two-level report for one block or composite.
#[derive(Clone, Debug, Default)]
pub struct BlockReport {
    pub name: String,
    /// Two-level literal count (paper "# of literals").
    pub literals: u64,
    pub area_ge: f64,
    pub delay_ns: f64,
    pub power_uw: f64,
    /// Fraction of TT rows that are DC (eq. 1/6 quantity); composites
    /// report the care-weighted mean of their parts.
    pub dc_fraction: f64,
    /// Verification mismatches on the care set (must be 0).
    pub verify_errors: u64,
}

impl BlockReport {
    fn accumulate(&mut self, other: &BlockReport) {
        self.literals += other.literals;
        self.area_ge += other.area_ge;
        self.power_uw += other.power_uw;
        self.verify_errors += other.verify_errors;
    }
}

/// Synthesized block: report + netlist (kept for composition/simulation).
pub struct SynthBlock {
    pub report: BlockReport,
    pub netlist: Netlist,
    pub spec: BlockSpec,
}

/// Run the full Fig. 3 pipeline on one block spec. `sample_care` draws
/// input minterms for power simulation (pass the application's input
/// distribution; defaults to uniform-over-care via [`care_sampler`]).
pub fn synth_block(spec: BlockSpec, objective: Objective) -> SynthBlock {
    let (two, nl) = synth::synthesize(&spec, objective);
    let verify_errors = synth::verify_on_care_set(&spec, &nl);
    let sampler = care_sampler(&spec);
    let power = nl.power_uw(POWER_VECTORS, sampler);
    SynthBlock {
        report: BlockReport {
            name: spec.name.clone(),
            literals: two.literals,
            area_ge: nl.area_ge(),
            delay_ns: nl.delay_ns(),
            power_uw: power,
            dc_fraction: spec.dc_fraction(),
            verify_errors,
        },
        netlist: nl,
        spec,
    }
}

/// Uniform sampler over a spec's care rows.
pub fn care_sampler(spec: &BlockSpec) -> impl FnMut(&mut Rng) -> u64 {
    let rows: Vec<u64> = (0..(1u64 << spec.nvars))
        .filter(|&m| spec.care.get(m))
        .collect();
    move |rng: &mut Rng| {
        if rows.is_empty() {
            0
        } else {
            rows[rng.below(rows.len() as u64) as usize]
        }
    }
}

/// A segmented (ripple-of-4-bit-slices) PPC adder: synthesizes each
/// segment with its propagated care set and combines the reports.
/// Delay composes along the carry chain (sum of segment delays).
pub fn segmented_adder(
    name: &str,
    wl_a: u32,
    wl_b: u32,
    a_set: &ValueSet,
    b_set: &ValueSet,
    objective: Objective,
) -> BlockReport {
    let specs = blocks::adder_segment_specs(wl_a, wl_b, a_set, b_set);
    let mut out = BlockReport { name: name.to_string(), ..Default::default() };
    let mut delay = 0.0;
    let mut dc_sum = 0.0;
    let n = specs.len();
    for spec in specs {
        let sb = synth_block(spec, objective);
        out.accumulate(&sb.report);
        delay += sb.report.delay_ns; // ripple chain
        dc_sum += sb.report.dc_fraction;
    }
    out.delay_ns = delay;
    out.dc_fraction = dc_sum / n as f64;
    out
}

/// Conventional (precise, library-style) adder: structural ripple AIG,
/// mapped directly — the baseline rows of the paper's tables.
pub fn conventional_adder(
    name: &str,
    wl_a: u32,
    wl_b: u32,
    objective: Objective,
) -> BlockReport {
    let g = blocks::ripple_adder_aig(wl_a, wl_b);
    structural_report(name, &g, wl_a + wl_b, objective)
}

/// Conventional array multiplier (full product width).
pub fn conventional_mult(
    name: &str,
    wl_a: u32,
    wl_b: u32,
    objective: Objective,
) -> BlockReport {
    let g = blocks::array_multiplier_aig(wl_a, wl_b);
    structural_report(name, &g, wl_a + wl_b, objective)
}

fn structural_report(name: &str, g: &crate::logic::aig::Aig, nvars: u32, objective: Objective) -> BlockReport {
    let nl = crate::logic::map::map_aig(g, &crate::logic::library::cells90(), objective);
    let mask = if nvars >= 64 { u64::MAX } else { (1u64 << nvars) - 1 };
    let power = nl.power_uw(POWER_VECTORS, move |r| r.next_u64() & mask);
    BlockReport {
        name: name.to_string(),
        literals: 0, // structural path has no two-level form
        area_ge: nl.area_ge(),
        delay_ns: nl.delay_ns(),
        power_uw: power,
        dc_fraction: 0.0,
        verify_errors: 0,
    }
}

/// Composed 8×8 PPC multiplier (supplementary Fig. 2): four 4×4
/// quadrants + adder tree, care sets propagated via value sets.
///
/// `sum = LL + ((LH + HL) << 4) + (HH << 8)`
pub fn composed_mult8(
    name: &str,
    a_set: &ValueSet,
    b_set: &ValueSet,
    objective: Objective,
) -> BlockReport {
    let q = blocks::mult_quadrant_specs(a_set, b_set);
    let mut out = BlockReport { name: name.to_string(), ..Default::default() };
    let mut quad_delay: f64 = 0.0;
    let mut dc_sum = 0.0;
    for spec in q.quads {
        let sb = synth_block(spec, objective);
        out.accumulate(&sb.report);
        quad_delay = quad_delay.max(sb.report.delay_ns);
        dc_sum += sb.report.dc_fraction;
    }
    // adder tree on propagated value sets
    let lh = &q.quad_out_sets[1];
    let hl = &q.quad_out_sets[2];
    let ll = &q.quad_out_sets[0];
    let hh = &q.quad_out_sets[3];
    let mid = lh.sum(hl); // LH + HL: 9 bits
    let a1 = segmented_adder("mul8_a1", 8, 8, lh, hl, objective);
    // LL + (mid << 4): 13 bits
    let mid_shift = mid.shl(4);
    let a2 = segmented_adder("mul8_a2", 13, 8, &mid_shift, ll, objective);
    let lo = mid_shift.sum(ll);
    // + (HH << 8): 16 bits
    let hh_shift = hh.shl(8);
    let a3 = segmented_adder("mul8_a3", 16, 14, &hh_shift, &lo, objective);
    out.accumulate(&a1);
    out.accumulate(&a2);
    out.accumulate(&a3);
    out.delay_ns = quad_delay + a1.delay_ns + a2.delay_ns + a3.delay_ns;
    out.dc_fraction = (dc_sum + a1.dc_fraction + a2.dc_fraction + a3.dc_fraction) / 7.0;
    // the flat two-level literal count is the paper's metric for
    // multipliers; quadrant literals already accumulated are the
    // composed-structure count. Callers wanting the flat count use
    // [`flat_mult_literals`].
    out
}

/// Flat (16-input) two-level literal count for an 8×8 PPM — the paper's
/// two-level metric for the IB/FRNN multipliers.
pub fn flat_mult_literals(a_set: &ValueSet, b_set: &ValueSet) -> u64 {
    let spec = blocks::ppm_flat_spec(8, 8, a_set, b_set);
    synth::two_level(&spec, Options::default()).literals
}

/// Flat two-level literal count for an adder (used for GDF where the
/// paper's scale indicates segment-level counting; see DESIGN.md).
pub fn segmented_adder_literals(wl_a: u32, wl_b: u32, a_set: &ValueSet, b_set: &ValueSet) -> u64 {
    blocks::adder_segment_specs(wl_a, wl_b, a_set, b_set)
        .iter()
        .map(|s| synth::two_level(s, Options::default()).literals)
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ppc::preprocess::{Chain, Preproc};

    #[test]
    fn segmented_adder_full_range() {
        let full = ValueSet::full(8);
        let r = segmented_adder("add8", 8, 8, &full, &full, Objective::Area);
        assert_eq!(r.verify_errors, 0);
        assert!(r.area_ge > 10.0);
        assert!(r.delay_ns > 0.0);
        assert!(r.literals > 100);
    }

    #[test]
    fn ds_shrinks_everything() {
        let full = ValueSet::full(8);
        let ds16 = full.map_chain(&Chain::of(Preproc::Ds(16)));
        let base = segmented_adder("add8", 8, 8, &full, &full, Objective::Area);
        let ppc = segmented_adder("add8ds16", 8, 8, &ds16, &ds16, Objective::Area);
        assert_eq!(ppc.verify_errors, 0);
        assert!(ppc.literals < base.literals);
        assert!(ppc.area_ge < base.area_ge);
        assert!(ppc.power_uw < base.power_uw);
    }

    #[test]
    fn conventional_blocks_report() {
        let a = conventional_adder("conv_add8", 8, 8, Objective::Area);
        assert!(a.area_ge > 10.0 && a.delay_ns > 0.0 && a.power_uw > 0.0);
        let m = conventional_mult("conv_mul4", 4, 4, Objective::Area);
        assert!(m.area_ge > a.area_ge / 2.0);
    }

    #[test]
    fn composed_mult8_sparse_cheaper() {
        let full = ValueSet::full(8);
        let ds32 = full.map_chain(&Chain::of(Preproc::Ds(32)));
        let base = composed_mult8("mul8", &full, &full, Objective::Area);
        assert_eq!(base.verify_errors, 0);
        let ppc = composed_mult8("mul8ds32", &ds32, &ds32, Objective::Area);
        assert_eq!(ppc.verify_errors, 0);
        assert!(ppc.area_ge < base.area_ge * 0.7, "{} !< {}", ppc.area_ge, base.area_ge);
        assert!(ppc.literals < base.literals / 2);
    }

    #[test]
    fn natural_sparsity_free_accuracy_cheaper_block() {
        // IB coefficient input: only half the range occurs naturally
        let full = ValueSet::full(8);
        let half = ValueSet::from_values(256, 0..128u32);
        let base = composed_mult8("mul8", &full, &full, Objective::Area);
        let nat = composed_mult8("mul8nat", &full, &half, Objective::Area);
        assert_eq!(nat.verify_errors, 0);
        assert!(nat.literals < base.literals);
    }
}
