//! The paper's contribution: partially-precise computing.
//!
//! - [`preprocess`] — `DS_x` / `TH_x^y` preprocessings, value sets,
//!   natural-sparsity range analysis (Section II).
//! - [`blocks`] — PPA/PPM truth-table generators with DC sets, and the
//!   conventional structural baselines (Section III + supplementary).
//! - [`error`] — PE/ME/MAE closed forms and exhaustive validation
//!   (eqs. 2–10), including netlist-level validation of synthesized
//!   units (bit-parallel).
//! - [`flow`] — the Fig. 3 design flow: range analysis → preprocessing →
//!   TT+DC → two-level → multi-level → report.
//! - [`units`] — executable synthesized composites (segmented adders,
//!   the composed 8×8 multiplier) with scalar and 256-lane compiled-tape
//!   evaluation; the arithmetic behind the native serving backend.
//! - [`lut`] — the word-level lookup-table backend (function
//!   memoization over a unit's small operand space) plus per-unit
//!   backend selection and calibration.
//!
//! ## Example: the whole paradigm in six lines
//!
//! ```
//! use ppc::ppc::preprocess::{Chain, Preproc, ValueSet};
//! use ppc::ppc::flow;
//! use ppc::logic::map::Objective;
//!
//! let sparse = ValueSet::full(8).map_chain(&Chain::of(Preproc::Ds(16)));
//! let block = flow::segmented_adder("add8", 8, 8, &sparse, &sparse, Objective::Area);
//! assert_eq!(block.verify_errors, 0); // exact on every care input
//! ```

pub mod blocks;
pub mod error;
pub mod flow;
pub mod lut;
pub mod preprocess;
pub mod units;
