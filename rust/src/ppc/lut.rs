//! Word-level lookup-table unit backend (function memoization).
//!
//! A PPC block is precise only on a *predefined* set of input values —
//! the logical software endpoint of that relaxation is to memoize the
//! unit outright: sweep the compiled tape over the unit's small operand
//! space once at construction and serve word-level lookups afterwards,
//! with no bit packing and no per-gate tape walk.
//!
//! Two table shapes cover the synthesized units:
//!
//! - [`SegmentedLut`] — one `2^(2·SEG_BITS+1)`-entry table per adder
//!   segment (4+4 bits + carry-in → 512 entries), the carry chain
//!   stitched in software exactly like `AdderUnit::eval_scalar`.
//! - [`PairLut`] — the whole 8×8 multiplier as one 64Ki × `u16` product
//!   table (≈ 128 KiB).
//!
//! **Don't-care contract.** Off the care set a PPC unit's output is
//! unspecified but *deterministic*: the synthesized netlist, the
//! compiled tape, and the LUT all realize the same logic network, so all
//! three agree bit-for-bit on **every** input, care or don't-care. The
//! tables here are built by sweeping the tape (not by re-deriving the
//! spec), which makes that agreement true by construction; the property
//! tests in `ppc::units` hold it for every registered unit config.
//!
//! Backend choice per unit is [`UnitBackend`]: `Tape` and `Lut` force a
//! path, `Auto` (the default) applies a width heuristic (total table
//! input bits ≤ [`MAX_TABLE_BITS`]) plus a one-shot calibration
//! microbench per unit kind, cached process-wide. `serve --unit-backend`
//! sets the process-global default before any unit is constructed.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::{Duration, Instant};

use crate::logic::compiled::{consecutive_lanes_w, unpack_lanes_w, CompiledNetlist};

/// How a unit evaluates batches: the compiled levelized tape, a
/// precomputed lookup table, or a per-kind calibrated choice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnitBackend {
    /// Width heuristic + one-shot calibration microbench (the default).
    Auto,
    /// Always the compiled SIMD tape (the bit-parallel oracle path).
    Tape,
    /// Always the precomputed lookup table.
    Lut,
}

impl UnitBackend {
    /// Parse a `serve --unit-backend` value.
    pub fn parse(s: &str) -> Option<UnitBackend> {
        match s {
            "auto" => Some(UnitBackend::Auto),
            "tape" => Some(UnitBackend::Tape),
            "lut" => Some(UnitBackend::Lut),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            UnitBackend::Auto => "auto",
            UnitBackend::Tape => "tape",
            UnitBackend::Lut => "lut",
        }
    }
}

/// Width heuristic ceiling: a table is only considered when its total
/// input space is at most `2^MAX_TABLE_BITS` entries (the 8×8 multiplier
/// pair table, 64Ki × u16 ≈ 128 KiB, is the intended maximum).
pub const MAX_TABLE_BITS: usize = 16;

static BACKEND: AtomicU8 = AtomicU8::new(0); // 0=Auto 1=Tape 2=Lut

/// Set the process-global backend default consulted by unit
/// constructors (`serve --unit-backend`). Call before building
/// executors; already-built units are unaffected (use the units'
/// `apply_backend` to rebuild).
pub fn set_unit_backend(b: UnitBackend) {
    let v = match b {
        UnitBackend::Auto => 0,
        UnitBackend::Tape => 1,
        UnitBackend::Lut => 2,
    };
    BACKEND.store(v, Ordering::Relaxed);
}

/// The process-global backend default.
pub fn unit_backend() -> UnitBackend {
    match BACKEND.load(Ordering::Relaxed) {
        1 => UnitBackend::Tape,
        2 => UnitBackend::Lut,
        _ => UnitBackend::Auto,
    }
}

/// Unit kinds calibrated independently — an adder's 512-entry segment
/// tables and a multiplier's 64Ki pair table have very different cache
/// behavior, so one verdict per kind.
#[derive(Debug, Clone, Copy)]
pub enum UnitKind {
    Adder,
    Mult,
}

fn verdict_cell(kind: UnitKind) -> &'static OnceLock<bool> {
    static ADDER: OnceLock<bool> = OnceLock::new();
    static MULT: OnceLock<bool> = OnceLock::new();
    match kind {
        UnitKind::Adder => &ADDER,
        UnitKind::Mult => &MULT,
    }
}

/// The cached calibration verdict for `kind`, if one exists — lets a
/// constructor skip building a candidate table the microbench already
/// rejected.
pub fn cached_verdict(kind: UnitKind) -> Option<bool> {
    verdict_cell(kind).get().copied()
}

/// One-shot calibration: time `tape_run` against `lut_run` (alternating,
/// best of three each) and cache "LUT wins" per unit kind for the life
/// of the process. Both closures should evaluate the same microbatch.
pub fn calibrate(kind: UnitKind, mut tape_run: impl FnMut(), mut lut_run: impl FnMut()) -> bool {
    *verdict_cell(kind).get_or_init(|| {
        fn best(f: &mut dyn FnMut(), reps: usize) -> Duration {
            let mut b = Duration::MAX;
            for _ in 0..reps {
                let t0 = Instant::now();
                f();
                b = b.min(t0.elapsed());
            }
            b
        }
        // warm both paths once (page in the table, fill the icache)
        tape_run();
        lut_run();
        let t = best(&mut tape_run, 3);
        let l = best(&mut lut_run, 3);
        l <= t
    })
}

/// Sweep a compiled tape over its full `2^bits` input space and return
/// the output word per minterm — the table builder. Runs the wide
/// `[u64; 4]` word, 256 minterms per pass.
pub fn sweep_tape(tape: &CompiledNetlist, bits: usize) -> Vec<u64> {
    assert!(bits <= MAX_TABLE_BITS, "table sweep over 2^{bits} inputs exceeds the width ceiling");
    const W: usize = 256; // <[u64; 4] as LaneWord>::BITS
    let total = 1usize << bits;
    let mut out = Vec::with_capacity(total);
    let mut base = 0usize;
    while base < total {
        let count = (total - base).min(W);
        let in_lanes = consecutive_lanes_w::<[u64; 4]>(base as u64, bits);
        let outs = tape.eval(&in_lanes);
        out.extend(unpack_lanes_w(&outs, count));
        base += count;
    }
    out
}

/// Per-segment tables for a segmented (ripple-of-slices) adder. Entry
/// `m` of table `s` is segment `s`'s full output word (`seg_bits` sum
/// bits, then the carry-out bit) for the 2·`seg_bits`+1-bit minterm
/// `a_slice | b_slice << seg_bits | carry_in << 2·seg_bits` — the same
/// layout `AdderUnit::eval_scalar` walks, so [`SegmentedLut::eval`]
/// stitches the carry chain identically.
pub struct SegmentedLut {
    seg_bits: u32,
    tables: Vec<Vec<u8>>,
}

impl SegmentedLut {
    /// Build by sweeping each segment's compiled tape over its full
    /// input space (care *and* don't-care minterms — see the module
    /// docs for why both must match).
    pub fn from_tapes(tapes: &[CompiledNetlist], seg_bits: u32) -> SegmentedLut {
        assert!(seg_bits + 1 <= 8, "segment output must fit a u8 table entry");
        let bits = 2 * seg_bits as usize + 1;
        let tables = tapes
            .iter()
            .map(|t| sweep_tape(t, bits).into_iter().map(|v| v as u8).collect())
            .collect();
        SegmentedLut { seg_bits, tables }
    }

    /// One sum via table lookups, carry stitched across segments.
    #[inline]
    pub fn eval(&self, a: u32, b: u32) -> u64 {
        let sb = self.seg_bits;
        let seg_mask = (1u64 << sb) - 1;
        let mut sum = 0u64;
        let mut carry = 0usize;
        for (s, t) in self.tables.iter().enumerate() {
            let sh = s as u32 * sb;
            let m = (((a as u64 >> sh) & seg_mask) as usize)
                | ((((b as u64 >> sh) & seg_mask) as usize) << sb)
                | (carry << (2 * sb));
            let o = t[m] as u64;
            sum |= (o & seg_mask) << sh;
            carry = ((o >> sb) & 1) as usize;
        }
        sum | ((carry as u64) << (self.tables.len() as u32 * self.seg_bits))
    }

    /// Table footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.tables.iter().map(Vec::len).sum()
    }
}

/// A whole-unit product table over two 8-bit operands: 64Ki × `u16`
/// (the 8×8 multiplier's product is at most 16 bits).
pub struct PairLut {
    table: Vec<u16>,
}

impl PairLut {
    /// Wrap a table built by the unit (index `a << 8 | b`).
    pub fn new(table: Vec<u16>) -> PairLut {
        assert_eq!(table.len(), 1 << 16);
        PairLut { table }
    }

    /// One product via a single word-level lookup.
    #[inline]
    pub fn eval(&self, a: u32, b: u32) -> u64 {
        self.table[(((a & 0xff) as usize) << 8) | (b & 0xff) as usize] as u64
    }

    /// Table footprint in bytes.
    pub fn bytes(&self) -> usize {
        self.table.len() * 2
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::library::cells90;
    use crate::logic::netlist::{Driver, Gate, Netlist};

    #[test]
    fn backend_parse_and_name_round_trip() {
        for b in [UnitBackend::Auto, UnitBackend::Tape, UnitBackend::Lut] {
            assert_eq!(UnitBackend::parse(b.name()), Some(b));
        }
        assert_eq!(UnitBackend::parse("simd"), None);
    }

    #[test]
    fn unit_backend_override_round_trips_under_the_shared_guard() {
        // BACKEND is process-global state: hold the shared override
        // lock so this test cannot interleave with anything else that
        // reads or asserts a specific default, then restore.
        let _guard = crate::util::pool::process_override_test_lock();
        let prev = unit_backend();
        for b in [UnitBackend::Tape, UnitBackend::Lut, UnitBackend::Auto] {
            set_unit_backend(b);
            assert_eq!(unit_backend(), b);
        }
        set_unit_backend(prev);
    }

    #[test]
    fn sweep_tape_matches_interpreted_eval_on_every_minterm() {
        // a 9-input netlist (the adder-segment shape)
        let lib = cells90();
        let cell = |n: &str| lib.iter().position(|c| c.name == n).unwrap();
        let (xor2, and2, or2) = (cell("XOR2"), cell("AND2"), cell("OR2"));
        let nl = Netlist {
            lib,
            num_inputs: 9,
            gates: vec![
                Gate { cell: xor2, inputs: vec![Driver::Input(0), Driver::Input(1)] },
                Gate { cell: and2, inputs: vec![Driver::Input(2), Driver::Input(3)] },
                Gate { cell: or2, inputs: vec![Driver::Gate(0), Driver::Gate(1)] },
                Gate { cell: xor2, inputs: vec![Driver::Gate(2), Driver::Input(8)] },
            ],
            outputs: vec![Driver::Gate(3), Driver::Gate(2)],
        };
        let tape = CompiledNetlist::from_netlist(&nl);
        let table = sweep_tape(&tape, 9);
        assert_eq!(table.len(), 512);
        for (m, &got) in table.iter().enumerate() {
            assert_eq!(got, nl.eval(m as u64), "minterm {m}");
        }
    }

    #[test]
    fn calibration_verdict_is_cached_once_per_kind() {
        let mut tape_calls = 0usize;
        let v1 = calibrate(UnitKind::Adder, || tape_calls += 1, || {});
        let before = tape_calls;
        assert!(before > 0 || cached_verdict(UnitKind::Adder).is_some());
        // second call must not re-run the microbench
        let v2 = calibrate(UnitKind::Adder, || tape_calls += 1, || {});
        assert_eq!(v1, v2);
        assert_eq!(tape_calls, before);
        assert_eq!(cached_verdict(UnitKind::Adder), Some(v1));
    }
}
