//! Error analysis of PPC blocks — Section II's PE/ME/MAE metrics.
//!
//! Two independent paths:
//!
//! - [`exhaustive_adder`] / [`exhaustive_mult`]: enumerate the full input
//!   space (uniform distribution, the paper's convention) and measure the
//!   exact Probability of Error, Mean Error and Mean Absolute Error of a
//!   block whose inputs are preprocessed.
//! - Closed forms ([`ds_adder`], [`ds_mult`], [`th_adder`]): derived
//!   analytically. The paper's printed eqs. 3, 5, 7, 8 and 10 contain
//!   typographical corruption (see EXPERIMENTS.md §Equation-notes); the
//!   forms here are re-derived and *verified against the exhaustive
//!   enumeration* by the test suite, with eq. 5 recovering the paper's
//!   own expression once the obvious OCR slip (`2^{2WL-2}` for
//!   `2^{2k-2}`) is undone.
//!
//! Error convention (matching the paper): `E = precise(a, b) −
//! block(preproc(a), preproc(b))`, averaged over uniform raw inputs.

use super::preprocess::Chain;
use super::units::BatchOp;
use crate::util::pool;

/// PE / ME / MAE triple.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct ErrorStats {
    pub pe: f64,
    pub me: f64,
    pub mae: f64,
}

/// Exhaustive stats for a WL-bit adder with both inputs preprocessed.
pub fn exhaustive_adder(wl: u32, pa: &Chain, pb: &Chain) -> ErrorStats {
    exhaustive(wl, pa, pb, |a, b| a as i64 + b as i64)
}

/// Exhaustive stats for a WL-bit multiplier with both inputs preprocessed.
pub fn exhaustive_mult(wl: u32, pa: &Chain, pb: &Chain) -> ErrorStats {
    exhaustive(wl, pa, pb, |a, b| a as i64 * b as i64)
}

fn exhaustive(wl: u32, pa: &Chain, pb: &Chain, f: impl Fn(u32, u32) -> i64 + Sync) -> ErrorStats {
    assert!(wl <= 12, "exhaustive error analysis limited to 2^24 pairs");
    let n = 1u32 << wl;
    // Precompute preprocessed values once per input.
    let amap: Vec<u32> = (0..n).map(|v| pa.apply(v)).collect();
    let bmap: Vec<u32> = (0..n).map(|v| pb.apply(v)).collect();
    let partials = pool::scope_chunks(n as usize, pool::default_threads(), |s, e| {
        let (mut errs, mut sum, mut abs) = (0u64, 0i64, 0i64);
        for a in s as u32..e as u32 {
            for b in 0..n {
                let exact = f(a, b);
                let approx = f(amap[a as usize], bmap[b as usize]);
                let e = exact - approx;
                if e != 0 {
                    errs += 1;
                    sum += e;
                    abs += e.abs();
                }
            }
        }
        (errs, sum, abs)
    });
    let (errs, sum, abs) = partials
        .into_iter()
        .fold((0u64, 0i64, 0i64), |(e1, s1, a1), (e2, s2, a2)| (e1 + e2, s1 + s2, a1 + a2));
    let total = (n as f64) * (n as f64);
    ErrorStats {
        pe: errs as f64 / total,
        me: sum as f64 / total,
        mae: abs as f64 / total,
    }
}

/// Exhaustive PE/ME/MAE of a *synthesized hardware unit* against the
/// precise operation `f` — the netlist-level counterpart of
/// [`exhaustive_adder`] / [`exhaustive_mult`]. Both operands are
/// preprocessed before they reach the unit (the paper's datapath order),
/// and the unit is evaluated bit-parallel,
/// [`crate::catalog::LANES`] operand pairs per pass.
///
/// With a unit that is exact on its care set and synthesized for the
/// preprocessed value sets, this must reproduce the value-map model's
/// numbers bit for bit — the test suite holds the two paths against
/// each other (and against the closed forms).
pub fn exhaustive_unit(
    wl: u32,
    unit: &(impl BatchOp + ?Sized),
    pa: &Chain,
    pb: &Chain,
    f: impl Fn(u32, u32) -> i64 + Sync,
) -> ErrorStats {
    assert!(wl <= 12, "exhaustive error analysis limited to 2^24 pairs");
    let n = 1u32 << wl;
    let amap: Vec<u32> = (0..n).map(|v| pa.apply(v)).collect();
    let bmap: Vec<u32> = (0..n).map(|v| pb.apply(v)).collect();
    let partials = pool::scope_chunks(n as usize, pool::default_threads(), |s, e| {
        let (mut errs, mut sum, mut abs) = (0u64, 0i64, 0i64);
        let mut asplat = [0u32; crate::catalog::LANES];
        let mut outs = [0u64; crate::catalog::LANES];
        for a in s as u32..e as u32 {
            asplat.fill(amap[a as usize]);
            let mut bbase = 0u32;
            while bbase < n {
                let cnt = crate::catalog::LANES.min((n - bbase) as usize);
                unit.batch(
                    &asplat[..cnt],
                    &bmap[bbase as usize..bbase as usize + cnt],
                    &mut outs[..cnt],
                );
                for (j, &approx) in outs[..cnt].iter().enumerate() {
                    let exact = f(a, bbase + j as u32);
                    let e = exact - approx as i64;
                    if e != 0 {
                        errs += 1;
                        sum += e;
                        abs += e.abs();
                    }
                }
                bbase += cnt as u32;
            }
        }
        (errs, sum, abs)
    });
    let (errs, sum, abs) = partials
        .into_iter()
        .fold((0u64, 0i64, 0i64), |(e1, s1, a1), (e2, s2, a2)| (e1 + e2, s1 + s2, a1 + a2));
    let total = (n as f64) * (n as f64);
    ErrorStats {
        pe: errs as f64 / total,
        me: sum as f64 / total,
        mae: abs as f64 / total,
    }
}

// ---------------------------------------------------------------------
// Closed forms
// ---------------------------------------------------------------------

/// Closed form for a WL-bit PPA with `DS_x` on both inputs
/// (`k = log2 x`).
///
/// - `PE = 1 − (1/x)² ` — paper eq. (2), confirmed.
/// - `ME = MAE = x − 1` — the paper's printed eq. (3) is corrupted; the
///   residues `a mod x` and `b mod x` are uniform on `[0, x)`, so the
///   error `(a mod x) + (b mod x)` has mean `2·(x−1)/2 = x−1`.
pub fn ds_adder(_wl: u32, x: u32) -> ErrorStats {
    let xf = x as f64;
    let me = xf - 1.0;
    ErrorStats { pe: 1.0 - 1.0 / (xf * xf), me, mae: me }
}

/// Closed form for a WL-bit PPM with `DS_x` on both inputs.
///
/// - `PE = 1 − (1/x² + 2/2^WL − 2/(x·2^WL))` — paper eq. (4), confirmed
///   (exact results occur iff both residues are 0 or either operand is 0).
/// - `ME = MAE = 2^{WL+k−1} − 2^{WL−1} − 2^{2k−2} + 2^{−2}` — the
///   paper's eq. (5) with the OCR slip `2^{2WL−2} → 2^{2k−2}` undone;
///   equivalently `((x−1)/2)·(2^WL − 1 − (x−1)/2)`.
pub fn ds_mult(wl: u32, x: u32) -> ErrorStats {
    let xf = x as f64;
    let range = (1u64 << wl) as f64;
    let pe = 1.0 - (1.0 / (xf * xf) + 2.0 / range - 2.0 / (xf * range));
    let me = (xf - 1.0) / 2.0 * (range - 1.0 - (xf - 1.0) / 2.0);
    ErrorStats { pe, me, mae: me }
}

/// Closed form for a WL-bit PPA with `TH_x^y` on both inputs, `y ≤ x`.
///
/// Per input, `e(v) = v − y` for `v < x`, else `0`.
/// - `PE = 1 − ((2^WL − x + [y<x]) / 2^WL)²` — the complement of both
///   inputs being exact. (The paper's eq. (7) reads `1 − (x/2^WL)²`,
///   which under a uniform input model inverts the exact-set size; our
///   form is validated exhaustively.)
/// - `ME = 2·x·(x−1−2y) / 2^{WL+1}` (sum of two i.i.d. per-input means).
/// - `MAE` additionally needs `E|e_a + e_b|`, which does not factor when
///   the per-input error changes sign (`0 < y < x−1`); we return the
///   exact value for the paper's configurations `y = 0` and `y = x`
///   (single-signed errors, where `MAE = |ME|`) and `NaN` otherwise —
///   use the exhaustive path for mixed-sign thresholds.
pub fn th_adder(wl: u32, x: u32, y: u32) -> ErrorStats {
    let range = (1u64 << wl) as f64;
    // x beyond the representable range behaves as x = 2^WL
    let x = x.min(1u32 << wl);
    let exact_per_input = (range - x as f64) + if y < x { 1.0 } else { 0.0 };
    let pe = 1.0 - (exact_per_input / range) * (exact_per_input / range);
    // E[e] per input: sum_{v<x} (v - y) / 2^WL
    let sum_e = (0..x).map(|v| v as f64 - y as f64).sum::<f64>();
    let me = 2.0 * sum_e / range;
    let mae = if y == 0 || y >= x.saturating_sub(1) {
        me.abs()
    } else {
        f64::NAN
    };
    ErrorStats { pe, me, mae }
}

/// Closed form PE for a WL-bit PPM with `TH_x^y` on both inputs, `y ≤ x`.
///
/// Exact iff both inputs are individually exact, or one operand's error
/// is annihilated: `a·b = â·b̂` additionally whenever `b = 0 ∧ â·b̂ = 0`
/// etc. For `y > 0` the preprocessed value is never 0, so zeros only
/// help when the *other* operand is 0: `a·0 = â·0 = 0` requires `b̂ = 0`
/// too — false for `y > 0` unless `b ≥ x`. The form below (validated
/// exhaustively) counts: both-exact ∪ (a = 0 ∧ b̂·â = 0)…; for the
/// paper's `y ≥ x` configurations this reduces to
/// `PE = 1 − (q² + 2·q0·(q − q0 + [y=0]·…))`; we implement the two used
/// regimes (`y = 0`, `y ≥ x`) and leave others to the exhaustive path.
pub fn th_mult_pe(wl: u32, x: u32, y: u32) -> f64 {
    let range = (1u64 << wl) as f64;
    let x = x.min(1u32 << wl);
    let q_exact = (range - x as f64) + if y < x { 1.0 } else { 0.0 };
    let q = q_exact / range;
    if y == 0 {
        // With y = 0, an inexact `a < x` maps to â = 0, so the product
        // is still exact exactly when b = 0. Exact pairs:
        //   (a exact ∧ b exact) ∪ (a inexact ∧ b = 0) ∪ (b inexact ∧ a = 0)
        // (the unions are disjoint: 0 is an exact input under y = 0).
        let p_zero = 1.0 / range;
        let p_exact = q * q + 2.0 * p_zero * (1.0 - q);
        1.0 - p_exact
    } else {
        // y ≥ x ≥ 1: preprocessed values never 0; a=0 gives a·b = 0 but
        // â·b̂ = y·b̂ > 0 unless b̂ = 0 (impossible) → a=0 is *always
        // wrong* unless a exact. So exact = both inputs exact.
        1.0 - q * q
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ppc::preprocess::{Chain, Preproc};

    fn ds(x: u32) -> Chain {
        Chain::of(Preproc::Ds(x))
    }
    fn th(x: u32, y: u32) -> Chain {
        Chain::of(Preproc::Th { x, y })
    }

    #[test]
    fn ds_adder_closed_matches_exhaustive() {
        for wl in [4u32, 6, 8] {
            for k in 1..wl.min(6) {
                let x = 1 << k;
                let ex = exhaustive_adder(wl, &ds(x), &ds(x));
                let cf = ds_adder(wl, x);
                assert!((ex.pe - cf.pe).abs() < 1e-12, "PE wl={wl} x={x}: {} vs {}", ex.pe, cf.pe);
                assert!((ex.me - cf.me).abs() < 1e-9, "ME wl={wl} x={x}: {} vs {}", ex.me, cf.me);
                assert!((ex.mae - cf.mae).abs() < 1e-9, "MAE wl={wl} x={x}");
            }
        }
    }

    #[test]
    fn ds_mult_closed_matches_exhaustive() {
        for wl in [4u32, 6, 8] {
            for k in 1..wl.min(6) {
                let x = 1 << k;
                let ex = exhaustive_mult(wl, &ds(x), &ds(x));
                let cf = ds_mult(wl, x);
                assert!((ex.pe - cf.pe).abs() < 1e-12, "PE wl={wl} x={x}: {} vs {}", ex.pe, cf.pe);
                assert!((ex.me - cf.me).abs() < 1e-9, "ME wl={wl} x={x}: {} vs {}", ex.me, cf.me);
                assert!((ex.mae - cf.mae).abs() < 1e-9, "MAE wl={wl} x={x}");
            }
        }
    }

    #[test]
    fn ds_mult_matches_paper_eq5_corrected() {
        // eq. 5 as printed modulo the OCR slip: 2^{WL+k-1} - 2^{WL-1}
        // - 2^{2k-2} + 2^{-2}
        for wl in [6u32, 8] {
            for k in 1..5u32 {
                let x = 1 << k;
                let expect = (2f64).powi((wl + k - 1) as i32) - (2f64).powi((wl - 1) as i32)
                    - (2f64).powi(2 * k as i32 - 2)
                    + 0.25;
                let got = ds_mult(wl, x).me;
                assert!((got - expect).abs() < 1e-9, "wl={wl} k={k}: {got} vs {expect}");
            }
        }
    }

    #[test]
    fn th_adder_closed_matches_exhaustive() {
        for wl in [6u32, 8] {
            for x in [1u32, 16, 48, 100] {
                for y in [0u32, x] {
                    let ex = exhaustive_adder(wl, &th(x, y), &th(x, y));
                    let cf = th_adder(wl, x, y);
                    assert!(
                        (ex.pe - cf.pe).abs() < 1e-12,
                        "PE wl={wl} x={x} y={y}: {} vs {}",
                        ex.pe,
                        cf.pe
                    );
                    assert!((ex.me - cf.me).abs() < 1e-9, "ME wl={wl} x={x} y={y}");
                    assert!((ex.mae - cf.mae).abs() < 1e-9, "MAE wl={wl} x={x} y={y}");
                }
            }
        }
    }

    #[test]
    fn th_mult_pe_matches_exhaustive() {
        for wl in [6u32, 8] {
            for x in [16u32, 48] {
                for y in [0u32, x] {
                    let ex = exhaustive_mult(wl, &th(x, y), &th(x, y));
                    let pe = th_mult_pe(wl, x, y);
                    assert!(
                        (ex.pe - pe).abs() < 1e-12,
                        "wl={wl} x={x} y={y}: {} vs {pe}",
                        ex.pe
                    );
                }
            }
        }
    }

    #[test]
    fn identity_has_zero_error() {
        let ex = exhaustive_adder(8, &Chain::id(), &Chain::id());
        assert_eq!(ex, ErrorStats { pe: 0.0, me: 0.0, mae: 0.0 });
    }

    #[test]
    fn error_grows_with_ds_rate() {
        let mut prev = ErrorStats::default();
        for k in 1..6 {
            let x = 1 << k;
            let e = exhaustive_mult(8, &ds(x), &ds(x));
            assert!(e.pe >= prev.pe && e.mae >= prev.mae, "x={x}");
            prev = e;
        }
    }

    #[test]
    fn composition_th_then_ds() {
        // TH48^48 + DS16 (the paper's row 8 config) has finite stats and
        // errors bounded by the two applied separately... not in general,
        // but PE must be ≥ each individual PE on the multiplier image
        // input side; here we only require sanity: 0 < PE < 1.
        let c = Chain::of(Preproc::Th { x: 48, y: 48 }).then(Preproc::Ds(16));
        let e = exhaustive_mult(8, &c, &c);
        assert!(e.pe > 0.9 && e.pe < 1.0);
        assert!(e.mae > 0.0);
    }
}
