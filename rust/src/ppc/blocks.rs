//! PPC block generators — partially-precise adders (PPA) and multipliers
//! (PPM) — plus the conventional (precise, library-style) structural
//! baselines.
//!
//! Two construction paths, following the paper:
//!
//! 1. **TT + DC path** (the paper's Fig. 3): build the block's truth
//!    table, mark every input combination outside the care set as
//!    don't-care, then run the two-level / multi-level flow. Flat for
//!    multipliers (≤ 16 inputs); adders are composed from 4-bit carry
//!    segments exactly as the paper's supplementary Figs. 2–3 prescribe
//!    (the TT-based process does not scale past ~9 inputs per block).
//! 2. **Structural path** (the "conventional synthesis process"): ripple
//!    adders and array multipliers built directly as AIGs from
//!    full-adder cells — the predesigned-library route that ignores DCs.

use super::preprocess::ValueSet;
use crate::logic::aig::{self, Aig, Edge};
use crate::logic::synth::BlockSpec;

// ---------------------------------------------------------------------
// TT+DC specs
// ---------------------------------------------------------------------

/// Flat adder spec: inputs `a` (low `wl_a` bits) and `b`; outputs the
/// full sum. Care set = `{(a, b) : a ∈ a_set, b ∈ b_set}`.
pub fn ppa_flat_spec(wl_a: u32, wl_b: u32, a_set: &ValueSet, b_set: &ValueSet) -> BlockSpec {
    let nvars = (wl_a + wl_b) as usize;
    let nouts = (wl_a.max(wl_b) + 1) as usize;
    let a_mask = (1u64 << wl_a) - 1;
    let mut spec = BlockSpec::from_fn(
        nvars,
        nouts,
        &format!("ppa{wl_a}x{wl_b}"),
        |m| (m & a_mask) + (m >> wl_a),
        |_| false,
    );
    // fill care from the value-set product (faster than predicate scan)
    for a in a_set.iter() {
        for b in b_set.iter() {
            let m = a as u64 | ((b as u64) << wl_a);
            spec.care.set(m);
            let y = a as u64 + b as u64;
            for (k, t) in spec.on.iter_mut().enumerate() {
                if (y >> k) & 1 == 1 {
                    t.set(m);
                }
            }
        }
    }
    spec
}

/// Flat multiplier spec (`wl_a + wl_b` inputs, `wl_a + wl_b` outputs).
pub fn ppm_flat_spec(wl_a: u32, wl_b: u32, a_set: &ValueSet, b_set: &ValueSet) -> BlockSpec {
    let nvars = (wl_a + wl_b) as usize;
    let nouts = nvars;
    let a_mask = (1u64 << wl_a) - 1;
    let mut spec = BlockSpec::from_fn(
        nvars,
        nouts,
        &format!("ppm{wl_a}x{wl_b}"),
        |m| (m & a_mask) * (m >> wl_a),
        |_| false,
    );
    for a in a_set.iter() {
        for b in b_set.iter() {
            let m = a as u64 | ((b as u64) << wl_a);
            spec.care.set(m);
            let y = a as u64 * b as u64;
            for (k, t) in spec.on.iter_mut().enumerate() {
                if (y >> k) & 1 == 1 {
                    t.set(m);
                }
            }
        }
    }
    spec
}

/// Segment width for composed adders (the paper cascades 4-bit slices).
pub const SEG_BITS: u32 = 4;

/// Split an adder into ripple segments of [`SEG_BITS`] with carry-in.
/// Per-segment care sets are extracted by *simulating the ripple
/// structure over the actual input value sets* — this is exactly how
/// natural sparsity "propagates to deeper blocks" in the paper.
///
/// Segment spec inputs (low → high): `a_seg` (SEG bits), `b_seg`
/// (SEG bits), `cin` (1 bit). Outputs: `sum_seg` (SEG bits), `cout`.
pub fn adder_segment_specs(
    wl_a: u32,
    wl_b: u32,
    a_set: &ValueSet,
    b_set: &ValueSet,
) -> Vec<BlockSpec> {
    let wl = wl_a.max(wl_b);
    let nseg = wl.div_ceil(SEG_BITS) as usize;
    let seg_mask = (1u64 << SEG_BITS) - 1;
    // Build blank segment specs (9 inputs, 5 outputs each).
    let mut specs: Vec<BlockSpec> = (0..nseg)
        .map(|s| {
            BlockSpec::from_fn(
                (2 * SEG_BITS + 1) as usize,
                (SEG_BITS + 1) as usize,
                &format!("ppa_seg{s}"),
                |m| {
                    let a = m & seg_mask;
                    let b = (m >> SEG_BITS) & seg_mask;
                    let cin = m >> (2 * SEG_BITS);
                    a + b + cin
                },
                |_| false,
            )
        })
        .collect();
    // Shannon-path variable order: interleave (a_i, b_i) MSB-first with
    // cin last — the linear-BDD order for addition.
    let mut order: Vec<usize> = Vec::new();
    for i in (0..SEG_BITS as usize).rev() {
        order.push(i);
        order.push(SEG_BITS as usize + i);
    }
    order.push(2 * SEG_BITS as usize);
    for spec in specs.iter_mut() {
        spec.bdd_order = Some(order.clone());
    }
    // Observe every (a_seg, b_seg, cin) triple each segment actually sees.
    for a in a_set.iter() {
        for b in b_set.iter() {
            let mut carry = 0u64;
            for (s, spec) in specs.iter_mut().enumerate() {
                let sh = s as u32 * SEG_BITS;
                let asg = ((a as u64) >> sh) & seg_mask;
                let bsg = ((b as u64) >> sh) & seg_mask;
                let m = asg | (bsg << SEG_BITS) | (carry << (2 * SEG_BITS));
                let y = asg + bsg + carry;
                if !spec.care.get(m) {
                    spec.care.set(m);
                    for (k, t) in spec.on.iter_mut().enumerate() {
                        if (y >> k) & 1 == 1 {
                            t.set(m);
                        }
                    }
                }
                carry = y >> SEG_BITS;
            }
        }
    }
    specs
}

/// Quadrant decomposition of an 8×8 multiplier into four 4×4 multipliers
/// (supplementary Fig. 2): `a·b = LL + (LH + HL)·2^4 + HH·2^8` where
/// `LL = a_lo·b_lo`, `LH = a_lo·b_hi`, `HL = a_hi·b_lo`, `HH = a_hi·b_hi`.
/// Care sets of the quadrants come from the observed (nibble, nibble)
/// pairs of the actual input value sets.
pub struct MultQuadrants {
    /// Quadrant specs in order LL, LH, HL, HH (each 8 inputs, 8 outputs).
    pub quads: Vec<BlockSpec>,
    /// Value sets of the quadrant outputs (for the adder tree care sets).
    pub quad_out_sets: Vec<ValueSet>,
}

pub fn mult_quadrant_specs(a_set: &ValueSet, b_set: &ValueSet) -> MultQuadrants {
    let blank = |name: &str| {
        let mut spec = BlockSpec::from_fn(8, 8, name, |m| (m & 15) * (m >> 4), |_| false);
        // interleaved (a_i, b_i) MSB-first order for the Shannon path
        spec.bdd_order = Some(vec![3, 7, 2, 6, 1, 5, 0, 4]);
        spec
    };
    let mut quads = vec![blank("mul4_LL"), blank("mul4_LH"), blank("mul4_HL"), blank("mul4_HH")];
    let mut out_sets = vec![ValueSet::empty(256); 4];
    for a in a_set.iter() {
        let (al, ah) = ((a & 15) as u64, ((a >> 4) & 15) as u64);
        for b in b_set.iter() {
            let (bl, bh) = ((b & 15) as u64, ((b >> 4) & 15) as u64);
            for (q, (x, y)) in [(al, bl), (al, bh), (ah, bl), (ah, bh)].iter().enumerate() {
                let m = x | (y << 4);
                let p = x * y;
                out_sets[q].insert(p as u32);
                if !quads[q].care.get(m) {
                    quads[q].care.set(m);
                    for (k, t) in quads[q].on.iter_mut().enumerate() {
                        if (p >> k) & 1 == 1 {
                            t.set(m);
                        }
                    }
                }
            }
        }
    }
    MultQuadrants { quads, quad_out_sets: out_sets }
}

// ---------------------------------------------------------------------
// Structural (conventional) builders
// ---------------------------------------------------------------------

/// Full adder on edges; returns (sum, carry).
fn full_adder(g: &mut Aig, a: Edge, b: Edge, c: Edge) -> (Edge, Edge) {
    let ab = g.xor(a, b);
    let sum = g.xor(ab, c);
    let t1 = g.and(a, b);
    let t2 = g.and(ab, c);
    let carry = g.or(t1, t2);
    (sum, carry)
}

/// Ripple-carry adder AIG: inputs `a` at vars `0..wl_a`, `b` at
/// `wl_a..wl_a+wl_b`; outputs `max(wl)+1` sum bits.
pub fn ripple_adder_aig(wl_a: u32, wl_b: u32) -> Aig {
    let n = (wl_a + wl_b) as usize;
    let mut g = Aig::new(n);
    let wl = wl_a.max(wl_b);
    let mut carry = aig::FALSE_EDGE;
    for i in 0..wl {
        let a = if i < wl_a { g.input(i as usize) } else { aig::FALSE_EDGE };
        let b = if i < wl_b { g.input((wl_a + i) as usize) } else { aig::FALSE_EDGE };
        let (s, c) = full_adder(&mut g, a, b, carry);
        g.outputs.push(s);
        carry = c;
    }
    g.outputs.push(carry);
    g
}

/// Unsigned array multiplier AIG (`wl_a × wl_b`, full product output).
pub fn array_multiplier_aig(wl_a: u32, wl_b: u32) -> Aig {
    let n = (wl_a + wl_b) as usize;
    let mut g = Aig::new(n);
    // partial products
    let mut rows: Vec<Vec<Edge>> = Vec::new();
    for j in 0..wl_b {
        let mut row = Vec::new();
        for i in 0..wl_a {
            let a = g.input(i as usize);
            let b = g.input((wl_a + j) as usize);
            row.push(g.and(a, b));
        }
        rows.push(row);
    }
    // ripple-accumulate rows (array structure)
    let mut acc: Vec<Edge> = rows[0].clone(); // product bits so far
    let mut outputs: Vec<Edge> = vec![acc[0]];
    for (j, row) in rows.iter().enumerate().skip(1) {
        // add row << j to acc; acc currently holds bits j-1.. (we peel
        // one output bit per row)
        let mut next: Vec<Edge> = Vec::new();
        let mut carry = aig::FALSE_EDGE;
        for i in 0..wl_a as usize {
            let acc_bit = if i + 1 < acc.len() { acc[i + 1] } else { aig::FALSE_EDGE };
            let (s, c) = full_adder(&mut g, acc_bit, row[i], carry);
            next.push(s);
            carry = c;
        }
        next.push(carry);
        outputs.push(next[0]);
        acc = next;
        let _ = j;
    }
    for &bit in acc.iter().skip(1) {
        outputs.push(bit);
    }
    outputs.truncate(n);
    while outputs.len() < n {
        outputs.push(aig::FALSE_EDGE);
    }
    g.outputs = outputs;
    g
}

/// Signed (two's-complement) Baugh-Wooley-style multiplier, built by
/// sign-extending both operands into a `(wl_a+wl_b)`-wide unsigned array
/// and truncating — functionally exact for two's-complement inputs.
pub fn signed_multiplier_aig(wl_a: u32, wl_b: u32) -> Aig {
    let n = (wl_a + wl_b) as usize;
    let w = wl_a + wl_b; // full-width operands after sign extension
    let mut g = Aig::new(n);
    let bit_a = |g: &mut Aig, i: u32| -> Edge {
        if i < wl_a {
            g.input(i as usize)
        } else {
            g.input((wl_a - 1) as usize) // sign extension
        }
    };
    let bit_b = |g: &mut Aig, j: u32| -> Edge {
        if j < wl_b {
            g.input((wl_a + j) as usize)
        } else {
            g.input((wl_a + wl_b - 1) as usize)
        }
    };
    // accumulate partial products modulo 2^w
    let mut acc: Vec<Edge> = vec![aig::FALSE_EDGE; w as usize];
    for j in 0..w {
        let mut carry = aig::FALSE_EDGE;
        let bj = bit_b(&mut g, j);
        for i in 0..(w - j) {
            let ai = bit_a(&mut g, i);
            let pp = g.and(ai, bj);
            let idx = (i + j) as usize;
            let (s, c) = full_adder(&mut g, acc[idx], pp, carry);
            acc[idx] = s;
            carry = c;
        }
    }
    g.outputs = acc;
    g
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::logic::espresso::Options;
    use crate::logic::map::{map_aig, Objective};
    use crate::logic::library::cells90;
    use crate::logic::synth::{self, two_level};
    use crate::ppc::preprocess::{Chain, Preproc};

    fn outputs_to_u64(bits: &[bool]) -> u64 {
        bits.iter()
            .enumerate()
            .fold(0u64, |acc, (i, &b)| acc | ((b as u64) << i))
    }

    #[test]
    fn ripple_adder_correct() {
        for (wa, wb) in [(4u32, 4u32), (4, 3), (5, 2)] {
            let g = ripple_adder_aig(wa, wb);
            for a in 0..(1u64 << wa) {
                for b in 0..(1u64 << wb) {
                    let m = a | (b << wa);
                    let got = outputs_to_u64(&g.eval(m));
                    assert_eq!(got, a + b, "wa={wa} wb={wb} a={a} b={b}");
                }
            }
        }
    }

    #[test]
    fn array_multiplier_correct() {
        for (wa, wb) in [(2u32, 3u32), (4, 4), (3, 5)] {
            let g = array_multiplier_aig(wa, wb);
            for a in 0..(1u64 << wa) {
                for b in 0..(1u64 << wb) {
                    let m = a | (b << wa);
                    let got = outputs_to_u64(&g.eval(m));
                    assert_eq!(got, a * b, "wa={wa} wb={wb} a={a} b={b}");
                }
            }
        }
    }

    #[test]
    fn array_multiplier_8x8_spot() {
        let g = array_multiplier_aig(8, 8);
        for (a, b) in [(255u64, 255u64), (17, 91), (128, 2), (0, 200)] {
            let got = outputs_to_u64(&g.eval(a | (b << 8)));
            assert_eq!(got, a * b);
        }
    }

    #[test]
    fn signed_multiplier_correct() {
        let (wa, wb) = (4u32, 4u32);
        let g = signed_multiplier_aig(wa, wb);
        let sign = |v: u64, w: u32| -> i64 {
            let v = v as i64;
            if v >= (1 << (w - 1)) {
                v - (1 << w)
            } else {
                v
            }
        };
        for a in 0..(1u64 << wa) {
            for b in 0..(1u64 << wb) {
                let m = a | (b << wa);
                let got = outputs_to_u64(&g.eval(m));
                let want = (sign(a, wa) * sign(b, wb)) as u64 & 0xff;
                assert_eq!(got, want, "a={a} b={b}");
            }
        }
    }

    #[test]
    fn ppa_flat_spec_counts() {
        let full = ValueSet::full(3);
        let spec = ppa_flat_spec(3, 3, &full, &full);
        assert_eq!(spec.care.count_ones(), 64);
        assert!((spec.dc_fraction() - 0.0).abs() < 1e-12);
        // DS2 on both inputs: eq. (1) -> 75% DC
        let ds2 = full.map_chain(&Chain::of(Preproc::Ds(2)));
        let spec2 = ppa_flat_spec(3, 3, &ds2, &ds2);
        assert!((spec2.dc_fraction() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn segments_cover_and_propagate() {
        let a = ValueSet::full(8);
        let b = ValueSet::full(8);
        let segs = adder_segment_specs(8, 8, &a, &b);
        assert_eq!(segs.len(), 2);
        // seg0 never sees cin=1
        assert_eq!(
            segs[0].care.count_ones(),
            256,
            "first segment care = all (a,b) nibble pairs with cin=0"
        );
        // seg1 sees carries
        assert!(segs[1].care.count_ones() > 256);
        // sparsity on inputs shrinks care of seg0
        let ds4 = a.map_chain(&Chain::of(Preproc::Ds(4)));
        let segs_ds = adder_segment_specs(8, 8, &ds4, &ds4);
        assert!(segs_ds[0].care.count_ones() < segs[0].care.count_ones());
    }

    #[test]
    fn quadrants_match_full_multiplier() {
        let a = ValueSet::full(8);
        let b = ValueSet::full(8);
        let q = mult_quadrant_specs(&a, &b);
        assert_eq!(q.quads.len(), 4);
        for quad in &q.quads {
            // full range: all 256 nibble pairs are care
            assert_eq!(quad.care.count_ones(), 256);
        }
        // reconstruct some products from quadrant specs' functions
        for (av, bv) in [(0x12u64, 0x34u64), (0xff, 0xff), (0x0f, 0xf0)] {
            let (al, ah) = (av & 15, av >> 4);
            let (bl, bh) = (bv & 15, bv >> 4);
            let prod = al * bl + ((al * bh + ah * bl) << 4) + ((ah * bh) << 8);
            assert_eq!(prod, av * bv);
        }
    }

    #[test]
    fn sparse_segment_synthesizes_smaller() {
        let full = ValueSet::full(8);
        let ds8 = full.map_chain(&Chain::of(Preproc::Ds(8)));
        let base = adder_segment_specs(8, 8, &full, &full);
        let sparse = adder_segment_specs(8, 8, &ds8, &ds8);
        let lit_base: u64 = base.iter().map(|s| two_level(s, Options::default()).literals).sum();
        let lit_sparse: u64 =
            sparse.iter().map(|s| two_level(s, Options::default()).literals).sum();
        assert!(lit_sparse < lit_base, "{lit_sparse} !< {lit_base}");
    }

    #[test]
    fn structural_maps_and_verifies() {
        // conventional 4+4 adder through the mapper stays correct
        let g = ripple_adder_aig(4, 4);
        let nl = map_aig(&g, &cells90(), Objective::Area);
        for a in 0..16u64 {
            for b in 0..16u64 {
                let got = nl.eval(a | (b << 4));
                assert_eq!(got, a + b);
            }
        }
        let _ = synth::BlockSpec::from_fn(2, 1, "t", |m| m & 1, |_| true);
    }
}
