//! Persistent netlist cache: synthesized, mapped netlists stored as
//! BLIF on disk so a warm `serve --backend native` cold start performs
//! **zero** two-level synthesis.
//!
//! Layout: one directory per `(ModelKey, objective)` —
//! `{cache}/{app}-{config}-{objective}/` — holding one
//! `{unit}.{spec}.blif` file per synthesized block (unit names scope
//! the spec names, which repeat across units: every adder has a
//! `ppa_seg0`). The files are exactly what
//! [`Netlist::to_blif`](crate::logic::netlist::Netlist::to_blif)
//! emits, i.e. the same interchange format the paper's SIS step uses,
//! so they are inspectable and editable with standard tools.
//!
//! Safety: a cached netlist is only used after
//! [`crate::logic::synth::verify_on_care_set`] passes bit-parallel
//! against the *current* block spec, so stale, corrupt or hand-edited
//! files can never serve wrong bits — they just count as misses and
//! get re-synthesized and rewritten. Cache writes are best-effort: an
//! unwritable directory degrades to fresh synthesis, never to an
//! error.

use crate::catalog::ModelKey;
use crate::logic::io::netlist_from_blif;
use crate::logic::library::cells90;
use crate::logic::map::Objective;
use crate::logic::netlist::Netlist;
use crate::logic::synth::{self, BlockSpec};
use crate::ppc::units::NetlistSource;
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// The on-disk netlist cache, with cache-wide hit/miss counters (a
/// *miss* is exactly one run of the two-level → multi-level → map
/// flow, so `misses() == 0` proves a construction synthesized
/// nothing).
pub struct NetlistCache {
    dir: PathBuf,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl NetlistCache {
    /// Open (creating if needed) a cache rooted at `dir`.
    pub fn new(dir: impl Into<PathBuf>) -> Result<NetlistCache> {
        let dir = dir.into();
        std::fs::create_dir_all(&dir)
            .with_context(|| format!("creating netlist cache dir {}", dir.display()))?;
        Ok(NetlistCache { dir, hits: AtomicU64::new(0), misses: AtomicU64::new(0) })
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Netlists served from disk since this cache was opened.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Netlists that had to be synthesized (absent/stale/corrupt file).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// View of the cache scoped to one model: files live under
    /// `{dir}/{app}-{config}-{objective}/`, and the scope keeps its own
    /// hit/miss counters (also rolled into the cache-wide totals) so a
    /// caller can tell whether *this* model loaded entirely warm.
    pub fn scope(&self, key: ModelKey, objective: Objective) -> ScopedNetlistCache<'_> {
        let obj = match objective {
            Objective::Area => "area",
            Objective::Delay => "delay",
        };
        ScopedNetlistCache {
            cache: self,
            dir: self.dir.join(format!("{}-{}-{obj}", key.app, key.config)),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }
}

/// A per-model view of the cache — the [`NetlistSource`] handed to the
/// hardware constructors during registration.
pub struct ScopedNetlistCache<'a> {
    cache: &'a NetlistCache,
    dir: PathBuf,
    hits: AtomicU64,
    misses: AtomicU64,
}

impl ScopedNetlistCache<'_> {
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

impl NetlistSource for ScopedNetlistCache<'_> {
    fn netlist(&self, unit: &str, spec: &BlockSpec, objective: Objective) -> Netlist {
        let path = self.dir.join(format!("{unit}.{}.blif", spec.name));
        if let Some(nl) = load_verified(&path, spec) {
            self.hits.fetch_add(1, Ordering::Relaxed);
            self.cache.hits.fetch_add(1, Ordering::Relaxed);
            return nl;
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        self.cache.misses.fetch_add(1, Ordering::Relaxed);
        let (_, nl) = synth::synthesize(spec, objective);
        // best-effort write — an unwritable cache must not break
        // serving. Written to a unique temp file and renamed into
        // place so a concurrent reader (another engine shard, another
        // process) can never observe a torn half-written BLIF.
        if std::fs::create_dir_all(&self.dir).is_ok() {
            static WRITE_SEQ: AtomicU64 = AtomicU64::new(0);
            let tmp = self.dir.join(format!(
                ".{unit}.{}.blif.tmp.{}.{}",
                spec.name,
                std::process::id(),
                WRITE_SEQ.fetch_add(1, Ordering::Relaxed)
            ));
            let text = nl.to_blif(&format!("{unit}_{}", spec.name));
            if std::fs::write(&tmp, text).is_ok() && std::fs::rename(&tmp, &path).is_err() {
                let _ = std::fs::remove_file(&tmp);
            }
        }
        nl
    }
}

/// Read + reconstruct + care-set-verify one cached netlist; any
/// failure (missing file, foreign BLIF, wrong shape, wrong bits) means
/// "not cached". An *absent* file is a silent miss (the normal cold
/// path); a file that is present but truncated, hand-edited or stale
/// logs a warning so operators learn their cache is being healed —
/// the entry falls back to re-synthesis either way, never a panic.
fn load_verified(path: &Path, spec: &BlockSpec) -> Option<Netlist> {
    let text = std::fs::read_to_string(path).ok()?;
    let nl = match netlist_from_blif(&text, &cells90()) {
        Ok(nl) => nl,
        Err(e) => {
            eprintln!(
                "warning: netlist cache entry {} is unreadable ({e:#}); re-synthesizing",
                path.display()
            );
            return None;
        }
    };
    let shape_ok = nl.num_inputs == spec.nvars && nl.outputs.len() == spec.num_outputs();
    if !shape_ok || synth::verify_on_care_set(spec, &nl) != 0 {
        eprintln!(
            "warning: netlist cache entry {} is stale or corrupt \
             (fails care-set verification); re-synthesizing",
            path.display()
        );
        return None;
    }
    Some(nl)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::catalog::PpcConfig;
    use crate::ppc::preprocess::ValueSet;
    use crate::ppc::units::AdderUnit;

    fn fresh_dir(tag: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("ppc_nlcache_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        dir
    }

    fn key() -> ModelKey {
        ModelKey::parse("gdf/ds32").unwrap()
    }

    #[test]
    fn second_construction_is_all_hits_and_bit_exact() {
        let dir = fresh_dir("warm");
        let set = ValueSet::full(8).map_chain(&PpcConfig::Ds32.chain());
        let cache = NetlistCache::new(&dir).unwrap();

        let cold_scope = cache.scope(key(), Objective::Area);
        let cold =
            AdderUnit::synthesize_via("t_add", 8, 8, &set, &set, Objective::Area, &cold_scope);
        assert!(cold_scope.misses() > 0, "first build must synthesize");
        assert_eq!(cold_scope.hits(), 0);

        let warm_scope = cache.scope(key(), Objective::Area);
        let warm =
            AdderUnit::synthesize_via("t_add", 8, 8, &set, &set, Objective::Area, &warm_scope);
        assert_eq!(warm_scope.misses(), 0, "warm build must not synthesize");
        assert_eq!(warm_scope.hits(), cold_scope.misses());
        assert_eq!(cache.misses(), cold_scope.misses());

        assert_eq!(warm.num_gates(), cold.num_gates());
        for a in set.iter() {
            for b in set.iter() {
                assert_eq!(warm.eval_scalar(a, b), (a + b) as u64, "a={a} b={b}");
            }
        }
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn corrupt_files_fall_back_to_synthesis() {
        let dir = fresh_dir("corrupt");
        let set = ValueSet::full(8).map_chain(&PpcConfig::Ds32.chain());
        let cache = NetlistCache::new(&dir).unwrap();
        let scope = cache.scope(key(), Objective::Area);
        AdderUnit::synthesize_via("t_add", 8, 8, &set, &set, Objective::Area, &scope);
        let n_files = scope.misses();

        // vandalize one cached file: it must count as a miss, get
        // re-synthesized, and the unit must still be exact
        let victim = std::fs::read_dir(scope.dir())
            .unwrap()
            .next()
            .unwrap()
            .unwrap()
            .path();
        std::fs::write(&victim, "this is not a blif file").unwrap();

        let scope2 = cache.scope(key(), Objective::Area);
        let unit =
            AdderUnit::synthesize_via("t_add", 8, 8, &set, &set, Objective::Area, &scope2);
        assert_eq!(scope2.misses(), 1, "exactly the vandalized file re-synthesizes");
        assert_eq!(scope2.hits(), n_files - 1);
        for a in set.iter().take(4) {
            for b in set.iter().take(4) {
                assert_eq!(unit.eval_scalar(a, b), (a + b) as u64);
            }
        }
        // and the rewrite healed the cache
        let scope3 = cache.scope(key(), Objective::Area);
        AdderUnit::synthesize_via("t_add", 8, 8, &set, &set, Objective::Area, &scope3);
        assert_eq!(scope3.misses(), 0);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn truncated_files_fall_back_to_resynthesis() {
        // a half-written BLIF (power loss, hand-editing) must never
        // panic or serve wrong bits: it re-synthesizes and heals
        let dir = fresh_dir("trunc");
        let set = ValueSet::full(8).map_chain(&PpcConfig::Ds32.chain());
        let cache = NetlistCache::new(&dir).unwrap();
        let scope = cache.scope(key(), Objective::Area);
        AdderUnit::synthesize_via("t_add", 8, 8, &set, &set, Objective::Area, &scope);
        let n_files = scope.misses();
        assert!(n_files > 0);

        // truncate every cached file to half its length
        for entry in std::fs::read_dir(scope.dir()).unwrap() {
            let path = entry.unwrap().path();
            let bytes = std::fs::read(&path).unwrap();
            std::fs::write(&path, &bytes[..bytes.len() / 2]).unwrap();
        }

        let scope2 = cache.scope(key(), Objective::Area);
        let unit =
            AdderUnit::synthesize_via("t_add", 8, 8, &set, &set, Objective::Area, &scope2);
        assert_eq!(scope2.misses(), n_files, "every truncated file re-synthesizes");
        assert_eq!(scope2.hits(), 0);
        for a in set.iter().take(4) {
            for b in set.iter().take(4) {
                assert_eq!(unit.eval_scalar(a, b), (a + b) as u64);
            }
        }
        // the rewrite healed the cache: third load is all hits
        let scope3 = cache.scope(key(), Objective::Area);
        AdderUnit::synthesize_via("t_add", 8, 8, &set, &set, Objective::Area, &scope3);
        assert_eq!(scope3.misses(), 0);
        assert_eq!(scope3.hits(), n_files);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn scopes_partition_by_model_and_objective() {
        let dir = fresh_dir("scopes");
        let cache = NetlistCache::new(&dir).unwrap();
        let a = cache.scope(ModelKey::parse("gdf/ds16").unwrap(), Objective::Area);
        let b = cache.scope(ModelKey::parse("gdf/ds32").unwrap(), Objective::Area);
        let c = cache.scope(ModelKey::parse("gdf/ds16").unwrap(), Objective::Delay);
        assert_ne!(a.dir(), b.dir());
        assert_ne!(a.dir(), c.dir());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
