//! PJRT runtime: load the AOT-compiled HLO-text artifacts and execute
//! them on the CPU PJRT client. Python never runs here — artifacts are
//! produced once by `make artifacts` and this module is self-contained
//! afterwards.
//!
//! NOTE: the `xla` crate's `PjRtClient` is `Rc`-backed (not `Send`), so a
//! [`Runtime`] must stay on the thread that created it. The coordinator
//! wraps it in a dedicated engine thread (see
//! [`crate::coordinator`]).

use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// Shape+dtype of one artifact port (only i32 tensors are used by the
/// three applications).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Port {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl Port {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One entry of `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub app: String,
    pub config: String,
    pub file: String,
    pub inputs: Vec<Port>,
    pub outputs: Vec<Port>,
}

/// Parse the manifest written by `python -m compile.aot`.
pub fn read_manifest(dir: &Path) -> Result<Vec<ArtifactMeta>> {
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
    let arts = j
        .get("artifacts")
        .and_then(|a| a.as_arr())
        .ok_or_else(|| anyhow!("manifest missing artifacts[]"))?;
    let port = |v: &Json| -> Result<Port> {
        let arr = v.as_arr().ok_or_else(|| anyhow!("bad port"))?;
        Ok(Port {
            dtype: arr[0].as_str().unwrap_or("i32").to_string(),
            dims: arr[1].flat_f64().iter().map(|&d| d as usize).collect(),
        })
    };
    arts.iter()
        .map(|a| {
            Ok(ArtifactMeta {
                app: a.get("app").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
                config: a.get("config").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
                file: a.get("file").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
                inputs: a
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(port)
                    .collect::<Result<_>>()?,
                outputs: a
                    .get("outputs")
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(port)
                    .collect::<Result<_>>()?,
            })
        })
        .collect()
}

/// A loaded executable plus its metadata.
pub struct Loaded {
    pub meta: ArtifactMeta,
    exe: xla::PjRtLoadedExecutable,
}

/// The artifact registry: a PJRT CPU client plus every compiled model
/// variant, keyed `"{app}/{config}"`.
pub struct Runtime {
    #[allow(dead_code)]
    client: xla::PjRtClient,
    executables: HashMap<String, Loaded>,
    pub dir: PathBuf,
}

impl Runtime {
    /// Compile every artifact in `dir` (per the manifest).
    pub fn load(dir: &Path) -> Result<Runtime> {
        Runtime::load_filtered(dir, |_| true)
    }

    /// Load only artifacts for one app (faster startup for examples).
    pub fn load_app(dir: &Path, app: &str) -> Result<Runtime> {
        let rt = Runtime::load_filtered(dir, |m| m.app == app)?;
        if rt.executables.is_empty() {
            bail!("no artifacts for app {app} in {}", dir.display());
        }
        Ok(rt)
    }

    pub fn load_filtered(dir: &Path, keep: impl Fn(&ArtifactMeta) -> bool) -> Result<Runtime> {
        let metas = read_manifest(dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
        let mut executables = HashMap::new();
        for meta in metas.into_iter().filter(|m| keep(m)) {
            let path = dir.join(&meta.file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
            )
            .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| anyhow!("compile {}: {e:?}", meta.file))?;
            executables.insert(format!("{}/{}", meta.app, meta.config), Loaded { meta, exe });
        }
        Ok(Runtime { client, executables, dir: dir.to_path_buf() })
    }

    pub fn keys(&self) -> Vec<String> {
        let mut k: Vec<String> = self.executables.keys().cloned().collect();
        k.sort();
        k
    }

    pub fn meta(&self, key: &str) -> Option<&ArtifactMeta> {
        self.executables.get(key).map(|l| &l.meta)
    }

    /// Execute an artifact on i32 tensors. `inputs[k]` must match the
    /// manifest's k-th input port (row-major). Returns one Vec<i32> per
    /// output port.
    pub fn exec_i32(&self, key: &str, inputs: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
        let loaded = self
            .executables
            .get(key)
            .ok_or_else(|| anyhow!("unknown artifact {key}; have {:?}", self.keys()))?;
        if inputs.len() != loaded.meta.inputs.len() {
            bail!(
                "{key}: expected {} inputs, got {}",
                loaded.meta.inputs.len(),
                inputs.len()
            );
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, port) in inputs.iter().zip(&loaded.meta.inputs) {
            if data.len() != port.elements() {
                bail!("{key}: input size {} != port {:?}", data.len(), port.dims);
            }
            let dims: Vec<i64> = port.dims.iter().map(|&d| d as i64).collect();
            let lit = xla::Literal::vec1(data)
                .reshape(&dims)
                .map_err(|e| anyhow!("reshape: {e:?}"))?;
            literals.push(lit);
        }
        let result = loaded
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| anyhow!("execute {key}: {e:?}"))?;
        let first = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("to_literal: {e:?}"))?;
        // jax lowers with return_tuple=True → unpack the tuple
        let parts = first.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|p| p.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}")))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join("ppc_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":[{"app":"gdf","config":"conv","file":"g.hlo.txt",
                "inputs":[["i32",[4,4]]],"outputs":[["i32",[4,4]]]}]}"#,
        )
        .unwrap();
        let m = read_manifest(&dir).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].app, "gdf");
        assert_eq!(m[0].inputs[0].dims, vec![4, 4]);
        assert_eq!(m[0].inputs[0].elements(), 16);
    }

    #[test]
    fn missing_manifest_is_friendly() {
        let err = read_manifest(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
