//! Execution backends for the serving stack.
//!
//! Two interchangeable backends sit behind
//! [`crate::coordinator::engine::Executor`], keyed by the typed
//! [`crate::catalog::ModelKey`] catalog:
//!
//! - [`native`] (default build): [`NativeExecutor`] executes the
//!   *synthesized PPC netlists themselves* — the gate-level adders and
//!   multipliers the design flow produces — bit-parallel on
//!   shape-carrying i32 tensors. Fully offline: no Python, no XLA, no
//!   artifacts. The [`cache`] module gives it a persistent BLIF
//!   netlist cache ([`NetlistCache`]) so warm cold starts synthesize
//!   nothing.
//! - [`pjrt`] (cargo feature `pjrt`): [`Runtime`] loads the
//!   AOT-compiled HLO-text artifacts produced by `make artifacts` and
//!   executes them on the CPU PJRT client. Without the feature the
//!   loader is a stub that returns a clear error pointing at the
//!   native backend.
//!
//! This module keeps the backend-agnostic pieces: the artifact manifest
//! schema ([`Port`], [`ArtifactMeta`], [`read_manifest`]) shared by the
//! PJRT loader and the integration tests.

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};
use std::path::Path;

pub mod cache;
pub mod native;
pub mod pjrt;

pub use cache::NetlistCache;
pub use native::{ModelInfo, NativeExecutor};
pub use pjrt::Runtime;

/// Shape+dtype of one artifact port (only i32 tensors are used by the
/// three applications).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Port {
    pub dtype: String,
    pub dims: Vec<usize>,
}

impl Port {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// One entry of `artifacts/manifest.json`.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    pub app: String,
    pub config: String,
    pub file: String,
    pub inputs: Vec<Port>,
    pub outputs: Vec<Port>,
}

/// Parse the manifest written by `python -m compile.aot`.
pub fn read_manifest(dir: &Path) -> Result<Vec<ArtifactMeta>> {
    let path = dir.join("manifest.json");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {} — run `make artifacts` first", path.display()))?;
    let j = Json::parse(&text).map_err(|e| anyhow!("manifest parse: {e}"))?;
    let arts = j
        .get("artifacts")
        .and_then(|a| a.as_arr())
        .ok_or_else(|| anyhow!("manifest missing artifacts[]"))?;
    let port = |v: &Json| -> Result<Port> {
        let arr = v.as_arr().ok_or_else(|| anyhow!("bad port"))?;
        Ok(Port {
            dtype: arr[0].as_str().unwrap_or("i32").to_string(),
            dims: arr[1].flat_f64().iter().map(|&d| d as usize).collect(),
        })
    };
    arts.iter()
        .map(|a| {
            Ok(ArtifactMeta {
                app: a.get("app").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
                config: a.get("config").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
                file: a.get("file").and_then(|v| v.as_str()).unwrap_or_default().to_string(),
                inputs: a
                    .get("inputs")
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(port)
                    .collect::<Result<_>>()?,
                outputs: a
                    .get("outputs")
                    .and_then(|v| v.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(port)
                    .collect::<Result<_>>()?,
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_parses() {
        let dir = std::env::temp_dir().join("ppc_rt_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"artifacts":[{"app":"gdf","config":"conv","file":"g.hlo.txt",
                "inputs":[["i32",[4,4]]],"outputs":[["i32",[4,4]]]}]}"#,
        )
        .unwrap();
        let m = read_manifest(&dir).unwrap();
        assert_eq!(m.len(), 1);
        assert_eq!(m[0].app, "gdf");
        assert_eq!(m[0].inputs[0].dims, vec![4, 4]);
        assert_eq!(m[0].inputs[0].elements(), 16);
    }

    #[test]
    fn missing_manifest_is_friendly() {
        let err = read_manifest(Path::new("/nonexistent")).unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
