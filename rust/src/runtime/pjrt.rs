//! PJRT/XLA execution backend (cargo feature `pjrt`).
//!
//! With the feature enabled this loads the AOT-compiled HLO-text
//! artifacts and executes them on the CPU PJRT client; Python never
//! runs here — artifacts are produced once by `make artifacts` and this
//! module is self-contained afterwards. Building with `--features pjrt`
//! requires the vendored `xla` crate (see the Cargo.toml header).
//!
//! Without the feature (the default, fully offline build) the same
//! [`Runtime`] type exists but every loader returns a clear error
//! directing callers to the feature flag or to the native backend
//! ([`super::NativeExecutor`]), so the coordinator/server stack and its
//! callers compile and fail gracefully at run time instead of at link
//! time.
//!
//! NOTE: the `xla` crate's `PjRtClient` is `Rc`-backed (not `Send`), so
//! a [`Runtime`] must stay on the thread that created it. The
//! coordinator builds it on (and confines it to) a single engine-pool
//! shard (see [`crate::coordinator`]).
//!
//! Artifact files are keyed by the manifest's `"{app}/{config}"`
//! strings on disk; the serving stack never sees those — the
//! [`Executor`](crate::coordinator::engine::Executor) adapter renders
//! each typed [`crate::catalog::ModelKey`] to its canonical string at
//! the boundary and parses manifest keys back into the catalog, so an
//! artifact for a key outside the typed catalog simply isn't servable.

/// The error returned by every entry point when the `pjrt` feature is
/// off.
#[cfg(not(feature = "pjrt"))]
pub const PJRT_DISABLED: &str = "this build has no PJRT/XLA backend (the `pjrt` cargo feature \
is off); rebuild with `--features pjrt` and the vendored `xla` crate, or serve through the \
native netlist backend (ppc::runtime::NativeExecutor / `ppc serve --backend native`)";

#[cfg(feature = "pjrt")]
mod imp {
    use super::super::{read_manifest, ArtifactMeta};
    use anyhow::{anyhow, bail, Result};
    use std::collections::HashMap;
    use std::path::{Path, PathBuf};

    /// A loaded executable plus its metadata.
    pub struct Loaded {
        pub meta: ArtifactMeta,
        exe: xla::PjRtLoadedExecutable,
    }

    /// The artifact registry: a PJRT CPU client plus every compiled
    /// model variant, keyed `"{app}/{config}"`.
    pub struct Runtime {
        #[allow(dead_code)]
        client: xla::PjRtClient,
        executables: HashMap<String, Loaded>,
        pub dir: PathBuf,
    }

    impl Runtime {
        /// Compile every artifact in `dir` (per the manifest).
        pub fn load(dir: &Path) -> Result<Runtime> {
            Runtime::load_filtered(dir, |_| true)
        }

        /// Load only artifacts for one app (faster startup for examples).
        pub fn load_app(dir: &Path, app: &str) -> Result<Runtime> {
            let rt = Runtime::load_filtered(dir, |m| m.app == app)?;
            if rt.executables.is_empty() {
                bail!("no artifacts for app {app} in {}", dir.display());
            }
            Ok(rt)
        }

        pub fn load_filtered(dir: &Path, keep: impl Fn(&ArtifactMeta) -> bool) -> Result<Runtime> {
            let metas = read_manifest(dir)?;
            let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu client: {e:?}"))?;
            let mut executables = HashMap::new();
            for meta in metas.into_iter().filter(|m| keep(m)) {
                let path = dir.join(&meta.file);
                let proto = xla::HloModuleProto::from_text_file(
                    path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
                )
                .map_err(|e| anyhow!("parse {}: {e:?}", path.display()))?;
                let comp = xla::XlaComputation::from_proto(&proto);
                let exe = client
                    .compile(&comp)
                    .map_err(|e| anyhow!("compile {}: {e:?}", meta.file))?;
                executables.insert(format!("{}/{}", meta.app, meta.config), Loaded { meta, exe });
            }
            Ok(Runtime { client, executables, dir: dir.to_path_buf() })
        }

        pub fn keys(&self) -> Vec<String> {
            let mut k: Vec<String> = self.executables.keys().cloned().collect();
            k.sort();
            k
        }

        pub fn meta(&self, key: &str) -> Option<&ArtifactMeta> {
            self.executables.get(key).map(|l| &l.meta)
        }

        /// Execute an artifact on i32 tensors. `inputs[k]` must match
        /// the manifest's k-th input port (row-major). Returns one
        /// Vec<i32> per output port.
        pub fn exec_i32(&self, key: &str, inputs: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
            let loaded = self
                .executables
                .get(key)
                .ok_or_else(|| anyhow!("unknown artifact {key}; have {:?}", self.keys()))?;
            if inputs.len() != loaded.meta.inputs.len() {
                bail!(
                    "{key}: expected {} inputs, got {}",
                    loaded.meta.inputs.len(),
                    inputs.len()
                );
            }
            let mut literals = Vec::with_capacity(inputs.len());
            for (data, port) in inputs.iter().zip(&loaded.meta.inputs) {
                if data.len() != port.elements() {
                    bail!("{key}: input size {} != port {:?}", data.len(), port.dims);
                }
                let dims: Vec<i64> = port.dims.iter().map(|&d| d as i64).collect();
                let lit = xla::Literal::vec1(data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("reshape: {e:?}"))?;
                literals.push(lit);
            }
            let result = loaded
                .exe
                .execute::<xla::Literal>(&literals)
                .map_err(|e| anyhow!("execute {key}: {e:?}"))?;
            let first = result[0][0]
                .to_literal_sync()
                .map_err(|e| anyhow!("to_literal: {e:?}"))?;
            // jax lowers with return_tuple=True → unpack the tuple
            let parts = first.to_tuple().map_err(|e| anyhow!("to_tuple: {e:?}"))?;
            parts
                .into_iter()
                .map(|p| p.to_vec::<i32>().map_err(|e| anyhow!("to_vec: {e:?}")))
                .collect()
        }
    }
}

#[cfg(not(feature = "pjrt"))]
mod imp {
    use super::super::ArtifactMeta;
    use super::PJRT_DISABLED;
    use anyhow::{bail, Result};
    use std::path::{Path, PathBuf};

    /// Feature-off stand-in: same surface as the real PJRT runtime, but
    /// every loader fails with [`PJRT_DISABLED`].
    pub struct Runtime {
        pub dir: PathBuf,
    }

    impl Runtime {
        pub fn load(_dir: &Path) -> Result<Runtime> {
            bail!("{PJRT_DISABLED}")
        }

        pub fn load_app(_dir: &Path, _app: &str) -> Result<Runtime> {
            bail!("{PJRT_DISABLED}")
        }

        pub fn load_filtered(
            _dir: &Path,
            _keep: impl Fn(&ArtifactMeta) -> bool,
        ) -> Result<Runtime> {
            bail!("{PJRT_DISABLED}")
        }

        pub fn keys(&self) -> Vec<String> {
            Vec::new()
        }

        pub fn meta(&self, _key: &str) -> Option<&ArtifactMeta> {
            None
        }

        pub fn exec_i32(&self, _key: &str, _inputs: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
            bail!("{PJRT_DISABLED}")
        }
    }
}

#[cfg(feature = "pjrt")]
pub use imp::Loaded;
pub use imp::Runtime;

#[cfg(all(test, not(feature = "pjrt")))]
mod tests {
    use super::*;
    use std::path::Path;

    #[test]
    fn disabled_backend_errors_clearly() {
        let err = Runtime::load(Path::new("/nonexistent")).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("pjrt"), "{msg}");
        assert!(msg.contains("native"), "{msg}");
    }
}
