//! Native execution backend: serve the synthesized PPC netlists
//! directly — no Python, no XLA, no artifacts.
//!
//! A [`NativeExecutor`] holds, per `"{app}/{config}"` key, the
//! application datapath built from mapped gate-level netlists
//! ([`GdfHardware`], [`BlendHardware`], [`FrnnHardware`]) and executes
//! requests on i32 tensors through the 64-way bit-parallel evaluator.
//! It implements [`Executor`], so the whole coordinator stack (router →
//! batcher → engine thread) serves real PPC computation offline; the
//! results are bit-exact with the fixed-point application simulations
//! (`gdf_filter`, `blend_images`, `forward_fx`) — exactness on the care
//! set is the paper's contract, and the units assert it at synthesis
//! time.
//!
//! Construction synthesizes hardware (two-level → multi-level → tech
//! map per block), so register only the configs you serve: sparse
//! configs (`ds16`, `ds32`, `th48ds16`) synthesize in well under a
//! second; full-range `conv` blocks take the longest.

use crate::apps::blend::{Alpha, BlendConfig, BlendHardware};
use crate::apps::frnn::dataset::{Face, IMG_PIXELS};
use crate::apps::frnn::hw::FrnnHardware;
use crate::apps::frnn::net::QuantFrnn;
use crate::apps::gdf::GdfHardware;
use crate::apps::image::Image;
use crate::coordinator::engine::Executor;
use crate::logic::map::Objective;
use crate::ppc::preprocess::{Chain, Preproc, ValueSet};
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;

/// Preprocessing chain of an image-app serving config (the names the
/// router in [`crate::coordinator::server::route_config`] emits).
pub fn config_chain(config: &str) -> Result<Chain> {
    match config {
        "conv" => Ok(Chain::id()),
        "ds16" => Ok(Chain::of(Preproc::Ds(16))),
        "ds32" => Ok(Chain::of(Preproc::Ds(32))),
        other => bail!("unknown PPC config {other:?} (want conv|ds16|ds32)"),
    }
}

/// (image chain, weight chain) of an FRNN serving config.
pub fn frnn_config_chains(config: &str) -> Result<(Chain, Chain)> {
    match config {
        "conv" => Ok((Chain::id(), Chain::id())),
        "th48ds16" => Ok((
            Chain::of(Preproc::Th { x: 48, y: 48 }).then(Preproc::Ds(16)),
            Chain::of(Preproc::Ds(16)),
        )),
        "ds32" => Ok((Chain::of(Preproc::Ds(32)), Chain::of(Preproc::Ds(32)))),
        other => bail!("unknown FRNN config {other:?} (want conv|th48ds16|ds32)"),
    }
}

/// The native model registry, keyed `"{app}/{config}"`.
pub struct NativeExecutor {
    objective: Objective,
    gdf: BTreeMap<String, GdfHardware>,
    blend: BTreeMap<String, BlendHardware>,
    frnn: BTreeMap<String, FrnnHardware>,
}

impl Default for NativeExecutor {
    fn default() -> Self {
        NativeExecutor::new()
    }
}

impl NativeExecutor {
    /// An empty registry (area-optimized mapping).
    pub fn new() -> NativeExecutor {
        NativeExecutor {
            objective: Objective::Area,
            gdf: BTreeMap::new(),
            blend: BTreeMap::new(),
            frnn: BTreeMap::new(),
        }
    }

    /// Change the technology-mapping objective for *subsequently*
    /// registered models.
    pub fn objective(mut self, objective: Objective) -> NativeExecutor {
        self.objective = objective;
        self
    }

    /// Synthesize and register the GDF adder tree under `gdf/{config}`.
    pub fn with_gdf(mut self, config: &str) -> Result<NativeExecutor> {
        let chain = config_chain(config)?;
        let hw = GdfHardware::synthesize(&ValueSet::full(8), &chain, self.objective);
        self.gdf.insert(config.to_string(), hw);
        Ok(self)
    }

    /// Synthesize and register the IB datapath under `blend/{config}`
    /// (natural coefficient sparsity: alpha must be in `[0, 127]`, the
    /// [`crate::coordinator::Job::Blend`] contract).
    pub fn with_blend(mut self, config: &str) -> Result<NativeExecutor> {
        let chain = config_chain(config)?;
        let cfg = BlendConfig::of(true, chain);
        let hw = BlendHardware::synthesize(&cfg, self.objective);
        self.blend.insert(config.to_string(), hw);
        Ok(self)
    }

    /// Synthesize and register the FRNN forward path under
    /// `frnn/{config}` with the given quantized weights.
    pub fn with_frnn(mut self, config: &str, net: QuantFrnn) -> Result<NativeExecutor> {
        let (ci, cw) = frnn_config_chains(config)?;
        let hw = FrnnHardware::synthesize(net, &ci, &cw, self.objective);
        self.frnn.insert(config.to_string(), hw);
        Ok(self)
    }

    /// Registered keys, sorted (same shape as the PJRT registry).
    pub fn registered_keys(&self) -> Vec<String> {
        let mut k: Vec<String> = Vec::new();
        k.extend(self.gdf.keys().map(|c| format!("gdf/{c}")));
        k.extend(self.blend.keys().map(|c| format!("blend/{c}")));
        k.extend(self.frnn.keys().map(|c| format!("frnn/{c}")));
        k.sort();
        k
    }

    fn unknown(&self, key: &str) -> anyhow::Error {
        anyhow!("unknown native model {key}; have {:?}", self.registered_keys())
    }

    fn exec_gdf(&self, key: &str, config: &str, inputs: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
        let hw = self.gdf.get(config).ok_or_else(|| self.unknown(key))?;
        if inputs.len() != 1 {
            bail!("{key}: expected 1 input tensor, got {}", inputs.len());
        }
        let img = to_image(inputs[0], key)?;
        let out = hw.filter(&img);
        Ok(vec![out.pixels.iter().map(|&p| p as i32).collect()])
    }

    fn exec_blend(&self, key: &str, config: &str, inputs: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
        let hw = self.blend.get(config).ok_or_else(|| self.unknown(key))?;
        if inputs.len() != 3 {
            bail!("{key}: expected (p1, p2, alpha), got {} tensors", inputs.len());
        }
        let (p1, p2, al) = (inputs[0], inputs[1], inputs[2]);
        if p1.len() != p2.len() {
            bail!("{key}: image sizes differ ({} vs {})", p1.len(), p2.len());
        }
        if al.len() != 1 || !(0..=127).contains(&al[0]) {
            bail!("{key}: alpha must be a single value in [0, 127], got {al:?}");
        }
        let a = to_pixels(p1, key)?;
        let b = to_pixels(p2, key)?;
        let out = hw.blend_flat(&a, &b, Alpha(al[0] as u8));
        Ok(vec![out.into_iter().map(|p| p as i32).collect()])
    }

    fn exec_frnn(&self, key: &str, config: &str, inputs: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
        let hw = self.frnn.get(config).ok_or_else(|| self.unknown(key))?;
        if inputs.len() != 1 {
            bail!("{key}: expected 1 input tensor, got {}", inputs.len());
        }
        let flat = inputs[0];
        if flat.is_empty() || flat.len() % IMG_PIXELS != 0 {
            bail!(
                "{key}: input length {} is not a multiple of the {IMG_PIXELS}-pixel row",
                flat.len()
            );
        }
        let pixels = to_pixels(flat, key)?;
        let mut out = Vec::with_capacity(pixels.len() / IMG_PIXELS * 7);
        for row in pixels.chunks(IMG_PIXELS) {
            let face = Face { pixels: row.to_vec(), id: 0, pose: 0, sunglasses: false };
            let (_, outs) = hw.forward(&face);
            out.extend(outs.iter().map(|&v| v as i32));
        }
        Ok(vec![out])
    }
}

impl Executor for NativeExecutor {
    fn exec(&self, key: &str, inputs: &[&[i32]]) -> Result<Vec<Vec<i32>>> {
        let (app, config) = key.split_once('/').ok_or_else(|| self.unknown(key))?;
        match app {
            "gdf" => self.exec_gdf(key, config, inputs),
            "blend" => self.exec_blend(key, config, inputs),
            "frnn" => self.exec_frnn(key, config, inputs),
            _ => Err(self.unknown(key)),
        }
    }

    fn keys(&self) -> Vec<String> {
        self.registered_keys()
    }
}

/// i32 tensor → u8 pixels, with a clear error on out-of-range values.
fn to_pixels(data: &[i32], what: &str) -> Result<Vec<u8>> {
    data.iter()
        .map(|&v| {
            if (0..=255).contains(&v) {
                Ok(v as u8)
            } else {
                Err(anyhow!("{what}: value {v} outside the u8 pixel range"))
            }
        })
        .collect()
}

/// Flat i32 tensor → square image (the native GDF path needs the 2-D
/// window structure; serve square images or use the PJRT backend whose
/// artifact manifest carries explicit shapes).
fn to_image(data: &[i32], what: &str) -> Result<Image> {
    let n = data.len();
    let side = (n as f64).sqrt().round() as usize;
    if side * side != n || n == 0 {
        bail!("{what}: native backend expects a square image, got {n} pixels");
    }
    Ok(Image { width: side, height: side, pixels: to_pixels(data, what)? })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::gdf;
    use crate::apps::image::synthetic_photo;
    use crate::util::prng::Rng;

    #[test]
    fn gdf_exec_matches_fixed_point_sim() {
        let ex = NativeExecutor::new().with_gdf("ds32").unwrap();
        assert_eq!(ex.registered_keys(), vec!["gdf/ds32"]);
        let img = synthetic_photo(16, 16, 9);
        let flat: Vec<i32> = img.pixels.iter().map(|&p| p as i32).collect();
        let out = ex.exec("gdf/ds32", &[&flat]).unwrap();
        let want = gdf::gdf_filter(&img, &config_chain("ds32").unwrap());
        let got: Vec<u8> = out[0].iter().map(|&v| v as u8).collect();
        assert_eq!(got, want.pixels);
    }

    #[test]
    fn graceful_errors() {
        let ex = NativeExecutor::new().with_gdf("ds32").unwrap();
        // unknown key
        let e = ex.exec("gdf/nope", &[&[0; 16]]).unwrap_err();
        assert!(format!("{e}").contains("unknown native model"));
        assert!(ex.exec("blend/ds32", &[&[0; 4], &[0; 4], &[64]]).is_err());
        // non-square image
        assert!(ex.exec("gdf/ds32", &[&[0; 15]]).is_err());
        // out-of-range pixel
        assert!(ex.exec("gdf/ds32", &[&[300; 16]]).is_err());
        // wrong arity
        assert!(ex.exec("gdf/ds32", &[&[0; 16], &[0; 16]]).is_err());
    }

    #[test]
    fn blend_exec_matches_fixed_point_sim() {
        use crate::apps::blend;
        let ex = NativeExecutor::new().with_blend("ds32").unwrap();
        let mut rng = Rng::new(0xB1);
        let p1: Vec<i32> = (0..100).map(|_| rng.below(256) as i32).collect();
        let p2: Vec<i32> = (0..100).map(|_| rng.below(256) as i32).collect();
        let out = ex.exec("blend/ds32", &[&p1, &p2, &[32]]).unwrap();
        let chain = config_chain("ds32").unwrap();
        for (j, &o) in out[0].iter().enumerate() {
            let want = blend::blend_pixel(
                p1[j] as u8,
                p2[j] as u8,
                Alpha(32),
                &chain,
                &chain,
            );
            assert_eq!(o, want as i32, "pixel {j}");
        }
        // alpha out of the natural range is rejected, not miscomputed
        assert!(ex.exec("blend/ds32", &[&p1, &p2, &[200]]).is_err());
    }
}
