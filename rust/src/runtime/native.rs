//! Native execution backend: serve the synthesized PPC netlists
//! directly — no Python, no XLA, no artifacts.
//!
//! A [`NativeExecutor`] is the typed model registry: one keyed map of
//! registered application datapaths ([`GdfHardware`],
//! [`BlendHardware`], [`FrnnHardware`]) behind the one [`Datapath`]
//! trait, plus a *recipe* per declared-but-unbuilt key. Requests and
//! responses are shape-carrying [`Tensor`]s, so non-square images
//! survive the trip, and every lookup, registration and error message
//! goes through the same [`ModelKey`] catalog the router and the CLI
//! use — there is no stringly-typed key anywhere on the path.
//!
//! It implements [`Executor`], so the whole coordinator stack (router →
//! batcher → engine thread) serves real PPC computation offline; the
//! results are bit-exact with the fixed-point application simulations
//! (`gdf_filter`, `blend_images`, `forward_fx`) — exactness on the care
//! set is the paper's contract, and the units assert it at synthesis
//! time.
//!
//! Construction synthesizes hardware (two-level → multi-level → tech
//! map per block) unless a persistent [`NetlistCache`] is attached
//! with [`NativeExecutor::with_cache`]: then every block whose BLIF is
//! already on disk (and verifies against the current care set) loads
//! without any synthesis, making the second cold start effectively
//! instant — [`ModelInfo::cached`] records, per model, whether the
//! whole datapath came in warm. Sparse configs (`ds16`, `ds32`,
//! `th48ds16`) synthesize in well under a second even uncached;
//! full-range `conv` blocks take the longest and profit the most from
//! the cache.
//!
//! Under sticky placement a shard no longer builds the whole catalog:
//! [`NativeExecutor::declare`] / [`NativeExecutor::declare_frnn`]
//! record a *recipe* (how to build a key) without building it, and
//! [`NativeExecutor::with_keys`] eagerly constructs just the shard's
//! assigned subset. Any other declared key is built **lazily on
//! demand** the first time a request for it arrives — spill traffic or
//! failover after another shard's build error. With a persistent cache
//! attached (the default for `serve`) that failover costs a BLIF load,
//! not a synthesis run; without one (`--no-cache`) the first spilled
//! request for a key pays full synthesis on the shard thread.
//! [`ModelInfo::lazy`] records which residents arrived that way.

use crate::apps::blend::{BlendConfig, BlendHardware};
use crate::apps::frnn::hw::FrnnHardware;
use crate::apps::frnn::net::QuantFrnn;
use crate::apps::gdf::GdfHardware;
use crate::apps::quality;
use crate::catalog::{self, App, Datapath, ModelKey, PpcConfig, QualityProfile, Tensor};
use crate::coordinator::engine::Executor;
use crate::logic::map::Objective;
use crate::ppc::preprocess::ValueSet;
use crate::ppc::units::{FreshSynth, NetlistSource};
use crate::runtime::cache::NetlistCache;
use anyhow::{anyhow, bail, Result};
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-model registration record: what the catalog knows about one
/// servable datapath (the `serve --list-models` row).
#[derive(Clone, Debug)]
pub struct ModelInfo {
    pub key: ModelKey,
    /// Total mapped-gate count of the datapath's netlists.
    pub gates: usize,
    /// Wall-clock time registration took (synthesis or cache load).
    pub build_time: Duration,
    /// True when every netlist came from the persistent cache — i.e.
    /// registration performed zero two-level synthesis.
    pub cached: bool,
    /// True when the model was registered lazily, on the first request
    /// for an unplaced key, instead of at construction.
    pub lazy: bool,
    /// Concurrent requests one bit-sliced netlist pass can carry
    /// ([`catalog::LANES`] word lanes).
    pub lanes: usize,
    /// Execution backend of the datapath's units: `"lut"`, `"tape"`, or
    /// `"mixed"` (per-unit selection under `--unit-backend auto`).
    pub backend: String,
    /// Measured quality of this tier (PSNR vs the precise tier for the
    /// image apps, top-1 accuracy on the in-tree eval split for FRNN),
    /// measured at declaration against the bit-exact fixed-point sims.
    pub quality: Option<QualityProfile>,
}

struct Model {
    datapath: Box<dyn Datapath>,
    info: ModelInfo,
}

/// How to build one declared model from a netlist source — stored so
/// unbuilt keys can register lazily when a request arrives for them.
type Recipe = Box<dyn Fn(&dyn NetlistSource, Objective) -> Box<dyn Datapath> + Send + Sync>;

/// The native model registry: the typed catalog of servable PPC
/// datapaths. `recipes` is everything the executor *can* serve;
/// `models` is what is built (resident) right now.
pub struct NativeExecutor {
    objective: Objective,
    cache: Option<NetlistCache>,
    recipes: BTreeMap<ModelKey, Recipe>,
    /// Measured quality per declared key — computed once at declaration
    /// (cached alongside the BLIF entries when a cache is attached), so
    /// lazy builds and `--list-models` report it without re-measuring.
    qualities: BTreeMap<ModelKey, QualityProfile>,
    models: Mutex<BTreeMap<ModelKey, Arc<Model>>>,
}

impl Default for NativeExecutor {
    fn default() -> Self {
        NativeExecutor::new()
    }
}

impl NativeExecutor {
    /// An empty registry (area-optimized mapping, no persistent cache).
    pub fn new() -> NativeExecutor {
        NativeExecutor {
            objective: Objective::Area,
            cache: None,
            recipes: BTreeMap::new(),
            qualities: BTreeMap::new(),
            models: Mutex::new(BTreeMap::new()),
        }
    }

    /// Change the technology-mapping objective for *subsequently*
    /// registered models.
    pub fn objective(mut self, objective: Objective) -> NativeExecutor {
        self.objective = objective;
        self
    }

    /// Attach a persistent netlist cache rooted at `dir`: subsequently
    /// registered models load their mapped netlists from BLIF on disk
    /// when present (verified on the care set) and write them back
    /// after synthesis otherwise.
    pub fn with_cache(mut self, dir: impl Into<std::path::PathBuf>) -> Result<NativeExecutor> {
        self.cache = Some(NetlistCache::new(dir)?);
        Ok(self)
    }

    /// The attached persistent cache, if any (its hit/miss counters
    /// tell whether construction synthesized anything).
    pub fn cache(&self) -> Option<&NetlistCache> {
        self.cache.as_ref()
    }

    /// Record how to build `key` without building it. Declared keys are
    /// servable: a request for one that is not resident registers it
    /// lazily. FRNN models carry weights, so they go through
    /// [`NativeExecutor::declare_frnn`] instead.
    pub fn declare(mut self, key: ModelKey) -> Result<NativeExecutor> {
        let key = ModelKey::new(key.app, key.config)?; // revalidate
        let config = key.config;
        let recipe: Recipe = match key.app {
            App::Gdf => Box::new(move |src, obj| {
                Box::new(GdfHardware::synthesize_via(
                    &ValueSet::full(8),
                    &config.chain(),
                    obj,
                    src,
                )) as Box<dyn Datapath>
            }),
            App::Blend => Box::new(move |src, obj| {
                // natural coefficient sparsity: alpha stays in [0, 127],
                // the Job::Blend contract
                let cfg = BlendConfig::of(true, config.chain());
                Box::new(BlendHardware::synthesize_via(&cfg, obj, src)) as Box<dyn Datapath>
            }),
            App::Frnn => {
                bail!("{key}: the FRNN datapath carries weights — declare it with declare_frnn")
            }
        };
        // measure the tier's quality against the fixed-point sims
        // (serving is bit-exact with them), drawing from / feeding the
        // persistent cache so warm starts don't re-measure
        let dir = self.cache.as_ref().map(|c| c.dir());
        let profile = quality::measure_image_app_cached(dir, key.app, config)?;
        self.qualities.insert(key, profile);
        self.recipes.insert(key, recipe);
        Ok(self)
    }

    /// Record how to build the FRNN forward path under `frnn/{config}`
    /// with the given quantized weights, without building it.
    pub fn declare_frnn(mut self, config: PpcConfig, net: QuantFrnn) -> Result<NativeExecutor> {
        let key = ModelKey::new(App::Frnn, config)?;
        // measured accuracy is weight-dependent, so the cache entry is
        // fingerprinted by the quantized weights
        let dir = self.cache.as_ref().map(|c| c.dir());
        let profile = quality::measure_frnn_cached(dir, config, &net);
        self.qualities.insert(key, profile);
        let recipe: Recipe = Box::new(move |src, obj| {
            Box::new(FrnnHardware::synthesize_via(
                net.clone(),
                &config.chain(),
                &config.weight_chain(),
                obj,
                src,
            )) as Box<dyn Datapath>
        });
        self.recipes.insert(key, recipe);
        Ok(self)
    }

    /// Eagerly build every key in `keys` (each must be declared) — the
    /// subset-construction entry point for a placed shard. Keys already
    /// resident are skipped.
    pub fn with_keys(self, keys: &[ModelKey]) -> Result<NativeExecutor> {
        for &key in keys {
            if self.models.lock().unwrap().contains_key(&key) {
                continue;
            }
            let recipe = self.recipes.get(&key).ok_or_else(|| self.unknown(key))?;
            let model = Arc::new(build_model(
                key,
                recipe,
                self.objective,
                self.cache.as_ref(),
                false,
                self.qualities.get(&key).copied(),
            ));
            self.models.lock().unwrap().insert(key, model);
        }
        Ok(self)
    }

    /// Synthesize (or cache-load) and register the datapath for `key`
    /// immediately (declare + build). FRNN models carry weights, so
    /// they go through [`NativeExecutor::register_frnn`] instead.
    pub fn register(self, key: ModelKey) -> Result<NativeExecutor> {
        self.declare(key)?.with_keys(&[key])
    }

    /// Synthesize (or cache-load) and register the FRNN forward path
    /// under `frnn/{config}` with the given quantized weights.
    pub fn register_frnn(self, config: PpcConfig, net: QuantFrnn) -> Result<NativeExecutor> {
        let key = ModelKey::new(App::Frnn, config)?;
        self.declare_frnn(config, net)?.with_keys(&[key])
    }

    /// Resident (built) keys, in catalog order.
    pub fn registered_keys(&self) -> Vec<ModelKey> {
        self.models.lock().unwrap().keys().copied().collect()
    }

    /// Every servable key — resident or lazily buildable — in catalog
    /// order.
    pub fn declared_keys(&self) -> Vec<ModelKey> {
        self.recipes.keys().copied().collect()
    }

    /// Registration records for every resident model (the
    /// `serve --list-models` rows).
    pub fn model_infos(&self) -> Vec<ModelInfo> {
        self.models.lock().unwrap().values().map(|m| m.info.clone()).collect()
    }

    fn unknown(&self, key: ModelKey) -> anyhow::Error {
        anyhow!(
            "unknown model {key}; available models: [{}]",
            catalog::join(self.recipes.keys())
        )
    }

    /// Fetch `key`'s resident datapath, lazily registering it from its
    /// recipe (shared cache first) when it is declared but not built —
    /// the failover path behind sticky-placement spills.
    fn model(&self, key: ModelKey) -> Result<Arc<Model>> {
        if let Some(m) = self.models.lock().unwrap().get(&key) {
            return Ok(m.clone());
        }
        let recipe = self.recipes.get(&key).ok_or_else(|| self.unknown(key))?;
        // build outside the lock: synthesis/cache-load can take a
        // while, and an executor is driven by one shard thread anyway
        let model = Arc::new(build_model(
            key,
            recipe,
            self.objective,
            self.cache.as_ref(),
            true,
            self.qualities.get(&key).copied(),
        ));
        eprintln!(
            "lazy-registered {key} in {:.1} ms ({})",
            model.info.build_time.as_secs_f64() * 1e3,
            if model.info.cached { "from netlist cache" } else { "fresh synthesis" }
        );
        let mut models = self.models.lock().unwrap();
        Ok(models.entry(key).or_insert(model).clone())
    }
}

/// Build one model from its recipe, drawing netlists from the
/// persistent cache when one is attached.
fn build_model(
    key: ModelKey,
    recipe: &Recipe,
    objective: Objective,
    cache: Option<&NetlistCache>,
    lazy: bool,
    quality: Option<QualityProfile>,
) -> Model {
    let t0 = Instant::now();
    let (datapath, cached) = match cache {
        Some(cache) => {
            let scope = cache.scope(key, objective);
            let dp = recipe(&scope, objective);
            let cached = scope.misses() == 0 && scope.hits() > 0;
            (dp, cached)
        }
        None => (recipe(&FreshSynth, objective), false),
    };
    let info = ModelInfo {
        key,
        gates: datapath.num_gates(),
        build_time: t0.elapsed(),
        cached,
        lazy,
        lanes: catalog::LANES,
        backend: datapath.backend_name().to_string(),
        quality,
    };
    Model { datapath, info }
}

impl Executor for NativeExecutor {
    fn exec(&self, key: ModelKey, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let model = self.model(key)?;
        model.datapath.exec(inputs).map_err(|e| anyhow!("{key}: {e:#}"))
    }

    /// Lane-batched execution: the whole batch goes to the datapath's
    /// [`Datapath::exec_batch`], which pools requests into 256-lane
    /// compiled-tape passes.
    fn exec_batch(&self, key: ModelKey, batch: &[Vec<Tensor>]) -> Result<Vec<Vec<Tensor>>> {
        let model = self.model(key)?;
        model.datapath.exec_batch(batch).map_err(|e| anyhow!("{key}: {e:#}"))
    }

    fn keys(&self) -> Vec<ModelKey> {
        self.declared_keys()
    }

    fn resident_keys(&self) -> Vec<ModelKey> {
        self.registered_keys()
    }

    fn quality(&self, key: ModelKey) -> Option<QualityProfile> {
        self.qualities.get(&key).copied()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::apps::gdf;
    use crate::apps::image::{synthetic_photo, Image};
    use crate::util::prng::Rng;

    fn mk(s: &str) -> ModelKey {
        ModelKey::parse(s).unwrap()
    }

    #[test]
    fn gdf_exec_matches_fixed_point_sim() {
        let ex = NativeExecutor::new().register(mk("gdf/ds32")).unwrap();
        assert_eq!(ex.registered_keys(), vec![mk("gdf/ds32")]);
        // GDF is all-adder hardware, so auto selection lands on one
        // uniform backend, never "mixed"
        let backend = &ex.model_infos()[0].backend;
        assert!(backend == "lut" || backend == "tape", "{backend}");
        let img = synthetic_photo(16, 16, 9);
        let out = ex.exec(mk("gdf/ds32"), &[img.to_tensor()]).unwrap();
        let want = gdf::gdf_filter(&img, &PpcConfig::Ds32.chain());
        assert_eq!(out[0], want.to_tensor());
    }

    #[test]
    fn gdf_serves_non_square_images() {
        let ex = NativeExecutor::new().register(mk("gdf/ds32")).unwrap();
        let img = Image {
            width: 12,
            height: 5,
            pixels: (0..60).map(|i| (i * 4) as u8).collect(),
        };
        let out = ex.exec(mk("gdf/ds32"), &[img.to_tensor()]).unwrap();
        assert_eq!(out[0].shape, vec![5, 12]);
        let want = gdf::gdf_filter(&img, &PpcConfig::Ds32.chain());
        assert_eq!(out[0], want.to_tensor());
    }

    #[test]
    fn graceful_errors() {
        let ex = NativeExecutor::new().register(mk("gdf/ds32")).unwrap();
        // unknown key → structured error listing the catalog
        let e = ex.exec(mk("gdf/ds16"), &[Tensor::vector(vec![0; 16])]).unwrap_err();
        let msg = format!("{e}");
        assert!(msg.contains("unknown model gdf/ds16"), "{msg}");
        assert!(msg.contains("available models: [gdf/ds32]"), "{msg}");
        assert!(ex
            .exec(mk("blend/ds32"), &[Tensor::vector(vec![0; 4])])
            .is_err());
        // flat non-square image
        assert!(ex.exec(mk("gdf/ds32"), &[Tensor::vector(vec![0; 15])]).is_err());
        // out-of-range pixel
        assert!(ex.exec(mk("gdf/ds32"), &[Tensor::vector(vec![300; 16])]).is_err());
        // wrong arity
        let t = Tensor::vector(vec![0; 16]);
        assert!(ex.exec(mk("gdf/ds32"), &[t.clone(), t]).is_err());
    }

    #[test]
    fn every_registered_tier_carries_a_measured_quality() {
        use crate::catalog::{Quality, QualityMetric, PSNR_CAP};
        let ex = NativeExecutor::new()
            .register(mk("gdf/conv"))
            .unwrap()
            .register(mk("gdf/ds32"))
            .unwrap();
        let infos = ex.model_infos();
        let conv = infos.iter().find(|i| i.key == mk("gdf/conv")).unwrap();
        let ds32 = infos.iter().find(|i| i.key == mk("gdf/ds32")).unwrap();
        let (cq, dq) = (conv.quality.unwrap(), ds32.quality.unwrap());
        assert_eq!(cq.metric, QualityMetric::Psnr);
        assert_eq!(cq.reference, Quality::Precise);
        assert_eq!(cq.value, PSNR_CAP, "the precise tier measures at the identity cap");
        assert!(dq.value < cq.value, "ds32 must measure below conv: {dq} vs {cq}");
        // the Executor surface reports the same numbers the infos carry
        assert_eq!(ex.quality(mk("gdf/ds32")), Some(dq));
        assert_eq!(ex.quality(mk("blend/ds16")), None, "undeclared keys are unmeasured");
    }

    #[test]
    fn registration_rejects_catalog_violations() {
        // th48ds16 is an FRNN-only config
        assert!(NativeExecutor::new()
            .register(ModelKey { app: App::Gdf, config: PpcConfig::Th48Ds16 })
            .is_err());
        // frnn needs weights
        let e = NativeExecutor::new().register(mk("frnn/ds32")).unwrap_err();
        assert!(format!("{e}").contains("declare_frnn"), "{e}");
    }

    #[test]
    fn with_keys_builds_only_the_assigned_subset() {
        let ex = NativeExecutor::new()
            .declare(mk("gdf/ds16"))
            .unwrap()
            .declare(mk("gdf/ds32"))
            .unwrap()
            .with_keys(&[mk("gdf/ds32")])
            .unwrap();
        assert_eq!(ex.declared_keys(), vec![mk("gdf/ds16"), mk("gdf/ds32")]);
        assert_eq!(ex.registered_keys(), vec![mk("gdf/ds32")], "only the subset is resident");
        assert_eq!(ex.keys(), ex.declared_keys(), "declared keys are servable");
        // building an undeclared key is a structured error
        let e = NativeExecutor::new().with_keys(&[mk("gdf/ds16")]).unwrap_err();
        assert!(format!("{e}").contains("unknown model gdf/ds16"), "{e}");
    }

    #[test]
    fn declared_but_unbuilt_keys_register_lazily_on_first_request() {
        let ex = NativeExecutor::new()
            .declare(mk("gdf/ds16"))
            .unwrap()
            .declare(mk("gdf/ds32"))
            .unwrap()
            .with_keys(&[mk("gdf/ds32")])
            .unwrap();
        let img = synthetic_photo(10, 10, 4);
        // first request for the unbuilt key builds it on demand…
        let out = ex.exec(mk("gdf/ds16"), &[img.to_tensor()]).unwrap();
        assert_eq!(out[0], gdf::gdf_filter(&img, &PpcConfig::Ds16.chain()).to_tensor());
        assert_eq!(
            ex.registered_keys(),
            vec![mk("gdf/ds16"), mk("gdf/ds32")],
            "lazy registration makes the key resident"
        );
        let infos = ex.model_infos();
        let ds16 = infos.iter().find(|i| i.key == mk("gdf/ds16")).unwrap();
        assert!(ds16.lazy, "ds16 was built on demand");
        assert!(!infos.iter().find(|i| i.key == mk("gdf/ds32")).unwrap().lazy);
        // …and an undeclared key still fails with the declared catalog
        let e = ex.exec(mk("blend/ds32"), &[img.to_tensor()]).unwrap_err();
        assert!(
            format!("{e}").contains("available models: [gdf/ds16, gdf/ds32]"),
            "{e}"
        );
    }

    #[test]
    fn lazy_registration_draws_from_the_shared_cache() {
        let dir = std::env::temp_dir()
            .join(format!("ppc_native_lazy_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        // warm the cache with a plain registration…
        NativeExecutor::new()
            .with_cache(&dir)
            .unwrap()
            .register(mk("gdf/ds32"))
            .unwrap();
        // …then let a subset executor pick the key up lazily: the build
        // must come from BLIF, not synthesis
        let ex = NativeExecutor::new()
            .with_cache(&dir)
            .unwrap()
            .declare(mk("gdf/ds32"))
            .unwrap()
            .with_keys(&[])
            .unwrap();
        assert!(ex.registered_keys().is_empty());
        let img = synthetic_photo(8, 8, 2);
        let out = ex.exec(mk("gdf/ds32"), &[img.to_tensor()]).unwrap();
        assert_eq!(out[0], gdf::gdf_filter(&img, &PpcConfig::Ds32.chain()).to_tensor());
        assert_eq!(ex.cache().unwrap().misses(), 0, "lazy failover must not synthesize");
        let infos = ex.model_infos();
        assert!(infos[0].lazy && infos[0].cached);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn blend_exec_matches_fixed_point_sim() {
        use crate::apps::blend::{self, Alpha};
        let ex = NativeExecutor::new().register(mk("blend/ds32")).unwrap();
        let mut rng = Rng::new(0xB1);
        let p1: Vec<i32> = (0..100).map(|_| rng.below(256) as i32).collect();
        let p2: Vec<i32> = (0..100).map(|_| rng.below(256) as i32).collect();
        let out = ex
            .exec(
                mk("blend/ds32"),
                &[
                    Tensor::matrix(10, 10, p1.clone()).unwrap(),
                    Tensor::matrix(10, 10, p2.clone()).unwrap(),
                    Tensor::scalar(32),
                ],
            )
            .unwrap();
        assert_eq!(out[0].shape, vec![10, 10], "blend keeps the request shape");
        let chain = PpcConfig::Ds32.chain();
        for (j, &o) in out[0].data.iter().enumerate() {
            let want = blend::blend_pixel(p1[j] as u8, p2[j] as u8, Alpha(32), &chain, &chain);
            assert_eq!(o, want as i32, "pixel {j}");
        }
        // alpha out of the natural range is rejected, not miscomputed
        assert!(ex
            .exec(
                mk("blend/ds32"),
                &[
                    Tensor::vector(p1.clone()),
                    Tensor::vector(p2.clone()),
                    Tensor::scalar(200)
                ],
            )
            .is_err());
        // shape-mismatched images are rejected before pixel checks
        assert!(ex
            .exec(
                mk("blend/ds32"),
                &[
                    Tensor::matrix(10, 10, p1).unwrap(),
                    Tensor::matrix(4, 25, p2).unwrap(),
                    Tensor::scalar(32)
                ],
            )
            .is_err());
    }

    #[test]
    fn warm_cache_construction_performs_zero_synthesis() {
        let dir = std::env::temp_dir()
            .join(format!("ppc_native_warm_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);

        // cold: everything synthesizes and lands in the cache
        let cold = NativeExecutor::new()
            .with_cache(&dir)
            .unwrap()
            .register(mk("gdf/ds32"))
            .unwrap();
        let cold_misses = cold.cache().unwrap().misses();
        assert!(cold_misses > 0);
        assert!(!cold.model_infos()[0].cached);

        // warm: a brand-new executor over the same dir loads every
        // netlist from BLIF — zero two-level synthesis (zero misses)
        let warm = NativeExecutor::new()
            .with_cache(&dir)
            .unwrap()
            .register(mk("gdf/ds32"))
            .unwrap();
        assert_eq!(warm.cache().unwrap().misses(), 0, "warm start must not synthesize");
        assert_eq!(warm.cache().unwrap().hits(), cold_misses);
        assert!(warm.model_infos()[0].cached);

        // …and serves bit-exact results
        let img = synthetic_photo(12, 12, 3);
        let out = warm.exec(mk("gdf/ds32"), &[img.to_tensor()]).unwrap();
        assert_eq!(out[0], gdf::gdf_filter(&img, &PpcConfig::Ds32.chain()).to_tensor());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
