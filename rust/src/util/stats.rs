//! Small statistics helpers shared by the bench harness, the error-analysis
//! module and the coordinator's metrics.

/// Summary statistics over a sample of f64s.
#[derive(Clone, Debug, Default)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p90: f64,
    pub p99: f64,
    pub p999: f64,
}

impl Summary {
    /// Compute a summary; `samples` is consumed (sorted in place).
    pub fn of(mut samples: Vec<f64>) -> Summary {
        if samples.is_empty() {
            return Summary::default();
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = samples.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        Summary {
            n,
            mean,
            std: var.sqrt(),
            min: samples[0],
            max: samples[n - 1],
            p50: percentile_sorted(&samples, 0.50),
            p90: percentile_sorted(&samples, 0.90),
            p99: percentile_sorted(&samples, 0.99),
            p999: percentile_sorted(&samples, 0.999),
        }
    }
}

/// Linear-interpolated percentile of an ascending-sorted slice.
pub fn percentile_sorted(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = pos - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Peak signal-to-noise ratio between two same-length u8 signals,
/// with the conventional 255 peak. Returns `f64::INFINITY` for identical
/// inputs (the paper reports this as "Ideal").
pub fn psnr_u8(a: &[u8], b: &[u8]) -> f64 {
    assert_eq!(a.len(), b.len());
    assert!(!a.is_empty());
    let mse: f64 = a
        .iter()
        .zip(b)
        .map(|(&x, &y)| {
            let d = x as f64 - y as f64;
            d * d
        })
        .sum::<f64>()
        / a.len() as f64;
    if mse == 0.0 {
        f64::INFINITY
    } else {
        10.0 * (255.0f64 * 255.0 / mse).log10()
    }
}

/// Mean squared error between two f64 slices.
pub fn mse(a: &[f64], b: &[f64]) -> f64 {
    assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f64>() / a.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(vec![1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert!((s.mean - 3.0).abs() < 1e-12);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert!((s.p50 - 3.0).abs() < 1e-12);
        // the tail percentiles of a tiny sample collapse toward the max
        assert!(s.p999 >= s.p99 && s.p999 <= s.max + 1e-12);
    }

    #[test]
    fn percentile_interpolates() {
        let v = vec![0.0, 10.0];
        assert!((percentile_sorted(&v, 0.5) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn psnr_identical_is_infinite() {
        let a = vec![1u8, 2, 3];
        assert!(psnr_u8(&a, &a).is_infinite());
    }

    #[test]
    fn psnr_known_value() {
        // constant error of 1 everywhere: MSE = 1 -> PSNR = 20*log10(255)
        let a = vec![10u8; 100];
        let b = vec![11u8; 100];
        let expect = 20.0 * 255.0f64.log10();
        assert!((psnr_u8(&a, &b) - expect).abs() < 1e-9);
    }

    #[test]
    fn psnr_symmetric() {
        let a = vec![0u8, 100, 200];
        let b = vec![5u8, 90, 250];
        assert!((psnr_u8(&a, &b) - psnr_u8(&b, &a)).abs() < 1e-12);
    }
}
