//! Deterministic pseudo-random number generation.
//!
//! The offline vendor set has no `rand` crate, so we carry a small,
//! well-known generator: SplitMix64 for seeding and xoshiro256** for the
//! stream. Deterministic by construction — every experiment seeds its own
//! generator so tables and figures are exactly reproducible.

/// SplitMix64 step — used to expand a single `u64` seed into a full
/// xoshiro256** state. Reference: Steele, Lea, Flood (2014).
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// xoshiro256** PRNG (Blackman & Vigna). Fast, 256-bit state, passes
/// BigCrush; more than adequate for workload generation and property tests.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform u32.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift (unbiased enough
    /// for our purposes; bounds here are tiny relative to 2^64).
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as u64
    }

    /// Uniform in the inclusive range `[lo, hi]`.
    #[inline]
    pub fn range_inclusive(&mut self, lo: u64, hi: u64) -> u64 {
        debug_assert!(lo <= hi);
        lo + self.below(hi - lo + 1)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (pairs discarded; fine for our use).
    pub fn next_gaussian(&mut self) -> f64 {
        loop {
            let u1 = self.next_f64();
            let u2 = self.next_f64();
            if u1 > 1e-300 {
                return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
            }
        }
    }

    /// Bernoulli draw with probability `p`.
    #[inline]
    pub fn bool_with(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, slice: &mut [T]) {
        for i in (1..slice.len()).rev() {
            let j = self.below((i + 1) as u64) as usize;
            slice.swap(i, j);
        }
    }

    /// Pick a uniformly random element.
    pub fn choose<'a, T>(&mut self, slice: &'a [T]) -> &'a T {
        &slice[self.below(slice.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn below_respects_bound() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(10) < 10);
        }
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniformity_rough() {
        let mut r = Rng::new(3);
        let mut buckets = [0usize; 10];
        for _ in 0..100_000 {
            buckets[r.below(10) as usize] += 1;
        }
        for &b in &buckets {
            assert!((8_000..12_000).contains(&b), "bucket {b} out of range");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.next_gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }
}
