//! Minimal property-based testing driver (no `proptest` offline).
//!
//! `forall(seed, cases, gen, prop)` draws `cases` random inputs from `gen`
//! and asserts `prop` on each; on failure it performs a simple greedy
//! shrink (if a `shrink` function is supplied via [`forall_shrink`]) and
//! panics with the minimized counterexample, mirroring the workflow of a
//! real property-testing crate.

use super::prng::Rng;
use std::fmt::Debug;

/// Check `prop` on `cases` random values produced by `gen`.
pub fn forall<T, G, P>(seed: u64, cases: usize, mut gen: G, mut prop: P)
where
    T: Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            panic!("property failed at case {case}: input = {input:?}");
        }
    }
}

/// Like [`forall`] but with a shrinker: `shrink(x)` yields candidate
/// smaller inputs; the first failing candidate is recursed into (greedy,
/// depth-bounded).
pub fn forall_shrink<T, G, P, S>(seed: u64, cases: usize, mut gen: G, mut prop: P, shrink: S)
where
    T: Debug + Clone,
    G: FnMut(&mut Rng) -> T,
    P: FnMut(&T) -> bool,
    S: Fn(&T) -> Vec<T>,
{
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let input = gen(&mut rng);
        if !prop(&input) {
            // Greedy shrink, bounded to keep failure paths fast.
            let mut current = input.clone();
            'outer: for _depth in 0..64 {
                for cand in shrink(&current) {
                    if !prop(&cand) {
                        current = cand;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed at case {case}:\n  original = {input:?}\n  shrunk   = {current:?}"
            );
        }
    }
}

/// Standard shrinker for unsigned integers: 0, halves, decrement.
pub fn shrink_u64(x: &u64) -> Vec<u64> {
    let x = *x;
    let mut out = Vec::new();
    if x > 0 {
        out.push(0);
        out.push(x / 2);
        out.push(x - 1);
    }
    out.dedup();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        forall(1, 500, |r| r.below(100), |&x| x < 100);
    }

    #[test]
    #[should_panic(expected = "property failed")]
    fn failing_property_panics() {
        forall(2, 500, |r| r.below(100), |&x| x < 50);
    }

    #[test]
    #[should_panic(expected = "shrunk")]
    fn shrinker_minimizes() {
        forall_shrink(
            3,
            100,
            |r| r.below(10_000),
            |&x| x < 17, // fails for x >= 17; shrink should walk toward 17
            |x| shrink_u64(x),
        );
    }
}
