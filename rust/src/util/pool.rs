//! A small scoped thread pool (no `rayon` in the offline vendor set).
//!
//! Provides `scope_chunks` — the single parallel primitive the hot paths
//! need: split an index range into contiguous chunks and run a closure per
//! chunk on `std::thread::scope` threads, collecting per-chunk results.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

/// Number of worker threads to use: respects `PPC_THREADS` if set,
/// otherwise `available_parallelism`, capped at 16.
///
/// The resolved count is cached on first call — this is consulted inside
/// batch hot loops, and an env-var read per lane pass is measurable.
pub fn default_threads() -> usize {
    static CACHED: OnceLock<usize> = OnceLock::new();
    *CACHED.get_or_init(|| {
        if let Ok(v) = std::env::var("PPC_THREADS") {
            if let Ok(n) = v.parse::<usize>() {
                return n.max(1);
            }
        }
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(4)
            .min(16)
    })
}

/// Explicit per-process batch-execution thread count (0 = unset).
///
/// Precedence: an explicit [`set_batch_threads`] always wins (benches and
/// `serve` use it for exact control); otherwise [`default_threads`] applies,
/// which itself honors `PPC_THREADS`.
static BATCH_THREADS: AtomicUsize = AtomicUsize::new(0);

/// Set the thread count used by batch execution (`add_many`/`mul_many` and
/// the app `exec_batch` poolers). `0` clears the override, falling back to
/// [`default_threads`].
pub fn set_batch_threads(n: usize) {
    BATCH_THREADS.store(n, Ordering::Relaxed);
}

/// Thread count for chunk-parallel batch execution: the explicit
/// [`set_batch_threads`] value if set, else [`default_threads`].
pub fn batch_threads() -> usize {
    match BATCH_THREADS.load(Ordering::Relaxed) {
        0 => default_threads(),
        n => n,
    }
}

/// Serializes tests that mutate *any* process-global override — the
/// batch-thread count here and the unit-backend default in
/// `crate::ppc::lut`. Every value is bit-exact, but a test asserting a
/// *specific* global must not interleave with another test's override,
/// at any `--test-threads`. One shared lock (rather than one per
/// global) keeps the suite order-independent even when a single test
/// touches several overrides.
#[doc(hidden)]
pub fn process_override_test_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::Mutex<()> = std::sync::Mutex::new(());
    LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// The batch-thread spelling of [`process_override_test_lock`] (same
/// lock — kept so existing guard sites read naturally).
#[doc(hidden)]
pub fn batch_threads_test_lock() -> std::sync::MutexGuard<'static, ()> {
    process_override_test_lock()
}

/// Run `f(chunk_start, chunk_end)` over `[0, n)` split into `threads`
/// contiguous chunks; returns the per-chunk results in order.
///
/// `f` must be `Send + Sync` and is invoked once per chunk on its own
/// scoped thread (the last chunk runs on the calling thread to save a
/// spawn).
pub fn scope_chunks<R, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, usize) -> R + Send + Sync,
{
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 || n == 0 {
        return vec![f(0, n)];
    }
    let chunk = n.div_ceil(threads);
    let bounds: Vec<(usize, usize)> = (0..threads)
        .map(|t| (t * chunk, ((t + 1) * chunk).min(n)))
        .filter(|(s, e)| s < e)
        .collect();
    let mut results: Vec<Option<R>> = Vec::new();
    results.resize_with(bounds.len(), || None);
    let fref = &f;
    std::thread::scope(|scope| {
        let mut handles = Vec::new();
        let mut iter = results.iter_mut().zip(bounds.iter());
        // keep one chunk for this thread
        let last = iter.next_back();
        for (slot, &(s, e)) in iter {
            handles.push(scope.spawn(move || {
                *slot = Some(fref(s, e));
            }));
        }
        if let Some((slot, &(s, e))) = last {
            *slot = Some(fref(s, e));
        }
        for h in handles {
            h.join().expect("pool worker panicked");
        }
    });
    results.into_iter().map(|r| r.unwrap()).collect()
}

/// Parallel map over items by index: returns `Vec<R>` with `R = f(i)` for
/// each `i in 0..n`, computed on up to `threads` threads.
pub fn par_map_index<R, F>(n: usize, threads: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Send + Sync,
{
    let per_chunk = scope_chunks(n, threads, |s, e| (s..e).map(&f).collect::<Vec<R>>());
    per_chunk.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chunks_cover_range() {
        let parts = scope_chunks(103, 8, |s, e| (s, e));
        let mut expect = 0;
        for (s, e) in parts {
            assert_eq!(s, expect);
            assert!(e > s);
            expect = e;
        }
        assert_eq!(expect, 103);
    }

    #[test]
    fn par_map_matches_serial() {
        let par = par_map_index(1000, 8, |i| i * i);
        let ser: Vec<usize> = (0..1000).map(|i| i * i).collect();
        assert_eq!(par, ser);
    }

    #[test]
    fn single_thread_and_empty() {
        assert_eq!(par_map_index(0, 4, |i| i).len(), 0);
        assert_eq!(par_map_index(5, 1, |i| i), vec![0, 1, 2, 3, 4]);
    }

    #[test]
    fn batch_threads_override_wins_and_clears() {
        let _guard = batch_threads_test_lock();
        // default_threads() is >= 1 whatever the environment
        assert!(batch_threads() >= 1);
        set_batch_threads(3);
        assert_eq!(batch_threads(), 3);
        set_batch_threads(0);
        assert_eq!(batch_threads(), default_threads());
    }

    #[test]
    fn sums_parallel() {
        let partials = scope_chunks(1_000_000, 8, |s, e| (s..e).map(|i| i as u64).sum::<u64>());
        let total: u64 = partials.into_iter().sum();
        assert_eq!(total, 499_999_500_000);
    }
}
