//! Minimal JSON support (no `serde` in the offline vendor set).
//!
//! A small value model, a writer, and a recursive-descent parser — enough to
//! round-trip the artifact metadata, trained FRNN weights, and experiment
//! reports this project exchanges with the python build layer.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// JSON value. Object keys are kept in a BTreeMap so emission is
/// deterministic (stable diffs in EXPERIMENTS.md).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }
    pub fn num_arr<'a, I: IntoIterator<Item = &'a f64>>(items: I) -> Json {
        Json::Arr(items.into_iter().map(|&x| Json::Num(x)).collect())
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Flatten a (possibly nested) numeric array into a Vec<f64>.
    pub fn flat_f64(&self) -> Vec<f64> {
        let mut out = Vec::new();
        fn rec(j: &Json, out: &mut Vec<f64>) {
            match j {
                Json::Num(x) => out.push(*x),
                Json::Arr(a) => a.iter().for_each(|e| rec(e, out)),
                _ => {}
            }
        }
        rec(self, &mut out);
        out
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.is_finite() {
                    if *x == x.trunc() && x.abs() < 1e15 {
                        let _ = write!(out, "{}", *x as i64);
                    } else {
                        let _ = write!(out, "{x}");
                    }
                } else {
                    // JSON has no Infinity; the paper prints "Ideal" for
                    // infinite PSNR — we encode it as null and let readers map it.
                    out.push_str("null");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, e) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    e.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    /// Parse a JSON document.
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut p = Parser { b: bytes, i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != bytes.len() {
            return Err(format!("trailing bytes at {}", p.i));
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.b.get(self.i) {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(b'N') => self.lit("NaN", Json::Num(f64::NAN)),
            Some(b'I') => self.lit("Infinity", Json::Num(f64::INFINITY)),
            Some(_) => self.number(),
            None => Err("unexpected eof".into()),
        }
    }

    fn lit(&mut self, text: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(text.as_bytes()) {
            self.i += text.len();
            Ok(v)
        } else {
            Err(format!("bad literal at {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if matches!(self.b.get(self.i), Some(b'-')) {
            self.i += 1;
            // python json.dumps may emit -Infinity
            if self.b[self.i..].starts_with(b"Infinity") {
                self.i += 8;
                return Ok(Json::Num(f64::NEG_INFINITY));
            }
        }
        while self
            .b
            .get(self.i)
            .map_or(false, |c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        debug_assert_eq!(self.b[self.i], b'"');
        self.i += 1;
        let mut s = String::new();
        loop {
            match self.b.get(self.i) {
                None => return Err("eof in string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.b.get(self.i) {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.i += 1; // [
        let mut items = Vec::new();
        self.ws();
        if matches!(self.b.get(self.i), Some(b']')) {
            self.i += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.ws();
            items.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected , or ] at {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.i += 1; // {
        let mut map = BTreeMap::new();
        self.ws();
        if matches!(self.b.get(self.i), Some(b'}')) {
            self.i += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.ws();
            let key = self.string()?;
            self.ws();
            if !matches!(self.b.get(self.i), Some(b':')) {
                return Err(format!("expected : at {}", self.i));
            }
            self.i += 1;
            self.ws();
            let val = self.value()?;
            map.insert(key, val);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected , or }} at {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let j = Json::obj(vec![
            ("a", Json::Num(1.0)),
            ("b", Json::Str("x\"y".into())),
            ("c", Json::arr(vec![Json::Bool(true), Json::Null])),
        ]);
        let s = j.to_string();
        let back = Json::parse(&s).unwrap();
        assert_eq!(j, back);
    }

    #[test]
    fn parse_nested() {
        let j = Json::parse(r#"{"w":[[1,2.5],[3,-4e2]],"name":"frnn"}"#).unwrap();
        assert_eq!(j.get("name").unwrap().as_str(), Some("frnn"));
        assert_eq!(j.get("w").unwrap().flat_f64(), vec![1.0, 2.5, 3.0, -400.0]);
    }

    #[test]
    fn parse_whitespace_and_escapes() {
        let j = Json::parse(" { \"k\" : \"a\\nb\\u0041\" } ").unwrap();
        assert_eq!(j.get("k").unwrap().as_str(), Some("a\nbA"));
    }

    #[test]
    fn integers_emitted_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string(), "3");
        assert_eq!(Json::Num(3.25).to_string(), "3.25");
    }

    #[test]
    fn rejects_trailing_garbage() {
        assert!(Json::parse("{} x").is_err());
    }

    #[test]
    fn python_infinity_literals() {
        // python json.dumps(float('inf')) emits bare Infinity
        let j = Json::parse("[Infinity, -Infinity]").unwrap();
        let v = j.flat_f64();
        assert!(v[0].is_infinite() && v[0] > 0.0);
        assert!(v[1].is_infinite() && v[1] < 0.0);
    }
}
