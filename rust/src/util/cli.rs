//! Tiny CLI argument parser (no `clap` in the offline vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, and positional
//! arguments; typed getters with defaults.

use std::collections::BTreeMap;

/// Parsed command-line arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    /// Parse from an explicit iterator (tests) or `std::env::args` (main).
    pub fn parse<I: IntoIterator<Item = String>>(items: I) -> Args {
        let mut out = Args::default();
        let mut it = items.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(rest) = a.strip_prefix("--") {
                if let Some((k, v)) = rest.split_once('=') {
                    out.options.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map_or(false, |nxt| !nxt.starts_with("--"))
                {
                    let v = it.next().unwrap();
                    out.options.insert(rest.to_string(), v);
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        out
    }

    pub fn from_env() -> Args {
        Args::parse(std::env::args().skip(1))
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.options.get(name).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn usize_or(&self, name: &str, default: usize) -> usize {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn u64_or(&self, name: &str, default: u64) -> u64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn f64_or(&self, name: &str, default: f64) -> f64 {
        self.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn mixes_positional_options_flags() {
        // NOTE: a bare `--name value` pair always binds as an option, so
        // flags go last or use `=`; positionals go before options.
        let a = parse("table1 extra --ds 16 --out=/tmp/x --verbose");
        assert_eq!(a.positional, vec!["table1", "extra"]);
        assert_eq!(a.get("ds"), Some("16"));
        assert_eq!(a.get("out"), Some("/tmp/x"));
        assert!(a.flag("verbose"));
    }

    #[test]
    fn typed_getters() {
        let a = parse("--n 42 --rate 0.5");
        assert_eq!(a.usize_or("n", 0), 42);
        assert!((a.f64_or("rate", 0.0) - 0.5).abs() < 1e-12);
        assert_eq!(a.usize_or("missing", 7), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = parse("--fast");
        assert!(a.flag("fast"));
        assert!(a.get("fast").is_none());
    }
}
