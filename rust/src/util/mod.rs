//! Support utilities hand-rolled for the offline build environment.
//!
//! Only the crates vendored at `/opt/xla-example/vendor` are available
//! (`xla`, `anyhow`, and transitive build deps) — so this module carries
//! small, tested replacements for the usual ecosystem crates:
//!
//! | would-be crate | here |
//! |---|---|
//! | `rand` / `rand_chacha` | [`prng`] (xoshiro256** + SplitMix64) |
//! | `serde`/`serde_json` | [`json`] (value model + writer + parser) |
//! | `rayon` | [`pool`] (scoped chunked thread pool) |
//! | `clap` | [`cli`] (flags / `--key value` / positional) |
//! | `criterion` | [`bench`] (warmup + timed iters + percentiles) |
//! | `proptest` | [`propcheck`] (randomized properties + greedy shrink) |

pub mod bench;
pub mod cli;
pub mod json;
pub mod pool;
pub mod prng;
pub mod propcheck;
pub mod stats;
